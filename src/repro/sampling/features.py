"""Per-machine feature shards + halo cache for minibatch training.

A minibatch's sampled ids are useless to a trainer without the feature
rows behind them.  This module adds the feature tensor path on top of
the owner map :class:`~repro.sampling.machine_csc.MachineCSC` already
defines:

* :class:`FeatureStore` packs, one shard at a time, each machine's
  *owned* vertices' feature rows (``shards[i][r]`` is the feature row of
  ``owned_gid[i, r]`` — the same owner-local row ids the sampler's flat
  tables use).  A machine resolves its own vertices' rows locally; every
  remote vertex in a batch costs one cross-machine fetch, deduplicated
  batch-wide.
* :class:`HaloCache` sits in front of the remote fetch: a **static hub
  tier** (the globally highest-degree remote vertices, preloaded, never
  evicted — power-law frontiers hit hubs constantly, so pinning them is
  cheap insurance) plus an **LRU tail** for the long tail of recent
  remote rows.

``FeatureStore.gather`` is the per-batch resolve: local rows from the
home shard, cache hits from the cache, and the remaining misses via one
deduplicated batched fetch whose rows are inserted into the LRU tail.
Cached and uncached resolution are bitwise identical (cache rows came
from the same shards); the per-gather :class:`FetchStats` record
hit/miss/bytes, and the per-hop ``fetched_unique`` stat the service
records is exactly the zero-cache miss upper bound — which makes the
benchmark's hit-rate-vs-budget study (``benchmarks/sampling_service.py
--cache-study``) an eviction study against a known ceiling.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from ..bsp.partition_runtime import PartitionRuntime
from .machine_csc import MachineCSC


@dataclasses.dataclass
class FetchStats:
    """Accounting for one :meth:`FeatureStore.gather` call.

    ``hits``/``misses`` count *deduplicated* remote vertices (so
    ``misses`` ≤ the batch's summed per-hop ``fetched_unique`` bound);
    ``local`` counts valid lanes resolved from the home shard and
    ``bytes_fetched`` is the cross-machine traffic this batch actually
    paid after the cache.
    """

    local: int = 0
    hits: int = 0
    misses: int = 0
    bytes_fetched: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.hits + self.misses)


class HaloCache:
    """Remote-feature cache: degree-ranked static hubs + an LRU tail.

    ``capacity`` is the **total** row budget; ``hub_ids`` (with their
    preloaded ``hub_rows``) occupy ``len(hub_ids)`` of it permanently
    and are never evicted, the remainder is the LRU tail.  Use
    :meth:`for_home` to build one with the hub tier auto-selected as the
    highest-global-degree vertices not owned by ``home``.
    """

    def __init__(self, capacity: int, hub_ids=(), hub_rows=None):
        capacity = int(capacity)
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        hub_ids = np.asarray(hub_ids, dtype=np.int64).reshape(-1)
        if len(hub_ids) > capacity:
            raise ValueError(f"{len(hub_ids)} hub ids exceed the total "
                             f"capacity {capacity}")
        if len(hub_ids) and (hub_rows is None
                             or len(hub_rows) != len(hub_ids)):
            raise ValueError("hub_rows must provide one preloaded row "
                             "per hub id")
        self.capacity = capacity
        self._hub = {int(v): np.asarray(hub_rows[j])
                     for j, v in enumerate(hub_ids)}
        self._lru: OrderedDict[int, np.ndarray] = OrderedDict()
        self.lru_capacity = capacity - len(self._hub)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_fetched = 0

    @classmethod
    def for_home(cls, store: "FeatureStore", home: int, capacity: int,
                 hub_frac: float = 0.5) -> "HaloCache":
        """Cache for machine ``home``: the ``ceil(capacity*hub_frac)``
        highest-global-degree vertices owned elsewhere become the
        preloaded hub tier (degree ties break to the lower vertex id),
        the rest of the budget is the LRU tail."""
        if not 0.0 <= hub_frac <= 1.0:
            raise ValueError(f"hub_frac must be in [0, 1], got {hub_frac}")
        gdeg = store.global_degree()
        remote = np.flatnonzero((store.csc.owner >= 0)
                                & (store.csc.owner != home))
        hub_n = min(int(np.ceil(int(capacity) * hub_frac)), len(remote))
        order = np.argsort(-gdeg[remote], kind="stable")[:hub_n]
        hub_ids = remote[order]
        return cls(capacity, hub_ids=hub_ids,
                   hub_rows=store.gather_global(hub_ids))

    @property
    def hub_ids(self) -> np.ndarray:
        return np.fromiter(self._hub.keys(), dtype=np.int64,
                           count=len(self._hub))

    def lru_ids(self) -> list:
        """LRU-tail ids, least-recent first (the eviction order)."""
        return list(self._lru.keys())

    def __contains__(self, vid) -> bool:
        return int(vid) in self._hub or int(vid) in self._lru

    def __len__(self) -> int:
        return len(self._hub) + len(self._lru)

    def lookup(self, vid: int):
        """The row for ``vid`` (refreshing its LRU recency) or ``None``.
        Hub hits never touch the LRU order — the tier is static."""
        vid = int(vid)
        row = self._hub.get(vid)
        if row is not None:
            return row
        row = self._lru.get(vid)
        if row is not None:
            self._lru.move_to_end(vid)
        return row

    def insert(self, vid: int, row: np.ndarray) -> None:
        """Admit a fetched row to the LRU tail (hubs are preloaded and
        ignore re-inserts), evicting the least-recent past capacity."""
        vid = int(vid)
        if vid in self._hub or self.lru_capacity == 0:
            return
        self._lru[vid] = row
        self._lru.move_to_end(vid)
        while len(self._lru) > self.lru_capacity:
            self._lru.popitem(last=False)
            self.evictions += 1

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.hits + self.misses)


class FeatureStore:
    """Owner-sharded vertex features over a partition's owner map."""

    def __init__(self, csc: MachineCSC, shards):
        if len(shards) != csc.p:
            raise ValueError(f"expected {csc.p} shards, got {len(shards)}")
        self.csc = csc
        self.shards = [np.asarray(s) for s in shards]
        dims = {s.shape[1:] for s in self.shards}
        if len(dims) != 1:
            raise ValueError(f"shards disagree on feature shape: {dims}")

    @classmethod
    def build(cls, source, features, **create_kw) -> "FeatureStore":
        """Shard ``features`` (``(V, F)``, any dtype) by vertex owner.

        ``source`` is anything that pins an owner map: a
        :class:`~repro.sampling.service.SamplingService`, a
        :class:`MachineCSC`, a ``PartitionRuntime``, or any
        ``PartitionRuntime.create`` source (``**create_kw`` forwarded).
        Shards are packed one machine at a time, so transient state
        never exceeds one shard beyond the input.
        """
        from .service import SamplingService
        if isinstance(source, SamplingService):
            csc = source.csc
        elif isinstance(source, MachineCSC):
            csc = source
        elif isinstance(source, PartitionRuntime):
            csc = MachineCSC.build(source)
        else:
            csc = MachineCSC.build(
                PartitionRuntime.create(source, **create_kw))
        features = np.asarray(features)
        if features.ndim < 2 or features.shape[0] != csc.num_vertices:
            raise ValueError(
                f"features must be (num_vertices={csc.num_vertices}, F), "
                f"got {features.shape}")
        shards = []
        for i in range(csc.p):
            n = int(csc.owned_per[i])
            shards.append(
                np.ascontiguousarray(features[csc.owned_gid[i, :n]]))
        return cls(csc, shards)

    @property
    def feat_dim(self) -> int:
        return int(np.prod(self.shards[0].shape[1:], dtype=np.int64))

    @property
    def row_bytes(self) -> int:
        return self.feat_dim * self.shards[0].dtype.itemsize

    def global_degree(self) -> np.ndarray:
        """(V,) global degree, scattered back from the owner shards."""
        csc = self.csc
        gdeg = np.zeros(csc.num_vertices, dtype=np.int64)
        for i in range(csc.p):
            n = int(csc.owned_per[i])
            gdeg[csc.owned_gid[i, :n]] = csc.deg[i, :n]
        return gdeg

    def gather_global(self, ids) -> np.ndarray:
        """Feature rows for ``ids`` with full shard knowledge — the
        uncached reference resolve (and the primitive a cross-machine
        fetch of remote rows bottoms out in).  ``-1`` lanes get zeros."""
        ids = np.asarray(ids, dtype=np.int64).reshape(-1)
        out = np.zeros((len(ids),) + self.shards[0].shape[1:],
                       dtype=self.shards[0].dtype)
        valid = np.flatnonzero(ids >= 0)
        own = self.csc.owner[ids[valid]]
        row = self.csc.row[ids[valid]]
        for m in np.unique(own):
            if m < 0:
                continue            # isolated vertices keep zeros
            sel = own == m
            out[valid[sel]] = self.shards[m][row[sel]]
        return out

    def gather(self, ids, home: int, cache: HaloCache | None = None):
        """Resolve ``ids`` for machine ``home``: local rows from its own
        shard, remote rows through ``cache`` (hub + LRU) with the
        residual misses fetched in one deduplicated batch and admitted
        to the cache.  Returns ``(rows, FetchStats)``; bitwise equal to
        :meth:`gather_global` for any cache state.
        """
        ids = np.asarray(ids, dtype=np.int64).reshape(-1)
        out = np.zeros((len(ids),) + self.shards[0].shape[1:],
                       dtype=self.shards[0].dtype)
        stats = FetchStats()
        valid = np.flatnonzero(ids >= 0)
        owner = self.csc.owner[ids[valid]]
        local = valid[owner == home]
        out[local] = self.shards[home][self.csc.row[ids[local]]]
        stats.local = len(local)
        remote = valid[(owner != home) & (owner >= 0)]
        if not len(remote):
            return out, stats
        uniq = np.unique(ids[remote])
        table = np.empty((len(uniq),) + self.shards[0].shape[1:],
                         dtype=self.shards[0].dtype)
        if cache is None:
            table[:] = self.gather_global(uniq)
            stats.misses = len(uniq)
        else:
            miss_pos = []
            for j, v in enumerate(uniq):
                row = cache.lookup(v)
                if row is None:
                    miss_pos.append(j)
                else:
                    table[j] = row
                    stats.hits += 1
            if miss_pos:
                miss_pos = np.asarray(miss_pos)
                fetched = self.gather_global(uniq[miss_pos])
                table[miss_pos] = fetched
                for j, v in zip(miss_pos, uniq[miss_pos]):
                    cache.insert(v, table[j])
                stats.misses = len(miss_pos)
            cache.hits += stats.hits
            cache.misses += stats.misses
        stats.bytes_fetched = stats.misses * self.row_bytes
        if cache is not None:
            cache.bytes_fetched += stats.bytes_fetched
        out[remote] = table[np.searchsorted(uniq, ids[remote])]
        return out, stats
