"""Async prefetch pipeline: overlap sampling with feature resolution.

Training wants ``(MiniBatch, features)`` pairs at a steady cadence; the
two stages that produce one — the fused k-hop device sample and the
host-side feature resolve (shard gathers + halo-cache bookkeeping) —
run on different resources, so :class:`PrefetchPipeline` overlaps them
graphbolt-datapipe-style: a bounded **sample** stage (seed draw → fused
k-hop dispatch) feeds a bounded **feature** stage (cache lookup →
deduplicated halo fetch) through depth-``depth`` queues, so batch
``i+1``'s sampling runs while batch ``i``'s features are still being
fetched.

Determinism is structural, not accidental:

* batch ``i``'s keys derive only from ``(key, i)`` —
  ``fold_in(key, i)`` then one ``split`` for (seed draw, hop keys) — so
  no stage ordering can change the sampled ids;
* the feature stage processes batches strictly in index order (one
  worker, FIFO queues), so the halo cache sees the same
  lookup/insert/evict sequence at every depth.

Hence ``depth=0`` (fully synchronous, no threads) and any ``depth >= 1``
yield **bitwise identical** batches, features, and cache stats — the
depth knob trades memory for overlap, never results.  Worker exceptions
propagate to the consumer on its next ``__next__`` (wrapped queues, no
silent death), and :meth:`close` shuts both workers down cleanly
mid-iteration (also invoked by ``with`` exit and on exhaustion).
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np


class _Err:
    """A worker exception crossing a stage queue."""

    def __init__(self, exc: BaseException):
        self.exc = exc


_DONE = object()


class PrefetchPipeline:
    """Bounded-depth double-buffered ``(MiniBatch, features)`` producer.

    Iterate it (``for mb, feats in pipeline``) or call ``next()``;
    ``feats`` is ``None`` when no ``store`` is given, otherwise the
    ``(len(mb.all_ids()), F)`` rows resolved through ``store``/``cache``
    for the batch's seeds + every hop, in that order.
    """

    def __init__(self, service, *, home: int, batch_size: int,
                 num_batches: int, key, depth: int = 2, store=None,
                 cache=None, train_mask=None, fused: bool = True):
        if depth < 0:
            raise ValueError(f"depth must be >= 0, got {depth}")
        if num_batches < 0:
            raise ValueError(f"num_batches must be >= 0, "
                             f"got {num_batches}")
        if cache is not None and store is None:
            raise ValueError("cache= without store= — the cache fronts "
                             "the feature store's remote fetches")
        self.service = service
        self.home = int(home)
        self.batch_size = int(batch_size)
        self.num_batches = int(num_batches)
        self.key = key
        self.depth = int(depth)
        self.store = store
        self.cache = cache
        self.train_mask = train_mask
        self.fused = bool(fused)
        self._emitted = 0
        self._closed = False
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._q_sample = None
        self._q_out = None

    # -- the two stages (shared verbatim by sync and threaded modes) ------

    def _sample_batch(self, i: int):
        """Stage 1 — keys from ``(key, i)`` only, then one fused k-hop
        dispatch; independent of pipeline depth by construction."""
        k_seed, k_hop = jax.random.split(jax.random.fold_in(self.key, i))
        seeds = self.service.local_seeds(self.home, self.batch_size,
                                         k_seed, self.train_mask)
        return self.service.sample(seeds, k_hop, home=self.home,
                                   fused=self.fused)

    def _resolve_features(self, mb):
        """Stage 2 — the batch's feature rows via shard + halo cache."""
        if self.store is None:
            return mb, None
        feats, _ = self.store.gather(mb.all_ids(), self.home, self.cache)
        return mb, feats

    # -- threaded plumbing -------------------------------------------------

    def _put(self, q, item) -> bool:
        while not self._stop.is_set():
            try:
                q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _sample_worker(self):
        try:
            for i in range(self.num_batches):
                if self._stop.is_set():
                    return
                if not self._put(self._q_sample, self._sample_batch(i)):
                    return
        except BaseException as exc:  # noqa: BLE001 — forwarded, not eaten
            self._put(self._q_sample, _Err(exc))
            return
        self._put(self._q_sample, _DONE)

    def _feature_worker(self):
        while not self._stop.is_set():
            try:
                item = self._q_sample.get(timeout=0.05)
            except queue.Empty:
                continue
            if item is _DONE or isinstance(item, _Err):
                self._put(self._q_out, item)
                return
            try:
                out = self._resolve_features(item)
            except BaseException as exc:  # noqa: BLE001
                self._put(self._q_out, _Err(exc))
                return
            if not self._put(self._q_out, out):
                return

    def _ensure_started(self):
        if self._threads or self.depth == 0:
            return
        self._q_sample = queue.Queue(maxsize=self.depth)
        self._q_out = queue.Queue(maxsize=self.depth)
        self._threads = [
            threading.Thread(target=self._sample_worker,
                             name="prefetch-sample", daemon=True),
            threading.Thread(target=self._feature_worker,
                             name="prefetch-features", daemon=True),
        ]
        for t in self._threads:
            t.start()

    # -- consumer surface --------------------------------------------------

    def __iter__(self):
        return self

    def __next__(self):
        if self._closed or self._emitted >= self.num_batches:
            self.close()
            raise StopIteration
        if self.depth == 0:
            out = self._resolve_features(self._sample_batch(self._emitted))
            self._emitted += 1
            return out
        self._ensure_started()
        while True:
            try:
                item = self._q_out.get(timeout=0.1)
                break
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration from None
                if not any(t.is_alive() for t in self._threads):
                    raise RuntimeError(
                        "prefetch workers exited without a sentinel — "
                        "pipeline state is corrupt") from None
        if isinstance(item, _Err):
            self.close()
            raise item.exc
        if item is _DONE:
            self.close()
            raise StopIteration
        self._emitted += 1
        return item

    def close(self):
        """Stop both workers and drop queued batches.  Safe to call
        mid-iteration, repeatedly, or from ``with`` exit; returns after
        the workers have exited."""
        self._closed = True
        self._stop.set()
        for q in (self._q_sample, self._q_out):
            if q is None:
                continue
            try:
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass
        for t in self._threads:
            if t.is_alive():
                t.join(timeout=10.0)
        self._threads = []

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
