"""Vectorized fixed-fanout neighbor sampling (jax) + its NumPy oracle.

Both paths draw from the same ``jax.random`` key, so the oracle is a
*bitwise* pin, not a statistical one:

* **with replacement** (fast path): one ``(B, fanout)`` uniform draw;
  neighbor index = ``floor(u * degree)`` clamped to the row — a single
  gather, no per-row work.
* **without replacement** (exact path): one ``(B, width)`` uniform draw;
  each row keeps its first ``degree`` uniforms, masks the rest to +inf,
  and takes the ``fanout`` smallest by stable argsort — exactly a uniform
  random permutation prefix of the true neighbor list (every neighbor's
  key is i.i.d. uniform, so any ordering is equally likely).

Rows are indices into a padded ``(R, D)`` neighbor table (``-1``-padded,
as :class:`~repro.sampling.machine_csc.MachineCSC` packs it).  Invalid
rows (``row < 0``) and zero-degree rows sample ``-1`` everywhere; rows
with ``degree < fanout`` pad their tail with ``-1`` in the
without-replacement path (a fanout draw never repeats a neighbor).

The NumPy oracle re-implements both selection rules with per-row Python
loops over the *same* uniforms — an independent derivation of the same
bits, which the smoke gate and the determinism tests compare bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def fanout_hop(table, deg, rows, key, fanout, replace,
               select: str = "top_k"):
    """One fanout hop, as pure traceable jax ops.

    This is the single source of the selection math: the per-hop
    :func:`sample_fanout` jit and the fused k-hop dispatch in
    :mod:`~repro.sampling.service` both trace this function.  ``select``
    picks the without-replacement selection lowering — ``"top_k"``
    (XLA:CPU custom call, ~20x faster at realistic widths) or ``"sort"``
    (the original stable-argsort prefix, kept as the reference the
    parity tests pin ``top_k`` against).  Both produce identical bits:
    among equal keys each prefers the lower index, and only positions
    ``j < min(degree, fanout)`` survive the live mask anyway.
    """
    if select not in ("top_k", "sort"):
        raise ValueError(f"select must be 'top_k' or 'sort', "
                         f"got {select!r}")
    R, D = table.shape
    B = rows.shape[0]
    safe = jnp.clip(rows, 0, R - 1)
    d = jnp.where(rows >= 0, deg[safe], 0)                    # (B,)
    if replace:
        u = jax.random.uniform(key, (B, fanout))
        # floor(u * d) < d for exact arithmetic; the clamp guards the
        # float32 rounding edge u*d == d.  Zero-degree rows mask below.
        idx = (u * d[:, None]).astype(jnp.int32)
        idx = jnp.minimum(idx, jnp.maximum(d[:, None] - 1, 0))
        out = jnp.take_along_axis(table[safe], idx, axis=1)
        return jnp.where(d[:, None] > 0, out, -1)
    width = max(D, fanout)
    u = jax.random.uniform(key, (B, width))
    live = jnp.arange(width)[None, :] < d[:, None]
    keyed = jnp.where(live, u, jnp.inf)
    if select == "top_k":
        order = jax.lax.top_k(-keyed, fanout)[1]
    else:
        order = jnp.argsort(keyed, axis=1)[:, :fanout]        # stable
    padded = table[safe]
    if width > D:
        padded = jnp.pad(padded, ((0, 0), (0, width - D)),
                         constant_values=-1)
    out = jnp.take_along_axis(padded, order, axis=1)
    live_out = jnp.arange(fanout)[None, :] < jnp.minimum(d, fanout)[:, None]
    return jnp.where(live_out, out, -1)


_sample_jit = functools.partial(
    jax.jit, static_argnames=("fanout", "replace", "select"))(fanout_hop)


def sample_fanout(table, deg, rows, key, fanout: int, *,
                  replace: bool = False, select: str = "top_k"):
    """Sample ``fanout`` neighbors for each of ``rows`` from ``table``.

    ``table`` — (R, D) int32 padded neighbor lists (global ids, -1 pad);
    ``deg`` — (R,) true neighbor count per row; ``rows`` — (B,) row
    indices, ``-1`` for invalid/remote-unresolved entries.  Returns
    (B, fanout) int32 sampled global ids, ``-1`` where no sample exists.
    """
    return _sample_jit(jnp.asarray(table), jnp.asarray(deg),
                       jnp.asarray(rows, dtype=jnp.int32), key,
                       int(fanout), bool(replace), str(select))


def sample_fanout_np(table, deg, rows, key, fanout: int, *,
                     replace: bool = False) -> np.ndarray:
    """NumPy oracle for :func:`sample_fanout` — same key, same bits,
    per-row Python loops; the jax path must match it bitwise."""
    table = np.asarray(table)
    deg = np.asarray(deg)
    rows = np.asarray(rows)
    B, D = len(rows), table.shape[1]
    fanout = int(fanout)
    out = np.full((B, fanout), -1, dtype=np.int32)
    if replace:
        u = np.asarray(jax.random.uniform(key, (B, fanout)))
        for b in range(B):
            r = int(rows[b])
            if r < 0:
                continue
            d = int(deg[r])
            if d == 0:
                continue
            for j in range(fanout):
                idx = min(int(np.float32(u[b, j]) * np.float32(d)), d - 1)
                out[b, j] = table[r, idx]
        return out
    width = max(D, fanout)
    u = np.asarray(jax.random.uniform(key, (B, width)))
    for b in range(B):
        r = int(rows[b])
        if r < 0:
            continue
        d = int(deg[r])
        keyed = u[b].copy()
        keyed[d:] = np.inf
        order = np.argsort(keyed, kind="stable")
        for j in range(min(d, fanout)):
            out[b, j] = table[r, order[j]]
    return out
