"""Per-machine CSC adjacency for neighbor sampling.

An edge partition gives every machine an edge shard; sampling instead
needs, per machine, the **full global adjacency of the vertices it
serves** — sampling a vertex's neighbors from one machine's partial edge
set would bias the draw toward co-located edges.  Following the
DistDGL/graphbolt layout, each vertex gets one *primary owner*: the
machine with the most incident edges on it (ties break to the lowest
machine id, so ownership is deterministic and derivable from any
equal-content runtime).  Each machine's CSC then holds its owned
vertices' complete neighbor lists, built by distributing every shard's
edges to both endpoints' owners — one shard at a time, so peak transient
state during packing is O(V) cursors plus one shard.

Rows are *degree-sorted* (descending global degree, stable — the same
local relabeling :class:`~repro.bsp.partition_runtime.LocalBSR` applies
to its Block-ELL matrices): hub rows cluster at the top of each
machine's table, which keeps the padded ``(rows, max_degree)`` neighbor
table's live entries in the leading columns of the leading rows.

Halo semantics fall out of ownership: a frontier vertex whose owner is
not the sampling machine must have its row fetched cross-machine — the
halo-fetch fraction the service reports per hop, and the quantity a
better partition (lower RF, stronger locality) directly shrinks.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..bsp.partition_runtime import PartitionRuntime, rank_of
from ..core.partition_state import cumcount


@dataclasses.dataclass(frozen=True)
class MachineCSC:
    """Owner-partitioned global adjacency, stacked over machines.

    ``indptr[i]`` / the padded ``nbr[i]`` describe machine ``i``'s CSC:
    row ``r`` holds the full neighbor list of ``owned_gid[i, r]``.  All
    machines share ``(Omax, D)`` padded shapes so the sampler's gathers
    vmap/jit like every other runtime array.
    """

    p: int
    num_vertices: int
    owner: np.ndarray       # (V,) int32 primary machine per vertex (-1: isolated)
    row: np.ndarray         # (V,) int32 owner-local row id (-1: isolated)
    owned_gid: np.ndarray   # (p, Omax) int32 global id per row (-1 pad)
    deg: np.ndarray         # (p, Omax) int32 full global degree per row
    indptr: np.ndarray      # (p, Omax+1) int64 CSC column pointers
    nbr: np.ndarray         # (p, Omax, D) int32 neighbor gids (-1 pad)
    owned_per: np.ndarray   # (p,) int64 owned-vertex count

    @property
    def omax(self) -> int:
        return self.owned_gid.shape[1]

    @property
    def max_degree(self) -> int:
        return self.nbr.shape[2]

    def flat_rowmap(self) -> np.ndarray:
        """(V,) int32 map: vertex -> row in the machine-stacked flat table
        (``owner * Omax + row``; -1 for isolated vertices) — the index
        space :func:`~repro.sampling.sampler.sample_fanout` consumes when
        the per-machine tables are reshaped to ``(p*Omax, D)``."""
        flat = self.owner.astype(np.int64) * self.omax + self.row
        return np.where(self.owner >= 0, flat, -1).astype(np.int32)

    @classmethod
    def build(cls, rt: PartitionRuntime) -> "MachineCSC":
        """Pack from a runtime's per-machine shards, one shard at a time."""
        p, V = rt.p, rt.num_vertices

        # Pass 1 — primary owner per vertex: the machine with the highest
        # local incidence count (strict > keeps the lowest machine id on
        # ties).  Running best arrays keep residency at O(V).
        best = np.zeros(V, dtype=np.int64)
        owner = np.full(V, -1, dtype=np.int32)
        gdeg = np.zeros(V, dtype=np.int64)
        for i in range(p):
            m = rt.vertex_valid[i]
            gids = rt.local_vertex_gid[i, m]
            gdeg[gids] = rt.global_degree[i, m]
            e = rt.local_edges[i][rt.edge_valid[i]]
            cnt_local = np.zeros(rt.vmax, dtype=np.int64)
            if len(e):
                np.add.at(cnt_local, e[:, 0], 1)
                np.add.at(cnt_local, e[:, 1], 1)
            cnt_g = np.zeros(V, dtype=np.int64)
            cnt_g[gids] = cnt_local[m]
            win = cnt_g > best
            owner[win] = i
            best[win] = cnt_g[win]

        # Degree-sorted local relabeling per owner (the LocalBSR idiom:
        # stable argsort on descending degree, rank_of for the inverse).
        owned_lists = [np.flatnonzero(owner == i) for i in range(p)]
        omax = max(1, max((len(o) for o in owned_lists), default=1))
        row = np.full(V, -1, dtype=np.int32)
        owned_gid = np.full((p, omax), -1, dtype=np.int32)
        deg = np.zeros((p, omax), dtype=np.int32)
        owned_per = np.zeros(p, dtype=np.int64)
        for i, o in enumerate(owned_lists):
            order = np.argsort(-gdeg[o], kind="stable").astype(np.int32)
            row[o] = rank_of(order, len(o))
            owned_gid[i, :len(o)] = o[order]
            deg[i, :len(o)] = gdeg[o[order]]
            owned_per[i] = len(o)
        D = max(1, int(gdeg.max(initial=0)))

        # Pass 2 — distribute each shard's edges to both endpoints' owner
        # rows.  Per-vertex fill cursors + within-batch occurrence ranks
        # (``cumcount``) make the scatter exact under duplicate endpoints.
        nbr = np.full((p, omax, D), -1, dtype=np.int32)
        cursor = np.zeros(V, dtype=np.int64)
        for i in range(p):
            e = rt.local_edges[i][rt.edge_valid[i]]
            if not len(e):
                continue
            ge = rt.local_vertex_gid[i][e].astype(np.int64)   # (k, 2) gids
            x = np.concatenate([ge[:, 0], ge[:, 1]])
            y = np.concatenate([ge[:, 1], ge[:, 0]]).astype(np.int32)
            slots = cursor[x] + cumcount(x)
            nbr[owner[x], row[x], slots] = y
            np.add.at(cursor, x, 1)
        if not np.array_equal(cursor, gdeg):
            short = np.flatnonzero(cursor != gdeg)[:8]
            raise ValueError(f"machine CSC fill disagrees with global "
                             f"degrees at vertices {short} — runtime "
                             f"shards do not cover the graph exactly once")

        indptr = np.zeros((p, omax + 1), dtype=np.int64)
        indptr[:, 1:] = np.cumsum(deg, axis=1)
        return cls(p=p, num_vertices=V, owner=owner, row=row,
                   owned_gid=owned_gid, deg=deg, indptr=indptr, nbr=nbr,
                   owned_per=owned_per)

    @classmethod
    def from_stream(cls, assignment) -> "MachineCSC":
        """Pack from an on-disk :class:`~repro.bsp.stream_assignment.
        StreamAssignment` (or its path) — the runtime itself is packed one
        shard at a time, then re-distributed here."""
        return cls.build(PartitionRuntime.create(assignment))
