"""Partitioned GNN minibatch sampling over the BSP runtime's shards.

Full-graph BSP sweeps touch every edge every superstep; GNN training hits
the same partition with k-hop *neighbor sampling* — many small frontier
expansions against machine-local adjacency, where every frontier vertex
owned by another machine is a cross-machine ("halo") fetch.  This package
makes partition quality directly observable on that workload:

* :mod:`~repro.sampling.machine_csc` — per-machine CSC adjacency packed
  one shard at a time from the runtime/stream state, with the degree-
  sorted local relabeling idiom of :class:`~repro.bsp.partition_runtime.
  LocalBSR`.
* :mod:`~repro.sampling.sampler` — vectorized jax fixed-fanout sampling
  (with-replacement fast path, without-replacement exact path) pinned
  bitwise against a NumPy oracle on the same PRNG key.
* :mod:`~repro.sampling.service` — k-hop minibatch sampling with
  ``jax.random`` key threading and per-hop batched halo-fetch
  accounting; the whole k-hop expansion is one fused jitted dispatch
  (the per-hop loop survives as the bitwise-pinned reference).
* :mod:`~repro.sampling.features` — owner-sharded feature store plus a
  hub-tier + LRU :class:`HaloCache` so remote feature rows are fetched
  once, not per batch.
* :mod:`~repro.sampling.pipeline` — bounded-depth async prefetch
  producing ``(MiniBatch, features)`` with batch ``i+1``'s sampling
  overlapping batch ``i``'s feature fetch, bitwise deterministic at
  every depth.

The layer consumes runtimes only through ``PartitionRuntime.create``.
"""
from .features import FeatureStore, FetchStats, HaloCache
from .machine_csc import MachineCSC
from .pipeline import PrefetchPipeline
from .sampler import sample_fanout, sample_fanout_np
from .service import HopStats, MiniBatch, SamplingService

__all__ = ["MachineCSC", "sample_fanout", "sample_fanout_np",
           "HopStats", "MiniBatch", "SamplingService",
           "FeatureStore", "FetchStats", "HaloCache",
           "PrefetchPipeline"]
