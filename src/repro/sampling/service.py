"""K-hop minibatch sampling service with per-hop halo-fetch accounting.

One :class:`SamplingService` wraps a partition (via
``PartitionRuntime.create`` — the only constructor surface this layer
uses) as an owner-partitioned :class:`~repro.sampling.machine_csc.
MachineCSC` plus device-resident flat tables, and answers minibatch
requests: seeds → ``fanouts[0]`` neighbors each → ``fanouts[1]``
neighbors of those → …, threading one ``jax.random`` key through
``jax.random.split`` per hop, so the whole minibatch is a pure function
of ``(partition, seeds, key)`` — bitwise reproducible across runs and
across equal-content runtimes, however they were built.

Two execution paths produce the same bits:

* the **fused path** (default): all hops collapse into one jitted
  dispatch, static over ``(fanouts, replace)`` so shapes are fixed; the
  per-hop keys are pre-split on host exactly as the loop splits them,
  and owner/halo accounting — including the deduplicated remote-row
  count — runs vectorized on device (sort + adjacent-difference, no
  host ``np.unique``);
* the **hop-at-a-time path** (``fused=False``): the original per-hop
  loop — one dispatch and one host round-trip per hop, stable-argsort
  selection, host ``np.unique`` accounting — kept verbatim as the
  reference implementation the parity tests pin the fused path against,
  bitwise (which also pins the fused path's ``top_k`` selection against
  the argsort lowering, every run).

Halo accounting: after each hop, the new frontier's vertices that are
*not* owned by the sampling machine would be resolved by one batched
cross-machine fetch of their owner rows (deduplicated per hop — the
replica-table analogue for the sampling workload).  The per-hop
``halo_frac`` is the fraction of valid frontier entries that are remote:
exactly the traffic a better partition (lower RF, stronger locality)
shrinks, which is what makes partition quality observable on this
workload.  ``fetched_unique`` is also the cache-miss upper bound for the
feature layer (:mod:`~repro.sampling.features`).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..bsp.partition_runtime import PartitionRuntime
from .machine_csc import MachineCSC
from .sampler import fanout_hop, sample_fanout


@dataclasses.dataclass(frozen=True)
class HopStats:
    """Fetch accounting for one hop's *output* frontier."""

    frontier: int        # valid sampled entries entering the next hop
    halo: int            # of those, entries owned by a remote machine
    fetched_unique: int  # deduplicated remote rows one batch fetch pulls

    @property
    def halo_frac(self) -> float:
        return self.halo / max(1, self.frontier)


@dataclasses.dataclass(frozen=True)
class MiniBatch:
    """One sampled k-hop neighborhood batch.

    ``hops[h]`` holds hop ``h``'s sampled global ids, flattened to
    ``(len(seeds) * prod(fanouts[:h+1]),)`` with ``-1`` for pad lanes
    (isolated/undersized neighborhoods propagate ``-1`` forward, keeping
    every hop's shape fixed — jit retraces once per hop shape).
    """

    seeds: np.ndarray
    hops: tuple
    hop_stats: tuple
    home: int | None

    def halo_fracs(self) -> tuple:
        return tuple(s.halo_frac for s in self.hop_stats)

    def num_sampled(self) -> int:
        return int(sum(s.frontier for s in self.hop_stats))

    def all_ids(self) -> np.ndarray:
        """Seeds + every hop, flattened in order (``-1`` pads kept) —
        the id set whose features a trainer needs for this batch."""
        return np.concatenate([self.seeds] + [h.reshape(-1)
                                              for h in self.hops])


@functools.partial(jax.jit,
                   static_argnames=("fanouts", "replace", "has_home"))
def _sample_khop_jit(table, deg, rowmap, owner, seeds, hop_keys, home,
                     fanouts, replace, has_home):
    """All hops in one dispatch.  ``hop_keys[h]`` must be the key the
    loop path would pass to hop ``h`` (host pre-split), so every hop
    traces :func:`fanout_hop` with identical inputs — bitwise parity
    with the hop-at-a-time path by construction.

    Per hop, alongside the sampled ids, returns
    ``(frontier, halo, fetched_unique)`` computed on device: the dedup
    is a sort with remote lanes keyed below a ``V`` sentinel, counting
    adjacent differences — the same dedup a batched halo fetch performs,
    so the accounting is free on this path.
    """
    V = rowmap.shape[0]
    frontier = seeds
    hops, stats = [], []
    for h, fanout in enumerate(fanouts):
        rows = jnp.where(frontier >= 0,
                         rowmap[jnp.clip(frontier, 0, V - 1)], -1)
        out = fanout_hop(table, deg, rows, hop_keys[h], fanout,
                         replace).reshape(-1)
        ok = out >= 0
        zero = jnp.zeros((), jnp.int32)
        if out.shape[0] == 0:
            stats.append(jnp.stack([zero, zero, zero]))
        elif has_home:
            remote = ok & (owner[jnp.clip(out, 0, V - 1)] != home)
            keyed = jnp.sort(jnp.where(remote, out, V))
            fresh = jnp.concatenate([jnp.ones(1, bool),
                                     keyed[1:] != keyed[:-1]])
            stats.append(jnp.stack(
                [ok.sum().astype(jnp.int32),
                 remote.sum().astype(jnp.int32),
                 ((keyed < V) & fresh).sum().astype(jnp.int32)]))
        else:
            stats.append(jnp.stack([ok.sum().astype(jnp.int32), zero,
                                    zero]))
        hops.append(out)
        frontier = out
    return tuple(hops), jnp.stack(stats)


class SamplingService:
    """Fixed-fanout k-hop neighbor sampling over a partitioned graph."""

    def __init__(self, rt: PartitionRuntime | MachineCSC,
                 fanouts=(10, 5), *, replace: bool = False):
        self.csc = rt if isinstance(rt, MachineCSC) else MachineCSC.build(rt)
        self.fanouts = tuple(int(f) for f in fanouts)
        if not self.fanouts or any(f < 1 for f in self.fanouts):
            raise ValueError(f"fanouts must be positive ints, got "
                             f"{self.fanouts}")
        self.replace = bool(replace)
        csc = self.csc
        # machine-stacked flat tables: row of vertex v = owner*Omax+row[v]
        self._table = jnp.asarray(
            csc.nbr.reshape(csc.p * csc.omax, csc.max_degree))
        self._deg = jnp.asarray(csc.deg.reshape(-1))
        self._rowmap = csc.flat_rowmap()                  # np (V,)
        self._owner = csc.owner
        self._rowmap_d = jnp.asarray(self._rowmap)
        self._owner_d = jnp.asarray(csc.owner)

    @classmethod
    def create(cls, source=None, *, fanouts=(10, 5), replace: bool = False,
               **create_kw) -> "SamplingService":
        """Build straight from any ``PartitionRuntime.create`` source:
        ``create(source=g, method="windgp", cluster=cl)``,
        ``create(source=g, assign=a, p=p)``, or
        ``create(source=stream_assignment_or_path)``."""
        rt = PartitionRuntime.create(source, **create_kw)
        return cls(rt, fanouts=fanouts, replace=replace)

    @property
    def p(self) -> int:
        return self.csc.p

    def local_seeds(self, home: int, n: int, key,
                    train_mask: np.ndarray | None = None) -> np.ndarray:
        """``n`` seed vertices owned by machine ``home`` — a uniform
        key-deterministic draw from its owned (optionally train-masked)
        vertex set.  Seeds are where minibatches start in DistDGL-style
        training: each trainer draws from its own machine's shard.

        When the (masked) pool holds fewer than ``n`` vertices the whole
        pool is returned in key-permuted order — the result length is
        ``min(n, pool size)``, never padded; callers wanting fixed batch
        shapes must check ``len(seeds)``.  This is pinned by test.
        """
        pool = self.csc.owned_gid[home][:int(self.csc.owned_per[home])]
        if train_mask is not None:
            tm = np.asarray(train_mask, dtype=bool)
            pool = pool[tm[pool]]
        if len(pool) == 0:
            return np.empty(0, dtype=np.int32)
        perm = np.asarray(jax.random.permutation(key, len(pool)))
        return pool[perm[:int(n)]].astype(np.int32)

    def _check_seeds(self, seeds) -> np.ndarray:
        frontier = np.asarray(seeds, dtype=np.int32).reshape(-1)
        if len(frontier):
            if frontier.max() >= self.csc.num_vertices:
                raise ValueError(
                    f"seed ids must lie in [0, {self.csc.num_vertices})")
            if frontier.min() < -1:
                raise ValueError(
                    f"seed ids must be >= -1 (-1 is the explicit pad "
                    f"lane); got {int(frontier.min())}")
        return frontier

    def sample(self, seeds, key, home: int | None = None, *,
               fused: bool = True) -> MiniBatch:
        """Sample the k-hop neighborhood of ``seeds`` (global vertex ids;
        ``-1`` marks an explicit pad lane, anything below is rejected).

        ``home`` is the machine running the batch: per hop, sampled
        vertices owned elsewhere count as halo fetches (``hop_stats``).
        ``key`` is split once per hop; the same ``(seeds, key)`` always
        yields the bitwise-same minibatch on either path (``fused=True``
        is one device dispatch; ``False`` is the per-hop reference loop).
        """
        frontier = self._check_seeds(seeds)
        if fused:
            return self._sample_fused(frontier, key, home)
        return self._sample_loop(frontier, key, home)

    def _hop_keys(self, key):
        """Pre-split one key per hop, exactly as the loop path splits
        (``key, sub = split(key)`` per hop) — batch determinism hangs on
        this being the single splitting rule."""
        subs = []
        for _ in self.fanouts:
            key, sub = jax.random.split(key)
            subs.append(sub)
        return subs

    def _sample_fused(self, frontier, key, home) -> MiniBatch:
        hop_keys = jnp.stack(self._hop_keys(key))
        hops, stats = _sample_khop_jit(
            self._table, self._deg, self._rowmap_d, self._owner_d,
            jnp.asarray(frontier), hop_keys,
            jnp.int32(-1 if home is None else home),
            self.fanouts, self.replace, home is not None)
        stats = np.asarray(stats)                 # one (k, 3) transfer
        return MiniBatch(
            seeds=frontier,
            hops=tuple(np.asarray(h) for h in hops),
            hop_stats=tuple(HopStats(frontier=int(f), halo=int(h),
                                     fetched_unique=int(u))
                            for f, h, u in stats),
            home=home)

    def _sample_loop(self, frontier, key, home) -> MiniBatch:
        seeds = frontier
        V = self.csc.num_vertices
        hops, stats = [], []
        for fanout, sub in zip(self.fanouts, self._hop_keys(key)):
            valid = frontier >= 0
            rows = np.where(valid,
                            self._rowmap[np.clip(frontier, 0, V - 1)], -1)
            out = np.asarray(sample_fanout(
                self._table, self._deg, rows, sub, fanout,
                replace=self.replace, select="sort")).reshape(-1)
            ok = out >= 0
            if home is None:
                halo = np.zeros(0, dtype=np.int32)
                n_halo = 0
            else:
                remote = ok & (self._owner[np.clip(out, 0, V - 1)] != home)
                halo = out[remote]
                n_halo = int(remote.sum())
            stats.append(HopStats(frontier=int(ok.sum()), halo=n_halo,
                                  fetched_unique=len(np.unique(halo))))
            hops.append(out)
            frontier = out
        return MiniBatch(seeds=seeds, hops=tuple(hops),
                         hop_stats=tuple(stats), home=home)
