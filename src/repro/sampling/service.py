"""K-hop minibatch sampling service with per-hop halo-fetch accounting.

One :class:`SamplingService` wraps a partition (via
``PartitionRuntime.create`` — the only constructor surface this layer
uses) as an owner-partitioned :class:`~repro.sampling.machine_csc.
MachineCSC` plus device-resident flat tables, and answers minibatch
requests: seeds → ``fanouts[0]`` neighbors each → ``fanouts[1]``
neighbors of those → …, threading one ``jax.random`` key through
``jax.random.split`` per hop, so the whole minibatch is a pure function
of ``(partition, seeds, key)`` — bitwise reproducible across runs and
across equal-content runtimes, however they were built.

Halo accounting: after each hop, the new frontier's vertices that are
*not* owned by the sampling machine would be resolved by one batched
cross-machine fetch of their owner rows (deduplicated per hop — the
replica-table analogue for the sampling workload).  The per-hop
``halo_frac`` is the fraction of valid frontier entries that are remote:
exactly the traffic a better partition (lower RF, stronger locality)
shrinks, which is what makes partition quality observable on this
workload.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from ..bsp.partition_runtime import PartitionRuntime
from .machine_csc import MachineCSC
from .sampler import sample_fanout


@dataclasses.dataclass(frozen=True)
class HopStats:
    """Fetch accounting for one hop's *output* frontier."""

    frontier: int        # valid sampled entries entering the next hop
    halo: int            # of those, entries owned by a remote machine
    fetched_unique: int  # deduplicated remote rows one batch fetch pulls

    @property
    def halo_frac(self) -> float:
        return self.halo / max(1, self.frontier)


@dataclasses.dataclass(frozen=True)
class MiniBatch:
    """One sampled k-hop neighborhood batch.

    ``hops[h]`` holds hop ``h``'s sampled global ids, flattened to
    ``(len(seeds) * prod(fanouts[:h+1]),)`` with ``-1`` for pad lanes
    (isolated/undersized neighborhoods propagate ``-1`` forward, keeping
    every hop's shape fixed — jit retraces once per hop shape).
    """

    seeds: np.ndarray
    hops: tuple
    hop_stats: tuple
    home: int | None

    def halo_fracs(self) -> tuple:
        return tuple(s.halo_frac for s in self.hop_stats)

    def num_sampled(self) -> int:
        return int(sum(s.frontier for s in self.hop_stats))


class SamplingService:
    """Fixed-fanout k-hop neighbor sampling over a partitioned graph."""

    def __init__(self, rt: PartitionRuntime | MachineCSC,
                 fanouts=(10, 5), *, replace: bool = False):
        self.csc = rt if isinstance(rt, MachineCSC) else MachineCSC.build(rt)
        self.fanouts = tuple(int(f) for f in fanouts)
        if not self.fanouts or any(f < 1 for f in self.fanouts):
            raise ValueError(f"fanouts must be positive ints, got "
                             f"{self.fanouts}")
        self.replace = bool(replace)
        csc = self.csc
        # machine-stacked flat tables: row of vertex v = owner*Omax+row[v]
        import jax.numpy as jnp
        self._table = jnp.asarray(
            csc.nbr.reshape(csc.p * csc.omax, csc.max_degree))
        self._deg = jnp.asarray(csc.deg.reshape(-1))
        self._rowmap = csc.flat_rowmap()                  # np (V,)
        self._owner = csc.owner

    @classmethod
    def create(cls, source=None, *, fanouts=(10, 5), replace: bool = False,
               **create_kw) -> "SamplingService":
        """Build straight from any ``PartitionRuntime.create`` source:
        ``create(source=g, method="windgp", cluster=cl)``,
        ``create(source=g, assign=a, p=p)``, or
        ``create(source=stream_assignment_or_path)``."""
        rt = PartitionRuntime.create(source, **create_kw)
        return cls(rt, fanouts=fanouts, replace=replace)

    @property
    def p(self) -> int:
        return self.csc.p

    def local_seeds(self, home: int, n: int, key,
                    train_mask: np.ndarray | None = None) -> np.ndarray:
        """``n`` seed vertices owned by machine ``home`` — a uniform
        key-deterministic draw from its owned (optionally train-masked)
        vertex set.  Seeds are where minibatches start in DistDGL-style
        training: each trainer draws from its own machine's shard."""
        pool = self.csc.owned_gid[home][:int(self.csc.owned_per[home])]
        if train_mask is not None:
            tm = np.asarray(train_mask, dtype=bool)
            pool = pool[tm[pool]]
        if len(pool) == 0:
            return np.empty(0, dtype=np.int32)
        perm = np.asarray(jax.random.permutation(key, len(pool)))
        return pool[perm[:int(n)]].astype(np.int32)

    def sample(self, seeds, key, home: int | None = None) -> MiniBatch:
        """Sample the k-hop neighborhood of ``seeds`` (global vertex ids).

        ``home`` is the machine running the batch: per hop, sampled
        vertices owned elsewhere count as halo fetches (``hop_stats``).
        ``key`` is split once per hop; the same ``(seeds, key)`` always
        yields the bitwise-same minibatch.
        """
        frontier = np.asarray(seeds, dtype=np.int32).reshape(-1)
        V = self.csc.num_vertices
        if len(frontier) and (frontier.max() >= V):
            raise ValueError(f"seed ids must lie in [0, {V})")
        hops, stats = [], []
        for fanout in self.fanouts:
            key, sub = jax.random.split(key)
            valid = frontier >= 0
            rows = np.where(valid,
                            self._rowmap[np.clip(frontier, 0, V - 1)], -1)
            out = np.asarray(sample_fanout(
                self._table, self._deg, rows, sub, fanout,
                replace=self.replace)).reshape(-1)
            ok = out >= 0
            if home is None:
                halo = np.zeros(0, dtype=np.int32)
                n_halo = 0
            else:
                remote = ok & (self._owner[np.clip(out, 0, V - 1)] != home)
                halo = out[remote]
                n_halo = int(remote.sum())
            stats.append(HopStats(frontier=int(ok.sum()), halo=n_halo,
                                  fetched_unique=len(np.unique(halo))))
            hops.append(out)
            frontier = out
        return MiniBatch(seeds=np.asarray(seeds, dtype=np.int32),
                         hops=tuple(hops), hop_stats=tuple(stats),
                         home=home)
