"""Graph-partitioning CLI: the production entry for the paper's own task.

  PYTHONPATH=src python -m repro.launch.partition \
      --graph rmat:13 --super 3 --normal 6 --method windgp --out part.npz
  PYTHONPATH=src python -m repro.launch.partition --graph edges.txt ...
  PYTHONPATH=src python -m repro.launch.partition --graph edges.txt.gz \
      --method hdrf --stream --dedup two_pass --out-dir parts/

Methods resolve through the unified partitioner registry
(``repro.core.partitioners``); ``--block-size`` reaches every method with
the ``blocked`` capability (the block-stream scorers).  ``--stream`` runs
a ``streamable`` method graph-free over an edge-list file — the edge set
never materializes; ``--dedup two_pass`` adds the exact spill-to-disk
dedup, and ``--out-dir`` persists the on-disk ``StreamAssignment``
(per-machine shards + membership) that ``PartitionRuntime.from_stream``
packs into the BSP runtime.  ``--workers W`` runs the whole stream
through the multi-process pipeline (``repro.core.parallel``): sharded
dedup plus W-worker wave scoring against membership snapshots synced
every ``--sync-blocks`` engine blocks.  ``--compact DIR`` is a
standalone maintenance pass: fold accumulated tombstone debt into the
shards of a finalized assignment directory and republish its meta.

``--pagerank`` closes the loop: it packs the partition it just built into
the BSP runtime and runs distributed PageRank supersteps on it, through a
selectable **edge-kernel backend** (``--backend``, see
``repro.bsp.backends``): ``scatter`` is the gather-scatter oracle,
``segment`` the sorted-CSR CPU fast path (~5x PageRank superstep
throughput on the proxies), ``pallas`` the blocked Block-ELL semiring
SpMV (MXU-shaped on TPU, interpreter on CPU).  ``--fused`` runs the whole
iteration as one on-device dispatch (``run_bsp_fused``) instead of one
dispatch + host sync per superstep; ``--tol`` additionally stops once
``‖pr_{t+1} − pr_t‖∞ ≤ tol`` (implies ``--fused``); ``--message-dtype
bfloat16`` opts into the low-precision message path.  In ``--stream``
mode ``--pagerank`` requires ``--out-dir`` (the runtime packs from the
on-disk shards, one machine at a time).

Every partition this CLI emits is also a valid *seed* for the dynamic
layer (``repro.core.DynamicPartitioner``): live edge inserts/deletes,
drift-triggered bounded repair, and epoch deltas that update the
``--out-dir`` shards and the BSP runtime in place — see the
dynamic-replay benchmark (``python -m benchmarks.dynamic_replay``) for
the measured workflow.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from ..core import evaluate, evaluate_membership, scaled_paper_cluster, windgp
from ..core import partitioners as registry
from ..data import graph500, read_edge_list, rmat, road_mesh

#: static mirror of ``repro.bsp.backends.BACKENDS`` — the bsp package
#: (and jax) must not load on the plain numpy partition path; a test
#: pins the two in sync
EDGE_BACKENDS = ("scatter", "segment", "pallas")

#: static mirror of ``repro.bsp.backends.MESSAGE_DTYPES`` (same test)
MESSAGE_DTYPES = ("float32", "bfloat16", "float16")


def load_graph(spec: str):
    if spec.startswith("rmat:"):
        return rmat(int(spec.split(":")[1]), seed=42)
    if spec.startswith("graph500:"):
        return graph500(int(spec.split(":")[1]), seed=42)
    if spec.startswith("mesh:"):
        return road_mesh(int(spec.split(":")[1]), seed=42)
    return read_edge_list(spec)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Graph-partitioning CLI (see module docstring for "
                    "the full tour).",
        epilog="dynamic-replay usage: the emitted partition seeds "
               "repro.core.DynamicPartitioner for live insert/delete "
               "streams with drift-triggered bounded repair; replay a "
               "mutation timeline against it (assignment-latency "
               "percentiles, amortized repair cost, TC drift vs "
               "scratch) with: PYTHONPATH=src python -m "
               "benchmarks.dynamic_replay [--smoke]")
    ap.add_argument("--graph",
                    help="rmat:<scale> | graph500:<scale> | mesh:<side> | "
                         "path to an edge list (.gz ok)")
    ap.add_argument("--super", type=int, default=3)
    ap.add_argument("--normal", type=int, default=6)
    ap.add_argument("--slack", type=float, default=1.8)
    ap.add_argument("--method", default="windgp",
                    choices=registry.names(exclude={"oracle"}))
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--beta", type=float, default=0.3)
    ap.add_argument("--t0", type=int, default=8)
    ap.add_argument("--theta", type=float, default=0.01)
    ap.add_argument("--block-size", type=int, default=None,
                    help="stream-block size for 'blocked' methods")
    ap.add_argument("--stream", action="store_true",
                    help="out-of-core: partition an edge-list file "
                         "graph-free ('streamable' methods only)")
    ap.add_argument("--dedup", default="block",
                    choices=("block", "two_pass"),
                    help="--stream dedup discipline: per-block only, or "
                         "exact two-pass spill-to-disk")
    ap.add_argument("--out-dir", default=None,
                    help="--stream: persist the StreamAssignment "
                         "(per-machine shards + membership) here")
    ap.add_argument("--workers", type=int, default=1,
                    help="--stream: run the W-process pipeline (sharded "
                         "two-pass dedup + parallel wave scoring); 1 = "
                         "the sequential path bit for bit")
    ap.add_argument("--sync-blocks", type=int, default=None,
                    help="--workers > 1: engine blocks between membership "
                         "sync barriers (1 = bit-identical to sequential; "
                         "default trades a bounded staleness window for "
                         "scoring overlap)")
    ap.add_argument("--compact", default=None, metavar="DIR",
                    help="standalone maintenance: fold tombstone debt "
                         "into the shards of a finalized StreamAssignment "
                         "directory, then exit (no partitioning run)")
    ap.add_argument("--compact-tomb-frac", type=float, default=0.0,
                    help="--compact: only rewrite shards whose tombstone "
                         "fraction exceeds this (0.0 = fold everything)")
    ap.add_argument("--pagerank", action="store_true",
                    help="after partitioning, pack the BSP runtime and "
                         "run distributed PageRank on the partition")
    ap.add_argument("--pagerank-iters", type=int, default=20)
    ap.add_argument("--backend", default="scatter",
                    choices=EDGE_BACKENDS,
                    help="edge-kernel backend for --pagerank: scatter "
                         "(gather-scatter oracle), segment (sorted-CSR "
                         "CPU fast path), pallas (blocked Block-ELL "
                         "semiring SpMV)")
    ap.add_argument("--fused", action="store_true",
                    help="--pagerank: run the whole iteration as one "
                         "on-device dispatch (run_bsp_fused) instead of "
                         "one dispatch + host sync per superstep")
    ap.add_argument("--tol", type=float, default=None,
                    help="--pagerank: stop once the on-device residual "
                         "max|pr_{t+1}-pr_t| <= TOL (implies --fused)")
    ap.add_argument("--message-dtype", default="float32",
                    choices=MESSAGE_DTYPES,
                    help="--pagerank: edge-message precision; bfloat16 "
                         "is the low-precision message path (messages "
                         "cast down, accumulation stays float32 on "
                         "scatter/segment)")
    ap.add_argument("--out", default=None, help=".npz output path")
    args = ap.parse_args(argv)

    if args.compact:
        return _run_compact(args)
    if not args.graph:
        ap.error("--graph is required (except with --compact)")
    if args.stream:
        return _run_stream(ap, args)

    g = load_graph(args.graph)
    cl = scaled_paper_cluster(args.super, args.normal, g.num_edges,
                              slack=args.slack)
    print(f"graph: V={g.num_vertices} E={g.num_edges} "
          f"maxdeg={int(g.degree().max())}; cluster p={cl.p}", flush=True)
    t0 = time.perf_counter()
    if args.method == "windgp":
        res = windgp(g, cl, alpha=args.alpha, beta=args.beta,
                     t0=args.t0, theta=args.theta)
        assign, stats = res.assign, res.stats
    else:
        part = registry.get(args.method)
        kw = {}
        if args.block_size is not None:
            if not part.supports("blocked"):
                ap.error(f"--block-size: method {part.name!r} is not a "
                         f"block-stream method (capabilities: "
                         f"{sorted(part.capabilities)})")
            kw["block_size"] = args.block_size
        assign = part(g, cl, **kw)
        stats = evaluate(g, assign, cl)
    dt = time.perf_counter() - t0
    report = {
        "method": args.method, "seconds": round(dt, 2),
        "TC": stats.tc, "RF": round(stats.rf, 4),
        "feasible": stats.feasible,
        "edges_per_machine": stats.edges_per_part.astype(int).tolist(),
        "t_total_per_machine": np.round(stats.t_total, 1).tolist(),
    }
    print(json.dumps(report, indent=2))
    if args.out:
        np.savez(args.out, assign=assign,
                 machines=np.array([m.as_tuple() for m in cl.machines]))
        print(f"wrote {args.out}")
    if args.pagerank:
        from ..bsp import PartitionRuntime
        rt = PartitionRuntime.create(g, assign=assign, cluster=cl)
        _run_pagerank(rt, args)
    return 0


def _run_pagerank(rt, args) -> None:
    """Distributed PageRank on the fresh partition via --backend."""
    from ..bsp import RunOptions, pagerank
    opts = RunOptions(backend=args.backend, fused=args.fused, tol=args.tol,
                      message_dtype=args.message_dtype)
    t0 = time.perf_counter()
    pr, actives = pagerank(rt, num_iters=args.pagerank_iters, options=opts)
    dt = time.perf_counter() - t0
    top = np.argsort(pr)[::-1][:5]
    steps = len(actives)
    mode = "fused" if (args.fused or args.tol is not None) else "stepwise"
    print(f"pagerank[{args.backend}/{mode}/{args.message_dtype}]: "
          f"{steps}/{args.pagerank_iters} supersteps on "
          f"p={rt.p} machines (R={rt.num_replicas} replicas) in {dt:.2f}s; "
          f"mass={pr.sum():.6f}")
    print("top-5:", {int(v): round(float(pr[v]), 6) for v in top})


def _run_compact(args) -> int:
    """Standalone tombstone-folding pass over a finalized assignment."""
    from ..bsp import StreamAssignment
    sa = StreamAssignment.open(args.compact)
    before = int(sa.tomb_rows.sum())
    t0 = time.perf_counter()
    meta = sa.compact(args.compact_tomb_frac)
    dt = time.perf_counter() - t0
    print(json.dumps({
        "compacted": args.compact, "seconds": round(dt, 3),
        "tomb_rows_folded": before - int(sa.tomb_rows.sum()),
        "tomb_rows_left": int(sa.tomb_rows.sum()),
        "shard_rows": meta["shard_rows"],
        "num_edges": meta["num_edges"],
    }, indent=2))
    return 0


def _run_stream(ap, args) -> int:
    """Out-of-core path: graph-free streaming over an edge-list file."""
    import pathlib

    from ..data import count_edge_list
    part = registry.get(args.method)
    if not part.supports("streamable"):
        ap.error(f"--stream: method {part.name!r} is not streamable "
                 f"(capabilities: {sorted(part.capabilities)}); "
                 f"streamable: {registry.names(require={'streamable'})}")
    if args.graph.split(":")[0] in ("rmat", "graph500", "mesh"):
        ap.error("--stream partitions edge-list files; generator specs "
                 "would materialize the graph first")
    if args.pagerank and not args.out_dir:
        ap.error("--stream --pagerank needs --out-dir: the BSP runtime "
                 "packs from the persisted StreamAssignment shards")

    if args.dedup == "two_pass":
        if args.workers > 1:
            # shard the spill/dedup passes across the same worker count
            # the scoring stage will use
            from ..core.parallel import ShardedTwoPassDedup
            source = ShardedTwoPassDedup(args.graph, workers=args.workers)
            source.prepare()
        else:
            from ..data import two_pass_dedup
            source = two_pass_dedup(args.graph)
        num_v, num_e = source.num_vertices, source.num_edges
    else:
        # count at the same reader granularity the stream will use:
        # per-block dedup makes the edge count a function of the window
        from ..data.io import DEFAULT_BLOCK_LINES
        source = args.graph
        num_v, num_e = count_edge_list(
            args.graph, args.block_size or DEFAULT_BLOCK_LINES)
    cl = scaled_paper_cluster(args.super, args.normal, num_e,
                              slack=args.slack)
    print(f"stream: V={num_v} E={num_e} dedup={args.dedup} p={cl.p}",
          flush=True)

    sa = None
    kw = {"dedup": args.dedup}
    if args.block_size is not None:
        kw["block_size"] = args.block_size
    if args.workers > 1:
        kw["workers"] = args.workers
        kw["sync_blocks"] = args.sync_blocks
    if args.out_dir:
        from ..bsp import StreamAssignment
        sa = StreamAssignment(pathlib.Path(args.out_dir), cl.p, num_v)
        kw["sink"] = sa.sink
    t0 = time.perf_counter()
    try:
        state = part.stream(source, num_v, num_e, cl, **kw)
    except BaseException:
        if sa is not None:
            sa.close()      # abort: drop shard handles, publish nothing
        raise
    finally:
        if hasattr(source, "close"):
            source.close()
    dt = time.perf_counter() - t0

    stats = evaluate_membership(state.cnt > 0, state.edges_per, cl)
    report = {
        "method": args.method, "mode": f"stream/{args.dedup}",
        "seconds": round(dt, 2),
        "TC": stats.tc, "RF": round(stats.rf, 4),
        "feasible": stats.feasible,
        "edges_per_machine": stats.edges_per_part.astype(int).tolist(),
        "t_total_per_machine": np.round(stats.t_total, 1).tolist(),
    }
    if state.spill_stats is not None:
        report["spill"] = {
            "buckets": state.spill_stats.num_buckets,
            "duplicate_rows": state.spill_stats.duplicate_rows,
            "peak_resident_rows": state.spill_stats.peak_resident_rows,
        }
    print(json.dumps(report, indent=2))
    if sa is not None:
        meta = sa.finalize(state, {"method": args.method,
                                   "dedup": args.dedup})
        print(f"wrote StreamAssignment to {args.out_dir} "
              f"(E={meta['num_edges']}, rf={meta['replication_factor']})")
        if args.pagerank:
            from ..bsp import PartitionRuntime
            rt = PartitionRuntime.create(sa)
            _run_pagerank(rt, args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
