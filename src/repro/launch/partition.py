"""Graph-partitioning CLI: the production entry for the paper's own task.

  PYTHONPATH=src python -m repro.launch.partition \
      --graph rmat:13 --super 3 --normal 6 --method windgp --out part.npz
  PYTHONPATH=src python -m repro.launch.partition --graph edges.txt ...

Methods resolve through the unified partitioner registry
(``repro.core.partitioners``); ``--block-size`` reaches every method with
the ``blocked`` capability (the block-stream scorers).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from ..core import evaluate, scaled_paper_cluster, windgp
from ..core import partitioners as registry
from ..data import graph500, read_edge_list, rmat, road_mesh


def load_graph(spec: str):
    if spec.startswith("rmat:"):
        return rmat(int(spec.split(":")[1]), seed=42)
    if spec.startswith("graph500:"):
        return graph500(int(spec.split(":")[1]), seed=42)
    if spec.startswith("mesh:"):
        return road_mesh(int(spec.split(":")[1]), seed=42)
    return read_edge_list(spec)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", required=True,
                    help="rmat:<scale> | graph500:<scale> | mesh:<side> | "
                         "path to an edge list (.gz ok)")
    ap.add_argument("--super", type=int, default=3)
    ap.add_argument("--normal", type=int, default=6)
    ap.add_argument("--slack", type=float, default=1.8)
    ap.add_argument("--method", default="windgp",
                    choices=registry.names(exclude={"oracle"}))
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--beta", type=float, default=0.3)
    ap.add_argument("--t0", type=int, default=8)
    ap.add_argument("--theta", type=float, default=0.01)
    ap.add_argument("--block-size", type=int, default=None,
                    help="stream-block size for 'blocked' methods")
    ap.add_argument("--out", default=None, help=".npz output path")
    args = ap.parse_args(argv)

    g = load_graph(args.graph)
    cl = scaled_paper_cluster(args.super, args.normal, g.num_edges,
                              slack=args.slack)
    print(f"graph: V={g.num_vertices} E={g.num_edges} "
          f"maxdeg={int(g.degree().max())}; cluster p={cl.p}", flush=True)
    t0 = time.perf_counter()
    if args.method == "windgp":
        res = windgp(g, cl, alpha=args.alpha, beta=args.beta,
                     t0=args.t0, theta=args.theta)
        assign, stats = res.assign, res.stats
    else:
        part = registry.get(args.method)
        kw = {}
        if args.block_size is not None:
            if not part.supports("blocked"):
                ap.error(f"--block-size: method {part.name!r} is not a "
                         f"block-stream method (capabilities: "
                         f"{sorted(part.capabilities)})")
            kw["block_size"] = args.block_size
        assign = part(g, cl, **kw)
        stats = evaluate(g, assign, cl)
    dt = time.perf_counter() - t0
    report = {
        "method": args.method, "seconds": round(dt, 2),
        "TC": stats.tc, "RF": round(stats.rf, 4),
        "feasible": stats.feasible,
        "edges_per_machine": stats.edges_per_part.astype(int).tolist(),
        "t_total_per_machine": np.round(stats.t_total, 1).tolist(),
    }
    print(json.dumps(report, indent=2))
    if args.out:
        np.savez(args.out, assign=assign,
                 machines=np.array([m.as_tuple() for m in cl.machines]))
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
