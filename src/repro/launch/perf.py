import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration driver (EXPERIMENTS.md §Perf).

Compiles one (arch × shape) cell with config overrides (the hillclimb
knobs) and prints the roofline terms, so each hypothesis → change →
re-lower → re-analyse loop is one command:

  PYTHONPATH=src python -m repro.launch.perf --arch qwen3-14b \
      --shape train_4k --set act_shard=seq --set fsdp=off
"""
import argparse
import dataclasses
import json
import sys

import numpy as np

from ..compat import peak_memory_bytes
from ..configs import ARCHS, SHAPES, get_config
from . import dryrun
from .mesh import make_production_mesh


def parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v in ("true", "on"):
            v = True
        elif v in ("false", "off"):
            v = False
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def run_variant(arch: str, shape: str, overrides: dict,
                multi_pod: bool = False) -> dict:
    cfg = get_config(arch)
    fsdp_override = overrides.pop("fsdp", None)
    cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    if fsdp_override is not None:
        # monkey-level knob: build_step decides FSDP by param count; force it
        dryrun._FSDP_OVERRIDE = bool(fsdp_override)
    else:
        dryrun._FSDP_OVERRIDE = None
    try:
        fn, args = dryrun.build_step(cfg, shape, mesh)
        with mesh:
            compiled = fn.lower(*args).compile()
            mem = compiled.memory_analysis()
            coll = dryrun._collective_bytes(
                compiled.as_text(),
                loop_trip=cfg.num_layers // cfg.pattern_period)
    finally:
        dryrun._FSDP_OVERRIDE = None
    sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                    "..", "..", ".."))
    from benchmarks import roofline
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "devices": int(np.prod(list(mesh.shape.values()))),
        "collectives": coll,
        "peak_bytes_per_device": peak_memory_bytes(mem),
        "flops_hlo_body_once": -1,
    }
    out = roofline.analyze(rec)
    out["collective_counts"] = coll["counts"]
    out["collective_bytes"] = coll["bytes_trip_scaled"]
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (plus fsdp=on/off)")
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args(argv)
    out = run_variant(args.arch, args.shape, parse_overrides(args.set),
                      args.multipod)
    print(json.dumps(out, indent=2, default=str))


if __name__ == "__main__":
    main()
