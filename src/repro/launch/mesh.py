"""Production mesh definitions.

A *function*, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

from .. import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic remesh)."""
    return compat.make_mesh(tuple(shape), tuple(axes))
