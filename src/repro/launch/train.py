"""Training driver.

CPU-scale sanity runs use reduced configs; the same driver drives full
configs on real hardware (mesh selection + shardings are config, not code).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
      --steps 50 --batch 8 --seq 128 --checkpoint-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, get_reduced
from ..data.lm_data import LMDataState, SyntheticLM
from ..models import init_params
from ..train import CheckpointManager, adamw_init, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", choices=["int8"], default=None)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} is an embedding-stub arch; train the "
                         "backbone via a token arch or extend the stub.")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={jax.device_count()}")
    opt = adamw_init(params)
    data = SyntheticLM(cfg.vocab_size, seed=args.seed)
    dstate = LMDataState(seed=args.seed, cursor=0)
    start_step = 0

    mgr = CheckpointManager(args.checkpoint_dir) if args.checkpoint_dir else None
    if mgr and args.resume and mgr.latest_step() is not None:
        tmpl = jax.eval_shape(lambda: {"params": params, "opt": opt})
        restored, start_step, extra = mgr.restore(tmpl)
        params, opt = restored["params"], restored["opt"]
        dstate = LMDataState(seed=extra["data_seed"],
                             cursor=extra["data_cursor"])
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(
        cfg, lr=args.lr, microbatches=args.microbatches,
        remat=args.remat, compress=args.compress))

    t0 = time.perf_counter()
    tokens_done = 0
    for step in range(start_step, args.steps):
        batch, dstate = data.batch(dstate, args.batch, args.seq)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step_fn(params, opt, batch)
        tokens_done += args.batch * args.seq
        if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
            dt = time.perf_counter() - t0
            print(f"step {step+1:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"{tokens_done/dt:.0f} tok/s", flush=True)
        if mgr and (step + 1) % args.checkpoint_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt},
                     extra={"data_seed": dstate.seed,
                            "data_cursor": dstate.cursor})
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt},
                 extra={"data_seed": dstate.seed,
                        "data_cursor": dstate.cursor})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
