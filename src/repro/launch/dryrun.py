import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.

For every cell this driver:
  1. builds ShapeDtypeStruct inputs (``input_specs`` — no allocation),
  2. jits the train/prefill/serve step with in/out shardings,
  3. ``.lower().compile()`` — sharding mismatches, compile-time OOM and
     unsupported collectives all fail HERE, which is the point,
  4. records ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()`` (FLOPs/bytes) and the per-collective byte counts
     parsed from the optimized HLO — the §Roofline inputs.

``--bsp`` dry-runs the *graph* side the same way: every (BSP app ×
edge-kernel backend) superstep is shard_mapped over an 8-machine mesh
and lower+compiled — backend sharding bugs, missing replication rules
(Pallas needs ``check_rep=False``; the backends declare it) and the
replica-exchange collective bytes all surface here without running a
superstep.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --bsp --out results/bsp.jsonl
"""

import argparse
import functools
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import peak_memory_bytes
from ..configs import ARCHS, SHAPES, cells, get_config
from ..models import decode_step, forward, init_cache, init_params
from ..sharding import (cache_specs, input_specs_for, logical_batch_spec,
                        param_specs)
from ..train import make_loss_fn, make_train_step
from .mesh import make_production_mesh

_FSDP_OVERRIDE = None   # perf.py may force FSDP on/off per variant


# ---------------------------------------------------------------------------
# input stand-ins (weak-type-correct, shardable, no device allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    if cfg.input_mode == "tokens":
        x = jax.ShapeDtypeStruct((B, S if kind != "decode" else 1), jnp.int32)
    else:
        x = jax.ShapeDtypeStruct(
            (B, S if kind != "decode" else 1, cfg.d_model), jnp.bfloat16)
    if kind == "train":
        return {"inputs": x, "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if kind == "prefill":
        return {"inputs": x}
    return {"inputs": x,
            "cache_len": jax.ShapeDtypeStruct((B,), jnp.int32)}


def _collective_bytes(hlo_text: str, loop_trip: int = 1) -> dict:
    """Per-collective byte totals from the optimized HLO.

    Handles sync and async (``*-start``/``*-done``) forms; for async ops the
    tuple output's first element (the operand alias) is skipped and the
    ``-done`` op is ignored (it aliases the ``-start``).  XLA emits each
    ``lax.scan`` body once; collectives inside non-ENTRY computations (loop
    bodies) execute ``loop_trip`` times per step — both raw and trip-scaled
    totals are reported (callers pass the layer-scan trip count).
    """
    colls = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    sizes = {c: 0 for c in colls}
    body_sizes = {c: 0 for c in colls}
    counts = {c: 0 for c in colls}
    dt_bytes = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    op_re = re.compile(
        r"(?:ROOT\s+)?%?\S+\s*=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter|"
        r"all-to-all|collective-permute)(-start|-done)?\(")
    in_entry = False
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("ENTRY"):
            in_entry = True
            continue
        if re.match(r"%?\S+\s*\(.*\)\s*->", stripped) and \
                stripped.endswith("{"):
            in_entry = False
        m = op_re.match(stripped)
        if not m:
            continue
        out_shape, op, variant = m.group(1), m.group(2), m.group(3)
        if variant == "-done":
            continue                    # aliases its -start
        shapes = shape_re.findall(out_shape)
        if variant == "-start" and len(shapes) > 1:
            shapes = shapes[1:]         # drop the operand alias
        total = 0
        for dt, dims in shapes:
            if dt not in dt_bytes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * dt_bytes[dt]
        (sizes if in_entry else body_sizes)[op] += total
        counts[op] += 1
    raw = {c: sizes[c] + body_sizes[c] for c in colls}
    scaled = {c: sizes[c] + loop_trip * body_sizes[c] for c in colls}
    return {"bytes": raw, "bytes_trip_scaled": scaled, "counts": counts,
            "total_bytes": int(sum(raw.values())),
            "total_bytes_trip_scaled": int(sum(scaled.values()))}


def build_step(cfg, shape_name: str, mesh):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    sh = SHAPES[shape_name]
    B, S, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    pshapes = jax.eval_shape(functools.partial(init_params, cfg),
                             jax.ShapeDtypeStruct((2,), jnp.uint32))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(pshapes))
    # >12B params: shard params over 'data' too (FSDP) or they don't fit HBM
    fsdp = n_params > 12e9
    if globals().get("_FSDP_OVERRIDE") is not None:
        fsdp = bool(_FSDP_OVERRIDE)   # perf.py hillclimb knob
    pspecs = param_specs(cfg, pshapes, mesh, fsdp=fsdp)
    psharding = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    ins = input_specs(cfg, shape_name)
    ispecs = input_specs_for(cfg, mesh, B, kind)
    isharding = {k: NamedSharding(mesh, v) for k, v in ispecs.items()}

    if kind == "train":
        step = make_train_step(cfg, remat=True)
        mspec = NamedSharding(mesh, P())
        opt_shapes = jax.eval_shape(
            lambda p: {"m": p, "v": p,
                       "step": jax.ShapeDtypeStruct((), jnp.int32)}, pshapes)
        # ZeRO: Adam moments additionally shard their largest free dim
        # over 'data'.
        from ..sharding import opt_state_specs
        mom_specs = opt_state_specs(pspecs, pshapes, mesh)
        mom_sharding = jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                                    mom_specs)
        ospec = {"m": mom_sharding, "v": mom_sharding,
                 "step": NamedSharding(mesh, P())}

        fn = jax.jit(
            lambda params, opt, batch: step(params, opt, batch),
            in_shardings=(psharding, ospec,
                          {"inputs": isharding["inputs"],
                           "labels": isharding["labels"]}),
            out_shardings=(psharding, ospec,
                           {"loss": mspec, "grad_norm": mspec}),
            donate_argnums=(0, 1))
        args = (pshapes, opt_shapes, ins)
        return fn, args

    # serving paths share the decode_step entry
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, B, S, dtype=jnp.bfloat16))
    cspecs = cache_specs(cfg, mesh, B, S)
    csharding = jax.tree.map(
        lambda s: NamedSharding(mesh, s), cspecs,
        is_leaf=lambda x: isinstance(x, P))
    bspec = logical_batch_spec(mesh, B)
    lsharding = NamedSharding(mesh, bspec)
    vshard = "model" if cfg.vocab_size % mesh.shape.get("model", 1) == 0 \
        else None
    logits_sharding = NamedSharding(
        mesh, P(bspec[0] if len(bspec) else None, None, vshard))
    if kind == "prefill":
        def prefill(params, cache, inputs):
            zero = jnp.zeros((B,), jnp.int32)
            return decode_step(cfg, params, cache, inputs, zero)
        fn = jax.jit(
            prefill,
            in_shardings=(psharding, csharding, isharding["inputs"]),
            out_shardings=(logits_sharding, csharding),
            donate_argnums=(1,))
        args = (pshapes, cache_shape, ins["inputs"])
        return fn, args

    def serve(params, cache, tokens, cache_len):
        return decode_step(cfg, params, cache, tokens, cache_len)
    fn = jax.jit(
        serve,
        in_shardings=(psharding, csharding, isharding["inputs"], lsharding),
        out_shardings=(logits_sharding, csharding),
        donate_argnums=(1,))
    args = (pshapes, cache_shape, ins["inputs"], ins["cache_len"])
    return fn, args


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    fn, args = build_step(cfg, shape_name, mesh)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = _collective_bytes(compiled.as_text(),
                                 loop_trip=cfg.num_layers // cfg.pattern_period)
    n_super = cfg.num_layers // cfg.pattern_period
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "devices": int(np.prod(list(mesh.shape.values()))),
        "n_super": n_super,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_hlo_body_once": float(cost.get("flops", -1)),
        "bytes_hlo_body_once": float(cost.get("bytes accessed", -1)),
        "argument_size_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_size_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "peak_bytes_per_device": peak_memory_bytes(mem),
        "collectives": coll,
    }
    return rec


def run_bsp_cell(rt, app: str, backend: str, mesh) -> dict:
    """Lower + compile one (BSP app × edge-kernel backend) superstep."""
    from ..bsp.apps import build_app
    from ..bsp.engine import make_step
    opts = {"block_size": 32} if backend == "pallas" else {}
    spec = build_app(rt, app, backend=backend, **opts)
    t0 = time.perf_counter()
    step = make_step(spec.superstep, spec.static, mesh=mesh,
                     check_rep=spec.check_rep)
    lowered = step.lower(spec.state)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()
    coll = _collective_bytes(compiled.as_text())
    return {
        "app": app, "backend": backend, "mesh": "machines8",
        "p": rt.p, "num_replicas": rt.num_replicas,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "peak_bytes_per_device": peak_memory_bytes(mem),
        "collectives": coll,
    }


def run_bsp_cells(out: str, skip_done: bool = False) -> int:
    """The --bsp mode: every (app × backend) superstep over an 8-machine
    mesh; a compile failure in any cell fails the run (that's the point).
    ``skip_done`` mirrors the model path: cells already recorded in
    ``out`` without an error are not re-compiled (re-run after a fix
    appends fresh records; the latest record per cell wins)."""
    from ..bsp import PartitionRuntime
    from ..bsp.apps import APP_BUILDERS
    from ..bsp.backends import BACKENDS
    from ..compat import make_mesh
    from ..core import scaled_paper_cluster, windgp
    from ..data import rmat

    g = rmat(9, seed=2)
    cl = scaled_paper_cluster(2, 6, g.num_edges)      # p = 8 machines
    rt = PartitionRuntime.create(g, assign=windgp(g, cl, t0=2).assign,
                                 cluster=cl)
    mesh = make_mesh((cl.p,), ("machines",))
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    done = set()
    if skip_done and os.path.exists(out):
        with open(out) as f:
            for line in f:
                r = json.loads(line)
                if "error" not in r and "app" in r:
                    done.add((r["app"], r["backend"]))
    failures = 0
    for app in APP_BUILDERS:
        for backend in BACKENDS:
            if (app, backend) in done:
                continue
            tag = f"bsp {app} × {backend}"
            try:
                rec = run_bsp_cell(rt, app, backend, mesh)
                print(f"OK   {tag}: peak "
                      f"{rec['peak_bytes_per_device']/2**20:.1f} MiB/dev, "
                      f"coll {rec['collectives']['total_bytes']/2**10:.1f} "
                      f"KiB/step, compile {rec['compile_s']}s", flush=True)
            except Exception as e:  # noqa: BLE001 - report and continue
                rec = {"app": app, "backend": backend,
                       "error": f"{type(e).__name__}: {e}"}
                print(f"FAIL {tag}: {rec['error'][:300]}", flush=True)
                failures += 1
            with open(out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    return 1 if failures else 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--bsp", action="store_true",
                    help="dry-run the BSP (app × edge-kernel backend) "
                         "supersteps over an 8-machine mesh instead of "
                         "the model cells")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args(argv)

    if args.bsp:
        return run_bsp_cells(args.out, skip_done=args.skip_done)

    todo = cells() if args.all else [(args.arch, args.shape)]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if "error" not in r:
                    done.add((r["arch"], r["shape"], r["mesh"]))
    failures = 0
    for arch, shape in todo:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            if (arch, shape, mesh_name) in done:
                continue
            tag = f"{arch} × {shape} × {mesh_name}"
            try:
                rec = run_cell(arch, shape, mp)
                print(f"OK   {tag}: peak {rec['peak_bytes_per_device']/2**30:.2f} GiB/dev, "
                      f"coll {rec['collectives']['total_bytes_trip_scaled']/2**30:.2f} GiB/step, "
                      f"compile {rec['compile_s']}s", flush=True)
            except Exception as e:  # noqa: BLE001 - report and continue
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "error": f"{type(e).__name__}: {e}"}
                print(f"FAIL {tag}: {rec['error'][:300]}", flush=True)
                failures += 1
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
            jax.clear_caches()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
