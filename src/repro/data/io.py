"""SNAP-style edge-list IO (whitespace-separated ``u v`` per line, # comments).

``iter_edge_blocks`` is the chunked reader behind the block-stream
partitioning path (``core/baselines/streaming.stream_partition``): it
yields ``(B, 2)`` int64 blocks without ever materializing the whole edge
list, transparently handles gzip (``.gz`` suffix), tolerates empty and
comment-only files, and applies ``from_edge_list``'s canonicalization
blockwise — ``u < v`` swap, self-loop drop, within-block dedup (cross-block
duplicates would need global state; callers that must dedup globally read
through ``read_edge_list``, which routes every block into
``from_edge_list``'s exact global dedup).
"""
from __future__ import annotations

import gzip
from typing import Iterator

import numpy as np

from ..core.graph import Graph, from_edge_list

#: Default lines-per-block for the chunked reader.
DEFAULT_BLOCK_LINES = 65536


def _open_text(path: str):
    if str(path).endswith(".gz"):
        return gzip.open(path, "rt")
    return open(path, "r")


def _parse_lines(lines: list[str], comments: str) -> np.ndarray:
    """Parse one buffered chunk of lines into an (n, 2) int64 array.

    Comment/blank tolerance is pre-filtered cheaply; the numeric parse runs
    through ``np.loadtxt`` (C tokenizer) — this is the hot path of the
    out-of-core reader, so no per-edge Python loop.
    """
    kept = [ln for ln in lines
            if ln.strip() and not ln.lstrip().startswith(comments)]
    if not kept:
        return np.empty((0, 2), dtype=np.int64)
    try:
        edges = np.loadtxt(kept, dtype=np.int64, comments=comments,
                           usecols=(0, 1), ndmin=2)
    except (ValueError, IndexError) as e:
        raise ValueError(f"malformed edge-list block: {e}") from None
    return edges.reshape(-1, 2)


def canonicalize_block(edges: np.ndarray, dedup: bool = True) -> np.ndarray:
    """``from_edge_list``'s edge canonicalization, applied to one block.

    Swaps to ``u < v``, drops self loops, and (``dedup``) keeps the first
    occurrence of each within-block duplicate, preserving arrival order.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    u = np.minimum(edges[:, 0], edges[:, 1])
    v = np.maximum(edges[:, 0], edges[:, 1])
    keep = u != v
    u, v = u[keep], v[keep]
    if dedup and len(u):
        key = np.stack([u, v], axis=1)
        _, first = np.unique(key, axis=0, return_index=True)
        first.sort()                       # keep arrival order
        u, v = u[first], v[first]
    return np.stack([u, v], axis=1)


def iter_edge_blocks(path: str, block_size: int = DEFAULT_BLOCK_LINES, *,
                     comments: str = "#",
                     canonicalize: bool = True) -> Iterator[np.ndarray]:
    """Yield ``(<=block_size, 2)`` int64 edge blocks from a (gzipped) file.

    Empty and comment-only files simply yield nothing (``np.loadtxt``
    raises on them).  With ``canonicalize`` each block is normalized like
    ``from_edge_list`` normalizes the whole array (u<v, no self loops,
    within-block dedup), so downstream per-block consumers see the same
    edge representation the in-memory path does.
    """
    block_size = max(1, int(block_size))
    with _open_text(path) as f:
        while True:
            lines = []
            for ln in f:
                lines.append(ln)
                if len(lines) >= block_size:
                    break
            if not lines:
                return
            edges = _parse_lines(lines, comments)
            if canonicalize:
                edges = canonicalize_block(edges)
            if len(edges):
                yield edges


def count_edge_list(path: str, block_size: int = DEFAULT_BLOCK_LINES, *,
                    comments: str = "#") -> tuple[int, int]:
    """(num_vertices, num_edges) of a file, in one chunked pass.

    ``num_vertices`` is ``max id + 1``; ``num_edges`` counts the
    canonicalized per-block edges (the same stream ``iter_edge_blocks``
    will later yield).  The counting pass the stream partitioner needs for
    its memory caps (and EBV's normalization).
    """
    n_v = 0
    n_e = 0
    for blk in iter_edge_blocks(path, block_size, comments=comments):
        n_v = max(n_v, int(blk.max()) + 1)
        n_e += len(blk)
    return n_v, n_e


def read_edge_list(path: str, num_vertices: int | None = None) -> Graph:
    """Read a whole edge list into a :class:`Graph` (exact global dedup)."""
    blocks = list(iter_edge_blocks(path, canonicalize=False))
    if blocks:
        edges = np.concatenate(blocks, axis=0)
    else:
        edges = np.empty((0, 2), dtype=np.int64)
    if num_vertices is None and len(edges) == 0:
        num_vertices = 0
    return from_edge_list(edges, num_vertices=num_vertices)


def write_edge_list(g: Graph, path: str) -> None:
    np.savetxt(path, g.edges, fmt="%d",
               header=f"V={g.num_vertices} E={g.num_edges}")
