"""SNAP-style edge-list IO (whitespace-separated ``u v`` per line, # comments)."""
from __future__ import annotations

import numpy as np

from ..core.graph import Graph, from_edge_list


def read_edge_list(path: str, num_vertices: int | None = None) -> Graph:
    edges = np.loadtxt(path, dtype=np.int64, comments="#", ndmin=2)
    return from_edge_list(edges[:, :2], num_vertices=num_vertices)


def write_edge_list(g: Graph, path: str) -> None:
    np.savetxt(path, g.edges, fmt="%d",
               header=f"V={g.num_vertices} E={g.num_edges}")
