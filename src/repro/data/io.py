"""SNAP-style edge-list IO (whitespace-separated ``u v`` per line, # comments).

``iter_edge_blocks`` is the chunked reader behind the block-stream
partitioning path (``core/baselines/streaming.stream_partition``): it
yields ``(B, 2)`` int64 blocks without ever materializing the whole edge
list, transparently handles gzip (``.gz`` suffix), tolerates empty and
comment-only files, and applies ``from_edge_list``'s canonicalization
blockwise — ``u < v`` swap, self-loop drop, within-block dedup.

Cross-block duplicates need global state; two layers provide it:

* ``read_edge_list`` routes every block into ``from_edge_list``'s exact
  in-memory global dedup (the whole edge set materializes);
* :class:`TwoPassDedup` is the out-of-core equivalent — pass one hashes
  canonicalized edges into bounded spill buckets on disk, pass two streams
  each bucket back exactly deduplicated and k-way-merges the buckets on
  their stamped arrival index, so iterating it yields the globally-unique
  edge stream *in first-occurrence order* while peak edge residency stays
  bounded by the bucket size (``SpillStats`` carries the accounting).  The
  external-memory discipline follows HEP-style hybrid partitioners: spill
  cheap, dedup per bounded bucket, merge streams.
"""
from __future__ import annotations

import dataclasses
import gzip
import os
import pathlib
import shutil
import tempfile
from typing import Iterator

import numpy as np

from ..core.graph import Graph, from_edge_list

#: Default lines-per-block for the chunked reader.
DEFAULT_BLOCK_LINES = 65536


def _open_text(path: str):
    if str(path).endswith(".gz"):
        return gzip.open(path, "rt")
    return open(path, "r")


def _parse_lines(lines: list[str], comments: str) -> np.ndarray:
    """Parse one buffered chunk of lines into an (n, 2) int64 array.

    Comment/blank tolerance is pre-filtered cheaply; the numeric parse runs
    through ``np.loadtxt`` (C tokenizer) — this is the hot path of the
    out-of-core reader, so no per-edge Python loop.
    """
    kept = [ln for ln in lines
            if ln.strip() and not ln.lstrip().startswith(comments)]
    if not kept:
        return np.empty((0, 2), dtype=np.int64)
    try:
        edges = np.loadtxt(kept, dtype=np.int64, comments=comments,
                           usecols=(0, 1), ndmin=2)
    except (ValueError, IndexError) as e:
        raise ValueError(f"malformed edge-list block: {e}") from None
    return edges.reshape(-1, 2)


def canonicalize_block(edges: np.ndarray, dedup: bool = True) -> np.ndarray:
    """``from_edge_list``'s edge canonicalization, applied to one block.

    Swaps to ``u < v``, drops self loops, and (``dedup``) keeps the first
    occurrence of each within-block duplicate, preserving arrival order.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    u = np.minimum(edges[:, 0], edges[:, 1])
    v = np.maximum(edges[:, 0], edges[:, 1])
    keep = u != v
    u, v = u[keep], v[keep]
    if dedup and len(u):
        key = np.stack([u, v], axis=1)
        _, first = np.unique(key, axis=0, return_index=True)
        first.sort()                       # keep arrival order
        u, v = u[first], v[first]
    return np.stack([u, v], axis=1)


def iter_edge_blocks(path: str, block_size: int = DEFAULT_BLOCK_LINES, *,
                     comments: str = "#",
                     canonicalize: bool = True) -> Iterator[np.ndarray]:
    """Yield ``(<=block_size, 2)`` int64 edge blocks from a (gzipped) file.

    Empty and comment-only files simply yield nothing (``np.loadtxt``
    raises on them).  With ``canonicalize`` each block is normalized like
    ``from_edge_list`` normalizes the whole array (u<v, no self loops,
    within-block dedup), so downstream per-block consumers see the same
    edge representation the in-memory path does.
    """
    block_size = max(1, int(block_size))
    with _open_text(path) as f:
        while True:
            lines = []
            for ln in f:
                lines.append(ln)
                if len(lines) >= block_size:
                    break
            if not lines:
                return
            edges = _parse_lines(lines, comments)
            if canonicalize:
                edges = canonicalize_block(edges)
            if len(edges):
                yield edges


def byte_ranges(path: str, n: int) -> list[tuple[int, int]]:
    """Split a plain-text file into ``n`` contiguous byte ranges.

    Combined with :func:`iter_edge_blocks_range`'s line-alignment rule
    (a reader owns exactly the lines whose *first byte* falls inside its
    range), the ranges partition the file's lines disjointly and
    exhaustively — the range-reader side of the sharded ingest pipeline
    (``core/parallel.py``).  Gzip files cannot be byte-ranged (no random
    access into the compressed stream); callers fall back to one range.
    """
    size = os.path.getsize(path)
    n = max(1, int(n))
    cuts = [size * i // n for i in range(n + 1)]
    return [(cuts[i], cuts[i + 1]) for i in range(n)]


def iter_edge_blocks_range(path: str, start: int, end: int,
                           block_size: int = DEFAULT_BLOCK_LINES, *,
                           comments: str = "#",
                           canonicalize: bool = True) -> Iterator[np.ndarray]:
    """``iter_edge_blocks`` restricted to the lines starting in [start, end).

    Hadoop-split alignment: the reader seeks to ``start - 1``, and skips
    one partial line only when the byte there is not a newline (that line
    *started* in the previous range, whose reader owns it); it then reads
    whole lines while their first byte lies before ``end`` — the final
    owned line may extend past the boundary.  Every line is therefore
    consumed by exactly one of the readers over :func:`byte_ranges`'s
    cover, in file order within each range.
    """
    if str(path).endswith(".gz"):
        raise ValueError("byte-range reads need a plain-text file; gzip "
                         "streams have no line-addressable byte offsets")
    block_size = max(1, int(block_size))
    with open(path, "rb") as f:
        if start > 0:
            f.seek(start - 1)
            if f.read(1) != b"\n":
                f.readline()
        pos = f.tell()
        lines: list[str] = []
        while pos < end:
            ln = f.readline()
            if not ln:
                break
            pos += len(ln)
            lines.append(ln.decode())
            if len(lines) >= block_size:
                edges = _parse_lines(lines, comments)
                lines = []
                if canonicalize:
                    edges = canonicalize_block(edges)
                if len(edges):
                    yield edges
        if lines:
            edges = _parse_lines(lines, comments)
            if canonicalize:
                edges = canonicalize_block(edges)
            if len(edges):
                yield edges


def count_edge_list(path: str, block_size: int = DEFAULT_BLOCK_LINES, *,
                    comments: str = "#") -> tuple[int, int]:
    """(num_vertices, num_edges) of a file, in one chunked pass.

    ``num_vertices`` is ``max id + 1``; ``num_edges`` counts the
    canonicalized per-block edges (the same stream ``iter_edge_blocks``
    will later yield).  The counting pass the stream partitioner needs for
    its memory caps (and EBV's normalization).
    """
    n_v = 0
    n_e = 0
    for blk in iter_edge_blocks(path, block_size, comments=comments):
        n_v = max(n_v, int(blk.max()) + 1)
        n_e += len(blk)
    return n_v, n_e


def read_edge_list(path: str, num_vertices: int | None = None) -> Graph:
    """Read a whole edge list into a :class:`Graph` (exact global dedup)."""
    blocks = list(iter_edge_blocks(path, canonicalize=False))
    if blocks:
        edges = np.concatenate(blocks, axis=0)
    else:
        edges = np.empty((0, 2), dtype=np.int64)
    if num_vertices is None and len(edges) == 0:
        num_vertices = 0
    return from_edge_list(edges, num_vertices=num_vertices)


def write_edge_list(g: Graph, path: str) -> None:
    np.savetxt(path, g.edges, fmt="%d",
               header=f"V={g.num_vertices} E={g.num_edges}")


# ---------------------------------------------------------------------------
# two-pass out-of-core exact dedup (spill buckets + ordered merge)
# ---------------------------------------------------------------------------

#: Bound on spill-bucket fan-out (open file handles during the merge).
MAX_BUCKETS = 4096

#: Rows (int64 triples) read per bucket per refill during the merge.
DEFAULT_MERGE_ROWS = 8192


def _bucket_of(u: np.ndarray, v: np.ndarray, nb: int) -> np.ndarray:
    """Deterministic spill bucket per canonical edge (Fibonacci mixing)."""
    h = (u.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
         ^ v.astype(np.uint64) * np.uint64(0xC2B2AE3D27D4EB4F))
    return (h % np.uint64(nb)).astype(np.int64)


@dataclasses.dataclass
class SpillStats:
    """Accounting of one :class:`TwoPassDedup` run.

    ``peak_resident_rows`` is the largest number of edge rows simultaneously
    held in memory across every phase (spill blocks, bucket dedup loads,
    merge buffers + emit batch) — the quantity the out-of-core guarantee
    bounds: it scales with ``bucket_rows``/``merge_rows``/``block_size``,
    never with the edge-set size.
    """

    num_buckets: int = 0
    bucket_rows: int = 0          # configured per-bucket row target
    merge_rows: int = 0           # per-bucket refill size during the merge
    spilled_rows: int = 0         # pass-1 canonicalized rows (pre-dedup)
    unique_edges: int = 0         # post-dedup edge count
    max_bucket_rows: int = 0      # largest raw bucket loaded in pass 2
    peak_resident_rows: int = 0
    #: processes that ran the spill/dedup passes (sharded ingest sums the
    #: per-worker residency peaks into ``peak_resident_rows``, so the bound
    #: stays an upper bound on *simultaneous* resident rows)
    workers: int = 1

    @property
    def duplicate_rows(self) -> int:
        return self.spilled_rows - self.unique_edges

    def _saw(self, rows: int) -> None:
        self.peak_resident_rows = max(self.peak_resident_rows, int(rows))


class TwoPassDedup:
    """Exact global dedup of an edge-list file without holding the edge set.

    Pass one streams ``iter_edge_blocks`` (canonicalized, per-block dedup),
    stamps each surviving row with its global arrival index, and appends
    ``(idx, u, v)`` int64 triples to ``ceil(rows / bucket_rows)`` hash
    buckets on disk — every duplicate of an edge lands in the same bucket.
    Pass two (:meth:`prepare` finishes it) loads one bucket at a time —
    peak residency is the largest bucket, not the edge set — keeps the
    earliest arrival of each edge, and writes the bucket back sorted by
    arrival index.  Iterating the object k-way-merges the sorted buckets in
    bounded ``merge_rows`` chunks, yielding ``(<=block_size, 2)`` blocks of
    globally-unique edges in first-occurrence order — the same stream order
    an in-memory partitioner would see after ``from_edge_list`` dedup, so
    streamed and in-memory decisions are comparable edge for edge.

    Use as a context manager (or call :meth:`close`) to drop the spill
    directory; iteration is repeatable until then.
    """

    def __init__(self, path: str, spill_dir: str | None = None, *,
                 block_size: int = DEFAULT_BLOCK_LINES,
                 bucket_rows: int = 1 << 16,
                 merge_rows: int = DEFAULT_MERGE_ROWS,
                 comments: str = "#"):
        self.path = str(path)
        self.block_size = max(1, int(block_size))
        self.comments = comments
        self._owns_dir = spill_dir is None
        self.spill_dir = pathlib.Path(
            tempfile.mkdtemp(prefix="windgp-spill-") if spill_dir is None
            else spill_dir)
        self.spill_dir.mkdir(parents=True, exist_ok=True)
        self.stats = SpillStats(bucket_rows=max(1, int(bucket_rows)),
                                merge_rows=max(1, int(merge_rows)))
        self.num_vertices = 0
        self.num_edges = 0
        self._prepared = False

    def _estimate_rows(self) -> int:
        """Cheap row-count bound for the bucket fan-out, from the byte size.

        Only bucket *sizing* depends on this — correctness never does
        (every duplicate pair hashes to the same bucket at any fan-out);
        a misestimate just moves actual bucket sizes off the
        ``bucket_rows`` target, and ``SpillStats`` reports the real ones.
        ``u v\\n`` lines run ≥ 8 bytes on average for graphs past toy ids;
        gzip text typically compresses ~3×.
        """
        import os
        size = os.path.getsize(self.path)
        if str(self.path).endswith(".gz"):
            size *= 3
        return max(1, size // 8)

    # -- pass 1 + per-bucket dedup ------------------------------------------
    def prepare(self) -> tuple[int, int]:
        """Run the spill and dedup passes; returns exact ``(|V|, |E|)``.

        Idempotent — the first call does the work, later calls return the
        cached counts (the merge iterator calls it defensively).
        """
        if self._prepared:
            return self.num_vertices, self.num_edges
        st = self.stats
        nb = int(min(MAX_BUCKETS,
                     max(1, -(-self._estimate_rows() // st.bucket_rows))))
        st.num_buckets = nb
        # pass 1: stamp arrival indices, split each block by bucket hash,
        # append (idx, u, v) triples — sequential appends, no seeks; the
        # vertex bound (which keys the bucket dedup) folds into this scan,
        # so the text file is parsed exactly once (pass 2 reads binary
        # buckets only)
        raw = [self.spill_dir / f"bucket{b}.raw" for b in range(nb)]
        files = [open(p, "wb") for p in raw]
        n_v = 0
        try:
            base = 0
            for blk in iter_edge_blocks(self.path, self.block_size,
                                        comments=self.comments):
                st._saw(len(blk))
                n_v = max(n_v, int(blk.max()) + 1)
                u, v = blk[:, 0], blk[:, 1]
                idx = np.arange(base, base + len(blk), dtype=np.int64)
                base += len(blk)
                h = _bucket_of(u, v, nb)
                order = np.argsort(h, kind="stable")
                rows = np.stack([idx, u, v], axis=1)[order]
                hs = h[order]
                bounds = np.searchsorted(hs, np.arange(nb + 1))
                for b in range(nb):
                    lo, hi = bounds[b], bounds[b + 1]
                    if hi > lo:
                        rows[lo:hi].tofile(files[b])
            st.spilled_rows = base
            self.num_vertices = n_v
        finally:
            for f in files:
                f.close()
        # pass 2a: exact dedup per bounded bucket, written back sorted by
        # arrival index (keep-first == min index: file order is arrival
        # order, np.unique's return_index picks the first occurrence)
        unique = 0
        for b in range(nb):
            arr = np.fromfile(raw[b], dtype=np.int64).reshape(-1, 3)
            raw[b].unlink()
            st.max_bucket_rows = max(st.max_bucket_rows, len(arr))
            st._saw(len(arr))
            if len(arr):
                key = arr[:, 1] * np.int64(max(1, n_v)) + arr[:, 2]
                _, first = np.unique(key, return_index=True)
                first.sort()
                arr = arr[first]
                arr.tofile(self.spill_dir / f"bucket{b}.dedup")
            unique += len(arr)
        st.unique_edges = unique
        self.num_edges = unique
        self._prepared = True
        return self.num_vertices, self.num_edges

    # -- pass 2b: ordered streaming merge -----------------------------------
    def __iter__(self) -> Iterator[np.ndarray]:
        """Yield ``(<=block_size, 2)`` globally-unique blocks in
        first-occurrence order, holding only ``num_buckets × merge_rows``
        rows of merge buffer plus one emit batch."""
        self.prepare()
        st = self.stats
        nb = st.num_buckets
        paths = [self.spill_dir / f"bucket{b}.dedup" for b in range(nb)]
        readers = [open(p, "rb") if p.exists() else None for p in paths]
        empty = np.empty((0, 3), dtype=np.int64)
        bufs = [empty] * nb
        done = [r is None for r in readers]
        try:
            while True:
                for b in range(nb):
                    if not len(bufs[b]) and not done[b]:
                        raw = readers[b].read(3 * 8 * st.merge_rows)
                        if raw:
                            bufs[b] = np.frombuffer(
                                raw, dtype=np.int64).reshape(-1, 3)
                        if len(raw) < 3 * 8 * st.merge_rows:
                            done[b] = True
                # rows beyond a live reader's buffer all carry larger
                # arrival indices (buckets are idx-sorted), so the safe
                # emit frontier is the smallest last-buffered index
                tails = [bufs[b][-1, 0] for b in range(nb)
                         if not done[b] and len(bufs[b])]
                frontier = min(tails) if tails else None
                parts = []
                for b in range(nb):
                    buf = bufs[b]
                    if not len(buf):
                        continue
                    cut = (len(buf) if frontier is None else
                           int(np.searchsorted(buf[:, 0], frontier,
                                               side="right")))
                    if cut:
                        parts.append(buf[:cut])
                        bufs[b] = buf[cut:]
                if not parts:
                    if all(done[b] and not len(bufs[b]) for b in range(nb)):
                        return
                    continue
                batch = np.concatenate(parts, axis=0)
                batch = batch[np.argsort(batch[:, 0], kind="stable")]
                st._saw(len(batch) + sum(len(x) for x in bufs))
                for lo in range(0, len(batch), self.block_size):
                    yield batch[lo:lo + self.block_size, 1:]
        finally:
            for r in readers:
                if r is not None:
                    r.close()

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        """Remove the spill directory (only if this object created it)."""
        if self._owns_dir and self.spill_dir.exists():
            shutil.rmtree(self.spill_dir, ignore_errors=True)

    def __enter__(self) -> "TwoPassDedup":
        self.prepare()
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def two_pass_dedup(path: str, spill_dir: str | None = None,
                   **kw) -> TwoPassDedup:
    """Prepared :class:`TwoPassDedup` over ``path`` (see the class docs)."""
    tp = TwoPassDedup(path, spill_dir, **kw)
    tp.prepare()
    return tp
