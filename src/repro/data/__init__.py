"""Graph generators and IO (R-MAT / road mesh / SNAP edge lists)."""
from .generators import rmat, road_mesh, erdos_renyi, graph500
from .io import read_edge_list, write_edge_list

__all__ = ["rmat", "road_mesh", "erdos_renyi", "graph500",
           "read_edge_list", "write_edge_list"]
