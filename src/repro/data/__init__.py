"""Graph generators and IO (R-MAT / road mesh / SNAP edge lists)."""
from .generators import rmat, road_mesh, erdos_renyi, graph500
from .io import (SpillStats, TwoPassDedup, canonicalize_block,
                 count_edge_list, iter_edge_blocks, read_edge_list,
                 two_pass_dedup, write_edge_list)

__all__ = ["rmat", "road_mesh", "erdos_renyi", "graph500",
           "read_edge_list", "write_edge_list", "iter_edge_blocks",
           "count_edge_list", "canonicalize_block", "TwoPassDedup",
           "SpillStats", "two_pass_dedup"]
