"""Deterministic, resumable synthetic LM data pipeline.

A Zipf-token Markov-chain corpus: enough structure that cross-entropy
drops well below the unigram entropy (so training curves are meaningful),
fully deterministic from (seed, cursor) so checkpoint resume is bitwise.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class LMDataState:
    seed: int
    cursor: int          # number of batches already served


class SyntheticLM:
    """Markov bigram sampler with Zipf marginals."""

    def __init__(self, vocab_size: int, branching: int = 8, seed: int = 0):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed ^ 0x5EED)
        # each token can transition to `branching` successors
        self.succ = rng.integers(0, vocab_size, size=(vocab_size, branching))
        probs = 1.0 / np.arange(1, vocab_size + 1)
        self.marginal = probs / probs.sum()
        self.seed = seed

    def batch(self, state: LMDataState, batch_size: int, seq_len: int):
        rng = np.random.default_rng((state.seed << 20) ^ state.cursor)
        toks = np.empty((batch_size, seq_len + 1), dtype=np.int32)
        toks[:, 0] = rng.choice(self.vocab, size=batch_size, p=self.marginal)
        choices = rng.integers(0, self.succ.shape[1],
                               size=(batch_size, seq_len))
        resets = rng.random((batch_size, seq_len)) < 0.05
        fresh = rng.choice(self.vocab, size=(batch_size, seq_len),
                           p=self.marginal)
        for t in range(seq_len):
            nxt = self.succ[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(resets[:, t], fresh[:, t], nxt)
        new_state = LMDataState(seed=state.seed, cursor=state.cursor + 1)
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}, new_state
