"""Synthetic graph generators.

* ``rmat``: recursive-matrix power-law generator (Chakrabarti et al. 2004),
  with the Graph500 parameterization (a,b,c,d)=(.57,.19,.19,.05) used by the
  paper's scalability study (Section 5.3, Table 12).
* ``road_mesh``: 2-D lattice with rewired diagonals — a mesh-like, nearly
  degree-regular stand-in for roadNet-CA (RN).
* ``erdos_renyi``: uniform random graph (control case).
"""
from __future__ import annotations

import numpy as np

from ..core.graph import Graph, from_edge_list


def rmat(scale: int, edge_factor: int = 16, *,
         a: float = 0.57, b: float = 0.19, c: float = 0.19,
         seed: int = 0) -> Graph:
    """R-MAT graph with 2**scale vertices and ~edge_factor·V edges."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        go_right = (r >= a) & (r < ab)          # src stays, dst moves
        go_down = (r >= ab) & (r < abc)          # src moves, dst stays
        go_diag = r >= abc                       # both move
        src = (src << 1) | (go_down | go_diag)
        dst = (dst << 1) | (go_right | go_diag)
    # Permute vertex ids to break the implicit locality of the recursion.
    perm = rng.permutation(n)
    return from_edge_list(np.stack([perm[src], perm[dst]], axis=1),
                          num_vertices=n)


def graph500(scale: int, seed: int = 0) -> Graph:
    """Graph500 reference settings: edge factor 16, (.57,.19,.19,.05)."""
    return rmat(scale, edge_factor=16, seed=seed)


def road_mesh(side: int, *, rewire: float = 0.02, seed: int = 0) -> Graph:
    """side×side 4-connected lattice; ``rewire`` fraction of extra chords.

    Mesh-like (max degree ~8, like roadNet-CA's 8): models the paper's RN.
    """
    rng = np.random.default_rng(seed)
    n = side * side
    idx = np.arange(n).reshape(side, side)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1)
    edges = [right, down]
    k = int(rewire * 2 * n)
    if k:
        u = rng.integers(0, n, k)
        # short-range chords only (keep it mesh-like)
        off = rng.integers(1, 4, k) * rng.choice([1, side, side + 1], k)
        v = np.clip(u + off, 0, n - 1)
        edges.append(np.stack([u, v], axis=1))
    return from_edge_list(np.concatenate(edges, axis=0), num_vertices=n)


def erdos_renyi(n: int, avg_degree: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2 * 1.1)
    e = rng.integers(0, n, size=(m, 2))
    return from_edge_list(e, num_vertices=n)
