"""mamba2-780m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=0, vocab_size=50280,
    attn_type="none", mlp_type="none",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="mamba2-780m-smoke", family="ssm",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=512,
    attn_type="none", mlp_type="none",
    ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_groups=1,
    ssd_chunk=16, tie_embeddings=True, dtype="float32",
)
