"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
on every other layer [arXiv:2403.19887].

Note: Jamba's SSM layers are Mamba-1 in the original; we implement them
with the SSD (Mamba-2) formulation — same state size/interface, TPU-native
chunked scan (see DESIGN.md hardware-adaptation notes).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=65536,
    num_experts=16, experts_per_token=2, moe_every=2,
    attn_every=8,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    mlp_type="swiglu",
)

REDUCED = ModelConfig(
    name="jamba-smoke", family="hybrid",
    num_layers=8, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=128, vocab_size=512,
    num_experts=4, experts_per_token=2, moe_every=2,
    attn_every=8,
    ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_groups=1,
    ssd_chunk=16, mlp_type="swiglu", dtype="float32",
)
