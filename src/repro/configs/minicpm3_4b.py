"""minicpm3-4b [dense] — MLA (multi-head latent attention) [hf:openbmb/MiniCPM3-4B]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=6400, vocab_size=73448,
    attn_type="mla", kv_lora_rank=256, q_lora_rank=768,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    mlp_type="swiglu",
)

REDUCED = ModelConfig(
    name="minicpm3-4b-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512,
    attn_type="mla", kv_lora_rank=32, q_lora_rank=48,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    mlp_type="swiglu", dtype="float32",
)
