"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import dataclasses

from ..models.config import ModelConfig
from . import (glm4_9b, granite_moe_3b_a800m, jamba_v01_52b, mamba2_780m,
               minicpm3_4b, musicgen_medium, paligemma_3b, phi35_moe_42b,
               qwen3_14b, qwen3_4b)

_MODULES = {
    "mamba2-780m": mamba2_780m,
    "glm4-9b": glm4_9b,
    "qwen3-4b": qwen3_4b,
    "minicpm3-4b": minicpm3_4b,
    "qwen3-14b": qwen3_14b,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b,
    "jamba-v0.1-52b": jamba_v01_52b,
    "musicgen-medium": musicgen_medium,
    "paligemma-3b": paligemma_3b,
}

ARCHS = tuple(_MODULES)

# Input shapes assigned to the LM family (seq_len, global_batch, kind).
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# long_500k needs a sub-quadratic sequence path: only SSM/hybrid qualify.
LONG_CONTEXT_ARCHS = ("mamba2-780m", "jamba-v0.1-52b")


def get_config(name: str) -> ModelConfig:
    return _MODULES[name].CONFIG


def get_reduced(name: str) -> ModelConfig:
    """Smoke-test-sized config of the same family/pattern."""
    return _MODULES[name].REDUCED


def cells():
    """All (arch, shape) dry-run cells, honoring the long-context skip."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            if s == "long_500k" and a not in LONG_CONTEXT_ARCHS:
                continue
            out.append((a, s))
    return out
