"""glm4-9b [dense] — RoPE (partial), GQA kv=2 [hf:THUDM/glm-4-9b]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=151552,
    rope_fraction=0.5, mlp_type="swiglu",
)

REDUCED = ModelConfig(
    name="glm4-9b-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512,
    rope_fraction=0.5, mlp_type="swiglu", dtype="float32",
)
