"""paligemma-3b [vlm] — SigLIP + gemma backbone [arXiv:2407.07726].
Backbone only: the vision tower is a stub; input_specs() provides
precomputed patch+text embeddings (B, S, d_model)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    head_dim=256, d_ff=16384, vocab_size=257216,
    mlp_type="geglu", input_mode="embeddings",
)

REDUCED = ModelConfig(
    name="paligemma-smoke", family="vlm",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=1,
    head_dim=32, d_ff=256, vocab_size=512,
    mlp_type="geglu", input_mode="embeddings", dtype="float32",
)
