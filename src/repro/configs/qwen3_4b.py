"""qwen3-4b [dense] — qk_norm, GQA kv=8 [hf:Qwen/Qwen3-8B family]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=9728, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, mlp_type="swiglu",
)

REDUCED = ModelConfig(
    name="qwen3-4b-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512,
    qk_norm=True, mlp_type="swiglu", dtype="float32",
)
