"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].  Modality frontend is a stub: input_specs() provides
precomputed frame embeddings (B, S, d_model)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    head_dim=64, d_ff=6144, vocab_size=2048,
    mlp_type="gelu", input_mode="embeddings",
)

REDUCED = ModelConfig(
    name="musicgen-smoke", family="audio",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=256, vocab_size=256,
    mlp_type="gelu", input_mode="embeddings", dtype="float32",
)
