"""granite-moe-3b-a800m [moe] — 40 experts top-8, per-expert d_ff=512
[hf:ibm-granite family]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    head_dim=64, d_ff=512, vocab_size=49155,
    num_experts=40, experts_per_token=8, moe_every=1,
    mlp_type="swiglu",
)

REDUCED = ModelConfig(
    name="granite-moe-smoke", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=64, vocab_size=512,
    num_experts=8, experts_per_token=2, moe_every=1,
    mlp_type="swiglu", dtype="float32",
)
