"""qwen3-14b [dense] — qk_norm, GQA kv=8 [hf:Qwen/Qwen3-8B family]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=17408, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, mlp_type="swiglu",
)

REDUCED = ModelConfig(
    name="qwen3-14b-smoke", family="dense",
    num_layers=2, d_model=160, num_heads=5, num_kv_heads=1,
    head_dim=32, d_ff=320, vocab_size=512,
    qk_norm=True, mlp_type="swiglu", dtype="float32",
)
