from .optimizer import adamw_init, adamw_update
from .train_step import make_train_step, make_loss_fn
from .checkpoint import CheckpointManager
from .compression import quantize_int8, dequantize_int8, compress_grads
from .hetero_batch import heterogeneous_batch_split

__all__ = ["adamw_init", "adamw_update", "make_train_step", "make_loss_fn",
           "CheckpointManager", "quantize_int8", "dequantize_int8",
           "compress_grads", "heterogeneous_batch_split"]
