"""Checkpoint manager: atomic, keep-last-k, bitwise-resumable.

Layout: <dir>/step_<n>/arrays.npz + manifest.json, written to a tmp dir and
renamed (atomic on POSIX) so a killed writer never leaves a half checkpoint
visible.  State includes params, optimizer moments, the data-pipeline
cursor and the PRNG key, so resume is bitwise.

On a real multi-host cluster each host writes its local shards
(process-local ``.npz``) and host 0 the manifest; restore reads per-host
files and ``jax.device_put``s onto the (possibly different) target mesh —
that re-sharding path is what ``elastic_restore`` exercises.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- write ----------------------------------------------------------
    def save(self, step: int, state: dict, extra: dict | None = None):
        """state: pytree of arrays; extra: json-serializable metadata."""
        arrays, _ = _flatten(state)
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {"step": int(step), "extra": extra or {},
                        "keys": sorted(arrays)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(self.directory, f"step_{step:010d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)           # atomic publish
        finally:
            if os.path.exists(tmp):
                shutil.rmtree(tmp, ignore_errors=True)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- read -----------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None,
                shardings=None):
        """template: pytree with the target structure (shapes for checking).

        shardings: optional matching pytree of NamedSharding — restoring
        onto a *different* mesh than the one that saved is the elastic path.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_flat = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(flat))
        leaves = []
        for (path, leaf), sh in zip(flat, shard_flat):
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in path)
            arr = data[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            x = jnp.asarray(arr, dtype=leaf.dtype)
            if sh is not None:
                x = jax.device_put(x, sh)
            leaves.append(x)
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        return state, manifest["step"], manifest["extra"]
