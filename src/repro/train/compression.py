"""Gradient compression: int8 symmetric quantization with per-tensor scale.

On a real cluster the quantized payload is what crosses the pod-to-pod DCN
link (8× less than f32, 2× less than bf16); here we reproduce the numerics
(quantize → dequantize) so convergence behaviour matches what the wire
format would deliver.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads):
    def roundtrip(g):
        if g.size <= 1024:      # tiny tensors (norms, biases): keep exact
            return g
        q, s = quantize_int8(g.astype(jnp.float32))
        return dequantize_int8(q, s).astype(g.dtype)
    return jax.tree.map(roundtrip, grads)
