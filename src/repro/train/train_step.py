"""Train step: remat'd forward, microbatched grad accumulation, AdamW.

Distribution notes (1000+-node design):
* gradients reduce over the DP axes implicitly through pjit (sharded batch,
  replicated params): XLA emits hierarchical reduce-scatter in-pod then
  all-reduce across the ``pod`` axis;
* microbatching (``microbatches > 1``) both caps activation memory and
  splits the backward into several reduce windows XLA's latency-hiding
  scheduler can overlap with compute;
* ``compress='int8'`` fake-quantizes gradients before the optimizer — the
  numerics of an int8-compressed all-reduce (the wire format on a real
  cluster) while staying a pure XLA program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..models import forward
from .compression import compress_grads
from .optimizer import adamw_init, adamw_update


def make_loss_fn(cfg, *, remat: bool = True):
    def loss_fn(params, inputs, labels):
        logits = forward(cfg, params, inputs, remat=remat).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        return (logz - gold).mean()
    return loss_fn


def make_train_step(cfg, *, lr=3e-4, weight_decay=0.01, grad_clip=1.0,
                    microbatches: int = 1, remat: bool = True,
                    compress: str | None = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch: {"inputs": (B, S) or (B, S, d), "labels": (B, S)}.
    """
    loss_fn = make_loss_fn(cfg, remat=remat)

    def train_step(params, opt_state, batch):
        inputs, labels = batch["inputs"], batch["labels"]
        if microbatches > 1:
            B = inputs.shape[0]
            k = microbatches
            assert B % k == 0, (B, k)
            mb_in = inputs.reshape((k, B // k) + inputs.shape[1:])
            mb_lb = labels.reshape((k, B // k) + labels.shape[1:])

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb[0], mb[1])
                g_acc = jax.tree.map(jnp.add, g_acc,
                                     jax.tree.map(lambda x: x / k, g))
                return (g_acc, l_acc + l / k), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = jax.lax.scan(acc_step, (g0, 0.0),
                                            (mb_in, mb_lb))
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, inputs, labels)
        if compress == "int8":
            grads = compress_grads(grads)
        params, opt_state, gnorm = adamw_update(
            grads, opt_state, params, lr=lr, weight_decay=weight_decay,
            grad_clip=grad_clip)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step
