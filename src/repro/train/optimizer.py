"""AdamW with f32 moments (params may be bf16), decoupled weight decay."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, opt_state, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.0, grad_clip=1.0):
    step = opt_state["step"] + 1
    if grad_clip:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
    else:
        gnorm = jnp.zeros(())

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params,
                       is_leaf=lambda x: isinstance(x, jax.Array))
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
