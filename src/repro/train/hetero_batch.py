"""Heterogeneous per-pod batch capacities — WindGP Algorithm 1, reused.

The paper's capacity phase answers: given machines with compute cost C_i
and memory M_i, how many work units should each hold so the slowest
machine's makespan is minimized?  For LM training across pods of mixed
TPU generations the work unit is one *sample*: C_i = measured (or modeled)
per-sample step time, M_i = HBM budget in per-sample activation units.

This is the paper's technique applied verbatim to the training substrate
(see DESIGN.md §4) — it is the straggler-mitigation story for dense archs
where no expert/graph structure exists.
"""
from __future__ import annotations

import numpy as np

from ..core.capacity import capacities
from ..core.machines import Cluster, Machine


def heterogeneous_batch_split(global_batch: int, pod_step_cost,
                              pod_mem_samples=None) -> np.ndarray:
    """Split ``global_batch`` samples across pods.

    pod_step_cost[i]: relative per-sample step time of pod i (e.g. 1.0 for
    v5e, 0.55 for v5p).  pod_mem_samples[i]: max samples pod i fits.
    Returns integer per-pod batch sizes summing to global_batch.
    """
    pod_step_cost = np.asarray(pod_step_cost, dtype=np.float64)
    p = len(pod_step_cost)
    if pod_mem_samples is None:
        pod_mem_samples = np.full(p, global_batch)
    machines = tuple(
        Machine(memory=float(m) * 1.0, c_node=0.0, c_edge=float(c), c_com=1.0)
        for c, m in zip(pod_step_cost, pod_mem_samples))
    # M^edge=1, M^node=0: memory is measured directly in samples.
    cluster = Cluster(machines=machines, m_node=0.0, m_edge=1.0)
    return capacities(cluster, num_vertices=0, num_edges=global_batch)
