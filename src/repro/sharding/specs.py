"""Name-based sharding rules: params, optimizer state, inputs, caches.

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``
single-pod.  Tensor parallelism lives on ``model``; batch DP on
``("pod", "data")``; ZeRO-sharded optimizer moments additionally use
``data``; FSDP (param sharding over ``data``) is opt-in per arch.

Every rule is divisibility-guarded: if the preferred dim doesn't divide the
mesh axis (e.g. 40 heads on model=16), the next-preference dim is tried,
ending at replication — so every (arch × mesh) cell lowers, and the perf
pass upgrades the hot archs explicitly.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def data_axes(mesh: Mesh):
    """The data-parallel axes present in this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def logical_batch_spec(mesh: Mesh, batch: int) -> P:
    """Shard batch over as many DP axes as divide it (long-context B=1
    falls back to replication)."""
    axes = []
    remaining = batch
    for a in data_axes(mesh):
        if remaining % mesh.shape[a] == 0:
            axes.append(a)
            remaining //= mesh.shape[a]
    return P(tuple(axes) if axes else None)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# name -> ordered dim preferences for the 'model' axis, by array *suffix*
# shape: prefs index the UNSTACKED layout (the stacked n_super leading dim
# inside blocks is accounted for by the ``offset`` shift in _spec_for).
_RULES = {
    # heads first, then the contracting d_model; NEVER head_dim — rope
    # slices it, and hd-sharding triggered a per-layer permute storm
    # (§Perf iteration log).
    "wq": (1, 0), "wk": (1, 0), "wv": (1, 0),   # (d_model, heads, head_dim)
    "wo": (0, 2),                               # (heads, head_dim, d_model)
    "w_gate": (-1, 0), "w_up": (-1, 0),         # (d_model, d_ff)
    "w_down": (0, -1),                          # (d_ff, d_model)
    "router": (-1,),
    "in_proj": (1, 0), "out_proj": (0, 1), "conv_w": (), "conv_b": (),
    "w_dkv": (1,), "w_uk": (1, 0), "w_uv": (1, 0), "w_kr": (),
    "w_dq": (1,), "w_uq": (1, 0),
    "embed": (0, 1), "unembed": (1, 0),
}
_MOE_RULES = {  # TP-within-expert: shard f, tokens never cross devices.
    # Expert-parallel (E-first) was measured 2-3x worse — XLA cannot
    # localize the data-dependent dispatch scatter and all-gathers the
    # token buffers (§Perf iteration log).
    "w_gate": (-1, 1), "w_up": (-1, 1), "w_down": (1, -1), "router": (-1,),
}


def _spec_for(name: str, shape, mesh: Mesh, *, stacked: bool,
              moe: bool, fsdp: bool) -> P:
    ndim = len(shape)
    rules = _MOE_RULES if (moe and name in _MOE_RULES) else _RULES
    prefs = rules.get(name, ())
    spec: list[Any] = [None] * ndim
    offset = 1 if stacked else 0
    m = mesh.shape.get("model", 1)
    chosen = None
    for pref in prefs:
        # prefs are written for the *unstacked* layout; shift by offset
        d = (pref + (ndim - offset) if pref < 0 else pref) + offset
        if d < offset or d >= ndim:
            continue
        if shape[d] % m == 0 and m > 1:
            spec[d] = "model"
            chosen = d
            break
    if fsdp:
        # shard the largest still-unsharded dim over 'data' (param FSDP)
        dp = mesh.shape.get("data", 1)
        if dp > 1:
            cands = [(shape[d], d) for d in range(offset, ndim)
                     if spec[d] is None and shape[d] % dp == 0]
            if cands:
                spec[max(cands)[1]] = "data"
    return P(*spec)


def param_specs(cfg, params_shape, mesh: Mesh, *, fsdp: bool = False):
    """PartitionSpec pytree matching ``init_params``' structure.

    params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape).
    """
    def walk(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        name = names[-1]
        stacked = names[0] == "blocks"
        moe = "ffn" in names and "router" in [n for n in names] or \
              ("ffn" in names and len(leaf.shape) - (1 if stacked else 0) == 3
               and name in ("w_gate", "w_up", "w_down"))
        if name in ("ln1", "ln2", "final_norm", "q_norm", "k_norm",
                    "kv_norm", "out_norm", "a_log", "dt_bias", "d_skip",
                    "conv_b", "conv_w"):
            return P()
        return _spec_for(name, leaf.shape, mesh, stacked=stacked, moe=moe,
                         fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(walk, params_shape)


def opt_state_specs(param_spec_tree, params_shape, mesh: Mesh):
    """ZeRO: moments get the param spec + 'data' on the largest free dim."""
    dp = mesh.shape.get("data", 1)

    def widen(spec: P, leaf):
        if dp <= 1 or "data" in tuple(spec):   # FSDP params: already ZeRO'd
            return spec
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        cands = [(leaf.shape[d], d) for d in range(len(leaf.shape))
                 if parts[d] is None and leaf.shape[d] % dp == 0
                 and leaf.shape[d] > 1]
        if cands:
            parts[max(cands)[1]] = "data"
        return P(*parts)

    return jax.tree.map(widen, param_spec_tree, params_shape)


# ---------------------------------------------------------------------------
# inputs & caches
# ---------------------------------------------------------------------------

def input_specs_for(cfg, mesh: Mesh, batch: int, kind: str):
    """Specs for the train/serve step inputs (tokens/embeddings/labels)."""
    bspec = logical_batch_spec(mesh, batch)
    b = bspec[0] if len(bspec) else None
    if cfg.input_mode == "tokens":
        x = P(b, None)
    else:
        x = P(b, None, None)
    if kind == "train":
        return {"inputs": x, "labels": P(b, None)}
    if kind == "prefill":
        return {"inputs": x}
    return {"inputs": x, "cache_len": P(b)}


def cache_specs(cfg, mesh: Mesh, batch: int, max_len: int):
    """Cache pytree specs.

    Batch shards over DP axes when divisible; KV heads shard over 'model'
    when divisible, otherwise the cache *sequence* axis shards over 'model'
    (none of the assigned archs has kv_heads % 16 == 0, and a 32k×128
    cache is 17 GiB/device unsharded — seq-sharding is what makes decode
    fit v5e HBM)."""
    bspec = logical_batch_spec(mesh, batch)
    b = bspec[0] if len(bspec) else None
    m = mesh.shape.get("model", 1)
    kvh, hd = cfg.num_kv_heads, cfg.head_dim_
    kv_shard = "model" if (kvh % m == 0 and m > 1) else None
    seq = "model" if (kv_shard is None and max_len % m == 0 and m > 1) \
        else None
    out = {}
    for pos in range(cfg.pattern_period):
        kind = cfg.layer_kind(pos)
        if kind == "attn":
            if cfg.attn_type == "mla":
                out[f"pos{pos}"] = {
                    "latent": P(None, b, seq, None),
                    "k_rope": P(None, b, seq, None),
                }
            else:
                out[f"pos{pos}"] = {
                    "k": P(None, b, seq, kv_shard, None),
                    "v": P(None, b, seq, kv_shard, None),
                }
        else:
            nh = cfg.ssm_heads
            h_shard = "model" if (nh % m == 0 and m > 1) else None
            out[f"pos{pos}"] = {
                "conv": P(None, b, None, None),
                "ssm": P(None, b, h_shard, None, None),
            }
    return out
