"""WindGP-based placement of MoE experts on heterogeneous pods.

The paper's §4 vertex-centric extension, applied to expert parallelism:

* vertices  = experts, weighted by expected token load (router statistics);
* edges     = expert co-activation (tokens routed to both experts under
  top-k must exchange activations if the experts sit on different pods);
* machines  = pods with (HBM, per-token compute cost, inter-pod link cost)
  quadruples.

WindGP edge-partitions the co-activation graph (3-phase: capacity →
best-first → SLS), then each expert lands on the machine holding the
largest share of its incident co-activation edges (the paper's
max-partial-degree rule), respecting memory.  Minimizing TC here minimizes
the BSP-style makespan of one MoE layer: max_pod(expert compute + cross-pod
token exchange) — the same long-tail the paper targets.
"""
from __future__ import annotations

import numpy as np

from ..core.graph import from_edge_list
from ..core.machines import Cluster, Machine
from ..core.windgp import windgp


def coactivation_graph(routing_counts: np.ndarray):
    """routing_counts: (tokens, k) expert ids per token → weighted edges.

    Returns (edges (M,2), weights (M,), loads (E,)): co-routed expert pairs
    and per-expert token loads.
    """
    toks, k = routing_counts.shape
    pairs = {}
    loads = np.bincount(routing_counts.reshape(-1),
                        minlength=int(routing_counts.max()) + 1)
    for t in range(toks):
        es = np.unique(routing_counts[t])
        for i in range(len(es)):
            for j in range(i + 1, len(es)):
                key = (int(es[i]), int(es[j]))
                pairs[key] = pairs.get(key, 0) + 1
    if not pairs:
        return np.zeros((0, 2), np.int64), np.zeros(0), loads
    edges = np.array(list(pairs), dtype=np.int64)
    weights = np.array(list(pairs.values()), dtype=np.float64)
    return edges, weights, loads


def place_experts(num_experts: int, routing_sample: np.ndarray,
                  pod_compute_cost, pod_memory_experts, pod_link_cost,
                  seed: int = 0) -> np.ndarray:
    """Returns (E,) pod index per expert.

    pod_compute_cost[i]: relative per-token FFN cost on pod i.
    pod_memory_experts[i]: how many experts fit in pod i's HBM.
    pod_link_cost[i]: relative cost of a token crossing into/out of pod i.
    """
    edges, weights, loads = coactivation_graph(routing_sample)
    p = len(pod_compute_cost)
    if len(edges) == 0:   # degenerate: round-robin by load
        order = np.argsort(-loads)
        out = np.zeros(num_experts, dtype=np.int64)
        out[order] = np.arange(len(order)) % p
        return out
    g = from_edge_list(edges, num_vertices=num_experts)
    # Edge-partition memory: proportional to pod HBM, scaled so the graph
    # always fits (the hard expert-count constraint is enforced in the
    # vertex-assignment pass below).
    mem_w = np.asarray(pod_memory_experts, dtype=np.float64)
    total_units = 2.5 * (0.5 * g.num_edges + g.num_vertices)
    mem_units = total_units * mem_w / mem_w.sum()
    machines = tuple(
        Machine(memory=float(m), c_node=float(c), c_edge=float(c),
                c_com=float(l))
        for c, m, l in zip(pod_compute_cost, mem_units, pod_link_cost))
    cluster = Cluster(machines=machines, m_node=1.0, m_edge=0.5)
    res = windgp(g, cluster, t0=10, seed=seed)
    # §4 vertex-centric rule, made load/speed-aware (the paper's
    # BalancedGreedyRepair applied at vertex level): experts are placed in
    # descending token-load order on the machine minimizing the resulting
    # weighted makespan, with the WindGP edge partition's partial degree as
    # the affinity tie-break (keeps co-activated experts co-located).
    place = np.full(num_experts, -1, dtype=np.int64)
    deg_by_machine = np.zeros((p, num_experts), dtype=np.int64)
    for eid, m in enumerate(res.assign):
        u, v = g.edges[eid]
        deg_by_machine[m, u] += 1
        deg_by_machine[m, v] += 1
    room = np.asarray(pod_memory_experts, dtype=np.float64)
    compute = np.asarray(pod_compute_cost, dtype=np.float64)
    order = np.argsort(-loads[:num_experts])          # heavy experts first
    used_tokens = np.zeros(p)
    used_slots = np.zeros(p)
    max_aff = deg_by_machine.sum(axis=0).max() or 1
    for e in order:
        load_e = float(loads[e]) if e < len(loads) else 0.0
        t_new = (used_tokens + load_e) * compute
        aff = deg_by_machine[:, e] / max_aff
        score = t_new * (1.0 - 0.25 * aff)            # affinity discount
        feasible = used_slots + 1 <= room
        cand = np.where(feasible, score, np.inf)
        m = int(np.argmin(cand)) if feasible.any() else \
            int(np.argmin(used_slots / room))
        place[e] = m
        used_tokens[m] += load_e
        used_slots[m] += 1
    return place


def placement_cost(place: np.ndarray, routing_sample: np.ndarray,
                   pod_compute_cost, pod_link_cost) -> float:
    """BSP makespan of one MoE layer under a placement (lower = better)."""
    p = len(pod_compute_cost)
    loads = np.zeros(p)
    comm = np.zeros(p)
    for t in range(routing_sample.shape[0]):
        pods = place[routing_sample[t]]
        for m in pods:
            loads[m] += pod_compute_cost[m]
        uniq = np.unique(pods)
        if len(uniq) > 1:
            for m in uniq:
                comm[m] += pod_link_cost[m] * (len(uniq) - 1)
    return float((loads + comm).max())
