from .specs import (param_specs, input_specs_for, cache_specs, opt_state_specs,
                    data_axes, logical_batch_spec)
from . import windgp_placement

__all__ = ["param_specs", "input_specs_for", "cache_specs", "opt_state_specs",
           "data_axes", "logical_batch_spec", "windgp_placement"]
