"""Compact on-disk product of a streaming partition: the BSP hand-off.

``stream_partition`` finalizes placements through a sink callback; before
this module the sink's output dead-ended in ad-hoc per-machine text files —
no runtime could consume them without re-reading (and re-deduplicating) the
raw edge list.  :class:`StreamAssignment` is the DistDGL-style durable
artifact in between: per-machine binary edge shards plus the vertex
membership/degree state, built *incrementally* as the stream runs, that
``PartitionRuntime.from_stream`` packs into the fixed-shape BSP arrays one
machine at a time — the raw list is never read again and the full edge set
never materializes in one array.

Layout under ``dir/``::

    shard<i>.edges    raw int64 (k_i, 2) endpoint pairs, appended in
                      admission order (placement order, not arrival order)
    state.npz         packed (p, V) membership bits, (V,) global degrees,
                      (p,) per-machine edge counts
    meta.json         counts, replication factor, method provenance —
                      written atomically (tmp + rename), last, and only
                      after every shard verifies against its byte length

The write protocol makes partial products detectable: a directory with no
``meta.json`` is unfinished by construction.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib

import numpy as np

#: bytes per on-disk edge row (two little-endian int64 endpoints)
_ROW_BYTES = 16

_FORMAT_VERSION = 1


@dataclasses.dataclass
class StreamAssignment:
    """Per-machine edge shards + membership, streamed to disk incrementally.

    Writer life-cycle: construct with ``p``/``num_vertices``, hand
    :meth:`sink` to ``stream_partition``, then :meth:`finalize` with the
    end-of-stream ``StreamMembership``.  Reader life-cycle:
    :meth:`StreamAssignment.open` on a finalized directory, then
    :meth:`machine_edges`/:meth:`membership` (or hand the whole object to
    ``PartitionRuntime.from_stream``).
    """

    dir: pathlib.Path
    p: int
    num_vertices: int
    edges_per: np.ndarray            # (p,) int64 edges appended per shard
    degree: np.ndarray               # (V,) int64 degree in the deduped graph
    meta: dict | None = None         # populated on finalize/open

    def __init__(self, out_dir, p: int, num_vertices: int):
        self.dir = pathlib.Path(out_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.p = int(p)
        self.num_vertices = int(num_vertices)
        self.edges_per = np.zeros(self.p, dtype=np.int64)
        self.degree = np.zeros(self.num_vertices, dtype=np.int64)
        self.meta = None
        self._member: np.ndarray | None = None
        self._files = [open(self._shard_path(i), "wb")
                       for i in range(self.p)]

    def _shard_path(self, i: int) -> pathlib.Path:
        return self.dir / f"shard{i}.edges"

    # -- incremental build (the stream sink) --------------------------------
    def sink(self, edges: np.ndarray, ms: np.ndarray) -> None:
        """Append one finalized placement wave: ``edges[j] -> ms[j]``.

        Matches ``stream_partition``'s sink contract; each edge arrives
        exactly once, so the running degree counts equal the deduplicated
        graph's degrees at stream end.
        """
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        ms = np.asarray(ms, dtype=np.int64)
        np.add.at(self.degree, edges.ravel(), 1)
        order = np.argsort(ms, kind="stable")
        rows, srt = edges[order], ms[order]
        bounds = np.searchsorted(srt, np.arange(self.p + 1))
        for i in range(self.p):
            lo, hi = bounds[i], bounds[i + 1]
            if hi > lo:
                rows[lo:hi].tofile(self._files[i])
        self.edges_per += np.bincount(ms, minlength=self.p)

    def close(self) -> None:
        """Close the shard handles without publishing (abort path).

        Idempotent; safe after :meth:`finalize` (which closes them
        itself).  The directory is left as an unfinished product — no
        ``meta.json``, so readers reject it — instead of leaking ``p``
        open file descriptors when the stream raises mid-run.
        """
        for f in self._files:
            if not f.closed:
                f.close()

    def __enter__(self) -> "StreamAssignment":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def finalize(self, membership, extra_meta: dict | None = None) -> dict:
        """Flush + verify every shard, persist state, then write meta.

        ``membership`` is the end-of-stream ``StreamMembership`` (or a
        raw ``(p, V)`` bool matrix).  Verification is byte-accurate: each
        shard's on-disk length must equal ``edges_per[i]`` rows, and the
        membership totals must agree with what the sink saw — only then is
        ``meta.json`` written (tmp + ``os.replace``), so a crash mid-write
        can never leave a directory that parses as complete.
        """
        for f in self._files:
            if not f.closed:
                f.flush()
                os.fsync(f.fileno())
                f.close()
        for i in range(self.p):
            want = int(self.edges_per[i]) * _ROW_BYTES
            got = self._shard_path(i).stat().st_size
            if got != want:
                raise IOError(
                    f"shard {i} short-flushed: {got} bytes on disk, "
                    f"expected {want} ({int(self.edges_per[i])} edges)")
        member = (membership if isinstance(membership, np.ndarray)
                  else membership.cnt > 0)
        member = np.asarray(member, dtype=bool)
        if member.shape != (self.p, self.num_vertices):
            raise ValueError(f"membership shape {member.shape} != "
                             f"{(self.p, self.num_vertices)}")
        sunk = np.flatnonzero(self.degree > 0)
        held = np.flatnonzero(member.any(axis=0))
        if not np.array_equal(sunk, held):
            raise ValueError("membership disagrees with the sunk edges: "
                             "a vertex is held iff an incident edge placed")
        self._member = member
        np.savez_compressed(
            self.dir / "state.npz",
            member_bits=np.packbits(member, axis=1),
            degree=self.degree, edges_per=self.edges_per)
        replicas = member.sum(axis=0)
        covered = replicas > 0
        rf = float(replicas[covered].sum() / max(1, covered.sum()))
        meta = {
            "format_version": _FORMAT_VERSION,
            "p": self.p, "num_vertices": self.num_vertices,
            "num_edges": int(self.edges_per.sum()),
            "edges_per_machine": self.edges_per.tolist(),
            "verts_per_machine": member.sum(axis=1).astype(int).tolist(),
            "replication_factor": round(rf, 6),
            "shards": [self._shard_path(i).name for i in range(self.p)],
        }
        meta.update(extra_meta or {})
        write_json_atomic(self.dir / "meta.json", meta)
        self.meta = meta
        return meta

    # -- reader surface ------------------------------------------------------
    @classmethod
    def open(cls, out_dir) -> "StreamAssignment":
        """Open a finalized assignment directory (meta.json required)."""
        d = pathlib.Path(out_dir)
        meta_path = d / "meta.json"
        if not meta_path.exists():
            raise FileNotFoundError(
                f"{d} has no meta.json — unfinished StreamAssignment "
                f"(finalize() never completed)")
        meta = json.loads(meta_path.read_text())
        if meta["format_version"] != _FORMAT_VERSION:
            raise ValueError(f"unsupported StreamAssignment format "
                             f"{meta['format_version']}")
        sa = cls.__new__(cls)
        sa.dir = d
        sa.p = int(meta["p"])
        sa.num_vertices = int(meta["num_vertices"])
        sa.meta = meta
        sa._files = []
        with np.load(d / "state.npz") as z:
            sa.degree = z["degree"]
            sa.edges_per = z["edges_per"]
            bits = z["member_bits"]
        sa._member = np.unpackbits(
            bits, axis=1, count=sa.num_vertices).astype(bool)
        np.testing.assert_array_equal(
            sa.edges_per, np.asarray(meta["edges_per_machine"]))
        return sa

    def membership(self) -> np.ndarray:
        """(p, V) bool — vertex v held by machine i (v ∈ V_i)."""
        if self._member is None:
            raise RuntimeError("membership unavailable before finalize()")
        return self._member

    def machine_edges(self, i: int) -> np.ndarray:
        """(k_i, 2) int64 endpoints of machine i's shard (one machine's
        worth of memory, read on demand)."""
        return np.fromfile(self._shard_path(i),
                           dtype=np.int64).reshape(-1, 2)

    def replication_factor(self) -> float:
        member = self.membership()
        r = member.sum(axis=0)
        covered = r > 0
        return float(r[covered].sum() / max(1, covered.sum()))


def write_json_atomic(path, payload: dict) -> None:
    """Write JSON via tmp + ``os.replace`` so readers never see a torn file."""
    path = pathlib.Path(path)
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(json.dumps(payload, indent=2))
    os.replace(tmp, path)
