"""Compact on-disk product of a streaming partition: the BSP hand-off.

``stream_partition`` finalizes placements through a sink callback; before
this module the sink's output dead-ended in ad-hoc per-machine text files —
no runtime could consume them without re-reading (and re-deduplicating) the
raw edge list.  :class:`StreamAssignment` is the DistDGL-style durable
artifact in between: per-machine binary edge shards plus the vertex
membership/degree state, built *incrementally* as the stream runs, that
``PartitionRuntime.from_stream`` packs into the fixed-shape BSP arrays one
machine at a time — the raw list is never read again and the full edge set
never materializes in one array.

Layout under ``dir/``::

    shard<i>.edges    raw int64 (k_i, 2) endpoint pairs, appended in
                      admission order (placement order, not arrival order)
    state.npz         packed (p, V) membership bits, (V,) global degrees,
                      (p,) per-machine edge counts
    meta.json         counts, replication factor, method provenance —
                      written atomically (tmp + rename), last, and only
                      after every shard verifies against its byte length

The write protocol makes partial products detectable: a directory with no
``meta.json`` is unfinished by construction.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib

import numpy as np

from ..core.graph import edge_keys
from ..core.partition_state import cumcount

#: bytes per on-disk edge row (two little-endian int64 endpoints)
_ROW_BYTES = 16

_FORMAT_VERSION = 1

#: compact a shard once tombstones cancel this fraction of its rows
_COMPACT_FRAC = 0.5

#: the keys ``_publish`` owns; anything else in ``meta`` is caller
#: provenance (method, dedup, ...) that maintenance passes carry over
_META_KEYS = frozenset({
    "format_version", "p", "num_vertices", "num_edges",
    "edges_per_machine", "verts_per_machine", "replication_factor",
    "shards", "shard_rows", "tomb_rows"})


def _drop_tombstoned(rows: np.ndarray, tomb: np.ndarray) -> np.ndarray:
    """Drop, for each tombstone (u, v), the earliest matching row.

    Tombstones always refer to rows appended *before* them (a delta can
    only remove edges that were live at its snapshot), so cancelling the
    first ``count`` occurrences of each pair in row order is exact: a
    pair re-added after its removal sits later in the file and survives.
    """
    rkey = edge_keys(rows[:, 0], rows[:, 1])
    tk, tcount = np.unique(edge_keys(tomb[:, 0], tomb[:, 1]),
                           return_counts=True)
    occ = cumcount(rkey)
    pos = np.searchsorted(tk, rkey)
    hit = pos < len(tk)
    hit[hit] = tk[pos[hit]] == rkey[hit]
    drop = np.zeros(len(rkey), dtype=bool)
    drop[hit] = occ[hit] < tcount[pos[hit]]
    return rows[~drop]


@dataclasses.dataclass
class StreamAssignment:
    """Per-machine edge shards + membership, streamed to disk incrementally.

    Writer life-cycle: construct with ``p``/``num_vertices``, hand
    :meth:`sink` to ``stream_partition``, then :meth:`finalize` with the
    end-of-stream ``StreamMembership``.  Reader life-cycle:
    :meth:`StreamAssignment.open` on a finalized directory, then
    :meth:`machine_edges`/:meth:`membership` (or hand the whole object to
    ``PartitionRuntime.from_stream``).
    """

    dir: pathlib.Path
    p: int
    num_vertices: int
    edges_per: np.ndarray            # (p,) int64 edges appended per shard
    degree: np.ndarray               # (V,) int64 degree in the deduped graph
    meta: dict | None = None         # populated on finalize/open

    def __init__(self, out_dir, p: int, num_vertices: int):
        self.dir = pathlib.Path(out_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.p = int(p)
        self.num_vertices = int(num_vertices)
        self.edges_per = np.zeros(self.p, dtype=np.int64)
        self.degree = np.zeros(self.num_vertices, dtype=np.int64)
        self.meta = None
        self._member: np.ndarray | None = None
        # gross on-disk accounting: live rows = shard_rows - tomb_rows
        self.shard_rows = np.zeros(self.p, dtype=np.int64)
        self.tomb_rows = np.zeros(self.p, dtype=np.int64)
        self._files = [open(self._shard_path(i), "wb")
                       for i in range(self.p)]

    def _shard_path(self, i: int) -> pathlib.Path:
        return self.dir / f"shard{i}.edges"

    def _tomb_path(self, i: int) -> pathlib.Path:
        return self.dir / f"shard{i}.tomb"

    # -- incremental build (the stream sink) --------------------------------
    def sink(self, edges: np.ndarray, ms: np.ndarray) -> None:
        """Append one finalized placement wave: ``edges[j] -> ms[j]``.

        Matches ``stream_partition``'s sink contract; each edge arrives
        exactly once, so the running degree counts equal the deduplicated
        graph's degrees at stream end.
        """
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        ms = np.asarray(ms, dtype=np.int64)
        np.add.at(self.degree, edges.ravel(), 1)
        order = np.argsort(ms, kind="stable")
        rows, srt = edges[order], ms[order]
        bounds = np.searchsorted(srt, np.arange(self.p + 1))
        for i in range(self.p):
            lo, hi = bounds[i], bounds[i + 1]
            if hi > lo:
                rows[lo:hi].tofile(self._files[i])
        self.edges_per += np.bincount(ms, minlength=self.p)

    def close(self) -> None:
        """Close the shard handles without publishing (abort path).

        Idempotent; safe after :meth:`finalize` (which closes them
        itself).  The directory is left as an unfinished product — no
        ``meta.json``, so readers reject it — instead of leaking ``p``
        open file descriptors when the stream raises mid-run.
        """
        for f in self._files:
            if not f.closed:
                f.close()

    def __enter__(self) -> "StreamAssignment":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def finalize(self, membership, extra_meta: dict | None = None) -> dict:
        """Flush + verify every shard, persist state, then write meta.

        ``membership`` is the end-of-stream ``StreamMembership`` (or a
        raw ``(p, V)`` bool matrix).  Verification is byte-accurate: each
        shard's on-disk length must equal ``edges_per[i]`` rows, and the
        membership totals must agree with what the sink saw — only then is
        ``meta.json`` written (tmp + ``os.replace``), so a crash mid-write
        can never leave a directory that parses as complete.
        """
        for f in self._files:
            if not f.closed:
                f.flush()
                os.fsync(f.fileno())
                f.close()
        for i in range(self.p):
            want = int(self.edges_per[i]) * _ROW_BYTES
            got = self._shard_path(i).stat().st_size
            if got != want:
                raise IOError(
                    f"shard {i} short-flushed: {got} bytes on disk, "
                    f"expected {want} ({int(self.edges_per[i])} edges)")
        member = (membership if isinstance(membership, np.ndarray)
                  else membership.cnt > 0)
        member = np.asarray(member, dtype=bool)
        if member.shape != (self.p, self.num_vertices):
            raise ValueError(f"membership shape {member.shape} != "
                             f"{(self.p, self.num_vertices)}")
        sunk = np.flatnonzero(self.degree > 0)
        held = np.flatnonzero(member.any(axis=0))
        if not np.array_equal(sunk, held):
            raise ValueError("membership disagrees with the sunk edges: "
                             "a vertex is held iff an incident edge placed")
        self._member = member
        self.shard_rows = self.edges_per.copy()
        self.tomb_rows = np.zeros(self.p, dtype=np.int64)
        return self._publish(extra_meta)

    def _publish(self, extra_meta: dict | None = None) -> dict:
        """Persist state.npz and write meta.json last — the commit point
        shared by :meth:`finalize` and :meth:`apply_delta`."""
        member = self._member
        np.savez_compressed(
            self.dir / "state.npz",
            member_bits=np.packbits(member, axis=1),
            degree=self.degree, edges_per=self.edges_per)
        replicas = member.sum(axis=0)
        covered = replicas > 0
        rf = float(replicas[covered].sum() / max(1, covered.sum()))
        meta = {
            "format_version": _FORMAT_VERSION,
            "p": self.p, "num_vertices": self.num_vertices,
            "num_edges": int(self.edges_per.sum()),
            "edges_per_machine": self.edges_per.tolist(),
            "verts_per_machine": member.sum(axis=1).astype(int).tolist(),
            "replication_factor": round(rf, 6),
            "shards": [self._shard_path(i).name for i in range(self.p)],
            "shard_rows": self.shard_rows.tolist(),
            "tomb_rows": self.tomb_rows.tolist(),
        }
        meta.update(extra_meta or {})
        write_json_atomic(self.dir / "meta.json", meta)
        self.meta = meta
        return meta

    # -- incremental update (the dynamic-epoch hand-off) ---------------------
    def apply_delta(self, delta, membership,
                    extra_meta: dict | None = None) -> dict:
        """Apply an epoch's :class:`~repro.core.dynamic.AssignmentDelta`
        in place: append + tombstone segments, re-verified at publish.

        Removed edges become tombstone rows in ``shard<i>.tomb`` (value-
        based: :func:`_drop_tombstoned` cancels the earliest matching
        row); added edges append to ``shard<i>.edges``.  A shard whose
        tombstones exceed ``_COMPACT_FRAC`` of its rows is rewritten
        compact.  ``membership`` is the post-epoch ``(p, V)`` matrix (or
        an object with ``.cnt``, e.g. the live ``PartitionState``).

        Crash-safe by the same meta-last protocol as :meth:`finalize`:
        ``meta.json`` is *removed* first, so a crash mid-delta leaves a
        detectably-unfinished directory, and only rewritten after every
        touched file is fsynced and every shard's byte length re-verifies
        against the updated row accounting.
        """
        if self.meta is None:
            raise RuntimeError("apply_delta needs a finalized (or opened) "
                               "StreamAssignment")
        member = (membership if isinstance(membership, np.ndarray)
                  else membership.cnt > 0)
        member = np.asarray(member, dtype=bool)
        nv = int(delta.num_vertices)
        if nv < self.num_vertices:
            raise ValueError(f"delta shrinks the vertex space "
                             f"({nv} < {self.num_vertices})")
        if member.shape != (self.p, nv):
            raise ValueError(f"membership shape {member.shape} != "
                             f"{(self.p, nv)}")
        # unpublish: from here until the meta rewrite the directory is an
        # unfinished product and every reader rejects it
        os.remove(self.dir / "meta.json")
        self.meta = None
        if nv > self.num_vertices:
            self.degree = np.concatenate(
                [self.degree,
                 np.zeros(nv - self.num_vertices, dtype=np.int64)])
            self.num_vertices = nv
        np.add.at(self.degree, delta.added.ravel(), 1)
        np.subtract.at(self.degree, delta.removed.ravel(), 1)
        if (self.degree < 0).any():
            raise ValueError("delta removes edges the shards never held")
        self._append_grouped(delta.removed, delta.removed_ms,
                             self._tomb_path, self.tomb_rows)
        self._append_grouped(delta.added, delta.added_ms,
                             self._shard_path, self.shard_rows)
        self.edges_per += (np.bincount(delta.added_ms, minlength=self.p)
                           - np.bincount(delta.removed_ms,
                                         minlength=self.p))
        if (self.edges_per < 0).any():
            raise ValueError("delta drives a shard's edge count negative")
        for i in np.flatnonzero(delta.machines_touched(self.p)):
            if self.tomb_rows[i] > _COMPACT_FRAC * max(1, self.shard_rows[i]):
                self._compact_shard(int(i))
        for i in range(self.p):
            for path, rows in ((self._shard_path(i), self.shard_rows[i]),
                               (self._tomb_path(i), self.tomb_rows[i])):
                got = path.stat().st_size if path.exists() else 0
                if got != int(rows) * _ROW_BYTES:
                    raise IOError(f"{path.name}: {got} bytes on disk, "
                                  f"expected {int(rows)} rows")
            if int(self.shard_rows[i]) - int(self.tomb_rows[i]) != \
                    int(self.edges_per[i]):
                raise IOError(f"shard {i}: row accounting out of balance")
        sunk = np.flatnonzero(self.degree > 0)
        held = np.flatnonzero(member.any(axis=0))
        if not np.array_equal(sunk, held):
            raise ValueError("membership disagrees with the updated "
                             "degrees: a vertex is held iff an incident "
                             "edge is placed")
        self._member = member
        return self._publish(extra_meta)

    def _append_grouped(self, edges: np.ndarray, ms: np.ndarray,
                        path_of, rows_acct: np.ndarray) -> None:
        """Append per-machine row groups to shard or tomb files, fsynced."""
        if not len(edges):
            return
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        ms = np.asarray(ms, dtype=np.int64)
        order = np.argsort(ms, kind="stable")
        rows, srt = edges[order], ms[order]
        bounds = np.searchsorted(srt, np.arange(self.p + 1))
        for i in range(self.p):
            lo, hi = bounds[i], bounds[i + 1]
            if hi > lo:
                with open(path_of(i), "ab") as f:
                    rows[lo:hi].tofile(f)
                    f.flush()
                    os.fsync(f.fileno())
                rows_acct[i] += hi - lo
        # the appends created/extended names in the directory: sync it so
        # the files survive a crash that the later meta rewrite survives
        dfd = os.open(self.dir, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)

    def _compact_shard(self, i: int) -> None:
        """Rewrite shard i with tombstones folded in (tmp + replace)."""
        rows = np.fromfile(self._shard_path(i),
                           dtype=np.int64).reshape(-1, 2)
        tomb_path = self._tomb_path(i)
        if tomb_path.exists() and tomb_path.stat().st_size:
            tomb = np.fromfile(tomb_path, dtype=np.int64).reshape(-1, 2)
            rows = _drop_tombstoned(rows, tomb)
        tmp = self._shard_path(i).with_suffix(".edges.tmp")
        with open(tmp, "wb") as f:
            rows.tofile(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._shard_path(i))
        if tomb_path.exists():
            os.remove(tomb_path)
        self.shard_rows[i] = len(rows)
        self.tomb_rows[i] = 0

    def compact(self, max_tomb_frac: float = 0.0) -> dict:
        """Fold tombstones into their shards as a standalone maintenance
        pass (``launch/partition.py --compact``).

        :meth:`apply_delta` compacts a shard only when its tombstones
        pass ``_COMPACT_FRAC`` *during* a delta, so a long-lived
        directory accumulates tombstone debt between epochs — every
        reader pays the :func:`_drop_tombstoned` scan on every
        ``machine_edges`` call.  This rewrites each shard whose tombstone
        fraction exceeds ``max_tomb_frac`` (default 0.0: fold everything)
        through the same tmp + ``os.replace`` path, under the same
        meta-last crash protocol: ``meta.json`` is removed first, every
        rewritten shard byte-verifies against the row accounting, and
        only then is the meta republished — provenance keys
        (method/dedup/...) carried over.  Live content is untouched:
        ``machine_edges`` returns identical rows before and after.  A
        no-op (nothing over the threshold) leaves the directory
        unpublished for zero time and returns the current meta.
        """
        if self.meta is None:
            raise RuntimeError("compact needs a finalized (or opened) "
                               "StreamAssignment")
        frac = float(max_tomb_frac)
        todo = [i for i in range(self.p)
                if self.tomb_rows[i] > 0
                and self.tomb_rows[i] > frac * max(1, self.shard_rows[i])]
        if not todo:
            return self.meta
        extra = {k: v for k, v in self.meta.items() if k not in _META_KEYS}
        os.remove(self.dir / "meta.json")
        self.meta = None
        for i in todo:
            self._compact_shard(i)
        for i in range(self.p):
            for path, rows in ((self._shard_path(i), self.shard_rows[i]),
                               (self._tomb_path(i), self.tomb_rows[i])):
                got = path.stat().st_size if path.exists() else 0
                if got != int(rows) * _ROW_BYTES:
                    raise IOError(f"{path.name}: {got} bytes on disk, "
                                  f"expected {int(rows)} rows")
            if int(self.shard_rows[i]) - int(self.tomb_rows[i]) != \
                    int(self.edges_per[i]):
                raise IOError(f"shard {i}: row accounting out of balance")
        return self._publish(extra)

    # -- reader surface ------------------------------------------------------
    @classmethod
    def open(cls, out_dir) -> "StreamAssignment":
        """Open a finalized assignment directory (meta.json required)."""
        d = pathlib.Path(out_dir)
        meta_path = d / "meta.json"
        if not meta_path.exists():
            raise FileNotFoundError(
                f"{d} has no meta.json — unfinished StreamAssignment "
                f"(finalize() never completed)")
        try:
            meta = json.loads(meta_path.read_text())
        except json.JSONDecodeError as exc:
            raise ValueError(
                f"{meta_path} is corrupt (truncated or torn write): "
                f"{exc}") from exc
        if meta["format_version"] != _FORMAT_VERSION:
            raise ValueError(f"unsupported StreamAssignment format "
                             f"{meta['format_version']}")
        sa = cls.__new__(cls)
        sa.dir = d
        sa.p = int(meta["p"])
        sa.num_vertices = int(meta["num_vertices"])
        sa.meta = meta
        sa._files = []
        sa.shard_rows = np.asarray(
            meta.get("shard_rows", meta["edges_per_machine"]),
            dtype=np.int64)
        sa.tomb_rows = np.asarray(
            meta.get("tomb_rows", [0] * sa.p), dtype=np.int64)
        with np.load(d / "state.npz") as z:
            sa.degree = z["degree"]
            sa.edges_per = z["edges_per"]
            bits = z["member_bits"]
        sa._member = np.unpackbits(
            bits, axis=1, count=sa.num_vertices).astype(bool)
        np.testing.assert_array_equal(
            sa.edges_per, np.asarray(meta["edges_per_machine"]))
        return sa

    def membership(self) -> np.ndarray:
        """(p, V) bool — vertex v held by machine i (v ∈ V_i)."""
        if self._member is None:
            raise RuntimeError("membership unavailable before finalize()")
        return self._member

    def machine_edges(self, i: int) -> np.ndarray:
        """(k_i, 2) int64 endpoints of machine i's *live* shard rows (one
        machine's worth of memory, read on demand).

        Unreadable before :meth:`finalize` — same contract as
        :meth:`membership`, so an unfinished directory is uniformly
        rejected rather than quietly serving a partially-written shard.
        After :meth:`apply_delta`, tombstoned rows are dropped here: each
        tombstone cancels the *earliest* surviving occurrence of its
        (u, v) pair, so a pair re-added after its removal (a later
        append) is untouched.
        """
        if self.meta is None:
            raise RuntimeError(
                "machine_edges unavailable before finalize() — this "
                "directory is an unfinished StreamAssignment")
        rows = np.fromfile(self._shard_path(i),
                           dtype=np.int64).reshape(-1, 2)
        tomb_path = self._tomb_path(i)
        if tomb_path.exists() and tomb_path.stat().st_size:
            tomb = np.fromfile(tomb_path, dtype=np.int64).reshape(-1, 2)
            rows = _drop_tombstoned(rows, tomb)
        if len(rows) != int(self.edges_per[i]):
            raise IOError(
                f"shard {i}: {len(rows)} live rows after tombstones, "
                f"meta says {int(self.edges_per[i])}")
        return rows

    def replication_factor(self) -> float:
        member = self.membership()
        r = member.sum(axis=0)
        covered = r > 0
        return float(r[covered].sum() / max(1, covered.sum()))


def write_json_atomic(path, payload: dict) -> None:
    """Write JSON via tmp + ``os.replace`` so readers never see a torn file.

    Both fsyncs matter for the durability half of the claim: the tmp file
    is synced before the rename (otherwise ``os.replace`` can publish a
    name whose *contents* are still unflushed — a crash then surfaces an
    empty or partial file under the final name), and the directory is
    synced after (otherwise the rename itself may not survive).
    """
    path = pathlib.Path(path)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "w") as f:
        f.write(json.dumps(payload, indent=2))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dfd = os.open(path.parent, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
