"""Single-machine numpy oracles for the BSP apps (test references)."""
from __future__ import annotations

import numpy as np

from ..core.graph import Graph


def pagerank(g: Graph, num_iters: int = 20, damping: float = 0.85):
    n = g.num_vertices
    deg = np.maximum(1, g.degree()).astype(np.float64)
    pr = np.full(n, 1.0 / n)
    u, v = g.edges[:, 0], g.edges[:, 1]
    for _ in range(num_iters):
        msg = pr / deg
        nxt = np.zeros(n)
        np.add.at(nxt, v, msg[u])
        np.add.at(nxt, u, msg[v])
        pr = (1 - damping) / n + damping * nxt
    return pr


def sssp(g: Graph, source: int = 0, weights: np.ndarray | None = None,
         num_iters: int = 30):
    n = g.num_vertices
    w = np.ones(g.num_edges) if weights is None else weights
    dist = np.full(n, np.inf)
    dist[source] = 0.0
    u, v = g.edges[:, 0], g.edges[:, 1]
    for _ in range(num_iters):
        cand = np.full(n, np.inf)
        np.minimum.at(cand, v, dist[u] + w)
        np.minimum.at(cand, u, dist[v] + w)
        new = np.minimum(dist, cand)
        if np.array_equal(new, dist, equal_nan=True):
            break
        dist = new
    return dist


def bfs(g: Graph, source: int = 0, num_iters: int = 30):
    return sssp(g, source, np.ones(g.num_edges), num_iters)


def triangle_count(g: Graph) -> int:
    count = 0
    for u, v in g.edges:
        count += len(np.intersect1d(g.neighbors(u), g.neighbors(v),
                                    assume_unique=True))
    return count // 3
