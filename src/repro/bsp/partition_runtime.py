"""Build fixed-shape per-machine arrays from an edge partition.

Every machine gets the same padded shapes (shard_map/vmap require it):

* ``local_vertex_gid``: (p, Vmax) global id of each local vertex (pad: -1)
* ``local_edges``:      (p, Emax, 2) endpoints in *local* indices (pad: 0)
* ``edge_valid``:       (p, Emax) bool
* ``edge_weight``:      (p, Emax) float32
* ``vertex_valid``:     (p, Vmax) bool
* ``global_degree``:    (p, Vmax) degree of the vertex in G (pad: 1)
* ``rep_slot``:         (p, Vmax) slot into the replica exchange table,
                        -1 if the vertex lives on a single machine.

The replica table has one slot per vertex present on ≥2 machines; the BSP
exchange is a psum/pmin over a (R+1,) buffer (last slot = scatter dump for
non-replicated lanes).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.graph import Graph


@dataclasses.dataclass(frozen=True)
class PartitionRuntime:
    p: int
    num_vertices: int
    num_replicas: int                  # R
    local_vertex_gid: np.ndarray       # (p, Vmax) int32
    vertex_valid: np.ndarray           # (p, Vmax) bool
    local_edges: np.ndarray            # (p, Emax, 2) int32 (local indices)
    edge_valid: np.ndarray             # (p, Emax) bool
    edge_weight: np.ndarray            # (p, Emax) float32
    global_degree: np.ndarray          # (p, Vmax) int32
    rep_slot: np.ndarray               # (p, Vmax) int32
    verts_per_machine: np.ndarray      # (p,)
    edges_per_machine: np.ndarray      # (p,)

    @property
    def vmax(self) -> int:
        return self.local_vertex_gid.shape[1]

    @property
    def emax(self) -> int:
        return self.local_edges.shape[1]

    @classmethod
    def build(cls, g: Graph, assign: np.ndarray, p: int,
              edge_weights: np.ndarray | None = None) -> "PartitionRuntime":
        assert (assign >= 0).all() and assign.max() < p
        deg = g.degree().astype(np.int32)
        if edge_weights is None:
            edge_weights = np.ones(g.num_edges, dtype=np.float32)

        # Vertex membership / replica sets from the shared incidence counts
        # (same accounting the partitioner's incremental layer maintains).
        from ..core.partition_state import edge_incidence_counts
        member = edge_incidence_counts(g, assign, p) > 0     # (p, V)

        locals_, edges_, weights_ = [], [], []
        lut = np.full(g.num_vertices, -1, dtype=np.int64)
        for i in range(p):
            eids = np.flatnonzero(assign == i)
            verts = np.flatnonzero(member[i])   # sorted endpoints of E_i
            lut[verts] = np.arange(len(verts))
            locals_.append(verts)
            edges_.append(lut[g.edges[eids]])
            weights_.append(edge_weights[eids])

        vmax = max(1, max(len(v) for v in locals_))
        emax = max(1, max(len(e) for e in edges_))
        member_count = member.sum(axis=0).astype(np.int32)
        rep_vertices = np.flatnonzero(member_count >= 2)
        rep_index = np.full(g.num_vertices, -1, dtype=np.int32)
        rep_index[rep_vertices] = np.arange(len(rep_vertices), dtype=np.int32)

        lv = np.full((p, vmax), -1, dtype=np.int32)
        vv = np.zeros((p, vmax), dtype=bool)
        le = np.zeros((p, emax, 2), dtype=np.int32)
        ev = np.zeros((p, emax), dtype=bool)
        ew = np.zeros((p, emax), dtype=np.float32)
        gd = np.ones((p, vmax), dtype=np.int32)
        rs = np.full((p, vmax), -1, dtype=np.int32)
        for i in range(p):
            nv, ne = len(locals_[i]), len(edges_[i])
            lv[i, :nv] = locals_[i]
            vv[i, :nv] = True
            gd[i, :nv] = deg[locals_[i]]
            rs[i, :nv] = rep_index[locals_[i]]
            if ne:
                le[i, :ne] = edges_[i]
                ev[i, :ne] = True
                ew[i, :ne] = weights_[i]
        return cls(
            p=p, num_vertices=g.num_vertices,
            num_replicas=len(rep_vertices),
            local_vertex_gid=lv, vertex_valid=vv, local_edges=le,
            edge_valid=ev, edge_weight=ew, global_degree=gd, rep_slot=rs,
            verts_per_machine=np.array([len(v) for v in locals_]),
            edges_per_machine=np.array([len(e) for e in edges_]))

    @classmethod
    def from_stream(cls, assignment,
                    edge_weights=None) -> "PartitionRuntime":
        """Pack the BSP runtime from an on-disk :class:`StreamAssignment`.

        The out-of-core counterpart of :meth:`build`: no ``Graph`` and no
        global edge array — vertex membership, global degrees, and the
        replica table come from the assignment's streamed state, and each
        machine's shard is read one at a time, so peak residency during
        packing is one machine's edge set plus the fixed-shape output.
        ``edge_weights`` may be a callable ``(edges_i, i) -> (k_i,)`` (the
        global edge-id order of :meth:`build` does not exist here).
        """
        from .stream_assignment import StreamAssignment
        if not isinstance(assignment, StreamAssignment):
            assignment = StreamAssignment.open(assignment)
        p, V = assignment.p, assignment.num_vertices
        member = assignment.membership()
        deg = assignment.degree.astype(np.int32)

        member_count = member.sum(axis=0).astype(np.int32)
        rep_vertices = np.flatnonzero(member_count >= 2)
        rep_index = np.full(V, -1, dtype=np.int32)
        rep_index[rep_vertices] = np.arange(len(rep_vertices), dtype=np.int32)

        verts_per = member.sum(axis=1).astype(np.int64)
        edges_per = assignment.edges_per.astype(np.int64)
        vmax = max(1, int(verts_per.max(initial=0)))
        emax = max(1, int(edges_per.max(initial=0)))

        lv = np.full((p, vmax), -1, dtype=np.int32)
        vv = np.zeros((p, vmax), dtype=bool)
        le = np.zeros((p, emax, 2), dtype=np.int32)
        ev = np.zeros((p, emax), dtype=bool)
        ew = np.zeros((p, emax), dtype=np.float32)
        gd = np.ones((p, vmax), dtype=np.int32)
        rs = np.full((p, vmax), -1, dtype=np.int32)
        lut = np.full(V, -1, dtype=np.int64)
        for i in range(p):
            verts = np.flatnonzero(member[i])
            lut[verts] = np.arange(len(verts))
            edges_i = assignment.machine_edges(i)     # one shard at a time
            nv, ne = len(verts), len(edges_i)
            assert ne == edges_per[i], (i, ne, edges_per[i])
            lv[i, :nv] = verts
            vv[i, :nv] = True
            gd[i, :nv] = deg[verts]
            rs[i, :nv] = rep_index[verts]
            if ne:
                le[i, :ne] = lut[edges_i]
                ev[i, :ne] = True
                ew[i, :ne] = (1.0 if edge_weights is None
                              else edge_weights(edges_i, i))
        return cls(
            p=p, num_vertices=V, num_replicas=len(rep_vertices),
            local_vertex_gid=lv, vertex_valid=vv, local_edges=le,
            edge_valid=ev, edge_weight=ew, global_degree=gd, rep_slot=rs,
            verts_per_machine=verts_per, edges_per_machine=edges_per)

    @classmethod
    def from_partitioner(cls, g: Graph, cluster, method: str = "windgp",
                         edge_weights: np.ndarray | None = None,
                         **knobs) -> "PartitionRuntime":
        """Partition ``g`` with a registered method and pack the runtime.

        ``method`` resolves through the unified registry
        (``repro.core.partitioners``); ``knobs`` pass through to it after
        name validation, so e.g. ``block_size=...`` reaches the
        block-stream scorers.  One-stop shop for the examples/benchmarks:
        partition → fixed-shape per-machine arrays.
        """
        from ..core.partitioners import get
        assign = get(method)(g, cluster, **knobs)
        return cls.build(g, assign, cluster.p, edge_weights=edge_weights)

    def gather_global(self, local_values: np.ndarray,
                      fill: float = 0.0) -> np.ndarray:
        """Merge per-machine local vertex values into a (V,) global array.

        Replicated vertices must agree across machines (post-exchange)."""
        out = np.full(self.num_vertices, fill, dtype=np.asarray(local_values).dtype)
        for i in range(self.p):
            m = self.vertex_valid[i]
            out[self.local_vertex_gid[i, m]] = local_values[i, m]
        return out
