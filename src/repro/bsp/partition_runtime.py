"""Build fixed-shape per-machine arrays from an edge partition.

Every machine gets the same padded shapes (shard_map/vmap require it):

* ``local_vertex_gid``: (p, Vmax) global id of each local vertex (pad: -1)
* ``local_edges``:      (p, Emax, 2) endpoints in *local* indices (pad: 0)
* ``edge_valid``:       (p, Emax) bool
* ``edge_weight``:      (p, Emax) float32
* ``vertex_valid``:     (p, Vmax) bool
* ``global_degree``:    (p, Vmax) degree of the vertex in G (pad: 1)
* ``weighted_degree``:  (p, Vmax) sum of incident edge weights (pad: 1)
* ``rep_slot``:         (p, Vmax) slot into the replica exchange table,
                        -1 if the vertex lives on a single machine.

The replica table has one slot per vertex present on ≥2 machines; the BSP
exchange is a psum/pmin over a (R+1,) buffer (last slot = scatter dump for
non-replicated lanes).

:meth:`PartitionRuntime.local_bsr` additionally exposes each machine's
edge set as a blocked local adjacency (:class:`LocalBSR`) — the layout the
Pallas edge-kernel backend consumes (``repro.bsp.backends``).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from ..core.graph import Graph


@dataclasses.dataclass(frozen=True)
class LocalBSR:
    """Per-machine blocked local adjacency, stacked over machines.

    Each machine's ``local_edges`` become one Block-ELL matrix
    (``repro.kernels.bsr_spmv``) over its padded local vertex space, after
    a *degree-sorted local relabeling*: local vertices are reordered by
    descending local degree, so hub rows/columns cluster into the leading
    blocks and the ELL fill concentrates there instead of smearing one
    nonzero block per hub edge across the whole matrix.  All machines
    share (R, K, bm) — K is padded to the machine-wise max with absent
    blocks — so the stack vmaps / shard_maps like every other runtime
    array.

    ``gather`` maps each padded BSR position to the local vertex whose
    value it reads (pad positions read slot 0; their matrix entries are
    all-absent so the contribution is the ⊕ identity); ``rank`` maps each
    local vertex to its BSR position — together they carry values into
    and out of the blocked index space inside a superstep.
    """

    cols: np.ndarray        # (p, R, K) int32 block-column ids
    blocks: np.ndarray      # (p, R, K, bm, bm) in ``dtype`` (absent-padded)
    gather: np.ndarray      # (p, R*bm) int32: BSR position -> local index
    rank: np.ndarray        # (p, Vmax) int32: local index -> BSR position
    block_size: int
    semiring: str
    fill_stats: tuple       # per-machine dicts (see BsrMatrix.fill_stats)
    dtype: str = "float32"  # stored block dtype (message precision)

    @property
    def p(self) -> int:
        return self.cols.shape[0]

    @property
    def padded(self) -> int:
        return self.gather.shape[1]

    def aggregate_fill(self) -> dict:
        """ELL fill/padding over all machines (the smoke-report numbers)."""
        tot = lambda k: sum(s[k] for s in self.fill_stats)
        slots = sum(s["rows"] * s["ell_k"] for s in self.fill_stats)
        cells = sum(s["nnz_blocks"] * s["block_size"] ** 2
                    for s in self.fill_stats)
        return {
            "machines": len(self.fill_stats),
            "block_size": self.block_size,
            "ell_k_max": max(s["ell_k"] for s in self.fill_stats),
            "nnz": tot("nnz"),
            "nnz_blocks": tot("nnz_blocks"),
            "block_fill": tot("nnz_blocks") / max(1, slots),
            "entry_fill": tot("nnz") / max(1, cells),
        }

    @classmethod
    def build(cls, rt: "PartitionRuntime", *, block_size: int = 128,
              semiring: str = "plus_times", weights: str = "weight",
              dtype: str = "float32") -> "LocalBSR":
        """Blocked adjacency from ``rt.local_edges``, one machine at a time.

        ``weights`` picks the stored ⊗ operand per edge: ``"weight"``
        (``rt.edge_weight``), ``"unit"`` (1, presence), or ``"zero"``
        (0 — (min,+) label propagation).  ``dtype`` is the stored block
        precision (``"bfloat16"`` for the low-precision message path;
        blocks are built in float32 and cast once).
        """
        from ..kernels.bsr_spmv import bsr_from_edges, get_semiring
        p, vmax = rt.p, rt.vmax
        bm = int(block_size)
        mats, orders, ranks = [], [], []
        for i in range(p):
            ev = rt.edge_valid[i]
            e = rt.local_edges[i][ev]
            # local degree over the valid prefix; invalid slots sort last
            deg = np.zeros(vmax, dtype=np.int64)
            if len(e):
                np.add.at(deg, e[:, 0], 1)
                np.add.at(deg, e[:, 1], 1)
            order = np.argsort(-deg, kind="stable").astype(np.int32)
            rank = rank_of(order, vmax)
            if weights == "weight":
                w = rt.edge_weight[i][ev]
            elif weights == "unit":
                w = np.ones(len(e), dtype=np.float32)
            elif weights == "zero":
                w = np.zeros(len(e), dtype=np.float32)
            else:
                raise ValueError(f"weights must be 'weight'|'unit'|'zero', "
                                 f"got {weights!r}")
            mats.append(bsr_from_edges(rank[e] if len(e) else e, vmax,
                                       values=w, block_size=bm,
                                       semiring=semiring))
            orders.append(order)
            ranks.append(rank)
        absent = get_semiring(semiring).absent
        R = mats[0].cols.shape[0]
        K = max(m.cols.shape[1] for m in mats)
        cols = np.zeros((p, R, K), dtype=np.int32)
        blocks = np.full((p, R, K, bm, bm), absent, dtype=np.float32)
        for i, m in enumerate(mats):
            k = m.cols.shape[1]
            cols[i, :, :k] = m.cols
            blocks[i, :, :k] = m.blocks
        gather = np.zeros((p, R * bm), dtype=np.int32)
        for i in range(p):
            gather[i, :vmax] = orders[i]
        if dtype != "float32":
            blocks = blocks.astype(_np_dtype(dtype))
        return cls(cols=cols, blocks=blocks, gather=gather,
                   rank=np.stack(ranks),
                   block_size=bm, semiring=get_semiring(semiring).name,
                   fill_stats=tuple(m.fill_stats() for m in mats),
                   dtype=str(dtype))


def _np_dtype(name: str):
    """numpy dtype by name, reaching into ml_dtypes (a jax dependency)
    for the narrow float types numpy itself does not register."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def rank_of(order: np.ndarray, n: int) -> np.ndarray:
    """Inverse permutation: position of each of ``n`` items in ``order``."""
    rank = np.empty(n, dtype=np.int32)
    rank[order] = np.arange(n, dtype=np.int32)
    return rank


@dataclasses.dataclass(frozen=True)
class PartitionRuntime:
    p: int
    num_vertices: int
    num_replicas: int                  # R
    local_vertex_gid: np.ndarray       # (p, Vmax) int32
    vertex_valid: np.ndarray           # (p, Vmax) bool
    local_edges: np.ndarray            # (p, Emax, 2) int32 (local indices)
    edge_valid: np.ndarray             # (p, Emax) bool
    edge_weight: np.ndarray            # (p, Emax) float32
    global_degree: np.ndarray          # (p, Vmax) int32
    weighted_degree: np.ndarray        # (p, Vmax) float32 (pad: 1)
    rep_slot: np.ndarray               # (p, Vmax) int32
    verts_per_machine: np.ndarray      # (p,)
    edges_per_machine: np.ndarray      # (p,)

    @property
    def vmax(self) -> int:
        return self.local_vertex_gid.shape[1]

    @property
    def emax(self) -> int:
        return self.local_edges.shape[1]

    @functools.cached_property
    def _bsr_cache(self) -> dict:
        return {}

    def local_bsr(self, *, block_size: int = 128,
                  semiring: str = "plus_times", weights: str = "weight",
                  dtype: str = "float32") -> LocalBSR:
        """The blocked per-machine adjacency (:class:`LocalBSR`).

        Built once from ``local_edges`` per (block_size, semiring,
        weights, dtype) combination and cached on the runtime — the
        Pallas edge-kernel backend's layout, with padding/ELL-fill stats
        on the returned object.  ``dtype`` is the stored block precision
        (the ``message_dtype`` knob: a bfloat16 operand cache entry
        halves the blocks' footprint and feeds the low-precision message
        path without touching the float32 entry).
        """
        key = (int(block_size), str(semiring), str(weights), str(dtype))
        if key not in self._bsr_cache:
            self._bsr_cache[key] = LocalBSR.build(
                self, block_size=block_size, semiring=semiring,
                weights=weights, dtype=dtype)
        return self._bsr_cache[key]

    @classmethod
    def create(cls, source=None, *, assign=None, p=None, cluster=None,
               method=None, edge_weights=None, **knobs) -> "PartitionRuntime":
        """One keyword-routed constructor for every runtime source.

        Routes on what ``source`` is and which keywords accompany it:

        * ``create(source=graph, assign=assign, p=p)`` — pack a runtime
          from an in-memory edge assignment (the old :meth:`build`);
          ``cluster=`` may replace ``p=`` (``cluster.p`` is used).
        * ``create(source=graph, method="windgp", cluster=cl, **knobs)``
          — partition first through the registry, then pack (the old
          :meth:`from_partitioner`); ``knobs`` are validated by the
          registry entry.
        * ``create(source=assignment_or_path)`` — pack out-of-core from
          an on-disk :class:`StreamAssignment` (the old
          :meth:`from_stream`); ``edge_weights`` may be a callable
          ``(edges_i, i) -> (k_i,)``.

        ``edge_weights`` is accepted on every route.  Conflicting or
        missing keywords raise ``ValueError`` naming the valid routes.
        The legacy constructors remain as thin aliases of this facade,
        so both spellings build bit-identical runtimes.
        """
        from .stream_assignment import StreamAssignment
        if source is None:
            raise ValueError(
                "PartitionRuntime.create requires source=: a Graph (with "
                "assign=+p=/cluster= or method=+cluster=), or a "
                "StreamAssignment / its directory path")
        import os
        if isinstance(source, (StreamAssignment, str, os.PathLike)):
            bad = {"assign": assign, "p": p, "cluster": cluster,
                   "method": method}
            bad = sorted(k for k, v in bad.items() if v is not None)
            if bad or knobs:
                raise ValueError(
                    f"create(source=<stream assignment>) takes only "
                    f"edge_weights=; got {bad + sorted(knobs)}")
            return cls._pack_from_stream(source, edge_weights=edge_weights)
        if not (hasattr(source, "edges") and hasattr(source, "num_vertices")):
            raise ValueError(
                f"create: source must be a Graph or a StreamAssignment "
                f"(or its path), got {type(source).__name__}")
        if method is not None:
            if assign is not None or p is not None:
                raise ValueError(
                    "create(source=graph, method=...) partitions the graph "
                    "itself — drop assign=/p= (or drop method= to pack a "
                    "precomputed assignment)")
            if cluster is None:
                raise ValueError(
                    "create(source=graph, method=...) requires cluster= "
                    "(the heterogeneous machine spec the partitioner "
                    "targets)")
            from ..core.partitioners import get
            assign = get(method)(source, cluster, **knobs)
            return cls._pack_from_assignment(source, assign, cluster.p,
                                             edge_weights=edge_weights)
        if assign is None:
            raise ValueError(
                "create(source=graph) needs either assign= (+ p= or "
                "cluster=) for a precomputed assignment, or method= "
                "(+ cluster=) to partition via the registry")
        if knobs:
            raise ValueError(
                f"create(source=graph, assign=...) got partitioner knobs "
                f"{sorted(knobs)} — knobs only apply with method=")
        if p is None:
            if cluster is None:
                raise ValueError(
                    "create(source=graph, assign=...) requires p= or "
                    "cluster= for the machine count")
            p = cluster.p
        return cls._pack_from_assignment(source, assign, p,
                                         edge_weights=edge_weights)

    @classmethod
    def build(cls, g: Graph, assign: np.ndarray, p: int,
              edge_weights: np.ndarray | None = None) -> "PartitionRuntime":
        """Thin alias of :meth:`create` (``source=g, assign=, p=``)."""
        return cls.create(g, assign=assign, p=p, edge_weights=edge_weights)

    @classmethod
    def _pack_from_assignment(cls, g: Graph, assign: np.ndarray, p: int,
                              edge_weights: np.ndarray | None = None,
                              ) -> "PartitionRuntime":
        assert (assign >= 0).all() and assign.max() < p
        deg = g.degree().astype(np.int32)
        if edge_weights is None:
            edge_weights = np.ones(g.num_edges, dtype=np.float32)

        # Vertex membership / replica sets from the shared incidence counts
        # (same accounting the partitioner's incremental layer maintains).
        from ..core.partition_state import edge_incidence_counts
        member = edge_incidence_counts(g, assign, p) > 0     # (p, V)

        locals_, edges_, weights_ = [], [], []
        lut = np.full(g.num_vertices, -1, dtype=np.int64)
        for i in range(p):
            eids = np.flatnonzero(assign == i)
            verts = np.flatnonzero(member[i])   # sorted endpoints of E_i
            lut[verts] = np.arange(len(verts))
            locals_.append(verts)
            edges_.append(lut[g.edges[eids]])
            weights_.append(edge_weights[eids])

        vmax = max(1, max(len(v) for v in locals_))
        emax = max(1, max(len(e) for e in edges_))
        member_count = member.sum(axis=0).astype(np.int32)
        rep_vertices = np.flatnonzero(member_count >= 2)
        rep_index = np.full(g.num_vertices, -1, dtype=np.int32)
        rep_index[rep_vertices] = np.arange(len(rep_vertices), dtype=np.int32)

        # global weighted degree: sum of incident edge weights (the
        # (+,×) message normalizer; equals ``deg`` for unit weights)
        wdeg = np.zeros(g.num_vertices, dtype=np.float64)
        np.add.at(wdeg, g.edges[:, 0], edge_weights)
        np.add.at(wdeg, g.edges[:, 1], edge_weights)

        lv = np.full((p, vmax), -1, dtype=np.int32)
        vv = np.zeros((p, vmax), dtype=bool)
        le = np.zeros((p, emax, 2), dtype=np.int32)
        ev = np.zeros((p, emax), dtype=bool)
        ew = np.zeros((p, emax), dtype=np.float32)
        gd = np.ones((p, vmax), dtype=np.int32)
        wd = np.ones((p, vmax), dtype=np.float32)
        rs = np.full((p, vmax), -1, dtype=np.int32)
        for i in range(p):
            nv, ne = len(locals_[i]), len(edges_[i])
            lv[i, :nv] = locals_[i]
            vv[i, :nv] = True
            gd[i, :nv] = deg[locals_[i]]
            wd[i, :nv] = wdeg[locals_[i]]
            rs[i, :nv] = rep_index[locals_[i]]
            if ne:
                le[i, :ne] = edges_[i]
                ev[i, :ne] = True
                ew[i, :ne] = weights_[i]
        return cls(
            p=p, num_vertices=g.num_vertices,
            num_replicas=len(rep_vertices),
            local_vertex_gid=lv, vertex_valid=vv, local_edges=le,
            edge_valid=ev, edge_weight=ew, global_degree=gd,
            weighted_degree=wd, rep_slot=rs,
            verts_per_machine=np.array([len(v) for v in locals_]),
            edges_per_machine=np.array([len(e) for e in edges_]))

    @classmethod
    def from_stream(cls, assignment,
                    edge_weights=None) -> "PartitionRuntime":
        """Thin alias of :meth:`create` (``source=assignment``)."""
        return cls.create(assignment, edge_weights=edge_weights)

    @classmethod
    def _pack_from_stream(cls, assignment,
                          edge_weights=None) -> "PartitionRuntime":
        """Pack the BSP runtime from an on-disk :class:`StreamAssignment`.

        The out-of-core counterpart of :meth:`build`: no ``Graph`` and no
        global edge array — vertex membership, global degrees, and the
        replica table come from the assignment's streamed state, and each
        machine's shard is read one at a time, so peak residency during
        packing is one machine's edge set plus the fixed-shape output.
        ``edge_weights`` may be a callable ``(edges_i, i) -> (k_i,)`` (the
        global edge-id order of :meth:`build` does not exist here).
        """
        from .stream_assignment import StreamAssignment
        if not isinstance(assignment, StreamAssignment):
            assignment = StreamAssignment.open(assignment)
        p, V = assignment.p, assignment.num_vertices
        member = assignment.membership()
        deg = assignment.degree.astype(np.int32)

        member_count = member.sum(axis=0).astype(np.int32)
        rep_vertices = np.flatnonzero(member_count >= 2)
        rep_index = np.full(V, -1, dtype=np.int32)
        rep_index[rep_vertices] = np.arange(len(rep_vertices), dtype=np.int32)

        verts_per = member.sum(axis=1).astype(np.int64)
        edges_per = assignment.edges_per.astype(np.int64)
        vmax = max(1, int(verts_per.max(initial=0)))
        emax = max(1, int(edges_per.max(initial=0)))

        lv = np.full((p, vmax), -1, dtype=np.int32)
        vv = np.zeros((p, vmax), dtype=bool)
        le = np.zeros((p, emax, 2), dtype=np.int32)
        ev = np.zeros((p, emax), dtype=bool)
        ew = np.zeros((p, emax), dtype=np.float32)
        gd = np.ones((p, vmax), dtype=np.int32)
        wd = np.ones((p, vmax), dtype=np.float32)
        rs = np.full((p, vmax), -1, dtype=np.int32)
        lut = np.full(V, -1, dtype=np.int64)
        # every edge lives on exactly one machine, so the global weighted
        # degree accumulates across the one-shard-at-a-time loop
        wdeg = np.zeros(V, dtype=np.float64)
        for i in range(p):
            verts = np.flatnonzero(member[i])
            lut[verts] = np.arange(len(verts))
            edges_i = assignment.machine_edges(i)     # one shard at a time
            nv, ne = len(verts), len(edges_i)
            assert ne == edges_per[i], (i, ne, edges_per[i])
            lv[i, :nv] = verts
            vv[i, :nv] = True
            gd[i, :nv] = deg[verts]
            rs[i, :nv] = rep_index[verts]
            if ne:
                le[i, :ne] = lut[edges_i]
                ev[i, :ne] = True
                w_i = (np.ones(ne, dtype=np.float32)
                       if edge_weights is None
                       else np.asarray(edge_weights(edges_i, i),
                                       dtype=np.float32))
                ew[i, :ne] = w_i
                np.add.at(wdeg, edges_i[:, 0], w_i)
                np.add.at(wdeg, edges_i[:, 1], w_i)
        for i in range(p):
            wd[i, vv[i]] = wdeg[lv[i, vv[i]]]
        return cls(
            p=p, num_vertices=V, num_replicas=len(rep_vertices),
            local_vertex_gid=lv, vertex_valid=vv, local_edges=le,
            edge_valid=ev, edge_weight=ew, global_degree=gd,
            weighted_degree=wd, rep_slot=rs,
            verts_per_machine=verts_per, edges_per_machine=edges_per)

    def apply_delta(self, assignment, delta) -> "PartitionRuntime":
        """Repack after a dynamic epoch, reusing every untouched machine.

        ``assignment`` is the :class:`StreamAssignment` (or its path)
        *after* ``apply_delta(delta, ...)`` ran on it; ``delta`` is that
        same :class:`~repro.core.dynamic.AssignmentDelta`.  Machines whose
        edge set did not change this epoch keep their packed local-vertex
        and local-edge rows verbatim (membership derives from a machine's
        own edges, so an untouched machine's vertex set is untouched too);
        only changed machines re-read their shard and relabel.  The
        cross-machine quantities are always rebuilt — the replica table
        and global degrees shift whenever *any* machine changes, and they
        are cheap (no disk, no relabeling).

        Only valid for unit edge weights (the runtimes the dynamic layer
        produces); weighted runtimes must repack via :meth:`from_stream`
        with their weight callable.
        """
        from .stream_assignment import StreamAssignment
        if not isinstance(assignment, StreamAssignment):
            assignment = StreamAssignment.open(assignment)
        p, V = assignment.p, assignment.num_vertices
        if p != self.p:
            raise ValueError(f"delta runtime repack across machine counts "
                             f"({self.p} -> {p})")
        if not bool(np.all(self.edge_weight[self.edge_valid] == 1.0)):
            raise ValueError("apply_delta supports unit edge weights only "
                             "— repack weighted runtimes via from_stream")
        touched = delta.machines_touched(p)
        member = assignment.membership()
        deg = assignment.degree.astype(np.int32)
        member_count = member.sum(axis=0).astype(np.int32)
        rep_vertices = np.flatnonzero(member_count >= 2)
        rep_index = np.full(V, -1, dtype=np.int32)
        rep_index[rep_vertices] = np.arange(len(rep_vertices),
                                            dtype=np.int32)
        verts_per = member.sum(axis=1).astype(np.int64)
        edges_per = assignment.edges_per.astype(np.int64)
        vmax = max(1, int(verts_per.max(initial=0)))
        emax = max(1, int(edges_per.max(initial=0)))

        lv = np.full((p, vmax), -1, dtype=np.int32)
        vv = np.zeros((p, vmax), dtype=bool)
        le = np.zeros((p, emax, 2), dtype=np.int32)
        ev = np.zeros((p, emax), dtype=bool)
        ew = np.zeros((p, emax), dtype=np.float32)
        gd = np.ones((p, vmax), dtype=np.int32)
        wd = np.ones((p, vmax), dtype=np.float32)
        rs = np.full((p, vmax), -1, dtype=np.int32)
        lut = np.full(V, -1, dtype=np.int64)
        for i in range(p):
            nv, ne = int(verts_per[i]), int(edges_per[i])
            if not touched[i]:
                # unchanged machine: row content beyond (nv, ne) is pad
                lv[i, :nv] = self.local_vertex_gid[i, :nv]
                le[i, :ne] = self.local_edges[i, :ne]
            else:
                verts = np.flatnonzero(member[i])
                lut[verts] = np.arange(len(verts))
                edges_i = assignment.machine_edges(i)
                if len(verts) != nv or len(edges_i) != ne:
                    raise ValueError(f"machine {i}: shard/membership "
                                     f"disagree with the meta counts")
                lv[i, :nv] = verts
                if ne:
                    le[i, :ne] = lut[edges_i]
            vv[i, :nv] = True
            ev[i, :ne] = True
            ew[i, :ne] = 1.0
            gids = lv[i, :nv]
            gd[i, :nv] = deg[gids]
            wd[i, :nv] = deg[gids]       # unit weights: wdeg == degree
            rs[i, :nv] = rep_index[gids]
        return type(self)(
            p=p, num_vertices=V, num_replicas=len(rep_vertices),
            local_vertex_gid=lv, vertex_valid=vv, local_edges=le,
            edge_valid=ev, edge_weight=ew, global_degree=gd,
            weighted_degree=wd, rep_slot=rs,
            verts_per_machine=verts_per, edges_per_machine=edges_per)

    @classmethod
    def from_partitioner(cls, g: Graph, cluster, method: str = "windgp",
                         edge_weights: np.ndarray | None = None,
                         **knobs) -> "PartitionRuntime":
        """Thin alias of :meth:`create` (``source=g, method=, cluster=``)."""
        return cls.create(g, method=method, cluster=cluster,
                          edge_weights=edge_weights, **knobs)

    def gather_global(self, local_values: np.ndarray,
                      fill: float = 0.0) -> np.ndarray:
        """Merge per-machine local vertex values into a (V,) global array.

        Replicated vertices must agree across machines (post-exchange)."""
        out = np.full(self.num_vertices, fill, dtype=np.asarray(local_values).dtype)
        for i in range(self.p):
            m = self.vertex_valid[i]
            out[self.local_vertex_gid[i, m]] = local_values[i, m]
        return out

    def scatter_global(self, global_values: np.ndarray,
                       fill: float = 0.0) -> np.ndarray:
        """Spread a (V,) global array onto (p, Vmax) local vertex values —
        the inverse of :meth:`gather_global`, used to warm-start a BSP app
        from a previous runtime's converged result after
        :meth:`apply_delta` (replicas all receive the same value; pad
        slots get ``fill``)."""
        g = np.asarray(global_values)
        if len(g) < self.num_vertices:
            # runtime grew past the old result: new vertices get fill
            g = np.concatenate(
                [g, np.full(self.num_vertices - len(g), fill,
                            dtype=g.dtype)])
        out = np.full((self.p, self.vmax), fill, dtype=g.dtype)
        for i in range(self.p):
            m = self.vertex_valid[i]
            out[i, m] = g[self.local_vertex_gid[i, m]]
        return out
