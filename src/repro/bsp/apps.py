"""Distributed graph algorithms on the BSP engine.

The paper evaluates dense (PageRank, TriangleCount) and sparse (SSSP, BFS)
algorithms over its edge partitions; these are the same four, written as
per-machine superstep bodies + the replica exchange.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .engine import exchange, run_bsp
from .partition_runtime import PartitionRuntime


def _static_tree(rt: PartitionRuntime):
    return {
        "edges": jnp.asarray(rt.local_edges),
        "edge_valid": jnp.asarray(rt.edge_valid),
        "edge_weight": jnp.asarray(rt.edge_weight),
        "vertex_valid": jnp.asarray(rt.vertex_valid),
        "global_degree": jnp.asarray(rt.global_degree),
        "rep_slot": jnp.asarray(rt.rep_slot),
    }


# ---------------------------------------------------------------------------
# PageRank (dense: every vertex/edge active every superstep)
# ---------------------------------------------------------------------------

def pagerank(rt: PartitionRuntime, num_iters: int = 20,
             damping: float = 0.85, *, mesh=None):
    """Returns (V,) global PageRank after ``num_iters`` supersteps."""
    r_pad = max(1, rt.num_replicas)
    n = rt.num_vertices

    def superstep(state, sa):
        pr = state["pr"]
        msg = jnp.where(sa["vertex_valid"], pr / sa["global_degree"], 0.0)
        src, dst = sa["edges"][:, 0], sa["edges"][:, 1]
        w = sa["edge_valid"]
        partial = jnp.zeros_like(pr)
        partial = partial.at[dst].add(jnp.where(w, msg[src], 0.0))
        partial = partial.at[src].add(jnp.where(w, msg[dst], 0.0))
        total = exchange(partial, sa["rep_slot"], r_pad, "sum")
        new_pr = jnp.where(sa["vertex_valid"],
                           (1.0 - damping) / n + damping * total, 0.0)
        active = sa["vertex_valid"].sum()
        return {"pr": new_pr}, active

    state = {"pr": jnp.where(jnp.asarray(rt.vertex_valid),
                             1.0 / n, 0.0).astype(jnp.float32)}
    static = _static_tree(rt)
    out, actives = run_bsp(superstep, state, static, num_iters, mesh=mesh)
    # isolated vertices (no incident edge, hence in no partition) hold the
    # teleport mass only:
    return rt.gather_global(np.asarray(out["pr"]),
                            fill=(1.0 - damping) / n), actives


# ---------------------------------------------------------------------------
# SSSP / BFS (sparse: active set shrinks/grows per superstep)
# ---------------------------------------------------------------------------

def _relax_app(rt: PartitionRuntime, source: int, num_iters: int,
               weighted: bool, mesh=None):
    r_pad = max(1, rt.num_replicas)
    inf = jnp.float32(jnp.inf)

    def superstep(state, sa):
        dist = state["dist"]
        src, dst = sa["edges"][:, 0], sa["edges"][:, 1]
        w = jnp.where(sa["edge_valid"],
                      sa["edge_weight"] if weighted else 1.0, inf)
        cand = jnp.full_like(dist, inf)
        cand = cand.at[dst].min(dist[src] + w)
        cand = cand.at[src].min(dist[dst] + w)
        new_local = jnp.minimum(dist, cand)
        new_dist = exchange(new_local, sa["rep_slot"], r_pad, "min")
        new_dist = jnp.where(sa["vertex_valid"], new_dist, inf)
        active = (new_dist < dist).sum()      # vertices updated this step
        return {"dist": new_dist}, active

    dist0 = np.full((rt.p, rt.vmax), np.inf, dtype=np.float32)
    holders = np.nonzero(rt.local_vertex_gid == source)
    dist0[holders] = 0.0
    state = {"dist": jnp.asarray(dist0)}
    static = _static_tree(rt)
    out, actives = run_bsp(superstep, state, static, num_iters, mesh=mesh)
    return rt.gather_global(np.asarray(out["dist"]), fill=np.inf), actives


def sssp(rt: PartitionRuntime, source: int = 0, num_iters: int = 30,
         *, mesh=None):
    return _relax_app(rt, source, num_iters, weighted=True, mesh=mesh)


def bfs(rt: PartitionRuntime, source: int = 0, num_iters: int = 30,
        *, mesh=None):
    return _relax_app(rt, source, num_iters, weighted=False, mesh=mesh)


# ---------------------------------------------------------------------------
# Weakly-connected components (label propagation, pmin exchange)
# ---------------------------------------------------------------------------

def connected_components(rt: PartitionRuntime, num_iters: int = 30,
                         *, mesh=None):
    """Min-label propagation; returns (V,) component id per vertex."""
    r_pad = max(1, rt.num_replicas)
    inf = jnp.float32(jnp.inf)

    def superstep(state, sa):
        lab = state["lab"]
        src, dst = sa["edges"][:, 0], sa["edges"][:, 1]
        ok = sa["edge_valid"]
        cand = jnp.full_like(lab, inf)
        cand = cand.at[dst].min(jnp.where(ok, lab[src], inf))
        cand = cand.at[src].min(jnp.where(ok, lab[dst], inf))
        new = jnp.minimum(lab, cand)
        new = exchange(new, sa["rep_slot"], r_pad, "min")
        new = jnp.where(sa["vertex_valid"], new, inf)
        active = (new < lab).sum()
        return {"lab": new}, active

    lab0 = jnp.where(jnp.asarray(rt.vertex_valid),
                     jnp.asarray(rt.local_vertex_gid, dtype=jnp.float32),
                     jnp.inf)
    out, actives = run_bsp(superstep, {"lab": lab0}, _static_tree(rt),
                           num_iters, mesh=mesh)
    return rt.gather_global(np.asarray(out["lab"]), fill=np.inf), actives


# ---------------------------------------------------------------------------
# Triangle counting (dense): edge-parallel |N(u) ∩ N(v)| with the global
# CSR replicated to every machine (HTC-style shared adjacency); each machine
# scans only its own edges.  Exact — every triangle is seen by exactly 3
# edges, hence the /3 (each edge of the triangle counts it once).
# ---------------------------------------------------------------------------

def triangle_count(rt: PartitionRuntime, g, *, max_degree: int = 64,
                   chunk: int = 4096, mesh=None) -> int:
    """Exact triangle count over the partitioned edge sets.

    Adjacency intersections run against a degree-bounded global neighbor
    table (ELL layout, TPU/MXU-friendly equality contraction); edges whose
    endpoint exceeds the bound take a numpy sorted-intersection fallback
    (hubs are few; each edge is still counted exactly once).
    """
    deg = g.degree()
    cap = int(max_degree)
    V = g.num_vertices
    ell = np.full((V, cap), -1, dtype=np.int32)
    over = np.flatnonzero(deg > cap)
    for v in np.flatnonzero((deg > 0) & (deg <= cap)):
        nb = g.neighbors(v)
        ell[v, :len(nb)] = np.sort(nb)
    ell_j = jnp.asarray(ell)

    @jax.jit
    def count_chunk(edges_gid, valid):
        a = ell_j[edges_gid[:, 0]]            # (chunk, cap)
        b = ell_j[edges_gid[:, 1]]
        hit = (a[:, :, None] == b[:, None, :]) & (a[:, :, None] >= 0)
        return jnp.where(valid, hit.sum(axis=(1, 2)), 0).sum()

    count = 0
    for i in range(rt.p):
        m = rt.edge_valid[i]
        gids = rt.local_vertex_gid[i][rt.local_edges[i]]
        both_ok = m & ~np.isin(gids[:, 0], over) & ~np.isin(gids[:, 1], over)
        idx = np.flatnonzero(both_ok)
        for s in range(0, len(idx), chunk):
            sel = idx[s:s + chunk]
            pad = chunk - len(sel)
            eg = np.pad(gids[sel], ((0, pad), (0, 0)))
            va = np.pad(np.ones(len(sel), bool), (0, pad))
            count += int(count_chunk(jnp.asarray(eg), jnp.asarray(va)))
        # numpy fallback for hub endpoints
        for e in np.flatnonzero(m & ~both_ok):
            u, v = gids[e]
            count += len(np.intersect1d(g.neighbors(u), g.neighbors(v),
                                        assume_unique=True))
    return count // 3
