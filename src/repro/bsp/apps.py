"""Distributed graph algorithms on the BSP engine.

The paper evaluates dense (PageRank, TriangleCount) and sparse (SSSP, BFS)
algorithms over its edge partitions; these are the same four, written as
per-machine superstep bodies + the replica exchange.

Every superstep's edge work is one semiring SpMV against the machine's
local adjacency, expressed through a pluggable **edge-kernel backend**
(``bsp/backends.py``): PageRank combines under (+, ×) with edge weights,
SSSP under (min, +), BFS expands its frontier under (or, and), and
connected components propagates labels under (min, +) with zero weights.
``backend="scatter"`` (default) is the historical gather-scatter loop and
the float-exact oracle; ``"segment"`` is the sorted-CSR CPU fast path;
``"pallas"`` runs the blocked Block-ELL kernel (``kernels/bsr_spmv``) over
``rt.local_bsr()``.  Results agree across backends — bitwise for the
min/max semirings, to ~1e-7 for (+, ×) — and under both vmap and a real
``shard_map`` mesh (tests pin both).

The replica exchange is fused into the backend combine's epilogue
(``EdgeBackend.prepare_exchanged``): each superstep body makes a single
``combine`` call that already returns post-exchange values instead of
materializing a separate pre-exchange ``(Vmax,)`` partial.  For the
(min, +) apps this rewrites ``exchange(min(dist, cand))`` as
``min(dist, exchange(cand))`` — bitwise equal, because replicas of a
vertex always agree on ``dist`` (it is itself a post-exchange value)
and min is exact.

The monotone apps (SSSP/CC) carry a **changed-vertex mask** in their
state: only vertices whose value improved last superstep send messages;
everyone else feeds the semiring's no-message value (+inf under
(min, +)), whose ⊕ contribution is the identity.  This is exact — a
vertex's value was already folded into its neighbors the superstep
after it last changed, and (min, +) states only improve — and it is the
mask the ``scatter`` backend's ``frontier_cap`` compaction keys on to
make supersteps O(frontier) instead of O(E_local).  BFS's frontier
(``dist == step``) already is that mask.

Every app wrapper runs on either engine runner: the per-step oracle
(``run_bsp``, default) or the fused on-device loop (``fused=True`` /
``tol=`` → ``run_bsp_fused``), and backend opts such as
``message_dtype="bfloat16"`` flow through ``**backend_opts``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .backends import BACKENDS, MESSAGE_DTYPES, get_backend
from .engine import run_bsp, run_bsp_fused
from .partition_runtime import PartitionRuntime

#: apps whose state is monotone under the semiring: they already early-exit
#: on an empty changed-set, so PageRank's ``tol`` residual gate does not
#: apply to them (RunOptions.validate rejects the combination).
MONOTONE_APPS = ("bfs", "cc", "sssp")


@dataclasses.dataclass(frozen=True)
class RunOptions:
    """The engine/backend knobs every BSP app shares, validated once.

    The four app wrappers used to re-declare ``backend / fused / tol /
    chunk / message_dtype / frontier_cap`` individually; this dataclass
    is the single surface for them.  Pass ``options=RunOptions(...)`` to
    any app (or ``launch/partition.py``); the individual kwargs remain
    as a legacy spelling that assembles one internally — mixing both
    raises.

    * ``backend`` — edge-kernel backend (``bsp/backends.py``).
    * ``fused`` — run the whole iteration as one on-device dispatch.
    * ``tol`` — PageRank residual early-exit (implies ``fused``); the
      monotone apps (:data:`MONOTONE_APPS`) reject it.
    * ``chunk`` — fused-runner scan chunk (steps per convergence check).
    * ``message_dtype`` — message precision (see ``MESSAGE_DTYPES``).
    * ``frontier_cap`` — scatter-only frontier compaction width.
    """

    backend: str = "scatter"
    fused: bool = False
    tol: float | None = None
    chunk: int = 8
    message_dtype: str = "float32"
    frontier_cap: int | None = None

    def validate(self, app: str | None = None) -> "RunOptions":
        """Raise ``ValueError`` on bad knobs / combinations; returns self."""
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown edge-kernel backend "
                             f"{self.backend!r} "
                             f"(choices: {sorted(BACKENDS)})")
        if self.message_dtype not in MESSAGE_DTYPES:
            raise ValueError(f"unknown message_dtype "
                             f"{self.message_dtype!r} (choices: "
                             f"{list(MESSAGE_DTYPES)})")
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        if self.tol is not None and app in MONOTONE_APPS:
            raise ValueError(
                f"tol= is the PageRank residual gate; {app!r} is monotone "
                f"and already exits on an empty changed-set — valid "
                f"choices: tol=None here, or tol with app='pagerank' "
                f"(use fused=True for the one-dispatch runner)")
        if self.frontier_cap is not None and self.backend != "scatter":
            raise ValueError(
                f"frontier_cap is a 'scatter'-backend knob (frontier "
                f"compaction); backend {self.backend!r} does not take it "
                f"— valid choices: backend='scatter', or frontier_cap="
                f"None")
        return self

    def backend_opts(self) -> dict:
        """The knobs that flow to ``get_backend`` for this run."""
        opts = {"message_dtype": self.message_dtype}
        if self.frontier_cap is not None:
            opts["frontier_cap"] = self.frontier_cap
        return opts


def _options(options: RunOptions | None, app: str, backend, fused, tol,
             chunk, backend_opts: dict):
    """Resolve ``options=`` vs the legacy per-kwarg spelling.

    Returns ``(RunOptions, extra_backend_opts)`` — extras are
    backend-specific knobs outside the shared surface (e.g. the pallas
    ``block_size``/``interpret``), which pass through either way.
    """
    extra = dict(backend_opts)
    if options is not None:
        mixed = [name for name, val, default in
                 (("backend", backend, "scatter"), ("fused", fused, False),
                  ("tol", tol, None), ("chunk", chunk, 8))
                 if val != default]
        mixed += sorted(k for k in ("message_dtype", "frontier_cap")
                        if k in extra)
        if mixed:
            raise ValueError(
                f"got both options=RunOptions(...) and the individual "
                f"kwarg(s) {mixed} — pass the shared knobs one way or "
                f"the other")
    else:
        options = RunOptions(
            backend=backend, fused=fused, tol=tol, chunk=chunk,
            message_dtype=extra.pop("message_dtype", "float32"),
            frontier_cap=extra.pop("frontier_cap", None))
    options.validate(app)
    return options, extra


def _static_tree(rt: PartitionRuntime):
    return {
        "edges": jnp.asarray(rt.local_edges),
        "edge_valid": jnp.asarray(rt.edge_valid),
        "edge_weight": jnp.asarray(rt.edge_weight),
        "vertex_valid": jnp.asarray(rt.vertex_valid),
        "global_degree": jnp.asarray(rt.global_degree),
        "weighted_degree": jnp.asarray(rt.weighted_degree),
        "rep_slot": jnp.asarray(rt.rep_slot),
    }


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """One app instance, ready for ``run_bsp`` (or a dryrun compile).

    ``superstep(state, static) -> (state, active)`` over rank-reduced
    per-machine arrays; ``static`` already carries the backend's prepared
    arrays; ``check_rep`` is the backend's shard_map replication-check
    flag the engine must honor.
    """

    name: str
    superstep: Callable
    state: dict
    static: dict
    check_rep: bool
    finalize: Callable        # (rt, out_state) -> global result array


def _resolve(rt, backend, semiring: str, weights: str, exchange_mode: str,
             **opts):
    """Backend + static tree + exchange-fused combine for one app.

    The returned ``combine(sa, x)`` yields *post-exchange* neighborhood
    values (``EdgeBackend.prepare_exchanged``) — the superstep bodies
    below never call :func:`~.engine.exchange` themselves.
    """
    r_pad = max(1, rt.num_replicas)
    eb = get_backend(backend, **opts)
    extras, combine = eb.prepare_exchanged(rt, semiring, weights,
                                           exchange_mode, r_pad)
    return eb, {**_static_tree(rt), **extras}, combine


def _run(spec: "AppSpec", num_steps: int, *, mesh=None, fused=False,
         tol=None, chunk=8):
    """Dispatch an :class:`AppSpec` to the stepwise or fused runner."""
    if fused or tol is not None:
        return run_bsp_fused(spec.superstep, spec.state, spec.static,
                             num_steps, mesh=mesh, check_rep=spec.check_rep,
                             chunk=chunk, tol=tol)
    return run_bsp(spec.superstep, spec.state, spec.static, num_steps,
                   mesh=mesh, check_rep=spec.check_rep)


# ---------------------------------------------------------------------------
# PageRank (dense: every vertex/edge active every superstep; (+, ×))
# ---------------------------------------------------------------------------

def build_pagerank(rt: PartitionRuntime, damping: float = 0.85, *,
                   backend="scatter", init: np.ndarray | None = None,
                   **backend_opts) -> AppSpec:
    """``init`` warm-starts from a previous run's (V,) global PageRank —
    the dynamic-epoch hand-off after ``PartitionRuntime.apply_delta``.
    Power iteration converges to the stationary distribution from *any*
    non-degenerate start, so a stale vector is a valid (and, for small
    deltas, nearby) initial point; vertices new to this runtime fall back
    to the uniform mass.  CC/SSSP get no such hook: their states are
    monotone under the semiring, so stale labels are invalid the moment a
    deletion can lengthen a path."""
    n = rt.num_vertices
    eb, static, combine = _resolve(rt, backend, "plus_times", "weight",
                                   "sum", **backend_opts)

    def superstep(state, sa):
        pr = state["pr"]
        # weighted PageRank: messages normalize by the *weighted* degree
        # and edges scale by their weight (all-ones weights reduce to the
        # classic uniform split)
        msg = jnp.where(sa["vertex_valid"],
                        pr / sa["weighted_degree"], 0.0)
        total = combine(sa, msg)              # post-exchange ("sum")
        new_pr = jnp.where(sa["vertex_valid"],
                           (1.0 - damping) / n + damping * total, 0.0)
        active = sa["vertex_valid"].sum()
        return {"pr": new_pr}, active

    if init is None:
        pr0 = jnp.where(jnp.asarray(rt.vertex_valid),
                        1.0 / n, 0.0).astype(jnp.float32)
    else:
        pr0 = jnp.asarray(
            rt.scatter_global(np.asarray(init, dtype=np.float32),
                              fill=1.0 / n),
            dtype=jnp.float32)
        pr0 = jnp.where(jnp.asarray(rt.vertex_valid), pr0, 0.0)
    state = {"pr": pr0}
    # isolated vertices (no incident edge, hence in no partition) hold the
    # teleport mass only:
    fin = lambda rt, out: rt.gather_global(np.asarray(out["pr"]),
                                           fill=(1.0 - damping) / n)
    return AppSpec("pagerank", superstep, state, static, eb.check_rep, fin)


def pagerank(rt: PartitionRuntime, num_iters: int = 20,
             damping: float = 0.85, *, mesh=None, options=None,
             backend="scatter", init: np.ndarray | None = None,
             fused=False, tol=None, chunk=8, **backend_opts):
    """Returns (V,) global PageRank after ``num_iters`` supersteps.

    ``init`` warm-starts from a previous (V,) result (see
    :func:`build_pagerank`).  ``fused=True`` runs the whole iteration as
    one on-device dispatch (``run_bsp_fused``); ``tol`` additionally
    stops early once ``‖pr_{t+1} − pr_t‖∞ ≤ tol`` (and implies fused).
    ``options=RunOptions(...)`` carries the shared engine knobs in one
    validated object."""
    opts, extra = _options(options, "pagerank", backend, fused, tol,
                           chunk, backend_opts)
    spec = build_pagerank(rt, damping, backend=opts.backend, init=init,
                          **opts.backend_opts(), **extra)
    out, actives = _run(spec, num_iters, mesh=mesh, fused=opts.fused,
                        tol=opts.tol, chunk=opts.chunk)
    return spec.finalize(rt, out), actives


# ---------------------------------------------------------------------------
# SSSP (sparse: active set shrinks per superstep; (min, +))
# ---------------------------------------------------------------------------

def build_relax(rt: PartitionRuntime, source: int, weighted: bool, *,
                backend="scatter", name: str = "sssp",
                **backend_opts) -> AppSpec:
    inf = jnp.float32(jnp.inf)
    eb, static, combine = _resolve(rt, backend, "min_plus",
                                   "weight" if weighted else "unit",
                                   "min", **backend_opts)

    def superstep(state, sa):
        dist, changed = state["dist"], state["changed"]
        # only vertices that improved last superstep send; +inf is the
        # (min, +) no-message value, so the masked entries fold to the
        # ⊕ identity — exact, because an unchanged vertex's distance was
        # already folded into its neighbors when it last changed
        msg = jnp.where(changed, dist, inf)
        cand = combine(sa, msg)               # post-exchange ("min")
        new_dist = jnp.minimum(dist, cand)
        new_dist = jnp.where(sa["vertex_valid"], new_dist, inf)
        new_changed = new_dist < dist         # vertices updated this step
        return {"dist": new_dist, "changed": new_changed}, new_changed.sum()

    dist0 = np.full((rt.p, rt.vmax), np.inf, dtype=np.float32)
    holders = np.nonzero(rt.local_vertex_gid == source)
    dist0[holders] = 0.0
    state = {"dist": jnp.asarray(dist0),
             "changed": jnp.asarray(np.isfinite(dist0))}
    fin = lambda rt, out: rt.gather_global(np.asarray(out["dist"]),
                                           fill=np.inf)
    return AppSpec(name, superstep, state, static, eb.check_rep, fin)


def sssp(rt: PartitionRuntime, source: int = 0, num_iters: int = 30,
         *, mesh=None, options=None, backend="scatter", fused=False,
         tol=None, chunk=8, **backend_opts):
    opts, extra = _options(options, "sssp", backend, fused, tol, chunk,
                           backend_opts)
    spec = build_relax(rt, source, weighted=True, backend=opts.backend,
                       **opts.backend_opts(), **extra)
    out, actives = _run(spec, num_iters, mesh=mesh, fused=opts.fused,
                        tol=opts.tol, chunk=opts.chunk)
    return spec.finalize(rt, out), actives


# ---------------------------------------------------------------------------
# BFS (sparse: frontier grows/shrinks; (or, and))
# ---------------------------------------------------------------------------

def build_bfs(rt: PartitionRuntime, source: int, *, backend="scatter",
              **backend_opts) -> AppSpec:
    """Layer-synchronous BFS: the frontier (vertices discovered last
    superstep) expands through one (or, and) product per step.  Distances
    equal the (min, +) relaxation with unit weights — the semiring view
    of the same traversal — which the backend-equivalence tests exploit.
    """
    eb, static, combine = _resolve(rt, backend, "or_and", "unit", "max",
                                   **backend_opts)

    def superstep(state, sa):
        dist, step = state["dist"], state["step"]
        frontier = jnp.where(sa["vertex_valid"] & (dist == step),
                             1.0, 0.0).astype(jnp.float32)
        reached = combine(sa, frontier)       # post-exchange ("max")
        newly = sa["vertex_valid"] & (reached > 0) & jnp.isinf(dist)
        new_dist = jnp.where(newly, step + 1.0, dist)
        return {"dist": new_dist, "step": step + 1.0}, newly.sum()

    dist0 = np.full((rt.p, rt.vmax), np.inf, dtype=np.float32)
    holders = np.nonzero(rt.local_vertex_gid == source)
    dist0[holders] = 0.0
    state = {"dist": jnp.asarray(dist0),
             "step": jnp.zeros(rt.p, dtype=jnp.float32)}
    fin = lambda rt, out: rt.gather_global(np.asarray(out["dist"]),
                                           fill=np.inf)
    return AppSpec("bfs", superstep, state, static, eb.check_rep, fin)


def bfs(rt: PartitionRuntime, source: int = 0, num_iters: int = 30,
        *, mesh=None, options=None, backend="scatter", fused=False,
        tol=None, chunk=8, **backend_opts):
    opts, extra = _options(options, "bfs", backend, fused, tol, chunk,
                           backend_opts)
    spec = build_bfs(rt, source, backend=opts.backend,
                     **opts.backend_opts(), **extra)
    out, actives = _run(spec, num_iters, mesh=mesh, fused=opts.fused,
                        tol=opts.tol, chunk=opts.chunk)
    return spec.finalize(rt, out), actives


# ---------------------------------------------------------------------------
# Weakly-connected components (label propagation: (min, +), zero weights)
# ---------------------------------------------------------------------------

def build_components(rt: PartitionRuntime, *, backend="scatter",
                     **backend_opts) -> AppSpec:
    inf = jnp.float32(jnp.inf)
    eb, static, combine = _resolve(rt, backend, "min_plus", "zero", "min",
                                   **backend_opts)

    def superstep(state, sa):
        lab, changed = state["lab"], state["changed"]
        msg = jnp.where(changed, lab, inf)    # changed-mask, as in SSSP
        cand = combine(sa, msg)               # post-exchange min label
        new = jnp.minimum(lab, cand)
        new = jnp.where(sa["vertex_valid"], new, inf)
        new_changed = new < lab
        return {"lab": new, "changed": new_changed}, new_changed.sum()

    lab0 = jnp.where(jnp.asarray(rt.vertex_valid),
                     jnp.asarray(rt.local_vertex_gid, dtype=jnp.float32),
                     jnp.inf)
    # every valid vertex broadcasts its own label once, on superstep 1
    state = {"lab": lab0, "changed": jnp.asarray(rt.vertex_valid)}
    fin = lambda rt, out: rt.gather_global(np.asarray(out["lab"]),
                                           fill=np.inf)
    return AppSpec("cc", superstep, state, static, eb.check_rep, fin)


def connected_components(rt: PartitionRuntime, num_iters: int = 30,
                         *, mesh=None, options=None, backend="scatter",
                         fused=False, tol=None, chunk=8, **backend_opts):
    """Min-label propagation; returns (V,) component id per vertex."""
    opts, extra = _options(options, "cc", backend, fused, tol, chunk,
                           backend_opts)
    spec = build_components(rt, backend=opts.backend,
                            **opts.backend_opts(), **extra)
    out, actives = _run(spec, num_iters, mesh=mesh, fused=opts.fused,
                        tol=opts.tol, chunk=opts.chunk)
    return spec.finalize(rt, out), actives


#: app name -> AppSpec builder (benchmarks/dryrun iterate this)
APP_BUILDERS = {
    "pagerank": build_pagerank,
    "sssp": lambda rt, **kw: build_relax(rt, kw.pop("source", 0), True,
                                         **kw),
    "bfs": lambda rt, **kw: build_bfs(rt, kw.pop("source", 0), **kw),
    "cc": build_components,
}


def build_app(rt: PartitionRuntime, app: str, *, backend="scatter",
              **kw) -> AppSpec:
    """Build any registered app's :class:`AppSpec` by name."""
    try:
        builder = APP_BUILDERS[app]
    except KeyError:
        raise ValueError(f"unknown BSP app {app!r} "
                         f"(choices: {sorted(APP_BUILDERS)})") from None
    return builder(rt, backend=backend, **kw)


# ---------------------------------------------------------------------------
# Triangle counting (dense): edge-parallel |N(u) ∩ N(v)| with the global
# CSR replicated to every machine (HTC-style shared adjacency); each machine
# scans only its own edges.  Exact — every triangle is seen by exactly 3
# edges, hence the /3 (each edge of the triangle counts it once).
# ---------------------------------------------------------------------------

def triangle_count(rt: PartitionRuntime, g, *, max_degree: int = 64,
                   chunk: int = 4096, mesh=None) -> int:
    """Exact triangle count over the partitioned edge sets.

    Adjacency intersections run against a degree-bounded global neighbor
    table (ELL layout, TPU/MXU-friendly equality contraction); edges whose
    endpoint exceeds the bound take a numpy sorted-intersection fallback
    (hubs are few; each edge is still counted exactly once).
    """
    deg = g.degree()
    cap = int(max_degree)
    V = g.num_vertices
    ell = np.full((V, cap), -1, dtype=np.int32)
    over = np.flatnonzero(deg > cap)
    for v in np.flatnonzero((deg > 0) & (deg <= cap)):
        nb = g.neighbors(v)
        ell[v, :len(nb)] = np.sort(nb)
    ell_j = jnp.asarray(ell)

    @jax.jit
    def count_chunk(edges_gid, valid):
        a = ell_j[edges_gid[:, 0]]            # (chunk, cap)
        b = ell_j[edges_gid[:, 1]]
        hit = (a[:, :, None] == b[:, None, :]) & (a[:, :, None] >= 0)
        return jnp.where(valid, hit.sum(axis=(1, 2)), 0).sum()

    count = 0
    for i in range(rt.p):
        m = rt.edge_valid[i]
        gids = rt.local_vertex_gid[i][rt.local_edges[i]]
        both_ok = m & ~np.isin(gids[:, 0], over) & ~np.isin(gids[:, 1], over)
        idx = np.flatnonzero(both_ok)
        for s in range(0, len(idx), chunk):
            sel = idx[s:s + chunk]
            pad = chunk - len(sel)
            eg = np.pad(gids[sel], ((0, pad), (0, 0)))
            va = np.pad(np.ones(len(sel), bool), (0, pad))
            count += int(count_chunk(jnp.asarray(eg), jnp.asarray(va)))
        # numpy fallback for hub endpoints
        for e in np.flatnonzero(m & ~both_ok):
            u, v = gids[e]
            count += len(np.intersect1d(g.neighbors(u), g.neighbors(v),
                                        assume_unique=True))
    return count // 3
