"""Heterogeneous-cluster BSP time simulator (paper Table 1 / Section 5.4).

Predicts the distributed running time of a graph algorithm on machines with
quadruples (M_i, C_i^node, C_i^edge, C_i^com), from the partition layout and
the *actual* per-superstep activity of the algorithm:

    t_i(step) = C_i^node·act_i(step) + C_i^edge·E_i·edge_frac(step)
              + Σ_{replicated v on i} (C_i^com + C_j^com)
    step time = max_i t_i(step)            (BSP barrier: long-tail effect)
    runtime   = Σ_steps step_time

For dense algorithms (PageRank) every vertex/edge is active each superstep
and the prediction reduces exactly to the TC metric × #supersteps — the
paper's equivalence claim; for sparse algorithms (SSSP/BFS) activity comes
from the engine's measured per-(step, machine) active counts.
"""
from __future__ import annotations

import numpy as np

from ..core.graph import Graph
from ..core.machines import Cluster
from .partition_runtime import PartitionRuntime


def _per_machine_comm(rt: PartitionRuntime, cluster: Cluster) -> np.ndarray:
    """Σ over replicated v on machine i of Σ_{j≠i holding v} (C_i + C_j)."""
    p = rt.p
    c_com = cluster.c_com()
    holders = np.zeros((p, rt.num_vertices), dtype=bool)
    for i in range(p):
        m = rt.vertex_valid[i]
        holders[i, rt.local_vertex_gid[i, m]] = True
    replicas = holders.sum(axis=0)
    com_sum = holders.T.astype(np.float64) @ c_com
    out = np.zeros(p)
    for i in range(p):
        vs = holders[i] & (replicas > 1)
        out[i] = ((replicas[vs] - 1) * c_com[i] + (com_sum[vs] - c_com[i])).sum()
    return out


def simulate_superstep_times(rt: PartitionRuntime, cluster: Cluster,
                             actives: np.ndarray | None = None,
                             num_steps: int = 1,
                             comm_scale: str = "static") -> np.ndarray:
    """(steps, p) per-machine superstep times.

    actives: (steps, p) active-vertex counts (None => dense: all active).
    comm_scale: 'static' charges the full replica sync each superstep (BSP
    engines sync every boundary each barrier); 'active' scales communication
    by the machine's active fraction (push-based engines).
    """
    p = cluster.p
    e_i = rt.edges_per_machine.astype(np.float64)
    v_i = rt.verts_per_machine.astype(np.float64)
    comm = _per_machine_comm(rt, cluster)
    if actives is None:
        actives = np.tile(v_i, (num_steps, 1))
    actives = np.asarray(actives, dtype=np.float64)
    frac = np.divide(actives, np.maximum(v_i, 1.0))
    t_cal = (cluster.c_node() * actives
             + cluster.c_edge() * e_i * frac)
    t_com = comm * (frac if comm_scale == "active" else 1.0)
    return t_cal + t_com


def simulate_runtime(rt: PartitionRuntime, cluster: Cluster,
                     actives: np.ndarray | None = None,
                     num_steps: int = 1, comm_scale: str = "static") -> float:
    """BSP makespan: Σ_steps max_i t_i(step)."""
    t = simulate_superstep_times(rt, cluster, actives, num_steps, comm_scale)
    return float(t.max(axis=1).sum())
