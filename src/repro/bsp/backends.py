"""Edge-kernel backends: how a BSP superstep combines messages over edges.

Every edge-centric superstep in ``bsp/apps.py`` is one semiring SpMV
against the machine's local adjacency (``y_i = ⊕_j A_ij ⊗ x_j``, symmetric
A):

* PageRank   — (+, ×) with edge weights;
* SSSP       — (min, +) with edge weights;
* BFS        — (or, and) frontier expansion (presence only);
* components — (min, +) with zero weights (min-label propagation).

A backend supplies that product.  ``prepare(rt, semiring, weights)``
returns ``(extras, combine)``: ``extras`` is a dict of ``(p, ...)`` arrays
merged into the superstep's static tree (vmap/shard_map stack them like
every other runtime array), and ``combine(sa, x)`` maps this machine's
``(Vmax,)`` vertex values to their ⊕-combined neighborhood values inside
the (rank-reduced) superstep body.

Backends:

``scatter``
    The historical gather-scatter loop (``at[dst].⊕(x[src] ⊗ w)``, one
    scatter per direction).  Kept as the oracle every other backend is
    tested against — float-identical to the pre-backend apps.
``segment``
    Sorted-CSR reduction.  The incidence list is pre-sorted by output
    vertex; (+, ×) reduces via an exclusive running sum differenced at
    the row pointers (no scatter at all — the CPU fast path; numerics
    note below), (min, +)/(or, and) via ``jax.ops.segment_min/max`` on
    the sorted indices.
``pallas``
    The blocked Block-ELL SpMV (``kernels/bsr_spmv``) over
    ``rt.local_bsr()``'s degree-sorted per-machine layout —
    MXU-shaped on TPU, interpret-mode on CPU.  Needs
    ``check_rep=False`` under shard_map (no replication rule for
    ``pallas_call``); the engine threads that through automatically.

Numerics: the ``segment`` (+, ×) running-sum is float32 and reassociates
the additions, so results drift O(eps·Σ|msg|) ≈ 1e-7 from ``scatter`` per
superstep — within the 1e-5 cross-backend contract the tests pin.
(min, +) and (or, and) are exact (min/max are associative), so sparse
apps agree bitwise across all three backends.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.bsr_spmv import get_semiring
from ..kernels.bsr_spmv.kernel import spmv_pallas

#: weight kinds an app may ask for: the stored ⊗ operand per edge
WEIGHT_KINDS = ("weight", "unit", "zero")


def _edge_operand(rt, weights: str) -> np.ndarray:
    """(p, Emax) raw ⊗ operand per edge for a weight kind."""
    if weights == "weight":
        return rt.edge_weight
    if weights == "unit":
        return np.ones_like(rt.edge_weight)
    if weights == "zero":
        return np.zeros_like(rt.edge_weight)
    raise ValueError(f"weights must be one of {WEIGHT_KINDS}, "
                     f"got {weights!r}")


@dataclasses.dataclass(frozen=True)
class EdgeBackend:
    """A named edge-combine strategy (see module docstring)."""

    name: str
    description: str
    prepare: Callable          # (rt, semiring, weights) -> (extras, combine)
    #: False when the backend's ops have no shard_map replication rule
    #: (Pallas) — the engine then passes ``check_vma=False``
    check_rep: bool = True


# ---------------------------------------------------------------------------
# scatter: the oracle (gather + at[].⊕ per direction)
# ---------------------------------------------------------------------------

def _scatter_prepare(rt, semiring: str, weights: str):
    sr = get_semiring(semiring)
    wkind = weights

    def combine(sa, x):
        src, dst = sa["edges"][:, 0], sa["edges"][:, 1]
        if wkind == "weight":
            w_raw = sa["edge_weight"]
        elif wkind == "unit":
            w_raw = jnp.ones_like(sa["edge_weight"])
        else:
            w_raw = jnp.zeros_like(sa["edge_weight"])
        w = sr.weights(w_raw, sa["edge_valid"])
        out = jnp.full(x.shape, sr.zero, dtype=x.dtype)
        out = sr.scatter_accum(out, dst, sr.times(w, x[src]))
        out = sr.scatter_accum(out, src, sr.times(w, x[dst]))
        return out

    return {}, combine


# ---------------------------------------------------------------------------
# segment: sorted-CSR reduction (cumsum-diff for ⊕ = +)
# ---------------------------------------------------------------------------

def _segment_prepare(rt, semiring: str, weights: str):
    sr = get_semiring(semiring)
    p, vmax, emax = rt.p, rt.vmax, rt.emax
    w_raw = _edge_operand(rt, weights)

    # both directions of every edge, output-major: row j of the incidence
    # receives x[inc_in[j]] ⊗ w[j] into output vertex inc_out[j]
    inc_out = np.concatenate([rt.local_edges[:, :, 1],
                              rt.local_edges[:, :, 0]], axis=1)  # (p, 2E)
    inc_in = np.concatenate([rt.local_edges[:, :, 0],
                             rt.local_edges[:, :, 1]], axis=1)
    valid2 = np.concatenate([rt.edge_valid, rt.edge_valid], axis=1)
    w2 = np.concatenate([w_raw, w_raw], axis=1).astype(np.float32)
    # invalid rows sort to a trailing dump segment (id = Vmax) and carry
    # the semiring's annihilator, so they contribute the ⊕ identity
    inc_out = np.where(valid2, inc_out, vmax).astype(np.int32)
    w2 = np.where(valid2, w2, np.float32(sr.absent))
    order = np.argsort(inc_out, axis=1, kind="stable")
    inc_out = np.take_along_axis(inc_out, order, 1)
    inc_in = np.take_along_axis(inc_in, order, 1).astype(np.int32)
    w2 = np.take_along_axis(w2, order, 1)
    ptr = np.zeros((p, vmax + 1), dtype=np.int32)
    for i in range(p):
        counts = np.bincount(inc_out[i][inc_out[i] < vmax], minlength=vmax)
        ptr[i, 1:] = np.cumsum(counts)
    extras = {"eb_seg_out": jnp.asarray(inc_out),
              "eb_seg_in": jnp.asarray(inc_in),
              "eb_seg_w": jnp.asarray(w2),
              "eb_seg_ptr": jnp.asarray(ptr)}

    def combine(sa, x):
        vals = sr.times(sa["eb_seg_w"], x[sa["eb_seg_in"]])
        if sr.name == "plus_times":
            s = jnp.concatenate([jnp.zeros(1, vals.dtype), jnp.cumsum(vals)])
            ptr_ = sa["eb_seg_ptr"]
            return (s[ptr_[1:]] - s[ptr_[:-1]]).astype(x.dtype)
        seg = (jax.ops.segment_min if sr.name == "min_plus"
               else jax.ops.segment_max)
        y = seg(vals, sa["eb_seg_out"], num_segments=vmax + 1,
                indices_are_sorted=True)[:vmax]
        # empty segments come back as the reduction's own identity
        # (+inf / -inf); clamp the (or, and) case to the semiring zero
        if sr.name == "or_and":
            y = jnp.maximum(y, sr.zero)
        return y.astype(x.dtype)

    return extras, combine


# ---------------------------------------------------------------------------
# pallas: blocked Block-ELL SpMV over the degree-sorted local adjacency
# ---------------------------------------------------------------------------

def _pallas_prepare_factory(block_size: int = 128,
                            interpret: bool | None = None):
    def prepare(rt, semiring: str, weights: str):
        sr = get_semiring(semiring)
        bsr = rt.local_bsr(block_size=block_size, semiring=sr.name,
                           weights=weights)
        ip = (jax.default_backend() != "tpu") if interpret is None \
            else interpret
        extras = {"eb_bsr_cols": jnp.asarray(bsr.cols),
                  "eb_bsr_blocks": jnp.asarray(bsr.blocks),
                  "eb_bsr_gather": jnp.asarray(bsr.gather),
                  "eb_bsr_rank": jnp.asarray(bsr.rank)}

        def combine(sa, x):
            xb = x[sa["eb_bsr_gather"]].astype(jnp.float32)
            y = spmv_pallas(sa["eb_bsr_cols"], sa["eb_bsr_blocks"], xb,
                            block_size=block_size, interpret=ip,
                            semiring=sr.name)
            return y[sa["eb_bsr_rank"]].astype(x.dtype)

        return extras, combine

    return prepare


_REGISTRY = {
    "scatter": lambda **kw: EdgeBackend(
        "scatter", "gather-scatter oracle (at[].⊕ per direction)",
        _scatter_prepare, **kw),
    "segment": lambda **kw: EdgeBackend(
        "segment", "sorted-CSR reduction (cumsum-diff; CPU fast path)",
        _segment_prepare, **kw),
    "pallas": lambda block_size=128, interpret=None, **kw: EdgeBackend(
        "pallas", "blocked Block-ELL semiring SpMV (kernels/bsr_spmv)",
        _pallas_prepare_factory(block_size, interpret),
        check_rep=False, **kw),
}

BACKENDS = tuple(_REGISTRY)


def get_backend(name, **opts) -> EdgeBackend:
    """Resolve a backend by name (``EdgeBackend`` passes through).

    ``opts`` are backend-specific: ``pallas`` takes ``block_size``
    (default 128, the MXU tile) and ``interpret`` (None = auto:
    interpreter off-TPU).
    """
    if isinstance(name, EdgeBackend):
        return name
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown edge-kernel backend {name!r} "
                         f"(choices: {sorted(_REGISTRY)})") from None
    return factory(**opts)
