"""Edge-kernel backends: how a BSP superstep combines messages over edges.

Every edge-centric superstep in ``bsp/apps.py`` is one semiring SpMV
against the machine's local adjacency (``y_i = ⊕_j A_ij ⊗ x_j``, symmetric
A):

* PageRank   — (+, ×) with edge weights;
* SSSP       — (min, +) with edge weights;
* BFS        — (or, and) frontier expansion (presence only);
* components — (min, +) with zero weights (min-label propagation).

A backend supplies that product.  ``prepare(rt, semiring, weights)``
returns ``(extras, combine)``: ``extras`` is a dict of ``(p, ...)`` arrays
merged into the superstep's static tree (vmap/shard_map stack them like
every other runtime array), and ``combine(sa, x)`` maps this machine's
``(Vmax,)`` vertex values to their ⊕-combined neighborhood values inside
the (rank-reduced) superstep body.

Backends:

``scatter``
    The historical gather-scatter loop (``at[dst].⊕(x[src] ⊗ w)``, one
    scatter per direction).  Kept as the oracle every other backend is
    tested against — float-identical to the pre-backend apps.
``segment``
    Sorted-CSR reduction.  The incidence list is pre-sorted by output
    vertex; (+, ×) reduces via an exclusive running sum differenced at
    the row pointers (no scatter at all — the CPU fast path; numerics
    note below), (min, +)/(or, and) via ``jax.ops.segment_min/max`` on
    the sorted indices.
``pallas``
    The blocked Block-ELL SpMV (``kernels/bsr_spmv``) over
    ``rt.local_bsr()``'s degree-sorted per-machine layout —
    MXU-shaped on TPU, interpret-mode on CPU.  Needs
    ``check_rep=False`` under shard_map (no replication rule for
    ``pallas_call``); the engine threads that through automatically.

Numerics: the ``segment`` (+, ×) running-sum is float32 and reassociates
the additions, so results drift O(eps·Σ|msg|) ≈ 1e-7 from ``scatter`` per
superstep — within the 1e-5 cross-backend contract the tests pin.
(min, +) and (or, and) are exact (min/max are associative), so sparse
apps agree bitwise across all three backends.

Two cross-cutting knobs every backend understands:

``message_dtype`` (default ``"float32"``)
    The ⊗ operand precision: messages and edge weights are cast to this
    dtype before the per-edge product.  ``scatter``/``segment`` cast the
    *products* back to float32 before ⊕-accumulating (low-precision
    messages, full-precision accumulation — the classic bf16 message
    path); ``pallas`` stores its blocks in the dtype
    (``rt.local_bsr(dtype=...)``) and accumulates in it too.  With
    ``"float32"`` every cast is a no-op, so the default path is
    bit-identical to the pre-knob backends.

``frontier_cap`` (``scatter`` only, default ``None``)
    Active-frontier sparsification, two-level: the combine first
    compacts the *vertices carrying a live message* (``x`` differs from
    the semiring's no-message value — +inf for (min, +), 0 for
    (or, and)/(+, ×)) into a ``(frontier_cap,)`` id buffer via
    ``jnp.nonzero(..., size=cap)`` — an O(Vmax) scan, not O(E) — then
    gathers those vertices' rows of a per-vertex ELL incidence
    ``(Vmax, dmax)`` built at prepare time and ⊕-scatters the
    ``cap × dmax`` expanded entries.  Superstep edge work drops from
    O(E_local) to O(frontier · dmax + Vmax).  The layout spends
    O(Vmax · dmax) memory, so this path fits bounded-degree graphs
    (road networks, meshes — exactly where BFS/SSSP frontiers stay
    narrow); on power-law graphs the hub degree makes ``dmax`` —
    and the padding — explode.  The caller must pick ``frontier_cap ≥``
    the per-machine live-vertex count (vertices beyond the cap are
    dropped) — :func:`frontier_entries` computes the exact count
    host-side, and the ``--latency`` benchmark re-buckets the cap per
    superstep as the BFS/SSSP frontier drains.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.bsr_spmv import get_semiring
from ..kernels.bsr_spmv.kernel import spmv_pallas
from .engine import exchange

#: weight kinds an app may ask for: the stored ⊗ operand per edge
WEIGHT_KINDS = ("weight", "unit", "zero")

#: message dtypes the low-precision path accepts
MESSAGE_DTYPES = ("float32", "bfloat16", "float16")


def _message_dtype(name: str):
    if str(name) not in MESSAGE_DTYPES:
        raise ValueError(f"message_dtype must be one of {MESSAGE_DTYPES}, "
                         f"got {name!r}")
    return jnp.dtype(str(name))


def _no_message(sr) -> float:
    """The x value meaning "this vertex sends nothing": its ⊗ product is
    the ⊕ identity for every edge weight ((min,+): +inf; else 0)."""
    return np.inf if sr.name == "min_plus" else 0.0


def _edge_operand(rt, weights: str) -> np.ndarray:
    """(p, Emax) raw ⊗ operand per edge for a weight kind."""
    if weights == "weight":
        return rt.edge_weight
    if weights == "unit":
        return np.ones_like(rt.edge_weight)
    if weights == "zero":
        return np.zeros_like(rt.edge_weight)
    raise ValueError(f"weights must be one of {WEIGHT_KINDS}, "
                     f"got {weights!r}")


@dataclasses.dataclass(frozen=True)
class EdgeBackend:
    """A named edge-combine strategy (see module docstring)."""

    name: str
    description: str
    prepare: Callable          # (rt, semiring, weights) -> (extras, combine)
    #: False when the backend's ops have no shard_map replication rule
    #: (Pallas) — the engine then passes ``check_vma=False``
    check_rep: bool = True

    def prepare_exchanged(self, rt, semiring: str, weights: str,
                          mode: str, r_pad: int):
        """``prepare`` with the replica :func:`~.engine.exchange` fused
        into the combine epilogue.

        The returned ``combine(sa, x)`` yields the *post-exchange*
        neighborhood values directly — the superstep never materializes
        the pre-exchange ``(Vmax,)`` partial as a separate value, and
        every app's cross-machine sync lives in one place instead of
        being re-spelled per superstep body.
        """
        extras, combine = self.prepare(rt, semiring, weights)

        def combine_exchanged(sa, x):
            return exchange(combine(sa, x), sa["rep_slot"], r_pad, mode)

        return extras, combine_exchanged


def frontier_entries(rt, changed: np.ndarray) -> np.ndarray:
    """(p,) live (message-carrying) vertices per machine for a changed
    mask — the exact lower bound for the ``scatter`` backend's
    ``frontier_cap``.

    ``changed``: (p, Vmax) bool, True where the vertex carries a message
    this superstep (the ``"changed"`` state leaf of the monotone apps;
    ``dist == step`` for BFS).
    """
    changed = np.asarray(changed, dtype=bool)
    return (changed & rt.vertex_valid).sum(axis=1).astype(np.int64)


# ---------------------------------------------------------------------------
# scatter: the oracle (gather + at[].⊕ per direction)
# ---------------------------------------------------------------------------

def _scatter_prepare_factory(message_dtype: str = "float32",
                             frontier_cap: int | None = None):
    def prepare(rt, semiring: str, weights: str):
        sr = get_semiring(semiring)
        mdt = _message_dtype(message_dtype)
        wkind = weights

        if frontier_cap is None:
            def combine(sa, x):
                src, dst = sa["edges"][:, 0], sa["edges"][:, 1]
                if wkind == "weight":
                    w_raw = sa["edge_weight"]
                elif wkind == "unit":
                    w_raw = jnp.ones_like(sa["edge_weight"])
                else:
                    w_raw = jnp.zeros_like(sa["edge_weight"])
                w = sr.weights(w_raw, sa["edge_valid"])
                xm = x.astype(mdt)
                out = jnp.full(x.shape, sr.zero, dtype=x.dtype)
                out = sr.scatter_accum(
                    out, dst,
                    sr.times(w.astype(mdt), xm[src]).astype(x.dtype))
                out = sr.scatter_accum(
                    out, src,
                    sr.times(w.astype(mdt), xm[dst]).astype(x.dtype))
                return out

            return {}, combine

        # frontier mode: per-vertex ELL of the directed incidence —
        # row v holds v's outgoing (dst, w) entries, padded to the
        # machine-max degree with the dump row / the ⊗ annihilator.
        # The combine compacts the live *vertices* (an O(Vmax) scan)
        # and expands only their rows: O(frontier · dmax) edge work.
        cap = int(frontier_cap)
        if cap < 1:
            raise ValueError(f"frontier_cap must be >= 1, got {cap}")
        w_raw = _edge_operand(rt, weights)
        p, vmax = rt.p, rt.vmax
        src2 = np.concatenate([rt.local_edges[:, :, 0],
                               rt.local_edges[:, :, 1]], axis=1)
        dst2 = np.concatenate([rt.local_edges[:, :, 1],
                               rt.local_edges[:, :, 0]], axis=1)
        valid2 = np.concatenate([rt.edge_valid, rt.edge_valid], axis=1)
        w2 = np.concatenate([w_raw, w_raw], axis=1).astype(np.float32)
        deg = np.zeros((p, vmax), dtype=np.int64)
        for i in range(p):
            np.add.at(deg[i], src2[i][valid2[i]], 1)
        dmax = max(1, int(deg.max()))
        ell_dst = np.full((p, vmax, dmax), vmax, dtype=np.int32)
        ell_w = np.full((p, vmax, dmax), np.float32(sr.absent),
                        dtype=np.float32)
        for i in range(p):
            s = src2[i][valid2[i]]
            order = np.argsort(s, kind="stable")
            s = s[order]
            slot = np.arange(len(s)) - np.searchsorted(s, s)
            ell_dst[i][s, slot] = dst2[i][valid2[i]][order]
            ell_w[i][s, slot] = w2[i][valid2[i]][order]
        extras = {"eb_fr_dst": jnp.asarray(ell_dst),
                  "eb_fr_w": jnp.asarray(ell_w)}
        none = _no_message(sr)

        def combine(sa, x):
            live = sa["vertex_valid"] & (x != none)
            ids = jnp.nonzero(live, size=cap, fill_value=0)[0]
            ok = jnp.arange(cap) < live.sum()        # (cap,) real rows
            rows_d = sa["eb_fr_dst"][ids]            # (cap, dmax)
            rows_w = sa["eb_fr_w"][ids].astype(mdt)
            vals = sr.times(rows_w,
                            x.astype(mdt)[ids][:, None]).astype(x.dtype)
            vals = jnp.where(ok[:, None], vals,
                             jnp.asarray(sr.zero, x.dtype))
            d = jnp.where(ok[:, None], rows_d, vmax)  # pad -> dump row
            out = jnp.full((vmax + 1,), sr.zero, dtype=x.dtype)
            return sr.scatter_accum(out, d.reshape(-1),
                                    vals.reshape(-1))[:vmax]

        return extras, combine

    return prepare


# ---------------------------------------------------------------------------
# segment: sorted-CSR reduction (cumsum-diff for ⊕ = +)
# ---------------------------------------------------------------------------

def _segment_prepare_factory(message_dtype: str = "float32"):
    def prepare(rt, semiring, weights):
        return _segment_prepare(rt, semiring, weights,
                                message_dtype=message_dtype)
    return prepare


def _segment_prepare(rt, semiring: str, weights: str,
                     message_dtype: str = "float32"):
    sr = get_semiring(semiring)
    mdt = _message_dtype(message_dtype)
    p, vmax, emax = rt.p, rt.vmax, rt.emax
    w_raw = _edge_operand(rt, weights)

    # both directions of every edge, output-major: row j of the incidence
    # receives x[inc_in[j]] ⊗ w[j] into output vertex inc_out[j]
    inc_out = np.concatenate([rt.local_edges[:, :, 1],
                              rt.local_edges[:, :, 0]], axis=1)  # (p, 2E)
    inc_in = np.concatenate([rt.local_edges[:, :, 0],
                             rt.local_edges[:, :, 1]], axis=1)
    valid2 = np.concatenate([rt.edge_valid, rt.edge_valid], axis=1)
    w2 = np.concatenate([w_raw, w_raw], axis=1).astype(np.float32)
    # invalid rows sort to a trailing dump segment (id = Vmax) and carry
    # the semiring's annihilator, so they contribute the ⊕ identity
    inc_out = np.where(valid2, inc_out, vmax).astype(np.int32)
    w2 = np.where(valid2, w2, np.float32(sr.absent))
    order = np.argsort(inc_out, axis=1, kind="stable")
    inc_out = np.take_along_axis(inc_out, order, 1)
    inc_in = np.take_along_axis(inc_in, order, 1).astype(np.int32)
    w2 = np.take_along_axis(w2, order, 1)
    ptr = np.zeros((p, vmax + 1), dtype=np.int32)
    for i in range(p):
        counts = np.bincount(inc_out[i][inc_out[i] < vmax], minlength=vmax)
        ptr[i, 1:] = np.cumsum(counts)
    extras = {"eb_seg_out": jnp.asarray(inc_out),
              "eb_seg_in": jnp.asarray(inc_in),
              "eb_seg_w": jnp.asarray(w2),
              "eb_seg_ptr": jnp.asarray(ptr)}

    def combine(sa, x):
        # low-precision messages, full-precision ⊕: ⊗ in message_dtype,
        # products back to the state dtype before the reduction
        vals = sr.times(sa["eb_seg_w"].astype(mdt),
                        x.astype(mdt)[sa["eb_seg_in"]]).astype(x.dtype)
        if sr.name == "plus_times":
            s = jnp.concatenate([jnp.zeros(1, vals.dtype), jnp.cumsum(vals)])
            ptr_ = sa["eb_seg_ptr"]
            return (s[ptr_[1:]] - s[ptr_[:-1]]).astype(x.dtype)
        seg = (jax.ops.segment_min if sr.name == "min_plus"
               else jax.ops.segment_max)
        y = seg(vals, sa["eb_seg_out"], num_segments=vmax + 1,
                indices_are_sorted=True)[:vmax]
        # empty segments come back as the reduction's own identity
        # (+inf / -inf); clamp the (or, and) case to the semiring zero
        if sr.name == "or_and":
            y = jnp.maximum(y, sr.zero)
        return y.astype(x.dtype)

    return extras, combine


# ---------------------------------------------------------------------------
# pallas: blocked Block-ELL SpMV over the degree-sorted local adjacency
# ---------------------------------------------------------------------------

def _pallas_prepare_factory(block_size: int = 128,
                            interpret: bool | None = None,
                            message_dtype: str = "float32"):
    def prepare(rt, semiring: str, weights: str):
        sr = get_semiring(semiring)
        mdt = _message_dtype(message_dtype)
        bsr = rt.local_bsr(block_size=block_size, semiring=sr.name,
                           weights=weights, dtype=str(message_dtype))
        ip = (jax.default_backend() != "tpu") if interpret is None \
            else interpret
        extras = {"eb_bsr_cols": jnp.asarray(bsr.cols),
                  "eb_bsr_blocks": jnp.asarray(bsr.blocks),
                  "eb_bsr_gather": jnp.asarray(bsr.gather),
                  "eb_bsr_rank": jnp.asarray(bsr.rank)}

        def combine(sa, x):
            # blocks are stored in message_dtype (LocalBSR dtype cache
            # key); x joins them, so the kernel computes — and, unlike
            # scatter/segment, ⊕-accumulates — in that dtype
            xb = x[sa["eb_bsr_gather"]].astype(mdt)
            y = spmv_pallas(sa["eb_bsr_cols"], sa["eb_bsr_blocks"], xb,
                            block_size=block_size, interpret=ip,
                            semiring=sr.name)
            return y[sa["eb_bsr_rank"]].astype(x.dtype)

        return extras, combine

    return prepare


_REGISTRY = {
    "scatter": lambda message_dtype="float32", frontier_cap=None, **kw:
        EdgeBackend(
            "scatter", "gather-scatter oracle (at[].⊕ per direction)",
            _scatter_prepare_factory(message_dtype, frontier_cap), **kw),
    "segment": lambda message_dtype="float32", **kw: EdgeBackend(
        "segment", "sorted-CSR reduction (cumsum-diff; CPU fast path)",
        _segment_prepare_factory(message_dtype), **kw),
    "pallas": lambda block_size=128, interpret=None,
        message_dtype="float32", **kw: EdgeBackend(
            "pallas", "blocked Block-ELL semiring SpMV (kernels/bsr_spmv)",
            _pallas_prepare_factory(block_size, interpret, message_dtype),
            check_rep=False, **kw),
}

BACKENDS = tuple(_REGISTRY)


def get_backend(name, **opts) -> EdgeBackend:
    """Resolve a backend by name (``EdgeBackend`` passes through).

    ``opts`` are backend-specific: every backend takes ``message_dtype``
    (default ``"float32"``; ``"bfloat16"`` is the low-precision message
    path); ``scatter`` adds ``frontier_cap`` (active-frontier
    sparsification — see module docstring); ``pallas`` adds
    ``block_size`` (default 128, the MXU tile) and ``interpret``
    (None = auto: interpreter off-TPU).
    """
    if isinstance(name, EdgeBackend):
        return name
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown edge-kernel backend {name!r} "
                         f"(choices: {sorted(_REGISTRY)})") from None
    return factory(**opts)
