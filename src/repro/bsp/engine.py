"""BSP superstep engine.

One *machine* = one lane of the ``machines`` axis.  The same superstep body
runs under

* ``jax.vmap(..., axis_name="machines")`` — single-device simulation (CPU
  tests, benchmarking), or
* ``jax.shard_map`` over a ``machines`` mesh axis — real multi-device runs
  (the multi-pod path; collectives become ICI traffic).

The replica exchange is the only cross-machine communication: a psum (or
pmin/pmax) over an (R+1,)-sized buffer — fixed shape, proportional to the
partition's replication, which is exactly the quantity the paper's TC comm
term charges for.

Superstep contract: ``superstep(state, static) -> (state, active_count)``
with per-machine (rank-reduced) arrays, using ``exchange`` for sync.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map

MACHINES = "machines"


def exchange(partial: jnp.ndarray, rep_slot: jnp.ndarray, r_pad: int,
             mode: str = "sum") -> jnp.ndarray:
    """Synchronize replicated-vertex values across machines.

    partial: (Vmax,) this machine's local value per local vertex.
    Returns (Vmax,) with replicated entries replaced by the cross-machine
    combination (sum / min / max); non-replicated entries pass through.
    """
    slot = jnp.where(rep_slot >= 0, rep_slot, r_pad)
    if mode == "sum":
        buf = jnp.zeros(r_pad + 1, dtype=partial.dtype)
        buf = buf.at[slot].add(jnp.where(rep_slot >= 0, partial, 0))
        tot = jax.lax.psum(buf, MACHINES)
    elif mode == "min":
        buf = jnp.full(r_pad + 1, jnp.inf, dtype=partial.dtype)
        buf = buf.at[slot].min(jnp.where(rep_slot >= 0, partial, jnp.inf))
        tot = jax.lax.pmin(buf, MACHINES)
    elif mode == "max":
        buf = jnp.full(r_pad + 1, -jnp.inf, dtype=partial.dtype)
        buf = buf.at[slot].max(jnp.where(rep_slot >= 0, partial, -jnp.inf))
        tot = jax.lax.pmax(buf, MACHINES)
    else:
        raise ValueError(mode)
    return jnp.where(rep_slot >= 0, tot[slot], partial)


def make_step(superstep: Callable, static, *, mesh: Mesh | None = None,
              check_rep: bool = True):
    """Compile one BSP superstep: state -> (state, (p,) active counts).

    ``check_rep=False`` disables shard_map's replication check — required
    when the superstep body contains ops without a replication rule
    (``pallas_call``; the edge-kernel backends declare this).
    """
    if mesh is None:
        body = jax.vmap(superstep, axis_name=MACHINES, in_axes=(0, 0))
        return jax.jit(lambda s: body(s, static))

    state_spec_of = lambda tree: jax.tree.map(lambda _: P(MACHINES), tree)
    static_spec = state_spec_of(static)

    def step(state):
        def inner(st, sa):
            st = jax.tree.map(lambda a: a[0], st)
            sa = jax.tree.map(lambda a: a[0], sa)
            new_state, active = superstep(st, sa)
            return (jax.tree.map(lambda a: jnp.asarray(a)[None], new_state),
                    jnp.asarray(active)[None])
        return shard_map(
            inner, mesh=mesh,
            in_specs=(state_spec_of(state), static_spec),
            out_specs=(state_spec_of(state), P(MACHINES)),
            check_vma=check_rep)(state, static)

    return jax.jit(step)


def run_bsp(superstep: Callable, state, static, num_steps: int,
            *, mesh: Mesh | None = None, check_rep: bool = True):
    """Iterate the superstep; returns (final_state, (steps, p) actives)."""
    step = make_step(superstep, static, mesh=mesh, check_rep=check_rep)
    actives = []
    for _ in range(num_steps):
        state, act = step(state)
        actives.append(np.asarray(act))
    return state, np.stack(actives) if actives else np.zeros((0,))
