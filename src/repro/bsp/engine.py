"""BSP superstep engine.

One *machine* = one lane of the ``machines`` axis.  The same superstep body
runs under

* ``jax.vmap(..., axis_name="machines")`` — single-device simulation (CPU
  tests, benchmarking), or
* ``jax.shard_map`` over a ``machines`` mesh axis — real multi-device runs
  (the multi-pod path; collectives become ICI traffic).

The replica exchange is the only cross-machine communication: a psum (or
pmin/pmax) over an (R+1,)-sized buffer — fixed shape, proportional to the
partition's replication, which is exactly the quantity the paper's TC comm
term charges for.

Superstep contract: ``superstep(state, static) -> (state, active_count)``
with per-machine (rank-reduced) arrays, using ``exchange`` for sync.

Two runners iterate that contract:

* :func:`run_bsp` — one jitted dispatch *per superstep* with a host sync
  in between (``np.asarray`` on the active counts).  Bit-exact oracle.
* :func:`run_bsp_fused` — the whole iteration fused on device:
  ``lax.scan`` over chunks of supersteps, each chunk an inner
  ``lax.while_loop`` gated on convergence (global active count == 0, or
  an on-device residual ``‖x_{t+1}−x_t‖∞ ≤ tol``), actives accumulated
  on device.  One dispatch and one host sync for the entire run — on
  dispatch-bound shards (small per-machine edge sets) this is where the
  superstep wall clock actually goes.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map

MACHINES = "machines"


def _extreme(dtype, sign: int):
    """Dtype-safe stand-in for ±∞: the most extreme representable value.

    Floats keep the true infinities; integer dtypes get ``iinfo`` max/min
    (``jnp.full(..., jnp.inf, dtype=int32)`` silently wraps — the replica
    exchange must stay correct for integer-valued states).
    """
    dt = jnp.dtype(dtype)
    if jnp.issubdtype(dt, jnp.integer):
        info = jnp.iinfo(dt)
        return dt.type(info.max if sign > 0 else info.min)
    return dt.type(jnp.inf if sign > 0 else -jnp.inf)


def exchange(partial: jnp.ndarray, rep_slot: jnp.ndarray, r_pad: int,
             mode: str = "sum") -> jnp.ndarray:
    """Synchronize replicated-vertex values across machines.

    partial: (Vmax,) this machine's local value per local vertex.
    Returns (Vmax,) with replicated entries replaced by the cross-machine
    combination (sum / min / max); non-replicated entries pass through.
    """
    slot = jnp.where(rep_slot >= 0, rep_slot, r_pad)
    if mode == "sum":
        buf = jnp.zeros(r_pad + 1, dtype=partial.dtype)
        buf = buf.at[slot].add(jnp.where(rep_slot >= 0, partial, 0))
        tot = jax.lax.psum(buf, MACHINES)
    elif mode == "min":
        hi = _extreme(partial.dtype, +1)
        buf = jnp.full(r_pad + 1, hi, dtype=partial.dtype)
        buf = buf.at[slot].min(jnp.where(rep_slot >= 0, partial, hi))
        tot = jax.lax.pmin(buf, MACHINES)
    elif mode == "max":
        lo = _extreme(partial.dtype, -1)
        buf = jnp.full(r_pad + 1, lo, dtype=partial.dtype)
        buf = buf.at[slot].max(jnp.where(rep_slot >= 0, partial, lo))
        tot = jax.lax.pmax(buf, MACHINES)
    else:
        raise ValueError(mode)
    return jnp.where(rep_slot >= 0, tot[slot], partial)


def make_step(superstep: Callable, static, *, mesh: Mesh | None = None,
              check_rep: bool = True):
    """Compile one BSP superstep: state -> (state, (p,) active counts).

    ``check_rep=False`` disables shard_map's replication check — required
    when the superstep body contains ops without a replication rule
    (``pallas_call``; the edge-kernel backends declare this).
    """
    if mesh is None:
        body = jax.vmap(superstep, axis_name=MACHINES, in_axes=(0, 0))
        return jax.jit(lambda s: body(s, static))

    state_spec_of = lambda tree: jax.tree.map(lambda _: P(MACHINES), tree)
    static_spec = state_spec_of(static)

    def step(state):
        def inner(st, sa):
            st = jax.tree.map(lambda a: a[0], st)
            sa = jax.tree.map(lambda a: a[0], sa)
            new_state, active = superstep(st, sa)
            return (jax.tree.map(lambda a: jnp.asarray(a)[None], new_state),
                    jnp.asarray(active)[None])
        return shard_map(
            inner, mesh=mesh,
            in_specs=(state_spec_of(state), static_spec),
            out_specs=(state_spec_of(state), P(MACHINES)),
            check_vma=check_rep)(state, static)

    return jax.jit(step)


def _num_machines(state) -> int:
    """p from any state tree: every leaf is machine-stacked on axis 0."""
    return len(jax.tree.leaves(state)[0])


def run_bsp(superstep: Callable, state, static, num_steps: int,
            *, mesh: Mesh | None = None, check_rep: bool = True):
    """Iterate the superstep; returns (final_state, (steps, p) actives).

    One jitted dispatch and one device→host sync per superstep — the
    bit-exact oracle :func:`run_bsp_fused` is pinned against.
    """
    step = make_step(superstep, static, mesh=mesh, check_rep=check_rep)
    actives = []
    for _ in range(num_steps):
        state, act = step(state)
        actives.append(np.asarray(act))
    if not actives:
        # zero steps still contract to a (0, p) actives array
        return state, np.zeros((0, _num_machines(state)))
    return state, np.stack(actives)


def _state_residual(old, new) -> jnp.ndarray:
    """Global ``‖new − old‖∞`` over every state leaf (cast to float32).

    The on-device convergence measure for contraction-map apps
    (PageRank): counter/mask leaves would keep it ≥ 1, which is why the
    monotone apps gate on the active count instead.
    """
    diffs = jax.tree.map(
        lambda a, b: jnp.max(jnp.abs(b.astype(jnp.float32)
                                     - a.astype(jnp.float32))), old, new)
    return functools.reduce(jnp.maximum, jax.tree.leaves(diffs))


def make_fused_runner(superstep: Callable, static, *,
                      mesh: Mesh | None = None, check_rep: bool = True,
                      chunk: int = 8, tol: float | None = None):
    """Build a reusable fused runner: ``run(state, num_steps)``.

    The returned callable executes the whole BSP iteration as ONE jitted
    dispatch — ``lax.scan`` over ``ceil(num_steps / chunk)`` chunks of
    supersteps, each chunk an inner ``lax.while_loop`` that steps until
    the chunk is exhausted *or* the run has converged:

    * ``tol is None`` — converged when the global active count hits 0
      (the monotone apps: BFS/SSSP/CC activity is exactly the changed
      set, and 0 is absorbing);
    * ``tol`` set — converged when the on-device residual
      ``‖state_{t+1} − state_t‖∞ ≤ tol`` (PageRank power iteration).

    After convergence every remaining chunk's while_loop exits on its
    first predicate check, so converged tail steps cost one condition
    evaluation instead of a superstep.  Active counts accumulate into an
    on-device ``(chunk, p)`` buffer per chunk; the single host sync at
    the end trims them to the steps actually run.

    The compiled computation is cached on the returned callable (one
    trace per distinct chunk count), so repeat runs — benchmark loops,
    dynamic-epoch hand-offs — pay dispatch, not retracing.  The
    convenience wrapper :func:`run_bsp_fused` rebuilds it per call, like
    :func:`run_bsp` rebuilds its step.
    """
    chunk = max(1, int(chunk))
    body = jax.vmap(superstep, axis_name=MACHINES, in_axes=(0, 0))

    def chunk_body(step_fn, done_of, buf_shape, act_dt, carry, limit):
        """One scan step: while_loop over ≤ chunk supersteps, gated."""
        st0, done0 = carry
        buf0 = jnp.zeros(buf_shape, dtype=act_dt)

        def cond(c):
            _, _, t, dn = c
            return (t < limit) & jnp.logical_not(dn)

        def step_once(c):
            st, buf, t, _ = c
            new_st, act = step_fn(st)
            return (new_st, buf.at[t].set(act), t + 1,
                    done_of(st, new_st, act))

        st, buf, t, done = jax.lax.while_loop(
            cond, step_once, (st0, buf0, jnp.int32(0), done0))
        return (st, done), (buf, t)

    if mesh is None:
        def done_of(st, new_st, act):
            if tol is not None:
                return _state_residual(st, new_st) <= tol
            return act.sum() == jnp.zeros((), act.dtype)

        @jax.jit
        def fused(state, limits):
            p = _num_machines(state)
            act_dt = jax.eval_shape(lambda s: body(s, static)[1],
                                    state).dtype
            run = functools.partial(chunk_body,
                                    lambda st: body(st, static), done_of,
                                    (chunk, p), act_dt)
            (st, _), (bufs, ts) = jax.lax.scan(
                run, (state, jnp.zeros((), bool)), limits)
            return st, bufs, ts

        def run(state, num_steps: int):
            p = _num_machines(state)
            if num_steps <= 0:
                return state, np.zeros((0, p))
            num_chunks = -(-num_steps // chunk)
            limits = np.full(num_chunks, chunk, dtype=np.int32)
            limits[-1] = num_steps - chunk * (num_chunks - 1)
            state, bufs, ts = fused(state, jnp.asarray(limits))
            steps = int(np.asarray(ts).sum())
            actives = np.asarray(bufs).reshape(-1, p)[:steps]
            return state, actives

        return run

    # shard_map: the fused loop runs rank-reduced per device; the gate
    # reduces with a collective so every device agrees on the predicate
    state_spec_of = lambda tree: jax.tree.map(lambda _: P(MACHINES), tree)
    static_spec = state_spec_of(static)

    def sharded(state_b, static_b, limits):
        st = jax.tree.map(lambda a: a[0], state_b)
        sa = jax.tree.map(lambda a: a[0], static_b)
        act_dt = jax.eval_shape(lambda s: superstep(s, sa)[1], st).dtype

        def done_of(old, new, act):
            if tol is not None:
                res = jax.lax.pmax(_state_residual(old, new), MACHINES)
                return res <= tol
            tot = jax.lax.psum(jnp.asarray(act), MACHINES)
            return tot == jnp.zeros((), tot.dtype)

        def step_fn(st):
            new_st, act = superstep(st, sa)
            return new_st, jnp.asarray(act)

        run = functools.partial(chunk_body, step_fn, done_of, (chunk,),
                                act_dt)
        (st, _), (bufs, ts) = jax.lax.scan(
            run, (st, jnp.zeros((), bool)), limits)
        return (jax.tree.map(lambda a: jnp.asarray(a)[None], st),
                jnp.asarray(bufs)[None], jnp.asarray(ts)[None])

    @jax.jit
    def fused(state, limits):
        # shard_map has no replication rule for while_loop, so the
        # replication check must stay off for the fused mesh path
        # regardless of the backend's check_rep flag
        return shard_map(
            sharded, mesh=mesh,
            in_specs=(state_spec_of(state), static_spec, P()),
            out_specs=(state_spec_of(state), P(MACHINES), P(MACHINES)),
            check_vma=False)(state, static, limits)

    def run(state, num_steps: int):
        p = _num_machines(state)
        if num_steps <= 0:
            return state, np.zeros((0, p))
        num_chunks = -(-num_steps // chunk)
        limits = np.full(num_chunks, chunk, dtype=np.int32)
        limits[-1] = num_steps - chunk * (num_chunks - 1)
        state, bufs, ts = fused(state, jnp.asarray(limits))
        steps = int(np.asarray(ts)[0].sum())
        # bufs: (p, num_chunks, chunk) -> (num_chunks*chunk, p), trimmed
        actives = np.asarray(bufs).transpose(1, 2, 0).reshape(-1, p)[:steps]
        return state, actives

    return run


def run_bsp_fused(superstep: Callable, state, static, num_steps: int,
                  *, mesh: Mesh | None = None, check_rep: bool = True,
                  chunk: int = 8, tol: float | None = None):
    """One fused on-device BSP run (see :func:`make_fused_runner`).

    Returns ``(final_state, (steps_run, p) actives)``.  With ``tol=None``
    the final state is bit-identical to :func:`run_bsp` after
    ``num_steps`` supersteps for min/max-semiring apps (converged
    supersteps are state fixpoints) and the actives are the stepwise
    prefix (the stepwise tail is all zeros).
    """
    runner = make_fused_runner(superstep, static, mesh=mesh,
                               check_rep=check_rep, chunk=chunk, tol=tol)
    return runner(state, num_steps)
