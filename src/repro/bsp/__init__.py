"""BSP distributed graph engine (JAX): the runtime the partitions feed.

The paper's machines run Plato-style BSP supersteps; here each *machine* is
a mesh device (or a vmap lane in single-device simulation).  Cross-machine
vertex synchronization is a fixed-shape collective over the replicated-
vertex table — TPU-native, and its size shrinks with partition quality.
"""
from .partition_runtime import PartitionRuntime, LocalBSR
from .stream_assignment import StreamAssignment, write_json_atomic
from .backends import (BACKENDS, MESSAGE_DTYPES, EdgeBackend, get_backend,
                       frontier_entries)
from .engine import make_fused_runner, run_bsp, run_bsp_fused
from .apps import (pagerank, sssp, bfs, triangle_count,
                   connected_components, build_app, AppSpec, APP_BUILDERS,
                   RunOptions, MONOTONE_APPS)
from . import ref
from .simulate import simulate_superstep_times, simulate_runtime

__all__ = ["PartitionRuntime", "LocalBSR", "StreamAssignment",
           "write_json_atomic",
           "BACKENDS", "MESSAGE_DTYPES", "EdgeBackend", "get_backend",
           "frontier_entries", "make_fused_runner", "run_bsp",
           "run_bsp_fused",
           "pagerank", "sssp", "bfs", "triangle_count",
           "connected_components", "build_app", "AppSpec", "APP_BUILDERS",
           "RunOptions", "MONOTONE_APPS",
           "ref", "simulate_superstep_times", "simulate_runtime"]
