"""Version compatibility for the jax API surface this repo touches.

jaxlib 0.4.37 (the container's pin) predates several now-top-level APIs:

* ``jax.shard_map``            -> ``jax.experimental.shard_map.shard_map``
  (and the new ``check_vma=`` kwarg is the old ``check_rep=``);
* ``jax.sharding.AbstractMesh(shape, axis_names)`` -> the 0.4.x ctor takes a
  single ``((name, size), ...)`` shape tuple;
* ``CompiledMemoryStats.peak_memory_in_bytes`` -> absent; the peak is
  reconstructed from the per-category sizes.

Import from here instead of sniffing versions at call sites.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "abstract_mesh", "make_mesh", "peak_memory_bytes"]


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with a fallback for jaxlibs that predate it."""
    if hasattr(jax, "make_mesh"):
        if devices is not None:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                                 devices=devices)
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    import math

    import numpy as np
    devs = list(devices if devices is not None else jax.devices())
    n = math.prod(axis_shapes)
    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape(tuple(axis_shapes)),
        tuple(axis_names))


if hasattr(jax, "shard_map"):                       # jax >= 0.6
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs, **kw):
        """Old-namespace shard_map; translates ``check_vma`` -> ``check_rep``."""
        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)


def abstract_mesh(axis_shapes, axis_names):
    """``AbstractMesh`` across the 0.4 -> 0.5 constructor change."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_shapes),
                                         tuple(axis_names))
    except TypeError:  # 0.4.x: single ((name, size), ...) tuple
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_shapes)))


def peak_memory_bytes(mem) -> int:
    """Per-device peak from ``compiled.memory_analysis()``, any jax version.

    Newer jaxlibs expose ``peak_memory_in_bytes`` directly; 0.4.x only
    reports per-category sizes, whose sum upper-bounds the true live peak
    (arguments + outputs + temps + generated code are all resident at the
    end of the step on TPU's arena allocator).
    """
    direct = getattr(mem, "peak_memory_in_bytes", None)
    if direct is not None:
        return int(direct)
    total = 0
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        total += int(getattr(mem, attr, 0) or 0)
    alias = int(getattr(mem, "alias_size_in_bytes", 0) or 0)
    return max(0, total - alias)
