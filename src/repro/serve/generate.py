"""Batched serving: prefill + greedy/temperature decode loop."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..models import decode_step, init_cache


def make_serve_step(cfg):
    """Jitted single-token decode step (the dry-run's serve entry)."""
    @jax.jit
    def step(params, cache, tokens, cache_len):
        return decode_step(cfg, params, cache, tokens, cache_len)
    return step


def generate(cfg, params, prompts, max_new_tokens: int, *,
             temperature: float = 0.0, key=None, max_len: int | None = None):
    """prompts: (B, P) token ids (or (B, P, d) embeddings for stub archs).

    Returns (B, max_new_tokens) sampled ids.  Greedy when temperature=0.
    """
    B, P = prompts.shape[0], prompts.shape[1]
    max_len = max_len or (P + max_new_tokens + 1)
    cache = init_cache(cfg, B, max_len)
    step = make_serve_step(cfg)
    logits, cache = step(params, cache, prompts, jnp.zeros(B, jnp.int32))
    lens = jnp.full((B,), P, jnp.int32)
    out = []
    key = key if key is not None else jax.random.PRNGKey(0)
    last = logits[:, -1]
    for t in range(max_new_tokens):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, last / temperature, axis=-1)
        else:
            tok = jnp.argmax(last, axis=-1)
        out.append(tok)
        if cfg.input_mode == "tokens":
            nxt = tok[:, None]
        else:  # embedding-stub archs feed the embedded token back
            nxt = jax.nn.one_hot(tok, cfg.d_model)[:, None, :]
        logits, cache = step(params, cache, nxt, lens)
        last = logits[:, 0]
        lens = lens + 1
    return jnp.stack(out, axis=1)
