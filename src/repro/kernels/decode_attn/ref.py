"""Pure-jnp oracle for flash-decode attention."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, bias):
    """q: (KVH, G, dh); k,v: (S, KVH, dh); bias: (S,) -> (KVH, G, dh)."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("hgd,shd->hgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = scores + bias[None, None, :].astype(jnp.float32)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    out = jnp.einsum("hgs,shd->hgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
