"""Batched jit wrapper for flash-decode attention (auto interpret off-TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import decode_attn_pallas


def decode_attention(q, k, v, lengths=None, *, block_s: int = 256,
                     interpret: bool | None = None):
    """Batched GQA decode attention.

    q: (B, H, dh); k, v: (B, S, KVH, dh); lengths: (B,) valid KV prefix.
    Returns (B, H, dh).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, dh = q.shape
    S, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, dh)
    if lengths is None:
        bias = jnp.zeros((B, S), dtype=jnp.float32)
    else:
        bias = jnp.where(jnp.arange(S)[None, :] < lengths[:, None],
                         0.0, -1e30).astype(jnp.float32)

    def one(qb, kb, vb, bb):
        return decode_attn_pallas(qb, kb, vb, bb, block_s=block_s,
                                  interpret=interpret)

    out = jax.vmap(one)(qg, k, v, bias)        # (B, KVH, G, dh)
    return out.reshape(B, H, dh)
