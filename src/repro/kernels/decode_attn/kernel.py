"""Flash-decode attention Pallas TPU kernel (GQA, one query token).

One new token attends over an (S, KVH, dh) KV cache.  The KV sequence is
streamed through VMEM in blocks with the online-softmax recurrence kept in
VMEM scratch (m, l, acc) across sequential grid steps — the TPU analogue of
GPU flash-decode's split-K + shared-memory reduction, without the
cross-block atomic: TPU grid order is sequential, so the accumulator simply
lives in scratch.

Layouts (per batch element; callers vmap over batch):
  q:    (KVH, G, dh)   — query heads grouped under their KV head
  k, v: (S, KVH, dh)
  bias: (S,)           — additive mask (0 valid, -inf padded)
Grid = (KVH, S/BS), KV-block index innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_scr, l_scr, acc_scr):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _reset():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (G, dh)
    k = k_ref[:, 0, :].astype(jnp.float32)      # (BS, dh)
    v = v_ref[:, 0, :].astype(jnp.float32)      # (BS, dh)
    bias = bias_ref[...].astype(jnp.float32)    # (BS,)
    scale = 1.0 / (q.shape[-1] ** 0.5)

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = scores + bias[None, :]             # (G, BS)

    m_prev, l_prev = m_scr[...], l_scr[...]     # (G, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    p = jnp.exp(scores - m_new)                 # (G, BS)
    alpha = jnp.exp(m_prev - m_new)             # (G, 1)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc_new = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new
    o_ref[0] = (acc_new / jnp.maximum(l_new, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attn_pallas(q, k, v, bias, *, block_s: int = 256,
                       interpret: bool = True):
    KVH, G, dh = q.shape
    S = k.shape[0]
    assert S % block_s == 0, (S, block_s)
    grid = (KVH, S // block_s)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, dh), lambda h, s: (h, 0, 0)),
            pl.BlockSpec((block_s, 1, dh), lambda h, s: (s, h, 0)),
            pl.BlockSpec((block_s, 1, dh), lambda h, s: (s, h, 0)),
            pl.BlockSpec((block_s,), lambda h, s: (s,)),
        ],
        out_specs=pl.BlockSpec((1, G, dh), lambda h, s: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((KVH, G, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, bias)
