"""Mamba-2 SSD (state-space duality) chunk-scan Pallas TPU kernel.

Computes the scalar-decay SSM

    h_t = exp(a_t)·h_{t-1} + B_t x_tᵀ          h: (ds, dh) per head
    y_t = C_tᵀ h_t

via the SSD block decomposition (arXiv:2405.21060): the sequence is tiled
into chunks of length L; within a chunk the quadratic "attention-like" form
rides the MXU, while the inter-chunk recurrence is a rank-preserving state
pass carried in a VMEM scratch accumulator across sequential grid steps —
the TPU-native replacement for the paper's warp-level GPU scan.

Grid = (BH, T/L), chunk index innermost (sequential on TPU), so the state
scratch is private per (b, h) lane and flows chunk to chunk.

    intra:  Y += ((C·Bᵀ) ⊙ M) X          M_ts = exp(cum_t − cum_s)·[t ≥ s]
    inter:  Y += exp(cum_t)·(C h_in)
    state:  h_out = exp(cum_L)·h_in + Σ_s exp(cum_L − cum_s) B_s x_sᵀ
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, b_ref, c_ref, a_ref, y_ref, h_scr):
    ch = pl.program_id(1)

    @pl.when(ch == 0)
    def _reset():
        h_scr[...] = jnp.zeros_like(h_scr)

    xl = x_ref[0].astype(jnp.float32)          # (L, dh)
    bl = b_ref[0].astype(jnp.float32)          # (L, ds)
    cl = c_ref[0].astype(jnp.float32)          # (L, ds)
    al = a_ref[0].astype(jnp.float32)          # (L,)
    L = xl.shape[0]
    cum = jnp.cumsum(al)                        # (L,)

    # intra-chunk quadratic form (MXU):
    seg = cum[:, None] - cum[None, :]
    tri = jnp.tril(jnp.ones((L, L), dtype=jnp.bool_))
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)), 0.0)
    scores = jnp.dot(cl, bl.T, preferred_element_type=jnp.float32) * decay
    y = jnp.dot(scores, xl, preferred_element_type=jnp.float32)

    # inter-chunk carry-in:
    h = h_scr[...]                              # (ds, dh)
    y += jnp.exp(cum)[:, None] * jnp.dot(
        cl, h, preferred_element_type=jnp.float32)

    # state update for the next chunk:
    w = jnp.exp(cum[-1] - cum)                  # (L,)
    h_scr[...] = (jnp.exp(cum[-1]) * h
                  + jnp.dot((w[:, None] * bl).T, xl,
                            preferred_element_type=jnp.float32))
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, b, c, a, *, chunk: int = 128, interpret: bool = True):
    """x: (BH, T, dh), b/c: (BH, T, ds), a: (BH, T) log-decay (<= 0)."""
    BH, T, dh = x.shape
    ds = b.shape[-1]
    assert T % chunk == 0, (T, chunk)
    grid = (BH, T // chunk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda bh, ch: (bh, ch, 0)),
            pl.BlockSpec((1, chunk, ds), lambda bh, ch: (bh, ch, 0)),
            pl.BlockSpec((1, chunk, ds), lambda bh, ch: (bh, ch, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ch: (bh, ch)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dh), lambda bh, ch: (bh, ch, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((ds, dh), jnp.float32)],
        interpret=interpret,
    )(x, b, c, a)
