"""Pure-jnp oracle: the naive per-step SSD recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, b, c, a):
    """x: (BH, T, dh), b/c: (BH, T, ds), a: (BH, T) log-decay.

    Returns y: (BH, T, dh) from the exact sequential recurrence."""
    ds, dh = b.shape[-1], x.shape[-1]

    def one(xh, bh, ch, ah):
        def step(h, inp):
            xt, bt, ct, at = inp
            h = jnp.exp(at) * h + bt[:, None] * xt[None, :]
            return h, jnp.dot(ct, h)
        h0 = jnp.zeros((ds, dh), dtype=jnp.float32)
        _, y = jax.lax.scan(step, h0, (xh.astype(jnp.float32),
                                       bh.astype(jnp.float32),
                                       ch.astype(jnp.float32),
                                       ah.astype(jnp.float32)))
        return y.astype(xh.dtype)

    return jax.vmap(one)(x, b, c, a)
