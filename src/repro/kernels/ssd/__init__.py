from .ops import ssd_chunked
from .ref import ssd_ref

__all__ = ["ssd_chunked", "ssd_ref"]
