"""Jit wrapper for the SSD chunk-scan kernel (auto interpret off-TPU)."""
from __future__ import annotations

import jax

from .kernel import ssd_pallas


def ssd_chunked(x, b, c, a, *, chunk: int = 128,
                interpret: bool | None = None):
    """SSD scan; pads T to a chunk multiple internally if needed."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    BH, T, dh = x.shape
    pad = (-T) % chunk
    if pad:
        import jax.numpy as jnp
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad)))
    y = ssd_pallas(x, b, c, a, chunk=chunk, interpret=interpret)
    return y[:, :T]
