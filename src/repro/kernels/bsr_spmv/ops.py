"""Public ops around the Block-ELL SpMV kernel: layout builder + jit wrapper."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import spmv_pallas


@dataclasses.dataclass(frozen=True)
class BsrMatrix:
    """Symmetric adjacency (optionally weighted) in Block-ELL layout."""

    cols: np.ndarray     # (R, K) int32 block-column ids
    blocks: np.ndarray   # (R, K, bm, bm) float32 dense blocks
    n: int               # logical dimension (<= R*bm)
    block_size: int

    @property
    def shape(self):
        return (self.n, self.n)

    @property
    def padded(self):
        return self.cols.shape[0] * self.block_size

    @property
    def nnz_blocks(self) -> int:
        return int((np.abs(self.blocks).sum(axis=(2, 3)) > 0).sum())


def bsr_from_edges(edges: np.ndarray, n: int, values: np.ndarray | None = None,
                   block_size: int = 128, symmetric: bool = True) -> BsrMatrix:
    """Build a Block-ELL matrix from an (E, 2) edge list.

    A[u, v] += w (and A[v, u] += w when symmetric).  Zero-padding blocks
    point at block-column 0 (their contribution is 0·x ≡ 0).
    """
    bm = block_size
    R = max(1, -(-n // bm))
    e = np.asarray(edges, dtype=np.int64)
    w = np.ones(len(e), dtype=np.float32) if values is None else values
    if symmetric:
        e = np.concatenate([e, e[:, ::-1]], axis=0)
        w = np.concatenate([w, w])
    bi, bj = e[:, 0] // bm, e[:, 1] // bm
    # group by (block-row, block-col)
    key = bi * R + bj
    order = np.argsort(key, kind="stable")
    e, w, bi, bj, key = e[order], w[order], bi[order], bj[order], key[order]
    uniq, start = np.unique(key, return_index=True)
    counts_per_row = np.bincount((uniq // R).astype(np.int64), minlength=R)
    K = max(1, int(counts_per_row.max()))
    cols = np.zeros((R, K), dtype=np.int32)
    blocks = np.zeros((R, K, bm, bm), dtype=np.float32)
    slot = np.zeros(R, dtype=np.int64)
    bounds = np.append(start, len(e))
    for s, t in zip(bounds[:-1], bounds[1:]):
        r, c = int(bi[s]), int(bj[s])
        k = slot[r]
        cols[r, k] = c
        np.add.at(blocks[r, k], (e[s:t, 0] % bm, e[s:t, 1] % bm), w[s:t])
        slot[r] += 1
    return BsrMatrix(cols=cols, blocks=blocks, n=n, block_size=bm)


def bsr_spmv(m: BsrMatrix, x: jnp.ndarray, *,
             interpret: bool | None = None) -> jnp.ndarray:
    """y = A @ x.  x: (n,) -> y: (n,).

    interpret=None auto-selects: Pallas interpreter on CPU (validation),
    compiled kernel on TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    xp = jnp.zeros(m.padded, dtype=jnp.float32).at[:m.n].set(x.astype(jnp.float32))
    y = spmv_pallas(jnp.asarray(m.cols), jnp.asarray(m.blocks), xp,
                    block_size=m.block_size, interpret=interpret)
    return y[:m.n].astype(x.dtype)
