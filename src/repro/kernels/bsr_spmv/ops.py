"""Public ops around the Block-ELL semiring SpMV kernel: layout + wrapper."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import spmv_pallas
from .semiring import Semiring, get_semiring


@dataclasses.dataclass(frozen=True)
class BsrMatrix:
    """Symmetric adjacency (optionally weighted) in Block-ELL layout.

    Missing entries hold ``semiring.absent`` (0 for (+,×)/(or,and), +inf
    for (min,+)), so zero-padding blocks and ELL fill slots contribute the
    ⊕ identity to every product.
    """

    cols: np.ndarray     # (R, K) int32 block-column ids
    blocks: np.ndarray   # (R, K, bm, bm) float32 dense blocks
    n: int               # logical dimension (<= R*bm)
    block_size: int
    semiring: str = "plus_times"

    @property
    def shape(self):
        return (self.n, self.n)

    @property
    def padded(self):
        return self.cols.shape[0] * self.block_size

    @property
    def nnz(self) -> int:
        """Stored (present) entries — parallel edges collapse to one."""
        absent = get_semiring(self.semiring).absent
        return int((self.blocks != absent).sum())

    @property
    def nnz_blocks(self) -> int:
        absent = get_semiring(self.semiring).absent
        return int((self.blocks != absent).any(axis=(2, 3)).sum())

    def fill_stats(self) -> dict:
        """ELL padding/fill accounting (the MXU-utilization proxy).

        * ``block_fill`` — fraction of the (R, K) ELL slots holding a
          nonzero block (1 − block_fill is pure padding work);
        * ``entry_fill`` — fraction of stored dense cells that are real
          entries (how dense the nonzero blocks are);
        * ``pad_frac``   — fraction of the padded dimension beyond ``n``.
        """
        R, K = self.cols.shape
        bm = self.block_size
        nb = self.nnz_blocks
        return {
            "rows": R, "ell_k": K, "block_size": bm,
            "nnz": self.nnz, "nnz_blocks": nb,
            "block_fill": nb / max(1, R * K),
            "entry_fill": self.nnz / max(1, nb * bm * bm),
            "pad_frac": (self.padded - self.n) / max(1, self.padded),
        }


def bsr_from_edges(edges: np.ndarray, n: int, values: np.ndarray | None = None,
                   block_size: int = 128, symmetric: bool = True,
                   semiring: str | Semiring = "plus_times") -> BsrMatrix:
    """Build a Block-ELL matrix from an (E, 2) edge list.

    ``A[u, v] ⊕= w`` (and ``A[v, u] ⊕= w`` when symmetric) under the
    semiring's ⊕ — parallel edges sum for (+,×), take the lightest weight
    for (min,+), and collapse to presence for (or,and).  Missing entries
    hold ``semiring.absent``; padding blocks point at block-column 0
    (their contribution is the ⊕ identity by the annihilator property).
    """
    sr = get_semiring(semiring)
    bm = block_size
    R = max(1, -(-n // bm))
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    w = np.ones(len(e), dtype=np.float32) if values is None \
        else np.asarray(values, dtype=np.float32)
    if symmetric and len(e):
        e = np.concatenate([e, e[:, ::-1]], axis=0)
        w = np.concatenate([w, w])
    if not len(e):
        return BsrMatrix(
            cols=np.zeros((R, 1), dtype=np.int32),
            blocks=np.full((R, 1, bm, bm), sr.absent, dtype=np.float32),
            n=n, block_size=bm, semiring=sr.name)
    bi, bj = e[:, 0] // bm, e[:, 1] // bm
    # group by (block-row, block-col)
    key = bi * R + bj
    order = np.argsort(key, kind="stable")
    e, w, bi, bj, key = e[order], w[order], bi[order], bj[order], key[order]
    uniq, start = np.unique(key, return_index=True)
    counts_per_row = np.bincount((uniq // R).astype(np.int64), minlength=R)
    K = max(1, int(counts_per_row.max()))
    cols = np.zeros((R, K), dtype=np.int32)
    blocks = np.full((R, K, bm, bm), sr.absent, dtype=np.float32)
    slot = np.zeros(R, dtype=np.int64)
    bounds = np.append(start, len(e))
    for s, t in zip(bounds[:-1], bounds[1:]):
        r, c = int(bi[s]), int(bj[s])
        k = slot[r]
        cols[r, k] = c
        sr.np_accum_at(blocks[r, k], (e[s:t, 0] % bm, e[s:t, 1] % bm), w[s:t])
        slot[r] += 1
    return BsrMatrix(cols=cols, blocks=blocks, n=n, block_size=bm,
                     semiring=sr.name)


def bsr_spmv(m: BsrMatrix, x: jnp.ndarray, *,
             interpret: bool | None = None) -> jnp.ndarray:
    """y = A ⊕.⊗ x under the matrix's semiring.  x: (n,) -> y: (n,).

    interpret=None auto-selects: Pallas interpreter on CPU (validation),
    compiled kernel on TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    xp = jnp.zeros(m.padded, dtype=jnp.float32).at[:m.n].set(x.astype(jnp.float32))
    y = spmv_pallas(jnp.asarray(m.cols), jnp.asarray(m.blocks), xp,
                    block_size=m.block_size, interpret=interpret,
                    semiring=m.semiring)
    return y[:m.n].astype(x.dtype)
