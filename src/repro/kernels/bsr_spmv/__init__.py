from .ops import bsr_from_edges, bsr_spmv, BsrMatrix
from .ref import bsr_spmv_ref, dense_from_bsr

__all__ = ["bsr_from_edges", "bsr_spmv", "BsrMatrix",
           "bsr_spmv_ref", "dense_from_bsr"]
