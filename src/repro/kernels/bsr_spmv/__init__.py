from .ops import bsr_from_edges, bsr_spmv, BsrMatrix
from .ref import bsr_spmv_ref, dense_from_bsr, dense_semiring_mv
from .semiring import (Semiring, SEMIRINGS, get_semiring,
                       PLUS_TIMES, MIN_PLUS, OR_AND)
from .kernel import spmv_pallas

__all__ = ["bsr_from_edges", "bsr_spmv", "BsrMatrix",
           "bsr_spmv_ref", "dense_from_bsr", "dense_semiring_mv",
           "Semiring", "SEMIRINGS", "get_semiring",
           "PLUS_TIMES", "MIN_PLUS", "OR_AND", "spmv_pallas"]
