"""Semirings for the edge-kernel layer: (⊕, ⊗) pairs the SpMV runs over.

An edge-centric BSP superstep is a sparse matrix–vector product over a
semiring: PageRank accumulates weighted messages ((+, ×)), SSSP relaxes
tentative distances ((min, +)), BFS propagates frontier membership
((or, and)).  Writing the superstep against a semiring object lets one
kernel (scatter, sorted-segment, or the blocked Pallas SpMV) serve every
app — the partition quality the paper optimizes then meets the same
hardware-shaped compute path regardless of algorithm.

Boolean semirings run in float32 0/1 (TPU-friendly, one dtype path):
``or`` is ``max`` and ``and`` is ``×`` on {0, 1}.

Each semiring fixes three scalars the layouts and kernels share:

* ``zero``    — the ⊕ identity: reduction init, and the value of an
  empty row;
* ``absent``  — the value stored for a *missing* matrix entry.  It is
  the ⊗ annihilator (``absent ⊗ x = zero`` for every finite ``x``), so
  zero-padding blocks and ELL fill slots contribute the identity;
* ``times``/``plus`` — the jnp elementwise ⊗ and the reduction ⊕.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Semiring:
    name: str
    zero: float                 # ⊕ identity (reduction init / empty row)
    absent: float               # stored value of a missing entry
    times: Callable             # jnp elementwise ⊗ (weight, operand)
    plus: Callable              # jnp pairwise ⊕ (accumulate)
    plus_reduce: Callable       # jnp ⊕-reduction over an axis
    #: in-place numpy ⊕-accumulation (``np.add.at``-style) — how parallel
    #: edges landing in the same matrix cell combine at layout-build time
    np_accum_at: Callable

    def scatter_accum(self, arr: jnp.ndarray, idx: jnp.ndarray,
                      vals: jnp.ndarray) -> jnp.ndarray:
        """jnp ``arr.at[idx].⊕(vals)`` for this semiring's ⊕."""
        if self.name == "plus_times":
            return arr.at[idx].add(vals)
        if self.name == "min_plus":
            return arr.at[idx].min(vals)
        return arr.at[idx].max(vals)        # or_and

    def weights(self, weight: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
        """Effective per-edge ⊗ operand: ``weight`` where valid, else the
        annihilator (padding edges contribute the ⊕ identity)."""
        return jnp.where(valid, weight, self.absent)


PLUS_TIMES = Semiring(
    name="plus_times", zero=0.0, absent=0.0,
    times=lambda a, x: a * x,
    plus=lambda a, b: a + b,
    plus_reduce=lambda a, axis: jnp.sum(a, axis=axis),
    np_accum_at=np.add.at)

MIN_PLUS = Semiring(
    name="min_plus", zero=np.inf, absent=np.inf,
    times=lambda a, x: a + x,
    plus=jnp.minimum,
    plus_reduce=lambda a, axis: jnp.min(a, axis=axis),
    np_accum_at=np.minimum.at)

#: boolean (or, and) in float 0/1: and = ×, or = max
OR_AND = Semiring(
    name="or_and", zero=0.0, absent=0.0,
    times=lambda a, x: a * x,
    plus=jnp.maximum,
    plus_reduce=lambda a, axis: jnp.max(a, axis=axis),
    np_accum_at=np.maximum.at)

SEMIRINGS = {s.name: s for s in (PLUS_TIMES, MIN_PLUS, OR_AND)}


def get_semiring(s) -> Semiring:
    """Resolve a semiring by name (or pass a ``Semiring`` through)."""
    if isinstance(s, Semiring):
        return s
    try:
        return SEMIRINGS[s]
    except KeyError:
        raise ValueError(f"unknown semiring {s!r} "
                         f"(choices: {sorted(SEMIRINGS)})") from None
