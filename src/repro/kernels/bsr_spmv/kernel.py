"""Block-ELL semiring SpMV Pallas TPU kernel.

TPU adaptation of the CPU/GPU CSR gather-scatter SpMV the paper's BSP
runtime hot loop uses: the adjacency matrix is tiled into dense 128×128
blocks (MXU-aligned); each block-row holds a fixed number K of nonzero
blocks (ELL padding).  Block column ids are *scalar-prefetched* so the
x-operand BlockSpec index_map can stream exactly the needed x blocks
HBM→VMEM; each grid step combines one dense (bm×bm) block with one (bm,)
x block and ⊕-accumulates into the y block.

The combine is semiring-parametric (``repro.kernels.bsr_spmv.semiring``):

* ``plus_times`` — ``y += A·x``, one MXU multiply per grid step
  (arithmetic intensity bm/6 FLOP/byte instead of the <1 of scalar
  gather-scatter);
* ``min_plus``   — ``y = min(y, min_j(A + x))``, the SSSP relaxation
  (VPU broadcast + row-min; absent entries hold +inf);
* ``or_and``     — ``y = max(y, max_j(A·x))`` over {0,1} floats, the
  BFS frontier expansion.

Layouts:
  cols:   (R, K)  int32    scalar-prefetch operand (SMEM)
  blocks: (R, K, bm, bm)   dense nonzero blocks (``semiring.absent``-padded)
  x:      (C*bm,)          input vector, padded to block multiple
  y:      (R*bm,)          output

Grid = (R, K); K is the inner (fastest) dimension so the y block for row r
is revisited across k — the standard Pallas output-reduction pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .semiring import get_semiring


def _make_kernel(semiring: str):
    def kernel(cols_ref, block_ref, x_ref, y_ref):
        k = pl.program_id(1)

        @pl.when(k == 0)
        def _init():
            if semiring == "plus_times":
                y_ref[...] = jnp.zeros_like(y_ref)
            elif semiring == "min_plus":
                y_ref[...] = jnp.full_like(y_ref, jnp.inf)
            else:                                   # or_and: 0/1 floats
                y_ref[...] = jnp.zeros_like(y_ref)

        a = block_ref[0, 0]                       # (bm, bm)
        x = x_ref[...]                            # (bm,)
        if semiring == "plus_times":
            y_ref[...] += jnp.dot(a, x, preferred_element_type=y_ref.dtype)
        elif semiring == "min_plus":
            y_ref[...] = jnp.minimum(y_ref[...],
                                     jnp.min(a + x[None, :], axis=1))
        else:                                     # or_and
            y_ref[...] = jnp.maximum(y_ref[...],
                                     jnp.max(a * x[None, :], axis=1))
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("block_size", "interpret", "semiring"))
def spmv_pallas(cols: jnp.ndarray, blocks: jnp.ndarray, x: jnp.ndarray,
                *, block_size: int = 128, interpret: bool = True,
                semiring: str = "plus_times"):
    sr = get_semiring(semiring)
    R, K = cols.shape
    bm = block_size
    assert blocks.shape == (R, K, bm, bm), blocks.shape
    grid = (R, K)
    return pl.pallas_call(
        _make_kernel(sr.name),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bm, bm),
                             lambda r, k, cols: (r, k, 0, 0)),
                pl.BlockSpec((bm,), lambda r, k, cols: (cols[r, k],)),
            ],
            out_specs=pl.BlockSpec((bm,), lambda r, k, cols: (r,)),
        ),
        out_shape=jax.ShapeDtypeStruct((R * bm,), x.dtype),
        interpret=interpret,
    )(cols, blocks, x)
