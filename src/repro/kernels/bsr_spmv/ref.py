"""Pure-jnp/numpy oracles for the Block-ELL semiring SpMV kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .ops import BsrMatrix
from .semiring import get_semiring


def dense_from_bsr(m: BsrMatrix) -> np.ndarray:
    """Materialize the dense matrix (missing entries = ``absent``)."""
    sr = get_semiring(m.semiring)
    bm = m.block_size
    R, K = m.cols.shape
    out = np.full((m.padded, m.padded), sr.absent, dtype=np.float32)
    for r in range(R):
        for k in range(K):
            c = int(m.cols[r, k])
            blk = m.blocks[r, k]
            tgt = out[r * bm:(r + 1) * bm, c * bm:(c + 1) * bm]
            if sr.name == "plus_times":
                # absent == 0 and padding blocks are all-zero, so plain
                # accumulation reproduces the historical behaviour
                tgt += blk
            elif sr.name == "min_plus":
                np.minimum(tgt, blk, out=tgt)
            else:                                   # or_and
                np.maximum(tgt, blk, out=tgt)
    return out[:m.n, :m.n]


def dense_semiring_mv(dense: np.ndarray, x: np.ndarray,
                      semiring: str) -> np.ndarray:
    """y_i = ⊕_j (A_ij ⊗ x_j) on a dense numpy matrix — the ground truth
    the kernel/ref/backends all triangulate against."""
    sr = get_semiring(semiring)
    if sr.name == "plus_times":
        return dense @ x
    if sr.name == "min_plus":
        return (dense + x[None, :]).min(axis=1)
    return (dense * x[None, :]).max(axis=1)         # or_and


def bsr_spmv_ref(m: BsrMatrix, x: jnp.ndarray) -> jnp.ndarray:
    """y = A ⊕.⊗ x without Pallas: per-block combine + ⊕-reduction."""
    sr = get_semiring(m.semiring)
    bm = m.block_size
    R, K = m.cols.shape
    xp = jnp.zeros(m.padded, dtype=jnp.float32).at[:m.n].set(
        x.astype(jnp.float32))
    xb = xp.reshape(-1, bm)                        # (C, bm)
    gathered = xb[jnp.asarray(m.cols)]             # (R, K, bm)
    blocks = jnp.asarray(m.blocks)                 # (R, K, bm, bm)
    if sr.name == "plus_times":
        y = jnp.einsum("rkij,rkj->ri", blocks, gathered)
    else:
        comb = sr.times(blocks, gathered[:, :, None, :])   # (R, K, bm, bm)
        y = sr.plus_reduce(sr.plus_reduce(comb, 3), 1)     # (R, bm)
    return y.reshape(-1)[:m.n].astype(x.dtype)
