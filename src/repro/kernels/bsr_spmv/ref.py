"""Pure-jnp oracle for the Block-ELL SpMV kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .ops import BsrMatrix


def dense_from_bsr(m: BsrMatrix) -> np.ndarray:
    bm = m.block_size
    R, K = m.cols.shape
    out = np.zeros((m.padded, m.padded), dtype=np.float32)
    for r in range(R):
        for k in range(K):
            c = int(m.cols[r, k])
            out[r * bm:(r + 1) * bm, c * bm:(c + 1) * bm] += m.blocks[r, k]
    return out[:m.n, :m.n]


def bsr_spmv_ref(m: BsrMatrix, x: jnp.ndarray) -> jnp.ndarray:
    """y = A @ x without Pallas: per-block einsum + scatter-add."""
    bm = m.block_size
    R, K = m.cols.shape
    xp = jnp.zeros(m.padded, dtype=jnp.float32).at[:m.n].set(
        x.astype(jnp.float32))
    xb = xp.reshape(-1, bm)                        # (C, bm)
    gathered = xb[jnp.asarray(m.cols)]             # (R, K, bm)
    y = jnp.einsum("rkij,rkj->ri", jnp.asarray(m.blocks), gathered)
    return y.reshape(-1)[:m.n].astype(x.dtype)
