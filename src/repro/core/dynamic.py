"""Dynamic-graph incremental repartitioning (the live-traffic layer).

WindGP computes a partition once; this module keeps it healthy while the
graph evolves.  A :class:`DynamicPartitioner` wraps the shared incremental
accounting (``PartitionState`` over a :class:`~repro.core.graph.
GrowableGraph`) and accepts an edge insert/delete stream against live
state:

* **inserts** are scored by the existing block-stream wave engine
  (``core/baselines/streaming.py``) against the live ``(p, V)``
  membership — the same scorer that would have placed them in a cold
  stream, so a quiet timeline converges to the static streaming
  partition;
* **deletes** route through ``PartitionState.remove_edges`` (exact
  Eq. 3/4 rollback); deleted edges keep their canonical id, so a later
  re-insert of the same pair reuses it and every downstream id-keyed
  structure stays valid;
* a **drift monitor** in the SDP tradition (arXiv 2110.15669) watches
  two health signals after every batch — balance skew
  ``max(T_i)/mean(T_i)`` and the replication factor — and when either
  crosses its threshold triggers a *bounded* repair: SLS destroy–repair
  waves (``sls.repair_edges``, arXiv 2012.09451) scoped to the edges of
  **overloaded machines incident to the touched frontier** (the vertices
  mutated since the last repair), never the whole graph.

Epoch deltas close the loop to the BSP side: :meth:`DynamicPartitioner.
snapshot` captures the assignment, :meth:`delta_since` diffs live state
against a snapshot into an :class:`AssignmentDelta` — add/remove
coalesced per edge, exactly what ``StreamAssignment.apply_delta``
(append + tombstone shard segments) and ``PartitionRuntime.apply_delta``
(in-place repack of the touched machines) consume.

Timeline replay, latency percentiles, and TC-vs-scratch drift live in
``benchmarks/dynamic_replay.py`` (tier-2 CI job ``dynamic``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .capacity import _mem_cap
from .graph import GrowableGraph
from .machines import Cluster
from .partition_state import PartitionState
from .baselines.streaming import ENGINE_DEFAULTS, SCORERS, _BlockEngine
from .sls import repair_edges


def _canonical(uv: np.ndarray) -> np.ndarray:
    """(k, 2) int64 canonical (u < v) pairs: loops dropped, batch-deduped
    keeping first occurrence, arrival order preserved."""
    uv = np.asarray(uv, dtype=np.int64).reshape(-1, 2)
    if (uv < 0).any():
        raise ValueError("negative vertex ids")
    u = np.minimum(uv[:, 0], uv[:, 1])
    v = np.maximum(uv[:, 0], uv[:, 1])
    keep = u != v
    u, v = u[keep], v[keep]
    key = (u << np.int64(32)) | v
    _, first = np.unique(key, return_index=True)
    first.sort()
    return np.stack([u[first], v[first]], axis=1)


@dataclasses.dataclass(frozen=True)
class AssignmentDelta:
    """Coalesced assignment diff between two epochs.

    ``added``/``added_ms``: edges live now but not at the snapshot (or
    live on a different machine), with their current machine.
    ``removed``/``removed_ms``: edges live at the snapshot but not now
    (or moved away), with the machine they left.  A moved edge appears in
    both — remove from the old shard, append to the new one.  An edge
    inserted *and* deleted within the epoch appears in neither: the diff
    is against assignments, not the operation log, so deltas
    auto-coalesce.
    """

    num_vertices: int
    added: np.ndarray        # (a, 2) int64 canonical endpoints
    added_ms: np.ndarray     # (a,)   int64 destination machines
    removed: np.ndarray      # (r, 2) int64 canonical endpoints
    removed_ms: np.ndarray   # (r,)   int64 source machines

    @property
    def num_changes(self) -> int:
        return len(self.added) + len(self.removed)

    def machines_touched(self, p: int) -> np.ndarray:
        """(p,) bool — machines whose edge set changed this epoch."""
        touched = np.zeros(p, dtype=bool)
        touched[self.added_ms] = True
        touched[self.removed_ms] = True
        return touched


@dataclasses.dataclass(frozen=True)
class RepairReport:
    """One bounded repair wave: what triggered it and what it did."""

    trigger: str             # "skew" | "rf" | "forced"
    edges_moved: int
    tc_before: float
    tc_after: float


class DynamicPartitioner:
    """Live partition maintenance over an edge insert/delete stream.

    Parameters:
      g, cluster:   the starting graph (wrapped in a ``GrowableGraph``)
                    and machine profile.
      assign:       (E,) starting assignment; ``None`` partitions the
                    seed graph from scratch with ``method``.
      method:       block-stream scorer for arriving edges (``greedy`` |
                    ``hdrf`` | ``ebv``) — engine knobs come from the
                    per-method ``ENGINE_DEFAULTS``.
      skew_limit:   repair when ``max(T_i)/mean(T_i)`` exceeds this.
      rf_limit:     repair when RF exceeds this; ``None`` (the default)
                    keeps an *adaptive leash*: ``rf_leash ×`` a running
                    RF baseline re-anchored to the measured RF after
                    every repair epoch, so long churn timelines keep
                    tripping on relative drift instead of outgrowing a
                    threshold frozen at construction.  A float pins the
                    limit absolutely (and assigning ``dp.rf_limit = x``
                    later does the same).
      rf_leash:     the adaptive leash's relative slack (default 1.15 —
                    repair when RF drifts 15% above the last-repair
                    baseline); ignored when ``rf_limit`` is pinned.
      repair_gamma: a machine is *overloaded* when its T is within the
                    top ``(1-gamma)`` fraction of the T spread
                    (``sls.destroy_repair``'s threshold).
      repair_theta: destroy at most this fraction of each overloaded
                    machine's frontier-incident edges per repair.
      repair_cap:   hard ceiling on edges destroyed per repair (the
                    *bounded* in bounded repair); ``None`` = 4096.
      auto_repair:  run the drift monitor after every batch (default);
                    ``False`` leaves :meth:`maybe_repair` to the caller —
                    the replay benchmark uses this to time assignment and
                    repair separately.
    """

    def __init__(self, g, cluster: Cluster,
                 assign: np.ndarray | None = None, *,
                 method: str = "hdrf", seed: int = 0,
                 skew_limit: float = 1.35, rf_limit: float | None = None,
                 rf_leash: float = 1.15,
                 repair_gamma: float = 0.75, repair_theta: float = 0.25,
                 repair_cap: int | None = None, auto_repair: bool = True,
                 **scorer_kw):
        if method not in SCORERS:
            raise ValueError(f"method must be one of {sorted(SCORERS)}, "
                             f"got {method!r}")
        self.g = GrowableGraph.from_graph(g)
        self.cluster = cluster
        if assign is None:
            from . import partitioners as registry
            assign = registry.get(method)(self.g, cluster,
                                          seed=seed, **scorer_kw)
        assign = np.asarray(assign, dtype=np.int32)
        if len(assign) != self.g.num_edges:
            raise ValueError(f"assign has {len(assign)} entries for "
                             f"{self.g.num_edges} edges")
        self.state = PartitionState.build(self.g, assign, cluster)
        self.method = method
        self.scorer = SCORERS[method](**scorer_kw)
        if hasattr(self.scorer, "reset"):
            self.scorer.reset(self.g.num_vertices)
            # seed-partition history: arriving edges should see the seed
            # stream's partial degrees, not a blank slate
            if hasattr(self.scorer, "_pdeg"):
                np.add.at(self.scorer._pdeg, self.g.edges.ravel(), 1)
        self.skew_limit = float(skew_limit)
        self.rf_leash = float(rf_leash)
        self._rf_anchor = max(1.0, self._rf())
        self._rf_override = None if rf_limit is None else float(rf_limit)
        self.repair_gamma = float(repair_gamma)
        self.repair_theta = float(repair_theta)
        self.repair_cap = 4096 if repair_cap is None else int(repair_cap)
        self.auto_repair = bool(auto_repair)
        self._touched = np.zeros(self.g.num_vertices, dtype=bool)
        self.repairs: list[RepairReport] = []
        self.counters = {"inserted": 0, "deleted": 0, "reinserted": 0,
                         "repair_moves": 0}

    # -- health views --------------------------------------------------------
    def _rf(self) -> float:
        r = self.state.replicas
        covered = r > 0
        return float(r[covered].sum() / max(1, covered.sum()))

    @property
    def tc(self) -> float:
        return self.state.tc

    @property
    def skew(self) -> float:
        t = self.state.t_total
        mean = t.mean()
        return float(t.max() / mean) if mean > 0 else 1.0

    @property
    def rf(self) -> float:
        return self._rf()

    @property
    def rf_limit(self) -> float:
        """The live RF repair threshold: the pinned override when one was
        given, else ``rf_leash ×`` the running baseline (the RF measured
        at construction, re-anchored after every repair epoch)."""
        if self._rf_override is not None:
            return self._rf_override
        return self.rf_leash * self._rf_anchor

    @rf_limit.setter
    def rf_limit(self, value: float | None) -> None:
        """Pin the threshold absolutely (``None`` returns to adaptive)."""
        self._rf_override = None if value is None else float(value)

    @property
    def num_live_edges(self) -> int:
        return int((self.state.assign >= 0).sum())

    def membership(self) -> np.ndarray:
        """(p, V) bool — the live vertex-membership matrix."""
        return self.state.cnt > 0

    # -- internal plumbing ---------------------------------------------------
    def _grow_frontier(self) -> None:
        nv = self.g.num_vertices
        if nv > len(self._touched):
            self._touched = np.concatenate(
                [self._touched, np.zeros(nv - len(self._touched),
                                         dtype=bool)])

    def _caps(self) -> np.ndarray:
        """Per-machine edge caps from *live* totals (not the retired-id
        universe — deleted edges must free capacity)."""
        live_v = int((self.state.replicas > 0).sum())
        live_e = max(1, self.num_live_edges)
        return np.floor(_mem_cap(self.cluster, max(1, live_v),
                                 live_e)).astype(np.int64)

    # -- the stream API ------------------------------------------------------
    def insert(self, uv: np.ndarray) -> int:
        """Insert a batch of (u, v) pairs; returns how many were placed.

        Pairs are canonicalized (loops dropped, batch-deduped); pairs
        already live are skipped (idempotent).  Previously-deleted pairs
        reuse their canonical id; genuinely-new pairs (and vertices) grow
        the universe via ``PartitionState.append_edges``.  The whole batch
        is placed by one fresh wave engine against live membership, then
        the drift monitor runs.
        """
        uv = _canonical(uv)
        if not len(uv):
            return 0
        eids = self.g.eids_of(uv[:, 0], uv[:, 1])
        known = eids >= 0
        live = np.zeros(len(uv), dtype=bool)
        live[known] = self.state.assign[eids[known]] >= 0
        fresh = ~known
        if fresh.any():
            eids = eids.copy()
            eids[fresh] = self.state.append_edges(uv[fresh])
        place = ~live
        if not place.any():
            return 0
        es = eids[place]
        u = uv[place, 0]
        v = uv[place, 1]
        self._grow_frontier()
        if hasattr(self.scorer, "grow"):
            self.scorer.grow(self.g.num_vertices)
        live_e = self.num_live_edges + len(es)
        dflt = ENGINE_DEFAULTS[self.method]
        eng = _BlockEngine(
            self.state, self.scorer, self._caps(), live_e,
            max(1, self.g.num_vertices), block_size=max(1, len(es)),
            max_waves=dflt["max_waves"],
            replica_frac=dflt["replica_frac"],
            creator_scalar=dflt["creator_scalar"])
        eng.push(u, v, es)
        eng.flush()
        if self.state._costs_stale:
            self.state.refresh_costs()
        self._touched[u] = True
        self._touched[v] = True
        self.counters["inserted"] += int(len(es))
        self.counters["reinserted"] += int((known & place).sum())
        if self.auto_repair:
            self.maybe_repair()
        return int(len(es))

    def delete(self, uv: np.ndarray, *, strict: bool = True) -> int:
        """Delete a batch of (u, v) pairs; returns how many were removed.

        Routes through ``PartitionState.remove_edges`` — the exact Eq. 3/4
        rollback.  Unknown or already-deleted pairs raise ``ValueError``
        under ``strict`` (the default: a deletion stream referencing edges
        we never held is corrupt), else they are skipped.
        """
        uv = _canonical(uv)
        if not len(uv):
            return 0
        eids = self.g.eids_of(uv[:, 0], uv[:, 1])
        live = np.zeros(len(uv), dtype=bool)
        live[eids >= 0] = self.state.assign[eids[eids >= 0]] >= 0
        if strict and not live.all():
            bad = uv[~live][:8]
            raise ValueError(f"delete: pairs not currently live: "
                             f"{bad.tolist()}")
        es = eids[live]
        if not len(es):
            return 0
        self.state.remove_edges(es)
        self._grow_frontier()
        self._touched[uv[live, 0]] = True
        self._touched[uv[live, 1]] = True
        self.counters["deleted"] += int(len(es))
        if self.auto_repair:
            self.maybe_repair()
        return int(len(es))

    # -- drift monitor + bounded repair --------------------------------------
    def drift(self) -> str | None:
        """The threshold currently violated (``"skew"`` | ``"rf"``), or
        None when the partition is healthy."""
        if self.skew > self.skew_limit:
            return "skew"
        if self._rf() > self.rf_limit:
            return "rf"
        return None

    def maybe_repair(self) -> RepairReport | None:
        trigger = self.drift()
        if trigger is None:
            return None
        return self.repair(trigger=trigger)

    def repair(self, trigger: str = "forced") -> RepairReport:
        """One bounded destroy–repair pass scoped to the touched frontier.

        Destroy set: edges on *overloaded* machines (T within the top
        ``1-gamma`` of the spread, ``sls.destroy_repair``'s rule) whose
        endpoint lies in the touched frontier — at most ``theta`` of each
        machine's candidates, at most ``repair_cap`` total.  Repair:
        ``sls.repair_edges`` vectorized waves over live state.  The
        frontier resets afterwards, so repair cost is charged to the
        mutations that accumulated it — this is what keeps amortized
        repair cost O(batch) instead of O(E).
        """
        tc_before = self.state.tc
        t = self.state.t_total
        thd = t.min() + self.repair_gamma * (t.max() - t.min())
        over = np.flatnonzero((t >= thd - 1e-12)
                              & (self.state.edges_per > 0))
        assign = self.state.assign
        edges = self.g.edges
        frontier = (self._touched[edges[:, 0]]
                    | self._touched[edges[:, 1]])
        moved = 0
        take_parts = []
        for i in over:
            cand = np.flatnonzero((assign == i) & frontier)
            if not len(cand):
                continue
            k = max(1, int(np.ceil(self.repair_theta * len(cand))))
            # prefer edges whose endpoints are replicated elsewhere —
            # moving them can shrink replica sets instead of growing them
            r = (self.state.replicas[edges[cand, 0]]
                 + self.state.replicas[edges[cand, 1]])
            take_parts.append(cand[np.argsort(-r, kind="stable")[:k]])
        if take_parts:
            sel = np.concatenate(take_parts)[:self.repair_cap]
            self.state.remove_edges(sel)
            repair_edges(self.state, sel,
                         [[] for _ in range(self.cluster.p)])
            moved = int(len(sel))
        self._touched[:] = False
        # re-anchor the adaptive RF leash to the post-repair baseline:
        # the next trigger fires on *new* drift, not on whatever floor
        # this repair could not recover below (a leash frozen at
        # construction either never trips on a long timeline or trips
        # every batch once the floor rises past it)
        self._rf_anchor = max(1.0, self._rf())
        report = RepairReport(trigger=trigger, edges_moved=moved,
                              tc_before=tc_before,
                              tc_after=self.state.tc)
        self.repairs.append(report)
        self.counters["repair_moves"] += moved
        return report

    # -- epoch deltas (the BSP hand-off) -------------------------------------
    def snapshot(self) -> dict:
        """Capture the current assignment for a later :meth:`delta_since`."""
        return {"assign": self.state.assign.copy(),
                "num_vertices": self.g.num_vertices}

    def delta_since(self, snap: dict) -> AssignmentDelta:
        """Diff live state against a snapshot into an `AssignmentDelta`.

        Ids only ever *grow* (deletion retires, never removes), so the
        snapshot assignment is a prefix of the live id space; appended ids
        diff against -1.
        """
        old = snap["assign"]
        new = self.state.assign
        if len(old) > len(new):
            raise ValueError("snapshot has more edge ids than live state")
        old_p = np.full(len(new), -1, dtype=old.dtype)
        old_p[:len(old)] = old
        changed = np.flatnonzero(old_p != new)
        edges = self.g.edges
        rem = changed[old_p[changed] >= 0]
        add = changed[new[changed] >= 0]
        return AssignmentDelta(
            num_vertices=self.g.num_vertices,
            added=edges[add].astype(np.int64),
            added_ms=new[add].astype(np.int64),
            removed=edges[rem].astype(np.int64),
            removed_ms=old_p[rem].astype(np.int64))
