"""Heterogeneous machine model, resource quantification, and the TC metric.

Implements Definition 4 of the paper: each machine is a quadruple
(M_i, C_i^node, C_i^edge, C_i^com); the partition quality metric is

    TC = max_i (T_i^cal + T_i^com)
    T_i^cal = C_i^node |V_i| + C_i^edge |E_i|
    T_i^com = sum_{v in V_i} sum_{j != i, v in V_j} (C_i^com + C_j^com)

plus the replication factor RF for homogeneous comparisons.
"""
from __future__ import annotations

import dataclasses
import math
from functools import reduce

import numpy as np


@dataclasses.dataclass(frozen=True)
class Machine:
    """One machine's quantified resources (paper Section 2.1)."""

    memory: float        # M_i, in M^node units
    c_node: float        # C_i^node
    c_edge: float        # C_i^edge
    c_com: float         # C_i^com

    def as_tuple(self):
        return (self.memory, self.c_node, self.c_edge, self.c_com)


@dataclasses.dataclass(frozen=True)
class Cluster:
    machines: tuple
    m_node: float = 1.0   # M^node
    m_edge: float = 2.0   # M^edge

    @property
    def p(self) -> int:
        return len(self.machines)

    def memory(self) -> np.ndarray:
        return np.array([m.memory for m in self.machines], dtype=np.float64)

    def c_node(self) -> np.ndarray:
        return np.array([m.c_node for m in self.machines], dtype=np.float64)

    def c_edge(self) -> np.ndarray:
        return np.array([m.c_edge for m in self.machines], dtype=np.float64)

    def c_com(self) -> np.ndarray:
        return np.array([m.c_com for m in self.machines], dtype=np.float64)


def paper_cluster(n_super: int, n_normal: int, *, large: bool = False) -> Cluster:
    """The paper's default machine template (Section 5.1).

    Large graphs: super=(1e8,10,15,15), normal=(3e7,5,10,10).
    Others:       super=(1e7,10,15,15), normal=(3e6,5,10,10).
    """
    sm = 1e8 if large else 1e7
    nm = 3e7 if large else 3e6
    machines = tuple([Machine(sm, 10, 15, 15)] * n_super
                     + [Machine(nm, 5, 10, 10)] * n_normal)
    return Cluster(machines=machines)


def scaled_paper_cluster(n_super: int, n_normal: int, num_edges: int,
                         slack: float = 3.0) -> Cluster:
    """Paper machine template with memory scaled to the given graph size.

    The paper's absolute memory numbers target 30M–1.2B-edge graphs; for
    laptop-scale graphs we keep the same super:normal memory ratio (10:3)
    and cost quadruples, scaling total memory to ``slack``× the minimum
    needed, so the memory constraint stays binding the same way.
    """
    total_units = (2.0 + 1.0) * num_edges * slack  # M^edge*E + M^node*~V
    # super:normal memory ratio 10:3.
    denom = 10 * n_super + 3 * n_normal
    sm = 10 * total_units / denom
    nm = 3 * total_units / denom
    machines = tuple([Machine(sm, 10, 15, 15)] * n_super
                     + [Machine(nm, 5, 10, 10)] * n_normal)
    return Cluster(machines=machines)


def quantify_machines(mem_gb, fp_time, fp_time_edge, co_time) -> Cluster:
    """Paper Section 2.1 'Quantification of Machine Resource'.

    mem_gb[i]:     memory in GB.
    fp_time[i]:    averaged float-mul benchmark time  -> C_i^node.
    fp_time_edge[i]: two-op benchmark time            -> C_i^edge.
    co_time[i]:    averaged 4KB send/recv time        -> C_i^com.
    """
    mem_gb = list(mem_gb)
    g_mem = reduce(math.gcd, [int(m) for m in mem_gb])
    g_fp = min(fp_time)
    machines = []
    for m, fn, fe, co in zip(mem_gb, fp_time, fp_time_edge, co_time):
        machines.append(Machine(
            memory=1e9 * m / (4 * g_mem),
            c_node=fn / g_fp,
            c_edge=fe / g_fp,
            c_com=co / (1024 * g_fp),
        ))
    return Cluster(machines=tuple(machines))


# ---------------------------------------------------------------------------
# Metrics over an edge partition.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PartitionStats:
    tc: float
    t_cal: np.ndarray          # (p,)
    t_com: np.ndarray          # (p,)
    edges_per_part: np.ndarray  # (p,)
    verts_per_part: np.ndarray  # (p,)
    rf: float
    alpha_balance: float       # max |E_i| / (|E|/p)
    feasible: bool             # memory constraint satisfied everywhere

    @property
    def t_total(self) -> np.ndarray:
        return self.t_cal + self.t_com


def vertex_partition_sets(graph, assign: np.ndarray, p: int):
    """Boolean (p, V) membership: vertex v in V_i iff it has an edge in E_i."""
    from .partition_state import edge_incidence_counts
    return edge_incidence_counts(graph, assign, p) > 0


def evaluate_membership(member: np.ndarray, edges_per: np.ndarray,
                        cluster: Cluster,
                        num_edges: int | None = None) -> PartitionStats:
    """TC/RF and per-machine costs from a ``(p, V)`` membership matrix plus
    per-machine edge counts — no ``Graph`` required.

    The metric layer shared by :func:`evaluate` (in-memory assignments) and
    the out-of-core stream path (``StreamMembership``/``StreamAssignment``
    carry exactly these two quantities); Eq. 3/4 only read memberships and
    counts, so both paths report through identical arithmetic.
    """
    p = cluster.p
    member = np.asarray(member, dtype=bool)
    edges_per = np.asarray(edges_per, dtype=np.float64)
    verts_per = member.sum(axis=1).astype(np.float64)

    c_node, c_edge, c_com = cluster.c_node(), cluster.c_edge(), cluster.c_com()
    t_cal = c_node * verts_per + c_edge * edges_per

    # T_i^com: for every replicated vertex v in V_i and every other machine j
    # holding v, cost (C_i^com + C_j^com) — one masked matmul, shared with
    # the incremental layer.
    from .partition_state import t_com_from_membership
    replicas = member.sum(axis=0)                     # (V,) |S(v)|
    com_sum = member.T.astype(np.float64) @ c_com      # (V,) Σ c_com over S(v)
    t_com = t_com_from_membership(member, replicas, com_sum, c_com)

    rf = replicas[replicas > 0].sum() / max(1, (replicas > 0).sum())
    mem_need = cluster.m_node * verts_per + cluster.m_edge * edges_per
    feasible = bool(np.all(mem_need <= cluster.memory() + 1e-9))
    tc = float((t_cal + t_com).max())
    nE = max(1, int(edges_per.sum()) if num_edges is None else num_edges)
    return PartitionStats(
        tc=tc, t_cal=t_cal, t_com=t_com, edges_per_part=edges_per,
        verts_per_part=verts_per, rf=float(rf),
        alpha_balance=float(edges_per.max() / (nE / p)), feasible=feasible)


def evaluate(graph, assign: np.ndarray, cluster: Cluster) -> PartitionStats:
    """Compute TC/RF and per-machine costs for an edge assignment.

    assign: (E,) int array mapping canonical edge id -> machine in [0, p).
    """
    p = cluster.p
    assert assign.min(initial=0) >= 0 and assign.max(initial=0) < p
    member = vertex_partition_sets(graph, assign, p)
    edges_per = np.bincount(assign, minlength=p).astype(np.float64)
    return evaluate_membership(member, edges_per, cluster,
                               num_edges=graph.num_edges)


def replication_factor(graph, assign: np.ndarray, p: int) -> float:
    member = vertex_partition_sets(graph, assign, p)
    replicas = member.sum(axis=0)
    covered = replicas > 0
    return float(replicas[covered].sum() / max(1, covered.sum()))
