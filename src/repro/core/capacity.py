"""Graph-oriented preprocessing: per-partition edge capacities (Alg. 1).

Solves (a simplification of) the MIP in paper Eq. (1)/(2):

    minimize  λ = max_i C_i |E_i|
    s.t.      Σ_i |E_i| = |E|
              (M^edge + M^node |V|/|E|) |E_i| <= M_i
              |E_i| integer >= 0

with C_i = C_i^edge + (|V|/|E|) C_i^node.  The heuristic water-fills the
unclamped machines so C_i δ_i is constant, clamps any machine whose memory
binds, and repeats on the remainder.  Error bound vs the LP optimum is
p²/|E| (paper Theorem 1).  ``exact_capacity`` solves the relaxed problem
exactly for cross-checking in tests.
"""
from __future__ import annotations

import numpy as np

from .machines import Cluster


def _mem_cap(cluster: Cluster, num_vertices: int, num_edges: int) -> np.ndarray:
    """δ_i^2: max edges machine i can hold, via |V_i| ≈ (|V|/|E|)|E_i|."""
    ratio = num_vertices / max(1, num_edges)
    per_edge_mem = cluster.m_edge + cluster.m_node * ratio
    return cluster.memory() / per_edge_mem


def effective_cost(cluster: Cluster, num_vertices: int, num_edges: int) -> np.ndarray:
    """C_i = C_i^edge + (|V|/|E|) C_i^node."""
    ratio = num_vertices / max(1, num_edges)
    return cluster.c_edge() + ratio * cluster.c_node()


def capacities(cluster: Cluster, num_vertices: int, num_edges: int) -> np.ndarray:
    """Algorithm 1: integer capacities δ_i with Σδ_i = |E|.

    Raises ValueError if no feasible assignment exists (Σ mem caps < |E|).
    """
    p = cluster.p
    C = effective_cost(cluster, num_vertices, num_edges)
    mem = np.floor(_mem_cap(cluster, num_vertices, num_edges)).astype(np.int64)
    if mem.sum() < num_edges:
        raise ValueError(
            f"infeasible: total memory capacity {mem.sum()} < |E|={num_edges}")

    delta = np.full(p, -1, dtype=np.int64)
    remaining = int(num_edges)
    active = np.ones(p, dtype=bool)
    # Water-fill: repeat until no machine newly clamps.
    while remaining > 0 and active.any():
        inv = (1.0 / C)[active]
        T = inv.sum()
        want = remaining / T * (1.0 / C)           # δ_i^1 for all (masked below)
        clamped = active & (want > mem)
        if clamped.any():
            delta[clamped] = mem[clamped]
            remaining -= int(mem[clamped].sum())
            active &= ~clamped
            continue
        # No clamping: distribute proportionally, floor, then hand out the
        # remainder one edge at a time to the cheapest machines (keeps the
        # Theorem-1 error bound).
        idx = np.flatnonzero(active)
        share = np.floor(want[idx]).astype(np.int64)
        share = np.minimum(share, mem[idx])
        delta[idx] = share
        remaining -= int(share.sum())
        active[:] = False
        if remaining > 0:
            room = mem[idx] - share
            order = idx[np.argsort(C[idx])]
            for i in order:
                if remaining == 0:
                    break
                take = int(min(room[np.where(idx == i)[0][0]], remaining))
                delta[i] += take
                remaining -= take
    if remaining > 0:
        # All machines clamped but memory is globally sufficient: top up.
        room = mem - delta
        order = np.argsort(C)
        for i in order:
            take = int(min(room[i], remaining))
            delta[i] += take
            remaining -= take
            if remaining == 0:
                break
    assert remaining == 0 and delta.sum() == num_edges, (delta, num_edges)
    return delta


def exact_capacity_relaxed(cluster: Cluster, num_vertices: int,
                           num_edges: int, iters: int = 64) -> np.ndarray:
    """Exact solution of the *relaxed* (continuous) problem, by bisection on λ.

    Feasible(λ): Σ_i min(λ/C_i, mem_i) >= |E|.  The optimal real-valued
    capacities are δ_i = min(λ*/C_i, mem_i).  Used as the test oracle for
    Lemma 1 / Theorem 1.
    """
    C = effective_cost(cluster, num_vertices, num_edges)
    mem = _mem_cap(cluster, num_vertices, num_edges)
    if mem.sum() < num_edges:
        raise ValueError("infeasible")
    lo, hi = 0.0, float(num_edges * C.max())
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if np.minimum(mid / C, mem).sum() >= num_edges:
            hi = mid
        else:
            lo = mid
    delta = np.minimum(hi / C, mem)
    # Scale the unclamped part so the sum is exact.
    slack = num_edges - delta.sum()
    un = delta < mem - 1e-12
    if un.any():
        delta[un] += slack * (1.0 / C[un]) / (1.0 / C[un]).sum()
    return delta
