"""Paper §4 extensions: Map-Reduce objective, vertex-centric conversion.

* **Map-Reduce engines** (GraphX/Giraph): communication only starts after
  every machine finishes local compute, so the makespan is
  ``max_i(max_j T_j^cal + T_i^com)`` instead of ``max_i(T_i^cal+T_i^com)``.
  ``evaluate_mapreduce`` scores it; ``windgp(..)`` results can be re-tuned
  against it by passing ``objective="mapreduce"`` to the SLS phase through
  ``sls_mapreduce``.
* **Vertex-centric partition** (edge-cut) derived from WindGP's vertex-cut:
  each vertex goes to the machine holding its largest partial degree (the
  paper's max deg_k(u)/(deg(u)+1) rule), memory-capped; each edge then
  lives on whichever endpoint machine keeps it internal, and the edge-cut
  is counted for Table-10-style comparisons.
"""
from __future__ import annotations

import numpy as np

from .graph import Graph
from .machines import Cluster, evaluate


def evaluate_mapreduce(g: Graph, assign: np.ndarray, cluster: Cluster):
    """Map-Reduce makespan: max_i ( max_j T_j^cal + T_i^com )."""
    s = evaluate(g, assign, cluster)
    return float(s.t_cal.max() + s.t_com.max()), s


def vertex_partition_from_edge_partition(g: Graph, assign: np.ndarray,
                                         cluster: Cluster) -> np.ndarray:
    """Paper §4: place vertex u on machine argmax_k deg_k(u)/(deg(u)+1),
    subject to machine memory (falls back to next-best machine).

    Returns (V,) machine id per vertex (-1 for isolated vertices).
    """
    p = cluster.p
    V = g.num_vertices
    partial = np.zeros((p, V), dtype=np.int64)
    e = g.edges
    np.add.at(partial, (assign, e[:, 0]), 1)
    np.add.at(partial, (assign, e[:, 1]), 1)
    deg = g.degree()
    score = partial / (deg[None, :] + 1.0)
    place = np.full(V, -1, dtype=np.int64)
    cap = cluster.memory() / max(cluster.m_node, 1e-9)
    used = np.zeros(p)
    # heavy vertices first (they are hardest to place once machines fill)
    for v in np.argsort(-deg, kind="stable"):
        if deg[v] == 0:
            continue
        for k in np.argsort(-score[:, v], kind="stable"):
            if used[k] + 1 <= cap[k]:
                place[v] = k
                used[k] += 1
                break
        if place[v] < 0:
            place[v] = int(np.argmin(used / np.maximum(cap, 1)))
            used[place[v]] += 1
    return place


def edge_cut(g: Graph, vertex_assign: np.ndarray) -> int:
    """Number of edges whose endpoints live on different machines."""
    a = vertex_assign[g.edges[:, 0]]
    b = vertex_assign[g.edges[:, 1]]
    return int(np.sum((a != b) & (a >= 0) & (b >= 0)))


def vertex_balance(vertex_assign: np.ndarray, p: int) -> float:
    """max_i |V_i| / (|V|/p) — the α' of edge-cut partitioning."""
    counts = np.bincount(vertex_assign[vertex_assign >= 0], minlength=p)
    return float(counts.max() / max(1e-9, counts.sum() / p))
