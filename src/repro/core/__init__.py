"""WindGP core: heterogeneous-machine edge partitioning (the paper's contribution)."""
from .graph import Graph, GrowableGraph, from_edge_list
from .machines import (Cluster, Machine, PartitionStats, evaluate,
                       evaluate_membership, paper_cluster, quantify_machines,
                       replication_factor, scaled_paper_cluster)
from .capacity import capacities, exact_capacity_relaxed, effective_cost
from .windgp import WindGPResult, windgp
from .dynamic import AssignmentDelta, DynamicPartitioner, RepairReport

__all__ = [
    "Graph", "GrowableGraph", "from_edge_list",
    "Cluster", "Machine", "PartitionStats",
    "evaluate", "evaluate_membership", "paper_cluster",
    "scaled_paper_cluster", "quantify_machines",
    "replication_factor", "capacities", "exact_capacity_relaxed",
    "effective_cost", "WindGPResult", "windgp",
    "AssignmentDelta", "DynamicPartitioner", "RepairReport",
]
