"""W-worker parallel partitioning: sharded dedup + synced wave scoring.

Everything downstream of the partitioner is vectorized and device-
parallel; this module parallelizes the partitioner itself — the counting/
dedup/streaming passes that are the wall-clock bottleneck at scale — in
the shape DGL's distpartitioning tools use, while keeping every process
boundary message-passing-clean (plain arrays over queues) so multi-host
later is a transport swap, not a redesign.  Three stages:

**Sharded ingest/dedup** (:class:`ShardedTwoPassDedup`).  Pass one
range-partitions the raw edge list into ``W`` byte ranges
(``data/io.byte_ranges`` + the Hadoop-style line-alignment rule), each
read by one worker that stamps *composite* arrival indices
``(range_id << 44) | local_idx`` and spills ``(idx, u, v)`` triples into
the same hash buckets as the sequential :class:`~repro.data.TwoPassDedup`
(per-``(bucket, range)`` part files, so writers never contend).  Because
the composite index is an order-preserving map of global file position,
pass two — buckets dedup'd keep-first in parallel, each worker owning a
disjoint bucket range — and the inherited ordered merge yield a stream
*identical block for block* to the sequential dedup: every duplicate pair
hashes to one bucket regardless of which range read it, and the kept row
is the pair's true first file occurrence under any chunking.  Per-worker
``SpillStats`` peaks sum into the global residency bound.

**Parallel wave scoring** (:func:`parallel_stream_partition`).  The edge
stream is re-chunked into engine blocks ("units") exactly as
``stream_partition`` does; every ``sync_blocks`` (K) consecutive units
form an *epoch*.  Units of an epoch are scored concurrently by W
long-lived workers, each running the unmodified ``_BlockEngine`` over its
own replica of the global ``StreamMembership`` frozen at the epoch start
(HDRF's partial-degree stream facts are stamped centrally, in arrival
order, and shipped with the unit).  At the epoch barrier each worker
reverts its local mutations (exact integer inverse), the coordinator
merges all admissions in unit order through the recount path
(``StreamMembership.apply_admissions``) and broadcasts them with the
per-machine |E|/|V| totals, so every replica is bitwise equal again.
Unadmitted stragglers carry into the next epoch's first unit, and a final
flush unit drains them at stream end.

The schedule depends only on K — never on W — so results are
*worker-count invariant* at any ``sync_blocks``, and at ``sync_blocks=1``
every unit sees fully fresh state, which makes the pipeline bit-identical
to sequential ``stream_partition`` (same membership, totals, and sink
byte stream; a fresh engine per unit is equivalent to the persistent
engine because waves are a pure function of (state, pending, aux)).
Larger K trades a bounded membership staleness window (quality-gated in
CI at the default) for W-way scoring overlap.

**Merge**.  Placements replay through the caller's sink on the
coordinator, in unit order — one ``StreamAssignment`` product, one
finalize, so ``PartitionRuntime.from_stream`` and the BSP layer are
untouched.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import pathlib
import queue as _queue

import numpy as np

from ..data import io as _io
from .capacity import _mem_cap
from .partition_state import StreamMembership

#: engine blocks ("units") scored between epoch barriers — the K knob.
#: 1 = bit-identical to sequential; larger K amortizes the barrier over
#: more concurrent scoring at a bounded membership-staleness cost
#: (TC/RF gated within 2% of W=1 in benchmarks/parallel_scale.py).
DEFAULT_SYNC_BLOCKS = 4

#: composite arrival index: ``(range_id << _RANGE_SHIFT) | local_idx`` —
#: reader-major, so ascending composite order is ascending file position
#: (2^44 rows per range, 2^19 ranges before int64 runs out)
_RANGE_SHIFT = 44

#: seconds the coordinator waits on a worker result before declaring the
#: pool wedged (a worker crash would otherwise hang the barrier forever)
_RESULT_TIMEOUT = 600.0


def _mp_ctx():
    """Fork start method when available (cheap worker spin-up; the
    workers only ever touch numpy state built after the fork)."""
    methods = mp.get_all_start_methods()
    return mp.get_context("fork" if "fork" in methods else None)


# ---------------------------------------------------------------------------
# stage 1: sharded two-pass dedup
# ---------------------------------------------------------------------------

def _spill_range(task):
    """Pass 1 over one byte range: spill composite-stamped triples.

    Writes per-``(bucket, range)`` part files ``bucket<b>.r<rid>`` so
    concurrent readers never share a file handle.  Returns
    ``(rid, n_v, rows_spilled, peak_resident_rows)``.
    """
    (path, start, end, rid, nb, spill_dir, block_size, comments,
     whole) = task
    sd = pathlib.Path(spill_dir)
    files = [open(sd / f"bucket{b}.r{rid}", "wb") for b in range(nb)]
    n_v = 0
    base = 0
    peak = 0
    blocks = (_io.iter_edge_blocks(path, block_size, comments=comments)
              if whole else
              _io.iter_edge_blocks_range(path, start, end, block_size,
                                         comments=comments))
    try:
        for blk in blocks:
            peak = max(peak, len(blk))
            n_v = max(n_v, int(blk.max()) + 1)
            u, v = blk[:, 0], blk[:, 1]
            idx = ((np.int64(rid) << np.int64(_RANGE_SHIFT))
                   + np.arange(base, base + len(blk), dtype=np.int64))
            base += len(blk)
            h = _io._bucket_of(u, v, nb)
            order = np.argsort(h, kind="stable")
            rows = np.stack([idx, u, v], axis=1)[order]
            hs = h[order]
            bounds = np.searchsorted(hs, np.arange(nb + 1))
            for b in range(nb):
                lo, hi = bounds[b], bounds[b + 1]
                if hi > lo:
                    rows[lo:hi].tofile(files[b])
    finally:
        for f in files:
            f.close()
    return rid, n_v, base, peak


def _dedup_buckets(task):
    """Pass 2 over one worker's bucket range: exact keep-first dedup.

    Part files concatenate in range order — ascending composite index, so
    ``np.unique``'s first-occurrence pick is the pair's earliest global
    arrival, exactly as in the sequential pass.  Returns
    ``(unique_rows, max_bucket_rows, peak_resident_rows)``.
    """
    (spill_dir, buckets, n_ranges, n_v) = task
    sd = pathlib.Path(spill_dir)
    unique = 0
    max_rows = 0
    peak = 0
    for b in buckets:
        parts = []
        for rid in range(n_ranges):
            part = sd / f"bucket{b}.r{rid}"
            if part.exists():
                arr = np.fromfile(part, dtype=np.int64).reshape(-1, 3)
                part.unlink()
                if len(arr):
                    parts.append(arr)
        arr = (np.concatenate(parts) if parts
               else np.empty((0, 3), dtype=np.int64))
        max_rows = max(max_rows, len(arr))
        peak = max(peak, len(arr))
        if len(arr):
            key = arr[:, 1] * np.int64(max(1, n_v)) + arr[:, 2]
            _, first = np.unique(key, return_index=True)
            first.sort()
            arr = arr[first]
            arr.tofile(sd / f"bucket{b}.dedup")
        unique += len(arr)
    return unique, max_rows, peak


class ShardedTwoPassDedup(_io.TwoPassDedup):
    """`TwoPassDedup` with both passes range-sharded across ``workers``.

    Drop-in: :meth:`prepare` returns the same exact ``(|V|, |E|)``, and
    iterating yields the *identical* globally-deduplicated block stream
    (the composite arrival index is order-isomorphic to the sequential
    one, so the inherited k-way merge emits the same batches).  With
    ``workers=1`` — or a ``.gz`` input, which admits no byte-range reads —
    pass one runs sequentially; pass two still shards across workers.
    ``stats.peak_resident_rows`` sums the per-worker peaks per phase: an
    upper bound on *simultaneous* resident rows that scales with
    ``workers × bucket_rows``, never with the edge-set size.
    """

    def __init__(self, path, spill_dir: str | None = None, *,
                 workers: int = 1, **kw):
        super().__init__(path, spill_dir, **kw)
        self.workers = max(1, int(workers))
        self.stats.workers = self.workers

    def prepare(self) -> tuple[int, int]:
        if self._prepared or self.workers == 1:
            return super().prepare()
        st = self.stats
        nb = int(min(_io.MAX_BUCKETS,
                     max(1, -(-self._estimate_rows() // st.bucket_rows))))
        st.num_buckets = nb
        whole = str(self.path).endswith(".gz")
        ranges = ([(0, 0)] if whole
                  else _io.byte_ranges(self.path, self.workers))
        tasks = [(self.path, s, e, rid, nb, str(self.spill_dir),
                  self.block_size, self.comments, whole)
                 for rid, (s, e) in enumerate(ranges)]
        res1 = self._map(_spill_range, tasks)
        self.num_vertices = int(max((r[1] for r in res1), default=0))
        st.spilled_rows = int(sum(r[2] for r in res1))
        st._saw(sum(r[3] for r in res1))
        groups = [list(range(w, nb, self.workers))
                  for w in range(self.workers)]
        tasks2 = [(str(self.spill_dir), grp, len(ranges),
                   self.num_vertices) for grp in groups if grp]
        res2 = self._map(_dedup_buckets, tasks2)
        st.unique_edges = int(sum(r[0] for r in res2))
        st.max_bucket_rows = int(max((r[1] for r in res2), default=0))
        st._saw(sum(r[2] for r in res2))
        self.num_edges = st.unique_edges
        self._prepared = True
        return self.num_vertices, self.num_edges

    def _map(self, fn, tasks):
        if len(tasks) <= 1:
            return [fn(t) for t in tasks]
        with _mp_ctx().Pool(min(self.workers, len(tasks))) as pool:
            return pool.map(fn, tasks)


# ---------------------------------------------------------------------------
# stage 2: parallel wave scoring against synced membership snapshots
# ---------------------------------------------------------------------------

class _UnitLog:
    """``StreamMembership`` proxy that records one unit's admissions.

    The engine mutates the worker's state replica *through* this wrapper
    (reads pass straight down, so mid-unit waves see their own
    placements), while every admission is logged in admission order.  At
    unit end the worker exports the log for the epoch barrier and calls
    :meth:`revert` — the exact integer inverse — so the replica returns
    to the epoch-start snapshot before the next unit is scored.
    """

    def __init__(self, sm: StreamMembership):
        self._sm = sm
        self._verts0 = sm.verts_per.copy()
        self._us: list[np.ndarray] = []
        self._vs: list[np.ndarray] = []
        self._ms: list[np.ndarray] = []

    @property
    def cnt(self):
        return self._sm.cnt

    @property
    def edges_per(self):
        return self._sm.edges_per

    @property
    def verts_per(self):
        return self._sm.verts_per

    @property
    def p(self):
        return self._sm.p

    def endpoint_presence(self, u, v):
        return self._sm.endpoint_presence(u, v)

    def admit_block(self, u, v, es, ms, verts_delta=None):
        self._sm.admit_block(u, v, es, ms, verts_delta=verts_delta)
        self._us.append(np.asarray(u, dtype=np.int64))
        self._vs.append(np.asarray(v, dtype=np.int64))
        self._ms.append(np.asarray(ms, dtype=np.int64))

    def admit_single(self, u, v, e, i, verts_delta):
        self._sm.admit_single(u, v, e, i, verts_delta)
        self._us.append(np.array([u], dtype=np.int64))
        self._vs.append(np.array([v], dtype=np.int64))
        self._ms.append(np.array([i], dtype=np.int64))

    def admissions(self):
        """Concatenated ``(u, v, ms)`` in admission order."""
        if not self._us:
            e = np.empty(0, dtype=np.int64)
            return e, e.copy(), e.copy()
        return (np.concatenate(self._us), np.concatenate(self._vs),
                np.concatenate(self._ms))

    def verts_delta(self) -> np.ndarray:
        """Per-machine |V_i| delta this unit actually produced."""
        return self._sm.verts_per - self._verts0

    def revert(self) -> None:
        u, v, ms = self.admissions()
        if len(u):
            self._sm.revert_admissions(u, v, ms, self.verts_delta())


def _score_worker(task_q, result_q, cfg):
    """Long-lived scoring worker: fresh engine per unit, revert, sync.

    Messages in (plain tuples of arrays — the multi-host transport
    boundary): ``("unit", uid, u, v, aux, flush)``,
    ``("sync", u, v, ms, (edges_per, verts_per))``, ``("stop",)``.
    Results out: ``(uid, adm_u, adm_v, adm_ms, (left_u, left_v,
    left_aux))``.
    """
    (method, scorer_kw, p, num_vertices, num_edges, caps, eng_kw) = cfg
    from .baselines import streaming as _s
    scorer = _s.SCORERS[method](**scorer_kw)
    if hasattr(scorer, "reset"):
        # stream facts (HDRF partial degrees) arrive precomputed with each
        # unit; the local scorer state exists only for stateless block_aux
        scorer.reset(num_vertices)
    state = StreamMembership.empty(num_vertices, p)
    nV = max(1, num_vertices)
    while True:
        msg = task_q.get()
        if msg[0] == "stop":
            return
        if msg[0] == "sync":
            _, su, sv, sms, totals = msg
            if len(su):
                state.apply_admissions(su, sv, sms)
            if not (np.array_equal(state.edges_per, totals[0])
                    and np.array_equal(state.verts_per, totals[1])):
                raise AssertionError(
                    "epoch barrier desync: worker replica totals diverge "
                    "from the coordinator's")
            continue
        _, uid, uu, vv, aux, flush = msg
        log = _UnitLog(state)
        eng = _s._BlockEngine(log, scorer, caps, num_edges, nV,
                              sink=None, **eng_kw)
        eng.push(uu, vv, aux=aux)
        if flush:
            eng.flush()
        left = (eng.u, eng.v, eng.aux)
        adm = log.admissions()
        log.revert()
        result_q.put((uid, *adm, left))


def _iter_unit_blocks(blocks, B: int):
    """Re-chunk a block source to exact ``B``-row units.

    Mirrors ``stream_partition``'s re-chunk loop: unit boundaries — and
    therefore the schedule, which depends only on them and K — must not
    depend on how the source happened to chunk the stream.
    """
    pend: list = []
    npend = 0
    for edges in blocks:
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if not len(edges):
            continue
        pend.append(edges)
        npend += len(edges)
        if npend < B:
            continue
        buf = np.concatenate(pend) if len(pend) > 1 else pend[0]
        lo = 0
        while lo + B <= len(buf):
            yield buf[lo:lo + B]
            lo += B
        pend = [buf[lo:]] if lo < len(buf) else []
        npend = len(buf) - lo
    if npend:
        yield np.concatenate(pend) if len(pend) > 1 else pend[0]


def _cat_aux(a, b):
    if a is None:
        return b
    if b is None:
        return a
    return np.concatenate([a, b])


def parallel_stream_partition(source, num_vertices: int | None = None,
                              num_edges: int | None = None,
                              cluster=None, method: str = "hdrf", *,
                              workers: int = 2,
                              sync_blocks: int | None = None,
                              dedup: str = "block",
                              spill_dir: str | None = None,
                              bucket_rows: int = 1 << 16,
                              block_size: int | None = None,
                              max_waves: int | None = None,
                              replica_frac: float | None = None,
                              creator_scalar: bool | None = None,
                              sink=None, **scorer_kw) -> StreamMembership:
    """``stream_partition`` semantics across ``workers`` processes.

    Same contract and knobs as
    :func:`repro.core.baselines.streaming.stream_partition` plus
    ``workers`` / ``sync_blocks`` (see the module docstring for the epoch
    scheme).  ``workers=1`` delegates to the sequential path unchanged —
    the bit-reproducible fallback.  With a path source and
    ``dedup="two_pass"`` the spill/dedup passes shard across the same
    worker count (:class:`ShardedTwoPassDedup`).  The sink runs on the
    coordinator only, in unit order — one shard product regardless of W.
    """
    from .baselines import streaming as _s
    workers = max(1, int(workers))
    if workers == 1:
        return _s.stream_partition(
            source, num_vertices, num_edges, cluster, method, dedup=dedup,
            spill_dir=spill_dir, bucket_rows=bucket_rows,
            block_size=block_size, max_waves=max_waves,
            replica_frac=replica_frac, creator_scalar=creator_scalar,
            sink=sink, **scorer_kw)
    if (isinstance(source, (str, os.PathLike)) and dedup == "two_pass"):
        tp = ShardedTwoPassDedup(source, spill_dir,
                                 bucket_rows=bucket_rows, workers=workers)
        nv, ne = tp.prepare()
        blocks, num_vertices, num_edges = tp, nv, ne
        spill, owned = tp, True
    else:
        blocks, num_vertices, num_edges, spill, owned = \
            _s._resolve_stream_source(
                source, num_vertices, num_edges, dedup=dedup,
                spill_dir=spill_dir, bucket_rows=bucket_rows,
                io_block=block_size)
    scorer = _s.SCORERS[method](**scorer_kw)
    if hasattr(scorer, "reset"):
        scorer.reset(num_vertices)
    dflt = _s.ENGINE_DEFAULTS[method]
    if block_size is None:
        block_size = dflt["block_size"] or _s.auto_block_size(num_edges)
    B = max(1, int(block_size))
    eng_kw = dict(
        block_size=B,
        max_waves=dflt["max_waves"] if max_waves is None else max_waves,
        replica_frac=(dflt["replica_frac"] if replica_frac is None
                      else replica_frac),
        creator_scalar=(dflt["creator_scalar"] if creator_scalar is None
                        else creator_scalar))
    caps = np.floor(_mem_cap(cluster, num_vertices,
                             num_edges)).astype(np.int64)
    K = (DEFAULT_SYNC_BLOCKS if sync_blocks is None
         else max(1, int(sync_blocks)))
    state = StreamMembership.empty(num_vertices, cluster.p)

    ctx = _mp_ctx()
    cfg = (method, scorer_kw, cluster.p, num_vertices, num_edges, caps,
           eng_kw)
    task_qs = [ctx.Queue() for _ in range(workers)]
    result_q = ctx.Queue()
    procs = [ctx.Process(target=_score_worker, args=(tq, result_q, cfg),
                         daemon=True) for tq in task_qs]
    for pr in procs:
        pr.start()
    uid = 0

    def run_epoch(units, flush):
        nonlocal uid
        ids = []
        for j, (uu, vv, aux) in enumerate(units):
            task_qs[j % workers].put(("unit", uid, uu, vv, aux, flush))
            ids.append(uid)
            uid += 1
        got = {}
        for _ in ids:
            try:
                r = result_q.get(timeout=_RESULT_TIMEOUT)
            except _queue.Empty:
                dead = [i for i, pr in enumerate(procs)
                        if not pr.is_alive()]
                raise RuntimeError(
                    f"parallel scoring stalled waiting for unit results "
                    f"(dead workers: {dead or 'none'})") from None
            got[r[0]] = r[1:]
        return [got[i] for i in ids]

    def merge_epoch(results):
        """Master-state merge + sink replay, in unit order."""
        parts_u, parts_v, parts_m = [], [], []
        for au, av, ams, _left in results:
            if len(au):
                if sink is not None:
                    sink(np.stack([au, av], axis=1), ams)
                parts_u.append(au)
                parts_v.append(av)
                parts_m.append(ams)
        if parts_u:
            cu = np.concatenate(parts_u)
            cv = np.concatenate(parts_v)
            cm = np.concatenate(parts_m)
            state.apply_admissions(cu, cv, cm)
        else:
            cu = np.empty(0, dtype=np.int64)
            cv, cm = cu.copy(), cu.copy()
        return cu, cv, cm

    try:
        units_src = _iter_unit_blocks(blocks, B)
        carry_u = np.empty(0, dtype=np.int64)
        carry_v = np.empty(0, dtype=np.int64)
        carry_aux = None
        while True:
            units = []
            for _ in range(K):
                blk = next(units_src, None)
                if blk is None:
                    break
                uu, vv = blk[:, 0].copy(), blk[:, 1].copy()
                units.append((uu, vv, scorer.block_aux(uu, vv)))
            if not units:
                break
            if len(carry_u):
                u0, v0, a0 = units[0]
                units[0] = (np.concatenate([carry_u, u0]),
                            np.concatenate([carry_v, v0]),
                            _cat_aux(carry_aux, a0))
                carry_u = carry_u[:0]
                carry_v = carry_v[:0]
                carry_aux = None
            results = run_epoch(units, flush=False)
            cu, cv, cm = merge_epoch(results)
            totals = state.totals()
            for tq in task_qs:
                tq.put(("sync", cu, cv, cm, totals))
            lefts = [r[3] for r in results]
            carry_u = np.concatenate([carry_u] + [l[0] for l in lefts])
            carry_v = np.concatenate([carry_v] + [l[1] for l in lefts])
            for l in lefts:
                carry_aux = _cat_aux(carry_aux, l[2])
        if len(carry_u):
            # final flush unit: drain the carried stragglers to empty on
            # one worker (already synced to the master state)
            results = run_epoch([(carry_u, carry_v, carry_aux)],
                                flush=True)
            merge_epoch(results)
            left = results[0][3]
            if len(left[0]):
                raise AssertionError(
                    f"flush left {len(left[0])} unplaced edges")
    finally:
        for tq in task_qs:
            try:
                tq.put(("stop",))
            except Exception:
                pass
        for pr in procs:
            pr.join(timeout=30)
            if pr.is_alive():
                pr.terminate()
        if owned:
            spill.close()
    if spill is not None:
        state.spill_stats = spill.stats
    return state
