"""Undirected graph in CSR form, numpy-backed.

The partitioner is a host-side sequential heuristic (control plane), so the
graph lives in numpy.  Edges are stored once with a canonical id; the CSR
adjacency stores each edge twice (u->v and v->u) but both directions carry
the same edge id, so edge-set membership is a single bitmap over E ids.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable CSR graph.

    Attributes:
      indptr:   (V+1,) int64 — CSR row pointers.
      indices:  (2E,)  int32 — neighbor vertex ids.
      edge_ids: (2E,)  int32 — canonical edge id for each adjacency slot;
                the two directions of one undirected edge share an id.
      edges:    (E, 2) int32 — canonical (u, v) with u < v.
    """

    indptr: np.ndarray
    indices: np.ndarray
    edge_ids: np.ndarray
    edges: np.ndarray

    @property
    def num_vertices(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    def degree(self, u=None):
        deg = np.diff(self.indptr)
        return deg if u is None else deg[u]

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]

    def incident_edge_ids(self, u: int) -> np.ndarray:
        return self.edge_ids[self.indptr[u]:self.indptr[u + 1]]

    @property
    def avg_degree(self) -> float:
        return 2.0 * self.num_edges / max(1, self.num_vertices)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Graph(V={self.num_vertices}, E={self.num_edges}, "
                f"maxdeg={int(self.degree().max(initial=0))})")


def from_edge_list(edges: np.ndarray, num_vertices: int | None = None) -> Graph:
    """Build a Graph from an (N, 2) array of (possibly duplicated) edges.

    Self loops are dropped; duplicate/reverse duplicates are merged.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    # Canonicalize: u < v, drop self loops.
    u = np.minimum(edges[:, 0], edges[:, 1])
    v = np.maximum(edges[:, 0], edges[:, 1])
    keep = u != v
    u, v = u[keep], v[keep]
    if num_vertices is None:
        num_vertices = int(max(u.max(initial=-1), v.max(initial=-1)) + 1)
    # Dedup via single key.
    key = u * np.int64(num_vertices) + v
    _, first = np.unique(key, return_index=True)
    u, v = u[first], v[first]
    E = len(u)
    eid = np.arange(E, dtype=np.int32)

    # Symmetric adjacency: (u->v, eid) and (v->u, eid).
    src = np.concatenate([u, v])
    dst = np.concatenate([v, u]).astype(np.int32)
    eids = np.concatenate([eid, eid])
    order = np.argsort(src, kind="stable")
    src, dst, eids = src[order], dst[order], eids[order]
    indptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    edges_canon = np.stack([u, v], axis=1).astype(np.int32)
    return Graph(indptr=indptr, indices=dst, edge_ids=eids, edges=edges_canon)


def subgraph_edge_mask(g: Graph, edge_mask: np.ndarray) -> Graph:
    """Graph induced by the edges where edge_mask is True (vertex ids kept)."""
    return from_edge_list(g.edges[edge_mask], num_vertices=g.num_vertices)


# ---------------------------------------------------------------------------
# growable graph (the dynamic-stream substrate)
# ---------------------------------------------------------------------------

#: edge-key packing: canonical (u, v) with u < v fits one int64 because
#: vertex ids are int32 — independent of |V|, so keys survive vertex growth
_KEY_SHIFT = np.int64(32)


def edge_keys(u: np.ndarray, v: np.ndarray) -> np.ndarray:
    """(k,) int64 canonical-pair keys, stable under vertex-count growth."""
    return (np.asarray(u, dtype=np.int64) << _KEY_SHIFT) \
        | np.asarray(v, dtype=np.int64)


class GrowableGraph:
    """A :class:`Graph` that accepts amortized-O(1) edge/vertex appends.

    Presents the read surface the partition layer uses (``edges``,
    ``num_vertices``, ``num_edges``, ``degree``, CSR ``indptr`` /
    ``indices`` / ``edge_ids``); mutation is :meth:`append` only —
    canonical ids are stable forever, so every (p, E) / (p, V) structure
    keyed on them stays valid across growth.  The edge array grows by
    capacity doubling; the CSR adjacency is invalidated on append and
    rebuilt lazily on first access (expansion-side consumers only — the
    dynamic hot path never touches it).

    Edge *identity* is tracked in an id index (``eids_of``): the dynamic
    layer uses it to reuse the canonical id when a previously-deleted
    edge is re-inserted, so an id means one (u, v) pair for the lifetime
    of the graph.
    """

    def __init__(self, edges: np.ndarray, num_vertices: int):
        edges = np.asarray(edges, dtype=np.int32).reshape(-1, 2)
        n = len(edges)
        self._edges = np.empty((max(16, 2 * n), 2), dtype=np.int32)
        self._edges[:n] = edges
        self._n = n
        self._num_vertices = int(num_vertices)
        self._deg = np.zeros(max(16, 2 * self._num_vertices), dtype=np.int64)
        np.add.at(self._deg[:self._num_vertices], edges.ravel(), 1)
        self._key_index = {int(k): i for i, k in
                           enumerate(edge_keys(edges[:, 0], edges[:, 1]))}
        self._csr: Graph | None = None

    @classmethod
    def from_graph(cls, g: "Graph | GrowableGraph") -> "GrowableGraph":
        if isinstance(g, cls):
            return g
        return cls(g.edges, g.num_vertices)

    # -- Graph read surface --------------------------------------------------
    @property
    def edges(self) -> np.ndarray:
        return self._edges[:self._n]

    @property
    def num_vertices(self) -> int:
        return self._num_vertices

    @property
    def num_edges(self) -> int:
        return self._n

    def degree(self, u=None):
        deg = self._deg[:self._num_vertices]
        return deg if u is None else deg[u]

    @property
    def avg_degree(self) -> float:
        return 2.0 * self._n / max(1, self._num_vertices)

    def _rebuild_csr(self) -> Graph:
        if self._csr is None or self._csr.num_edges != self._n:
            self._csr = from_edge_list(self.edges,
                                       num_vertices=self._num_vertices)
        return self._csr

    @property
    def indptr(self) -> np.ndarray:
        return self._rebuild_csr().indptr

    @property
    def indices(self) -> np.ndarray:
        return self._rebuild_csr().indices

    @property
    def edge_ids(self) -> np.ndarray:
        return self._rebuild_csr().edge_ids

    def neighbors(self, u: int) -> np.ndarray:
        return self._rebuild_csr().neighbors(u)

    def incident_edge_ids(self, u: int) -> np.ndarray:
        return self._rebuild_csr().incident_edge_ids(u)

    # -- identity / mutation -------------------------------------------------
    def eids_of(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """(k,) int64 canonical edge id per (already canonical) pair, -1
        where the pair was never appended."""
        idx = self._key_index
        return np.fromiter(
            (idx.get(int(k), -1) for k in edge_keys(u, v)),
            dtype=np.int64, count=len(u))

    def append(self, uv: np.ndarray) -> np.ndarray:
        """Append genuinely-new canonical (u < v) pairs; returns their new
        edge ids.  Pairs must be canonical, loop-free, unique within the
        batch, and absent from the graph (``ValueError`` otherwise) — the
        caller (``PartitionState.append_edges``) enforces all four, this
        re-checks the cheap ones."""
        uv = np.asarray(uv, dtype=np.int64).reshape(-1, 2)
        if len(uv) == 0:
            return np.empty(0, dtype=np.int64)
        if (uv[:, 0] >= uv[:, 1]).any():
            raise ValueError("append needs canonical loop-free pairs "
                             "(u < v)")
        keys = edge_keys(uv[:, 0], uv[:, 1])
        if len(np.unique(keys)) != len(keys):
            raise ValueError("append batch contains duplicate pairs")
        idx = self._key_index
        for j, key in enumerate(keys):       # validate before any mutation
            if int(key) in idx:
                raise ValueError(
                    f"edge ({uv[j, 0]}, {uv[j, 1]}) already present "
                    f"(id {idx[int(key)]}); re-place it instead of "
                    f"appending")
        n, k = self._n, len(uv)
        if n + k > len(self._edges):
            grown = np.empty((max(2 * len(self._edges), n + k), 2),
                             dtype=np.int32)
            grown[:n] = self._edges[:n]
            self._edges = grown
        self._edges[n:n + k] = uv
        for j, key in enumerate(keys):
            idx[int(key)] = n + j
        self._n = n + k
        nv = int(uv.max()) + 1
        if nv > self._num_vertices:
            self._num_vertices = nv
        if nv > len(self._deg):
            grown_deg = np.zeros(max(2 * len(self._deg), nv), dtype=np.int64)
            grown_deg[:len(self._deg)] = self._deg
            self._deg = grown_deg
        np.add.at(self._deg, uv.ravel(), 1)
        self._csr = None
        return np.arange(n, n + k, dtype=np.int64)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"GrowableGraph(V={self.num_vertices}, E={self.num_edges})")
