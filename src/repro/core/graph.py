"""Undirected graph in CSR form, numpy-backed.

The partitioner is a host-side sequential heuristic (control plane), so the
graph lives in numpy.  Edges are stored once with a canonical id; the CSR
adjacency stores each edge twice (u->v and v->u) but both directions carry
the same edge id, so edge-set membership is a single bitmap over E ids.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable CSR graph.

    Attributes:
      indptr:   (V+1,) int64 — CSR row pointers.
      indices:  (2E,)  int32 — neighbor vertex ids.
      edge_ids: (2E,)  int32 — canonical edge id for each adjacency slot;
                the two directions of one undirected edge share an id.
      edges:    (E, 2) int32 — canonical (u, v) with u < v.
    """

    indptr: np.ndarray
    indices: np.ndarray
    edge_ids: np.ndarray
    edges: np.ndarray

    @property
    def num_vertices(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    def degree(self, u=None):
        deg = np.diff(self.indptr)
        return deg if u is None else deg[u]

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]

    def incident_edge_ids(self, u: int) -> np.ndarray:
        return self.edge_ids[self.indptr[u]:self.indptr[u + 1]]

    @property
    def avg_degree(self) -> float:
        return 2.0 * self.num_edges / max(1, self.num_vertices)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Graph(V={self.num_vertices}, E={self.num_edges}, "
                f"maxdeg={int(self.degree().max(initial=0))})")


def from_edge_list(edges: np.ndarray, num_vertices: int | None = None) -> Graph:
    """Build a Graph from an (N, 2) array of (possibly duplicated) edges.

    Self loops are dropped; duplicate/reverse duplicates are merged.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    # Canonicalize: u < v, drop self loops.
    u = np.minimum(edges[:, 0], edges[:, 1])
    v = np.maximum(edges[:, 0], edges[:, 1])
    keep = u != v
    u, v = u[keep], v[keep]
    if num_vertices is None:
        num_vertices = int(max(u.max(initial=-1), v.max(initial=-1)) + 1)
    # Dedup via single key.
    key = u * np.int64(num_vertices) + v
    _, first = np.unique(key, return_index=True)
    u, v = u[first], v[first]
    E = len(u)
    eid = np.arange(E, dtype=np.int32)

    # Symmetric adjacency: (u->v, eid) and (v->u, eid).
    src = np.concatenate([u, v])
    dst = np.concatenate([v, u]).astype(np.int32)
    eids = np.concatenate([eid, eid])
    order = np.argsort(src, kind="stable")
    src, dst, eids = src[order], dst[order], eids[order]
    indptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    edges_canon = np.stack([u, v], axis=1).astype(np.int32)
    return Graph(indptr=indptr, indices=dst, edge_ids=eids, edges=edges_canon)


def subgraph_edge_mask(g: Graph, edge_mask: np.ndarray) -> Graph:
    """Graph induced by the edges where edge_mask is True (vertex ids kept)."""
    return from_edge_list(g.edges[edge_mask], num_vertices=g.num_vertices)
