"""Unified incremental per-(machine, vertex) accounting for edge partitions.

One state layer shared by every phase that mutates or scores an edge
assignment — expansion (``core/expand.py``), subgraph-local search
(``core/sls.py``), the driver's repair pass (``core/windgp.py``), the
baselines' capacity spill handling, and the BSP runtime packer
(``bsp/partition_runtime.py``).  Historically the same bookkeeping was
implemented twice (``ExpansionState`` plus the batched engine's private
counters, and ``sls.IncrementalTC``); this module is the single home.

Field ↔ paper-term map (Definition 4 / Eq. 3–5):

* ``cnt[i, v]``     — number of partition-i edges incident on v.  ``cnt > 0``
  is the vertex-membership matrix: v ∈ V_i ⇔ cnt[i, v] > 0 (Definition 3).
* ``edges_per[i]``  — |E_i|, ``verts_per[i]`` — |V_i|: the two factors of the
  computation cost  T_i^cal = C_i^node·|V_i| + C_i^edge·|E_i|   (Eq. 3).
* ``replicas[v]``   — |S(v)|, the number of machines holding a replica of v.
* ``com_sum[v]``    — Σ_{j ∈ S(v)} C_j^com, the communication mass of v's
  replica set; together with ``replicas`` it closes the communication cost
  T_i^com = Σ_{v ∈ V_i} Σ_{j ≠ i, v ∈ V_j} (C_i^com + C_j^com)    (Eq. 4)
  in O(1) per membership change.
* ``t_cal``/``t_com`` — the per-machine Eq. 3/Eq. 4 totals; ``tc`` is their
  max (the TC objective).  ``delta_t_batch`` scores hypothetical edge
  additions — the repair-side analogue of the expansion score w(v) (Eq. 5):
  both charge a candidate by the new replicas it would create.

All arrays hold integer-valued float64 (costs are integral in the paper's
machine quantification), so the batch recount path and the scalar
incremental path produce bit-identical state — the equivalence tests rely
on this.

Batch-first API: ``remove_edges``/``add_edges`` apply whole edge sets with
an exact *wave-local recount* — membership-derived quantities of the
touched vertex columns are recomputed from ``cnt`` rather than replayed
edge by edge — and ``delta_t_batch``/``mem_after_batch`` score
|edges| × |machines| hypothetical placements in one broadcast.  The scalar
methods survive as the oracle path (and for one-off callers).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # avoid runtime cycles: machines.py imports the helpers
    from .graph import Graph
    from .machines import Cluster


# ---------------------------------------------------------------------------
# membership helpers (shared with machines.evaluate / bsp runtime)
# ---------------------------------------------------------------------------

def edge_incidence_counts(g: "Graph", assign: np.ndarray, p: int) -> np.ndarray:
    """(p, V) int32 — partition-i edges incident on v (unassigned skipped)."""
    cnt = np.zeros((p, g.num_vertices), dtype=np.int32)
    ok = assign >= 0
    np.add.at(cnt, (assign[ok], g.edges[ok, 0]), 1)
    np.add.at(cnt, (assign[ok], g.edges[ok, 1]), 1)
    return cnt


def cumcount(a: np.ndarray) -> np.ndarray:
    """Occurrence rank of each element among equal values, in array order."""
    order = np.argsort(a, kind="stable")
    sa = a[order]
    fresh = np.concatenate([[True], sa[1:] != sa[:-1]])
    starts = np.flatnonzero(fresh)
    sizes = np.diff(np.append(starts, len(sa)))
    rank_sorted = np.arange(len(sa)) - np.repeat(starts, sizes)
    out = np.empty(len(a), dtype=np.int64)
    out[order] = rank_sorted
    return out


def t_com_from_membership(member: np.ndarray, replicas: np.ndarray,
                          com_sum: np.ndarray, c_com: np.ndarray) -> np.ndarray:
    """Vectorized Eq. 4:  T_i^com = Σ_{v∈V_i} [(|S(v)|−1)·C_i^com
    + (com_sum(v) − C_i^com)], as one masked matmul over [com_sum, |S|]."""
    m = member.astype(np.float64)
    cols = m @ np.stack([com_sum, replicas.astype(np.float64)], axis=1)
    return cols[:, 0] + c_com * cols[:, 1] - 2.0 * c_com * m.sum(axis=1)


# ---------------------------------------------------------------------------
# compacting working-CSR view
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkingCSR:
    """The live slice of a graph's CSR adjacency.

    As edges are consumed (assigned), dead entries accumulate; ``view``
    recompacts geometrically once fewer than ``compact_below`` of the stored
    entries are live.  Dropping dead entries preserves adjacency order, so
    it changes no engine decision — only how much dead data each gather
    touches.  Shared by the batched expansion engine and PartitionState.
    """

    indptr: np.ndarray
    indices: np.ndarray
    eids: np.ndarray

    @classmethod
    def from_graph(cls, g: "Graph") -> "WorkingCSR":
        return cls(indptr=g.indptr, indices=g.indices, eids=g.edge_ids)

    def view(self, edge_live, live_edges: int,
             compact_below: float = 0.75):
        """(indptr, indices, eids) of the live adjacency.

        ``edge_live`` is a (E,) bool over canonical edge ids — or a zero-arg
        callable producing it, evaluated only when compaction triggers;
        ``live_edges`` is its popcount (each live edge stores two slots).
        """
        stored = len(self.eids)
        if stored and 2 * live_edges < compact_below * stored:
            if callable(edge_live):
                edge_live = edge_live()
            live = edge_live[self.eids]
            cum = np.concatenate(
                [np.zeros(1, dtype=np.int64), np.cumsum(live)])
            self.indptr = cum[self.indptr]
            self.indices = self.indices[live]
            self.eids = self.eids[live]
        return self.indptr, self.indices, self.eids


# ---------------------------------------------------------------------------
# the unified incremental state
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PartitionState:
    """Incrementally-maintained per-machine costs for an edge assignment."""

    g: "Graph"
    cluster: "Cluster"
    assign: np.ndarray            # (E,) int32, machine per edge (-1 = unassigned)
    cnt: np.ndarray               # (p, V) int32: partition-i edges incident on v
    edges_per: np.ndarray         # (p,)  |E_i|            (Eq. 3)
    verts_per: np.ndarray         # (p,)  |V_i|            (Eq. 3)
    t_cal: np.ndarray             # (p,)  Eq. 3 totals
    t_com: np.ndarray             # (p,)  Eq. 4 totals
    com_sum: np.ndarray           # (V,)  Σ_{i∈S(v)} C_i^com
    replicas: np.ndarray          # (V,)  |S(v)|
    #: optional (V,) float64 per-vertex calculation weight — the
    #: training-aware balance term: ``1 + train_balance`` on train
    #: vertices, 1 elsewhere, so Eq. 3 charges machines extra for every
    #: labeled vertex they host and the scorers spread the training set.
    #: ``None`` (default) keeps every cost bit-identical to the unweighted
    #: accounting; ``verts_per``/memory always stay plain counts.
    node_weight: np.ndarray | None = None

    def __post_init__(self):
        # Cluster views are rebuilt per call; cache them once for hot loops.
        self._c_node = self.cluster.c_node()
        self._c_edge = self.cluster.c_edge()
        self._c_com = self.cluster.c_com()
        self._mem = self.cluster.memory()
        self._wcsr: WorkingCSR | None = None
        self._wcsr_edges = -1           # graph size the CSR view was cut at
        self._costs_stale = False       # set by light-path admit_block

    @classmethod
    def build(cls, g: "Graph", assign: np.ndarray, cluster: "Cluster", *,
              train_mask: np.ndarray | None = None,
              train_balance: float = 0.0):
        """Build from scratch — the reference for every incremental path.

        ``train_mask`` (V,) bool + ``train_balance`` > 0 switch on the
        training-aware node weight: Eq. 3 charges ``c_node * (1 +
        train_balance)`` per hosted train vertex, so every scorer that
        reads ``t_cal``/``placement_scores`` balances the labeled set
        across machines, not just edges.  Defaults reproduce the plain
        accounting bit for bit.
        """
        p = cluster.p
        cnt = edge_incidence_counts(g, assign, p)
        member = cnt > 0
        ok = assign >= 0
        edges_per = np.bincount(assign[ok], minlength=p).astype(np.float64)
        verts_per = member.sum(axis=1).astype(np.float64)
        c_com = cluster.c_com()
        replicas = member.sum(axis=0).astype(np.int64)
        com_sum = member.T.astype(np.float64) @ c_com
        node_weight = None
        if train_mask is not None and train_balance:
            tm = np.asarray(train_mask, dtype=bool)
            if tm.shape != (g.num_vertices,):
                raise ValueError(
                    f"train_mask must be ({g.num_vertices},) bool, got "
                    f"shape {tm.shape}")
            node_weight = 1.0 + float(train_balance) * tm.astype(np.float64)
        if node_weight is None:
            t_cal = (cluster.c_node() * verts_per
                     + cluster.c_edge() * edges_per)
        else:
            t_cal = (cluster.c_node() * (member.astype(np.float64)
                                         @ node_weight)
                     + cluster.c_edge() * edges_per)
        t_com = t_com_from_membership(member, replicas, com_sum, c_com)
        return cls(g=g, cluster=cluster, assign=np.asarray(assign, dtype=np.int32).copy(),
                   cnt=cnt, edges_per=edges_per, verts_per=verts_per,
                   t_cal=t_cal, t_com=t_com, com_sum=com_sum,
                   replicas=replicas, node_weight=node_weight)

    # -- objective views ----------------------------------------------------
    @property
    def p(self) -> int:
        return self.cluster.p

    @property
    def t_total(self) -> np.ndarray:
        return self.t_cal + self.t_com

    @property
    def tc(self) -> float:
        return float(self.t_total.max())

    def mem_used(self, i: int) -> float:
        return (self.cluster.m_node * self.verts_per[i]
                + self.cluster.m_edge * self.edges_per[i])

    def mem_used_all(self) -> np.ndarray:
        return (self.cluster.m_node * self.verts_per
                + self.cluster.m_edge * self.edges_per)

    @property
    def mem_limits(self) -> np.ndarray:
        """Per-machine memory caps M_i (cached cluster view)."""
        return self._mem

    def working_csr(self, compact_below: float = 0.75):
        """Live (unassigned-edge) adjacency view, recompacted geometrically.

        Appended edges (:meth:`append_edges`) invalidate the cached view:
        the next call recuts it from the grown adjacency, so CSR consumers
        never see a stale edge universe.
        """
        if self._wcsr is None or self._wcsr_edges != self.g.num_edges:
            self._wcsr = WorkingCSR.from_graph(self.g)
            self._wcsr_edges = self.g.num_edges
        return self._wcsr.view(self.assign < 0,
                               int((self.assign < 0).sum()),
                               compact_below=compact_below)

    # -- scalar oracle path -------------------------------------------------
    def _vertex_enter(self, i: int, v: int) -> None:
        c_com = self._c_com
        # v becomes present on i: pairs (i, j) for each j already holding v.
        self.t_com[i] += self.replicas[v] * c_com[i] + self.com_sum[v]
        holders = np.flatnonzero(self.cnt[:, v] > 0)
        self.t_com[holders] += c_com[holders] + c_com[i]
        self.replicas[v] += 1
        self.com_sum[v] += c_com[i]
        self.verts_per[i] += 1
        if self.node_weight is None:
            self.t_cal[i] += self._c_node[i]
        else:
            self.t_cal[i] += self._c_node[i] * self.node_weight[v]

    def _vertex_leave(self, i: int, v: int) -> None:
        c_com = self._c_com
        self.replicas[v] -= 1
        self.com_sum[v] -= c_com[i]
        self.t_com[i] -= self.replicas[v] * c_com[i] + self.com_sum[v]
        holders = np.flatnonzero(self.cnt[:, v] > 0)
        holders = holders[holders != i]
        self.t_com[holders] -= c_com[holders] + c_com[i]
        self.verts_per[i] -= 1
        if self.node_weight is None:
            self.t_cal[i] -= self._c_node[i]
        else:
            self.t_cal[i] -= self._c_node[i] * self.node_weight[v]

    def remove_edge(self, e: int) -> None:
        i = int(self.assign[e])
        if i < 0:
            raise ValueError(f"remove_edge: edge {e} is unassigned")
        u, v = self.g.edges[e]
        self.assign[e] = -1
        self.edges_per[i] -= 1
        self.t_cal[i] -= self._c_edge[i]
        for x in (int(u), int(v)):
            self.cnt[i, x] -= 1
            if self.cnt[i, x] == 0:
                self._vertex_leave(i, x)

    def add_edge(self, e: int, i: int) -> None:
        if self.assign[e] != -1:
            raise ValueError(f"add_edge: edge {e} is already assigned "
                             f"to machine {int(self.assign[e])}")
        if not 0 <= i < self.p:
            raise ValueError(f"add_edge: machine {i} outside [0, {self.p})")
        u, v = self.g.edges[e]
        for x in (int(u), int(v)):
            if self.cnt[i, x] == 0:
                self._vertex_enter(i, x)
            self.cnt[i, x] += 1
        self.assign[e] = i
        self.edges_per[i] += 1
        self.t_cal[i] += self._c_edge[i]

    def delta_t_if_added(self, e: int, i: int) -> float:
        """Resulting T_i if edge e were added to machine i (no mutation)."""
        u, v = self.g.edges[e]
        c_com = self._c_com
        dt = self._c_edge[i]
        for x in (int(u), int(v)):
            if self.cnt[i, x] == 0:
                c_n = (self._c_node[i] if self.node_weight is None
                       else self._c_node[i] * self.node_weight[x])
                dt += c_n + self.replicas[x] * c_com[i] + self.com_sum[x]
        return float(self.t_total[i] + dt)

    def mem_after(self, e: int, i: int) -> float:
        u, v = self.g.edges[e]
        new_v = sum(1 for x in (int(u), int(v)) if self.cnt[i, x] == 0)
        return (self.cluster.m_node * (self.verts_per[i] + new_v)
                + self.cluster.m_edge * (self.edges_per[i] + 1))

    # -- batch-first API ----------------------------------------------------
    def _tcom_contrib(self, member: np.ndarray, replicas: np.ndarray,
                      com_sum: np.ndarray) -> np.ndarray:
        return t_com_from_membership(member, replicas, com_sum, self._c_com)

    def _recount_columns(self, A: np.ndarray, mutate_cnt) -> None:
        """Exact wave-local recount: apply ``mutate_cnt`` (which edits the
        vertex columns A of ``cnt``), then rebuild every membership-derived
        quantity of those columns from scratch and apply the delta.  Exact
        regardless of how the wave's edges interact (shared endpoints,
        same-machine pileups), because Eq. 3/4 are separable per vertex."""
        mem_old = self.cnt[:, A] > 0
        old = self._tcom_contrib(mem_old, self.replicas[A], self.com_sum[A])
        mutate_cnt()
        mem_new = self.cnt[:, A] > 0
        self.replicas[A] = mem_new.sum(axis=0)
        self.com_sum[A] = mem_new.T.astype(np.float64) @ self._c_com
        new = self._tcom_contrib(mem_new, self.replicas[A], self.com_sum[A])
        self.t_com += new - old
        dv = (mem_new.sum(axis=1) - mem_old.sum(axis=1)).astype(np.float64)
        self.verts_per += dv
        if self.node_weight is None:
            self.t_cal += self._c_node * dv
        else:
            dvw = ((mem_new.astype(np.float64) - mem_old.astype(np.float64))
                   @ self.node_weight[A])
            self.t_cal += self._c_node * dvw

    def remove_edges(self, es: np.ndarray) -> None:
        """Batch ``remove_edge`` over an edge-id array.

        Preconditions (``ValueError``, never a stripped-out ``assert``):
        every id must be currently assigned, and ids must be unique within
        the batch — a duplicated id would hit ``np.subtract.at`` twice but
        the membership recount once, silently corrupting ``cnt``.
        """
        es = np.asarray(es, dtype=np.int64)
        if es.size == 0:
            return
        if len(np.unique(es)) != len(es):
            raise ValueError("remove_edges: duplicate edge ids in batch")
        ms = self.assign[es].astype(np.int64)
        if (ms < 0).any():
            bad = es[ms < 0][:8]
            raise ValueError(f"remove_edges: unassigned edge ids {bad}")
        u = self.g.edges[es, 0].astype(np.int64)
        v = self.g.edges[es, 1].astype(np.int64)
        A = np.unique(np.concatenate([u, v]))

        def mutate():
            np.subtract.at(self.cnt, (ms, u), 1)
            np.subtract.at(self.cnt, (ms, v), 1)

        self._recount_columns(A, mutate)
        self.assign[es] = -1
        dm = np.bincount(ms, minlength=self.p).astype(np.float64)
        self.edges_per -= dm
        self.t_cal -= self._c_edge * dm

    def add_edges(self, es: np.ndarray, ms: np.ndarray) -> None:
        """Batch ``add_edge``: place es[j] on machine ms[j].

        Preconditions (``ValueError``, never a stripped-out ``assert``):
        every id must be currently unassigned, machines in ``[0, p)``, and
        ids unique within the batch — a duplicated id would double-count
        in ``np.add.at`` while ``assign[es] = ms`` lands once.
        """
        es = np.asarray(es, dtype=np.int64)
        if es.size == 0:
            return
        ms = np.asarray(ms, dtype=np.int64)
        if es.shape != ms.shape:
            raise ValueError(f"add_edges: {len(es)} edge ids vs "
                             f"{len(ms)} machines")
        if len(np.unique(es)) != len(es):
            raise ValueError("add_edges: duplicate edge ids in batch")
        if ((ms < 0) | (ms >= self.p)).any():
            raise ValueError(f"add_edges: machine ids outside [0, {self.p})")
        if (self.assign[es] != -1).any():
            bad = es[self.assign[es] != -1][:8]
            raise ValueError(f"add_edges: already-assigned edge ids {bad}")
        u = self.g.edges[es, 0].astype(np.int64)
        v = self.g.edges[es, 1].astype(np.int64)
        A = np.unique(np.concatenate([u, v]))

        def mutate():
            np.add.at(self.cnt, (ms, u), 1)
            np.add.at(self.cnt, (ms, v), 1)

        self._recount_columns(A, mutate)
        self.assign[es] = ms
        dm = np.bincount(ms, minlength=self.p).astype(np.float64)
        self.edges_per += dm
        self.t_cal += self._c_edge * dm

    # -- dynamic growth (true insertion) ------------------------------------
    def append_edges(self, uv: np.ndarray) -> np.ndarray:
        """Grow the edge universe: append genuinely-new edges (and any new
        vertices), returning their fresh canonical edge ids — unassigned,
        ready for :meth:`add_edges` or the streaming wave engine.

        This is what ``add_edges`` alone cannot do: its ``assign[es] == -1``
        precondition re-places ids already present in ``self.g.edges``,
        whereas a live insert stream delivers pairs the graph has never
        seen.  Requires the graph to be a :class:`~repro.core.graph.
        GrowableGraph` (build the state over
        ``GrowableGraph.from_graph(g)``); ``uv`` must be canonical
        (``u < v``), loop-free, batch-unique, and absent from the graph —
        the graph's id index enforces absence, so a re-inserted deleted
        edge must go through its existing id instead.

        Every per-vertex structure (``cnt`` columns, ``replicas``,
        ``com_sum``) grows with the vertex space, and ``assign`` with the
        edge space, so shapes always match a fresh build.  No cost changes:
        an unassigned edge contributes nothing to Eq. 3/4, so the state
        stays exactly consistent with a fresh ``build`` on the grown graph.
        """
        if not hasattr(self.g, "append"):
            raise ValueError(
                "append_edges needs a growable graph — build the state "
                "over repro.core.graph.GrowableGraph.from_graph(g)")
        uv = np.asarray(uv, dtype=np.int64).reshape(-1, 2)
        if len(uv) == 0:
            return np.empty(0, dtype=np.int64)
        eids = self.g.append(uv)       # validates canonical/unique/absent
        nv = self.g.num_vertices
        if nv > self.cnt.shape[1]:
            grow = nv - self.cnt.shape[1]
            self.cnt = np.pad(self.cnt, ((0, 0), (0, grow)))
            self.replicas = np.pad(self.replicas, (0, grow))
            self.com_sum = np.pad(self.com_sum, (0, grow))
        grow_e = self.g.num_edges - len(self.assign)
        if grow_e > 0:
            self.assign = np.concatenate(
                [self.assign, np.full(grow_e, -1, dtype=self.assign.dtype)])
        return eids

    def placement_scores(self, es: np.ndarray,
                         cands: np.ndarray | None = None):
        """One-gather scoring kernel for the repair waves.

        Returns ``(T, mem, free_u, free_v)``, all (|es|, |cands|): the
        resulting T and memory footprint of adding each edge to each
        candidate (``delta_t_if_added``/``mem_after`` broadcast — every
        entry scored *independently*; wave admission bounds the
        staleness), plus the would-be-new-endpoint masks, so callers need
        no second pass over the ``cnt`` columns (``share = ~free``).
        """
        es = np.asarray(es, dtype=np.int64)
        cands = (np.arange(self.p, dtype=np.int64) if cands is None
                 else np.asarray(cands, dtype=np.int64))
        u = self.g.edges[es, 0].astype(np.int64)
        v = self.g.edges[es, 1].astype(np.int64)
        free_u = self.cnt[np.ix_(cands, u)] == 0          # (c, e)
        free_v = self.cnt[np.ix_(cands, v)] == 0
        c_node = self._c_node[cands][:, None]
        c_com = self._c_com[cands][:, None]
        if self.node_weight is None:
            c_node_u = c_node_v = c_node
        else:   # training-aware Eq. 3: per-endpoint weighted node charge
            c_node_u = c_node * self.node_weight[u][None, :]
            c_node_v = c_node * self.node_weight[v][None, :]
        # same summation order as the scalar oracle: c_edge, +u-term, +v-term
        dt = (self._c_edge[cands][:, None]
              + free_u * (c_node_u + self.replicas[u][None, :] * c_com
                          + self.com_sum[u][None, :])
              + free_v * (c_node_v + self.replicas[v][None, :] * c_com
                          + self.com_sum[v][None, :]))
        new_v = free_u.astype(np.float64) + free_v
        mem = (self.cluster.m_node * (self.verts_per[cands][:, None] + new_v)
               + self.cluster.m_edge * (self.edges_per[cands][:, None] + 1.0))
        return ((self.t_total[cands][:, None] + dt).T, mem.T,
                free_u.T, free_v.T)

    def delta_t_batch(self, es: np.ndarray,
                      cands: np.ndarray | None = None) -> np.ndarray:
        """(|es|, |cands|) resulting T — ``delta_t_if_added`` broadcast."""
        return self.placement_scores(es, cands)[0]

    def mem_after_batch(self, es: np.ndarray,
                        cands: np.ndarray | None = None) -> np.ndarray:
        """(|es|, |cands|) memory footprint — ``mem_after`` broadcast."""
        return self.placement_scores(es, cands)[1]

    # -- block-streaming hooks ---------------------------------------------
    def endpoint_presence(self, u: np.ndarray, v: np.ndarray):
        """(|u|, p) bool pair: is each endpoint already present on machine i.

        The replication term of every streaming scorer reads the shared
        membership matrix (``cnt > 0``) through this one gather — the
        block-stream engine's analogue of ``placement_scores``'s
        ``free_u``/``free_v`` masks (``pres == ~free``).
        """
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        return (self.cnt[:, u] > 0).T, (self.cnt[:, v] > 0).T

    def admit_block(self, u: np.ndarray, v: np.ndarray,
                    es: np.ndarray | None, ms: np.ndarray,
                    verts_delta: np.ndarray | None = None) -> None:
        """Admit one block-stream wave: edge ``es[j] = (u[j], v[j])`` onto
        machine ``ms[j]``.

        Without ``verts_delta`` this routes through ``add_edges`` (full
        Eq. 3/4 accounting).  With it — the engine passes the exact
        per-machine count of new (machine, vertex) cells, computed from
        its wave-leader bits — the admission takes the *light* path: cnt,
        assign, |E_i|/|V_i| and Eq. 3 update exactly, while the Eq. 4
        replica quantities (replicas/com_sum/t_com) go stale until one
        vectorized :meth:`refresh_costs` at stream end.  The streaming
        scorers never read the stale fields mid-stream.
        """
        assert es is not None, "PartitionState admission needs edge ids"
        if verts_delta is None or self.node_weight is not None:
            # the light path charges c_node per new vertex uniformly, which
            # is wrong under a train-weighted Eq. 3 — take the exact path
            self.add_edges(es, ms)
            return
        np.add.at(self.cnt, (ms, u), 1)
        np.add.at(self.cnt, (ms, v), 1)
        self.assign[es] = ms
        dm = np.bincount(ms, minlength=self.p).astype(np.float64)
        self.edges_per += dm
        self.verts_per += verts_delta
        self.t_cal += self._c_edge * dm + self._c_node * verts_delta
        self._costs_stale = True

    def admit_single(self, u: int, v: int, e, i: int,
                     verts_delta: float) -> None:
        """Light-path admission of one edge — the block engine's scalar
        drain calls this per replica-creating edge, so it carries none of
        :meth:`admit_block`'s batch scaffolding.  Same staleness contract:
        Eq. 4 quantities wait for :meth:`refresh_costs`.
        """
        if self.node_weight is not None:
            self.add_edge(int(e), int(i))   # exact path, as in admit_block
            return
        self.cnt[i, u] += 1
        self.cnt[i, v] += 1
        self.assign[e] = i
        self.edges_per[i] += 1.0
        self.verts_per[i] += verts_delta
        self.t_cal[i] += self._c_edge[i] + self._c_node[i] * verts_delta
        self._costs_stale = True

    def train_counts(self, train_mask: np.ndarray) -> np.ndarray:
        """(p,) count of train vertices each machine hosts a member of —
        the numerator of the train-skew metric (max/mean of this)."""
        tm = np.asarray(train_mask, dtype=bool)
        return (self.cnt[:, tm] > 0).sum(axis=1).astype(np.int64)

    def refresh_costs(self) -> None:
        """Rebuild the Eq. 4 quantities after light-path admissions."""
        member = self.cnt > 0
        self.replicas = member.sum(axis=0).astype(np.int64)
        self.com_sum = member.T.astype(np.float64) @ self._c_com
        self.t_com = t_com_from_membership(member, self.replicas,
                                           self.com_sum, self._c_com)
        self._costs_stale = False


# ---------------------------------------------------------------------------
# graph-free membership state for out-of-core edge streams
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StreamMembership:
    """The membership slice of ``PartitionState`` without a ``Graph``.

    Holds exactly the quantities the block-stream scorers read — the
    ``(p, V)`` incidence counts plus |E_i| / |V_i| — so the same engine can
    partition an edge stream that never materializes as a single array
    (``data/io.iter_edge_blocks``).  Update semantics match
    ``PartitionState`` bit for bit: a vertex is a member of machine i iff an
    incident edge is assigned there, and the per-machine totals are float64
    holding exact integers.
    """

    cnt: np.ndarray               # (p, V) int32 incidence counts
    edges_per: np.ndarray         # (p,) float64 |E_i|
    verts_per: np.ndarray         # (p,) float64 |V_i|
    #: spill/dedup accounting when the stream ran with ``dedup="two_pass"``
    #: (a ``repro.data.SpillStats``), else None
    spill_stats: object = None

    @classmethod
    def empty(cls, num_vertices: int, p: int) -> "StreamMembership":
        return cls(cnt=np.zeros((p, num_vertices), dtype=np.int32),
                   edges_per=np.zeros(p, dtype=np.float64),
                   verts_per=np.zeros(p, dtype=np.float64))

    @property
    def p(self) -> int:
        return len(self.edges_per)

    def endpoint_presence(self, u: np.ndarray, v: np.ndarray):
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        return (self.cnt[:, u] > 0).T, (self.cnt[:, v] > 0).T

    def admit_block(self, u: np.ndarray, v: np.ndarray,
                    es: np.ndarray | None, ms: np.ndarray,
                    verts_delta: np.ndarray | None = None) -> None:
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        ms = np.asarray(ms, dtype=np.int64)
        if verts_delta is None:         # recount the touched columns
            A = np.unique(np.concatenate([u, v]))
            before = (self.cnt[:, A] > 0).sum(axis=1)
            np.add.at(self.cnt, (ms, u), 1)
            np.add.at(self.cnt, (ms, v), 1)
            after = (self.cnt[:, A] > 0).sum(axis=1)
            verts_delta = (after - before).astype(np.float64)
        else:                           # engine-supplied exact delta
            np.add.at(self.cnt, (ms, u), 1)
            np.add.at(self.cnt, (ms, v), 1)
        self.verts_per += verts_delta
        self.edges_per += np.bincount(ms, minlength=self.p).astype(np.float64)

    def admit_single(self, u: int, v: int, e, i: int,
                     verts_delta: float) -> None:
        """One-edge admission without the batch scaffolding (scalar drain).

        ``e`` is accepted for signature parity with ``PartitionState`` and
        ignored — the stream state tracks no per-edge assignment."""
        self.cnt[i, u] += 1
        self.cnt[i, v] += 1
        self.edges_per[i] += 1.0
        self.verts_per[i] += verts_delta

    # -- delta exchange (the parallel-scoring epoch barrier) -----------------
    def totals(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-machine ``(|E_i|, |V_i|)`` snapshot — the scalar half of the
        epoch-barrier payload workers exchange in ``core/parallel.py``."""
        return self.edges_per.copy(), self.verts_per.copy()

    def apply_admissions(self, u: np.ndarray, v: np.ndarray,
                         ms: np.ndarray) -> None:
        """Merge admissions recorded on another replica of this state.

        Routes through :meth:`admit_block`'s recount path, which derives
        the exact per-machine ``|V_i|`` delta from the incidence counts —
        every replica that applies the same admission sequence from equal
        state lands on bitwise-equal state, which is the invariant the
        parallel scoring pipeline's epoch barrier relies on.
        """
        self.admit_block(u, v, None, ms, verts_delta=None)

    def revert_admissions(self, u: np.ndarray, v: np.ndarray,
                          ms: np.ndarray,
                          verts_delta: np.ndarray) -> None:
        """Exact integer inverse of admissions previously applied here.

        ``verts_delta`` must be the per-machine ``|V_i|`` delta those
        admissions actually produced (the admission log records it);
        incidence counts and totals subtract back to their prior values
        exactly — all updates are integer-valued, so no float drift.
        """
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        ms = np.asarray(ms, dtype=np.int64)
        np.subtract.at(self.cnt, (ms, u), 1)
        np.subtract.at(self.cnt, (ms, v), 1)
        self.edges_per -= np.bincount(ms, minlength=self.p).astype(np.float64)
        self.verts_per -= verts_delta

    @property
    def replicas(self) -> np.ndarray:
        """(V,) |S(v)| — derived, for end-of-stream RF reporting."""
        return (self.cnt > 0).sum(axis=0)

    def replication_factor(self) -> float:
        r = self.replicas
        covered = r > 0
        return float(r[covered].sum() / max(1, covered.sum()))
