"""Partition expansion via best-first search (paper Algorithms 2-3).

For machine i with capacity δ_i we grow edge set E_i by repeatedly expanding
the boundary vertex minimizing

    w(v) = (1+α)|N(v)\\S| − (α + I_B(v)·β)|N(v)|            (paper Eq. 5)

where S is the boundary set, C ⊆ S the core set (all remaining edges
consumed) and B the global border set.  Neighborhoods are taken in the
working graph of partition i — ``E(G) \\ Σ_{j<i} E_j`` (the input of
Algorithm 2) — frozen at the start of this partition's expansion:

* |N(v)|   = remaining degree of v when partition i starts;
* |N(v)\\S| = those neighbors not yet in S (edges assigned *during* this
  partition count toward cohesion |N(v)∩S|; edges consumed by earlier
  partitions never count).

Expanding x (AllocEdges, Alg. 3) pulls every unassigned neighbor y of x
into S and assigns all unassigned edges between y and S.  Invariant: within
one partition's expansion, every unassigned edge incident to S leads
outside S.

Complexity: O(|E_i| + |V_i| log |V_i|) per partition via a lazy min-heap
(the paper's Min-Heap optimization); set membership via uint8 bitmaps (the
paper's bitmap optimization).  Per-vertex neighborhood work is numpy-
vectorized.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .graph import Graph


@dataclasses.dataclass
class ExpansionState:
    """Shared state across the p sequential partition expansions."""

    g: Graph
    epoch: np.ndarray             # (E,) int32: partition that took e, -1 free
    rem_deg: np.ndarray           # (V,) int64: unassigned incident edges
    in_border: np.ndarray         # (V,) uint8: B, replicated-vertex set
    seed_heap: list               # lazy (rem_deg, v) heap for vertexSelection
    unassigned_edges: int

    @classmethod
    def fresh(cls, g: Graph) -> "ExpansionState":
        deg = g.degree().astype(np.int64)
        heap = [(int(d), int(v)) for v, d in enumerate(deg) if d > 0]
        heapq.heapify(heap)
        return cls(
            g=g,
            epoch=np.full(g.num_edges, -1, dtype=np.int32),
            rem_deg=deg.copy(),
            in_border=np.zeros(g.num_vertices, dtype=np.uint8),
            seed_heap=heap,
            unassigned_edges=g.num_edges,
        )

    @property
    def assigned(self) -> np.ndarray:
        return self.epoch >= 0


def _vertex_selection(st: ExpansionState, in_s: np.ndarray) -> int:
    """Pick a fresh seed: minimum remaining degree among untouched vertices."""
    h = st.seed_heap
    while h:
        d, v = h[0]
        rd = st.rem_deg[v]
        if rd <= 0 or in_s[v]:
            heapq.heappop(h)
            continue
        if rd != d:  # stale priority: refresh lazily
            heapq.heapreplace(h, (int(rd), v))
            continue
        return v
    return -1


def expand_partition(
    st: ExpansionState,
    part_id: int,
    delta: int,
    alpha: float,
    beta: float,
    *,
    memory_limit: float | None = None,
    m_node: float = 1.0,
    m_edge: float = 2.0,
    record_order: list | None = None,
) -> np.ndarray:
    """Grow one partition of up to ``delta`` edges; returns its edge ids.

    If ``memory_limit`` is given, expansion stops early once the *actual*
    memory footprint m_node·|V_i| + m_edge·|E_i| would exceed it (the δ from
    preprocessing bounds it only through the |V|/|E| estimate).
    """
    g, V = st.g, st.g.num_vertices
    indptr, indices, eids = g.indptr, g.indices, g.edge_ids
    epoch, rem_deg, in_border = st.epoch, st.rem_deg, st.in_border
    in_s = np.zeros(V, dtype=np.uint8)
    in_c = np.zeros(V, dtype=np.uint8)
    deg0 = rem_deg.copy()                   # |N(v)| in this partition's graph
    ext = deg0.copy()                       # |N(v)\S|, starts at |N(v)|
    edge_list: list[int] = []
    heap: list[tuple[float, int]] = []
    w_cur = np.zeros(V, dtype=np.float64)
    n_vertices = 0
    target = int(delta)

    def join_s(y: int) -> None:
        """Add y to S; assign all unassigned y→S edges (vectorized)."""
        nonlocal n_vertices
        in_s[y] = 1
        n_vertices += 1
        nb = indices[indptr[y]:indptr[y + 1]]
        es = eids[indptr[y]:indptr[y + 1]]
        live = epoch[es] == -1              # edges still in the working graph
        nb_live, es_live = nb[live], es[live]
        ext[nb_live] -= 1                   # y entered S (working-graph nbrs)
        s_nb = in_s[nb_live] == 1
        e_new, z_new = es_live[s_nb], nb_live[s_nb]
        room = target - len(edge_list)
        if len(e_new) > room:               # respect δ_i exactly (Alg.3 L8)
            e_new, z_new = e_new[:room], z_new[:room]
        if len(e_new):
            epoch[e_new] = part_id
            rem_deg[z_new] -= 1
            rem_deg[y] -= len(e_new)
            st.unassigned_edges -= len(e_new)
            edge_list.extend(e_new.tolist())
        # Refresh frontier priorities for affected S\C vertices (incl. y).
        front = nb_live[s_nb & (in_c[nb_live] == 0)]
        if in_c[y] == 0:
            front = np.append(front, y)
        if len(front):
            ws = ((1.0 + alpha) * ext[front]
                  - (alpha + beta * in_border[front]) * deg0[front])
            w_cur[front] = ws
            for w, v in zip(ws.tolist(), front.tolist()):
                heapq.heappush(heap, (w, v))

    while len(edge_list) < target and st.unassigned_edges > 0:
        if memory_limit is not None and (
                m_node * (n_vertices + 1) + m_edge * (len(edge_list) + 1)
                > memory_limit + 1e-9):
            break
        # --- select the expansion vertex x (Alg.2 L4-7) -------------------
        x = -1
        while heap:
            w, v = heap[0]
            if in_c[v] or not in_s[v] or w != w_cur[v]:
                heapq.heappop(heap)        # stale or consumed
                continue
            x = v
            break
        if x == -1:
            x = _vertex_selection(st, in_s)
            if x == -1:
                break                      # nothing expandable remains
            join_s(x)
            if len(edge_list) >= target:
                in_c[x] = 1
                break
        # --- AllocEdges(C, S, E_i, x, E) (Alg.3) ---------------------------
        in_c[x] = 1
        sl = slice(indptr[x], indptr[x + 1])
        nbs = indices[sl][epoch[eids[sl]] == -1]     # unassigned edges only
        for y in nbs[in_s[nbs] == 0].tolist():
            if in_s[y]:                    # joined via an earlier sibling
                continue
            join_s(y)
            if len(edge_list) >= target:
                break

    # B ← B ∪ (S \ C); plus core vertices that still have remaining edges
    # (they will replicate into later partitions).
    touched = np.flatnonzero(in_s)
    in_border[touched[in_c[touched] == 0]] = 1
    core = touched[in_c[touched] == 1]
    in_border[core[rem_deg[core] > 0]] = 1
    if record_order is not None:
        record_order.extend(edge_list)
    return np.asarray(edge_list, dtype=np.int64)


def run_expansion(
    g: Graph,
    deltas: np.ndarray,
    alpha: float = 0.3,
    beta: float = 0.3,
    *,
    memories: np.ndarray | None = None,
    m_node: float = 1.0,
    m_edge: float = 2.0,
    order: str = "asc_capacity",
    state: ExpansionState | None = None,
) -> tuple[np.ndarray, list[list[int]]]:
    """Run Algorithm 2 for every machine; returns (assign, per-part order).

    assign[e] = machine id, or -1 if the edge could not be placed under the
    memory guard (callers must repair; WindGP's driver does).
    ``order`` controls the machine visit order; ascending capacity keeps the
    big-capacity machines for last so they absorb the irregular tail.
    """
    p = len(deltas)
    st = state if state is not None else ExpansionState.fresh(g)
    orders: list[list[int]] = [[] for _ in range(p)]
    if order == "asc_capacity":
        visit = np.argsort(np.asarray(deltas), kind="stable")
    elif order == "desc_capacity":
        visit = np.argsort(-np.asarray(deltas), kind="stable")
    else:
        visit = np.arange(p)
    for i in visit:
        lim = None if memories is None else float(memories[i])
        rec: list[int] = []
        expand_partition(
            st, int(i), int(deltas[i]), alpha, beta,
            memory_limit=lim, m_node=m_node, m_edge=m_edge, record_order=rec)
        orders[int(i)] = rec
        if st.unassigned_edges == 0:
            break
    assign = st.epoch.astype(np.int32).copy()
    return assign, orders
