"""Partition expansion via best-first search (paper Algorithms 2-3).

For machine i with capacity δ_i we grow edge set E_i by repeatedly expanding
the boundary vertex minimizing

    w(v) = (1+α)|N(v)\\S| − (α + I_B(v)·β)|N(v)|            (paper Eq. 5)

where S is the boundary set, C ⊆ S the core set (all remaining edges
consumed) and B the global border set.  Neighborhoods are taken in the
working graph of partition i — ``E(G) \\ Σ_{j<i} E_j`` (the input of
Algorithm 2) — frozen at the start of this partition's expansion:

* |N(v)|   = remaining degree of v when partition i starts;
* |N(v)\\S| = those neighbors not yet in S (edges assigned *during* this
  partition count toward cohesion |N(v)∩S|; edges consumed by earlier
  partitions never count).

Expanding x (AllocEdges, Alg. 3) pulls every unassigned neighbor y of x
into S and assigns all unassigned edges between y and S.  Invariant: within
one partition's expansion, every unassigned edge incident to S leads
outside S.

Two engines implement the same expansion:

* ``engine="heap"`` — the reference oracle: a per-vertex lazy min-heap
  (the paper's Min-Heap optimization), O(|E_i| + |V_i| log |V_i|) per
  partition, but interpreter-bound (one Python iteration per expansion
  vertex, one ``heappush`` per touched neighbor).
* ``engine="batched"`` — the production engine: Eq. 5 scores are quantized
  to integers (``w·QUANT_SCALE`` with exactly-linear integer coefficients,
  so the ordering matches the float heap whenever ``(1+α)·scale`` and
  ``(α+β)·scale`` are integral) and kept *fresh* in a per-vertex array;
  each step scans the live frontier (a duplicate-tolerant id buffer with
  geometric compaction — see ``_FrontierBuffer``) and expands the whole
  best-score-window slice with fully vectorized AllocEdges — no
  per-neighbor Python work.  ``strict_ties=True`` degrades the slice to
  one vertex per step (min id at the best score), which makes the batched
  engine bit-identical to the heap oracle whenever the quantization is
  exact — the equivalence tests rely on this.  Adjacency access is
  degree-split (``hub_split``/``hub_degree``): a row gathered alone is a
  zero-copy CSR view, and hub-dominated multi-row gathers copy dense
  contiguous row slices while the tail keeps the ragged flat-index
  gather — identical output, far less index arithmetic on power-law
  graphs.

Set membership is uint8 bitmaps (the paper's bitmap optimization) in both.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .graph import Graph
from .partition_state import WorkingCSR

#: Integer score quantization for the batched engine: q(v) =
#: round((1+α)·S)·ext(v) − round((α+β·I_B(v))·S)·deg0(v).  64 keeps the
#: coefficients exact for α, β that are multiples of 1/64 (incl. 0.25, 0.5)
#: and within ~1% for the paper's α=0.1/0.3 — coefficient fidelity is what
#: keeps the batched TC close to the oracle; bucket merging comes from the
#: admission window, not from coarse quantization.
QUANT_SCALE = 64

ENGINES = ("heap", "batched")


@dataclasses.dataclass
class ExpansionState:
    """Shared state across the p sequential partition expansions."""

    g: Graph
    epoch: np.ndarray             # (E,) int32: partition that took e, -1 free
    rem_deg: np.ndarray           # (V,) int64: unassigned incident edges
    in_border: np.ndarray         # (V,) uint8: B, replicated-vertex set
    seed_heap: list | None        # lazy (rem_deg, v) heap for vertexSelection
    unassigned_edges: int
    # Working CSR for the batched engine: the live (unassigned) slice of
    # g's adjacency, recompacted geometrically as partitions consume edges
    # (shared compaction machinery: ``partition_state.WorkingCSR``).
    wcsr: WorkingCSR | None = None

    @classmethod
    def fresh(cls, g: Graph) -> "ExpansionState":
        deg = g.degree().astype(np.int64)
        return cls(
            g=g,
            epoch=np.full(g.num_edges, -1, dtype=np.int32),
            rem_deg=deg.copy(),
            in_border=np.zeros(g.num_vertices, dtype=np.uint8),
            seed_heap=None,   # built on first _vertex_selection call
            unassigned_edges=g.num_edges,
        )

    def working_csr(self, compact_below: float = 0.75):
        """(indptr, indices, eids) of the live adjacency, recompacting when
        fewer than ``compact_below`` of the stored entries are still live."""
        if self.wcsr is None:
            self.wcsr = WorkingCSR.from_graph(self.g)
        return self.wcsr.view(lambda: self.epoch == -1,
                              self.unassigned_edges,
                              compact_below=compact_below)

    @property
    def assigned(self) -> np.ndarray:
        return self.epoch >= 0


def _vertex_selection(st: ExpansionState, in_s: np.ndarray) -> int:
    """Pick a fresh seed: minimum remaining degree among untouched vertices."""
    if st.seed_heap is None:
        st.seed_heap = [(int(d), int(v))
                        for v, d in enumerate(st.rem_deg) if d > 0]
        heapq.heapify(st.seed_heap)
    h = st.seed_heap
    while h:
        d, v = h[0]
        rd = st.rem_deg[v]
        if rd <= 0 or in_s[v]:
            heapq.heappop(h)
            continue
        if rd != d:  # stale priority: refresh lazily
            heapq.heapreplace(h, (int(rd), v))
            continue
        return v
    return -1


def expand_partition(
    st: ExpansionState,
    part_id: int,
    delta: int,
    alpha: float,
    beta: float,
    *,
    memory_limit: float | None = None,
    m_node: float = 1.0,
    m_edge: float = 2.0,
    record_order: list | None = None,
    engine: str = "heap",
    **engine_kw,
) -> np.ndarray:
    """Grow one partition of up to ``delta`` edges; returns its edge ids.

    If ``memory_limit`` is given, expansion stops early once the *actual*
    memory footprint m_node·|V_i| + m_edge·|E_i| would exceed it (the δ from
    preprocessing bounds it only through the |V|/|E| estimate).

    ``engine`` selects the scalar heap oracle or the batched bucket-queue
    engine (see module docstring); extra kwargs go to the batched engine.
    """
    if engine == "batched":
        return expand_partition_batched(
            st, part_id, delta, alpha, beta, memory_limit=memory_limit,
            m_node=m_node, m_edge=m_edge, record_order=record_order,
            **engine_kw)
    if engine != "heap":
        raise ValueError(f"unknown expansion engine {engine!r}")
    if engine_kw:
        raise TypeError(
            f"engine='heap' takes no engine kwargs; got {sorted(engine_kw)}")
    return _expand_partition_heap(
        st, part_id, delta, alpha, beta, memory_limit=memory_limit,
        m_node=m_node, m_edge=m_edge, record_order=record_order)


def _expand_partition_heap(
    st: ExpansionState,
    part_id: int,
    delta: int,
    alpha: float,
    beta: float,
    *,
    memory_limit: float | None = None,
    m_node: float = 1.0,
    m_edge: float = 2.0,
    record_order: list | None = None,
) -> np.ndarray:
    """The scalar lazy-min-heap reference engine (paper Algorithms 2-3)."""
    g, V = st.g, st.g.num_vertices
    indptr, indices, eids = g.indptr, g.indices, g.edge_ids
    epoch, rem_deg, in_border = st.epoch, st.rem_deg, st.in_border
    in_s = np.zeros(V, dtype=np.uint8)
    in_c = np.zeros(V, dtype=np.uint8)
    deg0 = rem_deg.copy()                   # |N(v)| in this partition's graph
    ext = deg0.copy()                       # |N(v)\S|, starts at |N(v)|
    edge_list: list[int] = []
    heap: list[tuple[float, int]] = []
    w_cur = np.zeros(V, dtype=np.float64)
    n_vertices = 0
    target = int(delta)

    def join_s(y: int) -> None:
        """Add y to S; assign all unassigned y→S edges (vectorized)."""
        nonlocal n_vertices
        in_s[y] = 1
        n_vertices += 1
        nb = indices[indptr[y]:indptr[y + 1]]
        es = eids[indptr[y]:indptr[y + 1]]
        live = epoch[es] == -1              # edges still in the working graph
        nb_live, es_live = nb[live], es[live]
        ext[nb_live] -= 1                   # y entered S (working-graph nbrs)
        s_nb = in_s[nb_live] == 1
        e_new, z_new = es_live[s_nb], nb_live[s_nb]
        room = target - len(edge_list)
        if len(e_new) > room:               # respect δ_i exactly (Alg.3 L8)
            e_new, z_new = e_new[:room], z_new[:room]
        if len(e_new):
            epoch[e_new] = part_id
            rem_deg[z_new] -= 1
            rem_deg[y] -= len(e_new)
            st.unassigned_edges -= len(e_new)
            edge_list.extend(e_new.tolist())
        # Refresh frontier priorities for affected S\C vertices (incl. y).
        front = nb_live[s_nb & (in_c[nb_live] == 0)]
        if in_c[y] == 0:
            front = np.append(front, y)
        if len(front):
            ws = ((1.0 + alpha) * ext[front]
                  - (alpha + beta * in_border[front]) * deg0[front])
            w_cur[front] = ws
            for w, v in zip(ws.tolist(), front.tolist()):
                heapq.heappush(heap, (w, v))

    while len(edge_list) < target and st.unassigned_edges > 0:
        if memory_limit is not None and (
                m_node * (n_vertices + 1) + m_edge * (len(edge_list) + 1)
                > memory_limit + 1e-9):
            break
        # --- select the expansion vertex x (Alg.2 L4-7) -------------------
        x = -1
        while heap:
            w, v = heap[0]
            if in_c[v] or not in_s[v] or w != w_cur[v]:
                heapq.heappop(heap)        # stale or consumed
                continue
            x = v
            break
        if x == -1:
            x = _vertex_selection(st, in_s)
            if x == -1:
                break                      # nothing expandable remains
            join_s(x)
            if len(edge_list) >= target:
                in_c[x] = 1
                break
        # --- AllocEdges(C, S, E_i, x, E) (Alg.3) ---------------------------
        in_c[x] = 1
        sl = slice(indptr[x], indptr[x + 1])
        nbs = indices[sl][epoch[eids[sl]] == -1]     # unassigned edges only
        for y in nbs[in_s[nbs] == 0].tolist():
            if in_s[y]:                    # joined via an earlier sibling
                continue
            join_s(y)
            if len(edge_list) >= target:
                break

    # B ← B ∪ (S \ C); plus core vertices that still have remaining edges
    # (they will replicate into later partitions).
    touched = np.flatnonzero(in_s)
    in_border[touched[in_c[touched] == 0]] = 1
    core = touched[in_c[touched] == 1]
    in_border[core[rem_deg[core] > 0]] = 1
    if record_order is not None:
        record_order.extend(edge_list)
    return np.asarray(edge_list, dtype=np.int64)


# ---------------------------------------------------------------------------
# batched engine
# ---------------------------------------------------------------------------

class _FrontierBuffer:
    """Duplicate-tolerant id buffer over the live frontier (S \\ C).

    The predecessor of this structure was a monotone bucket queue keyed by
    exact quantized score; on skewed graphs the scores are near-unique, so
    every refresh opened ~hundreds of distinct buckets (one dict + heap op
    each) and the queue cost dominated the engine.  This buffer stores
    vertex *ids only* — scores are always read fresh from ``qscore`` at
    scan time, so there is no score staleness at all — and tolerates
    duplicates and departed vertices, compacting geometrically: when the
    live entries fall under half the buffer, or the buffer outgrows twice
    the true frontier, it collapses to ``unique(live)``.  A best-first
    admission step is then one vectorized scan of the live entries.
    """

    __slots__ = ("buf", "pend")

    def __init__(self):
        self.buf = np.zeros(0, dtype=np.int64)
        self.pend: list[np.ndarray] = []

    def push(self, verts: np.ndarray) -> None:
        self.pend.append(verts)

    def live(self, fr: np.ndarray, frontier_size: int) -> np.ndarray:
        """Current live entries (duplicates possible), compacting lazily."""
        if self.pend:
            arrs = ([self.buf] if len(self.buf) else []) + self.pend
            self.buf = arrs[0] if len(arrs) == 1 else np.concatenate(arrs)
            self.pend.clear()
        if not len(self.buf):
            return self.buf
        live = self.buf[fr[self.buf]]
        if (2 * len(live) < len(self.buf)
                or len(self.buf) > 2 * frontier_size + 64):
            self.buf = np.unique(live).astype(np.int64, copy=False)
            return self.buf
        return live


def expand_partition_batched(
    st: ExpansionState,
    part_id: int,
    delta: int,
    alpha: float,
    beta: float,
    *,
    memory_limit: float | None = None,
    m_node: float = 1.0,
    m_edge: float = 2.0,
    record_order: list | None = None,
    scale: int = QUANT_SCALE,
    strict_ties: bool = False,
    batch_target: int = 512,
    batch_frac: float = 0.5,
    batch_window: float = 6.0,
    hub_split: bool = True,
    hub_degree: int = 1024,
) -> np.ndarray:
    """Batched AllocEdges over bucket-queue frontier slices.

    Semantics match ``_expand_partition_heap`` with three deliberate
    deviations: (1) all frontier vertices sharing the best quantized score
    expand in one step (``strict_ties=True`` restores one-at-a-time pops
    for oracle equivalence); (2) successive buckets are drained best-first
    into one slice, bounded three ways — at most ``batch_target`` vertices,
    at most ``batch_frac`` of the live frontier (admitting a large fraction
    at once suppresses the cohesion feedback that makes best-first beat
    BFS, which is what degrades TC on skewed graphs), and only while the
    next bucket's score stays within ``batch_window`` w-units of the
    slice's best (must exceed one ext step, (1+α), to batch across mesh
    wavefronts at all); (3) under ``memory_limit`` the batched engine
    truncates joins so the footprint never exceeds the limit (the heap
    engine only pre-checks and may overshoot within one AllocEdges).

    ``hub_split`` enables the degree-split gather: adjacency rows with
    ≥ ``hub_degree`` stored entries (hubs) are materialized as dense
    contiguous row slices — a memcpy, or a zero-copy view when a row is
    gathered alone — while the tail keeps the ragged flat-index gather;
    the split path engages only when hub rows dominate the gather, where
    skipping their per-slot index arithmetic is a guaranteed win.  It is
    bit-neutral: the assembled output is identical either way (slot order
    preserved), so the split changes *no* engine decision, only its cost.
    """
    g, V = st.g, st.g.num_vertices
    indptr, indices, eids = st.working_csr()
    epoch, rem_deg, in_border = st.epoch, st.rem_deg, st.in_border
    in_s = np.zeros(V, dtype=np.uint8)
    in_c = np.zeros(V, dtype=np.uint8)
    fr = np.zeros(V, dtype=bool)            # frontier bitmap: S \ C
    # int32 score arithmetic is the fast path; fall back to int64 whenever
    # coef·maxdeg could approach 2^31 (huge hubs or a large user scale) —
    # a wrapped score would silently corrupt the best-first order.
    ca = round((1.0 + alpha) * scale)
    cd = round((alpha + beta) * scale)
    maxdeg = int(rem_deg.max(initial=0))
    qdtype = np.int32 if max(ca, cd) * max(1, maxdeg) < 2 ** 30 else np.int64
    deg0 = rem_deg.astype(qdtype)           # |N(v)| in this partition's graph
    ext = deg0.copy()                       # |N(v)\S|, starts at |N(v)|
    coef_a = qdtype(ca)
    coef_d = np.where(in_border != 0, qdtype(cd),
                      qdtype(round(alpha * scale))).astype(qdtype)
    qscore = np.zeros(V, dtype=qdtype)
    fb = _FrontierBuffer()
    rank_buf = np.full(V, -1, dtype=np.int32)   # batch rank scratch
    big = max(64, V // 8)   # ufunc.at beats bincount below this size

    def _dec(arr: np.ndarray, idx: np.ndarray) -> None:
        """arr[idx] -= 1 with repeats; bincount for large index sets."""
        if len(idx) > big:
            arr -= np.bincount(idx, minlength=V).astype(arr.dtype)
        else:
            np.subtract.at(arr, idx, 1)
    chunks: list[np.ndarray] = []
    n_edges = 0
    n_vertices = 0
    n_core = 0
    target = int(delta)
    window_q = int(round(batch_window * scale))
    def refresh(front: np.ndarray) -> None:
        """Recompute quantized priorities for S\\C vertices and enqueue."""
        qscore[front] = coef_a * ext[front] - coef_d[front] * deg0[front]
        fb.push(front)

    def gather_adj(verts: np.ndarray):
        """Ragged gather of verts' adjacency slices from the working CSR.

        Returns (nb, es, reps, offs): neighbor / edge-id arrays flattened
        in verts order, the owner rank of each flat slot, and each owner's
        start offset into the flat arrays.

        Degree-split: hub rows (≥ hub_degree entries) are copied as dense
        contiguous slices, the tail through the flat-index gather; a lone
        vertex returns zero-copy CSR views.  Output is identical in all
        paths — only the assembly cost differs.
        """
        starts = indptr[verts]
        counts = indptr[verts + 1] - starts
        total = int(counts.sum())
        offs = np.cumsum(counts) - counts
        if len(verts) == 1:             # dense row slice, no copy at all
            s0, s1 = int(starts[0]), int(starts[0] + counts[0])
            return (indices[s0:s1], eids[s0:s1],
                    np.zeros(total, dtype=np.int32), offs)
        reps = np.repeat(np.arange(len(verts), dtype=np.int32), counts)
        hubs = (np.flatnonzero(counts >= hub_degree)
                if hub_split and total >= 4096
                else np.zeros(0, dtype=np.int64))
        # The split pays ~3 index passes on the hub mass against ~1 extra
        # pass on the tail, so engage it only when hub rows dominate.
        if len(hubs) == 0 or 2 * int(counts[hubs].sum()) < total:
            flat = np.arange(total, dtype=np.int64) \
                + np.repeat(starts - offs, counts)
            return indices[flat], eids[flat], reps, offs
        nb = np.empty(total, dtype=indices.dtype)
        es = np.empty(total, dtype=eids.dtype)
        tail = np.ones(len(verts), dtype=bool)
        tail[hubs] = False
        tc, ts, to = counts[tail], starts[tail], offs[tail]
        tt = int(tc.sum())
        if tt:
            w = np.arange(tt, dtype=np.int64) - np.repeat(
                np.cumsum(tc) - tc, tc)
            dest = np.repeat(to, tc) + w
            src = np.repeat(ts, tc) + w
            nb[dest] = indices[src]
            es[dest] = eids[src]
        for j in hubs.tolist():
            o, s, c = int(offs[j]), int(starts[j]), int(counts[j])
            nb[o:o + c] = indices[s:s + c]
            es[o:o + c] = eids[s:s + c]
        return nb, es, reps, offs

    def batch_join(ys: np.ndarray) -> np.ndarray:
        """Vectorized join_s over an *ordered* batch of non-S vertices.

        Replicates the sequential heap semantics: y_j joins iff the edge
        budget (and, batched-only, the memory budget) is not exhausted
        before its turn; its S-incident edges (S = old S ∪ {y_i : i<j})
        assign in adjacency order, truncated exactly at the budget.
        Returns the vertices that actually joined.
        """
        nonlocal n_edges, n_vertices
        k = len(ys)
        if k == 0:
            return ys
        nb, es, reps, offs = gather_adj(ys)
        live = epoch[es] == -1
        rank_buf[ys] = np.arange(k, dtype=np.int32)
        rnb = rank_buf[nb]
        # assignable: live edge into old S, or into an earlier batch member
        cand = live & ((in_s[nb] == 1) | ((rnb >= 0) & (rnb < reps)))
        cum = np.cumsum(cand, dtype=np.int32)
        # candidates strictly before each owner's adjacency slice
        owner_before = cum[offs] - cand[offs]
        room = target - n_edges
        n_join = int(np.searchsorted(owner_before, room, side="left"))
        e_allowed = room
        if memory_limit is not None:
            # vertex feasibility: owner j joins only while the footprint of
            # (nv + j + 1) vertices plus the edges already taken fits.
            fits = m_node * (n_vertices + np.arange(1, k + 1)) \
                + m_edge * (n_edges + np.minimum(owner_before, room))
            n_fit = int(np.searchsorted(fits, memory_limit + 1e-9,
                                        side="right"))
            n_join = min(n_join, n_fit)
            if n_join > 0:
                e_allowed = min(room, int(
                    (memory_limit + 1e-9
                     - m_node * (n_vertices + n_join)) // m_edge) - n_edges)
        if n_join <= 0:
            rank_buf[ys] = -1
            return ys[:0]
        jmask = reps < n_join
        lj = live if n_join == k else live & jmask
        sel = cand & (cum <= e_allowed) if n_join == k \
            else cand & jmask & (cum <= e_allowed)
        joined = ys[:n_join]
        in_s[joined] = 1
        fr[joined] = True
        n_vertices += n_join
        # y entering S: every live working-graph neighbor loses one ext link
        nbl = nb[lj]
        _dec(ext, nbl)
        e_sel = es[sel]
        z_sel = None
        if len(e_sel):
            z_sel = nb[sel]
            y_sel = ys[reps[sel]]
            epoch[e_sel] = part_id
            _dec(rem_deg, z_sel)
            _dec(rem_deg, y_sel)
            st.unassigned_edges -= len(e_sel)
            n_edges += len(e_sel)
            chunks.append(e_sel)
        # refresh every touched frontier vertex (joiners included).  On the
        # fast path the frontier members whose ext changed are exactly the
        # in-S endpoints of the assigned edges (an unassigned live edge
        # into S only survives a *truncated* batch, which ends the
        # partition); strict mode mirrors the heap's full-neighborhood
        # refresh bit for bit.
        if strict_ties:
            front = np.concatenate([nbl, joined.astype(nbl.dtype)])
        elif z_sel is not None:
            front = np.concatenate([z_sel, joined.astype(z_sel.dtype)])
        else:
            front = joined
        front = front[fr[front]]
        rank_buf[ys] = -1
        if len(front):
            refresh(np.unique(front))
        return joined

    while n_edges < target and st.unassigned_edges > 0:
        if memory_limit is not None and (
                m_node * (n_vertices + 1) + m_edge * (n_edges + 1)
                > memory_limit + 1e-9):
            break
        # --- select the expansion slice (Alg.2 L4-7, batched) -------------
        # One scan of the live frontier (buffer + hub side array): take
        # every vertex within ``window_q`` of the best current score, in
        # (score, vertex id) order, capped.  Scores are read fresh from
        # ``qscore``, so the admitted set equals what draining an exact
        # best-first queue would admit — there is nothing stale to skip.
        X = None
        cap = 1 if strict_ties else max(
            1, min(batch_target, int((n_vertices - n_core) * batch_frac)))
        live = fb.live(fr, n_vertices - n_core)
        if len(live):
            ql = qscore[live]
            s_best = int(ql.min())
            thr = s_best if strict_ties else s_best + window_q
            cand = np.unique(live[ql <= thr]).astype(np.int64, copy=False)
            if strict_ties:
                X = cand[:1]           # all at s_best; sorted ⇒ min id,
                                       # the heap oracle's tie-break
            elif len(cand) > cap:
                order = np.argsort(qscore[cand], kind="stable")
                X = np.sort(cand[order[:cap]])
            else:
                X = cand
        if X is None:
            if strict_ties:
                x = _vertex_selection(st, in_s)
            else:
                # vectorized seed scan: exact min (rem_deg, v); avoids
                # materializing the shared lazy heap on the fast path
                d = np.where((rem_deg > 0) & (in_s == 0), rem_deg,
                             np.iinfo(np.int64).max)
                x = int(d.argmin())
                if d[x] == np.iinfo(np.int64).max:
                    x = -1
            if x == -1:
                break                      # nothing expandable remains
            X = np.array([x], dtype=np.int64)
            batch_join(X)
            if n_edges >= target:
                in_c[X] = 1
                fr[X] = False
                n_core += 1
                break
        # --- AllocEdges over the whole slice (Alg.3, batched) -------------
        in_c[X] = 1
        fr[X] = False
        n_core += len(X)
        nbs, ess, _, _ = gather_adj(X)
        open_nb = nbs[(epoch[ess] == -1) & (in_s[nbs] == 0)]
        if len(open_nb):
            # first-occurrence dedup keeps the heap engine's join order
            _, first = np.unique(open_nb, return_index=True)
            batch_join(open_nb[np.sort(first)])

    # B ← B ∪ (S \ C); plus core vertices that still have remaining edges
    # (they will replicate into later partitions).
    touched = np.flatnonzero(in_s)
    in_border[touched[in_c[touched] == 0]] = 1
    core = touched[in_c[touched] == 1]
    in_border[core[rem_deg[core] > 0]] = 1
    edge_list = (np.concatenate(chunks).astype(np.int64) if chunks
                 else np.zeros(0, dtype=np.int64))
    if record_order is not None:
        record_order.extend(edge_list.tolist())
    return edge_list


def run_expansion(
    g: Graph,
    deltas: np.ndarray,
    alpha: float = 0.3,
    beta: float = 0.3,
    *,
    memories: np.ndarray | None = None,
    m_node: float = 1.0,
    m_edge: float = 2.0,
    order: str = "asc_capacity",
    state: ExpansionState | None = None,
    engine: str = "heap",
    **engine_kw,
) -> tuple[np.ndarray, list[list[int]]]:
    """Run Algorithm 2 for every machine; returns (assign, per-part order).

    assign[e] = machine id, or -1 if the edge could not be placed under the
    memory guard (callers must repair; WindGP's driver does).
    ``order`` controls the machine visit order; ascending capacity keeps the
    big-capacity machines for last so they absorb the irregular tail.
    ``engine`` picks the expansion implementation (see module docstring).
    """
    p = len(deltas)
    st = state if state is not None else ExpansionState.fresh(g)
    orders: list[list[int]] = [[] for _ in range(p)]
    if order == "asc_capacity":
        visit = np.argsort(np.asarray(deltas), kind="stable")
    elif order == "desc_capacity":
        visit = np.argsort(-np.asarray(deltas), kind="stable")
    else:
        visit = np.arange(p)
    for i in visit:
        lim = None if memories is None else float(memories[i])
        rec: list[int] = []
        expand_partition(
            st, int(i), int(deltas[i]), alpha, beta,
            memory_limit=lim, m_node=m_node, m_edge=m_edge, record_order=rec,
            engine=engine, **engine_kw)
        orders[int(i)] = rec
        if st.unassigned_edges == 0:
            break
    assign = st.epoch.astype(np.int32).copy()
    return assign, orders
