"""Unified partitioner registry: one surface over every partitioning method.

Historically each consumer (the launch CLI, the benchmark tables, the BSP
runtime packer, the examples) kept its own ad-hoc ``PARTITIONERS`` dict and
special-cased WindGP.  This module is the single home: every method —
streaming baselines, NE, the METIS-like multilevel scheme, and the WindGP
driver variants — registers a :class:`Partitioner` record carrying its
name, kind, capability tags, and accepted knobs, and consumers resolve
methods through :func:`get`/:func:`names` instead of hand-rolled dicts.

Capability tags in use:

* ``heterogeneous`` — optimizes the paper's heterogeneous TC objective
  (all methods get the memory-cap adaptation regardless).
* ``blocked``  — streams edges through the block engine; accepts
  ``block_size`` and can run graph-free over an edge-block iterator.
* ``streamable`` — carries a graph-free ``stream`` entry point
  (``stream(source, |V|, |E|, cluster, **knobs)``) that partitions an
  edge-list path or block iterator out of core; its ``dedup`` knob picks
  single-pass per-block dedup (``"block"``) or the exact two-pass
  spill-to-disk dedup (``"two_pass"``).
* ``parallel`` — the ``stream`` entry accepts ``workers``/``sync_blocks``
  and can run the W-process pipeline (``core/parallel.py``): sharded
  two-pass dedup plus parallel wave scoring against membership snapshots
  synced every ``sync_blocks`` engine blocks.  ``workers=1`` is the
  sequential path bit for bit; results at any worker count depend only
  on ``sync_blocks``.
* ``oracle``   — per-edge reference loop kept for equivalence tests;
  excluded from the default benchmark surface.
* ``driver``   — full multi-phase driver (WindGP), returns via
  ``windgp(...)`` internally and exposes its knobs.

Implementations self-register at import; :func:`_ensure_builtin` makes any
entry point (CLI, benchmarks, tests) see the full set without import-order
footguns.  The legacy ``repro.core.baselines.PARTITIONERS`` dict is now a
snapshot of this registry (oracles excluded).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

import numpy as np

_REGISTRY: dict[str, "Partitioner"] = {}


@dataclasses.dataclass(frozen=True)
class Partitioner:
    """One registered partitioning method.

    ``fn(g, cluster, **knobs) -> (E,) int assignment``; calling the record
    itself validates knob names first, so CLI/benchmark typos fail loudly
    instead of landing in ``**kwargs`` silence.
    """

    name: str
    fn: Callable[..., np.ndarray]
    kind: str                       # streaming | expansion | multilevel | driver
    description: str = ""
    capabilities: frozenset = frozenset()
    knobs: tuple = ()               # accepted keyword-knob names
    stream_fn: Callable | None = None   # graph-free out-of-core entry
    stream_knobs: tuple = ()            # keyword-knob names it accepts

    def _knob_error(self, unknown: set, valid: tuple,
                    entry: str = "") -> TypeError:
        """Unknown-knob error naming the partitioner and its valid knobs."""
        what = (f"valid knobs for {self.name!r}{entry}: {sorted(valid)}"
                if valid else f"{self.name!r}{entry} accepts no knobs")
        return TypeError(
            f"partitioner {self.name!r}{entry} got unknown knob(s) "
            f"{sorted(unknown)}; {what}")

    def __call__(self, g, cluster, **kw) -> np.ndarray:
        unknown = set(kw) - set(self.knobs)
        if unknown:
            raise self._knob_error(unknown, self.knobs)
        return self.fn(g, cluster, **kw)

    def stream(self, source, num_vertices=None, num_edges=None,
               cluster=None, **kw):
        """Graph-free out-of-core run (``streamable`` capability only).

        ``source`` is an edge-list path, a block iterator, or a prepared
        ``TwoPassDedup``; returns the end-of-stream ``StreamMembership``.
        """
        if self.stream_fn is None:
            raise TypeError(
                f"partitioner {self.name!r} cannot stream "
                f"(capabilities: {sorted(self.capabilities)})")
        unknown = set(kw) - set(self.stream_knobs)
        if unknown:
            raise self._knob_error(unknown, self.stream_knobs, " stream")
        return self.stream_fn(source, num_vertices, num_edges, cluster, **kw)

    def supports(self, capability: str) -> bool:
        return capability in self.capabilities


def register(p: Partitioner) -> Partitioner:
    """Add (or replace, e.g. in tests) a registry entry."""
    _REGISTRY[p.name] = p
    return p


def _ensure_builtin() -> None:
    # Deferred so the registry module itself stays import-cycle-free: the
    # implementations import ``register`` from here at their module bottom.
    from . import windgp      # noqa: F401  (registers driver variants)
    from . import baselines   # noqa: F401  (registers streaming/ne/metis)


def get(name: str) -> Partitioner:
    _ensure_builtin()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown partitioner {name!r}; registered: {names()}") from None


def names(*, require: Iterable[str] = (),
          exclude: Iterable[str] = ()) -> list[str]:
    """Sorted registered names, filtered by capability tags."""
    _ensure_builtin()
    req, exc = set(require), set(exclude)
    return sorted(n for n, p in _REGISTRY.items()
                  if req <= p.capabilities and not (exc & p.capabilities))


def partitioner_dict(*, exclude: Iterable[str] = ()) -> dict[str, Partitioner]:
    """Snapshot ``{name: partitioner}`` — the legacy-dict compatibility view."""
    return {n: get(n) for n in names(exclude=exclude)}


def run(name: str, g, cluster, **knobs) -> np.ndarray:
    """Resolve and run in one call."""
    return get(name)(g, cluster, **knobs)
