"""Post-processing: Subgraph-Local Search (paper Algorithms 4-7).

Two operators over the current edge assignment:

* **destroy-and-repair** (Alg. 5): machines with T_i above the γ-quantile
  threshold lose a θ-fraction of their edges (last-in-first-out, preserving
  connectivity of what stays), which are re-inserted by
  BalancedGreedyRepair (Alg. 6) preferring machines already holding both
  endpoints, then either endpoint, then anybody — always the feasible
  machine with the lowest resulting T.
* **re-partition** (Alg. 7): on N0 consecutive non-improvements, the worst
  machine and its k-1 largest-replica-overlap peers are merged and re-expanded
  with Algorithm 2 to escape local optima.

All objective updates run through the shared incremental layer
(``core/partition_state.py``).  The repair sweep is *vectorized*: every
removed edge is scored against every machine in one broadcast
(``delta_t_batch``) and repairs are admitted in waves — the best-scoring
fraction of the pending edges per wave, with a conservative per-machine
memory prefix so caps are never violated — mirroring the batched expansion
engine's score-window admission.  State updates per wave are exact
(wave-local recount); only the *scores* of not-yet-admitted edges go stale
within a wave, which is the same deliberate approximation the batched
engine makes.  ``strict=True`` degrades to one edge per wave in removal
order, which reproduces the scalar oracle decision-for-decision (integer
cost arithmetic makes both paths bit-exact) — the equivalence tests rely
on this, like the expansion engine's ``strict_ties``.
"""
from __future__ import annotations

import numpy as np

from . import expand
from .graph import Graph, from_edge_list
from .machines import Cluster
from .partition_state import PartitionState, cumcount

#: Backwards-compatible name: the accounting that used to live here.
IncrementalTC = PartitionState


def balanced_greedy_repair(obj: PartitionState, e: int, cands) -> int:
    """Algorithm 6: feasible candidate with the lowest resulting T, or -1."""
    best, best_t = -1, np.inf
    mem = obj.mem_limits
    for i in cands:
        i = int(i)
        if obj.mem_after(e, i) > mem[i] + 1e-9:
            continue
        t = obj.delta_t_if_added(e, i)
        if t < best_t:
            best, best_t = i, t
    return best


def _repair_edge_scalar(obj: PartitionState, e: int,
                        orders: list[list[int]]) -> int:
    """One edge through the Alg. 5 L11-20 cascade (the scalar oracle)."""
    u, v = obj.g.edges[e]
    a_u = np.flatnonzero(obj.cnt[:, u] > 0)
    a_v = np.flatnonzero(obj.cnt[:, v] > 0)
    both = np.intersect1d(a_u, a_v)
    either = np.union1d(a_u, a_v)
    i = -1
    if len(both):
        i = balanced_greedy_repair(obj, e, both)
    if i < 0 and len(either):
        i = balanced_greedy_repair(obj, e, either)
    if i < 0:
        i = balanced_greedy_repair(obj, e, range(obj.cluster.p))
    if i < 0:
        # No memory anywhere (should not happen when input feasible):
        # force the machine with most free memory.
        free = obj.cluster.memory() - obj.mem_used_all()
        i = int(np.argmax(free))
    obj.add_edge(e, int(i))
    orders[int(i)].append(int(e))
    return int(i)


def _choose_machines(obj: PartitionState, es: np.ndarray):
    """Vectorized Alg. 6 cascade for every pending edge at once.

    Returns (best_m, best_t, best_mem, ok): per-edge chosen machine, its
    resulting T, its exact post-add footprint, and whether any feasible
    machine existed (rows with ok=False need the force-place fallback).
    """
    T, memA, free_u, free_v = obj.placement_scores(es)   # all (n, p)
    feas = memA <= obj.mem_limits[None, :] + 1e-9
    share_u, share_v = ~free_u, ~free_v
    allowed = feas & share_u & share_v              # tier 1: both endpoints
    need = ~allowed.any(axis=1)
    if need.any():                                  # tier 2: either endpoint
        allowed[need] = feas[need] & (share_u[need] | share_v[need])
        need = ~allowed.any(axis=1)
        if need.any():                              # tier 3: anybody feasible
            allowed[need] = feas[need]
    ok = allowed.any(axis=1)
    masked = np.where(allowed, T, np.inf)
    best_m = np.argmin(masked, axis=1)              # first-min = lowest id,
    rows = np.arange(len(es))                       # same as the scalar scan
    return best_m, masked[rows, best_m], memA[rows, best_m], ok


def repair_edges(obj: PartitionState, es: np.ndarray,
                 orders: list[list[int]], *,
                 strict: bool = False, wave_frac: float = 0.75,
                 wave_window: float | None = None) -> None:
    """BalancedGreedyRepair over an edge set, in vectorized waves.

    Each wave scores all pending edges × machines in one broadcast, then
    admits the best-scoring ``wave_frac`` of them.  ``wave_window`` (in
    (0, 1]) optionally tightens that to edges within the given fraction of
    the selected wave's score spread above its best — a *relative* window,
    so one setting transfers across graphs and cost scales.  Per machine,
    wave-mates are admitted in score order only while a *conservative*
    footprint bound (each earlier mate adds ≤ 1 edge + 2 vertices) still
    fits — refused edges simply stay pending for the next wave, where
    their scores are fresh; the wave's best edge always passes (exact
    check), so every wave makes progress.  ``strict=True``: one edge per
    wave in input order — the scalar oracle.
    """
    pending = np.asarray(es, dtype=np.int64)
    if strict:
        for e in pending.tolist():
            _repair_edge_scalar(obj, e, orders)
        return
    m_node, m_edge = obj.cluster.m_node, obj.cluster.m_edge
    mem = obj.mem_limits
    while len(pending):
        best_m, best_t, best_mem, ok = _choose_machines(obj, pending)
        if not ok.all():
            # nothing feasible for these rows: force-place (rare), then
            # rescore — the forced adds invalidate this wave's T/footprints
            for e in pending[~ok].tolist():
                free = mem - obj.mem_used_all()
                i = int(np.argmax(free))
                obj.add_edge(e, i)
                orders[i].append(int(e))
            pending = pending[ok]
            continue
        order = np.argsort(best_t, kind="stable")
        sel = order[:max(1, int(np.ceil(wave_frac * len(pending))))]
        if wave_window is not None and len(sel) > 1:
            spread = best_t[sel[-1]] - best_t[sel[0]]
            sel = sel[best_t[sel] <= best_t[sel[0]] + wave_window * spread]
        rank = cumcount(best_m[sel])
        fits = (best_mem[sel] + rank * (2.0 * m_node + m_edge)
                <= mem[best_m[sel]] + 1e-9)
        adm = sel[fits]
        adm_e, adm_m = pending[adm], best_m[adm]
        obj.add_edges(adm_e, adm_m)
        for i in np.unique(adm_m):
            orders[int(i)].extend(adm_e[adm_m == i].tolist())
        keep = np.ones(len(pending), dtype=bool)
        keep[adm] = False
        pending = pending[keep]


def destroy_repair(obj: PartitionState, orders: list[list[int]],
                   gamma: float, theta: float,
                   rng: np.random.Generator | None = None, *,
                   strict: bool = False, wave_frac: float = 0.75,
                   wave_window: float | None = None) -> bool:
    """Algorithm 5. Returns True iff TC strictly improved.

    The destroy phase is unchanged (LIFO stacks per overloaded machine);
    the repair phase runs through ``repair_edges`` — vectorized waves by
    default, the scalar oracle under ``strict=True``.
    """
    tc_before = obj.tc
    t = obj.t_total
    thd = t.min() + gamma * (t.max() - t.min())
    removed: list[int] = []
    seen: set[int] = set()             # an edge can sit twice in one stack
    for i in range(obj.cluster.p):
        if t[i] < thd - 1e-12 or obj.edges_per[i] == 0:
            continue
        k = max(1, int(np.ceil(theta * obj.edges_per[i])))
        stack = orders[i]
        # LIFO removal preserves the connectivity of the kept prefix.
        take = []
        while stack and len(take) < k:
            e = stack.pop()
            if obj.assign[e] == i and e not in seen:  # may have moved
                take.append(e)
                seen.add(e)
        removed.extend(take)
    removed_arr = np.asarray(removed, dtype=np.int64)
    obj.remove_edges(removed_arr)
    # Repair, endpoint-sharing machines first (Alg. 5 L11-20).
    repair_edges(obj, removed_arr, orders,
                 strict=strict, wave_frac=wave_frac, wave_window=wave_window)
    return obj.tc < tc_before - 1e-9


def repartition(obj: PartitionState, orders: list[list[int]],
                deltas: np.ndarray, k: int,
                alpha: float, beta: float,
                engine: str = "heap", strict: bool = False,
                **engine_kw) -> PartitionState:
    """Algorithm 7: re-run expansion over the worst machine + k-1 peers.

    ``engine`` selects the expansion implementation (heap oracle or the
    batched bucket-queue engine) — the same switch as ``run_expansion``;
    ``engine_kw`` passes batched-engine knobs through unchanged.
    """
    p = obj.cluster.p
    i = int(np.argmax(obj.t_total))
    # n_{i,j}: replica-node overlap with machine i.
    mi = obj.cnt[i] > 0
    n_ij = (obj.cnt > 0)[:, mi].sum(axis=1)
    n_ij[i] = -1
    k = min(k, p)
    peers = np.argsort(-n_ij, kind="stable")[:max(0, k - 1)]
    sel = sorted(set([i] + [int(j) for j in peers]))
    edge_pool = np.flatnonzero(np.isin(obj.assign, sel))
    if len(edge_pool) == 0:
        return obj
    # Expand the union with each member's capacity, on the union subgraph.
    sub = from_edge_list(obj.g.edges[edge_pool], num_vertices=obj.g.num_vertices)
    # Map: sub edge ids -> global edge ids (from_edge_list sorts by (u,v) key).
    u, v = obj.g.edges[edge_pool, 0], obj.g.edges[edge_pool, 1]
    order_key = np.argsort(
        u.astype(np.int64) * obj.g.num_vertices + v.astype(np.int64))
    sub_to_global = edge_pool[order_key]
    st = expand.ExpansionState.fresh(sub)
    # Seed the border set with vertices replicated on *unselected* machines.
    outside = np.ones(p, dtype=bool)
    outside[sel] = False
    st.in_border[:] = ((obj.cnt[outside] > 0).any(axis=0)).astype(np.uint8)
    assign = obj.assign.copy()
    new_orders = [list(o) for o in orders]
    mem = obj.cluster.memory()
    for j in sorted(sel, key=lambda m: deltas[m]):
        rec: list[int] = []
        eids = expand.expand_partition(
            st, int(j), int(deltas[j]), alpha, beta,
            memory_limit=float(mem[j]),
            m_node=obj.cluster.m_node, m_edge=obj.cluster.m_edge,
            record_order=rec, engine=engine, **engine_kw)
        assign[sub_to_global[eids]] = j
        new_orders[j] = [int(x) for x in sub_to_global[eids]]
    # Any leftover edges in the pool: greedy repair below.
    left = sub_to_global[~st.assigned]
    assign[left] = -1
    new_obj = PartitionState.build(obj.g, assign, obj.cluster)
    repair_edges(new_obj, left, new_orders, strict=strict)
    orders[:] = new_orders
    return new_obj


def sls(g: Graph, assign: np.ndarray, cluster: Cluster,
        orders: list[list[int]], deltas: np.ndarray, *,
        t0: int = 8, n0: int = 5, gamma: float = 0.9, theta: float = 0.01,
        k: int = 3, alpha: float = 0.3, beta: float = 0.3,
        seed: int = 0, engine: str = "heap", repair: str = "vectorized",
        **engine_kw) -> tuple[np.ndarray, float]:
    """Algorithm 4: the SLS driver.  Returns (best assignment, best TC).

    ``repair`` selects the destroy-repair sweep implementation:
    ``"vectorized"`` (wave admission, the default) or ``"scalar"`` (the
    per-edge oracle — same decisions, interpreter-bound).
    """
    assert repair in ("vectorized", "scalar"), repair
    strict = repair == "scalar"
    rng = np.random.default_rng(seed)
    obj = PartitionState.build(g, assign, cluster)
    best_assign, best_tc = obj.assign.copy(), obj.tc
    n = 0
    budget = t0
    while budget > 0:
        if destroy_repair(obj, orders, gamma, theta, rng, strict=strict):
            n = 0
        else:
            n += 1
        if obj.tc < best_tc - 1e-9:
            best_assign, best_tc = obj.assign.copy(), obj.tc
        if n > n0:
            obj = repartition(obj, orders, deltas, k, alpha, beta,
                              engine=engine, strict=strict, **engine_kw)
            if obj.tc < best_tc - 1e-9:
                best_assign, best_tc = obj.assign.copy(), obj.tc
            n = 0
        budget -= 1
    return best_assign, best_tc
