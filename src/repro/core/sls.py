"""Post-processing: Subgraph-Local Search (paper Algorithms 4-7).

Two operators over the current edge assignment:

* **destroy-and-repair** (Alg. 5): machines with T_i above the γ-quantile
  threshold lose a θ-fraction of their edges (last-in-first-out, preserving
  connectivity of what stays), which are greedily re-inserted by
  BalancedGreedyRepair (Alg. 6) preferring machines already holding both
  endpoints, then either endpoint, then anybody — always the feasible
  machine with the lowest resulting T.
* **re-partition** (Alg. 7): on N0 consecutive non-improvements, the worst
  machine and its k-1 largest-replica-overlap peers are merged and re-expanded
  with Algorithm 2 to escape local optima.

All objective updates are incremental via per-(machine, vertex) incident-edge
counts, so one destroy-repair sweep is O(p·|destroyed|) as in the paper's
analysis.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import expand
from .graph import Graph, from_edge_list
from .machines import Cluster


@dataclasses.dataclass
class IncrementalTC:
    """Incrementally-maintained per-machine costs for an edge assignment."""

    g: Graph
    cluster: Cluster
    assign: np.ndarray            # (E,) int32, machine per edge (-1 = unassigned)
    cnt: np.ndarray               # (p, V) int32: partition-i edges incident on v
    edges_per: np.ndarray         # (p,)
    verts_per: np.ndarray         # (p,)
    t_cal: np.ndarray             # (p,)
    t_com: np.ndarray             # (p,)
    com_sum: np.ndarray           # (V,) Σ_{i∈S(v)} c_com[i]
    replicas: np.ndarray          # (V,) |S(v)|

    @classmethod
    def build(cls, g: Graph, assign: np.ndarray, cluster: Cluster):
        p, V = cluster.p, g.num_vertices
        cnt = np.zeros((p, V), dtype=np.int32)
        ok = assign >= 0
        np.add.at(cnt, (assign[ok], g.edges[ok, 0]), 1)
        np.add.at(cnt, (assign[ok], g.edges[ok, 1]), 1)
        member = cnt > 0
        edges_per = np.bincount(assign[ok], minlength=p).astype(np.float64)
        verts_per = member.sum(axis=1).astype(np.float64)
        c_com = cluster.c_com()
        replicas = member.sum(axis=0).astype(np.int64)
        com_sum = member.T.astype(np.float64) @ c_com
        t_cal = cluster.c_node() * verts_per + cluster.c_edge() * edges_per
        t_com = np.zeros(p)
        for i in range(p):
            vs = member[i]
            t_com[i] = ((replicas[vs] - 1) * c_com[i]
                        + (com_sum[vs] - c_com[i])).sum()
        obj = cls(g=g, cluster=cluster, assign=assign.copy(), cnt=cnt,
                  edges_per=edges_per, verts_per=verts_per, t_cal=t_cal,
                  t_com=t_com, com_sum=com_sum, replicas=replicas)
        return obj

    # -- helpers -----------------------------------------------------------
    @property
    def t_total(self) -> np.ndarray:
        return self.t_cal + self.t_com

    @property
    def tc(self) -> float:
        return float(self.t_total.max())

    def mem_used(self, i: int) -> float:
        return (self.cluster.m_node * self.verts_per[i]
                + self.cluster.m_edge * self.edges_per[i])

    def _vertex_enter(self, i: int, v: int) -> None:
        c_com = self.cluster.c_com()
        # v becomes present on i: pairs (i, j) for each j already holding v.
        self.t_com[i] += self.replicas[v] * c_com[i] + self.com_sum[v]
        holders = np.flatnonzero(self.cnt[:, v] > 0)
        self.t_com[holders] += c_com[holders] + c_com[i]
        self.replicas[v] += 1
        self.com_sum[v] += c_com[i]
        self.verts_per[i] += 1
        self.t_cal[i] += self.cluster.c_node()[i]

    def _vertex_leave(self, i: int, v: int) -> None:
        c_com = self.cluster.c_com()
        self.replicas[v] -= 1
        self.com_sum[v] -= c_com[i]
        self.t_com[i] -= self.replicas[v] * c_com[i] + self.com_sum[v]
        holders = np.flatnonzero(self.cnt[:, v] > 0)
        holders = holders[holders != i]
        self.t_com[holders] -= c_com[holders] + c_com[i]
        self.verts_per[i] -= 1
        self.t_cal[i] -= self.cluster.c_node()[i]

    def remove_edge(self, e: int) -> None:
        i = int(self.assign[e])
        assert i >= 0
        u, v = self.g.edges[e]
        self.assign[e] = -1
        self.edges_per[i] -= 1
        self.t_cal[i] -= self.cluster.c_edge()[i]
        for x in (int(u), int(v)):
            self.cnt[i, x] -= 1
            if self.cnt[i, x] == 0:
                self._vertex_leave(i, x)

    def add_edge(self, e: int, i: int) -> None:
        assert self.assign[e] == -1
        u, v = self.g.edges[e]
        for x in (int(u), int(v)):
            if self.cnt[i, x] == 0:
                self._vertex_enter(i, x)
            self.cnt[i, x] += 1
        self.assign[e] = i
        self.edges_per[i] += 1
        self.t_cal[i] += self.cluster.c_edge()[i]

    def delta_t_if_added(self, e: int, i: int) -> float:
        """Resulting T_i if edge e were added to machine i (no mutation)."""
        u, v = self.g.edges[e]
        c_com = self.cluster.c_com()
        dt = self.cluster.c_edge()[i]
        for x in (int(u), int(v)):
            if self.cnt[i, x] == 0:
                dt += (self.cluster.c_node()[i]
                       + self.replicas[x] * c_com[i] + self.com_sum[x])
        return float(self.t_total[i] + dt)

    def mem_after(self, e: int, i: int) -> float:
        u, v = self.g.edges[e]
        new_v = sum(1 for x in (int(u), int(v)) if self.cnt[i, x] == 0)
        return (self.cluster.m_node * (self.verts_per[i] + new_v)
                + self.cluster.m_edge * (self.edges_per[i] + 1))


def balanced_greedy_repair(obj: IncrementalTC, e: int, cands) -> int:
    """Algorithm 6: feasible candidate with the lowest resulting T, or -1."""
    best, best_t = -1, np.inf
    mem = obj.cluster.memory()
    for i in cands:
        i = int(i)
        if obj.mem_after(e, i) > mem[i] + 1e-9:
            continue
        t = obj.delta_t_if_added(e, i)
        if t < best_t:
            best, best_t = i, t
    return best


def destroy_repair(obj: IncrementalTC, orders: list[list[int]],
                   gamma: float, theta: float,
                   rng: np.random.Generator) -> bool:
    """Algorithm 5. Returns True iff TC strictly improved."""
    tc_before = obj.tc
    t = obj.t_total
    thd = t.min() + gamma * (t.max() - t.min())
    removed: list[int] = []
    for i in range(obj.cluster.p):
        if t[i] < thd - 1e-12 or obj.edges_per[i] == 0:
            continue
        k = max(1, int(np.ceil(theta * obj.edges_per[i])))
        stack = orders[i]
        # LIFO removal preserves the connectivity of the kept prefix.
        take = []
        while stack and len(take) < k:
            e = stack.pop()
            if obj.assign[e] == i:     # may have moved since recorded
                take.append(e)
        for e in take:
            obj.remove_edge(e)
        removed.extend(take)
    # Repair, endpoint-sharing machines first (Alg. 5 L11-20).
    for e in removed:
        u, v = obj.g.edges[e]
        a_u = np.flatnonzero(obj.cnt[:, u] > 0)
        a_v = np.flatnonzero(obj.cnt[:, v] > 0)
        both = np.intersect1d(a_u, a_v)
        either = np.union1d(a_u, a_v)
        i = -1
        if len(both):
            i = balanced_greedy_repair(obj, e, both)
        if i < 0 and len(either):
            i = balanced_greedy_repair(obj, e, either)
        if i < 0:
            i = balanced_greedy_repair(obj, e, range(obj.cluster.p))
        if i < 0:
            # No memory anywhere (should not happen when input feasible):
            # force the machine with most free memory.
            free = obj.cluster.memory() - np.array(
                [obj.mem_used(j) for j in range(obj.cluster.p)])
            i = int(np.argmax(free))
        obj.add_edge(e, i)
        orders[i].append(e)
    return obj.tc < tc_before - 1e-9


def repartition(obj: IncrementalTC, orders: list[list[int]],
                deltas: np.ndarray, k: int,
                alpha: float, beta: float,
                engine: str = "heap", **engine_kw) -> IncrementalTC:
    """Algorithm 7: re-run expansion over the worst machine + k-1 peers.

    ``engine`` selects the expansion implementation (heap oracle or the
    batched bucket-queue engine) — the same switch as ``run_expansion``;
    ``engine_kw`` passes batched-engine knobs through unchanged.
    """
    p = obj.cluster.p
    i = int(np.argmax(obj.t_total))
    # n_{i,j}: replica-node overlap with machine i.
    mi = obj.cnt[i] > 0
    n_ij = (obj.cnt > 0)[:, mi].sum(axis=1)
    n_ij[i] = -1
    k = min(k, p)
    peers = np.argsort(-n_ij, kind="stable")[:max(0, k - 1)]
    sel = sorted(set([i] + [int(j) for j in peers]))
    edge_pool = np.flatnonzero(np.isin(obj.assign, sel))
    if len(edge_pool) == 0:
        return obj
    # Expand the union with each member's capacity, on the union subgraph.
    sub = from_edge_list(obj.g.edges[edge_pool], num_vertices=obj.g.num_vertices)
    # Map: sub edge ids -> global edge ids (from_edge_list sorts by (u,v) key).
    u, v = obj.g.edges[edge_pool, 0], obj.g.edges[edge_pool, 1]
    order_key = np.argsort(
        u.astype(np.int64) * obj.g.num_vertices + v.astype(np.int64))
    sub_to_global = edge_pool[order_key]
    st = expand.ExpansionState.fresh(sub)
    # Seed the border set with vertices replicated on *unselected* machines.
    outside = np.ones(p, dtype=bool)
    outside[sel] = False
    st.in_border[:] = ((obj.cnt[outside] > 0).any(axis=0)).astype(np.uint8)
    assign = obj.assign.copy()
    new_orders = [list(o) for o in orders]
    mem = obj.cluster.memory()
    for j in sorted(sel, key=lambda m: deltas[m]):
        rec: list[int] = []
        eids = expand.expand_partition(
            st, int(j), int(deltas[j]), alpha, beta,
            memory_limit=float(mem[j]),
            m_node=obj.cluster.m_node, m_edge=obj.cluster.m_edge,
            record_order=rec, engine=engine, **engine_kw)
        assign[sub_to_global[eids]] = j
        new_orders[j] = [int(x) for x in sub_to_global[eids]]
    # Any leftover edges in the pool: greedy repair below.
    left = sub_to_global[~st.assigned]
    assign[left] = -1
    new_obj = IncrementalTC.build(obj.g, assign, obj.cluster)
    for e in left.tolist():
        u_, v_ = obj.g.edges[e]
        cands = np.flatnonzero((new_obj.cnt[:, u_] > 0) | (new_obj.cnt[:, v_] > 0))
        i2 = balanced_greedy_repair(new_obj, e, cands if len(cands) else range(p))
        if i2 < 0:
            i2 = balanced_greedy_repair(new_obj, e, range(p))
        if i2 < 0:
            i2 = int(np.argmax(mem - new_obj.cluster.m_edge * new_obj.edges_per))
        new_obj.add_edge(e, i2)
        new_orders[i2].append(e)
    orders[:] = new_orders
    return new_obj


def sls(g: Graph, assign: np.ndarray, cluster: Cluster,
        orders: list[list[int]], deltas: np.ndarray, *,
        t0: int = 8, n0: int = 5, gamma: float = 0.9, theta: float = 0.01,
        k: int = 3, alpha: float = 0.3, beta: float = 0.3,
        seed: int = 0, engine: str = "heap",
        **engine_kw) -> tuple[np.ndarray, float]:
    """Algorithm 4: the SLS driver.  Returns (best assignment, best TC)."""
    rng = np.random.default_rng(seed)
    obj = IncrementalTC.build(g, assign, cluster)
    best_assign, best_tc = obj.assign.copy(), obj.tc
    n = 0
    budget = t0
    while budget > 0:
        if destroy_repair(obj, orders, gamma, theta, rng):
            n = 0
        else:
            n += 1
        if obj.tc < best_tc - 1e-9:
            best_assign, best_tc = obj.assign.copy(), obj.tc
        if n > n0:
            obj = repartition(obj, orders, deltas, k, alpha, beta,
                              engine=engine, **engine_kw)
            if obj.tc < best_tc - 1e-9:
                best_assign, best_tc = obj.assign.copy(), obj.tc
            n = 0
        budget -= 1
    return best_assign, best_tc
