"""METIS-like multilevel edge partitioner.

The paper converts METIS (a vertex-centric partitioner) to edge-centric:
degree-weighted vertices are partitioned with ``gpmetis``, then every edge
``uv`` goes to u's or v's machine (randomly) if memory allows.  We implement
the same recipe with a compact multilevel scheme:

  coarsen (heavy-edge matching) → greedy balanced region-growing on the
  coarsest graph → project back with boundary refinement (one FM-light pass
  per level) → edge assignment with memory caps.
"""
from __future__ import annotations

import numpy as np

from ..capacity import _mem_cap
from ..graph import Graph, from_edge_list
from ..machines import Cluster


def _coarsen(edges: np.ndarray, weights: np.ndarray, vwgt: np.ndarray,
             rng: np.random.Generator):
    """One heavy-edge-matching coarsening level."""
    n = len(vwgt)
    order = np.argsort(-weights, kind="stable")      # heavy edges first
    match = np.full(n, -1, dtype=np.int64)
    for k in order:
        u, v = edges[k]
        if match[u] == -1 and match[v] == -1 and u != v:
            match[u] = v
            match[v] = u
    # build coarse ids
    coarse = np.full(n, -1, dtype=np.int64)
    nxt = 0
    for u in range(n):
        if coarse[u] != -1:
            continue
        coarse[u] = nxt
        if match[u] != -1:
            coarse[match[u]] = nxt
        nxt += 1
    cvwgt = np.zeros(nxt, dtype=np.int64)
    np.add.at(cvwgt, coarse, vwgt)
    ce = coarse[edges]
    keep = ce[:, 0] != ce[:, 1]
    ce, cw = ce[keep], weights[keep]
    # merge parallel edges
    key = ce[:, 0] * np.int64(nxt) + ce[:, 1]
    uniq, inv = np.unique(key, return_inverse=True)
    w = np.zeros(len(uniq), dtype=np.int64)
    np.add.at(w, inv, cw)
    e2 = np.stack([uniq // nxt, uniq % nxt], axis=1)
    return e2, w, cvwgt, coarse


def _initial_partition(edges, weights, vwgt, targets, rng):
    """Greedy region growing on the coarsest graph toward weight targets."""
    n, p = len(vwgt), len(targets)
    # adjacency
    adj: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for (u, v), w in zip(edges, weights):
        adj[u].append((int(v), int(w)))
        adj[v].append((int(u), int(w)))
    part = np.full(n, -1, dtype=np.int32)
    load = np.zeros(p, dtype=np.int64)
    order = np.argsort(-vwgt, kind="stable")
    ptr = 0
    for i in np.argsort(-np.asarray(targets)):
        # seed from heaviest unassigned vertex
        while ptr < n and part[order[ptr]] != -1:
            ptr += 1
        if ptr >= n:
            break
        frontier = [int(order[ptr])]
        while frontier and load[i] < targets[i]:
            u = frontier.pop()
            if part[u] != -1:
                continue
            part[u] = i
            load[i] += vwgt[u]
            for v, _ in adj[u]:
                if part[v] == -1:
                    frontier.append(v)
    # leftovers: least-relative-load machine
    for u in range(n):
        if part[u] == -1:
            i = int(np.argmin(load / np.maximum(1, targets)))
            part[u] = i
            load[i] += vwgt[u]
    return part


def _refine(edges, weights, vwgt, part, targets, passes: int = 2):
    """FM-light boundary refinement: move if it cuts weight & keeps balance."""
    p = len(targets)
    load = np.zeros(p, dtype=np.int64)
    np.add.at(load, part, vwgt)
    for _ in range(passes):
        moved = 0
        # gain per boundary vertex toward each neighbor part (approximate)
        for (u, v), w in zip(edges, weights):
            pu, pv = part[u], part[v]
            if pu == pv:
                continue
            # try moving the lighter-degree endpoint
            for (x, src, dst) in ((u, pu, pv), (v, pv, pu)):
                if (load[dst] + vwgt[x] <= 1.1 * targets[dst]
                        and load[src] - vwgt[x] >= 0.5 * targets[src]):
                    part[x] = dst
                    load[src] -= vwgt[x]
                    load[dst] += vwgt[x]
                    moved += 1
                    break
        if moved == 0:
            break
    return part


def metis_like(g: Graph, cluster: Cluster, seed: int = 0,
               coarsest: int = 2048) -> np.ndarray:
    rng = np.random.default_rng(seed)
    p = cluster.p
    caps = np.floor(_mem_cap(cluster, g.num_vertices, g.num_edges)).astype(np.int64)
    # vertex weights = degree (paper's adaptation), equal part targets
    edges = g.edges.astype(np.int64)
    weights = np.ones(g.num_edges, dtype=np.int64)
    vwgt = g.degree().astype(np.int64)
    maps = []
    while len(vwgt) > coarsest and len(edges) > 0:
        edges, weights, vwgt, cmap = _coarsen(edges, weights, vwgt, rng)
        maps.append(cmap)
        if len(maps) > 30:
            break
    total = int(vwgt.sum())
    targets = np.full(p, total // p, dtype=np.int64)
    part = _initial_partition(edges, weights, vwgt, targets, rng)
    part = _refine(edges, weights, vwgt, part, targets)
    for cmap in reversed(maps):
        part = part[cmap]
    # vertex partition -> edge partition with memory caps
    counts = np.zeros(p, dtype=np.int64)
    assign = np.empty(g.num_edges, dtype=np.int32)
    side = rng.integers(0, 2, g.num_edges)
    for e in range(g.num_edges):
        u, v = g.edges[e]
        cands = (int(part[u]), int(part[v])) if side[e] == 0 \
            else (int(part[v]), int(part[u]))
        placed = False
        for i in cands:
            if counts[i] < caps[i]:
                assign[e] = i
                counts[i] += 1
                placed = True
                break
        if not placed:
            i = int(np.argmin(counts - caps))
            assign[e] = i
            counts[i] += 1
    return assign


from ..partitioners import Partitioner, register  # noqa: E402

register(Partitioner(
    "metis", metis_like, "multilevel",
    "METIS-like multilevel scheme, edge-assigned with memory caps",
    frozenset(), ("seed", "coarsest")))
