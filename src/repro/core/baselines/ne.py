"""NE — Neighborhood Expansion [Zhang et al. 2017], heterogeneous-memory
adapted exactly as the paper does: homogeneous capacity α'|E|/p per machine,
clamped by memory; expansion minimizes |N(v)\\S| (our best-first machinery
with α = β = 0 degenerates to NE's criterion)."""
from __future__ import annotations

import numpy as np

from .. import expand as exp_mod
from ..capacity import _mem_cap
from ..graph import Graph
from ..machines import Cluster
from ..partition_state import PartitionState
from ..sls import repair_edges


def ne(g: Graph, cluster: Cluster, seed: int = 0,
       balance: float = 1.0) -> np.ndarray:
    p = cluster.p
    caps = np.floor(_mem_cap(cluster, g.num_vertices, g.num_edges)).astype(np.int64)
    target = int(np.ceil(balance * g.num_edges / p))
    deltas = np.minimum(np.full(p, target, dtype=np.int64), caps)
    short = g.num_edges - int(deltas.sum())
    j = 0
    while short > 0 and j < p:    # top up where memory allows
        take = min(int(caps[j] - deltas[j]), short)
        deltas[j] += take
        short -= take
        j += 1
    assign, _ = exp_mod.run_expansion(
        g, deltas, 0.0, 0.0, memories=cluster.memory(),
        m_node=cluster.m_node, m_edge=cluster.m_edge, order="natural")
    # place stragglers (memory-guard leftovers) through the shared
    # incremental layer: one vectorized greedy-repair wave set, memory-aware
    left = np.flatnonzero(assign < 0)
    if len(left):
        obj = PartitionState.build(g, assign, cluster)
        repair_edges(obj, left, [[] for _ in range(p)])
        assign = obj.assign
    return assign


from ..partitioners import Partitioner, register  # noqa: E402

register(Partitioner(
    "ne", ne, "expansion",
    "Neighborhood Expansion [Zhang et al. 2017], memory-adapted",
    frozenset(), ("seed", "balance")))
