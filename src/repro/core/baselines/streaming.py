"""Streaming edge partitioners: hash, DBH, PowerGraph-greedy, HDRF, EBV.

All receive the same heterogeneous-memory adaptation the paper applies to
its baselines: a per-machine edge-capacity cap derived from M_i (identical
to the one WindGP's preprocessing uses), with overflow spilling to the
best-scoring machine that still has room.  The hash-family overflow pass
(hash, DBH) runs through the shared incremental layer
(``core/partition_state.py``): overflow edges beyond each machine's cap
are repaired in vectorized greedy waves instead of a per-edge Python scan
over its own bincounts.

The order-sensitive scorers (greedy, HDRF, EBV) run through the
**block-stream engine**: edges are consumed in blocks of ``block_size``
stream positions, each block is scored against *all* machines in one
broadcast (the replication term reads the shared ``(p, V)`` membership
matrix via ``PartitionState.endpoint_presence``; balance terms read its
``edges_per``/``verts_per`` totals), and conflict-free within-block
assignments are admitted in waves:

* only the stream-first edge per endpoint may be admitted in a wave, so
  every admitted edge's replication term is exact w.r.t. the pre-wave
  state (wave-mates are pairwise endpoint-disjoint);
* per machine, wave-mates are admitted in stream order only while the
  capacity cap still fits (``counts + rank < cap``) and within a
  ``ceil(candidates / p)`` spread quota, so the stale balance term cannot
  pile a whole wave onto one machine — refused edges stay pending and are
  rescored next wave against fresh state.

``block_size=1`` degrades to one edge per wave, which reproduces the
per-edge loops decision for decision (identical float arithmetic, same
first-argmax tie-breaks) — those loops survive below as ``*_oracle``, the
test reference rather than the implementation, mirroring the SLS repair
waves' ``strict`` mode.  ``stream_partition`` runs the same engine over an
edge-block iterator with the graph-free ``StreamMembership`` state, for
graphs that never materialize as a single array.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..capacity import _mem_cap
from ..graph import Graph
from ..machines import Cluster
from ..partition_state import (PartitionState, StreamMembership, cumcount)
from ..sls import repair_edges

#: Fallback stream-block size (the public methods use ``auto_block_size``
#: via the per-method ``ENGINE_DEFAULTS``): large enough that per-wave
#: broadcasts amortize the Python dispatch, small enough that stale
#: balance terms self-correct within a fraction of a machine's capacity.
DEFAULT_BLOCK = 1024


def auto_block_size(num_edges: int) -> int:
    """Default block: ~1/48 of the stream, clamped to [256, 8192].

    What degrades block quality is the *fraction* of the stream scored
    against one membership snapshot, not the absolute block size — the
    same 1024-edge block is a 2% slice of the LJ proxy but an 8% slice of
    the CI smoke proxy.  E/48 reproduces the LJ-tuned 1024 at LJ scale
    and shrinks/grows proportionally elsewhere.
    """
    return int(max(256, min(8192, num_edges // 48)))


def _caps(cluster: Cluster, g: Graph) -> np.ndarray:
    return np.floor(_mem_cap(cluster, g.num_vertices, g.num_edges)).astype(np.int64)


def _spill(scores: np.ndarray, counts: np.ndarray, caps: np.ndarray) -> int:
    """Best-scoring machine with room (scores higher = better)."""
    ok = counts < caps
    if not ok.any():
        return int(np.argmin(counts - caps))   # least-overfull fallback
    masked = np.where(ok, scores, -np.inf)
    return int(np.argmax(masked))


def _cap_spill(g: Graph, cluster: Cluster, assign: np.ndarray,
               caps: np.ndarray) -> np.ndarray:
    """Deterministic overflow pass for the hash-family partitioners.

    Each machine keeps its first ``caps[i]`` edges in stream order; the
    overflow is re-placed by the shared vectorized BalancedGreedyRepair
    (memory-aware, TC-accounted) instead of the old per-edge count scan.
    """
    if np.all(np.bincount(assign, minlength=cluster.p) <= caps):
        return assign
    over = cumcount(assign) >= caps[assign]
    assign = assign.copy()
    assign[over] = -1
    obj = PartitionState.build(g, assign, cluster)
    repair_edges(obj, np.flatnonzero(over), [[] for _ in range(cluster.p)])
    return obj.assign


def random_hash(g: Graph, cluster: Cluster, seed: int = 0) -> np.ndarray:
    """f(e) = hash(e) % p with memory spill."""
    p = cluster.p
    h = (g.edges[:, 0].astype(np.uint64) * np.uint64(2654435761)
         ^ g.edges[:, 1].astype(np.uint64) * np.uint64(40503)) % np.uint64(p)
    return _cap_spill(g, cluster, h.astype(np.int32), _caps(cluster, g))


def dbh(g: Graph, cluster: Cluster, seed: int = 0) -> np.ndarray:
    """Degree-Based Hashing [Xie et al. 2014]: hash the low-degree endpoint."""
    p = cluster.p
    deg = g.degree()
    u, v = g.edges[:, 0], g.edges[:, 1]
    low = np.where(deg[u] <= deg[v], u, v).astype(np.uint64)
    assign = ((low * np.uint64(2654435761)) % np.uint64(p)).astype(np.int32)
    return _cap_spill(g, cluster, assign, _caps(cluster, g))


# ---------------------------------------------------------------------------
# per-edge reference loops (the stream-order oracles)
# ---------------------------------------------------------------------------

def powergraph_greedy_oracle(g: Graph, cluster: Cluster,
                             seed: int = 0) -> np.ndarray:
    """PowerGraph's greedy vertex-cut [Gonzalez et al. 2012], per edge.

    Prefer machines holding both endpoints, then either, then least loaded;
    ties broken by load.  Kept as the block engine's bit-exact reference.
    """
    p = cluster.p
    caps = _caps(cluster, g)
    member = np.zeros((p, g.num_vertices), dtype=bool)
    counts = np.zeros(p, dtype=np.int64)
    assign = np.empty(g.num_edges, dtype=np.int32)
    rng = np.random.default_rng(seed)
    order = rng.permutation(g.num_edges)       # stream order
    load_score = lambda: -counts / np.maximum(1, caps)
    for e in order:
        u, v = g.edges[e]
        au, av = member[:, u], member[:, v]
        both, either = au & av, au | av
        base = load_score()
        if both.any():
            scores = np.where(both, base + 4, -np.inf)
        elif either.any():
            scores = np.where(either, base + 2, -np.inf)
        else:
            scores = base
        i = _spill(scores, counts, caps)
        assign[e] = i
        member[i, u] = member[i, v] = True
        counts[i] += 1
    return assign


def hdrf_oracle(g: Graph, cluster: Cluster, seed: int = 0,
                lam: float = 1.0, eps: float = 1.0) -> np.ndarray:
    """High-Degree Replicated First [Petroni et al. 2015], per edge."""
    p = cluster.p
    caps = _caps(cluster, g)
    member = np.zeros((p, g.num_vertices), dtype=bool)
    counts = np.zeros(p, dtype=np.int64)
    pdeg = np.zeros(g.num_vertices, dtype=np.int64)   # partial degrees
    assign = np.empty(g.num_edges, dtype=np.int32)
    rng = np.random.default_rng(seed)
    order = rng.permutation(g.num_edges)
    for e in order:
        u, v = g.edges[e]
        pdeg[u] += 1
        pdeg[v] += 1
        du, dv = pdeg[u], pdeg[v]
        theta_u = du / (du + dv)
        theta_v = 1.0 - theta_u
        g_u = np.where(member[:, u], 1.0 + (1.0 - theta_u), 0.0)
        g_v = np.where(member[:, v], 1.0 + (1.0 - theta_v), 0.0)
        maxs, mins = counts.max(), counts.min()
        c_bal = lam * (maxs - counts) / (eps + maxs - mins)
        i = _spill(g_u + g_v + c_bal, counts, caps)
        assign[e] = i
        member[i, u] = member[i, v] = True
        counts[i] += 1
    return assign


def ebv_oracle(g: Graph, cluster: Cluster, seed: int = 0,
               w_e: float = 1.0, w_v: float = 1.0) -> np.ndarray:
    """Efficient-and-Balanced Vertex-cut [Zhang et al. 2021], per edge.

    Streams edges sorted by end-degree sum ascending; score for machine i:
    I(u∉V_i) + I(v∉V_i) + w_e·p|E_i|/|E| + w_v·p|V_i|/|V|  (minimized).
    """
    p = cluster.p
    caps = _caps(cluster, g)
    member = np.zeros((p, g.num_vertices), dtype=bool)
    counts = np.zeros(p, dtype=np.int64)
    vcounts = np.zeros(p, dtype=np.int64)
    assign = np.empty(g.num_edges, dtype=np.int32)
    deg = g.degree()
    order = np.argsort(deg[g.edges[:, 0]] + deg[g.edges[:, 1]], kind="stable")
    nE, nV = g.num_edges, max(1, g.num_vertices)
    for e in order:
        u, v = g.edges[e]
        rep = (~member[:, u]).astype(np.float64) + (~member[:, v])
        score = rep + w_e * p * counts / nE + w_v * p * vcounts / nV
        i = _spill(-score, counts, caps)
        assign[e] = i
        if not member[i, u]:
            member[i, u] = True
            vcounts[i] += 1
        if not member[i, v]:
            member[i, v] = True
            vcounts[i] += 1
        counts[i] += 1
    return assign


# ---------------------------------------------------------------------------
# block-stream scorers: one (n, p) broadcast per wave, oracle float-for-float
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GreedyScorer:
    """PowerGraph-greedy score, vectorized row-per-edge."""

    name = "greedy"

    def stream_order(self, g: Graph, seed: int) -> np.ndarray:
        return np.random.default_rng(seed).permutation(g.num_edges)

    def block_aux(self, u: np.ndarray, v: np.ndarray) -> np.ndarray | None:
        return None

    def score(self, state, u, v, pres_u, pres_v, aux, caps,
              nE: int, nV: int) -> np.ndarray:
        base = -state.edges_per / np.maximum(1, caps)        # (p,)
        both = pres_u & pres_v
        either = pres_u | pres_v
        s_both = np.where(both, base + 4, -np.inf)
        s_either = np.where(either, base + 2, -np.inf)
        s_base = np.broadcast_to(base, both.shape)
        return np.where(both.any(axis=1)[:, None], s_both,
                        np.where(either.any(axis=1)[:, None],
                                 s_either, s_base))

    def wave_penalty(self, state, caps, nE: int, nV: int) -> np.ndarray:
        return 1.0 / np.maximum(1, caps)

    def fresh_priority(self, state, caps, nE: int, nV: int):
        c = np.maximum(1, caps)
        return state.edges_per / c, 1.0 / c


@dataclasses.dataclass
class HDRFScorer:
    """HDRF score; partial degrees are stream-position facts, so they are
    computed exactly per block (running totals + within-block occurrence
    ranks) regardless of how waves defer placements."""

    lam: float = 1.0
    eps: float = 1.0
    name = "hdrf"

    def __post_init__(self):
        self._pdeg: np.ndarray | None = None

    def reset(self, num_vertices: int) -> None:
        self._pdeg = np.zeros(num_vertices, dtype=np.int64)

    def grow(self, num_vertices: int) -> None:
        """Extend the partial-degree history to a grown vertex space
        (dynamic insert streams) without erasing it — new vertices start
        at degree 0, exactly as if they had been allocated up front."""
        if self._pdeg is None:
            self.reset(num_vertices)
        elif num_vertices > len(self._pdeg):
            self._pdeg = np.concatenate(
                [self._pdeg,
                 np.zeros(num_vertices - len(self._pdeg), dtype=np.int64)])

    def stream_order(self, g: Graph, seed: int) -> np.ndarray:
        return np.random.default_rng(seed).permutation(g.num_edges)

    def block_aux(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        ends = np.empty(2 * len(u), dtype=np.int64)
        ends[0::2] = u
        ends[1::2] = v
        occ = cumcount(ends)
        du = self._pdeg[u] + occ[0::2] + 1
        dv = self._pdeg[v] + occ[1::2] + 1
        np.add.at(self._pdeg, ends, 1)
        return np.stack([du, dv], axis=1)

    def score(self, state, u, v, pres_u, pres_v, aux, caps,
              nE: int, nV: int) -> np.ndarray:
        du, dv = aux[:, 0], aux[:, 1]
        theta_u = du / (du + dv)
        theta_v = 1.0 - theta_u
        g_u = np.where(pres_u, 1.0 + (1.0 - theta_u)[:, None], 0.0)
        g_v = np.where(pres_v, 1.0 + (1.0 - theta_v)[:, None], 0.0)
        counts = state.edges_per
        maxs, mins = counts.max(), counts.min()
        c_bal = self.lam * (maxs - counts) / (self.eps + maxs - mins)
        return g_u + g_v + c_bal[None, :]

    def wave_penalty(self, state, caps, nE: int, nV: int) -> np.ndarray:
        counts = state.edges_per
        spread = self.eps + counts.max() - counts.min()
        return np.full(len(caps), self.lam / spread)

    def fresh_priority(self, state, caps, nE: int, nV: int):
        # c_bal is strictly decreasing in the own count and uniform
        # otherwise, so fresh placement greedily fills the lowest count
        return state.edges_per.copy(), np.ones(len(caps))


@dataclasses.dataclass
class EBVScorer:
    """EBV score (minimized in the oracle; negated here, higher = better)."""

    w_e: float = 1.0
    w_v: float = 1.0
    name = "ebv"

    def stream_order(self, g: Graph, seed: int) -> np.ndarray:
        deg = g.degree()
        return np.argsort(deg[g.edges[:, 0]] + deg[g.edges[:, 1]],
                          kind="stable")

    def block_aux(self, u: np.ndarray, v: np.ndarray) -> np.ndarray | None:
        return None

    def score(self, state, u, v, pres_u, pres_v, aux, caps,
              nE: int, nV: int) -> np.ndarray:
        p = state.p
        rep = (~pres_u).astype(np.float64) + (~pres_v)
        score = (rep + self.w_e * p * state.edges_per / nE
                 + self.w_v * p * state.verts_per / nV)
        return -score

    def wave_penalty(self, state, caps, nE: int, nV: int) -> np.ndarray:
        # each admitted edge adds 1 to |E_i| and at most 2 to |V_i|
        p = state.p
        return np.full(len(caps),
                       self.w_e * p / nE + 2.0 * self.w_v * p / nV)

    def fresh_priority(self, state, caps, nE: int, nV: int):
        p = state.p
        a = (self.w_e * p * state.edges_per / nE
             + self.w_v * p * state.verts_per / nV)
        b = np.full(len(caps), self.w_e * p / nE + 2.0 * self.w_v * p / nV)
        return a, b


#: scorer factories by method name (the ``blocked`` capability surface)
SCORERS = {
    "greedy": GreedyScorer,
    "hdrf": HDRFScorer,
    "ebv": EBVScorer,
}


# ---------------------------------------------------------------------------
# the block-stream engine
# ---------------------------------------------------------------------------

class _BlockEngine:
    """Wave admission over a stream-ordered pending buffer with carry.

    ``push`` appends one block (auxiliary stream facts — HDRF's partial
    degrees — are stamped at arrival, so deferral never changes them) and
    runs at most ``max_waves`` admission waves; unadmitted rows *carry*
    into the next block's pending, where they ride along with its full
    waves instead of draining through many tiny straggler waves — and see
    membership several blocks ahead, which is what the replica throttle
    needs.  ``flush`` drains to empty at stream end.  Rows keep stream
    order throughout, so the leader/quota logic stays order-faithful.

    Admission is the sum of three guards (the scalar oracles reduce to
    the quota path at one row per wave):

    * fresh edges → exact water-fill of the scorer's linear balance score;
    * membership-tiered edges → per-machine rank quota anchored to the
      block size, with a rank-*stability* override (the batched form of
      the oracle's continuous balance steering) and a replica throttle
      (creations wait a wave to see the membership just built);
    * per-machine capacity prefix (each wave-mate adds exactly one edge).
    """

    def __init__(self, state, scorer, caps, nE, nV, *,
                 block_size: int = 4096, max_waves: int = 3,
                 replica_frac: float = 0.5, creator_scalar: bool = False,
                 sink=None):
        self.state, self.scorer, self.caps = state, scorer, caps
        self.nE, self.nV, self.max_waves, self.sink = nE, nV, max_waves, sink
        self.block_size = max(1, int(block_size))
        self.replica_frac = replica_frac
        self.creator_scalar = creator_scalar
        self.u = np.empty(0, dtype=np.int64)
        self.v = np.empty(0, dtype=np.int64)
        self.eids: np.ndarray | None = None
        self.aux: np.ndarray | None = None
        self._scratch = np.full(max(1, nV), -1, dtype=np.int64)

    def push(self, u, v, eids=None, aux=None) -> None:
        # aux may arrive precomputed (the parallel pipeline stamps stream
        # facts centrally, in arrival order, before shipping units out)
        if aux is None:
            aux = self.scorer.block_aux(u, v)
        self.u = np.concatenate([self.u, u])
        self.v = np.concatenate([self.v, v])
        if eids is not None:
            self.eids = (eids if self.eids is None
                         else np.concatenate([self.eids, eids]))
        if aux is not None:
            self.aux = (aux if self.aux is None
                        else np.concatenate([self.aux, aux]))
        self._drain(self.max_waves)

    def flush(self) -> None:
        self._drain(None)

    def _emit(self, sel, ms, verts_delta=None) -> None:
        e = None if self.eids is None else self.eids[sel]
        self.state.admit_block(self.u[sel], self.v[sel], e, ms,
                               verts_delta=verts_delta)
        if self.sink is not None:
            self.sink(np.stack([self.u[sel], self.v[sel]], axis=1), ms)

    def _shrink(self, taken: np.ndarray) -> None:
        keep = np.ones(len(self.u), dtype=bool)
        keep[taken] = False
        self.u, self.v = self.u[keep], self.v[keep]
        if self.eids is not None:
            self.eids = self.eids[keep]
        if self.aux is not None:
            self.aux = self.aux[keep]

    def _drain(self, max_waves: int | None) -> None:
        waves = 0
        while len(self.u) and (max_waves is None or waves < max_waves):
            waves += 1
            if not self._wave():
                break

    def _wave(self) -> bool:
        """One admission wave; returns False on the overflow fallback."""
        state, scorer, caps = self.state, self.scorer, self.caps
        nE, nV = self.nE, self.nV
        u, v = self.u, self.v
        n = len(u)
        p = state.p
        pres_u, pres_v = state.endpoint_presence(u, v)
        scores = scorer.score(state, u, v, pres_u, pres_v,
                              self.aux, caps, nE, nV)
        counts = state.edges_per
        ok = counts < caps
        if not ok.any():
            # Global overflow (least-overfull fallback): the argmin moves
            # with every placement, so drain scalar — the oracle's path.
            for j in range(n):
                i = np.argmin(state.edges_per - caps)
                self._emit(np.array([j]), np.array([i], dtype=np.int64))
            self._shrink(np.arange(n))
            return False
        masked = np.where(ok[None, :], scores, -np.inf)
        best = np.argmax(masked, axis=1)         # first-max = scalar _spill
        # (1) endpoint leaders: the stream-first toucher of each vertex
        # this wave, found by a reversed scatter-write (first write wins
        # after reversal; stale scratch entries are never read because
        # every slot is written before it is read).  An edge may join the
        # wave iff each endpoint is steered to the same machine as that
        # endpoint's leader — same-machine followers only reinforce the
        # membership their leader creates, so hub edges co-admit in one
        # wave; disagreeing edges defer and are rescored against fresh
        # state.
        ends = np.empty(2 * n, dtype=np.int64)
        ends[0::2] = u
        ends[1::2] = v
        idx = np.arange(2 * n)
        self._scratch[ends[::-1]] = idx[::-1]
        lead_slot = self._scratch[ends]
        is_first = lead_slot == idx
        stream_first = is_first[0::2] & is_first[1::2]
        any_u = pres_u.any(axis=1)
        any_v = pres_v.any(axis=1)
        fresh = ~(any_u | any_v)
        # (2a) *fresh* edges — no endpoint present anywhere, so their score
        # rows are identical and stale argmax would pile a whole wave onto
        # one machine.  Their balance score is linear in the own-machine
        # count (a_i + b_i·t), so the oracle's repeated-argmax placement
        # sequence is exactly the ascending merge of the per-machine
        # priority ladders — water-fill them in one argsort, capped by
        # each machine's remaining room.  Only stream-first fresh edges
        # join (followers defer one wave and return membership-tiered).
        fcand = np.flatnonzero(fresh & stream_first)
        falloc = np.zeros(p, dtype=np.int64)
        take_parts = []
        m_parts = []
        lead_m = best.copy()                    # leaders' *actual* machines
        if len(fcand) > 1:
            a, b = scorer.fresh_priority(state, caps, nE, nV)
            k = len(fcand)
            room = np.where(ok, caps - counts.astype(np.int64), 0)
            t = np.arange(min(k, int(room.max())), dtype=np.float64)
            ladder = a[:, None] + b[:, None] * t[None, :]
            ladder[t[None, :] >= room[:, None]] = np.inf
            flat = np.argsort(ladder, axis=None, kind="stable")[:k]
            seq = (flat // len(t)).astype(np.int64)
            seq = seq[np.isfinite(ladder.ravel()[flat])]
            fc = fcand[:len(seq)]        # room-limited leftovers defer
            falloc = np.bincount(seq, minlength=p)
            take_parts.append(fc)
            m_parts.append(seq)
            lead_m[fc] = seq
            nfmask = ~fresh
        else:
            nfmask = ~fresh | stream_first
        # follower agreement checks the machine its endpoint leader was
        # actually sent to (water-filled fresh leaders included)
        first_m = lead_m[lead_slot // 2]
        nfmask &= (first_m[0::2] == best) & (first_m[1::2] == best)
        # (2b) membership-tiered edges: cap + balance guard per machine in
        # stream order.  Each earlier wave-mate (fresh water-fill included)
        # adds exactly one edge (cap check).  The per-machine rank quota is
        # anchored to the *block size*, not the pending size, so carried
        # stragglers never coarsen admission.  Beyond the quota an edge
        # needs rank-*stability*: after charging the scorer's per-edge
        # balance penalty for every earlier wave-mate on its machine, its
        # score must still beat the row's second-best allowed machine.
        # Replica-*creating* placements (an endpoint present elsewhere but
        # not on the chosen machine) additionally respect the quota as a
        # global rate limit: deferring the rest one wave lets them see the
        # membership the admitted edges just built — the oracle's
        # continuously-discovered co-location at wave granularity.
        cand = np.flatnonzero(nfmask)
        scalar_rows = np.empty(0, dtype=np.int64)
        if len(cand):
            m = best[cand]
            creating = ((any_u[cand] & ~pres_u[cand, m])
                        | (any_v[cand] & ~pres_v[cand, m]))
            if self.creator_scalar:
                # hub_split idiom: the replica-*creating* minority (the
                # placements the binary-presence scorers are staleness-
                # sensitive to) drains through the exact per-edge path
                # after the wave; the non-creating majority stays
                # vectorized.  No throttle needed — creators see fully
                # fresh membership.
                scalar_rows = cand[creating]
                cand = cand[~creating]
                m = best[cand]
                creating = np.zeros(len(cand), dtype=bool)
            r = cumcount(m) + falloc[m]
            quota = max(1, -(-min(len(cand), self.block_size) // p))
            rc = np.zeros(len(cand), dtype=np.int64)
            rc[creating] = np.arange(int(creating.sum()))
            rc_quota = max(1, int(self.replica_frac * quota))
            in_quota = ((r - falloc[m] < quota)
                        & (~creating | (rc < rc_quota)))
            capok = counts[m] + r < caps[m]
            keep_c = capok & in_quota
            # stability override — non-fresh, non-creating rows only,
            # computed lazily on the rows that actually need it
            over = capok & ~in_quota & ~fresh[cand] & ~creating
            if over.any() and p >= 2:
                pen = scorer.wave_penalty(state, caps, nE, nV)
                second = np.partition(masked[cand[over]], -2, axis=1)[:, -2]
                stable = (masked[cand[over], m[over]]
                          - r[over] * pen[m[over]] >= second)
                keep_c[over] = stable
            take_parts.append(cand[keep_c])
            m_parts.append(m[keep_c])
        take = np.concatenate(take_parts) if take_parts else \
            np.empty(0, dtype=np.int64)
        ms = np.concatenate(m_parts) if m_parts else \
            np.empty(0, dtype=np.int64)
        ms = ms.astype(np.int64)
        # progress: the globally stream-first pending edge is either a
        # fresh leader (water-fill places it first) or its own endpoint
        # leader at rank 0 with a machine ``best`` knows has room — every
        # wave admits at least one edge.
        # exact |V_i| delta from admitted-set leader bits: all admitted
        # touchers of a vertex share a machine, so the 0→1 cell events are
        # exactly the admitted leaders landing where their endpoint is absent
        et = np.empty(2 * len(take), dtype=np.int64)
        et[0::2] = u[take]
        et[1::2] = v[take]
        it = np.arange(2 * len(take))
        self._scratch[et[::-1]] = it[::-1]
        lead_t = self._scratch[et] == it
        new_u = lead_t[0::2] & ~pres_u[take, ms]
        new_v = lead_t[1::2] & ~pres_v[take, ms]
        dv = (np.bincount(ms[new_u], minlength=p)
              + np.bincount(ms[new_v], minlength=p)).astype(np.float64)
        self._emit(take, ms, verts_delta=dv)
        if len(scalar_rows):
            self._scalar_drain(scalar_rows)
            take = np.concatenate([take, scalar_rows])
        self._shrink(take)
        return True

    def _scalar_drain(self, rows: np.ndarray) -> None:
        """Exact per-edge placement for replica-creating rows.

        Each row rescores against fully fresh state and places through the
        oracle's decision rule (first-argmax over machines with room, else
        least-overfull), so a wave's creating placements are decision-
        identical to the per-edge loop run at this point in the stream —
        the vectorized majority pays none of that cost.  State mutation
        goes through the one-edge light path (``admit_single``); the sink
        sees the drained rows as one batch at the end, in placement order.
        """
        state, scorer, caps = self.state, self.scorer, self.caps
        cnt, eper = state.cnt, state.edges_per
        ms = np.empty(len(rows), dtype=np.int64)
        for t, j in enumerate(rows):
            uj, vj = self.u[j], self.v[j]
            pu, pv = cnt[:, uj] > 0, cnt[:, vj] > 0
            aux = None if self.aux is None else self.aux[j:j + 1]
            sc = scorer.score(state, self.u[j:j + 1], self.v[j:j + 1],
                              pu[None], pv[None], aux, caps,
                              self.nE, self.nV)[0]
            ok = eper < caps
            if ok.any():
                i = int(np.argmax(np.where(ok, sc, -np.inf)))
            else:
                i = int(np.argmin(eper - caps))
            dv = float(~pu[i]) + float((uj != vj) & ~pv[i])
            state.admit_single(uj, vj,
                               None if self.eids is None else self.eids[j],
                               i, dv)
            ms[t] = i
        if self.sink is not None:
            self.sink(np.stack([self.u[rows], self.v[rows]], axis=1), ms)


def block_stream_assign(g: Graph, cluster: Cluster, scorer, *,
                        block_size: int = DEFAULT_BLOCK, seed: int = 0,
                        order: np.ndarray | None = None,
                        max_waves: int = 3,
                        replica_frac: float = 0.5,
                        creator_scalar: bool = False) -> np.ndarray:
    """Run a block-stream scorer over an in-memory graph.

    The shared ``(p, V)`` membership matrix and per-machine totals live in
    ``PartitionState`` (built all-unassigned), so the engine's accounting is
    the same layer expansion/SLS/overflow already use; ``order`` overrides
    the scorer's stream order (tests use this to cross-check the graph-free
    path).  ``block_size=1`` reproduces the ``*_oracle`` loops bit for bit.
    """
    state = PartitionState.build(
        g, np.full(g.num_edges, -1, dtype=np.int32), cluster)
    caps = _caps(cluster, g)
    if order is None:
        order = scorer.stream_order(g, seed)
    if hasattr(scorer, "reset"):
        scorer.reset(g.num_vertices)
    B = max(1, int(block_size))
    eu = g.edges[:, 0].astype(np.int64)
    ev = g.edges[:, 1].astype(np.int64)
    eng = _BlockEngine(state, scorer, caps, g.num_edges,
                       max(1, g.num_vertices), block_size=B,
                       max_waves=max_waves, replica_frac=replica_frac,
                       creator_scalar=creator_scalar)
    for lo in range(0, len(order), B):
        blk = order[lo:lo + B]
        eng.push(eu[blk], ev[blk], blk)
    eng.flush()
    return state.assign


def _resolve_stream_source(source, num_vertices, num_edges, *,
                           dedup: str, spill_dir, bucket_rows, io_block):
    """Normalize ``stream_partition``'s edge source to (blocks, |V|, |E|).

    ``source`` may be a block iterable (the historical contract), an
    edge-list path, or a prepared :class:`repro.data.TwoPassDedup`.  With
    ``dedup="two_pass"`` a path is spilled/deduplicated out of core first
    (exact counts come back from the spill accounting); with
    ``dedup="block"`` a path streams through ``iter_edge_blocks`` with
    per-block dedup only, counting once when counts were not supplied.
    Returns ``(blocks, num_vertices, num_edges, spill, owned)`` — ``spill``
    is the TwoPassDedup in play (for its accounting), ``owned`` marks that
    it was created here and must be closed at stream end.
    """
    import os
    from ...data import io as _io
    if dedup not in ("block", "two_pass"):
        raise ValueError(f"dedup must be 'block' or 'two_pass', got {dedup!r}")
    if isinstance(source, _io.TwoPassDedup):
        nv, ne = source.prepare()
        return source, nv, ne, source, False
    if isinstance(source, (str, os.PathLike)):
        if dedup == "two_pass":
            tp = _io.TwoPassDedup(source, spill_dir,
                                  bucket_rows=bucket_rows)
            nv, ne = tp.prepare()
            return tp, nv, ne, tp, True
        io_block = io_block or _io.DEFAULT_BLOCK_LINES
        if num_vertices is None or num_edges is None:
            num_vertices, num_edges = _io.count_edge_list(source, io_block)
        return _io.iter_edge_blocks(source, io_block), \
            num_vertices, num_edges, None, False
    if dedup == "two_pass":
        raise ValueError(
            "dedup='two_pass' needs a re-readable edge-list path (or a "
            "prepared TwoPassDedup), not an exhaustible block iterator")
    if num_vertices is None or num_edges is None:
        raise ValueError("block iterables need explicit num_vertices/"
                         "num_edges (use a path to let the stream count)")
    return source, num_vertices, num_edges, None, False


def stream_partition(source, num_vertices: int | None = None,
                     num_edges: int | None = None,
                     cluster: Cluster = None, method: str = "hdrf", *,
                     dedup: str = "block", spill_dir: str | None = None,
                     bucket_rows: int = 1 << 16,
                     block_size: int | None = None,
                     max_waves: int | None = None,
                     replica_frac: float | None = None,
                     creator_scalar: bool | None = None, sink=None,
                     workers: int = 1, sync_blocks: int | None = None,
                     **scorer_kw) -> StreamMembership:
    """Partition an edge stream that never materializes as one array.

    ``source`` yields (B, 2) int arrays (``data/io.iter_edge_blocks``), or
    is an edge-list path, or a prepared ``TwoPassDedup``; stream order is
    arrival order (EBV's degree sort is not available without a sort pass
    — documented deviation).  ``num_vertices`` and ``num_edges`` come from
    a counting pass (both are needed for the memory caps; EBV also
    normalizes by them) and may be ``None`` when ``source`` can count
    itself (a path or a TwoPassDedup).

    ``dedup`` picks the cross-block duplicate discipline: ``"block"`` (the
    single-pass mode — within-block dedup only, duplicates that span
    blocks are partitioned twice) or ``"two_pass"`` (exact global dedup
    via bounded spill buckets on disk; the engine then sees every edge
    exactly once, in first-occurrence order, so its decisions are
    comparable to the in-memory path on the deduplicated graph).

    Each incoming block is re-chunked to ``block_size`` and pushed through
    the same wave engine as the in-memory path, over the graph-free
    ``StreamMembership`` state; ``sink(edges, ms)`` receives ``((k, 2)
    endpoints, (k,) machines)`` slices as placements finalize —
    admission-wave order, not arrival order, since deferred edges carry
    across blocks.  Returns the end-of-stream membership state (RF,
    counts); after a two-pass run its ``spill_stats`` attribute carries
    the :class:`repro.data.SpillStats` accounting.

    ``workers > 1`` hands the whole call to the multi-process pipeline
    (``core/parallel.py``): sharded spill/dedup plus W-worker wave
    scoring against membership snapshots synced every ``sync_blocks``
    engine blocks.  Results are worker-count invariant (the schedule
    depends only on ``sync_blocks``), and ``sync_blocks=1`` is
    bit-identical to this sequential path; ``sync_blocks`` is ignored at
    ``workers=1``, where every wave sees fresh state.
    """
    if workers is not None and int(workers) > 1:
        from ..parallel import parallel_stream_partition
        return parallel_stream_partition(
            source, num_vertices, num_edges, cluster, method,
            workers=int(workers), sync_blocks=sync_blocks, dedup=dedup,
            spill_dir=spill_dir, bucket_rows=bucket_rows,
            block_size=block_size, max_waves=max_waves,
            replica_frac=replica_frac, creator_scalar=creator_scalar,
            sink=sink, **scorer_kw)
    blocks, num_vertices, num_edges, spill, owned = _resolve_stream_source(
        source, num_vertices, num_edges, dedup=dedup, spill_dir=spill_dir,
        bucket_rows=bucket_rows, io_block=block_size)
    scorer = SCORERS[method](**scorer_kw)
    if hasattr(scorer, "reset"):
        scorer.reset(num_vertices)
    state = StreamMembership.empty(num_vertices, cluster.p)
    caps = np.floor(_mem_cap(cluster, num_vertices,
                             num_edges)).astype(np.int64)
    dflt = ENGINE_DEFAULTS[method]
    if block_size is None:
        block_size = dflt["block_size"] or auto_block_size(num_edges)
    B = max(1, int(block_size))
    eng = _BlockEngine(
        state, scorer, caps, num_edges, max(1, num_vertices), block_size=B,
        max_waves=dflt["max_waves"] if max_waves is None else max_waves,
        replica_frac=(dflt["replica_frac"] if replica_frac is None
                      else replica_frac),
        creator_scalar=(dflt["creator_scalar"] if creator_scalar is None
                        else creator_scalar), sink=sink)
    try:
        # re-chunk the source to exact engine-block boundaries: the wave
        # engine's admission quotas key off its block size, so decisions
        # must not depend on how the *source* happened to chunk the stream
        # (spill-merge emit sizes, reader line blocks, ...)
        pend: list = []
        npend = 0
        for edges in blocks:
            edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
            if not len(edges):
                continue
            pend.append(edges)
            npend += len(edges)
            if npend < B:
                continue
            buf = np.concatenate(pend) if len(pend) > 1 else pend[0]
            lo = 0
            while lo + B <= len(buf):
                eng.push(buf[lo:lo + B, 0].copy(), buf[lo:lo + B, 1].copy())
                lo += B
            pend = [buf[lo:]] if lo < len(buf) else []
            npend = len(buf) - lo
        if npend:
            buf = np.concatenate(pend) if len(pend) > 1 else pend[0]
            eng.push(buf[:, 0].copy(), buf[:, 1].copy())
        eng.flush()
    finally:
        if owned:
            spill.close()
    if spill is not None:
        state.spill_stats = spill.stats
    return state


#: Per-method engine defaults, picked from the LJ-proxy grid
#: (benchmarks/partition_time.run_streaming_compare): block size, waves
#: per block before stragglers carry, the replica-throttle fraction, and
#: whether replica-*creating* placements drain through the exact scalar
#: path.  EBV's binary-presence score is the staleness-sensitive one —
#: it sequentializes exactly the creating minority (``creator_scalar``,
#: the hub_split idiom) and keeps the non-creating ~85% vectorized, which
#: replaces the old full-drain + hard-throttle compromise.
ENGINE_DEFAULTS = {
    "greedy": dict(block_size=None, max_waves=6, replica_frac=0.5,
                   creator_scalar=False),
    "hdrf": dict(block_size=None, max_waves=3, replica_frac=1.0,
                 creator_scalar=False),
    "ebv": dict(block_size=None, max_waves=3, replica_frac=0.25,
                creator_scalar=True),
}


def _block_method(name, key, scorer_cls):
    dflt = ENGINE_DEFAULTS[key]

    def run(g: Graph, cluster: Cluster, seed: int = 0,
            block_size: int | None = None, max_waves: int | None = None,
            replica_frac: float | None = None,
            creator_scalar: bool | None = None, **scorer_kw) -> np.ndarray:
        if block_size is None:
            block_size = (dflt["block_size"]
                          or auto_block_size(g.num_edges))
        return block_stream_assign(
            g, cluster, scorer_cls(**scorer_kw), seed=seed,
            block_size=block_size,
            max_waves=dflt["max_waves"] if max_waves is None else max_waves,
            replica_frac=(dflt["replica_frac"] if replica_frac is None
                          else replica_frac),
            creator_scalar=(dflt["creator_scalar"] if creator_scalar is None
                            else creator_scalar))
    run.__name__ = name
    run.__doc__ = (f"Block-stream {name} (see module docstring); "
                   f"``block_size=1`` bit-reproduces ``{name}_oracle``.")
    return run


powergraph_greedy = _block_method("powergraph_greedy", "greedy", GreedyScorer)
hdrf = _block_method("hdrf", "hdrf", HDRFScorer)
ebv = _block_method("ebv", "ebv", EBVScorer)


# ---------------------------------------------------------------------------
# registry entries
# ---------------------------------------------------------------------------

from ..partitioners import Partitioner, register  # noqa: E402

register(Partitioner(
    "hash", random_hash, "streaming",
    "random edge hash + memory spill", frozenset(), ("seed",)))
register(Partitioner(
    "dbh", dbh, "streaming",
    "degree-based hashing [Xie et al. 2014]", frozenset(), ("seed",)))
_ENGINE_KNOBS = ("seed", "block_size", "max_waves", "replica_frac",
                 "creator_scalar")
#: knobs of the graph-free ``stream`` entry (``Partitioner.stream``):
#: engine knobs minus ``seed`` (stream order is arrival order), plus the
#: dedup discipline, spill controls, the placement sink, and the
#: multi-process pipeline's worker count / sync period (the ``parallel``
#: capability).
_STREAM_KNOBS = ("block_size", "max_waves", "replica_frac",
                 "creator_scalar", "dedup", "spill_dir", "bucket_rows",
                 "sink", "workers", "sync_blocks")


def _stream_entry(key):
    def run(source, num_vertices=None, num_edges=None, cluster=None,
            **kw) -> StreamMembership:
        return stream_partition(source, num_vertices, num_edges, cluster,
                                method=key, **kw)
    run.__name__ = f"stream_{key}"
    return run


register(Partitioner(
    "greedy", powergraph_greedy, "streaming",
    "PowerGraph greedy vertex-cut, block-stream engine",
    frozenset({"blocked", "streamable", "parallel"}), _ENGINE_KNOBS,
    stream_fn=_stream_entry("greedy"), stream_knobs=_STREAM_KNOBS))
register(Partitioner(
    "hdrf", hdrf, "streaming",
    "HDRF [Petroni et al. 2015], block-stream engine",
    frozenset({"blocked", "streamable", "parallel"}),
    _ENGINE_KNOBS + ("lam", "eps"),
    stream_fn=_stream_entry("hdrf"),
    stream_knobs=_STREAM_KNOBS + ("lam", "eps")))
register(Partitioner(
    "ebv", ebv, "streaming",
    "EBV [Zhang et al. 2021], block-stream engine",
    frozenset({"blocked", "streamable", "parallel"}),
    _ENGINE_KNOBS + ("w_e", "w_v"),
    stream_fn=_stream_entry("ebv"),
    stream_knobs=_STREAM_KNOBS + ("w_e", "w_v")))
register(Partitioner(
    "greedy_oracle", powergraph_greedy_oracle, "streaming",
    "per-edge PowerGraph greedy (block-engine test reference)",
    frozenset({"oracle"}), ("seed",)))
register(Partitioner(
    "hdrf_oracle", hdrf_oracle, "streaming",
    "per-edge HDRF (block-engine test reference)",
    frozenset({"oracle"}), ("seed", "lam", "eps")))
register(Partitioner(
    "ebv_oracle", ebv_oracle, "streaming",
    "per-edge EBV (block-engine test reference)",
    frozenset({"oracle"}), ("seed", "w_e", "w_v")))
