"""Streaming edge partitioners: hash, DBH, PowerGraph-greedy, HDRF, EBV.

All receive the same heterogeneous-memory adaptation the paper applies to
its baselines: a per-machine edge-capacity cap derived from M_i (identical
to the one WindGP's preprocessing uses), with overflow spilling to the
best-scoring machine that still has room.  The hash-family overflow pass
(hash, DBH) runs through the shared incremental layer
(``core/partition_state.py``): overflow edges beyond each machine's cap
are repaired in vectorized greedy waves instead of a per-edge Python scan
over its own bincounts.
"""
from __future__ import annotations

import numpy as np

from ..capacity import _mem_cap
from ..graph import Graph
from ..machines import Cluster
from ..partition_state import PartitionState, cumcount
from ..sls import repair_edges


def _caps(cluster: Cluster, g: Graph) -> np.ndarray:
    return np.floor(_mem_cap(cluster, g.num_vertices, g.num_edges)).astype(np.int64)


def _spill(scores: np.ndarray, counts: np.ndarray, caps: np.ndarray) -> int:
    """Best-scoring machine with room (scores higher = better)."""
    ok = counts < caps
    if not ok.any():
        return int(np.argmin(counts - caps))   # least-overfull fallback
    masked = np.where(ok, scores, -np.inf)
    return int(np.argmax(masked))


def _cap_spill(g: Graph, cluster: Cluster, assign: np.ndarray,
               caps: np.ndarray) -> np.ndarray:
    """Deterministic overflow pass for the hash-family partitioners.

    Each machine keeps its first ``caps[i]`` edges in stream order; the
    overflow is re-placed by the shared vectorized BalancedGreedyRepair
    (memory-aware, TC-accounted) instead of the old per-edge count scan.
    """
    if np.all(np.bincount(assign, minlength=cluster.p) <= caps):
        return assign
    over = cumcount(assign) >= caps[assign]
    assign = assign.copy()
    assign[over] = -1
    obj = PartitionState.build(g, assign, cluster)
    repair_edges(obj, np.flatnonzero(over), [[] for _ in range(cluster.p)])
    return obj.assign


def random_hash(g: Graph, cluster: Cluster, seed: int = 0) -> np.ndarray:
    """f(e) = hash(e) % p with memory spill."""
    p = cluster.p
    h = (g.edges[:, 0].astype(np.uint64) * np.uint64(2654435761)
         ^ g.edges[:, 1].astype(np.uint64) * np.uint64(40503)) % np.uint64(p)
    return _cap_spill(g, cluster, h.astype(np.int32), _caps(cluster, g))


def dbh(g: Graph, cluster: Cluster, seed: int = 0) -> np.ndarray:
    """Degree-Based Hashing [Xie et al. 2014]: hash the low-degree endpoint."""
    p = cluster.p
    deg = g.degree()
    u, v = g.edges[:, 0], g.edges[:, 1]
    low = np.where(deg[u] <= deg[v], u, v).astype(np.uint64)
    assign = ((low * np.uint64(2654435761)) % np.uint64(p)).astype(np.int32)
    return _cap_spill(g, cluster, assign, _caps(cluster, g))


def powergraph_greedy(g: Graph, cluster: Cluster, seed: int = 0) -> np.ndarray:
    """PowerGraph's greedy vertex-cut [Gonzalez et al. 2012].

    Prefer machines holding both endpoints, then either, then least loaded;
    ties broken by load.
    """
    p = cluster.p
    caps = _caps(cluster, g)
    member = np.zeros((p, g.num_vertices), dtype=bool)
    counts = np.zeros(p, dtype=np.int64)
    assign = np.empty(g.num_edges, dtype=np.int32)
    rng = np.random.default_rng(seed)
    order = rng.permutation(g.num_edges)       # stream order
    load_score = lambda: -counts / np.maximum(1, caps)
    for e in order:
        u, v = g.edges[e]
        au, av = member[:, u], member[:, v]
        both, either = au & av, au | av
        base = load_score()
        if both.any():
            scores = np.where(both, base + 4, -np.inf)
        elif either.any():
            scores = np.where(either, base + 2, -np.inf)
        else:
            scores = base
        i = _spill(scores, counts, caps)
        assign[e] = i
        member[i, u] = member[i, v] = True
        counts[i] += 1
    return assign


def hdrf(g: Graph, cluster: Cluster, seed: int = 0,
         lam: float = 1.0, eps: float = 1.0) -> np.ndarray:
    """High-Degree Replicated First [Petroni et al. 2015]."""
    p = cluster.p
    caps = _caps(cluster, g)
    member = np.zeros((p, g.num_vertices), dtype=bool)
    counts = np.zeros(p, dtype=np.int64)
    pdeg = np.zeros(g.num_vertices, dtype=np.int64)   # partial degrees
    assign = np.empty(g.num_edges, dtype=np.int32)
    rng = np.random.default_rng(seed)
    order = rng.permutation(g.num_edges)
    for e in order:
        u, v = g.edges[e]
        pdeg[u] += 1
        pdeg[v] += 1
        du, dv = pdeg[u], pdeg[v]
        theta_u = du / (du + dv)
        theta_v = 1.0 - theta_u
        g_u = np.where(member[:, u], 1.0 + (1.0 - theta_u), 0.0)
        g_v = np.where(member[:, v], 1.0 + (1.0 - theta_v), 0.0)
        maxs, mins = counts.max(), counts.min()
        c_bal = lam * (maxs - counts) / (eps + maxs - mins)
        i = _spill(g_u + g_v + c_bal, counts, caps)
        assign[e] = i
        member[i, u] = member[i, v] = True
        counts[i] += 1
    return assign


def ebv(g: Graph, cluster: Cluster, seed: int = 0,
        w_e: float = 1.0, w_v: float = 1.0) -> np.ndarray:
    """Efficient-and-Balanced Vertex-cut [Zhang et al. 2021].

    Streams edges sorted by end-degree sum ascending; score for machine i:
    I(u∉V_i) + I(v∉V_i) + w_e·p|E_i|/|E| + w_v·p|V_i|/|V|  (minimized).
    """
    p = cluster.p
    caps = _caps(cluster, g)
    member = np.zeros((p, g.num_vertices), dtype=bool)
    counts = np.zeros(p, dtype=np.int64)
    vcounts = np.zeros(p, dtype=np.int64)
    assign = np.empty(g.num_edges, dtype=np.int32)
    deg = g.degree()
    order = np.argsort(deg[g.edges[:, 0]] + deg[g.edges[:, 1]], kind="stable")
    nE, nV = g.num_edges, max(1, g.num_vertices)
    for e in order:
        u, v = g.edges[e]
        rep = (~member[:, u]).astype(np.float64) + (~member[:, v])
        score = rep + w_e * p * counts / nE + w_v * p * vcounts / nV
        i = _spill(-score, counts, caps)
        assign[e] = i
        if not member[i, u]:
            member[i, u] = True
            vcounts[i] += 1
        if not member[i, v]:
            member[i, v] = True
            vcounts[i] += 1
        counts[i] += 1
    return assign
