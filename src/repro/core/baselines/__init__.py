"""Baseline edge partitioners the paper compares against (Section 5).

Each is adapted — exactly as the paper does for fairness — to heterogeneous
machines by adding per-machine memory-capacity constraints; otherwise they
optimize their original homogeneous objectives.
"""
from .streaming import dbh, ebv, hdrf, powergraph_greedy, random_hash
from .ne import ne
from .metis_like import metis_like

PARTITIONERS = {
    "hash": random_hash,
    "dbh": dbh,
    "greedy": powergraph_greedy,
    "hdrf": hdrf,
    "ebv": ebv,
    "ne": ne,
    "metis": metis_like,
}

__all__ = ["dbh", "ebv", "hdrf", "powergraph_greedy", "random_hash", "ne",
           "metis_like", "PARTITIONERS"]
