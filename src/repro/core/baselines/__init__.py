"""Baseline edge partitioners the paper compares against (Section 5).

Each is adapted — exactly as the paper does for fairness — to heterogeneous
machines by adding per-machine memory-capacity constraints; otherwise they
optimize their original homogeneous objectives.

Every method lives in the unified registry (``core/partitioners.py``);
``PARTITIONERS`` survives as a snapshot of it (per-edge ``*_oracle``
reference loops excluded — they are test references, not benchmark
entries), so legacy ``PARTITIONERS[name](g, cluster)`` call sites keep
working unchanged.
"""
from .streaming import (dbh, ebv, ebv_oracle, hdrf, hdrf_oracle,
                        powergraph_greedy, powergraph_greedy_oracle,
                        random_hash, stream_partition)
from .ne import ne
from .metis_like import metis_like

from ..partitioners import get, partitioner_dict

# windgp's driver entries register on import of repro.core.windgp, which
# ``partitioner_dict`` triggers via the registry's _ensure_builtin.
PARTITIONERS = partitioner_dict(exclude={"oracle"})

windgp_heap = PARTITIONERS["windgp_heap"]
windgp_batched = PARTITIONERS["windgp_batched"]

__all__ = ["dbh", "ebv", "hdrf", "powergraph_greedy", "random_hash", "ne",
           "metis_like", "windgp_heap", "windgp_batched", "PARTITIONERS",
           "ebv_oracle", "hdrf_oracle", "powergraph_greedy_oracle",
           "stream_partition", "get", "partitioner_dict"]
