"""Baseline edge partitioners the paper compares against (Section 5).

Each is adapted — exactly as the paper does for fairness — to heterogeneous
machines by adding per-machine memory-capacity constraints; otherwise they
optimize their original homogeneous objectives.

``windgp_heap`` / ``windgp_batched`` expose the two WindGP expansion
engines through the same ``(g, cluster) -> assign`` interface so the
benchmark harnesses can sweep every method uniformly.
"""
from .streaming import dbh, ebv, hdrf, powergraph_greedy, random_hash
from .ne import ne
from .metis_like import metis_like


def _windgp_with(engine):
    def run(g, cluster, **kw):
        from ..windgp import windgp  # deferred: windgp imports this package
        return windgp(g, cluster, engine=engine, **kw).assign
    run.__name__ = f"windgp_{engine}"
    return run


windgp_heap = _windgp_with("heap")
windgp_batched = _windgp_with("batched")

PARTITIONERS = {
    "hash": random_hash,
    "dbh": dbh,
    "greedy": powergraph_greedy,
    "hdrf": hdrf,
    "ebv": ebv,
    "ne": ne,
    "metis": metis_like,
    "windgp_heap": windgp_heap,
    "windgp_batched": windgp_batched,
}

__all__ = ["dbh", "ebv", "hdrf", "powergraph_greedy", "random_hash", "ne",
           "metis_like", "windgp_heap", "windgp_batched", "PARTITIONERS"]
