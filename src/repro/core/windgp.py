"""WindGP driver: preprocessing → best-first expansion → subgraph-local search.

The three phases correspond to the paper's Figure 4.  Ablation levels:

* ``windgp-``  : naive NE-style expansion with homogeneous |E|/p capacities
                 (the paper's WindGP− baseline)
* ``windgp*``  : + heterogeneous capacities (Alg. 1), NE-style expansion
* ``windgp+``  : + best-first search (α, β)             [no post-processing]
* ``windgp``   : + subgraph-local search                [the full method]

Expansion engines (the ``engine=`` switch, threaded through both the
expansion phase and SLS's re-partition operator):

* ``engine="batched"`` (default): the frontier-scan engine — quantized
  Eq. 5 scores kept fresh per vertex, whole best-window frontier slices
  admitted per step with vectorized AllocEdges, degree-split hub/tail
  frontier (≥5× faster partitioning at matching TC; see
  ``core/expand.py``).  Extra knobs (``scale``, ``batch_frac``,
  ``batch_window``, ``strict_ties``, ``hub_split``, ``hub_degree``) pass
  through ``**engine_kw``.
* ``engine="heap"``: the scalar lazy-min-heap reference oracle — exactly
  the paper's Algorithms 2-3; keep for equivalence checks and debugging.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from . import capacity as cap
from . import expand as exp
from . import sls as sls_mod
from .graph import Graph
from .machines import Cluster, PartitionStats, evaluate


@dataclasses.dataclass(frozen=True)
class WindGPResult:
    assign: np.ndarray
    stats: PartitionStats
    deltas: np.ndarray
    seconds: float
    phase_seconds: dict


def _repair_unassigned(g: Graph, assign: np.ndarray, cluster: Cluster,
                       orders: list[list[int]]) -> np.ndarray:
    """Safety net: greedily place any edge the expansion could not fit.

    Runs the shared vectorized BalancedGreedyRepair waves over the whole
    leftover set at once (``sls.repair_edges``).
    """
    left = np.flatnonzero(assign < 0)
    if len(left) == 0:
        return assign
    obj = sls_mod.PartitionState.build(g, assign, cluster)
    sls_mod.repair_edges(obj, left, orders)
    return obj.assign


def _train_rebalance(g: Graph, assign: np.ndarray, cluster: Cluster,
                     orders: list[list[int]], train_mask: np.ndarray,
                     mu: float, rounds: int = 3,
                     slack: float = 1.05) -> np.ndarray:
    """Spread the labeled/train vertex set across machines (GNN epochs
    stress machines by hosted train vertices, not edges — cf. graphstorm's
    ``--balance_train``).

    Evicts the partition-local edges of the cheapest-to-move train
    vertices on machines holding more than ``slack ×`` the mean train
    count, then re-places them through the shared BalancedGreedyRepair
    waves over a *train-weighted* :class:`PartitionState` (Eq. 3 charges
    ``c_node·(1+mu)`` per hosted train vertex), so the repair itself
    steers the replacements toward train-light machines.  Bounded and
    monotone-ish: each round only touches overloaded machines; stops
    early when none remain.
    """
    tm = np.asarray(train_mask, dtype=bool)
    state = sls_mod.PartitionState.build(g, assign, cluster,
                                         train_mask=tm, train_balance=mu)
    train_ids = np.flatnonzero(tm)
    if len(train_ids) == 0:
        return state.assign
    for _ in range(max(1, int(rounds))):
        counts = state.train_counts(tm).astype(np.float64)
        target = counts.mean() * slack
        over = np.flatnonzero(counts > target + 1.0)
        if len(over) == 0:
            break
        evict = []
        for i in over:
            held = train_ids[state.cnt[i, train_ids] > 0]
            # cheapest-to-move first: fewest machine-i incident edges
            held = held[np.argsort(state.cnt[i, held], kind="stable")]
            n_drop = int(min(len(held), np.ceil(counts[i] - target)))
            for v in held[:n_drop]:
                eids = g.incident_edge_ids(int(v))
                evict.append(eids[state.assign[eids] == i])
        if not evict:
            break
        es = np.unique(np.concatenate(evict))
        if len(es) == 0:
            break
        state.remove_edges(es)
        sls_mod.repair_edges(state, es, orders)
    return state.assign


def windgp(
    g: Graph,
    cluster: Cluster,
    *,
    alpha: float = 0.3,
    beta: float = 0.3,
    gamma: float = 0.9,
    theta: float = 0.01,
    t0: int = 8,
    n0: int = 5,
    k: int = 3,
    level: str = "windgp",
    seed: int = 0,
    engine: str = "batched",
    repair: str = "vectorized",
    train_mask: np.ndarray | None = None,
    train_balance: float = 0.0,
    train_rounds: int = 3,
    **engine_kw,
) -> WindGPResult:
    """Run WindGP (or one of its ablations) and evaluate the TC metric.

    ``repair`` selects SLS's destroy-repair sweep: the vectorized wave
    implementation (default) or the per-edge ``"scalar"`` oracle.
    ``train_mask`` + ``train_balance`` > 0 append the training-aware
    rebalance pass (:func:`_train_rebalance`): machines then balance
    hosted labeled vertices as well as Eq. 3/4 cost — the knob GNN
    minibatch sampling needs so every machine draws comparable seed
    batches.
    """
    assert level in ("windgp-", "windgp*", "windgp+", "windgp")
    assert engine in exp.ENGINES, engine
    t_start = time.perf_counter()
    phases = {}

    # Phase 1: capacities.
    t0_ = time.perf_counter()
    if level == "windgp-":
        # Homogeneous target |E|/p, clamped by memory (naive baseline).
        p = cluster.p
        mem_cap = np.floor(cluster.memory()
                           / (cluster.m_edge + cluster.m_node
                              * g.num_vertices / max(1, g.num_edges)))
        deltas = np.minimum(np.full(p, g.num_edges // p + 1), mem_cap)
        deltas = deltas.astype(np.int64)
        # ensure sum >= |E| by topping up machines with memory room
        short = g.num_edges - int(deltas.sum())
        j = 0
        while short > 0 and j < p:
            room = int(mem_cap[j] - deltas[j])
            take = min(room, short)
            deltas[j] += take
            short -= take
            j += 1
    else:
        deltas = cap.capacities(cluster, g.num_vertices, g.num_edges)
    phases["preprocess"] = time.perf_counter() - t0_

    # Phase 2: expansion.
    t0_ = time.perf_counter()
    if level in ("windgp-", "windgp*"):
        a, b = 0.0, 0.0        # pure NE-style: only |N(v)\S| drives selection
    else:
        a, b = alpha, beta
    assign, orders = exp.run_expansion(
        g, deltas, a, b, memories=cluster.memory(),
        m_node=cluster.m_node, m_edge=cluster.m_edge,
        engine=engine, **engine_kw)
    assign = _repair_unassigned(g, assign, cluster, orders)
    phases["expand"] = time.perf_counter() - t0_

    # Phase 3: subgraph-local search.
    t0_ = time.perf_counter()
    if level == "windgp":
        assign, _ = sls_mod.sls(
            g, assign, cluster, orders, deltas, t0=t0, n0=n0,
            gamma=gamma, theta=theta, k=k, alpha=alpha, beta=beta, seed=seed,
            engine=engine, repair=repair, **engine_kw)
    phases["sls"] = time.perf_counter() - t0_

    # Phase 4 (optional): training-aware rebalance.
    if train_mask is not None and train_balance:
        t0_ = time.perf_counter()
        assign = _train_rebalance(g, assign, cluster, orders, train_mask,
                                  float(train_balance),
                                  rounds=int(train_rounds))
        phases["train_balance"] = time.perf_counter() - t0_

    stats = evaluate(g, assign, cluster)
    return WindGPResult(
        assign=assign, stats=stats, deltas=np.asarray(deltas),
        seconds=time.perf_counter() - t_start, phase_seconds=phases)


# ---------------------------------------------------------------------------
# registry entries: the driver and its two expansion engines
# ---------------------------------------------------------------------------

from .partitioners import Partitioner, register  # noqa: E402

_DRIVER_KNOBS = ("alpha", "beta", "gamma", "theta", "t0", "n0", "k",
                 "level", "seed", "repair", "scale", "batch_frac",
                 "batch_window", "strict_ties", "hub_split", "hub_degree",
                 "train_mask", "train_balance", "train_rounds")


def _windgp_assign(engine=None):
    def run(g, cluster, **kw):
        if engine is not None:
            kw["engine"] = engine
        return windgp(g, cluster, **kw).assign
    run.__name__ = f"windgp_{engine}" if engine else "windgp"
    return run


register(Partitioner(
    "windgp", _windgp_assign(), "driver",
    "full WindGP driver (default batched engine)",
    frozenset({"driver", "heterogeneous"}), _DRIVER_KNOBS + ("engine",)))
register(Partitioner(
    "windgp_heap", _windgp_assign("heap"), "driver",
    "WindGP with the scalar heap expansion oracle",
    frozenset({"driver", "heterogeneous"}), _DRIVER_KNOBS))
register(Partitioner(
    "windgp_batched", _windgp_assign("batched"), "driver",
    "WindGP with the batched frontier-scan engine",
    frozenset({"driver", "heterogeneous"}), _DRIVER_KNOBS))
