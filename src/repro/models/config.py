"""Architecture configuration for the composable decoder stack."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | ssm | moe | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads

    # attention flavor
    attn_type: str = "gqa"        # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_fraction: float = 1.0    # glm4 rotates half the head dim

    # MLA (minicpm3)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1            # jamba: MoE on every 2nd layer
    capacity_factor: float = 1.25

    # SSM (mamba2 / jamba)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 128

    # hybrid pattern: one attention layer per `attn_every` (jamba 1:7)
    attn_every: int = 0

    # misc
    mlp_type: str = "swiglu"      # swiglu | geglu | gelu | none
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    input_mode: str = "tokens"    # tokens | embeddings (audio/vlm stubs)
    max_seq_len: int = 131072
    dtype: str = "bfloat16"

    # ---- performance knobs (hillclimbed in EXPERIMENTS.md §Perf) -------
    act_shard: str = "none"       # none | batch: pin residual stream to DP
                                  # sharding | seq: Megatron-SP over S
    moe_ep: bool = False          # constrain expert buffers to EP sharding
    moe_groups: int = 0           # >0: group-local token dispatch (sorts
                                  # stay shard-local; one all-to-all into
                                  # expert sharding instead of global sort)
    pad_group_to: int = 0         # GQA in-group q-head padding: pad each
                                  # kv group to this size (exact semantics,
                                  # enables clean head TP for 40-head archs)
    block_q: int = 512            # flash-attention tile sizes
    block_k: int = 1024

    # ----- derived -----------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:     # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def num_heads_padded(self) -> int:
        """Q heads after in-group padding (pad_group_to); == num_heads when
        the knob is off.  Padded slots carry zero weights (exact semantics)
        and make the head count divisible for clean TP."""
        if self.attn_type != "gqa" or not self.pad_group_to:
            return self.num_heads
        g = self.num_heads // self.num_kv_heads
        if self.pad_group_to <= g:
            return self.num_heads
        return self.num_kv_heads * self.pad_group_to

    def layer_kind(self, i: int) -> str:
        """'attn' | 'ssm' for the mixer of layer i."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            # 1:7 interleave — one attention layer per attn_every block.
            return "attn" if (i % self.attn_every) == self.attn_every // 2 \
                else "ssm"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if self.num_experts == 0:
            return False
        return (i % self.moe_every) == self.moe_every - 1

    @property
    def pattern_period(self) -> int:
        """Layers per scanned super-block (lcm of mixer / moe patterns)."""
        if self.family == "hybrid":
            import math
            return math.lcm(self.attn_every, self.moe_every)
        return self.moe_every if self.num_experts else 1
