"""Shared neural layers: norms, RoPE, attention (GQA / MLA), FFN, MoE, SSD.

Everything is a pure function over a params dict; init_* builds the params.
All attention paths use a JAX-native blockwise (flash) formulation so the
32k-prefill dry-runs fit memory; the Pallas kernels in ``repro.kernels``
are the TPU-optimized drop-ins validated against the same math.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from .config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms & RoPE
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float, fraction: float = 1.0):
    """x: (..., S, H, D). Rotates the first ``fraction·D`` dims."""
    D = x.shape[-1]
    rot = int(D * fraction) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # positions: (..., S) -> (..., S, 1, half)
    ang = positions.astype(jnp.float32)[..., :, None, None] * freq
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half], xr[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Blockwise causal attention (JAX-native flash)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True, q_offset=0,
                    kv_lengths=None, block_q: int = 512, block_k: int = 1024):
    """q: (B, Sq, H, D); k, v: (B, Skv, KVH, D) -> (B, Sq, H, D).

    Online-softmax over KV blocks inside a scan over Q blocks: HLO stays
    O(1) in sequence length and live memory is O(block_q · block_k).
    q_offset: absolute position of q[0] (decode/prefill continuation).
    kv_lengths: (B,) valid KV prefix for left-padded caches.
    """
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]                 # may differ from D (MLA latent values)
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    nq, nk = -(-Sq // bq), -(-Skv // bk)
    # pad to block multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * bq - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * bk - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * bk - Skv), (0, 0), (0, 0)))
    qp = qp.reshape(B, nq, bq, KVH, G, D)
    kp = kp.reshape(B, nk, bk, KVH, D)
    vp = vp.reshape(B, nk, bk, KVH, Dv)

    def q_block(carry, qi):
        qb = qp[:, qi]                                     # (B,bq,KVH,G,D)
        q_pos = q_offset + qi * bq + jnp.arange(bq)

        def kv_block(acc, ki):
            m, l, o = acc
            kb, vb = kp[:, ki], vp[:, ki]                  # (B,bk,KVH,D)
            k_pos = ki * bk + jnp.arange(bk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            mask = k_pos[None, :] < Skv                    # drop pad
            if causal:
                mask = mask & (q_pos[:, None] >= k_pos[None, :])
            if kv_lengths is not None:
                mask = mask[None] & (
                    k_pos[None, None, :] < kv_lengths[:, None, None])
                s = jnp.where(mask[:, None, None], s, -1e30)
            else:
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            o_new = o * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, KVH, G, bq), -1e30, dtype=jnp.float32)
        l0 = jnp.zeros((B, KVH, G, bq), dtype=jnp.float32)
        o0 = jnp.zeros((B, KVH, G, bq, Dv), dtype=jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0), jnp.arange(nk))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        # (B,KVH,G,bq,D) -> (B,bq,KVH,G,D)
        return carry, o.transpose(0, 3, 1, 2, 4)

    _, blocks = jax.lax.scan(q_block, 0, jnp.arange(nq))   # (nq,B,bq,KVH,G,D)
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * bq, H, Dv)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def head_pad_mask(cfg: ModelConfig):
    """(Hp,) 1.0 for real q-head slots, 0.0 for in-group padding slots.

    Slot layout: kv group j owns slots [j·P, (j+1)·P); the first G are real
    (G = true group size, P = cfg.pad_group_to).  Flash attention's
    ``slot // P -> kv head`` mapping is then exact by construction.
    """
    Hp, H, KVH = cfg.num_heads_padded, cfg.num_heads, cfg.num_kv_heads
    if Hp == H:
        return None
    g, P = H // KVH, Hp // KVH
    mask = jnp.zeros((Hp,), jnp.float32)
    real = jnp.arange(KVH)[:, None] * P + jnp.arange(g)[None, :]
    return mask.at[real.reshape(-1)].set(1.0)


def init_attention(cfg: ModelConfig, key):
    hd, KVH, d = cfg.head_dim_, cfg.num_kv_heads, cfg.d_model
    Hp = cfg.num_heads_padded
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(k1, (d, Hp, hd), _dtype(cfg)) * s,
        "wk": jax.random.normal(k2, (d, KVH, hd), _dtype(cfg)) * s,
        "wv": jax.random.normal(k3, (d, KVH, hd), _dtype(cfg)) * s,
        "wo": jax.random.normal(k4, (Hp, hd, d), _dtype(cfg)) * s / math.sqrt(cfg.num_layers),
    }
    mask = head_pad_mask(cfg)
    if mask is not None:
        # padded slots are zero and receive zero gradients (their wo rows
        # are zero, so no loss path reaches them): exact semantics.
        p["wq"] = p["wq"] * mask[None, :, None].astype(p["wq"].dtype)
        p["wo"] = p["wo"] * mask[:, None, None].astype(p["wo"].dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), _dtype(cfg))
        p["k_norm"] = jnp.ones((hd,), _dtype(cfg))
    return p


def attention(cfg: ModelConfig, p, x, positions, *, cache=None,
              cache_len=None):
    """x: (B, S, d).  cache: dict(k,v: (B, Smax, KVH, hd)) for decode."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    new_cache = None
    if cache is not None:
        # decode: append at cache_len, attend over the prefix
        idx = cache_len[:, None] + jnp.arange(x.shape[1])[None, :]
        ck = jax.vmap(lambda c, i, u: c.at[i].set(u))(cache["k"], idx, k)
        cv = jax.vmap(lambda c, i, u: c.at[i].set(u))(cache["v"], idx, v)
        new_cache = {"k": ck, "v": cv}
        # S > 1 => prefill from an empty cache (causal); S == 1 => decode.
        out = flash_attention(q, ck, cv, causal=x.shape[1] > 1,
                              kv_lengths=cache_len + x.shape[1],
                              block_q=cfg.block_q, block_k=cfg.block_k)
    else:
        out = flash_attention(q, k, v, causal=True,
                              block_q=cfg.block_q, block_k=cfg.block_k)
    mask = head_pad_mask(cfg)
    if mask is not None:
        # zero the padded heads' outputs so their wo rows get zero grads
        # (keeps padding semantically inert under training)
        out = out * mask[None, None, :, None].astype(out.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA attention (MiniCPM3 / DeepSeek-style latent KV)
# ---------------------------------------------------------------------------

def init_mla(cfg: ModelConfig, key):
    d, H = cfg.d_model, cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    p = {
        "w_dkv": jax.random.normal(ks[0], (d, r), _dtype(cfg)) * s,
        "kv_norm": jnp.ones((r,), _dtype(cfg)),
        "w_uk": jax.random.normal(ks[1], (r, H, dn), _dtype(cfg)) / math.sqrt(r),
        "w_uv": jax.random.normal(ks[2], (r, H, dv), _dtype(cfg)) / math.sqrt(r),
        "w_kr": jax.random.normal(ks[3], (d, dr), _dtype(cfg)) * s,
        "wo": jax.random.normal(ks[4], (H, dv, d), _dtype(cfg)) / math.sqrt(H * dv * cfg.num_layers),
    }
    if qr:
        p["w_dq"] = jax.random.normal(ks[5], (d, qr), _dtype(cfg)) * s
        p["q_norm"] = jnp.ones((qr,), _dtype(cfg))
        p["w_uq"] = jax.random.normal(ks[6], (qr, H, dn + dr), _dtype(cfg)) / math.sqrt(qr)
    else:
        p["wq"] = jax.random.normal(ks[6], (d, H, dn + dr), _dtype(cfg)) * s
    return p


def mla_attention(cfg: ModelConfig, p, x, positions, *, cache=None,
                  cache_len=None):
    """Multi-head Latent Attention; caches the compressed latent + k_rope."""
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if cfg.q_lora_rank:
        ql = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"],
                      cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", ql, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    latent = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"],
                      cfg.norm_eps)
    k_rope = rope(jnp.einsum("bsd,dk->bsk", x, p["w_kr"])[:, :, None, :],
                  positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    if cache is not None:
        idx = cache_len[:, None] + jnp.arange(S)[None, :]
        cl = jax.vmap(lambda c, i, u: c.at[i].set(u))(cache["latent"], idx, latent)
        cr = jax.vmap(lambda c, i, u: c.at[i].set(u))(cache["k_rope"], idx, k_rope)
        new_cache = {"latent": cl, "k_rope": cr}
        latent_all, k_rope_all = cl, cr
        lengths = cache_len + S
    else:
        latent_all, k_rope_all = latent, k_rope
        lengths = None

    # absorbed form: score = (q_nope·W_uk)·latent + q_rope·k_rope
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, p["w_uk"])
    qq = jnp.concatenate([q_lat, q_rope], axis=-1)          # (B,S,H,r+dr)
    kk = jnp.concatenate([latent_all,
                          k_rope_all], axis=-1)[:, :, None, :]  # (B,Sk,1,r+dr)
    # values = latent (per-head projection absorbed after attention)
    ctx = flash_attention(qq, kk, latent_all[:, :, None, :],
                          causal=(cache is None or S > 1),
                          kv_lengths=lengths,
                          block_q=cfg.block_q, block_k=cfg.block_k)
    out = jnp.einsum("bshr,rhv->bshv", ctx, p["w_uv"])      # (B,S,H,dv)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# FFN & MoE
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    p = {"w_down": jax.random.normal(k2, (f, d), _dtype(cfg)) / math.sqrt(f * cfg.num_layers)}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(k1, (d, f), _dtype(cfg)) * s
        p["w_up"] = jax.random.normal(k3, (d, f), _dtype(cfg)) * s
    else:
        p["w_up"] = jax.random.normal(k1, (d, f), _dtype(cfg)) * s
    return p


def mlp(cfg: ModelConfig, p, x):
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) \
            * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


def init_moe(cfg: ModelConfig, key):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "router": jax.random.normal(k1, (d, E), jnp.float32) * s,
        "w_gate": jax.random.normal(k2, (E, d, f), _dtype(cfg)) * s,
        "w_up": jax.random.normal(k3, (E, d, f), _dtype(cfg)) * s,
        "w_down": jax.random.normal(k4, (E, f, d), _dtype(cfg)) / math.sqrt(f * cfg.num_layers),
    }


def moe_ffn(cfg: ModelConfig, p, x):
    """Token-choice top-k MoE with sort-based capacity dispatch.

    Fixed-shape throughout (argsort + scatter).  With ``cfg.moe_groups = G``
    tokens are split into G groups aligned with the data sharding so every
    argsort/scatter is shard-local, and with ``cfg.moe_ep`` the per-group
    expert buffers are constrained to (g→data, e→model) — the cross-device
    motion becomes one buffer all-to-all into expert parallelism instead of
    a global sort (§Perf iteration log).
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    N = B * S
    G = cfg.moe_groups if (cfg.moe_groups and (B * S) % cfg.moe_groups == 0
                           and B * S // cfg.moe_groups >= K) else 1
    n = N // G
    xf = x.reshape(G, n, d)
    logits = jnp.einsum("gnd,de->gne", xf.astype(jnp.float32), p["router"])
    gates, idx = jax.lax.top_k(logits, K)                  # (G, n, K)
    weights = jax.nn.softmax(gates, axis=-1)
    flat_e = idx.reshape(G, n * K)
    tok = jnp.tile(jnp.repeat(jnp.arange(n), K)[None], (G, 1))
    w = weights.reshape(G, n * K)
    order = jnp.argsort(flat_e, axis=-1)                   # per-group sort
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    stok = jnp.take_along_axis(tok, order, axis=-1)
    sw = jnp.take_along_axis(w, order, axis=-1)
    seg_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E), side="left"))(se)
    pos = jnp.arange(n * K)[None, :] - jnp.take_along_axis(
        seg_start, se, axis=-1)
    # capacity: cf-scaled at training batch sizes; clamped so tiny serving
    # batches (decode: one token per sequence) never drop.
    cap = max(1, int(cfg.capacity_factor * n * K / E), min(n, 128))
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, E * cap)        # overflow -> dump
    gslot = (jnp.arange(G)[:, None] * (E * cap + 1) + slot).reshape(-1)
    gtok = (jnp.arange(G)[:, None] * n + stok).reshape(-1)
    buf = jnp.zeros((G * (E * cap + 1), d), x.dtype).at[gslot].add(
        jnp.where(keep.reshape(-1)[:, None], xf.reshape(N, d)[gtok], 0))
    h = buf.reshape(G, E * cap + 1, d)[:, :-1].reshape(G, E, cap, d)
    if cfg.moe_ep:
        from jax.sharding import PartitionSpec as _P
        h = jax.lax.with_sharding_constraint(
            h, _P("data" if G > 1 else None, "model", None, None))
    act = jax.nn.silu if cfg.mlp_type != "gelu" else jax.nn.gelu
    hidden = act(jnp.einsum("gecd,edf->gecf", h, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", h, p["w_up"])
    out_e = jnp.einsum("gecf,efd->gecd", hidden, p["w_down"])
    if cfg.moe_ep:
        from jax.sharding import PartitionSpec as _P
        out_e = jax.lax.with_sharding_constraint(
            out_e, _P("data" if G > 1 else None, None, None, None))
    flat_out = out_e.reshape(G, E * cap, d)
    flat_out = jnp.concatenate(
        [flat_out, jnp.zeros((G, 1, d), x.dtype)], axis=1).reshape(-1, d)
    gathered = flat_out[gslot]
    out = jnp.zeros((N, d), x.dtype).at[gtok].add(
        gathered * (sw.reshape(-1) * keep.reshape(-1))[:, None].astype(x.dtype))
    return out.reshape(B, S, d)


# ---------------------------------------------------------------------------
# Mamba-2 mixer (SSD)
# ---------------------------------------------------------------------------

def ssd_jax(x, b, c, a, chunk: int, return_state: bool = False):
    """Chunked SSD, pure JAX (the lowering-friendly twin of kernels/ssd).

    x: (B, T, nh, dh); b, c: (B, T, G, ds); a: (B, T, nh) log-decay.
    If return_state, also returns the final state (B, nh, ds, dh) so
    prefill can seed the decode cache.
    """
    B, T, nh, dh = x.shape
    G, ds = b.shape[2], b.shape[3]
    L = min(chunk, T)
    pad = (-T) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // L
    rep = nh // G
    xc = x.reshape(B, nc, L, nh, dh).astype(jnp.float32)
    bc = jnp.repeat(b.reshape(B, nc, L, G, ds), rep, axis=3).astype(jnp.float32)
    cc = jnp.repeat(c.reshape(B, nc, L, G, ds), rep, axis=3).astype(jnp.float32)
    ac = a.reshape(B, nc, L, nh).astype(jnp.float32)
    cum = jnp.cumsum(ac, axis=2)                          # (B,nc,L,nh)

    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,L,L,nh)
    tri = jnp.tril(jnp.ones((L, L), bool))[None, None, :, :, None]
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)), 0.0)
    scores = jnp.einsum("bnlhs,bnmhs->bnlmh", cc, bc) * decay
    y_intra = jnp.einsum("bnlmh,bnmhd->bnlhd", scores, xc)

    # chunk states
    wdec = jnp.exp(cum[:, :, -1:, :] - cum)               # (B,nc,L,nh)
    s_c = jnp.einsum("bnlh,bnlhs,bnlhd->bnhsd", wdec, bc, xc)
    d_c = jnp.exp(cum[:, :, -1, :])                       # (B,nc,nh)

    def step(h, inp):
        s, dmul = inp
        h_new = dmul[:, :, None, None] * h + s
        return h_new, h                                    # emit carry-in
    h0 = jnp.zeros((B, nh, ds, dh), jnp.float32)
    h_last, h_in = jax.lax.scan(step, h0, (s_c.transpose(1, 0, 2, 3, 4),
                                           d_c.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                   # (B,nc,nh,ds,dh)
    y_inter = jnp.einsum("bnlhs,bnhsd->bnlhd",
                         cc * jnp.exp(cum)[..., None], h_in)
    y = (y_intra + y_inter).reshape(B, Tp, nh, dh)[:, :T]
    if return_state:
        return y.astype(x.dtype), h_last
    return y.astype(x.dtype)


def init_ssm(cfg: ModelConfig, key):
    d, di = cfg.d_model, cfg.d_inner
    nh, ds, G = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    conv_ch = di + 2 * G * ds
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * di + 2 * G * ds + nh), _dtype(cfg)) * s,
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, conv_ch),
                                    _dtype(cfg)) / math.sqrt(cfg.conv_width),
        "conv_b": jnp.zeros((conv_ch,), _dtype(cfg)),
        "a_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "out_norm": jnp.ones((di,), _dtype(cfg)),
        "out_proj": jax.random.normal(ks[2], (di, d), _dtype(cfg))
        / math.sqrt(di * cfg.num_layers),
    }


def _causal_conv(u, w, b):
    """u: (B, T, C) depthwise causal conv, width W; returns same shape."""
    W = w.shape[0]
    pads = [jnp.pad(u, ((0, 0), (W - 1 - i, i), (0, 0)))[:, :u.shape[1]]
            for i in range(W)]
    # pads[i] = u shifted so position t sees u[t - (W-1-i)]
    out = sum(pads[i] * w[i][None, None, :] for i in range(W))
    return out + b[None, None, :]


def ssm_mixer(cfg: ModelConfig, p, x, *, state=None):
    """Mamba-2 block.  state: dict(conv: (B, W-1, C), ssm: (B,nh,ds,dh))
    for single-step decode; None for full-sequence (train/prefill)."""
    B, T, _ = x.shape
    di, nh, ds, G = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    dh = cfg.ssm_head_dim
    proj = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xs, bc, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + 2 * G * ds], axis=-1)
    conv_in = jnp.concatenate([xs, bc], axis=-1)           # (B,T,C)
    new_state = None
    if state is None or state == "prefill":
        conv = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    else:
        # roll the conv buffer one step (T == 1)
        hist = jnp.concatenate([state["conv"], conv_in], axis=1)  # (B,W,C)
        conv = jax.nn.silu(
            jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"]
        )[:, None, :]
        new_conv = hist[:, 1:]
    xs, b, c = jnp.split(conv, [di, di + G * ds], axis=-1)
    xh = xs.reshape(B, T, nh, dh)
    bh = b.reshape(B, T, G, ds)
    ch = c.reshape(B, T, G, ds)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,T,nh)
    a = -jnp.exp(p["a_log"])[None, None, :] * dt                  # log decay
    xdt = xh * dt[..., None].astype(xh.dtype)
    if state == "prefill":
        y, h_last = ssd_jax(xdt, bh, ch, a, cfg.ssd_chunk, return_state=True)
        new_state = {"conv": conv_in[:, -(cfg.conv_width - 1):], "ssm": h_last}
    elif state is None:
        y = ssd_jax(xdt, bh, ch, a, cfg.ssd_chunk)
    else:
        # single-step recurrence: h = exp(a) h + B x ; y = C h
        h = state["ssm"]                                   # (B,nh,ds,dh)
        rep = nh // G
        b1 = jnp.repeat(bh[:, 0], rep, axis=1).astype(jnp.float32)  # (B,nh,ds)
        c1 = jnp.repeat(ch[:, 0], rep, axis=1).astype(jnp.float32)
        x1 = xdt[:, 0].astype(jnp.float32)                 # (B,nh,dh)
        h = jnp.exp(a[:, 0])[..., None, None] * h \
            + b1[..., :, None] * x1[..., None, :]
        y = jnp.einsum("bhs,bhsd->bhd", c1, h)[:, None].astype(x.dtype)
        new_state = {"conv": new_conv, "ssm": h}
    y = y.reshape(B, T, nh, dh)
    y = y + p["d_skip"][None, None, :, None].astype(y.dtype) * \
        xdt.reshape(B, T, nh, dh)
    y = y.reshape(B, T, di)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, new_state
