"""Decoder stack assembly: pattern-periodic blocks scanned over depth.

Layers are grouped into super-blocks of ``cfg.pattern_period`` sub-layers
(dense archs: 1; jamba: lcm(attention interleave, MoE interleave)); the
stack is a ``lax.scan`` over ``num_layers / period`` super-blocks, so the
HLO is O(period) regardless of depth — essential for the 62-layer MiniCPM3
and the 512-device dry-run compile times.

API (all pure functions over a params pytree):
  init_params(cfg, key)                 -> params
  forward(cfg, params, inputs)          -> logits            (training)
  init_cache(cfg, batch, max_len)       -> cache
  decode_step(cfg, params, cache, toks, cache_len)
                                        -> (logits, cache)   (serving)
      S > 1 with an all-zero cache_len acts as prefill.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ModelConfig


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_sublayer(cfg: ModelConfig, layer_idx: int, key):
    """Params for one sub-layer (mixer + optional FFN)."""
    kind = cfg.layer_kind(layer_idx)
    k1, k2 = jax.random.split(key)
    p = {"ln1": jnp.ones((cfg.d_model,), _dt(cfg))}
    if kind == "attn":
        init = L.init_mla if cfg.attn_type == "mla" else L.init_attention
        p["mixer"] = init(cfg, k1)
    else:
        p["mixer"] = L.init_ssm(cfg, k1)
    if cfg.d_ff > 0:
        p["ln2"] = jnp.ones((cfg.d_model,), _dt(cfg))
        if cfg.layer_is_moe(layer_idx):
            p["ffn"] = L.init_moe(cfg, k2)
        else:
            p["ffn"] = L.init_mlp(cfg, k2)
    return p


def init_params(cfg: ModelConfig, key):
    period = cfg.pattern_period
    n_super = cfg.num_layers // period
    assert n_super * period == cfg.num_layers, \
        f"{cfg.name}: num_layers {cfg.num_layers} % period {period} != 0"
    keys = jax.random.split(key, period + 2)
    blocks = {}
    for pos in range(period):
        sub_keys = jax.random.split(keys[pos], n_super)
        blocks[f"pos{pos}"] = jax.vmap(
            functools.partial(_init_sublayer, cfg, pos))(sub_keys)
    params = {
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), _dt(cfg)),
    }
    if cfg.input_mode == "tokens":
        params["embed"] = jax.random.normal(
            keys[-1], (cfg.vocab_size, cfg.d_model), _dt(cfg)) * 0.02
        if not cfg.tie_embeddings:
            params["unembed"] = jax.random.normal(
                keys[-2], (cfg.d_model, cfg.vocab_size), _dt(cfg)) \
                / math.sqrt(cfg.d_model)
    else:
        params["unembed"] = jax.random.normal(
            keys[-2], (cfg.d_model, cfg.vocab_size), _dt(cfg)) \
            / math.sqrt(cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# sub-layer application
# ---------------------------------------------------------------------------

def _constrain_act(cfg, x):
    """Residual-stream sharding constraint between sub-layers.

    'batch': pin (B, S, d) to batch-sharded/replicated-d — stops XLA's
    propagation from settling on a batch-replicated, d-sharded layout
    inside the layer scan (observed fixpoint on 40-head archs; §Perf).
    'seq': Megatron-style sequence parallelism — shard S over 'model'
    between blocks (all-reduce becomes reduce-scatter + all-gather).
    No-op outside a mesh context (CPU unit tests)."""
    if cfg.act_shard == "none" or x.ndim != 3 or x.shape[1] <= 1:
        return x
    from jax._src import mesh as mesh_lib
    from jax.sharding import PartitionSpec as _P
    m = mesh_lib.thread_resources.env.physical_mesh
    if m.empty:
        return x
    if cfg.act_shard == "seq":
        if "model" in m.shape and x.shape[1] % m.shape["model"] == 0:
            return jax.lax.with_sharding_constraint(
                x, _P(None, "model", None))
    elif cfg.act_shard == "batch":
        axes = tuple(a for a in ("pod", "data") if a in m.shape)
        import math as _math
        if axes and x.shape[0] % _math.prod(m.shape[a] for a in axes) == 0:
            return jax.lax.with_sharding_constraint(
                x, _P(axes, None, None))
    return x


def _apply_sublayer(cfg, layer_idx, p, x, positions, cache, cache_len, mode):
    kind = cfg.layer_kind(layer_idx)
    x = _constrain_act(cfg, x)
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn":
        attn = L.mla_attention if cfg.attn_type == "mla" else L.attention
        y, new_cache = attn(cfg, p["mixer"], h, positions,
                            cache=cache, cache_len=cache_len)
    else:
        state = cache if mode == "decode" else (
            "prefill" if mode == "prefill" else None)
        y, new_cache = L.ssm_mixer(cfg, p["mixer"], h, state=state)
    x = x + y
    if cfg.d_ff > 0:
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.layer_is_moe(layer_idx):
            x = x + L.moe_ffn(cfg, p["ffn"], h)
        else:
            x = x + L.mlp(cfg, p["ffn"], h)
    return x, new_cache


def _stack(cfg, params, x, positions, caches, cache_len, mode, remat=False):
    """Scan over super-blocks; caches is a pytree stacked on n_super or None."""
    period = cfg.pattern_period

    def super_block(carry, scanned):
        xx = carry
        block_params, block_cache = scanned
        new_caches = {}
        for pos in range(period):
            c = None if block_cache is None else block_cache.get(f"pos{pos}")
            xx, nc = _apply_sublayer(cfg, pos, block_params[f"pos{pos}"], xx,
                                     positions, c, cache_len, mode)
            if nc is not None:
                new_caches[f"pos{pos}"] = nc
        return xx, (new_caches if new_caches else None)

    if caches is None:
        body = lambda c, bp: super_block(c, (bp, None))
        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        x, _ = jax.lax.scan(body, x, params["blocks"])
        return x, None
    x, new_caches = jax.lax.scan(super_block, x, (params["blocks"], caches))
    return x, new_caches


def _embed_in(cfg, params, inputs):
    if cfg.input_mode == "tokens":
        return params["embed"][inputs].astype(_dt(cfg))
    return inputs.astype(_dt(cfg))


def _logits_out(cfg, params, x):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.input_mode == "tokens" and cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"])


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, inputs, *, remat: bool = False):
    """Training forward: inputs (B, S) tokens or (B, S, d) embeddings."""
    x = _embed_in(cfg, params, inputs)
    positions = jnp.arange(x.shape[1])[None, :]
    x, _ = _stack(cfg, params, x, positions, None, None, mode="train",
                  remat=remat)
    return _logits_out(cfg, params, x)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Decode cache pytree, stacked (n_super, ...) per pattern position."""
    dt = dtype or _dt(cfg)
    period = cfg.pattern_period
    n_super = cfg.num_layers // period
    hd, KVH = cfg.head_dim_, cfg.num_kv_heads
    out = {}
    for pos in range(period):
        kind = cfg.layer_kind(pos)
        if kind == "attn":
            if cfg.attn_type == "mla":
                c = {"latent": jnp.zeros(
                        (n_super, batch, max_len, cfg.kv_lora_rank), dt),
                     "k_rope": jnp.zeros(
                        (n_super, batch, max_len, cfg.qk_rope_dim), dt)}
            else:
                c = {"k": jnp.zeros((n_super, batch, max_len, KVH, hd), dt),
                     "v": jnp.zeros((n_super, batch, max_len, KVH, hd), dt)}
        else:
            conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            c = {"conv": jnp.zeros(
                    (n_super, batch, cfg.conv_width - 1, conv_ch), dt),
                 "ssm": jnp.zeros(
                    (n_super, batch, cfg.ssm_heads, cfg.ssm_state,
                     cfg.ssm_head_dim), jnp.float32)}
        out[f"pos{pos}"] = c
    return out


def decode_step(cfg: ModelConfig, params, cache, tokens, cache_len):
    """One serving step.

    tokens: (B, S) or (B, S, d); S == 1 => decode, S > 1 (cache_len == 0)
    => prefill.  Returns (logits (B, S, vocab), new cache).
    """
    S = tokens.shape[1]
    mode = "decode" if S == 1 else "prefill"
    x = _embed_in(cfg, params, tokens)
    positions = cache_len[:, None] + jnp.arange(S)[None, :]
    x, new_cache = _stack(cfg, params, x, positions, cache, cache_len, mode)
    return _logits_out(cfg, params, x), new_cache


# ---------------------------------------------------------------------------
# parameter accounting (roofline MODEL_FLOPS uses these)
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> int:
    n = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda k: init_params(cfg, k),
                       jax.ShapeDtypeStruct((2,), jnp.uint32))))
    return int(n)


def active_param_count(cfg: ModelConfig) -> int:
    """Per-token active params (MoE: only top-k experts count)."""
    total = param_count(cfg)
    if cfg.num_experts == 0:
        return total
    # subtract inactive expert weights
    d, f, E, K = cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.experts_per_token
    per_layer_expert = 3 * d * f
    n_moe = sum(1 for i in range(cfg.num_layers) if cfg.layer_is_moe(i))
    return int(total - n_moe * (E - K) * per_layer_expert)
