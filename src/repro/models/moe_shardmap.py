"""True expert-parallel MoE dispatch via shard_map + all_to_all.

XLA's SPMD partitioner cannot localize the data-dependent dispatch scatter
(measured in EXPERIMENTS.md §Perf Cell 2: EP sharding constraints made the
collective term 2–3× *worse*).  This module expresses the canonical EP flow
manually:

  tokens sharded over ('data','model') → local top-k routing → per-
  destination capacity buffers → ``all_to_all`` over 'model' → local expert
  FFN on the E/tp resident experts → ``all_to_all`` back → local combine.

Opt-in (not wired into the default decoder): call sites run it under an
explicit mesh; gradients flow through all_to_all natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from .config import ModelConfig


def _dispatch_local(cfg: ModelConfig, router, xf, tp: int, cap: int):
    """Route n local tokens into (tp, E/tp, cap, d) send buffers.

    Returns (buffers, combine weights, slot bookkeeping) — all local.
    """
    n, d = xf.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    e_loc = E // tp
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), router)
    gates, idx = jax.lax.top_k(logits, K)                 # (n, K)
    weights = jax.nn.softmax(gates, axis=-1)
    flat_e = idx.reshape(-1)                              # (n*K,)
    tok = jnp.repeat(jnp.arange(n), K)
    w = weights.reshape(-1)
    order = jnp.argsort(flat_e)
    se, stok, sw = flat_e[order], tok[order], w[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(n * K) - seg_start[se]
    keep = pos < cap
    # slot within the (tp, e_loc, cap) send layout
    dest, e_in = se // e_loc, se % e_loc
    slot = jnp.where(keep, (dest * e_loc + e_in) * cap + pos, E * cap)
    buf = jnp.zeros((E * cap + 1, d), xf.dtype).at[slot].add(
        jnp.where(keep[:, None], xf[stok], 0))
    return buf[:-1].reshape(tp, e_loc, cap, d), (slot, stok, sw, keep)


def _combine_local(n: int, d: int, out_buf, book):
    slot, stok, sw, keep = book
    flat = jnp.concatenate(
        [out_buf.reshape(-1, d), jnp.zeros((1, d), out_buf.dtype)])
    gathered = flat[slot]
    return jnp.zeros((n, d), out_buf.dtype).at[stok].add(
        gathered * (sw * keep)[:, None].astype(out_buf.dtype))


def moe_ffn_ep(cfg: ModelConfig, params, x, mesh: Mesh,
               capacity_factor: float | None = None):
    """x: (B, S, d) global view; params: global (replicated router,
    E-sharded experts).  Returns (B, S, d).

    Requires mesh axes 'data' and 'model', B % data == 0,
    S % model == 0 and E % model == 0.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    dp, tp = mesh.shape["data"], mesh.shape["model"]
    assert E % tp == 0 and B % dp == 0 and S % tp == 0, (E, B, S, dp, tp)
    n_loc = (B // dp) * (S // tp)
    cf = capacity_factor or cfg.capacity_factor
    cap = max(1, int(cf * n_loc * K / E), min(n_loc, 64))

    def body(router, w_gate, w_up, w_down, xs):
        # xs: (B/dp, S/tp, d) local tokens; experts local: (E/tp, d, f)
        xf = xs.reshape(-1, d)
        send, book = _dispatch_local(cfg, router, xf, tp, cap)
        # exchange: concat over the tp dim -> (tp, e_loc, cap, d) received
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv: (tp, e_loc, cap, d) = per-source buffers for MY experts
        h = recv.reshape(tp, -1, cap, d)
        act = jax.nn.silu if cfg.mlp_type != "gelu" else jax.nn.gelu
        hidden = act(jnp.einsum("secd,edf->secf", h, w_gate)) \
            * jnp.einsum("secd,edf->secf", h, w_up)
        out = jnp.einsum("secf,efd->secd", hidden, w_down)
        back = jax.lax.all_to_all(out, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        y = _combine_local(n_loc, d, back, book)
        return y.reshape(xs.shape)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), P("data", "model", None)),
        out_specs=P("data", "model", None),
        check_vma=False)
    return fn(params["router"], params["w_gate"], params["w_up"],
              params["w_down"], x)
