"""LM model zoo: the assigned architectures as one composable decoder stack."""
from .config import ModelConfig
from .model import (forward, init_params, init_cache, decode_step,
                    param_count, active_param_count)

__all__ = ["ModelConfig", "forward", "init_params", "init_cache",
           "decode_step", "param_count", "active_param_count"]
