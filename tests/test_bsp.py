"""BSP engine + apps vs numpy oracles; multi-device shard_map parity."""
import subprocess
import sys

import numpy as np
import pytest

from repro.bsp import (PartitionRuntime, bfs, pagerank, ref,
                       simulate_runtime, sssp, triangle_count)
from repro.core import scaled_paper_cluster, windgp, evaluate
from repro.core.baselines import PARTITIONERS
from repro.data import rmat, road_mesh


@pytest.fixture(scope="module")
def part():
    g = rmat(9, seed=2)
    cl = scaled_paper_cluster(2, 4, g.num_edges)
    r = windgp(g, cl, t0=2)
    rt = PartitionRuntime.build(g, r.assign, cl.p)
    return g, cl, rt


class TestApps:
    def test_pagerank_matches_reference(self, part):
        g, _, rt = part
        pr, _ = pagerank(rt, num_iters=15)
        expect = ref.pagerank(g, num_iters=15)
        np.testing.assert_allclose(pr, expect, rtol=2e-4)
        # mass is conserved up to the teleport leak of dangling vertices
        assert abs(pr.sum() - expect.sum()) < 1e-4

    def test_sssp_matches_reference(self, part):
        g, _, rt = part
        d, _ = sssp(rt, source=0, num_iters=25)
        expect = ref.sssp(g, source=0, num_iters=25)
        np.testing.assert_array_equal(np.isinf(d), np.isinf(expect))
        m = ~np.isinf(d)
        np.testing.assert_allclose(d[m], expect[m], rtol=1e-6)

    def test_bfs_matches_reference(self, part):
        g, _, rt = part
        d, actives = bfs(rt, source=1, num_iters=25)
        expect = ref.bfs(g, source=1, num_iters=25)
        m = ~np.isinf(expect)
        np.testing.assert_allclose(d[m], expect[m])
        # sparse algorithm: activity decays to zero once converged
        assert actives.sum(axis=1)[-1] == 0

    def test_triangles_exact(self, part):
        g, _, rt = part
        assert triangle_count(rt, g) == ref.triangle_count(g)

    def test_triangles_mesh(self):
        g = road_mesh(10, rewire=0.05, seed=3)
        cl = scaled_paper_cluster(1, 3, g.num_edges)
        r = windgp(g, cl, t0=2)
        rt = PartitionRuntime.build(g, r.assign, cl.p)
        assert triangle_count(rt, g) == ref.triangle_count(g)

    def test_partition_invariance(self, part):
        """Results must not depend on the partitioning (only speed does)."""
        g, cl, rt = part
        a_hash = PARTITIONERS["hash"](g, cl)
        rt2 = PartitionRuntime.build(g, a_hash, cl.p)
        pr1, _ = pagerank(rt, num_iters=10)
        pr2, _ = pagerank(rt2, num_iters=10)
        np.testing.assert_allclose(pr1, pr2, rtol=2e-4, atol=1e-9)


class TestSimulator:
    def test_dense_equals_tc_times_steps(self, part):
        """Paper Sec 2.1: for dense algorithms runtime ∝ TC exactly."""
        g, cl, rt = part
        a = np.zeros(g.num_edges, dtype=np.int32)
        for name in ["hash", "ne"]:
            a = PARTITIONERS[name](g, cl)
            s = evaluate(g, a, cl)
            rt2 = PartitionRuntime.build(g, a, cl.p)
            t = simulate_runtime(rt2, cl, num_steps=7)
            assert abs(t - 7 * s.tc) / (7 * s.tc) < 1e-9

    def test_sparse_faster_than_dense(self, part):
        """SSSP touches fewer vertices per superstep than PageRank."""
        g, cl, rt = part
        _, act = sssp(rt, source=0, num_iters=10)
        t_sparse = simulate_runtime(rt, cl, actives=act, comm_scale="active")
        t_dense = simulate_runtime(rt, cl, num_steps=10)
        assert t_sparse < t_dense

    def test_better_partition_lower_runtime(self, part):
        g, cl, _ = part
        a_hash = PARTITIONERS["hash"](g, cl)
        r = windgp(g, cl, t0=4)
        t_hash = simulate_runtime(
            PartitionRuntime.build(g, a_hash, cl.p), cl, num_steps=5)
        t_wind = simulate_runtime(
            PartitionRuntime.build(g, r.assign, cl.p), cl, num_steps=5)
        assert t_wind < t_hash


MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from jax.sharding import Mesh
from repro.bsp import PartitionRuntime, pagerank, sssp, ref
from repro.core import scaled_paper_cluster, windgp
from repro.data import rmat

g = rmat(9, seed=2)
cl = scaled_paper_cluster(2, 6, g.num_edges)   # p = 8 machines = 8 devices
r = windgp(g, cl, t0=2)
rt = PartitionRuntime.build(g, r.assign, cl.p)
mesh = jax.make_mesh((8,), ("machines",))
pr, _ = pagerank(rt, num_iters=10, mesh=mesh)
np.testing.assert_allclose(pr, ref.pagerank(g, num_iters=10), rtol=2e-4)
d, _ = sssp(rt, source=0, num_iters=20, mesh=mesh)
e = ref.sssp(g, source=0, num_iters=20)
m = ~np.isinf(e)
np.testing.assert_allclose(d[m], e[m], rtol=1e-6)
print("MULTIDEV_OK")
"""


def test_sharded_engine_8_devices():
    """The same superstep body over a real 8-device mesh via shard_map."""
    out = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "MULTIDEV_OK" in out.stdout, out.stderr[-2000:]
