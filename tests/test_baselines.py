"""Baseline partitioners: validity + qualitative ordering vs WindGP."""
import numpy as np
import pytest

from repro.core import evaluate, scaled_paper_cluster, windgp
from repro.core.baselines import PARTITIONERS
from repro.data import rmat


@pytest.fixture(scope="module")
def setup():
    g = rmat(11, seed=3)
    cl = scaled_paper_cluster(3, 6, g.num_edges, slack=2.0)
    return g, cl


@pytest.mark.parametrize("name", sorted(PARTITIONERS))
def test_valid_edge_partition(setup, name):
    g, cl = setup
    assign = PARTITIONERS[name](g, cl)
    assert assign.shape == (g.num_edges,)
    assert assign.min() >= 0 and assign.max() < cl.p


@pytest.mark.parametrize("name", ["hash", "dbh", "ebv", "hdrf", "greedy", "ne"])
def test_respects_memory_caps(setup, name):
    g, cl = setup
    assign = PARTITIONERS[name](g, cl)
    s = evaluate(g, assign, cl)
    # all streaming baselines get the paper's memory adaptation
    assert s.feasible


def test_windgp_beats_streaming_baselines(setup):
    """Paper Fig. 12: WindGP below every streaming baseline on power-law."""
    g, cl = setup
    r = windgp(g, cl, t0=30, theta=0.02, alpha=0.1, beta=0.1)
    for name in ["hash", "dbh", "hdrf", "greedy", "ebv"]:
        s = evaluate(g, PARTITIONERS[name](g, cl), cl)
        assert r.stats.tc < s.tc, f"windgp should beat {name}"


def test_hash_worst_ne_best_among_baselines(setup):
    """Qualitative: locality-aware NE ≪ random hash (paper Sec. 2.2)."""
    g, cl = setup
    tc = {n: evaluate(g, PARTITIONERS[n](g, cl), cl).tc
          for n in ["hash", "ne"]}
    assert tc["ne"] < 0.5 * tc["hash"]
