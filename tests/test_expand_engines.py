"""Expansion-core properties + heap↔batched engine equivalence.

Runs under real hypothesis or the fixed-seed shim in ``tests/_hyp.py``.
"""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import (Cluster, Machine, capacities, evaluate,
                        from_edge_list, scaled_paper_cluster, windgp)
from repro.core import expand as exp_mod
from repro.data import rmat

# α, β values whose quantized coefficients are exact at QUANT_SCALE, so
# the integer bucket ordering matches the float heap ordering bit for bit.
EXACT_AB = [0.0, 0.25, 0.5]


def random_graph(rng, v_max=40):
    V = int(rng.integers(6, v_max))
    E = int(rng.integers(V, V * 4))
    return from_edge_list(rng.integers(0, V, size=(E, 2)), num_vertices=V)


def paper_example():
    # Figure 2 / Section 2.1 running example: a-b-c, d-e-f, c-f
    return from_edge_list(np.array(
        [[0, 1], [1, 2], [3, 4], [4, 5], [2, 5]]), num_vertices=6)


def paper_cluster():
    return Cluster(machines=(
        Machine(7, 0, 1, 1), Machine(7, 0, 2, 2), Machine(5, 0, 1, 1)),
        m_node=1.0, m_edge=2.0)


class TestExpansionProperties:
    @given(st.integers(0, 2 ** 31), st.integers(1, 4),
           st.sampled_from(exp_mod.ENGINES))
    @settings(max_examples=20, deadline=None)
    def test_every_edge_assigned_exactly_once(self, seed, p, engine):
        rng = np.random.default_rng(seed)
        g = random_graph(rng)
        if g.num_edges == 0:
            return
        deltas = np.full(p, g.num_edges // p + 1)
        assign, orders = exp_mod.run_expansion(g, deltas, 0.3, 0.3,
                                               engine=engine)
        # no memory guard + Σδ ≥ |E|: everything places, exactly once
        assert (assign >= 0).all()
        flat = [e for o in orders for e in o]
        assert len(flat) == g.num_edges
        assert len(set(flat)) == g.num_edges
        for i, o in enumerate(orders):
            assert np.all(assign[np.asarray(o, dtype=int)] == i)

    @given(st.integers(0, 2 ** 31), st.sampled_from(exp_mod.ENGINES))
    @settings(max_examples=20, deadline=None)
    def test_delta_respected(self, seed, engine):
        rng = np.random.default_rng(seed)
        g = random_graph(rng)
        if g.num_edges < 4:
            return
        p = 3
        deltas = rng.integers(1, max(2, g.num_edges // 2), size=p)
        assign, _ = exp_mod.run_expansion(g, deltas, 0.25, 0.25,
                                          engine=engine)
        placed = assign >= 0
        sizes = np.bincount(assign[placed], minlength=p)
        assert np.all(sizes <= deltas)

    @given(st.integers(0, 2 ** 31))
    @settings(max_examples=20, deadline=None)
    def test_memory_guard_batched_never_exceeds(self, seed):
        """The batched engine truncates joins: footprint ≤ limit, always."""
        rng = np.random.default_rng(seed)
        g = random_graph(rng)
        if g.num_edges < 8:
            return
        p = 3
        m_node, m_edge = 1.0, 2.0
        memories = rng.integers(
            int(0.3 * m_edge * g.num_edges),
            int(1.2 * m_edge * g.num_edges), size=p).astype(float)
        deltas = np.full(p, g.num_edges)
        assign, _ = exp_mod.run_expansion(
            g, deltas, 0.25, 0.25, memories=memories,
            m_node=m_node, m_edge=m_edge, engine="batched")
        for i in range(p):
            mask = assign == i
            e_i = int(mask.sum())
            v_i = len(np.unique(g.edges[mask])) if e_i else 0
            assert m_node * v_i + m_edge * e_i <= memories[i] + 1e-6, \
                (i, v_i, e_i, memories[i])

    @given(st.integers(0, 2 ** 31), st.sampled_from(exp_mod.ENGINES))
    @settings(max_examples=15, deadline=None)
    def test_border_contains_every_replicated_vertex(self, seed, engine):
        """B must cover every vertex whose edges span ≥ 2 partitions."""
        rng = np.random.default_rng(seed)
        g = random_graph(rng)
        if g.num_edges < 4:
            return
        p = 3
        deltas = np.full(p, g.num_edges // p + 1)
        st_ = exp_mod.ExpansionState.fresh(g)
        exp_mod.run_expansion(g, deltas, 0.25, 0.25, engine=engine,
                              state=st_)
        assign = st_.epoch
        holders = np.zeros((p, g.num_vertices), dtype=bool)
        for i in range(p):
            vs = np.unique(g.edges[assign == i])
            holders[i, vs.astype(int)] = True
        replicated = holders.sum(axis=0) >= 2
        assert np.all(st_.in_border[replicated] == 1)


class TestEngineEquivalence:
    @given(st.integers(0, 2 ** 31), st.integers(1, 4),
           st.sampled_from(EXACT_AB), st.sampled_from(EXACT_AB))
    @settings(max_examples=25, deadline=None)
    def test_strict_batched_matches_heap_exactly(self, seed, p, a, b):
        """strict_ties + exact quantization ⇒ bit-identical to the oracle,
        including per-partition assignment order."""
        rng = np.random.default_rng(seed)
        g = random_graph(rng)
        if g.num_edges == 0:
            return
        deltas = np.full(p, g.num_edges // p + 1)
        a1, o1 = exp_mod.run_expansion(g, deltas, a, b, engine="heap")
        a2, o2 = exp_mod.run_expansion(g, deltas, a, b, engine="batched",
                                       strict_ties=True)
        np.testing.assert_array_equal(a1, a2)
        assert o1 == o2

    def test_strict_matches_on_rmat_and_uneven_deltas(self):
        g = rmat(9, seed=3)
        deltas = np.array([g.num_edges // 5, g.num_edges // 3,
                           g.num_edges], dtype=np.int64)
        a1, o1 = exp_mod.run_expansion(g, deltas, 0.25, 0.5, engine="heap")
        a2, o2 = exp_mod.run_expansion(g, deltas, 0.25, 0.5,
                                       engine="batched", strict_ties=True)
        np.testing.assert_array_equal(a1, a2)
        assert o1 == o2

    def test_fast_batched_tc_close_on_rmat10(self):
        """Default (fast) batched engine: TC within 2% of the heap oracle
        in expectation over seeds, never beyond 8% on any instance."""
        gaps = []
        for seed in range(6):
            g = rmat(10, seed=seed)
            cl = scaled_paper_cluster(2, 4, g.num_edges)
            th = windgp(g, cl, level="windgp+", engine="heap")
            tb = windgp(g, cl, level="windgp+", engine="batched")
            gap = (tb.stats.tc - th.stats.tc) / th.stats.tc
            gaps.append(gap)
            assert abs(gap) < 0.08, (seed, gap)
        assert float(np.mean(gaps)) < 0.02, gaps


class TestFigure2Golden:
    """Pin the paper's Figure 2 / Section 2.1 TC numbers."""

    def test_reference_partitions_evaluate_to_paper_numbers(self):
        g = paper_example()
        cl = paper_cluster()
        eid = {tuple(e): i for i, e in enumerate(map(tuple, g.edges))}
        good = np.zeros(5, dtype=np.int32)
        good[eid[(0, 1)]] = 0
        good[eid[(1, 2)]] = 0
        good[eid[(3, 4)]] = 1
        good[eid[(4, 5)]] = 1
        good[eid[(2, 5)]] = 2
        s = evaluate(g, good, cl)
        assert s.t_cal.tolist() == [2, 4, 1]
        assert s.t_com.tolist() == [2, 3, 5]
        assert s.tc == 7
        bad = np.zeros(5, dtype=np.int32)
        bad[eid[(0, 1)]] = 0
        bad[eid[(1, 2)]] = 1
        bad[eid[(2, 5)]] = 1
        bad[eid[(3, 4)]] = 2
        bad[eid[(4, 5)]] = 2
        assert evaluate(g, bad, cl).tc == 10

    def test_batched_engine_reaches_paper_optimum(self):
        """The batched driver lands exactly on Figure 2's best TC = 7."""
        r = windgp(paper_example(), paper_cluster(), engine="batched")
        assert r.stats.tc == 7.0

    def test_heap_engine_pinned(self):
        """Oracle regression pin on the same instance (currently TC = 10;
        any drift means the reference engine changed behavior)."""
        r = windgp(paper_example(), paper_cluster(), engine="heap")
        assert r.stats.tc == 10.0


def test_unknown_engine_rejected():
    g = paper_example()
    with pytest.raises(ValueError):
        exp_mod.run_expansion(g, np.array([5]), 0.3, 0.3, engine="nope")
