"""Partitioned GNN minibatch sampling + the unified construction API.

Five contracts, each tested here:

* ``MachineCSC`` packs, per machine, the full global adjacency of its
  owned vertices (degree-sorted rows, ``-1`` pad) — every vertex with an
  edge gets exactly one owner, isolated vertices get ``-1``;
* the jax fanout sampler equals its NumPy oracle **bitwise** on the same
  PRNG key in both replacement modes, samples only true neighbors, and
  never repeats a neighbor without replacement;
* a minibatch is a pure function of ``(partition, seeds, key)`` —
  bitwise identical across repeated runs and across equal-content
  runtimes built through *different* ``create`` routes (in-memory
  assignment vs on-disk stream), with empty-frontier and
  isolated-vertex seeds handled as all-``-1`` lanes;
* ``PartitionRuntime.create`` routes by keywords, builds bit-identical
  runtimes to every legacy constructor, and rejects conflicting routes;
  ``RunOptions`` validates the shared app knobs once (tol on a monotone
  app, frontier_cap off-scatter, options=+kwargs mixing);
* the registry's knob errors name the offending partitioner and its
  valid knobs, and ``windgp``'s ``train_balance`` knob reduces
  train-vertex skew while the default stays bit-identical.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.bsp import (MONOTONE_APPS, PartitionRuntime, RunOptions,
                       StreamAssignment, pagerank, sssp)
from repro.core import from_edge_list, scaled_paper_cluster
from repro.core import partitioners as registry
from repro.core.partition_state import PartitionState, edge_incidence_counts
from repro.data import rmat
from repro.sampling import (MachineCSC, SamplingService, sample_fanout,
                            sample_fanout_np)


@pytest.fixture(scope="module")
def small():
    """Graph + cluster + hdrf assignment shared by the sampling tests."""
    g = rmat(8, edge_factor=8, seed=3)
    cl = scaled_paper_cluster(2, 4, g.num_edges)
    assign = registry.get("hdrf")(g, cl)
    return g, cl, assign


def _neighbors(g):
    nbrs = {v: [] for v in range(g.num_vertices)}
    for u, v in g.edges:
        nbrs[int(u)].append(int(v))
        nbrs[int(v)].append(int(u))
    return nbrs


class TestMachineCSC:
    def test_owner_and_rows_cover_global_adjacency(self, small):
        g, cl, assign = small
        csc = MachineCSC.build(PartitionRuntime.create(g, assign=assign,
                                                       cluster=cl))
        nbrs = _neighbors(g)
        deg = np.array([len(nbrs[v]) for v in range(g.num_vertices)])
        # one owner per non-isolated vertex, -1 for isolated
        assert np.array_equal(csc.owner >= 0, deg > 0)
        assert csc.owner.max() < cl.p
        rowmap = csc.flat_rowmap()
        flat_nbr = csc.nbr.reshape(-1, csc.max_degree)
        flat_deg = csc.deg.reshape(-1)
        for v in range(g.num_vertices):
            if deg[v] == 0:
                continue
            r = rowmap[v]
            assert flat_deg[r] == deg[v]
            row = flat_nbr[r]
            assert sorted(row[:deg[v]].tolist()) == sorted(nbrs[v])
            assert (row[deg[v]:] == -1).all()

    def test_rows_degree_sorted_per_machine(self, small):
        g, cl, assign = small
        csc = MachineCSC.build(PartitionRuntime.create(g, assign=assign,
                                                       cluster=cl))
        for i in range(csc.p):
            n = int(csc.owned_per[i])
            d = csc.deg[i, :n]
            assert (np.diff(d) <= 0).all(), "owned rows not degree-sorted"

    def test_isolated_vertex_owner_is_minus_one(self):
        g = from_edge_list(np.array([[0, 1], [1, 2]]), num_vertices=5)
        cl = scaled_paper_cluster(1, 1, g.num_edges)
        csc = MachineCSC.build(
            PartitionRuntime.create(g, assign=np.zeros(2, np.int32),
                                    cluster=cl))
        assert (csc.owner[3:] == -1).all()
        assert (csc.owner[:3] == 0).all()


class TestSamplerOracle:
    @pytest.mark.parametrize("replace", [False, True])
    def test_bitwise_equals_numpy_oracle(self, small, replace):
        g, cl, assign = small
        svc = SamplingService(
            PartitionRuntime.create(g, assign=assign, cluster=cl))
        rows = svc.csc.flat_rowmap()[np.arange(g.num_vertices)]
        key = jax.random.PRNGKey(7)
        got = np.asarray(sample_fanout(svc._table, svc._deg, rows, key, 6,
                                       replace=replace))
        want = sample_fanout_np(np.asarray(svc._table),
                                np.asarray(svc._deg), rows, key, 6,
                                replace=replace)
        assert np.array_equal(got, want)

    def test_samples_are_true_neighbors_no_dups(self, small):
        g, cl, assign = small
        svc = SamplingService(
            PartitionRuntime.create(g, assign=assign, cluster=cl))
        nbrs = _neighbors(g)
        key = jax.random.PRNGKey(1)
        seeds = svc.local_seeds(0, 32, key)
        mb = svc.sample(seeds, jax.random.fold_in(key, 1), home=0)
        hop0 = mb.hops[0].reshape(len(seeds), -1)
        for s, row in zip(seeds.tolist(), hop0):
            picked = row[row >= 0].tolist()
            assert set(picked) <= set(nbrs[s])
            assert len(picked) == len(set(picked)), \
                "without-replacement repeated a neighbor"
            assert len(picked) == min(len(nbrs[s]), svc.fanouts[0])


class TestDeterminism:
    def test_same_key_same_minibatch(self, small):
        g, cl, assign = small
        svc = SamplingService(
            PartitionRuntime.create(g, assign=assign, cluster=cl))
        key = jax.random.PRNGKey(11)
        seeds = svc.local_seeds(1, 16, key)
        a = svc.sample(seeds, jax.random.fold_in(key, 5), home=1)
        b = svc.sample(seeds, jax.random.fold_in(key, 5), home=1)
        for ha, hb in zip(a.hops, b.hops):
            assert np.array_equal(ha, hb)
        assert a.hop_stats == b.hop_stats

    def test_bitwise_across_create_routes(self, small, tmp_path):
        """Same partition through the in-memory route and the on-disk
        stream route yields the bitwise-same minibatch."""
        g, cl, assign = small
        sa = StreamAssignment(tmp_path / "assign", cl.p, g.num_vertices)
        sa.sink(g.edges, assign)
        sa.finalize(edge_incidence_counts(g, assign, cl.p) > 0,
                    {"method": "hdrf"})
        key = jax.random.PRNGKey(2)
        batches = []
        for source_kw in (dict(source=g, assign=assign, cluster=cl),
                          dict(source=g, assign=assign, p=cl.p),
                          dict(source=sa)):
            svc = SamplingService.create(fanouts=(5, 3), **source_kw)
            seeds = svc.local_seeds(0, 16, key)
            batches.append(svc.sample(seeds, jax.random.fold_in(key, 3),
                                      home=0))
        for mb in batches[1:]:
            assert np.array_equal(mb.seeds, batches[0].seeds)
            for ha, hb in zip(mb.hops, batches[0].hops):
                assert np.array_equal(ha, hb)
            assert mb.hop_stats == batches[0].hop_stats

    def test_empty_frontier(self, small):
        g, cl, assign = small
        svc = SamplingService(
            PartitionRuntime.create(g, assign=assign, cluster=cl))
        mb = svc.sample(np.empty(0, np.int32), jax.random.PRNGKey(0),
                        home=0)
        assert all(h.size == 0 for h in mb.hops)
        assert all(s.frontier == 0 and s.halo == 0 for s in mb.hop_stats)

    def test_isolated_seed_samples_all_pad(self):
        g = from_edge_list(np.array([[0, 1]]), num_vertices=4)
        cl = scaled_paper_cluster(1, 1, g.num_edges)
        svc = SamplingService(
            PartitionRuntime.create(g, assign=np.zeros(1, np.int32),
                                    cluster=cl), fanouts=(3, 2))
        mb = svc.sample(np.array([2, 3], np.int32), jax.random.PRNGKey(0),
                        home=0)
        assert all((h == -1).all() for h in mb.hops)
        assert mb.num_sampled() == 0

    def test_out_of_range_seed_raises(self, small):
        g, cl, assign = small
        svc = SamplingService(
            PartitionRuntime.create(g, assign=assign, cluster=cl))
        with pytest.raises(ValueError, match="seed ids"):
            svc.sample(np.array([g.num_vertices], np.int32),
                       jax.random.PRNGKey(0))

    def test_seed_below_minus_one_raises(self, small):
        """Only -1 is the pad lane; -2 etc. would silently alias rows
        through the clip — must be rejected, not sampled."""
        g, cl, assign = small
        svc = SamplingService(
            PartitionRuntime.create(g, assign=assign, cluster=cl))
        with pytest.raises(ValueError, match="pad lane"):
            svc.sample(np.array([0, -2], np.int32), jax.random.PRNGKey(0))

    def test_local_seeds_undersized_pool_returns_whole_pool(self, small):
        """When a machine owns fewer (masked) vertices than n, the whole
        pool comes back key-permuted — length min(n, pool), no padding."""
        g, cl, assign = small
        svc = SamplingService(
            PartitionRuntime.create(g, assign=assign, cluster=cl))
        pool = int(svc.csc.owned_per[0])
        seeds = svc.local_seeds(0, pool + 100, jax.random.PRNGKey(4))
        assert len(seeds) == pool
        want = svc.csc.owned_gid[0][:pool]
        assert np.array_equal(np.sort(seeds), np.sort(want))
        # masked variant: pool shrinks to the masked subset
        mask = np.zeros(g.num_vertices, bool)
        mask[want[:3]] = True
        masked = svc.local_seeds(0, 50, jax.random.PRNGKey(4), mask)
        assert len(masked) == 3 and set(masked) == set(want[:3].tolist())

    def test_bad_fanouts_raise(self, small):
        g, cl, assign = small
        rt = PartitionRuntime.create(g, assign=assign, cluster=cl)
        with pytest.raises(ValueError, match="fanouts"):
            SamplingService(rt, fanouts=(5, 0))


class TestCreateFacade:
    def test_assign_route_bit_identical_to_build(self, small):
        g, cl, assign = small
        a = PartitionRuntime.build(g, assign, cl.p)
        b = PartitionRuntime.create(g, assign=assign, p=cl.p)
        c = PartitionRuntime.create(g, assign=assign, cluster=cl)
        for f in dataclasses.fields(a):
            va = getattr(a, f.name)
            for other in (b, c):
                vo = getattr(other, f.name)
                if isinstance(va, np.ndarray):
                    assert np.array_equal(va, vo), f.name
                else:
                    assert va == vo, f.name

    def test_method_route_bit_identical_to_from_partitioner(self, small):
        g, cl, _ = small
        a = PartitionRuntime.from_partitioner(g, cl, "hdrf")
        b = PartitionRuntime.create(g, method="hdrf", cluster=cl)
        assert np.array_equal(a.local_edges, b.local_edges)
        assert np.array_equal(a.local_vertex_gid, b.local_vertex_gid)

    def test_stream_route_bit_identical_to_from_stream(self, small,
                                                       tmp_path):
        g, cl, assign = small
        sa = StreamAssignment(tmp_path / "a", cl.p, g.num_vertices)
        sa.sink(g.edges, assign)
        sa.finalize(edge_incidence_counts(g, assign, cl.p) > 0, {})
        a = PartitionRuntime.from_stream(sa)
        b = PartitionRuntime.create(sa)
        c = PartitionRuntime.create(str(tmp_path / "a"))
        for other in (b, c):
            assert np.array_equal(a.local_edges, other.local_edges)
            assert np.array_equal(a.local_vertex_gid,
                                  other.local_vertex_gid)

    def test_route_conflicts_raise(self, small, tmp_path):
        g, cl, assign = small
        sa = StreamAssignment(tmp_path / "a", cl.p, g.num_vertices)
        sa.sink(g.edges, assign)
        sa.finalize(edge_incidence_counts(g, assign, cl.p) > 0, {})
        with pytest.raises(ValueError, match="requires source="):
            PartitionRuntime.create()
        with pytest.raises(ValueError, match="takes only"):
            PartitionRuntime.create(sa, assign=assign)
        with pytest.raises(ValueError, match="drop assign"):
            PartitionRuntime.create(g, method="hdrf", cluster=cl,
                                    assign=assign)
        with pytest.raises(ValueError, match="requires cluster="):
            PartitionRuntime.create(g, method="hdrf")
        with pytest.raises(ValueError):
            PartitionRuntime.create(g, assign=assign)  # no p=/cluster=
        with pytest.raises(ValueError):
            PartitionRuntime.create(42)


class TestRunOptions:
    def test_options_equals_legacy_kwargs_bitwise(self, small):
        g, cl, assign = small
        rt = PartitionRuntime.create(g, assign=assign, cluster=cl)
        legacy, _ = pagerank(rt, num_iters=5, backend="segment")
        via_opts, _ = pagerank(rt, num_iters=5,
                               options=RunOptions(backend="segment"))
        assert np.array_equal(np.asarray(legacy), np.asarray(via_opts))

    def test_tol_rejected_on_monotone_apps(self, small):
        g, cl, assign = small
        rt = PartitionRuntime.create(g, assign=assign, cluster=cl)
        assert "sssp" in MONOTONE_APPS
        with pytest.raises(ValueError, match="monotone"):
            sssp(rt, source=0, options=RunOptions(tol=1e-3))

    def test_frontier_cap_is_scatter_only(self):
        with pytest.raises(ValueError, match="scatter"):
            RunOptions(backend="segment", frontier_cap=8).validate()

    def test_mixing_options_and_kwargs_raises(self, small):
        g, cl, assign = small
        rt = PartitionRuntime.create(g, assign=assign, cluster=cl)
        with pytest.raises(ValueError, match="both options="):
            pagerank(rt, num_iters=2, backend="segment",
                     options=RunOptions())

    def test_unknown_backend_named(self):
        with pytest.raises(ValueError, match="unknown edge-kernel backend"):
            RunOptions(backend="nope").validate()


class TestRegistryKnobErrors:
    def test_error_names_partitioner_and_valid_knobs(self, small):
        g, cl, _ = small
        with pytest.raises(TypeError) as ei:
            registry.get("hdrf")(g, cl, bogus=1)
        msg = str(ei.value)
        assert "partitioner 'hdrf'" in msg
        assert "bogus" in msg
        assert "valid knobs for 'hdrf'" in msg

    def test_error_lists_training_knobs_for_windgp(self, small):
        g, cl, _ = small
        with pytest.raises(TypeError, match="train_balance"):
            registry.get("windgp")(g, cl, bogus=1)


class TestTrainBalance:
    def test_default_bitwise_identical_without_mask(self, small):
        g, cl, _ = small
        wind = registry.get("windgp")
        a = wind(g, cl, t0=6, alpha=0.1, beta=0.1)
        train = np.zeros(g.num_vertices, bool)
        b = wind(g, cl, t0=6, alpha=0.1, beta=0.1, train_balance=0.0)
        assert np.array_equal(a, b)

    def test_balance_knob_reduces_train_skew(self):
        g = rmat(10, edge_factor=7, seed=42)
        cl = scaled_paper_cluster(2, 4, g.num_edges)
        train = np.random.default_rng(0).random(g.num_vertices) < 0.1
        wind = registry.get("windgp")

        def skew(assign):
            member = edge_incidence_counts(g, assign, cl.p) > 0
            c = member[:, train].sum(axis=1).astype(np.float64)
            return float(c.max() / c.mean())

        s_def = skew(wind(g, cl, t0=6, alpha=0.1, beta=0.1))
        s_bal = skew(wind(g, cl, t0=6, alpha=0.1, beta=0.1,
                          train_mask=train, train_balance=1.0))
        assert s_bal < s_def

    def test_weighted_state_cost_and_counts(self, small):
        g, cl, assign = small
        train = np.zeros(g.num_vertices, bool)
        train[:16] = True
        st = PartitionState.build(g, assign, cl, train_mask=train,
                                  train_balance=0.5)
        member = edge_incidence_counts(g, assign, cl.p) > 0
        w = 1.0 + 0.5 * train
        want = cl.c_node() * (member.astype(np.float64) @ w) \
            + cl.c_edge() * st.edges_per
        assert np.allclose(st.t_cal, want)
        assert np.array_equal(st.train_counts(train),
                              member[:, train].sum(axis=1))

    def test_bad_train_mask_shape_raises(self, small):
        g, cl, assign = small
        with pytest.raises(ValueError, match="train_mask"):
            PartitionState.build(g, assign, cl,
                                 train_mask=np.zeros(3, bool),
                                 train_balance=1.0)
