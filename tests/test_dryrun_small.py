"""Dry-run machinery at CI scale: an 8-device (2,2,2) mesh in a subprocess
(device count locks at first jax init, so tests must isolate it), reduced
configs, every family represented.  The production 512-device sweep runs
via ``python -m repro.launch.dryrun --all`` (results in EXPERIMENTS.md)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import peak_memory_bytes
from repro.configs import get_reduced, SHAPES
from repro.launch import dryrun
from repro.models import init_params
from repro.sharding import param_specs

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

# shrink the assigned shapes to CI scale
dryrun.SHAPES = {
    "train_4k": dict(seq_len=64, global_batch=8, kind="train"),
    "prefill_32k": dict(seq_len=128, global_batch=4, kind="prefill"),
    "decode_32k": dict(seq_len=128, global_batch=8, kind="decode"),
}
import repro.launch.dryrun as dr
results = {}
for arch in %(archs)s:
    cfg = get_reduced(arch)
    for shape in dr.SHAPES:
        fn, args = dr.build_step(cfg, shape, mesh)
        with mesh:
            compiled = fn.lower(*args).compile()
            mem = compiled.memory_analysis()
        results[f"{arch}/{shape}"] = peak_memory_bytes(mem)
print("RESULTS:" + json.dumps(results))
"""


@pytest.mark.parametrize("archs", [
    ["glm4-9b", "mamba2-780m"],
    ["minicpm3-4b", "phi3.5-moe-42b-a6.6b"],
    ["jamba-v0.1-52b", "paligemma-3b"],
])
def test_small_mesh_dryrun_compiles(archs):
    script = SCRIPT % {"archs": repr(archs)}
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=1200,
        env={**os.environ, "PYTHONPATH": "src"})
    assert "RESULTS:" in out.stdout, out.stderr[-3000:]
    results = json.loads(out.stdout.split("RESULTS:")[1])
    assert len(results) == len(archs) * 3
    for cell, peak in results.items():
        assert peak > 0, cell


def test_production_dryrun_results_exist_and_clean():
    """The full 512-device sweep must have run with zero failures."""
    path = "results/dryrun.jsonl"
    if not os.path.exists(path):
        pytest.skip("run `python -m repro.launch.dryrun --all` first")
    seen, errors = set(), []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if "error" in r:
                errors.append((r["arch"], r["shape"], r["mesh"]))
            else:
                seen.add((r["arch"], r["shape"], r["mesh"]))
    assert not [e for e in errors if e not in seen], errors
    from repro.configs import cells
    expect = {(a, s, m) for a, s in cells()
              for m in ("pod16x16", "pod2x16x16")}
    missing = expect - seen
    assert not missing or len(seen) < len(expect), missing
