"""Guard for ``repro/compat.py``: once the container's jax grows the
native top-level APIs, every shim must *delegate* to them — so the
jax ≥ 0.6 cleanup (ROADMAP jax-drift debt) is a pure deletion, with no
behavior change hiding in the shims."""
import importlib

import jax
import numpy as np

import repro.compat as compat


def test_shard_map_delegates_when_native():
    """With ``jax.shard_map`` present, the module must re-export it as-is
    (the shim binds at import time, hence the reload dance)."""
    sentinel = object()
    had = hasattr(jax, "shard_map")
    orig = getattr(jax, "shard_map", None)
    jax.shard_map = sentinel
    try:
        mod = importlib.reload(compat)
        assert mod.shard_map is sentinel
    finally:
        if had:
            jax.shard_map = orig
        else:
            del jax.shard_map
        importlib.reload(compat)


def test_shard_map_shim_active_only_without_native():
    """Whatever this jaxlib provides, the exported symbol must be the
    native one when it exists, the old-namespace wrapper otherwise."""
    if hasattr(jax, "shard_map"):
        assert compat.shard_map is jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as old
        assert compat.shard_map is not old          # the kwarg-translating
        assert compat.shard_map.__doc__ and "check_vma" in compat.shard_map.__doc__


def test_make_mesh_delegates_when_native(monkeypatch):
    calls = []

    def fake(axis_shapes, axis_names, *, devices=None):
        calls.append((axis_shapes, axis_names, devices))
        return "native-mesh"

    monkeypatch.setattr(jax, "make_mesh", fake, raising=False)
    assert compat.make_mesh([2, 1], ["x", "y"]) == "native-mesh"
    assert calls[-1] == ((2, 1), ("x", "y"), None)
    assert compat.make_mesh((1,), ("x",), devices=["d0"]) == "native-mesh"
    assert calls[-1] == ((1,), ("x",), ["d0"])


def test_make_mesh_fallback_without_native(monkeypatch):
    monkeypatch.delattr(jax, "make_mesh", raising=False)
    mesh = compat.make_mesh((1,), ("x",))
    assert tuple(mesh.axis_names) == ("x",)
    assert mesh.devices.shape == (1,)


def test_peak_memory_bytes_prefers_native_field():
    class Native:
        peak_memory_in_bytes = 12345
        temp_size_in_bytes = 999       # must be ignored when peak exists

    assert compat.peak_memory_bytes(Native()) == 12345

    class Old:
        argument_size_in_bytes = 10
        output_size_in_bytes = 20
        temp_size_in_bytes = 30
        generated_code_size_in_bytes = 5
        alias_size_in_bytes = 15

    assert compat.peak_memory_bytes(Old()) == 10 + 20 + 30 + 5 - 15


def test_abstract_mesh_builds_on_this_jax():
    m = compat.abstract_mesh((2,), ("x",))
    assert tuple(m.axis_names) == ("x",)
