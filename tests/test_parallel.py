"""W-worker partitioning pipeline: sharded dedup + epoch-parallel scoring.

Contracts under test (the ``core/parallel.py`` layer):

* ``byte_ranges`` + ``iter_edge_blocks_range`` split any plain-text edge
  list into disjoint, exhaustive, line-aligned pieces — concatenating
  the per-range streams reproduces the whole-file stream exactly
  (property test over random files, trailing-newline/comment variants);
* ``ShardedTwoPassDedup`` yields the block-identical deduplicated
  stream to the sequential ``TwoPassDedup``, at any worker count, with
  the spill accounting aggregated (gzip falls back to a whole-file
  pass-1 and must still agree);
* ``stream_partition(..., workers=1)`` is bit-identical to the existing
  single-process path — membership, totals, TC/RF, and the
  ``StreamAssignment`` shard bytes (the acceptance criterion);
* worker-count invariance: at ``sync_blocks=1`` every W is bit-identical
  to sequential (all three streamable methods); at the default sync
  period the result depends only on ``sync_blocks`` — W=2 and W=4 are
  bit-identical to each other — and TC/RF stay within the 2% gate of
  sequential on the LJ proxy;
* ``StreamAssignment.compact`` folds tombstone debt below the automatic
  ``_COMPACT_FRAC`` threshold, preserves live content and caller meta,
  and no-ops above ``max_tomb_frac``.
"""
import gzip
import pathlib
import tempfile

import numpy as np
import pytest

from _hyp import given, settings, strategies as st

from repro.bsp.stream_assignment import StreamAssignment
from repro.core import (AssignmentDelta, evaluate_membership,
                        scaled_paper_cluster)
from repro.core.baselines import streaming as S
from repro.core.parallel import ShardedTwoPassDedup
from repro.data import TwoPassDedup, iter_edge_blocks, rmat
from repro.data.io import byte_ranges, iter_edge_blocks_range


def _cat(blocks):
    blocks = list(blocks)
    return (np.concatenate(blocks) if blocks
            else np.empty((0, 2), dtype=np.int64))


def _random_text(seed: int, n_lines: int, trailing_nl: bool) -> str:
    """Edge-list text with comment/blank lines and long/short numbers so
    line lengths vary and range cuts land mid-line, mid-number, and on
    newlines."""
    rng = np.random.default_rng(seed)
    lines = []
    for _ in range(n_lines):
        r = rng.random()
        if r < 0.08:
            lines.append("# c" + "x" * int(rng.integers(0, 9)))
        elif r < 0.12:
            lines.append("")
        else:
            hi = 10 if rng.random() < 0.5 else 10_000_000
            u, v = rng.integers(0, hi, size=2)
            lines.append(f"{u} {v}")
    txt = "\n".join(lines)
    if trailing_nl and txt:
        txt += "\n"
    return txt


class TestByteRanges:
    @given(st.integers(0, 2 ** 31), st.integers(0, 60), st.booleans(),
           st.integers(1, 7))
    @settings(max_examples=30, deadline=None)
    def test_disjoint_exhaustive_line_cover(self, seed, n_lines,
                                            trailing_nl, n_ranges):
        """The property the sharded ingest rests on: the ranges tile the
        file's bytes, and the per-range readers together consume every
        line exactly once, in file order."""
        with tempfile.TemporaryDirectory() as d:
            path = pathlib.Path(d) / "edges.txt"
            path.write_text(_random_text(seed, n_lines, trailing_nl))
            size = path.stat().st_size
            ranges = byte_ranges(str(path), n_ranges)
            # byte-level: contiguous, disjoint, exhaustive
            assert len(ranges) == n_ranges
            assert ranges[0][0] == 0 and ranges[-1][1] == size
            assert all(ranges[i][1] == ranges[i + 1][0]
                       for i in range(len(ranges) - 1))
            # line-level: concatenated range streams == whole-file stream
            # (canonicalize off: per-block dedup is boundary-sensitive,
            # the line-ownership property is not)
            whole = _cat(iter_edge_blocks(path, 16, canonicalize=False))
            pieces = _cat(b for s, e in ranges
                          for b in iter_edge_blocks_range(
                              str(path), s, e, 16, canonicalize=False))
            np.testing.assert_array_equal(pieces, whole)

    def test_gzip_cannot_be_ranged(self, tmp_path):
        path = tmp_path / "edges.txt.gz"
        with gzip.open(path, "wt") as f:
            f.write("0 1\n2 3\n")
        with pytest.raises(ValueError, match="gzip"):
            next(iter_edge_blocks_range(str(path), 0, 4))


def _dup_heavy_file(tmp_path, *, gz=False, seed=0, n_hot=40, repeats=25,
                    n_unique=500, id_range=160):
    """Duplicates spanning far-apart blocks (defeats per-block dedup)."""
    rng = np.random.default_rng(seed)
    hot = rng.integers(0, id_range // 4, size=(n_hot, 2))
    uniq = rng.integers(0, id_range, size=(n_unique, 2))
    chunks = []
    step = max(1, n_unique // repeats)
    for i in range(repeats):
        chunks.append(hot)
        chunks.append(uniq[i * step:(i + 1) * step])
    rows = np.concatenate(chunks)
    path = tmp_path / ("edges.txt.gz" if gz else "edges.txt")
    txt = "# adversarial\n" + "\n".join(f"{u} {v}" for u, v in rows) + "\n"
    if gz:
        with gzip.open(path, "wt") as f:
            f.write(txt)
    else:
        path.write_text(txt)
    return path


class TestShardedDedup:
    @pytest.mark.parametrize("workers", [2, 3])
    def test_block_identical_to_sequential(self, tmp_path, workers):
        """Same blocks, in the same order, with the same block boundaries
        — the scoring stage downstream sees a bit-identical stream."""
        path = _dup_heavy_file(tmp_path, seed=5)
        with TwoPassDedup(path, block_size=64, bucket_rows=128) as seq, \
                ShardedTwoPassDedup(path, workers=workers, block_size=64,
                                    bucket_rows=128) as par:
            seq_blocks = [b.copy() for b in seq]
            par_blocks = [b.copy() for b in par]
            assert len(seq_blocks) == len(par_blocks)
            for a, b in zip(seq_blocks, par_blocks):
                np.testing.assert_array_equal(a, b)
            assert par.num_edges == seq.num_edges
            assert par.num_vertices == seq.num_vertices
            # aggregated accounting: same dedup'd set, workers recorded
            # (spilled_rows may differ — per-block pre-dedup is chunk-
            # boundary-sensitive; the unique set never is)
            assert par.stats.workers == workers
            assert par.stats.unique_edges == seq.stats.unique_edges
            assert par.stats.spilled_rows >= par.stats.unique_edges

    def test_gzip_falls_back_to_whole_file_pass1(self, tmp_path):
        path = _dup_heavy_file(tmp_path, gz=True, seed=6)
        with TwoPassDedup(path, block_size=64, bucket_rows=128) as seq, \
                ShardedTwoPassDedup(path, workers=2, block_size=64,
                                    bucket_rows=128) as par:
            np.testing.assert_array_equal(_cat(seq), _cat(par))

    def test_workers1_is_the_sequential_path(self, tmp_path):
        path = _dup_heavy_file(tmp_path, seed=7)
        with ShardedTwoPassDedup(path, workers=1, block_size=64,
                                 bucket_rows=128) as tp, \
                TwoPassDedup(path, block_size=64, bucket_rows=128) as ref:
            assert tp.prepare() == ref.prepare()
            assert tp.stats.workers == 1


def _proxy_graph(tmp_path):
    """The quick-LJ proxy the tier-2 gate runs on, written to disk."""
    g = rmat(13, edge_factor=7, seed=42)
    path = tmp_path / "edges.txt"
    np.savetxt(path, g.edges, fmt="%d")
    cl = scaled_paper_cluster(3, 6, g.num_edges, slack=1.8)
    return path, cl


def _partition(path, cl, out_dir, method="hdrf", **kw):
    """One full dedup → scoring → StreamAssignment pipeline run."""
    workers = kw.get("workers", 1)
    tp = (TwoPassDedup(str(path)) if workers == 1
          else ShardedTwoPassDedup(str(path), workers=workers))
    try:
        tp.prepare()
        sa = StreamAssignment(out_dir, cl.p, tp.num_vertices)
        state = S.stream_partition(tp, cluster=cl, method=method,
                                   dedup="two_pass", sink=sa.sink, **kw)
    finally:
        tp.close()
    sa.finalize(state, {"method": method})
    return state, sa


def _shard_bytes(sa):
    return [(sa.dir / f"shard{i}.edges").read_bytes()
            for i in range(sa.p)]


def assert_states_identical(a, b):
    np.testing.assert_array_equal(a.cnt, b.cnt)
    np.testing.assert_array_equal(a.edges_per, b.edges_per)
    np.testing.assert_array_equal(a.verts_per, b.verts_per)


class TestWorkerInvariance:
    @pytest.fixture(scope="class")
    def proxy(self, tmp_path_factory):
        tmp = tmp_path_factory.mktemp("parallel_proxy")
        path, cl = _proxy_graph(tmp)
        seq, sa = _partition(path, cl, tmp / "seq")
        return tmp, path, cl, seq, sa

    def test_workers1_bit_identical_incl_shards(self, proxy):
        """The acceptance criterion: ``workers=1`` is the single-process
        path bit for bit — membership, totals, TC/RF, shard bytes."""
        tmp, path, cl, seq, sa_seq = proxy
        one, sa_one = _partition(path, cl, tmp / "w1", workers=1)
        assert_states_identical(seq, one)
        assert _shard_bytes(sa_seq) == _shard_bytes(sa_one)
        s = evaluate_membership(seq.cnt > 0, seq.edges_per, cl)
        q = evaluate_membership(one.cnt > 0, one.edges_per, cl)
        assert (s.tc, s.rf) == (q.tc, q.rf)

    @pytest.mark.parametrize("workers", [2, 4])
    def test_sync1_bit_identical_to_sequential(self, proxy, workers):
        """At ``sync_blocks=1`` every epoch is one block scored against a
        fresh snapshot — the parallel schedule degenerates to the
        sequential one at any W, shard bytes included."""
        tmp, path, cl, seq, sa_seq = proxy
        par, sa_par = _partition(path, cl, tmp / f"k1w{workers}",
                                 workers=workers, sync_blocks=1)
        assert_states_identical(seq, par)
        assert _shard_bytes(sa_seq) == _shard_bytes(sa_par)

    def test_default_sync_w_invariant_and_within_gate(self, proxy):
        """At the default sync period the result is a pure function of
        ``sync_blocks`` — W=2 and W=4 agree bit for bit — and TC/RF hold
        the tier-2 gate (≤2% signed degradation) vs sequential."""
        tmp, path, cl, seq, _ = proxy
        w2, sa2 = _partition(path, cl, tmp / "w2", workers=2)
        w4, sa4 = _partition(path, cl, tmp / "w4", workers=4)
        assert_states_identical(w2, w4)
        assert _shard_bytes(sa2) == _shard_bytes(sa4)
        s = evaluate_membership(seq.cnt > 0, seq.edges_per, cl)
        q = evaluate_membership(w2.cnt > 0, w2.edges_per, cl)
        assert max(0.0, (q.tc - s.tc) / s.tc) <= 0.02 + 1e-9
        assert max(0.0, (q.rf - s.rf) / s.rf) <= 0.02 + 1e-9

    @pytest.mark.parametrize("method", ["hdrf", "ebv", "greedy"])
    def test_sync1_all_methods_tiny(self, tmp_path, method):
        """Every streamable scorer survives the ship-score-merge round
        trip (aux shipping, admission recount, revert) bit for bit."""
        g = rmat(9, edge_factor=6, seed=3)
        rows = np.concatenate([g.edges, g.edges[::5]])   # inject dups
        path = tmp_path / "edges.txt"
        np.savetxt(path, rows, fmt="%d")
        cl = scaled_paper_cluster(2, 4, g.num_edges, slack=2.0)
        seq = S.stream_partition(str(path), cluster=cl, method=method,
                                 block_size=256, dedup="two_pass")
        par = S.stream_partition(str(path), cluster=cl, method=method,
                                 block_size=256, dedup="two_pass",
                                 workers=2, sync_blocks=1)
        assert_states_identical(seq, par)

    def test_registry_advertises_parallel(self):
        from repro.core import partitioners as registry
        assert set(registry.names(require={"parallel"})) == \
            {"greedy", "hdrf", "ebv"}


class TestCompact:
    def _assignment_with_tombs(self, tmp_path):
        """Finalize a 2-machine assignment, then delete a small slice via
        apply_delta — few enough tombstones to stay under the automatic
        ``_COMPACT_FRAC`` rewrite."""
        g = rmat(8, edge_factor=6, seed=9)
        cl = scaled_paper_cluster(1, 2, g.num_edges, slack=2.0)
        path = tmp_path / "edges.txt"
        np.savetxt(path, g.edges, fmt="%d")
        state, sa = _partition(path, cl, tmp_path / "assign")
        # drop every 10th edge of machine 0's shard (value-based tombs)
        rows = sa.machine_edges(0)[::10]
        degree = sa.degree.copy()
        np.subtract.at(degree, rows.ravel(), 1)
        member = (state.cnt > 0).copy()
        member[:, degree == 0] = False
        delta = AssignmentDelta(
            num_vertices=sa.num_vertices,
            added=np.empty((0, 2), dtype=np.int64),
            added_ms=np.empty(0, dtype=np.int64),
            removed=rows.astype(np.int64),
            removed_ms=np.zeros(len(rows), dtype=np.int64))
        sa.apply_delta(delta, member, {"method": "hdrf"})
        return sa

    def test_folds_tombstones_and_preserves_content(self, tmp_path):
        sa = self._assignment_with_tombs(tmp_path)
        assert sa.tomb_rows[0] > 0          # below auto threshold: kept
        before = [sa.machine_edges(i).copy() for i in range(sa.p)]
        extra_method = sa.meta["method"]
        meta = sa.compact()
        assert sa.tomb_rows.sum() == 0
        assert not (sa.dir / "shard0.tomb").exists()
        for i in range(sa.p):
            np.testing.assert_array_equal(sa.machine_edges(i), before[i])
        # provenance keys survive the republish; reopen agrees
        assert meta["method"] == extra_method
        sb = StreamAssignment.open(sa.dir)
        np.testing.assert_array_equal(sb.membership(), sa.membership())
        assert sb.meta["tomb_rows"] == [0] * sa.p

    def test_noop_above_threshold(self, tmp_path):
        sa = self._assignment_with_tombs(tmp_path)
        meta = sa.meta
        assert sa.compact(max_tomb_frac=1.0) is meta    # untouched
        assert sa.tomb_rows[0] > 0

    def test_requires_finalize(self, tmp_path):
        sa = StreamAssignment(tmp_path / "a", 2, 4)
        with pytest.raises(RuntimeError, match="finalized"):
            sa.compact()
