"""True-EP MoE (shard_map + all_to_all) vs the SPMD dispatch oracle."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.models import init_params, layers as L
from repro.models.moe_shardmap import moe_ffn_ep

cfg = dataclasses.replace(get_reduced("phi3.5-moe-42b-a6.6b"),
                          capacity_factor=8.0)   # no drops on either path
params = init_params(cfg, jax.random.PRNGKey(0))
ffn = jax.tree.map(lambda a: a[0], params["blocks"]["pos0"]["ffn"])
mesh = jax.make_mesh((2, 4), ("data", "model"))
B, S = 4, 16
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)

ref = L.moe_ffn(cfg, ffn, x)
with mesh:
    out = moe_ffn_ep(cfg, ffn, x, mesh)
err = float(jnp.abs(out - ref).max())
scale = float(jnp.abs(ref).max())
assert err / scale < 1e-5, (err, scale)

# gradients flow through the all_to_all exchange
def loss_ep(f, xx):
    with mesh:
        return jnp.sum(moe_ffn_ep(cfg, f, xx, mesh) ** 2)
def loss_ref(f, xx):
    return jnp.sum(L.moe_ffn(cfg, f, xx) ** 2)
g_ep = jax.grad(loss_ep)(ffn, x)
g_rf = jax.grad(loss_ref)(ffn, x)
for a, b in zip(jax.tree.leaves(g_ep), jax.tree.leaves(g_rf)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-4, atol=5e-4)
print("MOE_EP_OK")
"""


def test_shardmap_ep_matches_spmd_dispatch():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"})
    assert "MOE_EP_OK" in out.stdout, out.stderr[-3000:]
