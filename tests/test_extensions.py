"""Paper §4 extensions + WCC app + partition CLI."""
import json
import subprocess
import sys

import numpy as np

from repro.bsp import PartitionRuntime, connected_components
from repro.core import evaluate, scaled_paper_cluster, windgp, from_edge_list
from repro.core.extensions import (edge_cut, evaluate_mapreduce,
                                   vertex_balance,
                                   vertex_partition_from_edge_partition)
from repro.data import rmat


def _setup():
    g = rmat(10, seed=9)
    cl = scaled_paper_cluster(2, 4, g.num_edges)
    res = windgp(g, cl, t0=4)
    return g, cl, res


class TestMapReduceObjective:
    def test_mapreduce_geq_bsp(self):
        """MR makespan uses the global max of T_cal: never below BSP TC."""
        g, cl, res = _setup()
        mr, s = evaluate_mapreduce(g, res.assign, cl)
        assert mr >= s.tc - 1e-9
        assert mr <= s.t_cal.max() + s.t_com.max() + 1e-9


class TestVertexCentricConversion:
    def test_valid_vertex_partition(self):
        g, cl, res = _setup()
        place = vertex_partition_from_edge_partition(g, res.assign, cl)
        deg = g.degree()
        assert (place[deg > 0] >= 0).all()
        assert (place[deg == 0] == -1).all()

    def test_better_than_random_edge_cut(self):
        g, cl, res = _setup()
        place = vertex_partition_from_edge_partition(g, res.assign, cl)
        rng = np.random.default_rng(0)
        rand = rng.integers(0, cl.p, g.num_vertices)
        assert edge_cut(g, place) < edge_cut(g, rand)
        assert vertex_balance(place, cl.p) < 3.0


class TestConnectedComponents:
    def test_matches_union_find(self):
        # two components: a clique and a path, plus isolated vertices
        edges = [[0, 1], [1, 2], [2, 0], [5, 6], [6, 7]]
        g = from_edge_list(np.array(edges), num_vertices=10)
        cl = scaled_paper_cluster(1, 2, g.num_edges)
        res = windgp(g, cl, t0=2)
        rt = PartitionRuntime.build(g, res.assign, cl.p)
        lab, _ = connected_components(rt, num_iters=10)
        assert lab[0] == lab[1] == lab[2] == 0
        assert lab[5] == lab[6] == lab[7] == 5
        assert np.isinf(lab[3]) and np.isinf(lab[9])

    def test_power_law_graph(self):
        g = rmat(9, seed=4)
        cl = scaled_paper_cluster(2, 4, g.num_edges)
        res = windgp(g, cl, t0=2)
        rt = PartitionRuntime.build(g, res.assign, cl.p)
        lab, actives = connected_components(rt, num_iters=25)
        # giant component exists; labels are fixed points (converged)
        assert actives.sum(axis=1)[-1] == 0
        # every edge's endpoints share a label
        a, b = lab[g.edges[:, 0]], lab[g.edges[:, 1]]
        np.testing.assert_array_equal(a, b)


def test_partition_cli_runs():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.partition",
         "--graph", "rmat:9", "--super", "1", "--normal", "3",
         "--method", "windgp", "--t0", "2"],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-2000:]
    payload = out.stdout[out.stdout.index("{"):]
    rep = json.loads(payload.split("}\n")[0] + "}")
    assert rep["feasible"] is True
