"""Unit + property tests for the WindGP core (paper Algorithms 1-7)."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import (Cluster, Machine, capacities, evaluate,
                        exact_capacity_relaxed, from_edge_list,
                        paper_cluster, replication_factor,
                        scaled_paper_cluster, windgp)
from repro.core import capacity as cap_mod
from repro.core import expand as exp_mod
from repro.core import sls as sls_mod
from repro.data import rmat, road_mesh


def small_graph():
    # the paper's Figure 2 example: a-b-c, d-e-f, c-f
    # ids: a0 b1 c2 d3 e4 f5
    return from_edge_list(np.array(
        [[0, 1], [1, 2], [3, 4], [4, 5], [2, 5]]), num_vertices=6)


class TestGraph:
    def test_csr_roundtrip(self):
        g = small_graph()
        assert g.num_vertices == 6 and g.num_edges == 5
        assert sorted(g.neighbors(1).tolist()) == [0, 2]
        assert sorted(g.neighbors(5).tolist()) == [2, 4]
        assert g.degree(4) == 2

    def test_dedup_and_selfloops(self):
        g = from_edge_list(np.array([[0, 1], [1, 0], [0, 0], [1, 2], [1, 2]]))
        assert g.num_edges == 2

    def test_edge_ids_symmetric(self):
        g = small_graph()
        # both directions of an edge share the id
        for u, v in g.edges:
            eu = dict(zip(g.neighbors(u).tolist(),
                          g.incident_edge_ids(u).tolist()))
            ev = dict(zip(g.neighbors(v).tolist(),
                          g.incident_edge_ids(v).tolist()))
            assert eu[v] == ev[u]


class TestPaperExample:
    """Section 2.1's running example of the TC metric."""

    def cluster(self):
        return Cluster(machines=(
            Machine(7, 0, 1, 1), Machine(7, 0, 2, 2), Machine(5, 0, 1, 1)),
            m_node=1.0, m_edge=2.0)

    def test_tc_of_good_partition(self):
        g = small_graph()
        cl = self.cluster()
        # {ab, bc} -> M0, {de, ef} -> M1, {cf} -> M2
        assign = np.zeros(5, dtype=np.int32)
        eid = {tuple(e): i for i, e in enumerate(map(tuple, g.edges))}
        assign[eid[(0, 1)]] = 0
        assign[eid[(1, 2)]] = 0
        assign[eid[(3, 4)]] = 1
        assign[eid[(4, 5)]] = 1
        assign[eid[(2, 5)]] = 2
        s = evaluate(g, assign, cl)
        # Paper: computing costs {2,4,1}, communication {2,3,5}, TC=7.
        assert s.t_cal.tolist() == [2, 4, 1]
        assert s.t_com.tolist() == [2, 3, 5]
        assert s.tc == 7
        assert abs(s.rf - 8 / 6) < 1e-9
        assert s.feasible

    def test_tc_of_bad_partition(self):
        g = small_graph()
        cl = self.cluster()
        assign = np.zeros(5, dtype=np.int32)
        eid = {tuple(e): i for i, e in enumerate(map(tuple, g.edges))}
        # {ab} -> M0, {bc, cf} -> M1, {de, ef} -> M2 : TC=10, RF unchanged.
        assign[eid[(0, 1)]] = 0
        assign[eid[(1, 2)]] = 1
        assign[eid[(2, 5)]] = 1
        assign[eid[(3, 4)]] = 2
        assign[eid[(4, 5)]] = 2
        s = evaluate(g, assign, cl)
        assert s.tc == 10
        assert abs(s.rf - 8 / 6) < 1e-9


class TestCapacity:
    def test_sums_to_e(self):
        cl = paper_cluster(2, 4)
        d = capacities(cl, 1000, 20000)
        assert d.sum() == 20000 and (d >= 0).all()

    def test_respects_memory(self):
        cl = Cluster(machines=(Machine(100, 0, 1, 1), Machine(10000, 0, 1, 1)))
        d = capacities(cl, 0, 2000)
        # machine 0 fits at most 100/2 = 50 edges
        assert d[0] <= 50 and d.sum() == 2000

    def test_infeasible_raises(self):
        cl = Cluster(machines=(Machine(10, 0, 1, 1),))
        with pytest.raises(ValueError):
            capacities(cl, 0, 1000)

    def test_balances_compute(self):
        # no memory pressure: C_i * delta_i should be ~constant (Lemma 1)
        cl = Cluster(machines=(Machine(1e9, 0, 1, 1), Machine(1e9, 0, 3, 1)))
        d = capacities(cl, 0, 40000)
        assert abs(d[0] - 3 * d[1]) <= 4  # integer rounding slack

    @given(st.integers(2, 8), st.integers(100, 50000), st.integers(0, 2 ** 31))
    @settings(max_examples=50, deadline=None)
    def test_matches_relaxed_optimum(self, p, E, seed):
        """Theorem 1: heuristic λ within p²/|E| (relative) of LP optimum."""
        rng = np.random.default_rng(seed)
        machines = tuple(
            Machine(memory=float(rng.integers(E // p, 4 * E)),
                    c_node=float(rng.integers(0, 5)),
                    c_edge=float(rng.integers(1, 20)),
                    c_com=1.0)
            for _ in range(p))
        cl = Cluster(machines=machines)
        V = E // 10
        mem_caps = np.floor(cl.memory() / (cl.m_edge + cl.m_node * V / E))
        if mem_caps.sum() < E:
            return  # infeasible instance; covered by test_infeasible_raises
        d = capacities(cl, V, E)
        assert d.sum() == E
        assert np.all(d <= mem_caps + 1e-9)
        C = cap_mod.effective_cost(cl, V, E)
        lam = float((C * d).max())
        d_star = exact_capacity_relaxed(cl, V, E)
        lam_star = float((C * d_star).max())
        # heuristic never better than the relaxation, and within bound
        assert lam >= lam_star - 1e-6
        bound = max(p * C.max(), lam_star * (p ** 2) / E + p * C.max())
        assert lam - lam_star <= bound


class TestExpansion:
    def test_partitions_all_edges(self):
        g = rmat(10, seed=0)
        cl = scaled_paper_cluster(2, 4, g.num_edges)
        d = capacities(cl, g.num_vertices, g.num_edges)
        assign, orders = exp_mod.run_expansion(g, d, 0.3, 0.3,
                                               memories=cl.memory())
        placed = assign >= 0
        # memory guard may defer a few edges; driver repairs them
        assert placed.sum() >= 0.95 * g.num_edges
        sizes = np.bincount(assign[placed], minlength=cl.p)
        assert np.all(sizes <= d)

    def test_orders_match_assignment(self):
        g = rmat(9, seed=1)
        cl = scaled_paper_cluster(1, 3, g.num_edges)
        d = capacities(cl, g.num_vertices, g.num_edges)
        assign, orders = exp_mod.run_expansion(g, d, 0.3, 0.3)
        for i, o in enumerate(orders):
            assert np.all(assign[np.array(o, dtype=int)] == i)

    def test_single_partition_connected(self):
        """One machine big enough: expansion yields one connected chunk."""
        g = road_mesh(12, rewire=0.0)
        cl = Cluster(machines=(Machine(1e9, 0, 1, 1),))
        d = capacities(cl, g.num_vertices, g.num_edges)
        assign, _ = exp_mod.run_expansion(g, d, 0.3, 0.3)
        assert (assign == 0).all()

    def test_best_first_cohesion_lowers_rf(self):
        """Paper Sec. 3.3 claim: on clustered graphs the cohesion term (α)
        and border term (β) reduce replication vs pure NE expansion."""
        rng = np.random.default_rng(0)
        blocks, bs = 32, 64
        parts = []
        for b in range(blocks):  # dense communities
            parts.append(rng.integers(0, bs, size=(bs * 10, 2)) + b * bs)
        parts.append(rng.integers(0, blocks * bs, size=(blocks * bs, 2)))
        g = from_edge_list(np.concatenate(parts), num_vertices=blocks * bs)
        cl = scaled_paper_cluster(3, 6, g.num_edges)
        d = capacities(cl, g.num_vertices, g.num_edges)
        rfs = {}
        for a, b in [(0.0, 0.0), (0.5, 0.5)]:
            assign, _ = exp_mod.run_expansion(g, d, a, b,
                                              memories=cl.memory())
            assign[assign < 0] = 0
            rfs[(a, b)] = replication_factor(g, assign, cl.p)
        assert rfs[(0.5, 0.5)] <= rfs[(0.0, 0.0)] + 1e-9


class TestSLS:
    def test_incremental_matches_reference(self):
        g = rmat(9, seed=2)
        cl = scaled_paper_cluster(2, 4, g.num_edges)
        d = capacities(cl, g.num_vertices, g.num_edges)
        assign, orders = exp_mod.run_expansion(g, d, 0.3, 0.3,
                                               memories=cl.memory())
        assign[assign < 0] = 0
        obj = sls_mod.IncrementalTC.build(g, assign, cl)
        rng = np.random.default_rng(0)
        for _ in range(5):
            sls_mod.destroy_repair(obj, orders, 0.8, 0.05, rng)
        ref = evaluate(g, obj.assign, cl)
        np.testing.assert_allclose(obj.t_cal, ref.t_cal)
        np.testing.assert_allclose(obj.t_com, ref.t_com)

    def test_sls_never_worsens_best(self):
        g = rmat(10, seed=4)
        cl = scaled_paper_cluster(2, 4, g.num_edges)
        r_plus = windgp(g, cl, level="windgp+")
        r_full = windgp(g, cl, level="windgp", t0=10)
        assert r_full.stats.tc <= r_plus.stats.tc + 1e-6

    def test_add_remove_roundtrip(self):
        g = small_graph()
        cl = paper_cluster(1, 2)
        assign = np.array([0, 0, 1, 1, 2], dtype=np.int32)
        obj = sls_mod.IncrementalTC.build(g, assign, cl)
        t0 = obj.t_total.copy()
        obj.remove_edge(4)
        obj.add_edge(4, 2)
        np.testing.assert_allclose(obj.t_total, t0)


class TestEndToEnd:
    @pytest.mark.parametrize("level", ["windgp-", "windgp*", "windgp+", "windgp"])
    def test_feasible_complete_partition(self, level):
        g = rmat(10, seed=7)
        cl = scaled_paper_cluster(3, 6, g.num_edges, slack=2.0)
        r = windgp(g, cl, level=level, t0=6)
        assert (r.assign >= 0).all()
        assert r.stats.feasible
        assert np.bincount(r.assign, minlength=cl.p).sum() == g.num_edges

    def test_full_beats_naive(self):
        g = rmat(12, seed=1)
        cl = scaled_paper_cluster(3, 6, g.num_edges)
        naive = windgp(g, cl, level="windgp-", alpha=0.1, beta=0.1)
        full = windgp(g, cl, level="windgp", alpha=0.1, beta=0.1,
                      t0=30, theta=0.02)
        assert full.stats.tc < naive.stats.tc

    def test_homogeneous_rf_reasonable(self):
        """Paper Table 10: on homogeneous clusters WindGP ≈ NE quality."""
        g = rmat(11, seed=5)
        cl = Cluster(machines=tuple([Machine(1e9, 5, 10, 10)] * 8))
        r = windgp(g, cl, t0=10)
        hash_assign = ((g.edges[:, 0].astype(np.int64) * 2654435761) % 8
                       ).astype(np.int32)
        rf_hash = replication_factor(g, hash_assign, 8)
        assert r.stats.rf < 0.7 * rf_hash  # far better than random hash


@given(st.integers(0, 2 ** 31), st.integers(2, 5))
@settings(max_examples=10, deadline=None)
def test_property_valid_edge_partition(seed, n_machines):
    """Definition 3: every edge in exactly one partition; every partition
    vertex has an incident partition edge; memory constraints hold."""
    g = rmat(8, seed=seed)
    cl = scaled_paper_cluster(1, n_machines - 1, g.num_edges, slack=2.5)
    r = windgp(g, cl, t0=3)
    assert (r.assign >= 0).all()
    s = r.stats
    assert s.feasible
    assert int(s.edges_per_part.sum()) == g.num_edges
    # V_i = endpoints of E_i exactly (Definition 3 condition 1)
    for i in range(cl.p):
        mask = r.assign == i
        vs = np.unique(g.edges[mask])
        assert len(vs) == int(s.verts_per_part[i])
