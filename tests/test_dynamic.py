"""Dynamic incremental repartitioning: live mutation stream, epoch deltas,
and the BSP warm-start hand-off.

The layer's core promise is that incremental state never diverges from
what a fresh build over the same live assignment would produce — every
test here ultimately reduces to that equivalence, plus the durability
contracts of the on-disk delta path (append + tombstone segments,
meta-last crash safety).
"""
import numpy as np
import pytest

from repro.bsp import PartitionRuntime, pagerank
from repro.bsp.stream_assignment import StreamAssignment
from repro.core import (AssignmentDelta, DynamicPartitioner,
                        from_edge_list, scaled_paper_cluster)
from repro.core.graph import edge_keys
from repro.core.partition_state import PartitionState
from repro.data import rmat


def split_timeline(scale=9, seed=2, seed_frac=0.7):
    """A proxy graph split into (seed graph, arriving edges, cluster)."""
    g = rmat(scale, seed=seed)
    rng = np.random.default_rng(seed)
    edges = g.edges[rng.permutation(g.num_edges)]
    n = int(seed_frac * len(edges))
    gseed = from_edge_list(edges[:n], num_vertices=g.num_vertices)
    cl = scaled_paper_cluster(2, 4, g.num_edges, slack=2.0)
    return gseed, edges[n:], cl


def keyset(uv):
    return set(edge_keys(uv[:, 0], uv[:, 1]).tolist())


@pytest.fixture()
def dp():
    gseed, arrivals, cl = split_timeline()
    d = DynamicPartitioner(gseed, cl, method="hdrf", auto_repair=False)
    return d, arrivals, cl


class TestMutationStream:
    def test_insert_places_whole_batch(self, dp):
        d, arrivals, cl = dp
        before = d.num_live_edges
        placed = d.insert(arrivals[:200])
        assert placed == 200
        assert d.num_live_edges == before + 200
        assert (d.state.assign >= 0).all()

    def test_state_matches_fresh_build_after_churn(self, dp):
        """The equivalence everything else rests on: live incremental
        state == PartitionState.build over the same graph + assignment."""
        d, arrivals, cl = dp
        d.insert(arrivals[:300])
        live = np.flatnonzero(d.state.assign >= 0)
        d.delete(d.g.edges[live[::7]])
        d.insert(arrivals[300:400])
        fresh = PartitionState.build(d.g, d.state.assign, cl)
        np.testing.assert_array_equal(d.state.cnt, fresh.cnt)
        np.testing.assert_array_equal(d.state.t_cal, fresh.t_cal)
        np.testing.assert_array_equal(d.state.t_com, fresh.t_com)
        np.testing.assert_array_equal(d.state.verts_per, fresh.verts_per)
        assert d.tc == fresh.tc

    def test_insert_is_idempotent(self, dp):
        d, arrivals, cl = dp
        d.insert(arrivals[:50])
        assign = d.state.assign.copy()
        assert d.insert(arrivals[:50]) == 0     # all already live
        np.testing.assert_array_equal(d.state.assign, assign)

    def test_insert_grows_the_vertex_universe(self, dp):
        d, _, _ = dp
        v0 = d.g.num_vertices
        d.insert(np.array([[v0 + 1, 3], [v0, v0 + 1]]))
        assert d.g.num_vertices == v0 + 2
        assert d.membership().shape[1] == v0 + 2
        assert d.membership()[:, v0 + 1].any()

    def test_reinsert_reuses_the_canonical_id(self, dp):
        d, _, _ = dp
        pair = d.g.edges[:1]
        eid = d.g.eids_of(pair[:, 0], pair[:, 1])
        ne = d.g.num_edges
        d.delete(pair)
        assert d.state.assign[eid[0]] == -1
        d.insert(pair)
        assert d.g.num_edges == ne             # no new id minted
        assert d.state.assign[eid[0]] >= 0
        assert d.counters["reinserted"] == 1

    def test_delete_strict_rejects_unknown_pairs(self, dp):
        d, _, _ = dp
        ghost = np.array([[d.g.num_vertices + 5, d.g.num_vertices + 6]])
        with pytest.raises(ValueError, match="not currently live"):
            d.delete(ghost)
        assert d.delete(ghost, strict=False) == 0

    def test_loops_and_duplicates_are_canonicalized_away(self, dp):
        d, _, _ = dp
        v0 = d.g.num_vertices
        placed = d.insert(np.array([[v0, v0], [v0, v0 + 1],
                                    [v0 + 1, v0]]))
        assert placed == 1                     # loop dropped, pair deduped


class TestDriftRepair:
    def test_quiet_timeline_never_repairs(self, dp):
        d, arrivals, _ = dp
        d.auto_repair = True
        d.insert(arrivals[:100])
        assert d.repairs == []

    def test_tight_skew_leash_triggers_bounded_repair(self):
        gseed, arrivals, cl = split_timeline()
        d = DynamicPartitioner(gseed, cl, method="hdrf",
                               skew_limit=1.0 + 1e-9, repair_cap=256)
        d.insert(arrivals[:256])
        assert d.repairs and d.repairs[0].trigger == "skew"
        assert all(r.edges_moved <= 256 for r in d.repairs)

    def test_forced_repair_keeps_state_exact_and_complete(self, dp):
        d, arrivals, cl = dp
        d.insert(arrivals[:300])
        rep = d.repair()
        assert rep.trigger == "forced"
        assert (d.state.assign >= 0).all()     # destroy set fully re-placed
        fresh = PartitionState.build(d.g, d.state.assign, cl)
        np.testing.assert_array_equal(d.state.cnt, fresh.cnt)
        assert d.tc == fresh.tc
        assert not d._touched.any()            # frontier reset

    def test_repair_scoped_to_frontier(self, dp):
        """With an empty frontier a repair has nothing to destroy."""
        d, _, _ = dp
        rep = d.repair()
        assert rep.edges_moved == 0


class TestAdaptiveLeash:
    def test_limit_tracks_running_baseline(self, dp):
        """Default threshold = rf_leash x the RF anchor, re-based to the
        post-repair RF after every repair epoch — the next trigger needs
        *new* drift, not the floor the repair could not recover below."""
        d, arrivals, _ = dp
        assert d.rf_limit == pytest.approx(1.15 * max(1.0, d.rf))
        d.insert(arrivals[:300])
        anchor_before = d._rf_anchor
        d.repair()
        assert d._rf_anchor == max(1.0, d.rf)
        assert d.rf_limit == pytest.approx(d.rf_leash * d._rf_anchor)
        assert d._rf_anchor != anchor_before or d.rf == anchor_before

    def test_pinned_override_survives_repair(self, dp):
        d, arrivals, _ = dp
        d.rf_limit = 9.9                   # pin absolutely
        d.insert(arrivals[:100])
        d.repair()
        assert d.rf_limit == 9.9           # re-anchoring does not unpin
        d.rf_limit = None                  # back to adaptive
        assert d.rf_limit == pytest.approx(d.rf_leash * d._rf_anchor)

    def test_ctor_rf_limit_pins(self):
        gseed, _, cl = split_timeline()
        d = DynamicPartitioner(gseed, cl, method="hdrf", rf_limit=9.9,
                               auto_repair=False)
        assert d.rf_limit == 9.9

    def test_zero_slack_leash_trips_on_new_drift_only(self):
        """rf_leash=1.0: any RF growth beyond the running baseline trips
        an ``"rf"`` repair; because the anchor re-bases, a repair that
        cannot lower RF does not retrigger forever on the same floor."""
        gseed, arrivals, cl = split_timeline()
        d = DynamicPartitioner(gseed, cl, method="hdrf", rf_leash=1.0,
                               skew_limit=1e9, repair_cap=256)
        d.insert(arrivals[:256])
        assert d.repairs and d.repairs[0].trigger == "rf"
        assert d.drift() is None           # anchor >= live RF again


class TestDelta:
    def test_delta_coalesces_within_epoch(self, dp):
        d, arrivals, _ = dp
        snap = d.snapshot()
        d.insert(arrivals[:60])
        d.delete(arrivals[:20])                # inserted then deleted
        seed_pair = d.g.edges[:1]
        d.delete(seed_pair)                    # live at snapshot
        delta = d.delta_since(snap)
        added, removed = keyset(delta.added), keyset(delta.removed)
        flash = keyset(arrivals[:20])
        assert not (flash & added) and not (flash & removed)
        assert keyset(arrivals[20:60]) <= added
        assert keyset(seed_pair) <= removed
        assert delta.num_changes == len(delta.added) + len(delta.removed)

    def test_empty_epoch_empty_delta(self, dp):
        d, _, _ = dp
        delta = d.delta_since(d.snapshot())
        assert delta.num_changes == 0
        assert not delta.machines_touched(d.cluster.p).any()


def finalized_assignment(tmp_path, d):
    """A finalized StreamAssignment mirroring the live partition."""
    sa = StreamAssignment(tmp_path / "assign", d.cluster.p,
                          d.g.num_vertices)
    live = np.flatnonzero(d.state.assign >= 0)
    sa.sink(d.g.edges[live], d.state.assign[live].astype(np.int64))
    sa.finalize(d.membership())
    return sa


class TestDeltaRoundTrip:
    def test_shards_track_live_assignment(self, dp, tmp_path):
        d, arrivals, cl = dp
        sa = finalized_assignment(tmp_path, d)
        snap = d.snapshot()
        d.insert(arrivals[:250])
        live = np.flatnonzero(d.state.assign >= 0)
        d.delete(d.g.edges[live[::9]])
        d.repair()                             # moves => tombstone + append
        sa.apply_delta(d.delta_since(snap), d.membership())
        for i in range(cl.p):
            want = d.g.edges[d.state.assign == i]
            rows = sa.machine_edges(i)
            assert sorted(map(tuple, rows.tolist())) == \
                sorted(map(tuple, want.tolist()))
        sb = StreamAssignment.open(tmp_path / "assign")   # reopen clean
        np.testing.assert_array_equal(sb.membership(), d.membership())
        np.testing.assert_array_equal(sb.edges_per, sa.edges_per)

    def test_runtime_apply_delta_equals_full_repack(self, dp, tmp_path):
        d, arrivals, cl = dp
        sa = finalized_assignment(tmp_path, d)
        rt = PartitionRuntime.from_stream(sa)
        snap = d.snapshot()
        d.insert(arrivals[:200])
        live = np.flatnonzero(d.state.assign >= 0)
        d.delete(d.g.edges[live[::11]])
        delta = d.delta_since(snap)
        sa.apply_delta(delta, d.membership())
        fast = rt.apply_delta(sa, delta)
        full = PartitionRuntime.from_stream(sa)
        import dataclasses
        for f in dataclasses.fields(full):
            np.testing.assert_array_equal(
                getattr(fast, f.name), getattr(full, f.name), err_msg=f.name)

    def test_warm_start_pagerank_reaches_the_same_fixed_point(
            self, dp, tmp_path):
        d, arrivals, cl = dp
        sa = finalized_assignment(tmp_path, d)
        rt = PartitionRuntime.from_stream(sa)
        pr_old, _ = pagerank(rt, num_iters=40)
        snap = d.snapshot()
        d.insert(arrivals[:200])
        delta = d.delta_since(snap)
        sa.apply_delta(delta, d.membership())
        rt2 = rt.apply_delta(sa, delta)
        warm, _ = pagerank(rt2, num_iters=40, init=pr_old)
        cold, _ = pagerank(rt2, num_iters=40)
        np.testing.assert_allclose(warm, cold, rtol=2e-4, atol=1e-7)
        assert abs(warm.sum() - cold.sum()) < 1e-3


class TestDurability:
    def test_open_rejects_truncated_meta(self, dp, tmp_path):
        d, _, _ = dp
        sa = finalized_assignment(tmp_path, d)
        meta = sa.dir / "meta.json"
        meta.write_text(meta.read_text()[: meta.stat().st_size // 2])
        with pytest.raises(ValueError, match="corrupt"):
            StreamAssignment.open(sa.dir)

    def test_machine_edges_unreadable_before_finalize(self, tmp_path):
        sa = StreamAssignment(tmp_path / "a", 2, 4)
        sa.sink(np.array([[0, 1]]), np.array([0]))
        with pytest.raises(RuntimeError, match="unfinished"):
            sa.machine_edges(0)
        sa.close()

    def test_apply_delta_requires_finalize(self, tmp_path):
        sa = StreamAssignment(tmp_path / "a", 2, 4)
        empty = np.empty((0, 2), dtype=np.int64)
        delta = AssignmentDelta(num_vertices=4, added=empty,
                                added_ms=np.empty(0, dtype=np.int64),
                                removed=empty,
                                removed_ms=np.empty(0, dtype=np.int64))
        with pytest.raises(RuntimeError, match="finalized"):
            sa.apply_delta(delta, np.zeros((2, 4), dtype=bool))
        sa.close()

    def test_mid_delta_directory_is_detectably_unfinished(
            self, dp, tmp_path, monkeypatch):
        """A crash between unpublish and republish leaves no meta.json."""
        d, arrivals, _ = dp
        sa = finalized_assignment(tmp_path, d)
        snap = d.snapshot()
        d.insert(arrivals[:50])

        def boom(*a, **k):
            raise RuntimeError("simulated crash")

        monkeypatch.setattr(sa, "_publish", boom)
        with pytest.raises(RuntimeError, match="simulated crash"):
            sa.apply_delta(d.delta_since(snap), d.membership())
        assert not (sa.dir / "meta.json").exists()
        with pytest.raises(FileNotFoundError, match="meta.json"):
            StreamAssignment.open(sa.dir)

    def test_tombstone_compaction_folds_in(self, tmp_path):
        sa = StreamAssignment(tmp_path / "a", 1, 4)
        edges = np.array([[0, 1], [0, 2], [0, 3], [1, 2]])
        sa.sink(edges, np.zeros(4, dtype=np.int64))
        member = np.ones((1, 4), dtype=bool)
        sa.finalize(member)
        removed = edges[:3]
        member2 = np.array([[False, True, True, False]])
        delta = AssignmentDelta(
            num_vertices=4,
            added=np.empty((0, 2), dtype=np.int64),
            added_ms=np.empty(0, dtype=np.int64),
            removed=removed.astype(np.int64),
            removed_ms=np.zeros(3, dtype=np.int64))
        sa.apply_delta(delta, member2)
        assert not (sa.dir / "shard0.tomb").exists()   # compacted away
        assert sa.shard_rows[0] == 1 and sa.tomb_rows[0] == 0
        np.testing.assert_array_equal(sa.machine_edges(0),
                                      np.array([[1, 2]]))
