"""Hypothesis compat shim: property tests run even without `hypothesis`.

When the real package is installed it is re-exported unchanged.  Otherwise a
minimal fixed-seed sampler stands in: ``@given(...)`` draws
``max_examples`` (default 20) pseudo-random examples per test from a
deterministic PRNG, so CI without the dev extras still exercises the
property suites (with less coverage and no shrinking).

Only the strategy surface this repo uses is implemented: ``integers``,
``floats``, ``booleans``, ``sampled_from``, ``lists``, ``tuples`` and
``just``.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _SEED = 0x517D  # fixed: runs are reproducible

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self._draw(rng)))

        def filter(self, pred, _tries=100):
            def draw(rng):
                for _ in range(_tries):
                    x = self._draw(rng)
                    if pred(x):
                        return x
                raise ValueError("filter predicate too strict for shim")
            return _Strategy(draw)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(
                lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def lists(elems, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elems.example(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*strats):
            return _Strategy(lambda rng: tuple(s.example(rng)
                                               for s in strats))

    strategies = _Strategies()

    def settings(max_examples=20, **_kw):
        """Records max_examples on the test; other knobs are ignored."""
        def deco(fn):
            inner = getattr(fn, "__wrapped_by_given__", None)
            if inner is not None:
                inner.max_examples = max_examples
            else:
                fn.__shim_max_examples__ = max_examples
            return fn
        return deco

    class _GivenState:
        def __init__(self):
            self.max_examples = None

    def given(*strats, **kw_strats):
        state = _GivenState()

        def deco(fn):
            default = getattr(fn, "__shim_max_examples__", 20)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(f"{_SEED}:{fn.__qualname__}")
                n = state.max_examples or default
                for i in range(n):
                    ex_args = tuple(s.example(rng) for s in strats)
                    ex_kw = {k: s.example(rng) for k, s in kw_strats.items()}
                    try:
                        fn(*args, *ex_args, **kwargs, **ex_kw)
                    except Exception as e:  # noqa: BLE001 - re-raise w/ context
                        raise AssertionError(
                            f"shim falsifying example #{i}: "
                            f"args={ex_args} kwargs={ex_kw}") from e
            # pytest reads the signature to collect fixtures: hide the
            # example-supplied parameters (and functools' __wrapped__).
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            keep = len(params) - len(strats) - len(kw_strats)
            wrapper.__signature__ = sig.replace(parameters=params[:keep])
            del wrapper.__wrapped__
            wrapper.__wrapped_by_given__ = state
            return wrapper
        return deco
