"""Fused on-device BSP loop vs the per-step oracle.

``run_bsp`` (one dispatch + host sync per superstep) is the bit-exact
reference; ``run_bsp_fused`` must reproduce it — bitwise for the min/max
semiring apps (SSSP/BFS/CC, whose exchange-epilogue rewrite is exact)
and to 1e-6 for PageRank's (+,×) — including the actives trajectory,
early exit mid-chunk, and the zero-step edge case.  The frontier-
sparsified scatter path and the low-precision message knob are pinned
here too, plus the dtype-safe integer exchange identities.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bsp import (PartitionRuntime, bfs, connected_components,
                       frontier_entries, pagerank, run_bsp, run_bsp_fused,
                       sssp)
from repro.bsp.apps import build_pagerank
from repro.bsp.engine import MACHINES, exchange, make_fused_runner
from repro.core import scaled_paper_cluster, windgp
from repro.data import rmat

APPS = {
    "pagerank": (pagerank, dict(num_iters=15)),
    "sssp": (sssp, dict(source=0, num_iters=25)),
    "bfs": (bfs, dict(source=1, num_iters=25)),
    "cc": (connected_components, dict(num_iters=25)),
}


@pytest.fixture(scope="module")
def part():
    g = rmat(8, seed=2)
    cl = scaled_paper_cluster(2, 4, g.num_edges)
    r = windgp(g, cl, t0=2)
    rt = PartitionRuntime.build(g, r.assign, cl.p)
    return g, rt


class TestFusedEquivalence:
    @pytest.mark.parametrize("backend", ["scatter", "segment"])
    @pytest.mark.parametrize("app", list(APPS))
    def test_fused_matches_stepwise(self, part, app, backend):
        """Fused ≡ stepwise: results and the actives prefix, per app."""
        _, rt = part
        fn, kw = APPS[app]
        a, acts_a = fn(rt, backend=backend, **kw)
        b, acts_b = fn(rt, backend=backend, fused=True, chunk=4, **kw)
        if app == "pagerank":
            np.testing.assert_allclose(a, b, atol=1e-6)
        else:
            np.testing.assert_array_equal(a, b)   # bitwise (min/max)
        n = len(acts_b)
        np.testing.assert_array_equal(acts_a[:n], acts_b)
        # anything the fused runner skipped, the oracle spent idling
        assert np.asarray(acts_a)[n:].sum() == 0

    @pytest.mark.parametrize("chunk", [1, 3, 8, 64])
    def test_chunk_size_is_cosmetic(self, part, chunk):
        """Any chunking (incl. chunk > budget) gives the same trajectory."""
        _, rt = part
        d0, acts0 = sssp(rt, source=0, num_iters=25)
        d1, acts1 = sssp(rt, source=0, num_iters=25, fused=True,
                         chunk=chunk)
        np.testing.assert_array_equal(d0, d1)
        # monotone app exits early mid-chunk regardless of the boundary
        assert 0 < len(acts1) < 25
        np.testing.assert_array_equal(np.asarray(acts0)[:len(acts1)],
                                      acts1)

    def test_pagerank_tol_early_exit(self, part):
        """The on-device residual gate stops well before the budget."""
        _, rt = part
        pr_t, acts_t = pagerank(rt, num_iters=50, tol=1e-7)
        pr_f, _ = pagerank(rt, num_iters=50)
        assert len(acts_t) < 50
        # drift from stopping early is bounded by ~tol·d/(1-d)
        assert np.abs(pr_t - pr_f).max() <= 1e-6

    def test_zero_steps_returns_0_by_p(self, part):
        """num_steps=0: (0, p) actives and an untouched state tree."""
        _, rt = part
        spec = build_pagerank(rt)
        for runner in (run_bsp, run_bsp_fused):
            out, acts = runner(spec.superstep, spec.state, spec.static, 0)
            assert acts.shape == (0, rt.p), runner.__name__
            for a, b in zip(jax.tree.leaves(spec.state),
                            jax.tree.leaves(out)):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))

    def test_runner_factory_reuse(self, part):
        """One compiled runner serves many calls and step budgets."""
        _, rt = part
        spec = build_pagerank(rt)
        run = make_fused_runner(spec.superstep, spec.static, chunk=4)
        _, acts5 = run(spec.state, 5)
        _, acts9 = run(spec.state, 9)
        assert acts5.shape == (5, rt.p) and acts9.shape == (9, rt.p)
        np.testing.assert_array_equal(acts9[:5], acts5)


class TestFrontier:
    def test_frontier_cap_bitwise_vs_dense(self, part):
        """A generous cap never drops a message: bitwise == dense."""
        _, rt = part
        for fn, kw in [(sssp, dict(source=0, num_iters=25)),
                       (bfs, dict(source=1, num_iters=25))]:
            dense, _ = fn(rt, backend="scatter", **kw)
            sparse, _ = fn(rt, backend="scatter",
                           frontier_cap=int(rt.vmax), **kw)
            np.testing.assert_array_equal(dense, sparse)

    def test_frontier_entries_counts_live_vertices(self, part):
        """Per-machine live-vertex counts, restricted to valid slots."""
        _, rt = part
        cnt = frontier_entries(rt, np.asarray(rt.vertex_valid))
        np.testing.assert_array_equal(
            cnt, np.asarray(rt.vertex_valid).sum(axis=1))
        assert frontier_entries(
            rt, np.zeros_like(np.asarray(rt.vertex_valid))).sum() == 0

    def test_frontier_cap_validation(self, part):
        _, rt = part
        with pytest.raises(ValueError, match="frontier_cap"):
            sssp(rt, source=0, num_iters=2, backend="scatter",
                 frontier_cap=0)


class TestMessageDtype:
    def test_float32_is_identity(self, part):
        """The default knob must be a bitwise no-op on every backend."""
        _, rt = part
        for backend in ("scatter", "segment"):
            a, _ = pagerank(rt, num_iters=12, backend=backend)
            b, _ = pagerank(rt, num_iters=12, backend=backend,
                            message_dtype="float32")
            np.testing.assert_array_equal(a, b)

    def test_bfloat16_close_and_finite(self, part):
        _, rt = part
        a, _ = pagerank(rt, num_iters=12)
        b, _ = pagerank(rt, num_iters=12, message_dtype="bfloat16")
        assert np.isfinite(b).all()
        assert np.abs(a - b).max() < 1e-2

    def test_unknown_dtype_rejected(self, part):
        _, rt = part
        with pytest.raises(ValueError, match="message_dtype"):
            pagerank(rt, num_iters=2, message_dtype="float64")


class TestExchangeDtypes:
    """min/max identities must be representable in integer dtypes."""

    @pytest.mark.parametrize("dtype", [np.int32, np.float32])
    def test_min_max_roundtrip(self, dtype):
        # two machines; vertex 0 replicated in slot 0, vertex 1 private
        rep_slot = jnp.asarray(np.array([[0, -1], [0, -1]], np.int32))
        vals = jnp.asarray(np.array([[5, 7], [3, 9]], dtype))
        lo = jax.vmap(lambda v, s: exchange(v, s, 1, "min"),
                      axis_name=MACHINES)(vals, rep_slot)
        np.testing.assert_array_equal(np.asarray(lo), [[3, 7], [3, 9]])
        hi = jax.vmap(lambda v, s: exchange(v, s, 1, "max"),
                      axis_name=MACHINES)(vals, rep_slot)
        np.testing.assert_array_equal(np.asarray(hi), [[5, 7], [5, 9]])
        assert np.asarray(hi).dtype == dtype


MESH_FUSED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.bsp import (PartitionRuntime, pagerank, sssp, bfs,
                       connected_components)
from repro.core import scaled_paper_cluster, windgp
from repro.data import rmat

g = rmat(9, seed=2)
cl = scaled_paper_cluster(2, 6, g.num_edges)   # p = 8 machines
r = windgp(g, cl, t0=2)
rt = PartitionRuntime.build(g, r.assign, cl.p)
mesh = jax.make_mesh((8,), ("machines",))

for name, fn, kw in [("pagerank", pagerank, dict(num_iters=10)),
                     ("sssp", sssp, dict(source=0, num_iters=20)),
                     ("bfs", bfs, dict(source=1, num_iters=20)),
                     ("cc", connected_components, dict(num_iters=20))]:
    a, acts_a = fn(rt, mesh=mesh, **kw)
    b, acts_b = fn(rt, mesh=mesh, fused=True, chunk=4, **kw)
    if name == "pagerank":
        np.testing.assert_allclose(a, b, atol=1e-6)
    else:
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(acts_a[:len(acts_b)], acts_b)

_, acts = pagerank(rt, num_iters=50, mesh=mesh, tol=1e-6)
assert len(acts) < 50
print("MESH_FUSED_OK")
"""


def test_fused_sharded_8_devices():
    """Fused while/scan loop under shard_map over a real 8-device mesh."""
    out = subprocess.run(
        [sys.executable, "-c", MESH_FUSED_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"})
    assert "MESH_FUSED_OK" in out.stdout, out.stderr[-2000:]
