"""Semiring Block-ELL SpMV: kernel/ref/dense triangulation + LocalBSR.

The edge-kernel layer's contract: for every semiring, every block size,
and every graph shape (empty, isolated vertices, hubs), the Pallas kernel
(interpret mode), the pure-jnp reference, and the dense numpy oracle all
compute the same ``y = A ⊕.⊗ x`` — and the per-machine blocked adjacency
(``PartitionRuntime.local_bsr``) round-trips ``local_edges`` exactly.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.bsp import PartitionRuntime
from repro.core import scaled_paper_cluster, windgp
from repro.data import rmat, road_mesh
from repro.kernels.bsr_spmv import (SEMIRINGS, BsrMatrix, bsr_from_edges,
                                    bsr_spmv, bsr_spmv_ref, dense_from_bsr,
                                    dense_semiring_mv, get_semiring)

ALL_SEMIRINGS = tuple(SEMIRINGS)


def _operand(rng, n, semiring):
    x = rng.random(n).astype(np.float32)
    if semiring == "or_and":
        return (x > 0.5).astype(np.float32)
    return x


class TestSemiringSpmv:
    @pytest.mark.parametrize("semiring", ALL_SEMIRINGS)
    @pytest.mark.parametrize("block_size", [8, 32, 128])
    def test_kernel_ref_dense_agree(self, semiring, block_size):
        g = rmat(8, seed=1)
        rng = np.random.default_rng(1)
        w = (rng.random(g.num_edges) + 0.1).astype(np.float32)
        m = bsr_from_edges(g.edges, g.num_vertices, values=w,
                           block_size=block_size, semiring=semiring)
        x = _operand(rng, g.num_vertices, semiring)
        y_k = np.asarray(bsr_spmv(m, jnp.asarray(x), interpret=True))
        y_r = np.asarray(bsr_spmv_ref(m, jnp.asarray(x)))
        y_d = dense_semiring_mv(dense_from_bsr(m), x, semiring)
        if semiring == "plus_times":
            np.testing.assert_allclose(y_k, y_r, rtol=1e-5, atol=1e-4)
            np.testing.assert_allclose(y_k, y_d, rtol=1e-5, atol=1e-4)
        else:                       # min/max semirings reassociate exactly
            np.testing.assert_array_equal(y_k, y_r)
            np.testing.assert_array_equal(y_k, y_d)

    @pytest.mark.parametrize("semiring", ALL_SEMIRINGS)
    def test_empty_graph(self, semiring):
        m = bsr_from_edges(np.empty((0, 2), dtype=np.int64), 7,
                           block_size=8, semiring=semiring)
        x = np.ones(7, dtype=np.float32)
        y = np.asarray(bsr_spmv(m, jnp.asarray(x), interpret=True))
        sr = get_semiring(semiring)
        np.testing.assert_array_equal(y, np.full(7, sr.zero,
                                                 dtype=np.float32))

    @pytest.mark.parametrize("semiring", ALL_SEMIRINGS)
    def test_isolated_vertices_get_identity(self, semiring):
        """Rows with no incident edge must hold the ⊕ identity."""
        edges = np.array([[0, 1], [0, 2]])       # vertices 3..9 isolated
        m = bsr_from_edges(edges, 10, block_size=8, semiring=semiring)
        rng = np.random.default_rng(0)
        x = _operand(rng, 10, semiring)
        y = np.asarray(bsr_spmv(m, jnp.asarray(x), interpret=True))
        sr = get_semiring(semiring)
        np.testing.assert_array_equal(y[3:], np.full(7, sr.zero,
                                                     dtype=np.float32))
        y_d = dense_semiring_mv(dense_from_bsr(m), x, semiring)
        np.testing.assert_allclose(y, y_d, rtol=1e-6, atol=1e-6)

    def test_parallel_edges_combine_by_plus(self):
        """Duplicates: sum under (+,×), lightest under (min,+)."""
        edges = np.array([[0, 1], [0, 1]])
        w = np.array([3.0, 5.0], dtype=np.float32)
        m_sum = bsr_from_edges(edges, 2, values=w, block_size=8,
                               semiring="plus_times")
        m_min = bsr_from_edges(edges, 2, values=w, block_size=8,
                               semiring="min_plus")
        assert dense_from_bsr(m_sum)[0, 1] == 8.0
        assert dense_from_bsr(m_min)[0, 1] == 3.0

    @given(st.integers(1, 30), st.integers(0, 1000),
           st.sampled_from(ALL_SEMIRINGS), st.sampled_from([8, 16]))
    @settings(max_examples=15, deadline=None)
    def test_property_random_graphs(self, n_over, seed, semiring, bm):
        n = 3 * n_over                           # deliberately non-multiple
        rng = np.random.default_rng(seed)
        e = rng.integers(0, n, size=(max(1, 2 * n), 2))
        e = e[e[:, 0] != e[:, 1]]
        if len(e) == 0:
            return
        w = (rng.random(len(e)) + 0.05).astype(np.float32)
        m = bsr_from_edges(e, n, values=w, block_size=bm, semiring=semiring)
        x = _operand(rng, n, semiring)
        y_k = np.asarray(bsr_spmv(m, jnp.asarray(x), interpret=True))
        y_d = dense_semiring_mv(dense_from_bsr(m), x, semiring)
        if semiring == "plus_times":
            np.testing.assert_allclose(y_k, y_d, rtol=1e-4, atol=1e-3)
        else:
            np.testing.assert_array_equal(y_k, y_d)

    def test_fill_stats_accounting(self):
        g = road_mesh(8, rewire=0.1, seed=2)
        m = bsr_from_edges(g.edges, g.num_vertices, block_size=16)
        s = m.fill_stats()
        assert 0 < s["block_fill"] <= 1
        assert 0 < s["entry_fill"] <= 1
        # symmetric adjacency: one stored entry per direction
        assert s["nnz"] == 2 * g.num_edges
        assert s["rows"] * 16 >= g.num_vertices

    def test_unknown_semiring_rejected(self):
        with pytest.raises(ValueError, match="unknown semiring"):
            bsr_from_edges(np.array([[0, 1]]), 2, semiring="max_times")


class TestLocalBSR:
    @pytest.fixture(scope="class")
    def rt(self):
        g = rmat(8, seed=2)
        cl = scaled_paper_cluster(2, 4, g.num_edges)
        r = windgp(g, cl, t0=2)
        return PartitionRuntime.build(g, r.assign, cl.p)

    @pytest.mark.parametrize("semiring,weights", [
        ("plus_times", "weight"), ("min_plus", "weight"),
        ("min_plus", "zero"), ("or_and", "unit")])
    def test_round_trip_vs_local_edges(self, rt, semiring, weights):
        """Per machine, dense(blocks) == dense adjacency of local_edges
        under the degree-sorted relabeling — edge-exactly-once, both
        directions, correct weights."""
        b = rt.local_bsr(block_size=16, semiring=semiring, weights=weights)
        sr = get_semiring(semiring)
        for i in range(rt.p):
            m = BsrMatrix(cols=b.cols[i], blocks=b.blocks[i], n=rt.vmax,
                          block_size=16, semiring=semiring)
            d = dense_from_bsr(m)
            ref = np.full((rt.vmax, rt.vmax), sr.absent, dtype=np.float32)
            e = rt.local_edges[i][rt.edge_valid[i]]
            if weights == "weight":
                w = rt.edge_weight[i][rt.edge_valid[i]]
            elif weights == "unit":
                w = np.ones(len(e), dtype=np.float32)
            else:
                w = np.zeros(len(e), dtype=np.float32)
            re_ = b.rank[i][e]
            sr.np_accum_at(ref, (re_[:, 0], re_[:, 1]), w)
            sr.np_accum_at(ref, (re_[:, 1], re_[:, 0]), w)
            np.testing.assert_array_equal(d, ref)

    def test_permutations_are_inverse(self, rt):
        b = rt.local_bsr(block_size=16)
        for i in range(rt.p):
            gather_head = b.gather[i, :rt.vmax]
            np.testing.assert_array_equal(
                b.rank[i][gather_head], np.arange(rt.vmax))
            # gather pads (beyond Vmax) must be in-range x indices
            assert b.gather[i].max() < rt.vmax

    def test_degree_sort_densifies(self, rt):
        """Hubs first: the leading BSR row must not be emptier than the
        trailing one (the relabeling's whole point)."""
        b = rt.local_bsr(block_size=16)
        for s in b.fill_stats:
            assert s["nnz"] > 0
        # stacked shapes agree across machines
        assert b.cols.shape[0] == rt.p
        assert b.blocks.shape[:3] == b.cols.shape
        agg = b.aggregate_fill()
        assert 0 < agg["block_fill"] <= 1

    def test_cache_reuse_and_separation(self, rt):
        a = rt.local_bsr(block_size=16)
        assert rt.local_bsr(block_size=16) is a
        c = rt.local_bsr(block_size=16, semiring="min_plus")
        assert c is not a and c.semiring == "min_plus"
