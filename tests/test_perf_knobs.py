"""The §Perf hillclimb knobs must never change model semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import forward, init_params

KEY = jax.random.PRNGKey(0)


def _logits(cfg, params, inp):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        return jax.jit(lambda p, x: forward(cfg, p, x))(params, inp)


class TestKnobsPreserveSemantics:
    def test_seq_parallel_constraint_is_noop_numerically(self):
        cfg = get_reduced("qwen3-4b")
        params = init_params(cfg, KEY)
        inp = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        base = _logits(cfg, params, inp)
        sp = _logits(dataclasses.replace(cfg, act_shard="seq"), params, inp)
        np.testing.assert_allclose(np.asarray(base), np.asarray(sp),
                                   rtol=1e-5, atol=1e-5)

    def test_moe_ep_constraint_is_noop_numerically(self):
        cfg = get_reduced("phi3.5-moe-42b-a6.6b")
        params = init_params(cfg, KEY)
        inp = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        base = _logits(cfg, params, inp)
        ep = _logits(dataclasses.replace(cfg, moe_ep=True), params, inp)
        np.testing.assert_allclose(np.asarray(base), np.asarray(ep),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("bq,bk", [(4, 8), (16, 16), (64, 32)])
    def test_flash_block_sizes_are_noop(self, bq, bk):
        cfg = get_reduced("glm4-9b")
        params = init_params(cfg, KEY)
        inp = jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size)
        base = forward(cfg, params, inp)
        var = forward(dataclasses.replace(cfg, block_q=bq, block_k=bk),
                      params, inp)
        np.testing.assert_allclose(np.asarray(base), np.asarray(var),
                                   rtol=2e-5, atol=2e-5)
