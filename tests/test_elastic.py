"""Elastic scaling: a checkpoint saved under one mesh restores onto a
different device count/topology (the node-failure / resize path)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced
from repro.models import init_params, forward
from repro.sharding import param_specs
from repro.train import CheckpointManager

cfg = get_reduced("glm4-9b")
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
inp = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
mgr = CheckpointManager("%(dir)s", keep=2)

# "train" on a (4, 2) mesh and checkpoint
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
shapes = jax.eval_shape(lambda: params)
spec_a = param_specs(cfg, shapes, mesh_a)
p_a = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh_a, s), spec_a))
with mesh_a:
    base = np.asarray(jax.jit(lambda p, x: forward(cfg, p, x))(p_a, inp))
mgr.save(1, {"params": p_a})

# "cluster shrinks": restore onto a (2, 2) mesh over 4 devices
mesh_b = jax.make_mesh((2, 2), ("data", "model"),
                       devices=jax.devices()[:4])
spec_b = param_specs(cfg, shapes, mesh_b)
shard_b = jax.tree.map(lambda s: NamedSharding(mesh_b, s), spec_b)
restored, step, _ = mgr.restore(jax.eval_shape(lambda: {"params": params}),
                                shardings={"params": shard_b})
with mesh_b:
    out = np.asarray(jax.jit(lambda p, x: forward(cfg, p, x))(
        restored["params"], inp))
np.testing.assert_allclose(out, base, rtol=2e-5, atol=1e-5)
print("ELASTIC_OK")
"""


def test_elastic_restore_across_meshes(tmp_path):
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT % {"dir": str(tmp_path)}],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"})
    assert "ELASTIC_OK" in out.stdout, out.stderr[-3000:]
