"""Block-stream scorer engine + partitioner registry + chunked IO.

The engine's contract has three layers, each tested here:

* ``block_size=1`` reproduces the per-edge streaming oracles decision for
  decision — bitwise-equal assignments (integer/IEEE-identical arithmetic,
  same first-argmax tie-breaks);
* at production block sizes the invariants hold: every edge placed exactly
  once, memory caps respected, and the light-path PartitionState
  accounting is exact (equal to a from-scratch rebuild after
  ``refresh_costs``);
* the graph-free stream path (``stream_partition`` over
  ``iter_edge_blocks``) makes the same decisions as the in-memory path on
  the same arrival order — ``StreamMembership`` ↔ ``PartitionState``
  cross-check.
"""
import gzip

import numpy as np
import pytest

from repro.core import evaluate, from_edge_list, scaled_paper_cluster
from repro.core import partitioners as registry
from repro.core.baselines import PARTITIONERS
from repro.core.baselines import streaming as S
from repro.core.partition_state import PartitionState, StreamMembership
from repro.data import (canonicalize_block, count_edge_list,
                        iter_edge_blocks, read_edge_list, rmat)

ORACLES = {"greedy": S.powergraph_greedy_oracle,
           "hdrf": S.hdrf_oracle,
           "ebv": S.ebv_oracle}
BLOCKED = {"greedy": S.powergraph_greedy,
           "hdrf": S.hdrf,
           "ebv": S.ebv}


@pytest.fixture(scope="module")
def small():
    g = rmat(8, edge_factor=8, seed=1)
    cl = scaled_paper_cluster(3, 6, g.num_edges, slack=2.0)
    return g, cl


class TestBlockOracleEquivalence:
    @pytest.mark.parametrize("method", sorted(ORACLES))
    def test_block1_bitwise_equals_oracle(self, small, method):
        g, cl = small
        a_blk = BLOCKED[method](g, cl, seed=3, block_size=1)
        a_orc = ORACLES[method](g, cl, seed=3)
        np.testing.assert_array_equal(a_blk, a_orc)

    @pytest.mark.parametrize("method", sorted(ORACLES))
    def test_block1_bitwise_on_random_graph(self, method):
        rng = np.random.default_rng(7)
        g = from_edge_list(rng.integers(0, 60, size=(400, 2)),
                           num_vertices=60)
        cl = scaled_paper_cluster(2, 4, g.num_edges, slack=2.0)
        np.testing.assert_array_equal(
            BLOCKED[method](g, cl, seed=0, block_size=1),
            ORACLES[method](g, cl, seed=0))


class TestBlockInvariants:
    @pytest.mark.parametrize("method", sorted(BLOCKED))
    @pytest.mark.parametrize("block_size", [64, 512, 10 ** 6])
    def test_every_edge_exactly_once_and_caps(self, small, method,
                                              block_size):
        g, cl = small
        a = BLOCKED[method](g, cl, seed=0, block_size=block_size)
        assert a.shape == (g.num_edges,)
        assert a.min() >= 0 and a.max() < cl.p
        assert np.bincount(a, minlength=cl.p).sum() == g.num_edges
        caps = S._caps(cl, g)
        assert np.all(np.bincount(a, minlength=cl.p) <= caps)
        assert evaluate(g, a, cl).feasible

    @pytest.mark.parametrize("method", sorted(BLOCKED))
    @pytest.mark.parametrize("creator_scalar", [False, True])
    def test_light_path_state_is_exact(self, small, method, creator_scalar):
        """Engine-final PartitionState == from-scratch rebuild, bit for
        bit, once the deferred Eq. 4 quantities are refreshed — on both
        the batch light path and the scalar-drain light path
        (``admit_single``)."""
        g, cl = small
        scorer = S.SCORERS[method]()
        state = PartitionState.build(
            g, np.full(g.num_edges, -1, dtype=np.int32), cl)
        caps = S._caps(cl, g)
        order = scorer.stream_order(g, 0)
        if hasattr(scorer, "reset"):
            scorer.reset(g.num_vertices)
        eng = S._BlockEngine(state, scorer, caps, g.num_edges,
                             g.num_vertices, block_size=128, max_waves=3,
                             creator_scalar=creator_scalar)
        eu = g.edges[:, 0].astype(np.int64)
        ev = g.edges[:, 1].astype(np.int64)
        for lo in range(0, len(order), 128):
            blk = order[lo:lo + 128]
            eng.push(eu[blk], ev[blk], blk)
        eng.flush()
        state.refresh_costs()
        ref = PartitionState.build(g, state.assign, cl)
        for field in ("cnt", "edges_per", "verts_per", "t_cal", "t_com",
                      "replicas", "com_sum"):
            np.testing.assert_array_equal(getattr(state, field),
                                          getattr(ref, field), err_msg=field)


class TestCreatorScalar:
    """The EBV speed fix: replica-creating placements drain through the
    exact per-edge path while the non-creating majority stays vectorized
    (the hub_split idiom applied to the wave engine)."""

    @pytest.mark.parametrize("method", sorted(ORACLES))
    def test_block1_bitwise_both_modes(self, small, method):
        """One edge per wave reduces both modes to the oracle's decision
        rule — creating edges via the scalar path, the rest via quota."""
        g, cl = small
        a_orc = ORACLES[method](g, cl, seed=3)
        for cs in (False, True):
            a = BLOCKED[method](g, cl, seed=3, block_size=1,
                                creator_scalar=cs)
            np.testing.assert_array_equal(a, a_orc)

    @pytest.mark.parametrize("block_size", [64, 512])
    def test_invariants_hold(self, small, block_size):
        g, cl = small
        a = S.ebv(g, cl, block_size=block_size, creator_scalar=True)
        assert np.bincount(a, minlength=cl.p).sum() == g.num_edges
        assert np.all(np.bincount(a, minlength=cl.p) <= S._caps(cl, g))

    def test_quality_within_gate_on_proxy(self):
        """The tier-2 promise at unit-test scale: default EBV (creator
        scalar on) stays within 2% TC/RF of the per-edge oracle.  Needs a
        graph big enough that the auto block is a small stream fraction
        (the ``small`` fixture's 1.3k edges make one block 20% of the
        stream — staleness the gate never sees on the real proxies)."""
        g = rmat(10, edge_factor=8, seed=1)
        cl = scaled_paper_cluster(3, 6, g.num_edges, slack=2.0)
        s_orc = evaluate(g, S.ebv_oracle(g, cl), cl)
        s_blk = evaluate(g, S.ebv(g, cl), cl)
        assert (s_blk.tc - s_orc.tc) / s_orc.tc <= 0.02 + 1e-9
        assert (s_blk.rf - s_orc.rf) / s_orc.rf <= 0.02 + 1e-9

    def test_stream_entry_accepts_knob(self, tmp_path, small):
        g, cl = small
        path = tmp_path / "edges.txt"
        np.savetxt(path, g.edges, fmt="%d")
        st = registry.get("ebv").stream(str(path), g.num_vertices,
                                        g.num_edges, cl,
                                        creator_scalar=True)
        assert int(st.edges_per.sum()) == g.num_edges


class TestStreamPath:
    @pytest.mark.parametrize("method", ["greedy", "hdrf"])
    def test_stream_matches_in_memory_on_same_order(self, tmp_path, method):
        """Graph-free StreamMembership path ≡ PartitionState path when both
        consume the identical arrival order."""
        g = rmat(7, edge_factor=6, seed=4)
        cl = scaled_paper_cluster(2, 4, g.num_edges, slack=2.0)
        path = tmp_path / "edges.txt"
        np.savetxt(path, g.edges, fmt="%d")

        order = np.arange(g.num_edges)
        a_mem = S.block_stream_assign(g, cl, S.SCORERS[method](),
                                      block_size=128, seed=0, order=order,
                                      max_waves=3, replica_frac=0.5)

        got = {}
        def sink(edges, ms):
            for (u, v), m in zip(edges.tolist(), ms.tolist()):
                got[(u, v)] = m
        state = S.stream_partition(
            iter_edge_blocks(path, 128), g.num_vertices, g.num_edges, cl,
            method=method, block_size=128, max_waves=3, replica_frac=0.5,
            sink=sink)

        assert len(got) == g.num_edges          # every edge exactly once
        a_stream = np.array([got[(int(u), int(v))] for u, v in g.edges])
        np.testing.assert_array_equal(a_mem, a_stream)
        np.testing.assert_array_equal(
            state.edges_per, np.bincount(a_mem, minlength=cl.p))
        assert state.replication_factor() == pytest.approx(
            evaluate(g, a_mem, cl).rf)

    def test_stream_partition_ebv_runs(self, tmp_path):
        g = rmat(7, edge_factor=6, seed=5)
        cl = scaled_paper_cluster(2, 4, g.num_edges, slack=2.0)
        path = tmp_path / "edges.txt"
        np.savetxt(path, g.edges, fmt="%d")
        placed = []
        S.stream_partition(iter_edge_blocks(path, 256), g.num_vertices,
                           g.num_edges, cl, method="ebv", block_size=256,
                           sink=lambda e, m: placed.append(len(e)))
        assert sum(placed) == g.num_edges


class TestRegistry:
    def test_every_registered_partitioner_round_trips(self, small):
        """Registry round-trip: each method yields a valid assignment."""
        g, cl = small
        for name in registry.names():
            a = registry.get(name)(g, cl)
            assert a.shape == (g.num_edges,), name
            assert a.min() >= 0 and a.max() < cl.p, name

    def test_unknown_knob_raises(self, small):
        g, cl = small
        with pytest.raises(TypeError, match="unknown"):
            registry.get("hdrf")(g, cl, bogus_knob=3)

    def test_unknown_name_raises(self):
        with pytest.raises(KeyError, match="registered"):
            registry.get("nope")

    def test_capability_filters(self):
        blocked = registry.names(require={"blocked"})
        assert set(blocked) == {"greedy", "hdrf", "ebv"}
        assert all(n.endswith("_oracle")
                   for n in registry.names(require={"oracle"}))
        assert "windgp" in registry.names(require={"driver"})

    def test_partitioners_dict_excludes_oracles(self):
        assert not any(n.endswith("_oracle") for n in PARTITIONERS)
        assert {"hash", "dbh", "greedy", "hdrf", "ebv", "ne", "metis",
                "windgp_heap", "windgp_batched"} <= set(PARTITIONERS)

    def test_bsp_runtime_from_partitioner(self, small):
        from repro.bsp.partition_runtime import PartitionRuntime
        g, cl = small
        rt = PartitionRuntime.from_partitioner(g, cl, "dbh")
        assert rt.p == cl.p
        assert int(rt.edges_per_machine.sum()) == g.num_edges


class TestChunkedIO:
    def test_iter_blocks_and_gzip(self, tmp_path):
        edges = np.array([[0, 1], [2, 1], [3, 4], [1, 0], [5, 5], [4, 3]])
        txt = "# comment\n" + "\n".join(f"{u} {v}" for u, v in edges) + "\n"
        plain = tmp_path / "e.txt"
        plain.write_text(txt)
        gz = tmp_path / "e.txt.gz"
        with gzip.open(gz, "wt") as f:
            f.write(txt)
        for path in (plain, gz):
            blocks = list(iter_edge_blocks(path, block_size=3))
            all_edges = np.concatenate(blocks)
            # canonicalized: u<v, no self loops; dedup is per-block only,
            # so the cross-block (1,0) duplicate survives (5 not 4)
            assert (all_edges[:, 0] < all_edges[:, 1]).all()
            assert len(all_edges) == 5
        # whole-file read dedups globally, like from_edge_list
        g = read_edge_list(str(plain))
        ref = from_edge_list(edges)
        np.testing.assert_array_equal(g.edges, ref.edges)

    def test_empty_and_comment_only_files(self, tmp_path):
        empty = tmp_path / "empty.txt"
        empty.write_text("")
        comments = tmp_path / "c.txt"
        comments.write_text("# a\n# b\n\n")
        for path in (empty, comments):
            assert list(iter_edge_blocks(path)) == []
            g = read_edge_list(str(path))
            assert g.num_edges == 0
        assert count_edge_list(empty) == (0, 0)

    def test_malformed_line_raises(self, tmp_path):
        bad = tmp_path / "bad.txt"
        bad.write_text("1 2\n3\n")
        with pytest.raises(ValueError, match="malformed"):
            list(iter_edge_blocks(bad))

    def test_canonicalize_block_matches_from_edge_list(self):
        rng = np.random.default_rng(0)
        edges = rng.integers(0, 20, size=(200, 2))
        blk = canonicalize_block(edges)
        ref = from_edge_list(edges, num_vertices=20)
        # same edge *set* (canonicalize keeps arrival order, graph sorts)
        assert (set(map(tuple, blk.tolist()))
                == set(map(tuple, ref.edges.tolist())))

    def test_count_edge_list(self, tmp_path):
        g = rmat(6, edge_factor=4, seed=9)
        path = tmp_path / "g.txt"
        np.savetxt(path, g.edges, fmt="%d")
        n_v, n_e = count_edge_list(path, block_size=7)
        assert n_e == g.num_edges
        assert n_v == int(g.edges.max()) + 1


class TestExampleCLI:
    @pytest.mark.parametrize("method", ["hdrf", "dbh"])
    def test_partition_edgelist_end_to_end(self, tmp_path, method):
        import importlib.util, pathlib, sys
        spec = importlib.util.spec_from_file_location(
            "partition_edgelist",
            pathlib.Path(__file__).parent.parent / "examples"
            / "partition_edgelist.py")
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        g = rmat(6, edge_factor=4, seed=2)
        path = tmp_path / "edges.txt"
        np.savetxt(path, g.edges, fmt="%d")
        out = tmp_path / "parts"
        assert mod.main([str(path), "--part-method", method,
                         "--num-parts", "4", "--block-size", "64",
                         "--out-dir", str(out)]) == 0
        total = 0
        for i in range(4):
            f = out / f"part{i}.edges"
            assert f.exists()
            lines = [ln for ln in f.read_text().splitlines()
                     if ln and not ln.startswith("#")]
            total += len(lines)
        assert total == g.num_edges
        assert (out / "meta.json").exists()


class TestWaveWindow:
    def test_relative_wave_window_keeps_invariants(self):
        from repro.core import capacities
        from repro.core import expand as exp_mod
        from repro.core import sls as sls_mod
        g = rmat(8, seed=3)
        cl = scaled_paper_cluster(2, 4, g.num_edges, slack=2.0)
        d = capacities(cl, g.num_vertices, g.num_edges)
        assign, orders = exp_mod.run_expansion(
            g, d, 0.25, 0.25, memories=cl.memory(),
            m_node=cl.m_node, m_edge=cl.m_edge, engine="batched")
        obj = PartitionState.build(g, assign, cl)
        sls_mod.repair_edges(obj, np.flatnonzero(assign < 0), orders,
                             wave_frac=0.5, wave_window=0.25)
        assert (obj.assign >= 0).all()
        assert np.all(obj.mem_used_all() <= cl.memory() + 1e-6)
        ref = PartitionState.build(g, obj.assign, cl)
        np.testing.assert_array_equal(obj.t_com, ref.t_com)
