"""Serving path: generation loop, cache reuse, sharding spec units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import abstract_mesh
from repro.configs import get_reduced
from repro.models import decode_step, forward, init_cache, init_params
from repro.serve import generate
from repro.sharding.specs import (_spec_for, logical_batch_spec,
                                  opt_state_specs, param_specs)

KEY = jax.random.PRNGKey(0)


class TestGenerate:
    def test_greedy_matches_forward_argmax(self):
        """Greedy decode must emit argmax(forward) at every position."""
        cfg = get_reduced("qwen3-4b")
        params = init_params(cfg, KEY)
        prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
        out = generate(cfg, params, prompts, max_new_tokens=4)
        assert out.shape == (2, 4)
        # reference: iterative forward over the growing sequence
        seq = prompts
        for t in range(4):
            logits = forward(cfg, params, seq)
            nxt = jnp.argmax(logits[:, -1], axis=-1)
            np.testing.assert_array_equal(np.asarray(out[:, t]),
                                          np.asarray(nxt))
            seq = jnp.concatenate([seq, nxt[:, None]], axis=1)

    def test_generate_ssm_arch(self):
        cfg = get_reduced("mamba2-780m")
        params = init_params(cfg, KEY)
        prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
        out = generate(cfg, params, prompts, max_new_tokens=3)
        assert out.shape == (2, 3)
        assert (np.asarray(out) >= 0).all()

    def test_temperature_sampling_differs(self):
        cfg = get_reduced("glm4-9b")
        params = init_params(cfg, KEY)
        prompts = jax.random.randint(KEY, (4, 8), 0, cfg.vocab_size)
        a = generate(cfg, params, prompts, 8, temperature=2.0,
                     key=jax.random.PRNGKey(1))
        b = generate(cfg, params, prompts, 8, temperature=2.0,
                     key=jax.random.PRNGKey(2))
        assert not np.array_equal(np.asarray(a), np.asarray(b))


class TestShardingSpecs:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_param_specs_cover_tree(self):
        cfg = get_reduced("jamba-v0.1-52b")
        shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = param_specs(cfg, shapes, self._mesh())
        assert jax.tree.structure(specs) == jax.tree.structure(
            shapes, is_leaf=lambda x: hasattr(x, "shape"))

    def test_divisibility_guard(self):
        """40 heads on model=16 must fall back, not crash."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        import jax.sharding as js
        spec = _spec_for("wq", (2, 64, 40, 32), mesh, stacked=True,
                         moe=False, fsdp=False)
        assert isinstance(spec, js.PartitionSpec)

    def test_batch_spec_handles_batch_one(self):
        """B=1 on a real DP axis must replicate (long_500k decode)."""
        mesh = abstract_mesh((2, 16), ("data", "model"))
        assert logical_batch_spec(mesh, 1) == jax.sharding.PartitionSpec(None)
        assert tuple(logical_batch_spec(mesh, 8))[0] in ("data", ("data",))
