"""Edge-kernel backends: cross-backend app equivalence + weighted PageRank.

The refactor's contract: all four BSP apps produce the same results
whichever backend combines their edge messages — bitwise for the
(min, +)/(or, and) apps (min/max reassociate exactly), within 1e-5 for
(+, ×) (the segment path's running sum reassociates float adds) — under
vmap here and under a real 8-device shard_map mesh in the subprocess
test.  Weighted PageRank is pinned against a NetworkX-free dense oracle.
"""
import subprocess
import sys

import numpy as np
import pytest

from repro.bsp import (PartitionRuntime, bfs, build_app, connected_components,
                       get_backend, pagerank, ref, sssp)
from repro.core import scaled_paper_cluster, windgp
from repro.data import rmat

PALLAS_OPTS = {"block_size": 16}
OTHER = (("segment", {}), ("pallas", PALLAS_OPTS))


@pytest.fixture(scope="module")
def part():
    g = rmat(8, seed=2)
    cl = scaled_paper_cluster(2, 4, g.num_edges)
    r = windgp(g, cl, t0=2)
    return g, cl, PartitionRuntime.build(g, r.assign, cl.p)


@pytest.fixture(scope="module")
def weighted_part():
    g = rmat(8, seed=3)
    cl = scaled_paper_cluster(2, 4, g.num_edges)
    r = windgp(g, cl, t0=2)
    w = (np.random.default_rng(5).random(g.num_edges) + 0.1).astype(
        np.float32)
    rt = PartitionRuntime.build(g, r.assign, cl.p, edge_weights=w)
    return g, w, rt


def dense_weighted_pagerank(g, w, num_iters=20, damping=0.85):
    """NetworkX-free oracle: dense weighted adjacency, float64."""
    n = g.num_vertices
    A = np.zeros((n, n))
    np.add.at(A, (g.edges[:, 0], g.edges[:, 1]), w)
    np.add.at(A, (g.edges[:, 1], g.edges[:, 0]), w)
    wdeg = A.sum(axis=1)
    pr = np.full(n, 1.0 / n)
    for _ in range(num_iters):
        msg = np.where(wdeg > 0, pr / np.maximum(wdeg, 1e-300), 0.0)
        pr = (1 - damping) / n + damping * (A @ msg)
    return pr


class TestCrossBackend:
    def test_pagerank_close(self, part):
        _, _, rt = part
        base, _ = pagerank(rt, num_iters=15)
        for be, opts in OTHER:
            got, _ = pagerank(rt, num_iters=15, backend=be, **opts)
            np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)

    def test_sssp_bitwise(self, part):
        _, _, rt = part
        base, _ = sssp(rt, source=0, num_iters=20)
        for be, opts in OTHER:
            got, _ = sssp(rt, source=0, num_iters=20, backend=be, **opts)
            np.testing.assert_array_equal(got, base)

    def test_bfs_bitwise(self, part):
        g, _, rt = part
        base, actives = bfs(rt, source=1, num_iters=20)
        # the (or, and) frontier rewrite still equals the min-plus oracle
        expect = ref.bfs(g, source=1, num_iters=20)
        m = ~np.isinf(expect)
        np.testing.assert_allclose(base[m], expect[m])
        assert actives.sum(axis=1)[-1] == 0
        for be, opts in OTHER:
            got, _ = bfs(rt, source=1, num_iters=20, backend=be, **opts)
            np.testing.assert_array_equal(got, base)

    def test_cc_bitwise(self, part):
        _, _, rt = part
        base, _ = connected_components(rt, num_iters=20)
        for be, opts in OTHER:
            got, _ = connected_components(rt, num_iters=20, backend=be,
                                          **opts)
            np.testing.assert_array_equal(got, base)

    def test_unknown_backend_rejected(self, part):
        _, _, rt = part
        with pytest.raises(ValueError, match="unknown edge-kernel backend"):
            pagerank(rt, num_iters=1, backend="gpu_warp")

    def test_backend_declares_check_rep(self):
        assert get_backend("scatter").check_rep
        assert get_backend("segment").check_rep
        assert not get_backend("pallas").check_rep

    def test_cli_mirror_matches_registry(self):
        """launch.partition's static choices (kept jax-free) == BACKENDS."""
        from repro.bsp import BACKENDS, MESSAGE_DTYPES
        from repro.launch import partition as cli
        assert set(cli.EDGE_BACKENDS) == set(BACKENDS)
        assert set(cli.MESSAGE_DTYPES) == set(MESSAGE_DTYPES)

    def test_build_app_specs(self, part):
        _, _, rt = part
        for app in ("pagerank", "sssp", "bfs", "cc"):
            spec = build_app(rt, app, backend="segment")
            assert spec.name in (app, "sssp")
            assert "eb_seg_out" in spec.static
        with pytest.raises(ValueError, match="unknown BSP app"):
            build_app(rt, "betweenness")


class TestWeightedPageRank:
    def test_matches_dense_oracle(self, weighted_part):
        """The `edge_weight`-vs-`edge_valid` bug regression: weights must
        actually scale messages and the degree normalizer."""
        g, w, rt = weighted_part
        got, _ = pagerank(rt, num_iters=20)
        expect = dense_weighted_pagerank(g, w, num_iters=20)
        np.testing.assert_allclose(got, expect, rtol=2e-4, atol=1e-8)
        # weights genuinely change the answer (guards a silent all-ones)
        uniform = ref.pagerank(g, num_iters=20)
        assert np.abs(got - uniform).max() > 1e-4

    def test_weighted_across_backends(self, weighted_part):
        _, _, rt = weighted_part
        base, _ = pagerank(rt, num_iters=15)
        for be, opts in OTHER:
            got, _ = pagerank(rt, num_iters=15, backend=be, **opts)
            np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)

    def test_default_unit_weights_unchanged(self, part):
        """No weights supplied == the classic uniform-split PageRank."""
        g, _, rt = part
        got, _ = pagerank(rt, num_iters=15)
        np.testing.assert_allclose(got, ref.pagerank(g, num_iters=15),
                                   rtol=2e-4)

    def test_weighted_degree_field(self, weighted_part):
        g, w, rt = weighted_part
        wdeg = np.zeros(g.num_vertices)
        np.add.at(wdeg, g.edges[:, 0], w)
        np.add.at(wdeg, g.edges[:, 1], w)
        for i in range(rt.p):
            m = rt.vertex_valid[i]
            np.testing.assert_allclose(
                rt.weighted_degree[i, m],
                wdeg[rt.local_vertex_gid[i, m]], rtol=1e-6)


MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.bsp import (PartitionRuntime, pagerank, sssp, bfs,
                       connected_components)
from repro.core import scaled_paper_cluster, windgp
from repro.data import rmat

g = rmat(9, seed=2)
cl = scaled_paper_cluster(2, 6, g.num_edges)   # p = 8 machines = 8 devices
r = windgp(g, cl, t0=2)
rt = PartitionRuntime.build(g, r.assign, cl.p)
mesh = jax.make_mesh((8,), ("machines",))
pr0, _ = pagerank(rt, num_iters=8)
d0, _ = sssp(rt, source=0, num_iters=12)
b0, _ = bfs(rt, source=1, num_iters=12)
c0, _ = connected_components(rt, num_iters=12)
for be, kw in (("scatter", {}), ("segment", {}),
               ("pallas", {"block_size": 32})):
    pr, _ = pagerank(rt, num_iters=8, mesh=mesh, backend=be, **kw)
    np.testing.assert_allclose(pr, pr0, rtol=1e-5, atol=1e-5)
    d, _ = sssp(rt, source=0, num_iters=12, mesh=mesh, backend=be, **kw)
    np.testing.assert_array_equal(d, d0)
    b, _ = bfs(rt, source=1, num_iters=12, mesh=mesh, backend=be, **kw)
    np.testing.assert_array_equal(b, b0)
    c, _ = connected_components(rt, num_iters=12, mesh=mesh, backend=be,
                                **kw)
    np.testing.assert_array_equal(c, c0)
print("MULTIDEV_BACKENDS_OK")
"""


def test_backends_on_8_device_mesh():
    """Every backend under a real shard_map mesh == the vmap scatter
    reference — including Pallas through ``check_rep=False``."""
    out = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "MULTIDEV_BACKENDS_OK" in out.stdout, out.stderr[-2000:]
