"""Fused k-hop sampling, the feature halo cache, and the prefetch
pipeline.

The contracts pinned here:

* the fused single-dispatch k-hop path is **bitwise** identical to the
  hop-at-a-time reference loop — ids and per-hop stats, both replacement
  modes (this also pins the fused ``top_k`` selection against the
  reference argsort lowering, and the device-side dedup count against
  host ``np.unique``);
* ``FeatureStore`` shards by owner and ``gather`` through any cache
  state is bitwise equal to the uncached ``gather_global``;
* ``HaloCache``: LRU eviction order is exactly
  least-recently-used-first, the hub tier is never evicted, and hub hits
  leave the LRU order untouched;
* ``PrefetchPipeline`` yields bitwise-identical ``(batch, features)``
  streams — and identical cumulative cache stats — at every depth,
  propagates worker exceptions to the consumer, and shuts down cleanly
  mid-iteration.
"""
import threading

import jax
import numpy as np
import pytest

from repro.bsp import PartitionRuntime
from repro.core import scaled_paper_cluster
from repro.core import partitioners as registry
from repro.data import rmat
from repro.sampling import (FeatureStore, HaloCache, PrefetchPipeline,
                            SamplingService)


@pytest.fixture(scope="module")
def svc():
    g = rmat(8, edge_factor=8, seed=3)
    cl = scaled_paper_cluster(2, 4, g.num_edges)
    assign = registry.get("hdrf")(g, cl)
    return SamplingService(
        PartitionRuntime.create(g, assign=assign, cluster=cl))


@pytest.fixture(scope="module")
def store(svc):
    rng = np.random.default_rng(0)
    feats = rng.standard_normal(
        (svc.csc.num_vertices, 8)).astype(np.float32)
    return FeatureStore.build(svc, feats), feats


def _assert_minibatch_equal(a, b):
    assert np.array_equal(a.seeds, b.seeds)
    assert len(a.hops) == len(b.hops)
    for ha, hb in zip(a.hops, b.hops):
        assert np.array_equal(ha, hb)
    assert a.hop_stats == b.hop_stats


class TestFusedParity:
    @pytest.mark.parametrize("replace", [False, True])
    def test_fused_bitwise_equals_loop(self, svc, replace):
        s = SamplingService(svc.csc, fanouts=(6, 4, 3), replace=replace)
        key = jax.random.PRNGKey(9)
        seeds = s.local_seeds(0, 24, key)
        a = s.sample(seeds, jax.random.fold_in(key, 1), home=0,
                     fused=True)
        b = s.sample(seeds, jax.random.fold_in(key, 1), home=0,
                     fused=False)
        _assert_minibatch_equal(a, b)
        # stats equality above also pins the device-side dedup count
        # (sort + adjacent difference) against the loop's np.unique
        assert any(st.fetched_unique > 0 for st in a.hop_stats)

    def test_fused_parity_without_home(self, svc):
        key = jax.random.PRNGKey(3)
        seeds = svc.local_seeds(1, 16, key)
        a = svc.sample(seeds, jax.random.fold_in(key, 2), fused=True)
        b = svc.sample(seeds, jax.random.fold_in(key, 2), fused=False)
        _assert_minibatch_equal(a, b)
        assert all(st.halo == 0 and st.fetched_unique == 0
                   for st in a.hop_stats)

    def test_all_ids_layout(self, svc):
        key = jax.random.PRNGKey(5)
        seeds = svc.local_seeds(0, 8, key)
        mb = svc.sample(seeds, jax.random.fold_in(key, 1), home=0)
        ids = mb.all_ids()
        assert len(ids) == len(seeds) + sum(h.size for h in mb.hops)
        assert np.array_equal(ids[:len(seeds)], seeds)


class TestFeatureStore:
    def test_shards_match_owner_map(self, svc, store):
        fs, feats = store
        csc = svc.csc
        for i in range(csc.p):
            n = int(csc.owned_per[i])
            assert fs.shards[i].shape == (n, 8)
            assert np.array_equal(fs.shards[i],
                                  feats[csc.owned_gid[i, :n]])

    def test_gather_global_matches_raw(self, svc, store):
        fs, feats = store
        ids = np.array([-1, 0, 5, 5, 17], np.int64)
        got = fs.gather_global(ids)
        assert np.all(got[0] == 0)
        for j, v in enumerate(ids):
            if v >= 0 and svc.csc.owner[v] >= 0:
                assert np.array_equal(got[j], feats[v])

    def test_gather_bitwise_equals_uncached_any_cache_state(self, svc,
                                                            store):
        fs, _ = store
        key = jax.random.PRNGKey(7)
        cache = HaloCache.for_home(fs, 0, capacity=64, hub_frac=0.5)
        for b in range(4):      # evolving cache state across batches
            k_seed, k_hop = jax.random.split(jax.random.fold_in(key, b))
            seeds = svc.local_seeds(0, 32, k_seed)
            mb = svc.sample(seeds, k_hop, home=0)
            got, st = fs.gather(mb.all_ids(), 0, cache)
            assert np.array_equal(got, fs.gather_global(mb.all_ids()))
            bound = sum(s.fetched_unique for s in mb.hop_stats)
            assert st.misses <= bound

    def test_build_validates_shape(self, svc):
        with pytest.raises(ValueError, match="num_vertices"):
            FeatureStore.build(svc, np.zeros((3, 2), np.float32))


class TestHaloCache:
    def test_lru_eviction_order(self):
        c = HaloCache(capacity=3)
        rows = {v: np.full(2, v, np.float32) for v in range(5)}
        for v in (0, 1, 2):
            c.insert(v, rows[v])
        assert c.lru_ids() == [0, 1, 2]
        c.lookup(0)                       # refresh 0 -> 1 is now LRU
        assert c.lru_ids() == [1, 2, 0]
        c.insert(3, rows[3])              # evicts 1
        assert c.lru_ids() == [2, 0, 3]
        assert 1 not in c and c.evictions == 1
        c.insert(4, rows[4])              # evicts 2
        assert c.lru_ids() == [0, 3, 4]
        assert c.evictions == 2

    def test_hub_tier_never_evicted(self):
        hub_rows = np.arange(4, dtype=np.float32).reshape(2, 2)
        c = HaloCache(capacity=4, hub_ids=[10, 11], hub_rows=hub_rows)
        assert c.lru_capacity == 2
        for v in range(20, 40):           # churn far past capacity
            c.insert(v, np.zeros(2, np.float32))
        assert 10 in c and 11 in c
        assert np.array_equal(c.lookup(10), hub_rows[0])
        assert len(c.lru_ids()) == 2

    def test_hub_hit_does_not_touch_lru_order(self):
        c = HaloCache(capacity=3, hub_ids=[99],
                      hub_rows=np.zeros((1, 2), np.float32))
        c.insert(1, np.zeros(2, np.float32))
        c.insert(2, np.zeros(2, np.float32))
        c.lookup(99)
        assert c.lru_ids() == [1, 2]

    def test_validation(self):
        with pytest.raises(ValueError, match="capacity"):
            HaloCache(capacity=-1)
        with pytest.raises(ValueError, match="exceed"):
            HaloCache(capacity=1, hub_ids=[1, 2],
                      hub_rows=np.zeros((2, 2)))
        with pytest.raises(ValueError, match="hub_rows"):
            HaloCache(capacity=4, hub_ids=[1, 2])

    def test_for_home_picks_remote_top_degree(self, svc, store):
        fs, _ = store
        c = HaloCache.for_home(fs, 0, capacity=8, hub_frac=1.0)
        gdeg = fs.global_degree()
        owner = svc.csc.owner
        assert len(c.hub_ids) == 8
        assert all(owner[v] >= 0 and owner[v] != 0 for v in c.hub_ids)
        remote = np.flatnonzero((owner >= 0) & (owner != 0))
        floor = gdeg[c.hub_ids].min()
        assert (gdeg[remote] > floor).sum() < 8   # nothing hotter missed


def _stream(svc, store_pair, depth, num_batches=5, budget=48,
            with_store=True):
    fs, _ = store_pair
    cache = HaloCache.for_home(fs, 0, capacity=budget) if with_store \
        else None
    with PrefetchPipeline(svc, home=0, batch_size=16,
                          num_batches=num_batches,
                          key=jax.random.PRNGKey(13), depth=depth,
                          store=fs if with_store else None,
                          cache=cache) as pl:
        out = list(pl)
    stats = (cache.hits, cache.misses, cache.evictions) if with_store \
        else None
    return out, stats


class TestPrefetchPipeline:
    @pytest.mark.parametrize("depth", [1, 4])
    def test_bitwise_deterministic_at_every_depth(self, svc, store,
                                                  depth):
        (sync, st0), (deep, std) = (_stream(svc, store, 0),
                                    _stream(svc, store, depth))
        assert len(sync) == len(deep) == 5
        for (ma, fa), (mb, fb) in zip(sync, deep):
            _assert_minibatch_equal(ma, mb)
            assert np.array_equal(fa, fb)
        assert st0 == std     # same cache hit/miss/evict sequence

    def test_no_store_yields_none_features(self, svc, store):
        out, _ = _stream(svc, store, 2, with_store=False)
        assert all(f is None for _, f in out)

    def test_worker_exception_propagates(self, svc, store):
        fs, _ = store

        class Boom(RuntimeError):
            pass

        pl = PrefetchPipeline(svc, home=0, batch_size=16, num_batches=6,
                              key=jax.random.PRNGKey(1), depth=2,
                              store=fs)

        def explode(mb):
            raise Boom("feature stage died")

        pl._resolve_features = explode
        with pytest.raises(Boom, match="feature stage died"):
            list(pl)
        assert not any(t.is_alive() for t in pl._threads or [])

    def test_mid_iteration_shutdown(self, svc, store):
        fs, _ = store
        pl = PrefetchPipeline(svc, home=0, batch_size=16, num_batches=50,
                              key=jax.random.PRNGKey(2), depth=2,
                              store=fs)
        next(pl)
        next(pl)
        pl.close()
        assert not any(t.name.startswith("prefetch-")
                       for t in threading.enumerate())
        with pytest.raises(StopIteration):
            next(pl)

    def test_validation(self, svc, store):
        fs, _ = store
        with pytest.raises(ValueError, match="depth"):
            PrefetchPipeline(svc, home=0, batch_size=4, num_batches=1,
                             key=jax.random.PRNGKey(0), depth=-1)
        with pytest.raises(ValueError, match="without store"):
            PrefetchPipeline(svc, home=0, batch_size=4, num_batches=1,
                             key=jax.random.PRNGKey(0),
                             cache=HaloCache(capacity=4))
