"""Unified incremental PartitionState layer: batch↔scalar exactness and
SLS destroy–repair / repartition invariants.

Costs in the paper's machine quantification are integral, so every
quantity PartitionState maintains is an integer-valued float64 — the
batch recount path and the scalar incremental path must therefore agree
*bit for bit*, not just within tolerance.  The clusters built here keep
integer costs to exercise exactly that.
"""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import (Cluster, GrowableGraph, Machine, capacities,
                        evaluate, from_edge_list, scaled_paper_cluster)
from repro.core import expand as exp_mod
from repro.core import sls as sls_mod
from repro.core.partition_state import (PartitionState, WorkingCSR, cumcount,
                                        edge_incidence_counts,
                                        t_com_from_membership)
from repro.data import rmat


def random_graph(rng, v_max=40):
    V = int(rng.integers(6, v_max))
    E = int(rng.integers(V, V * 4))
    return from_edge_list(rng.integers(0, V, size=(E, 2)), num_vertices=V)


def int_cluster(rng, p, num_edges):
    """Integer-cost cluster with enough memory slack to stay feasible."""
    machines = tuple(
        Machine(memory=float(rng.integers(2 * num_edges, 6 * num_edges)),
                c_node=float(rng.integers(0, 8)),
                c_edge=float(rng.integers(1, 16)),
                c_com=float(rng.integers(1, 16)))
        for _ in range(p))
    return Cluster(machines=machines)


def random_state(rng, p=4, v_max=40):
    g = random_graph(rng, v_max)
    cl = int_cluster(rng, p, g.num_edges)
    assign = rng.integers(0, p, size=g.num_edges).astype(np.int32)
    return g, cl, assign


def assert_states_equal(a: PartitionState, b: PartitionState, exact=True):
    eq = (np.testing.assert_array_equal if exact
          else np.testing.assert_allclose)
    np.testing.assert_array_equal(a.assign, b.assign)
    np.testing.assert_array_equal(a.cnt, b.cnt)
    np.testing.assert_array_equal(a.replicas, b.replicas)
    eq(a.com_sum, b.com_sum)
    eq(a.edges_per, b.edges_per)
    eq(a.verts_per, b.verts_per)
    eq(a.t_cal, b.t_cal)
    eq(a.t_com, b.t_com)


class TestBuild:
    @given(st.integers(0, 2 ** 31))
    @settings(max_examples=25, deadline=None)
    def test_build_matches_evaluate(self, seed):
        """Vectorized Eq. 3/4 (one masked matmul) == the metric reference."""
        rng = np.random.default_rng(seed)
        g, cl, assign = random_state(rng)
        obj = PartitionState.build(g, assign, cl)
        ref = evaluate(g, assign, cl)
        np.testing.assert_array_equal(obj.t_cal, ref.t_cal)
        np.testing.assert_array_equal(obj.t_com, ref.t_com)
        assert obj.tc == ref.tc

    def test_t_com_from_membership_matches_loop(self):
        rng = np.random.default_rng(0)
        p, V = 5, 30
        member = rng.random((p, V)) < 0.3
        c_com = rng.integers(1, 9, size=p).astype(np.float64)
        replicas = member.sum(axis=0)
        com_sum = member.T.astype(np.float64) @ c_com
        ref = np.zeros(p)
        for i in range(p):           # the pre-vectorization reference
            vs = member[i]
            ref[i] = ((replicas[vs] - 1) * c_com[i]
                      + (com_sum[vs] - c_com[i])).sum()
        got = t_com_from_membership(member, replicas, com_sum, c_com)
        np.testing.assert_array_equal(got, ref)

    def test_edge_incidence_counts(self):
        g = from_edge_list(np.array([[0, 1], [1, 2], [2, 3]]))
        cnt = edge_incidence_counts(g, np.array([0, 0, 1]), 2)
        assert cnt[0].tolist() == [1, 2, 1, 0]
        assert cnt[1].tolist() == [0, 0, 1, 1]


class TestBatchOps:
    @given(st.integers(0, 2 ** 31))
    @settings(max_examples=25, deadline=None)
    def test_remove_add_batch_bitwise_equals_scalar(self, seed):
        rng = np.random.default_rng(seed)
        g, cl, assign = random_state(rng)
        k = int(rng.integers(1, max(2, g.num_edges // 2)))
        es = rng.choice(g.num_edges, size=k, replace=False)
        ms = rng.integers(0, cl.p, size=k)
        a = PartitionState.build(g, assign, cl)
        b = PartitionState.build(g, assign, cl)
        for e in es.tolist():
            a.remove_edge(e)
        b.remove_edges(es)
        assert_states_equal(a, b)
        for e, m in zip(es.tolist(), ms.tolist()):
            a.add_edge(e, m)
        b.add_edges(es, ms)
        assert_states_equal(a, b)

    @given(st.integers(0, 2 ** 31))
    @settings(max_examples=25, deadline=None)
    def test_delta_t_and_mem_batch_equal_scalar(self, seed):
        rng = np.random.default_rng(seed)
        g, cl, assign = random_state(rng)
        k = int(rng.integers(1, max(2, g.num_edges // 3)))
        es = rng.choice(g.num_edges, size=k, replace=False)
        obj = PartitionState.build(g, assign, cl)
        obj.remove_edges(es)
        T = obj.delta_t_batch(es)
        M = obj.mem_after_batch(es)
        assert T.shape == M.shape == (k, cl.p)
        for j, e in enumerate(es.tolist()):
            for i in range(cl.p):
                assert T[j, i] == obj.delta_t_if_added(e, i), (j, i)
                assert M[j, i] == obj.mem_after(e, i), (j, i)

    def test_mem_used_all(self):
        rng = np.random.default_rng(3)
        g, cl, assign = random_state(rng)
        obj = PartitionState.build(g, assign, cl)
        np.testing.assert_array_equal(
            obj.mem_used_all(),
            np.array([obj.mem_used(i) for i in range(cl.p)]))


class TestWorkingCSR:
    def test_view_compacts_to_live_adjacency(self):
        g = rmat(8, seed=1)
        alive = np.ones(g.num_edges, dtype=bool)
        rng = np.random.default_rng(0)
        dead = rng.choice(g.num_edges, size=int(0.8 * g.num_edges),
                          replace=False)
        alive[dead] = False
        w = WorkingCSR.from_graph(g)
        indptr, indices, eids = w.view(alive, int(alive.sum()))
        assert len(eids) == 2 * int(alive.sum())    # compaction triggered
        for v in range(g.num_vertices):             # order-preserving slices
            sl = slice(g.indptr[v], g.indptr[v + 1])
            keep = alive[g.edge_ids[sl]]
            np.testing.assert_array_equal(
                indices[indptr[v]:indptr[v + 1]], g.indices[sl][keep])
            np.testing.assert_array_equal(
                eids[indptr[v]:indptr[v + 1]], g.edge_ids[sl][keep])

    def test_view_no_compaction_when_mostly_live(self):
        g = rmat(7, seed=2)
        w = WorkingCSR.from_graph(g)
        called = []

        def live():
            called.append(1)
            return np.ones(g.num_edges, dtype=bool)

        indptr, indices, eids = w.view(live, g.num_edges)
        assert not called                  # lazy mask never materialized
        assert indices is g.indices

    def test_partition_state_working_csr(self):
        rng = np.random.default_rng(5)
        g, cl, assign = random_state(rng, v_max=30)
        # mostly assigned ⇒ few live (unassigned) edges ⇒ compaction fires
        assign[rng.random(g.num_edges) < 0.1] = -1
        obj = PartitionState.build(g, assign, cl)
        indptr, indices, eids = obj.working_csr()
        live = np.flatnonzero(assign < 0)
        assert sorted(np.unique(eids).tolist()) == sorted(live.tolist())


def test_cumcount():
    a = np.array([3, 1, 3, 3, 1, 0])
    assert cumcount(a).tolist() == [0, 0, 1, 2, 1, 0]


class TestMutationHardening:
    """The mutation paths reject malformed batches with ValueError — a bad
    dynamic stream must fail loudly, not desync the incremental state
    (bare asserts vanish under ``python -O``)."""

    @pytest.fixture()
    def obj(self):
        rng = np.random.default_rng(11)
        g, cl, assign = random_state(rng)
        return PartitionState.build(g, assign, cl)

    def test_remove_rejects_duplicate_ids(self, obj):
        with pytest.raises(ValueError, match="duplicate"):
            obj.remove_edges(np.array([0, 1, 0]))

    def test_remove_rejects_unassigned(self, obj):
        obj.remove_edges(np.array([2]))
        with pytest.raises(ValueError, match="unassigned"):
            obj.remove_edges(np.array([2]))
        with pytest.raises(ValueError, match="unassigned"):
            obj.remove_edge(2)

    def test_add_rejects_shape_mismatch(self, obj):
        obj.remove_edges(np.array([0, 1]))
        with pytest.raises(ValueError, match="edge ids vs"):
            obj.add_edges(np.array([0, 1]), np.array([0]))

    def test_add_rejects_duplicate_ids(self, obj):
        obj.remove_edges(np.array([0]))
        with pytest.raises(ValueError, match="duplicate"):
            obj.add_edges(np.array([0, 0]), np.array([0, 1]))

    def test_add_rejects_machine_out_of_range(self, obj):
        p = obj.cluster.p
        obj.remove_edges(np.array([0]))
        with pytest.raises(ValueError, match="machine"):
            obj.add_edges(np.array([0]), np.array([p]))
        with pytest.raises(ValueError, match="machine"):
            obj.add_edge(0, -1)

    def test_add_rejects_already_assigned(self, obj):
        with pytest.raises(ValueError, match="assigned"):
            obj.add_edges(np.array([0]), np.array([0]))
        with pytest.raises(ValueError, match="assigned"):
            obj.add_edge(0, 0)

    def test_rejected_batch_leaves_state_untouched(self, obj):
        ref = PartitionState.build(obj.g, obj.assign, obj.cluster)
        with pytest.raises(ValueError):
            obj.remove_edges(np.array([0, 0]))
        assert_states_equal(obj, ref)

    def test_append_requires_growable_graph(self, obj):
        with pytest.raises(ValueError, match="growable"):
            obj.append_edges(np.array([[0, 1]]))


class TestInterleavedMutation:
    """Satellite invariant of the dynamic layer: ANY interleaving of
    remove / re-add / append leaves cnt, t_cal, t_com, and verts_per
    bit-identical to a fresh ``PartitionState.build`` over the final
    graph + assignment."""

    @given(st.integers(0, 2 ** 31))
    @settings(max_examples=15, deadline=None)
    def test_any_interleaving_matches_fresh_build(self, seed):
        rng = np.random.default_rng(seed)
        g, cl, assign = random_state(rng)
        gg = GrowableGraph.from_graph(g)
        obj = PartitionState.build(gg, assign, cl)
        for _ in range(8):
            op = int(rng.integers(0, 3))
            if op == 0:                         # retire some live edges
                live = np.flatnonzero(obj.assign >= 0)
                if not len(live):
                    continue
                k = int(rng.integers(1, len(live) + 1))
                obj.remove_edges(rng.choice(live, size=k, replace=False))
            elif op == 1:                       # re-admit retired edges
                dead = np.flatnonzero(obj.assign < 0)
                if not len(dead):
                    continue
                k = int(rng.integers(1, len(dead) + 1))
                es = rng.choice(dead, size=k, replace=False)
                obj.add_edges(es, rng.integers(0, cl.p, size=k))
            else:                               # append brand-new pairs
                V = gg.num_vertices
                raw = rng.integers(0, V + 2, size=(8, 2))
                u = np.minimum(raw[:, 0], raw[:, 1])
                v = np.maximum(raw[:, 0], raw[:, 1])
                keep = u != v
                u, v = u[keep], v[keep]
                _, first = np.unique((u << np.int64(32)) | v,
                                     return_index=True)
                u, v = u[first], v[first]
                new = gg.eids_of(u, v) < 0
                if not new.any():
                    continue
                eids = obj.append_edges(np.stack([u[new], v[new]], axis=1))
                obj.add_edges(eids, rng.integers(0, cl.p, size=len(eids)))
        fresh = PartitionState.build(gg, obj.assign, cl)
        assert_states_equal(obj, fresh)
        assert obj.tc == fresh.tc


# ---------------------------------------------------------------------------
# SLS invariants on the new layer
# ---------------------------------------------------------------------------

def scalar_destroy_repair_reference(obj, orders, gamma, theta):
    """PR-1's per-edge destroy–repair sweep, kept verbatim as the oracle."""
    tc_before = obj.tc
    t = obj.t_total
    thd = t.min() + gamma * (t.max() - t.min())
    removed, seen = [], set()
    for i in range(obj.cluster.p):
        if t[i] < thd - 1e-12 or obj.edges_per[i] == 0:
            continue
        k = max(1, int(np.ceil(theta * obj.edges_per[i])))
        stack = orders[i]
        take = []
        while stack and len(take) < k:
            e = stack.pop()
            if obj.assign[e] == i and e not in seen:
                take.append(e)
                seen.add(e)
        for e in take:
            obj.remove_edge(e)
        removed.extend(take)
    for e in removed:
        u, v = obj.g.edges[e]
        a_u = np.flatnonzero(obj.cnt[:, u] > 0)
        a_v = np.flatnonzero(obj.cnt[:, v] > 0)
        both = np.intersect1d(a_u, a_v)
        either = np.union1d(a_u, a_v)
        i = -1
        if len(both):
            i = sls_mod.balanced_greedy_repair(obj, e, both)
        if i < 0 and len(either):
            i = sls_mod.balanced_greedy_repair(obj, e, either)
        if i < 0:
            i = sls_mod.balanced_greedy_repair(obj, e, range(obj.cluster.p))
        if i < 0:
            free = obj.cluster.memory() - obj.mem_used_all()
            i = int(np.argmax(free))
        obj.add_edge(e, i)
        orders[i].append(e)
    return obj.tc < tc_before - 1e-9


def expanded_state(seed, scale=9):
    g = rmat(scale, seed=seed)
    cl = scaled_paper_cluster(2, 4, g.num_edges, slack=2.0)
    d = capacities(cl, g.num_vertices, g.num_edges)
    assign, orders = exp_mod.run_expansion(
        g, d, 0.25, 0.25, memories=cl.memory(),
        m_node=cl.m_node, m_edge=cl.m_edge, engine="batched")
    obj = PartitionState.build(g, assign, cl)
    sls_mod.repair_edges(obj, np.flatnonzero(assign < 0), orders)
    return g, cl, obj, orders


class TestDestroyRepair:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_strict_flag_bitwise_equals_scalar_oracle(self, seed):
        """``strict=True`` reproduces the per-edge oracle decision for
        decision — same assignment, same incremental state, bit for bit
        (like the expansion engine's ``strict_ties``)."""
        g, cl, obj_a, orders_a = expanded_state(seed)
        obj_b = PartitionState.build(g, obj_a.assign, cl)
        orders_b = [list(o) for o in orders_a]
        for _ in range(3):
            ra = sls_mod.destroy_repair(obj_a, orders_a, 0.8, 0.05, None,
                                        strict=True)
            rb = scalar_destroy_repair_reference(obj_b, orders_b, 0.8, 0.05)
            assert ra == rb
        assert orders_a == orders_b
        assert_states_equal(obj_a, obj_b)

    @pytest.mark.parametrize("strict", [False, True])
    @pytest.mark.parametrize("seed", [3, 4])
    def test_invariants_after_sweeps(self, seed, strict):
        """Edge-exactly-once, memory caps, incremental == rebuilt."""
        g, cl, obj, orders = expanded_state(seed)
        for _ in range(4):
            sls_mod.destroy_repair(obj, orders, 0.9, 0.05, None,
                                   strict=strict)
        assert (obj.assign >= 0).all()
        assert np.bincount(obj.assign, minlength=cl.p).sum() == g.num_edges
        assert np.all(obj.mem_used_all() <= cl.memory() + 1e-6)
        assert_states_equal(obj, PartitionState.build(g, obj.assign, cl))

    def test_vectorized_tc_close_to_scalar(self):
        """The wave approximation stays within 2% of the oracle's TC."""
        gaps = []
        for seed in range(4):
            g, cl, obj_a, orders_a = expanded_state(seed, scale=10)
            obj_b = PartitionState.build(g, obj_a.assign, cl)
            orders_b = [list(o) for o in orders_a]
            for _ in range(4):
                sls_mod.destroy_repair(obj_a, orders_a, 0.9, 0.03, None,
                                       strict=False)
                sls_mod.destroy_repair(obj_b, orders_b, 0.9, 0.03, None,
                                       strict=True)
            gaps.append((obj_a.tc - obj_b.tc) / obj_b.tc)
        assert float(np.mean(gaps)) < 0.02, gaps

    def test_no_per_edge_python_loop_on_hot_path(self):
        """The default repair path never calls the scalar per-edge kernel."""
        g, cl, obj, orders = expanded_state(5)
        calls = []
        orig = sls_mod._repair_edge_scalar
        sls_mod._repair_edge_scalar = (
            lambda *a, **k: calls.append(1) or orig(*a, **k))
        try:
            sls_mod.destroy_repair(obj, orders, 0.9, 0.05, None)
        finally:
            sls_mod._repair_edge_scalar = orig
        assert not calls


class TestRepartition:
    @pytest.mark.parametrize("engine", ["heap", "batched"])
    def test_invariants_after_repartition(self, engine):
        g, cl, obj, orders = expanded_state(6)
        deltas = capacities(cl, g.num_vertices, g.num_edges)
        new = sls_mod.repartition(obj, orders, deltas, k=3,
                                  alpha=0.25, beta=0.25, engine=engine)
        assert (new.assign >= 0).all()
        assert np.bincount(new.assign, minlength=cl.p).sum() == g.num_edges
        flat = [e for o in orders for e in o]
        assert np.all(new.assign[np.asarray(flat, dtype=int)] >= 0)
        assert_states_equal(new, PartitionState.build(g, new.assign, cl))


class TestSLSDriver:
    @pytest.mark.parametrize("repair", ["vectorized", "scalar"])
    def test_sls_never_worsens(self, repair):
        g = rmat(9, seed=7)
        cl = scaled_paper_cluster(2, 4, g.num_edges, slack=2.0)
        d = capacities(cl, g.num_vertices, g.num_edges)
        assign, orders = exp_mod.run_expansion(
            g, d, 0.25, 0.25, memories=cl.memory(),
            m_node=cl.m_node, m_edge=cl.m_edge, engine="batched")
        obj = PartitionState.build(g, assign, cl)
        sls_mod.repair_edges(obj, np.flatnonzero(assign < 0), orders)
        tc0 = obj.tc
        _, best_tc = sls_mod.sls(g, obj.assign, cl, orders, d,
                                 t0=6, repair=repair, engine="batched")
        assert best_tc <= tc0 + 1e-9
