"""In-group q-head padding (pad_group_to): exact semantics + zero-grad pads."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import forward, init_params
from repro.models.layers import head_pad_mask

KEY = jax.random.PRNGKey(0)


def _scatter_params(cfg_pad, params):
    """Rearrange unpadded attention weights into the padded slot layout."""
    Hp, H, KVH = (cfg_pad.num_heads_padded, cfg_pad.num_heads,
                  cfg_pad.num_kv_heads)
    g, P = H // KVH, Hp // KVH
    real = (np.arange(KVH)[:, None] * P + np.arange(g)[None, :]).reshape(-1)

    def fix(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name == "wq" and leaf.ndim == 4:        # (n_super, d, H, hd)
            out = jnp.zeros(leaf.shape[:2] + (Hp, leaf.shape[3]), leaf.dtype)
            return out.at[:, :, real, :].set(leaf)
        if name == "wo" and leaf.ndim == 4:        # (n_super, H, hd, d)
            out = jnp.zeros((leaf.shape[0], Hp) + leaf.shape[2:], leaf.dtype)
            return out.at[:, real, :, :].set(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, params)


def test_mask_layout():
    cfg = dataclasses.replace(get_reduced("qwen3-14b"), pad_group_to=6)
    # reduced qwen3-14b: 5 heads, 1 kv head -> G=5, padded to 6
    m = np.asarray(head_pad_mask(cfg))
    assert m.shape == (6,)
    assert m.tolist() == [1, 1, 1, 1, 1, 0]


def test_padded_forward_matches_unpadded():
    cfg = get_reduced("qwen3-14b")          # 5 heads, kv=1 (G=5)
    cfg_pad = dataclasses.replace(cfg, pad_group_to=6)
    params = init_params(cfg, KEY)
    params_pad = _scatter_params(cfg_pad, params)
    inp = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    base = forward(cfg, params, inp)
    padded = forward(cfg_pad, params_pad, inp)
    np.testing.assert_allclose(np.asarray(padded), np.asarray(base),
                               rtol=2e-5, atol=2e-5)


def test_pad_slots_receive_zero_grads():
    cfg = dataclasses.replace(get_reduced("qwen3-14b"), pad_group_to=6)
    params = init_params(cfg, KEY)
    inp = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    labels = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)

    def loss(p):
        lg = forward(cfg, p, inp).astype(jnp.float32)
        return -jnp.take_along_axis(jax.nn.log_softmax(lg),
                                    labels[..., None], -1).mean()

    grads = jax.grad(loss)(params)
    mask = np.asarray(head_pad_mask(cfg))
    pad_slots = np.flatnonzero(mask == 0)
    gq = np.asarray(grads["blocks"]["pos0"]["mixer"]["wq"], np.float32)
    go = np.asarray(grads["blocks"]["pos0"]["mixer"]["wo"], np.float32)
    assert np.abs(gq[:, :, pad_slots, :]).max() == 0.0
    assert np.abs(go[:, pad_slots, :, :]).max() == 0.0
