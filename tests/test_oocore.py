"""Two-pass out-of-core dedup + streamed-assignment BSP hand-off.

Four contracts, each tested here:

* ``TwoPassDedup`` equals ``read_edge_list``'s exact in-memory dedup bit
  for bit (gzip and plain, empty/comment-only, and a duplicate-heavy
  adversarial list that defeats per-block dedup), yields edges in global
  first-occurrence order, and its ``SpillStats`` accounting bounds peak
  edge residency by the spill knobs — never by the edge-set size;
* ``stream_partition(dedup="two_pass")`` makes the same decisions as the
  in-memory block engine consuming the identical deduplicated stream;
* ``StreamAssignment`` round-trips through disk, verifies its shards
  before publishing ``meta.json`` (atomically), and
  ``PartitionRuntime.from_stream`` packs the same runtime arrays as the
  in-memory ``build`` for the same assignment;
* the example CLI runs partition→PageRank end to end on a
  never-materialized list, with the spill accounting in its meta.
"""
import gzip
import importlib.util
import json
import pathlib

import numpy as np
import pytest

from repro.bsp import PartitionRuntime, StreamAssignment, pagerank, ref
from repro.core import (evaluate, evaluate_membership, from_edge_list,
                        scaled_paper_cluster)
from repro.core import partitioners as registry
from repro.core.baselines import streaming as S
from repro.data import (TwoPassDedup, iter_edge_blocks, read_edge_list,
                        rmat, two_pass_dedup)


def _dup_heavy_file(tmp_path, *, gz=False, seed=0, n_hot=40, repeats=25,
                    n_unique=500, id_range=160):
    """Edge list whose duplicates span far-apart blocks: ``n_hot`` edges
    repeated ``repeats`` times, interleaved with unique edges — a small
    dedup window (per-block dedup) misses almost every repeat."""
    rng = np.random.default_rng(seed)
    hot = rng.integers(0, id_range // 4, size=(n_hot, 2))
    uniq = rng.integers(0, id_range, size=(n_unique, 2))
    chunks = []
    step = max(1, n_unique // repeats)
    for i in range(repeats):
        chunks.append(hot)
        chunks.append(uniq[i * step:(i + 1) * step])
    rows = np.concatenate(chunks)
    path = tmp_path / ("edges.txt.gz" if gz else "edges.txt")
    txt = "# adversarial\n" + "\n".join(f"{u} {v}" for u, v in rows) + "\n"
    if gz:
        with gzip.open(path, "wt") as f:
            f.write(txt)
    else:
        path.write_text(txt)
    return path


def _first_occurrence_order(path, block_size):
    """Reference: canonicalized first-occurrence (u, v) sequence."""
    seen, order = set(), []
    for blk in iter_edge_blocks(path, block_size):
        for u, v in blk.tolist():
            if (u, v) not in seen:
                seen.add((u, v))
                order.append((u, v))
    return order


class TestTwoPassDedup:
    @pytest.mark.parametrize("gz", [False, True], ids=["plain", "gzip"])
    def test_round_trip_equals_read_edge_list(self, tmp_path, gz):
        """Spill/restore == in-memory exact dedup, bit for bit."""
        path = _dup_heavy_file(tmp_path, gz=gz)
        with TwoPassDedup(path, block_size=64, bucket_rows=128,
                          merge_rows=32) as tp:
            streamed = np.concatenate(
                list(tp) + [np.empty((0, 2), dtype=np.int64)])
            ref_g = read_edge_list(str(path))
            assert tp.num_edges == ref_g.num_edges == len(streamed)
            got = from_edge_list(streamed, num_vertices=tp.num_vertices)
            np.testing.assert_array_equal(got.edges, ref_g.edges)
            np.testing.assert_array_equal(got.indptr, ref_g.indptr)
            np.testing.assert_array_equal(got.indices, ref_g.indices)

    def test_first_occurrence_order(self, tmp_path):
        path = _dup_heavy_file(tmp_path)
        with TwoPassDedup(path, block_size=64, bucket_rows=128,
                          merge_rows=32) as tp:
            streamed = [tuple(r) for b in tp for r in b.tolist()]
        assert streamed == _first_occurrence_order(path, 64)

    def test_blocks_respect_block_size(self, tmp_path):
        path = _dup_heavy_file(tmp_path)
        with TwoPassDedup(path, block_size=37, bucket_rows=64) as tp:
            assert all(len(b) <= 37 for b in tp)

    def test_empty_and_comment_only(self, tmp_path):
        empty = tmp_path / "empty.txt"
        empty.write_text("")
        comments = tmp_path / "c.txt"
        comments.write_text("# a\n# b\n\n")
        for path in (empty, comments):
            with TwoPassDedup(path) as tp:
                assert tp.prepare() == (0, 0)
                assert list(tp) == []

    def test_adversarial_defeats_per_block_dedup(self, tmp_path):
        """Per-block dedup leaves the cross-block repeats in; the spill
        layer removes every one of them out of core."""
        path = _dup_heavy_file(tmp_path)
        per_block = sum(len(b) for b in iter_edge_blocks(path, 64))
        with TwoPassDedup(path, block_size=64, bucket_rows=128) as tp:
            st = tp.stats
            assert tp.num_edges == read_edge_list(str(path)).num_edges
            assert per_block > 1.5 * tp.num_edges     # block dedup defeated
            assert st.duplicate_rows == st.spilled_rows - tp.num_edges
            assert st.duplicate_rows > 0

    def test_bucket_accounting_bounds_residency(self, tmp_path):
        """The out-of-core guarantee, via the accounting: peak resident
        rows are bounded by the spill knobs (io block, largest bucket,
        merge buffers), not by the stream size."""
        path = _dup_heavy_file(tmp_path, repeats=40, n_unique=2000,
                               id_range=700)
        bucket_rows, merge_rows, block = 128, 16, 64
        with TwoPassDedup(path, block_size=block, bucket_rows=bucket_rows,
                          merge_rows=merge_rows) as tp:
            n = sum(len(b) for b in tp)
            st = tp.stats
            assert n == tp.num_edges
            bound = max(block, st.max_bucket_rows,
                        2 * st.num_buckets * merge_rows)
            assert st.peak_resident_rows <= bound
            # and the bound is far below both the raw and deduped stream
            assert st.peak_resident_rows < 0.5 * st.spilled_rows
            assert st.num_buckets >= 2
            assert st.max_bucket_rows < 0.5 * st.spilled_rows

    def test_reiterable_and_close(self, tmp_path):
        path = _dup_heavy_file(tmp_path)
        tp = two_pass_dedup(str(path), block_size=64, bucket_rows=128)
        a = np.concatenate(list(tp))
        b = np.concatenate(list(tp))
        np.testing.assert_array_equal(a, b)
        spill = tp.spill_dir
        assert spill.exists()
        tp.close()
        assert not spill.exists()


class TestStreamTwoPass:
    @pytest.mark.parametrize("method", ["greedy", "hdrf"])
    def test_matches_in_memory_on_deduplicated_stream(self, tmp_path,
                                                      method):
        """``stream_partition(dedup="two_pass")`` == the in-memory block
        engine consuming the identical deduplicated stream (the acceptance
        criterion): same per-edge machines, same totals, same RF."""
        path = _dup_heavy_file(tmp_path, seed=3)
        with TwoPassDedup(path, block_size=64, bucket_rows=128) as tp:
            streamed = np.concatenate(list(tp))
            got = {}

            def sink(edges, ms):
                for (u, v), m in zip(edges.tolist(), ms.tolist()):
                    got[(u, v)] = m

            state = S.stream_partition(
                tp, cluster=scaled_paper_cluster(2, 4, tp.num_edges,
                                                 slack=2.0),
                method=method, block_size=128, max_waves=3,
                replica_frac=0.5, dedup="two_pass", sink=sink)
        g = from_edge_list(streamed, num_vertices=state.cnt.shape[1])
        cl = scaled_paper_cluster(2, 4, g.num_edges, slack=2.0)
        # map the stream's arrival order onto canonical edge ids
        key_sorted = (g.edges[:, 0].astype(np.int64) * g.num_vertices
                      + g.edges[:, 1])
        key_stream = (streamed[:, 0] * g.num_vertices + streamed[:, 1])
        order = np.searchsorted(key_sorted, key_stream)
        a_mem = S.block_stream_assign(g, cl, S.SCORERS[method](),
                                      block_size=128, order=order,
                                      max_waves=3, replica_frac=0.5)
        assert len(got) == g.num_edges
        a_stream = np.array([got[(int(u), int(v))] for u, v in g.edges])
        np.testing.assert_array_equal(a_mem, a_stream)
        np.testing.assert_array_equal(
            state.edges_per, np.bincount(a_mem, minlength=cl.p))
        mem_stats = evaluate(g, a_mem, cl)
        stream_stats = evaluate_membership(state.cnt > 0, state.edges_per,
                                           cl)
        assert stream_stats.tc == pytest.approx(mem_stats.tc)
        assert stream_stats.rf == pytest.approx(mem_stats.rf)

    def test_two_pass_needs_a_path(self):
        cl = scaled_paper_cluster(1, 2, 100)
        blocks = iter([np.array([[0, 1]])])
        with pytest.raises(ValueError, match="re-readable"):
            S.stream_partition(blocks, 2, 1, cl, dedup="two_pass")

    def test_unknown_dedup_rejected(self, tmp_path):
        path = _dup_heavy_file(tmp_path)
        cl = scaled_paper_cluster(1, 2, 100)
        with pytest.raises(ValueError, match="dedup"):
            S.stream_partition(str(path), cluster=cl, dedup="exactly")

    def test_path_source_counts_itself(self, tmp_path):
        path = _dup_heavy_file(tmp_path)
        cl = scaled_paper_cluster(2, 4, 1000, slack=2.0)
        state = S.stream_partition(str(path), cluster=cl, method="hdrf",
                                   block_size=128)
        # single-pass mode: per-block dedup only, duplicates counted twice
        assert state.edges_per.sum() == sum(
            len(b) for b in iter_edge_blocks(path, 128))
        assert state.spill_stats is None

    def test_registry_stream_surface(self, tmp_path):
        assert set(registry.names(require={"streamable"})) == \
            {"greedy", "hdrf", "ebv"}
        path = _dup_heavy_file(tmp_path)
        part = registry.get("hdrf")
        cl = scaled_paper_cluster(2, 4, 1000, slack=2.0)
        state = part.stream(str(path), cluster=cl, dedup="two_pass")
        assert state.spill_stats is not None
        assert state.edges_per.sum() == read_edge_list(
            str(path)).num_edges
        with pytest.raises(TypeError, match="unknown"):
            part.stream(str(path), cluster=cl, bogus=1)
        with pytest.raises(TypeError, match="cannot stream"):
            registry.get("ne").stream(str(path), cluster=cl)


@pytest.fixture()
def streamed_assignment(tmp_path):
    """A finalized StreamAssignment + the matching in-memory reference."""
    path = _dup_heavy_file(tmp_path, seed=7)
    with TwoPassDedup(path, block_size=64, bucket_rows=128) as tp:
        streamed = np.concatenate(list(tp))
        cl = scaled_paper_cluster(2, 4, tp.num_edges, slack=2.0)
        sa = StreamAssignment(tmp_path / "assign", cl.p, tp.num_vertices)
        got = {}

        def sink(edges, ms):
            sa.sink(edges, ms)
            for (u, v), m in zip(edges.tolist(), ms.tolist()):
                got[(u, v)] = m

        state = S.stream_partition(tp, cluster=cl, method="hdrf",
                                   block_size=128, sink=sink)
    sa.finalize(state, {"method": "hdrf"})
    g = from_edge_list(streamed, num_vertices=tp.num_vertices)
    assign = np.array([got[(int(u), int(v))] for u, v in g.edges],
                      dtype=np.int32)
    return sa, g, assign, cl


class TestStreamAssignment:
    def test_round_trips_through_disk(self, streamed_assignment, tmp_path):
        sa, g, assign, cl = streamed_assignment
        sb = StreamAssignment.open(tmp_path / "assign")
        np.testing.assert_array_equal(sb.membership(), sa.membership())
        np.testing.assert_array_equal(sb.degree, sa.degree)
        np.testing.assert_array_equal(sb.edges_per, sa.edges_per)
        # shard contents are exactly each machine's edge set
        for i in range(sb.p):
            want = g.edges[assign == i]
            rows = sb.machine_edges(i)
            assert sorted(map(tuple, rows.tolist())) == \
                sorted(map(tuple, want.tolist()))
        # degrees match the deduplicated graph's degrees
        np.testing.assert_array_equal(sa.degree, g.degree())

    def test_finalize_verifies_shards(self, tmp_path):
        sa = StreamAssignment(tmp_path / "a", 2, 4)
        sa.sink(np.array([[0, 1], [2, 3]]), np.array([0, 1]))
        sa.edges_per[0] += 1           # simulate a lost write
        member = np.zeros((2, 4), dtype=bool)
        member[0, :2] = member[1, 2:] = True
        with pytest.raises(IOError, match="short-flushed"):
            sa.finalize(member)
        assert not (tmp_path / "a" / "meta.json").exists()
        assert not (tmp_path / "a" / "meta.json.tmp").exists()

    def test_finalize_cross_checks_membership(self, tmp_path):
        sa = StreamAssignment(tmp_path / "a", 2, 4)
        sa.sink(np.array([[0, 1]]), np.array([0]))
        member = np.zeros((2, 4), dtype=bool)
        member[1, 3] = True            # claims a vertex no edge touched
        with pytest.raises(ValueError, match="membership disagrees"):
            sa.finalize(member)

    def test_open_requires_finalize(self, tmp_path):
        sa = StreamAssignment(tmp_path / "a", 2, 4)
        sa.sink(np.array([[0, 1]]), np.array([0]))
        with pytest.raises(FileNotFoundError, match="meta.json"):
            StreamAssignment.open(tmp_path / "a")


class TestFromStream:
    def test_matches_in_memory_build(self, streamed_assignment):
        """from_stream packs the same runtime as build() for the same
        assignment: identical vertex tables, edge sets, replica slots."""
        sa, g, assign, cl = streamed_assignment
        rt_s = PartitionRuntime.from_stream(sa)
        rt_m = PartitionRuntime.build(g, assign, cl.p)
        assert rt_s.p == rt_m.p
        assert rt_s.num_replicas == rt_m.num_replicas
        assert rt_s.vmax == rt_m.vmax
        assert rt_s.emax == rt_m.emax
        for f in ("local_vertex_gid", "vertex_valid", "global_degree",
                  "rep_slot"):
            np.testing.assert_array_equal(getattr(rt_s, f),
                                          getattr(rt_m, f), err_msg=f)
        np.testing.assert_array_equal(rt_s.verts_per_machine,
                                      rt_m.verts_per_machine)
        np.testing.assert_array_equal(rt_s.edges_per_machine,
                                      rt_m.edges_per_machine)
        # edge shards arrive in admission order, build in edge-id order —
        # same per-machine edge sets in local coordinates
        for i in range(rt_s.p):
            gids_s = rt_s.local_vertex_gid[i][
                rt_s.local_edges[i][rt_s.edge_valid[i]]]
            gids_m = rt_m.local_vertex_gid[i][
                rt_m.local_edges[i][rt_m.edge_valid[i]]]
            assert sorted(map(tuple, gids_s.tolist())) == \
                sorted(map(tuple, gids_m.tolist()))

    def test_pagerank_on_streamed_runtime(self, streamed_assignment):
        sa, g, _, _ = streamed_assignment
        rt = PartitionRuntime.from_stream(sa)
        pr, _ = pagerank(rt, num_iters=25)
        np.testing.assert_allclose(pr, ref.pagerank(g, num_iters=25),
                                   atol=1e-5)


def _load_example():
    spec = importlib.util.spec_from_file_location(
        "partition_edgelist",
        pathlib.Path(__file__).parent.parent / "examples"
        / "partition_edgelist.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestExampleTwoPassPipeline:
    def test_partition_pagerank_end_to_end(self, tmp_path):
        """--two-pass --pagerank on a duplicate-heavy list: the acceptance
        pipeline, spill accounting included."""
        mod = _load_example()
        path = _dup_heavy_file(tmp_path, seed=11)
        out = tmp_path / "parts"
        assert mod.main([str(path), "--part-method", "hdrf",
                         "--num-parts", "4", "--block-size", "64",
                         "--bucket-rows", "128", "--two-pass",
                         "--pagerank", "--pagerank-iters", "10",
                         "--out-dir", str(out)]) == 0
        meta = json.loads((out / "meta.json").read_text())
        n_exact = read_edge_list(str(path)).num_edges
        assert meta["dedup"] == "two_pass"
        assert meta["num_edges"] == n_exact
        # the text shards hold every edge exactly once
        total = sum(
            len([ln for ln in (out / f"part{i}.edges").read_text()
                 .splitlines() if ln and not ln.startswith("#")])
            for i in range(4))
        assert total == n_exact
        # spill accounting rode along and bounds the residency
        spill = meta["spill"]
        assert spill["duplicate_rows"] > 0
        assert spill["peak_resident_rows"] <= max(
            64, spill["max_bucket_rows"],
            2 * spill["num_buckets"] * 8192)
        assert spill["peak_resident_rows"] < spill["spilled_rows"]
        # runtime hand-off artifact is complete and loadable
        sa = StreamAssignment.open(out / "assignment")
        assert int(sa.edges_per.sum()) == n_exact
        assert not (out / "meta.json.tmp").exists()

    def test_block_mode_still_works(self, tmp_path):
        mod = _load_example()
        g = rmat(6, edge_factor=4, seed=2)
        path = tmp_path / "edges.txt"
        np.savetxt(path, g.edges, fmt="%d")
        out = tmp_path / "parts"
        assert mod.main([str(path), "--part-method", "greedy",
                         "--num-parts", "4", "--block-size", "64",
                         "--out-dir", str(out)]) == 0
        meta = json.loads((out / "meta.json").read_text())
        assert meta["dedup"] == "block"
        assert meta["num_edges"] == g.num_edges
        assert "spill" not in meta

    def test_two_pass_rejects_in_memory_methods(self, tmp_path):
        mod = _load_example()
        path = _dup_heavy_file(tmp_path)
        with pytest.raises(SystemExit):
            mod.main([str(path), "--part-method", "ne", "--two-pass",
                      "--out-dir", str(tmp_path / "x")])
