"""Per-architecture smoke tests (reduced configs): one forward + one train
step on CPU asserting output shapes and no NaNs; prefill/decode consistency
against the training forward pass; MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.models import (active_param_count, decode_step, forward,
                          init_cache, init_params, param_count)

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B, S, key=KEY):
    if cfg.input_mode == "tokens":
        return jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    cfg = get_reduced(request.param)
    params = init_params(cfg, KEY)
    return request.param, cfg, params


class TestSmoke:
    def test_forward_shapes_and_finite(self, arch_setup):
        name, cfg, params = arch_setup
        B, S = 2, 16
        logits = forward(cfg, params, _inputs(cfg, B, S))
        assert logits.shape == (B, S, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), f"{name}: NaN/inf in logits"

    def test_train_step_no_nans(self, arch_setup):
        name, cfg, params = arch_setup
        B, S = 2, 16
        inp = _inputs(cfg, B, S)
        labels = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)

        def loss_fn(p):
            lg = forward(cfg, p, inp).astype(jnp.float32)
            lp = jax.nn.log_softmax(lg, axis=-1)
            return -jnp.take_along_axis(lp, labels[..., None], -1).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert bool(jnp.isfinite(loss))
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in flat), \
            f"{name}: NaN in grads"
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in flat))
        assert float(gnorm) > 0

    def test_prefill_decode_matches_forward(self, arch_setup):
        name, cfg, params = arch_setup
        B, S, P = 2, 12, 8
        inp = _inputs(cfg, B, S)
        logits = forward(cfg, params, inp)
        cache = init_cache(cfg, B, 24)
        lg, cache = decode_step(cfg, params, cache, inp[:, :P],
                                jnp.zeros(B, jnp.int32))
        scale = float(jnp.abs(logits).max())
        assert float(jnp.abs(lg - logits[:, :P]).max()) / scale < 1e-4
        lens = jnp.full((B,), P, jnp.int32)
        for t in range(P, S):
            step_in = inp[:, t:t + 1]
            lg, cache = decode_step(cfg, params, cache, step_in, lens)
            err = float(jnp.abs(lg[:, 0] - logits[:, t]).max()) / scale
            assert err < 1e-4, f"{name}: decode diverges at t={t}: {err}"
            lens = lens + 1


class TestFullConfigs:
    """The FULL configs are exercised via eval_shape only (no allocation)."""

    @pytest.mark.parametrize("name", ARCHS)
    def test_full_config_param_shapes(self, name):
        cfg = get_config(name)
        shapes = jax.eval_shape(
            lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        assert n > 1e8, f"{name}: implausibly small full model ({n})"

    def test_param_counts_match_names(self):
        # plausibility bands around the sizes the model names advertise
        bands = {
            "mamba2-780m": (0.6e9, 1.0e9),
            "glm4-9b": (7e9, 12e9),
            "qwen3-4b": (3e9, 5.5e9),
            "minicpm3-4b": (3e9, 6e9),
            "qwen3-14b": (11e9, 17e9),
            "granite-moe-3b-a800m": (2e9, 4.5e9),
            "phi3.5-moe-42b-a6.6b": (33e9, 50e9),
            "jamba-v0.1-52b": (40e9, 60e9),
            "musicgen-medium": (1e9, 2.5e9),
            "paligemma-3b": (2e9, 3.7e9),
        }
        for name, (lo, hi) in bands.items():
            n = param_count(get_config(name))
            assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params out of band"

    def test_moe_active_params_smaller(self):
        for name in ["granite-moe-3b-a800m", "phi3.5-moe-42b-a6.6b",
                     "jamba-v0.1-52b"]:
            cfg = get_config(name)
            assert active_param_count(cfg) < 0.6 * param_count(cfg)


class TestMoEInvariants:
    def test_router_distributes_tokens(self):
        cfg = get_reduced("phi3.5-moe-42b-a6.6b")
        params = init_params(cfg, KEY)
        from repro.models import layers as L
        x = jax.random.normal(KEY, (4, 32, cfg.d_model), jnp.float32)
        blk = jax.tree.map(lambda a: a[0], params["blocks"]["pos0"])
        out = L.moe_ffn(cfg, blk["ffn"], x)
        assert out.shape == x.shape
        assert bool(jnp.isfinite(out).all())

    def test_moe_permutation_invariance(self):
        """Token order must not change per-token outputs (no drops here)."""
        cfg = get_reduced("granite-moe-3b-a800m")
        params = init_params(cfg, KEY)
        from repro.models import layers as L
        blk = jax.tree.map(lambda a: a[0], params["blocks"]["pos0"])
        x = jax.random.normal(KEY, (1, 16, cfg.d_model), jnp.float32)
        out1 = L.moe_ffn(cfg, blk["ffn"], x)
        perm = jax.random.permutation(KEY, 16)
        out2 = L.moe_ffn(cfg, blk["ffn"], x[:, perm])
        np.testing.assert_allclose(np.asarray(out2), np.asarray(out1[:, perm]),
                                   rtol=2e-4, atol=1e-5)
