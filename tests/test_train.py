"""Training substrate: optimizer, train step, checkpointing, compression,
heterogeneous batch split, WindGP expert placement."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.sharding.windgp_placement import (coactivation_graph,
                                             place_experts, placement_cost)
from repro.train import (CheckpointManager, adamw_init, adamw_update,
                         compress_grads, dequantize_int8,
                         heterogeneous_batch_split, make_train_step,
                         quantize_int8)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=4, S=16, key=KEY):
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


class TestTrainStep:
    def test_loss_decreases(self):
        cfg = get_reduced("qwen3-4b")
        params = init_params(cfg, KEY)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg, lr=3e-3, remat=False))
        batch = _batch(cfg)
        losses = []
        for _ in range(8):
            params, opt, metrics = step(params, opt, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses

    def test_remat_matches_no_remat(self):
        cfg = get_reduced("glm4-9b")
        params = init_params(cfg, KEY)
        batch = _batch(cfg)
        opt = adamw_init(params)
        s1 = jax.jit(make_train_step(cfg, remat=False))
        s2 = jax.jit(make_train_step(cfg, remat=True))
        _, _, m1 = s1(params, opt, batch)
        _, _, m2 = s2(params, opt, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)

    def test_microbatching_matches_full_batch(self):
        cfg = get_reduced("qwen3-4b")
        params = init_params(cfg, KEY)
        opt = adamw_init(params)
        batch = _batch(cfg, B=4)
        s1 = jax.jit(make_train_step(cfg, microbatches=1, remat=False))
        s2 = jax.jit(make_train_step(cfg, microbatches=2, remat=False))
        p1, _, m1 = s1(params, opt, batch)
        p2, _, m2 = s2(params, opt, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)
        # same optimizer update (up to accumulation-order float noise)
        l1, l2 = jax.tree.leaves(p1), jax.tree.leaves(p2)
        for a, b in zip(l1, l2):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-4)

    def test_compressed_training_still_converges(self):
        cfg = get_reduced("qwen3-4b")
        params = init_params(cfg, KEY)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg, lr=3e-3, remat=False,
                                       compress="int8"))
        batch = _batch(cfg)
        losses = []
        for _ in range(8):
            params, opt, metrics = step(params, opt, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses


class TestCompression:
    def test_int8_roundtrip_error_bounded(self):
        x = jax.random.normal(KEY, (1024,), jnp.float32)
        q, s = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, s) - x).max()
        assert float(err) <= float(s) * 0.5 + 1e-9

    def test_small_tensors_exact(self):
        g = {"tiny": jnp.array([1.234567]), "big": jnp.ones((64, 64)) * 0.37}
        out = compress_grads(g)
        np.testing.assert_array_equal(np.asarray(out["tiny"]),
                                      np.asarray(g["tiny"]))


class TestCheckpoint:
    def test_save_restore_bitwise(self, tmp_path):
        cfg = get_reduced("glm4-9b")
        params = init_params(cfg, KEY)
        opt = adamw_init(params)
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state = {"params": params, "opt": opt}
        mgr.save(3, state, extra={"data_cursor": 1234, "rng": [0, 7]})
        restored, step, extra = mgr.restore(jax.eval_shape(lambda: state))
        assert step == 3 and extra["data_cursor"] == 1234
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_last_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state = {"x": jnp.arange(4)}
        for s in [1, 2, 3, 4]:
            mgr.save(s, state)
        assert mgr.all_steps() == [3, 4]

    def test_kill_and_resume_training(self, tmp_path):
        """Train 4 steps; 'crash'; resume from step 2; states match a
        continuous 4-step run bitwise."""
        cfg = get_reduced("qwen3-4b")
        params = init_params(cfg, KEY)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg, lr=1e-3, remat=False))
        batches = [_batch(cfg, key=jax.random.fold_in(KEY, i))
                   for i in range(4)]
        mgr = CheckpointManager(str(tmp_path), keep=3)
        # continuous run, checkpointing at step 2
        p, o = params, opt
        for i, b in enumerate(batches):
            p, o, _ = step(p, o, b)
            if i == 1:
                mgr.save(i + 1, {"params": p, "opt": o})
        # crash + resume
        restored, at, _ = mgr.restore(
            jax.eval_shape(lambda: {"params": params, "opt": opt}))
        p2, o2 = restored["params"], restored["opt"]
        for b in batches[at:]:
            p2, o2, _ = step(p2, o2, b)
        for a, b_ in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))

    def test_no_partial_checkpoint_visible(self, tmp_path):
        """Atomicity: directory only ever contains complete step dirs."""
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(1, {"x": jnp.ones((128, 128))})
        entries = [e for e in os.listdir(tmp_path) if not e.startswith(".")]
        assert entries == ["step_0000000001"]
        files = os.listdir(tmp_path / "step_0000000001")
        assert set(files) == {"arrays.npz", "manifest.json"}


class TestHeterogeneousBatch:
    def test_faster_pods_get_more(self):
        split = heterogeneous_batch_split(256, [1.0, 1.0, 0.5])
        assert split.sum() == 256
        assert split[2] > split[0] == split[1]
        # water-filling: cost-balanced => c_i * b_i ~ const
        assert abs(split[2] * 0.5 - split[0] * 1.0) <= 1.0

    def test_memory_clamp(self):
        split = heterogeneous_batch_split(256, [1.0, 0.25],
                                          pod_mem_samples=[256, 64])
        assert split.sum() == 256
        assert split[1] == 64          # fast pod clamped by HBM
        assert split[0] == 192

    def test_infeasible_raises(self):
        with pytest.raises(ValueError):
            heterogeneous_batch_split(256, [1.0, 1.0],
                                      pod_mem_samples=[16, 16])


class TestExpertPlacement:
    def _routing(self, E=16, toks=400, hot=4, seed=0):
        rng = np.random.default_rng(seed)
        # hot experts co-activate: tokens pick 2 experts, biased to hot set
        a = rng.choice(hot, size=(toks // 2, 1))
        b = rng.choice(hot, size=(toks // 2, 1))
        cold = rng.choice(np.arange(hot, E), size=(toks - toks // 2, 2))
        return np.concatenate([np.concatenate([a, b], 1), cold], 0)

    def test_coactivation_graph(self):
        r = self._routing()
        edges, w, loads = coactivation_graph(r)
        assert loads.sum() == r.size
        assert (w > 0).all()

    def test_placement_beats_round_robin(self):
        E = 16
        r = self._routing(E=E)
        compute = [1.0, 1.0, 2.0]       # pod 2 slower
        mem = [8, 8, 8]
        link = [1.0, 1.0, 1.0]
        place = place_experts(E, r, compute, mem, link)
        assert place.shape == (E,)
        assert all(np.bincount(place, minlength=3) <= np.array(mem) + 1)
        rr = np.arange(E) % 3
        assert placement_cost(place, r, compute, link) <= \
            placement_cost(rr, r, compute, link)
