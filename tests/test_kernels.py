"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.data import rmat, road_mesh
from repro.kernels.bsr_spmv import (bsr_from_edges, bsr_spmv, bsr_spmv_ref,
                                    dense_from_bsr)
from repro.kernels.decode_attn import decode_attention, decode_attention_ref
from repro.kernels.ssd import ssd_chunked, ssd_ref


class TestBsrSpmv:
    @pytest.mark.parametrize("block_size", [8, 64, 128])
    @pytest.mark.parametrize("seed", [0, 1])
    def test_matches_ref_and_dense(self, block_size, seed):
        g = rmat(8, seed=seed)
        m = bsr_from_edges(g.edges, g.num_vertices, block_size=block_size)
        x = np.random.default_rng(seed).random(g.num_vertices).astype(np.float32)
        y_k = np.asarray(bsr_spmv(m, jnp.asarray(x), interpret=True))
        y_r = np.asarray(bsr_spmv_ref(m, jnp.asarray(x)))
        y_d = dense_from_bsr(m) @ x
        np.testing.assert_allclose(y_k, y_r, rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(y_k, y_d, rtol=1e-5, atol=1e-4)

    def test_weighted(self):
        g = road_mesh(8, rewire=0.1, seed=2)
        w = np.random.default_rng(0).random(g.num_edges).astype(np.float32)
        m = bsr_from_edges(g.edges, g.num_vertices, values=w, block_size=32)
        x = np.ones(g.num_vertices, dtype=np.float32)
        y = np.asarray(bsr_spmv(m, jnp.asarray(x), interpret=True))
        # row sums of the symmetric weighted adjacency
        expect = np.zeros(g.num_vertices)
        np.add.at(expect, g.edges[:, 0], w)
        np.add.at(expect, g.edges[:, 1], w)
        np.testing.assert_allclose(y, expect, rtol=1e-5, atol=1e-4)

    @given(st.integers(1, 40), st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_property_random_edges(self, n_over_8, seed):
        n = 8 * n_over_8
        rng = np.random.default_rng(seed)
        e = rng.integers(0, n, size=(max(1, n), 2))
        e = e[e[:, 0] != e[:, 1]]
        if len(e) == 0:
            return
        m = bsr_from_edges(e, n, block_size=8)
        x = rng.standard_normal(n).astype(np.float32)
        y_k = np.asarray(bsr_spmv(m, jnp.asarray(x), interpret=True))
        y_d = dense_from_bsr(m) @ x
        np.testing.assert_allclose(y_k, y_d, rtol=1e-4, atol=1e-3)


class TestSsd:
    @pytest.mark.parametrize("dh,ds,chunk", [(16, 8, 32), (64, 32, 64),
                                             (32, 128, 128)])
    def test_matches_recurrence(self, dh, ds, chunk):
        rng = np.random.default_rng(0)
        BH, T = 3, 2 * chunk
        x = jnp.asarray(rng.standard_normal((BH, T, dh)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((BH, T, ds)) * .5, jnp.float32)
        c = jnp.asarray(rng.standard_normal((BH, T, ds)) * .5, jnp.float32)
        a = jnp.asarray(-np.abs(rng.standard_normal((BH, T))) * .1, jnp.float32)
        y_k = np.asarray(ssd_chunked(x, b, c, a, chunk=chunk, interpret=True))
        y_r = np.asarray(ssd_ref(x, b, c, a))
        np.testing.assert_allclose(y_k, y_r, rtol=2e-4, atol=2e-4)

    def test_ragged_length_padding(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((2, 100, 16)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((2, 100, 8)) * .5, jnp.float32)
        c = jnp.asarray(rng.standard_normal((2, 100, 8)) * .5, jnp.float32)
        a = jnp.asarray(-np.abs(rng.standard_normal((2, 100))) * .1, jnp.float32)
        y_k = np.asarray(ssd_chunked(x, b, c, a, chunk=64, interpret=True))
        y_r = np.asarray(ssd_ref(x, b, c, a))
        np.testing.assert_allclose(y_k, y_r, rtol=2e-4, atol=2e-4)

    def test_bf16_inputs(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((2, 128, 32)), jnp.bfloat16)
        b = jnp.asarray(rng.standard_normal((2, 128, 16)) * .5, jnp.bfloat16)
        c = jnp.asarray(rng.standard_normal((2, 128, 16)) * .5, jnp.bfloat16)
        a = jnp.asarray(-np.abs(rng.standard_normal((2, 128))) * .1, jnp.float32)
        y_k = ssd_chunked(x, b, c, a.astype(jnp.float32), chunk=64,
                          interpret=True)
        y_r = ssd_ref(x, b, c, a)
        assert y_k.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(y_k, dtype=np.float32),
            np.asarray(y_r, dtype=np.float32), rtol=1e-1, atol=1e-1)

    def test_long_decay_stability(self):
        """Strong decay: later chunks must not blow up (exp bounded)."""
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((1, 256, 16)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((1, 256, 8)), jnp.float32)
        c = jnp.asarray(rng.standard_normal((1, 256, 8)), jnp.float32)
        a = jnp.full((1, 256), -5.0, dtype=jnp.float32)
        y = np.asarray(ssd_chunked(x, b, c, a, chunk=64, interpret=True))
        assert np.isfinite(y).all()


class TestDecodeAttn:
    @pytest.mark.parametrize("H,KVH,dh,S,bs", [
        (8, 2, 64, 512, 128), (4, 4, 32, 256, 64),   # GQA + MHA
        (16, 1, 64, 256, 256),                        # MQA
    ])
    def test_matches_ref(self, H, KVH, dh, S, bs):
        rng = np.random.default_rng(0)
        B = 2
        q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, KVH, dh)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, KVH, dh)), jnp.float32)
        lens = jnp.asarray([S, S // 2 + 3])
        out = decode_attention(q, k, v, lens, block_s=bs, interpret=True)
        G = H // KVH
        bias = jnp.where(jnp.arange(S)[None, :] < lens[:, None], 0.0, -1e30)
        ref = jax.vmap(decode_attention_ref)(
            q.reshape(B, KVH, G, dh), k, v, bias).reshape(B, H, dh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_short_length_ignores_padding(self):
        """Poisoned padded KV must not leak into the output."""
        rng = np.random.default_rng(4)
        B, H, KVH, dh, S = 1, 4, 2, 32, 256
        q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, KVH, dh)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, KVH, dh)), jnp.float32)
        k = k.at[:, 100:].set(1e4)
        v = v.at[:, 100:].set(1e4)
        lens = jnp.asarray([100])
        out = decode_attention(q, k, v, lens, block_s=64, interpret=True)
        out2 = decode_attention(q, k[:, :128], v[:, :128],
                                lens, block_s=64, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                                   rtol=1e-5, atol=1e-5)
