"""Quickstart: partition a graph for a heterogeneous cluster with WindGP.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (evaluate, scaled_paper_cluster, windgp)
from repro.core.partitioners import get as partitioner
from repro.data import rmat

# 1. a power-law graph (R-MAT, Graph500 parameters)
g = rmat(12, seed=7)
print(f"graph: {g}")

# 2. a heterogeneous cluster: 3 'super' + 6 'normal' machines, the paper's
#    quadruples (memory, c_node, c_edge, c_com), memory scaled to the graph
cluster = scaled_paper_cluster(3, 6, g.num_edges)
for i, m in enumerate(cluster.machines[:4]):
    print(f"machine {i}: mem={m.memory:.2e} c_node={m.c_node} "
          f"c_edge={m.c_edge} c_com={m.c_com}")

# 3. WindGP: capacity preprocessing -> best-first expansion -> SLS
res = windgp(g, cluster, alpha=0.1, beta=0.1, t0=20, theta=0.02)
print(f"\nWindGP : TC={res.stats.tc:.4e}  RF={res.stats.rf:.3f}  "
      f"feasible={res.stats.feasible}  ({res.seconds:.2f}s)")

# 4. compare against the strongest homogeneous baseline (NE)
a = partitioner("ne")(g, cluster)
s = evaluate(g, a, cluster)
print(f"NE     : TC={s.tc:.4e}  RF={s.rf:.3f}")
print(f"speedup: {s.tc / res.stats.tc:.2f}x on the TC metric")

# 5. per-machine cost breakdown (the long-tail WindGP flattens)
t = res.stats.t_total
print(f"\nper-machine total cost: min={t.min():.3e} max={t.max():.3e} "
      f"(imbalance {t.max()/t.mean():.2f}x)")
