"""Train a (reduced) assigned-architecture LM with the full substrate:
synthetic data pipeline, AdamW, remat, checkpoint/resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 60]
"""
import argparse
import tempfile

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="qwen3-4b")
    args = ap.parse_args()
    ckpt = tempfile.mkdtemp(prefix="lm_ckpt_")
    rc = train_main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps), "--batch", "8", "--seq", "128",
        "--lr", "3e-3", "--checkpoint-dir", ckpt,
        "--checkpoint-every", "25", "--log-every", "10",
    ])
    print(f"checkpoints in {ckpt}")
    raise SystemExit(rc)
