"""WindGP as an LM-framework feature: place MoE experts on heterogeneous
pods (the paper's §4 vertex-centric extension over the expert
co-activation graph), and split the global batch with Algorithm 1.

    PYTHONPATH=src python examples/hetero_moe_placement.py
"""
import numpy as np

from repro.sharding.windgp_placement import (coactivation_graph,
                                             place_experts, placement_cost)
from repro.train import heterogeneous_batch_split

# --- expert placement ------------------------------------------------------
E, toks = 16, 2000
rng = np.random.default_rng(0)
# skewed router: a hot clique of 4 experts co-activates heavily
hot = rng.choice(4, size=(toks // 2, 2))
cold = rng.choice(np.arange(4, E), size=(toks - toks // 2, 2))
routing = np.concatenate([hot, cold])

pods = {"v5p": dict(compute=0.5, mem=8, link=1.0),
        "v5e-a": dict(compute=1.0, mem=6, link=1.0),
        "v5e-b": dict(compute=1.0, mem=6, link=1.5)}
names = list(pods)
place = place_experts(
    E, routing,
    [pods[n]["compute"] for n in names],
    [pods[n]["mem"] for n in names],
    [pods[n]["link"] for n in names])
print("expert -> pod:", {e: names[p] for e, p in enumerate(place)})
rr = np.arange(E) % len(names)
print(f"makespan windgp={placement_cost(place, routing, [pods[n]['compute'] for n in names], [pods[n]['link'] for n in names]):.0f} "
      f"round-robin={placement_cost(rr, routing, [pods[n]['compute'] for n in names], [pods[n]['link'] for n in names]):.0f}")

# --- heterogeneous batch split (Algorithm 1 verbatim) ----------------------
split = heterogeneous_batch_split(
    global_batch=1024,
    pod_step_cost=[1.0, 1.0, 0.55],     # two v5e pods + one v5p pod
    pod_mem_samples=[448, 448, 640])
print(f"\nglobal batch 1024 -> per-pod {split.tolist()} "
      f"(fast pod takes {split[2]/1024:.0%})")
