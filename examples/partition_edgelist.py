"""Partition a SNAP edge list into per-machine edge files.

A dgl/graphstorm-style partitioning CLI over the unified registry and the
chunked edge-list reader:

    PYTHONPATH=src python examples/partition_edgelist.py edges.txt \
        --part-method hdrf --num-parts 8 --block-size 4096 --out-dir parts/

Block-stream methods (``blocked`` capability: greedy/hdrf/ebv) run fully
chunked — a counting pass for |V|/|E| (the stream partitioner needs both
for its memory caps), then one streaming pass that writes each machine's
edge file as placements finalize; the graph is never materialized as a
single array.  Every other registered method (``--part-method ne``,
``metis``, ``windgp``, ...) falls back to an in-memory graph build.

Output layout: ``<out-dir>/part<i>.edges`` (one ``u v`` line per edge)
plus ``<out-dir>/meta.json`` with counts and the replication factor.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from repro.core import evaluate, scaled_paper_cluster
from repro.core import partitioners as registry
from repro.core.baselines.streaming import stream_partition
from repro.data import count_edge_list, iter_edge_blocks, read_edge_list


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("edge_list", help="whitespace u-v edge list (.gz ok)")
    ap.add_argument("--part-method", default="hdrf",
                    choices=registry.names(exclude={"oracle"}))
    ap.add_argument("--num-parts", type=int, default=8)
    ap.add_argument("--super", type=int, default=0, dest="n_super",
                    help="how many of the parts are 'super' machines "
                         "(0 = one in three, the paper's default mix)")
    ap.add_argument("--block-size", type=int, default=4096)
    ap.add_argument("--slack", type=float, default=1.8)
    ap.add_argument("--out-dir", default="parts")
    args = ap.parse_args(argv)

    part = registry.get(args.part_method)
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    print(f"counting {args.edge_list} ...", flush=True)
    # same block size as the partitioning pass, so both passes see the
    # identical canonicalized stream (dedup is per-block)
    num_v, num_e = count_edge_list(args.edge_list, args.block_size)
    n_super = args.n_super or max(1, args.num_parts // 3)
    cl = scaled_paper_cluster(n_super, args.num_parts - n_super, num_e,
                              slack=args.slack)
    print(f"V={num_v} E={num_e} p={cl.p} method={part.name} "
          f"(kind={part.kind}, caps={sorted(part.capabilities)})")

    files = [open(out / f"part{i}.edges", "w") for i in range(cl.p)]
    counts = np.zeros(cl.p, dtype=np.int64)
    t0 = time.perf_counter()
    try:
        if part.supports("blocked"):
            # true streaming path: the graph never materializes
            def sink(edges, ms):
                counts[:] = counts + np.bincount(ms, minlength=cl.p)
                for i in np.unique(ms):
                    np.savetxt(files[int(i)], edges[ms == i], fmt="%d")

            state = stream_partition(
                iter_edge_blocks(args.edge_list, args.block_size),
                num_v, num_e, cl, method=part.name,
                block_size=args.block_size, sink=sink)
            rf = state.replication_factor()
        else:
            g = read_edge_list(args.edge_list)
            # global dedup can shrink the edge count vs the per-block
            # counting pass; the written total must match the graph
            num_e = g.num_edges
            assign = part(g, cl)
            stats = evaluate(g, assign, cl)
            rf = stats.rf
            for i in range(cl.p):
                sel = g.edges[assign == i]
                counts[i] = len(sel)
                np.savetxt(files[i], sel, fmt="%d")
    finally:
        for f in files:
            f.close()
    dt = time.perf_counter() - t0

    meta = {
        "method": part.name, "num_parts": cl.p, "num_vertices": num_v,
        "num_edges": num_e, "block_size": args.block_size,
        "seconds": round(dt, 3), "replication_factor": round(float(rf), 4),
        "edges_per_part": counts.tolist(),
        "files": [f"part{i}.edges" for i in range(cl.p)],
    }
    (out / "meta.json").write_text(json.dumps(meta, indent=2))
    print(json.dumps(meta, indent=2))
    assert int(counts.sum()) == num_e, "every edge exactly once"
    return 0


if __name__ == "__main__":
    sys.exit(main())
