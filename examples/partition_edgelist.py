"""Partition a SNAP edge list into per-machine shards — then run on them.

A dgl/graphstorm-style partitioning CLI over the unified registry and the
chunked edge-list reader:

    PYTHONPATH=src python examples/partition_edgelist.py edges.txt \
        --part-method hdrf --num-parts 8 --block-size 4096 --out-dir parts/

Block-stream methods (``blocked`` capability: greedy/hdrf/ebv) run fully
chunked — a counting pass for |V|/|E| (the stream partitioner needs both
for its memory caps), then one streaming pass that writes each machine's
edge file as placements finalize; the graph is never materialized as a
single array.  Every other registered method (``--part-method ne``,
``metis``, ``windgp``, ...) falls back to an in-memory graph build.

Output layout: ``<out-dir>/part<i>.edges`` (one ``u v`` line per edge),
``<out-dir>/assignment/`` (the binary ``StreamAssignment`` the BSP runtime
consumes — streaming methods only), plus ``<out-dir>/meta.json`` with
counts and the replication factor.  ``meta.json`` is written last, via
tmp + atomic rename, and only after every shard verified its flushed byte
length — a crash can never leave a directory that parses as complete.

Out-of-core workflow
--------------------
The full partition→compute pipeline on a list that never materializes:

    PYTHONPATH=src python examples/partition_edgelist.py edges.txt.gz \
        --part-method hdrf --num-parts 8 --two-pass --pagerank \
        --out-dir parts/

``--two-pass`` replaces the single-pass per-block dedup with the exact
spill-to-disk dedup (``repro.data.TwoPassDedup``): pass one hashes
canonicalized edges into bounded disk buckets, pass two streams each
bucket back globally deduplicated in first-occurrence order, so the
partitioner sees every edge exactly once while peak edge residency stays
bounded by the spill-bucket accounting (reported in meta.json under
``spill``).  ``--pagerank`` then packs the streamed shards into the BSP
runtime (``PartitionRuntime.from_stream`` — reads one machine's shard at
a time, never the raw list) and runs distributed PageRank supersteps on
the partition it just built — the paper's end-to-end claim, out of core.

Multi-worker workflow
---------------------
``--workers W`` runs the same out-of-core pipeline through the
W-process orchestrator (``repro.core.parallel``) when the wall clock,
not memory, is the constraint::

    PYTHONPATH=src python examples/partition_edgelist.py edges.txt \
        --part-method hdrf --num-parts 8 --two-pass --workers 4 \
        --pagerank --out-dir parts/

Count and spill/dedup shard first: the raw list splits into W byte
ranges (line-aligned), each worker hash-shuffles its range into the
shared spill buckets, and pass-2 dedup runs per worker over disjoint
bucket sets — the merged stream is *identical* block for block to the
sequential dedup.  The parallel stream then scores engine blocks on W
workers against membership snapshots synced every ``sync_blocks``
blocks (results depend only on that period, never on W; at
``sync_blocks=1`` they are bit-identical to ``--workers 1``).
Placements replay through the sink on the coordinator in a
deterministic order, so this script still writes ONE
``StreamAssignment`` (and one set of ``part<i>.edges``) regardless of
W — ``--pagerank`` packs and runs on it exactly as in the sequential
workflow.  ``benchmarks/parallel_scale.py`` is the measured version
(dedup+scoring wall at W∈{1,2,4}, TC/RF gap vs sequential) and runs in
CI as the tier-2 ``parallel`` job.

Choosing an edge-kernel backend
-------------------------------
``--backend`` selects how each PageRank superstep combines messages over
the machine's local edges (``repro.bsp.backends``); results agree to
1e-5, only speed and hardware shape differ:

* ``scatter`` (default) — the gather-scatter oracle (``at[].add`` per
  direction).  Slowest, but the reference every other backend is tested
  against; pick it when validating a new partition pipeline.
* ``segment`` — sorted-CSR reduction via a running sum differenced at
  row pointers.  No scatter at all, ~5x the scatter superstep
  throughput on CPU proxies; the right default for CPU runs.
* ``pallas`` — the blocked Block-ELL semiring SpMV
  (``repro.kernels.bsr_spmv``) over the degree-sorted per-machine
  adjacency (``PartitionRuntime.local_bsr``).  MXU-shaped 128x128
  blocks on TPU; on CPU it runs the Pallas interpreter, so treat it as
  a validation/portability path, not a CPU speedup.

The same flag exists on ``repro.launch.partition`` (with ``--stream``)
and the backend registry is shared by all four BSP apps — SSSP/BFS/
components run the same kernels under (min, +)/(or, and) semirings.

Three more knobs tune how the supersteps *run*, independent of which
backend combines the edges:

* ``--fused`` — run the whole PageRank iteration as ONE on-device
  dispatch (``repro.bsp.engine.run_bsp_fused``: ``lax.scan`` over
  chunks of supersteps, host sync only at the end) instead of one
  jitted dispatch + device→host sync per superstep.  On small/medium
  shards the per-step runner is dispatch-bound, so this is the main
  superstep-latency lever — same results, bitwise at the default
  message dtype.
* ``--tol T`` — convergence gate for the fused runner (implies
  ``--fused``): stop as soon as the on-device residual
  ``max|pr_{t+1} − pr_t| <= T`` instead of always running
  ``--pagerank-iters`` supersteps.  The monotone apps (SSSP/BFS/CC)
  need no tolerance — their fused runs already early-exit when the
  active count hits zero.
* ``--message-dtype bfloat16`` — the low-precision message path:
  per-edge ⊗ operands are cast to bfloat16 while scatter/segment
  ⊕-accumulation stays float32.  Halves message bandwidth at ~1e-3
  relative PageRank error; ``benchmarks/bsp_apps.py --bf16-study``
  prints the error-vs-iteration table to judge the trade.  The default
  ``float32`` is bit-identical to not having the knob.

Dynamic workflow
----------------
The partition this script writes is a *seed*, not a terminal product:
when the graph keeps evolving, wrap it in the dynamic layer instead of
re-running the pipeline per snapshot::

    from repro.core import DynamicPartitioner
    from repro.bsp import PartitionRuntime, StreamAssignment, pagerank

    dp = DynamicPartitioner(g, cl, assign)     # live state over the seed
    sa = StreamAssignment.open(out_dir / "assignment")
    rt = PartitionRuntime.create(sa)
    snap = dp.snapshot()
    dp.insert(new_edges)                       # wave-scored vs live (p,V)
    dp.delete(stale_edges)                     # exact Eq.3/4 rollback
    # drift monitor fires bounded SLS repair automatically when balance
    # skew or RF crosses its leash; per-epoch, hand the diff downstream:
    delta = dp.delta_since(snap)
    sa.apply_delta(delta, dp.membership())     # shard append + tombstones
    rt = rt.apply_delta(sa, delta)             # repack touched machines
    pr, _ = pagerank(rt, init=pr_prev)         # warm-start from last run

Inserts are scored by the same block-stream engine this script uses for
the cold pass, so a quiet timeline converges to the static partition.
``benchmarks/dynamic_replay.py`` is the measured version of this loop
(assignment-latency percentiles, amortized repair cost, TC drift vs
scratch) and runs in CI as the tier-2 ``dynamic`` job.

Sampling workflow
-----------------
The shards this script writes also feed GNN minibatch training: wrap
the partition in the sampling service (``repro.sampling``) and draw
fixed-fanout k-hop neighborhoods per machine::

    from repro.sampling import (FeatureStore, HaloCache,
                                PrefetchPipeline, SamplingService)
    import jax

    svc = SamplingService.create(out_dir / "assignment",
                                 fanouts=(10, 5))
    key = jax.random.PRNGKey(0)
    seeds = svc.local_seeds(home=0, n=1024, key=key)   # machine 0's shard
    mb = svc.sample(seeds, jax.random.fold_in(key, 1), home=0)
    mb.halo_fracs()    # per-hop fraction of frontier owned elsewhere

    # feature path: owner-sharded store + per-trainer halo cache
    store = FeatureStore.build(svc, features)      # features: (V, F)
    cache = HaloCache.for_home(store, home=0, capacity=4096)
    rows, st = store.gather(mb.all_ids(), home=0, cache=cache)
    st.hit_rate    # deduplicated remote rows served without a fetch

    # steady-state training loop: prefetch overlaps batch i+1's fused
    # k-hop sampling with batch i's feature resolve; any depth (incl.
    # 0 = fully synchronous) yields the bitwise-same stream
    with PrefetchPipeline(svc, home=0, batch_size=1024, num_batches=100,
                          key=key, depth=2, store=store,
                          cache=cache) as pipe:
        for mb, feats in pipe:
            ...                                    # train on the batch

``SamplingService.create`` accepts every ``PartitionRuntime.create``
source — the assignment directory above, or ``(graph, method=,
cluster=)`` to partition in-process, or ``(graph, assign=, p=)`` for a
precomputed assignment.  Each machine holds a degree-sorted CSC of its
*owned* vertices; the whole k-hop expansion runs as one fused jitted
dispatch (a hop-at-a-time reference path survives behind
``sample(..., fused=False)``, pinned bitwise).  Per hop, sampled
vertices owned elsewhere are counted as one deduplicated batched halo
fetch — the traffic a better partition shrinks, which is how partition
quality becomes observable on the training workload; the feature
store's ``gather`` pays exactly that traffic, minus what the
``HaloCache`` (static degree-ranked hub tier + LRU tail) absorbs.  The
sampler is key-deterministic (same ``(partition, seeds, key)`` →
bitwise-same minibatch, pinned against a NumPy oracle) and
``local_seeds(..., train_mask=m)`` restricts seeds to labeled vertices.
For training-aware partitions, pass ``train_mask=`` /
``train_balance=`` to the windgp partitioner — Eq. 3 then weighs
hosted train vertices extra, balancing the labeled set across machines.
``benchmarks/sampling_service.py`` is the measured version (samples/sec,
fused-vs-loop speedup, halo fraction and cache hit rate windgp vs hdrf
vs hash, prefetch depth sweep, train-skew reduction) and runs in CI as
the tier-2 ``sampling`` job.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from repro.bsp import (PartitionRuntime, StreamAssignment,
                       write_json_atomic)
from repro.core import evaluate, evaluate_membership, scaled_paper_cluster
from repro.core import partitioners as registry
from repro.data import TwoPassDedup, count_edge_list, read_edge_list
from repro.launch.partition import (EDGE_BACKENDS, MESSAGE_DTYPES,
                                    _run_pagerank)


def _partition_streaming(args, part, out: pathlib.Path):
    """Graph-free path: count → (optional two-pass dedup) → stream.

    Returns (num_v, num_e, stats, StreamAssignment, spill_stats).
    """
    source: object
    if args.two_pass:
        print(f"spilling+deduplicating {args.edge_list} "
              f"(workers={args.workers}) ...", flush=True)
        if args.workers > 1:
            from repro.core.parallel import ShardedTwoPassDedup
            source = ShardedTwoPassDedup(
                args.edge_list, block_size=args.block_size,
                bucket_rows=args.bucket_rows, workers=args.workers)
        else:
            source = TwoPassDedup(args.edge_list,
                                  block_size=args.block_size,
                                  bucket_rows=args.bucket_rows)
        num_v, num_e = source.prepare()
    else:
        print(f"counting {args.edge_list} ...", flush=True)
        # same block size as the partitioning pass, so both passes see the
        # identical canonicalized stream (dedup is per-block)
        num_v, num_e = count_edge_list(args.edge_list, args.block_size)
        source = args.edge_list
    n_super = args.n_super or max(1, args.num_parts // 3)
    cl = scaled_paper_cluster(n_super, args.num_parts - n_super, num_e,
                              slack=args.slack)
    print(f"V={num_v} E={num_e} p={cl.p} method={part.name} "
          f"(kind={part.kind}, caps={sorted(part.capabilities)})")

    sa = StreamAssignment(out / "assignment", cl.p, num_v)
    files = [open(out / f"part{i}.edges", "w") for i in range(cl.p)]
    try:
        def sink(edges, ms):
            sa.sink(edges, ms)
            for i in np.unique(ms):
                np.savetxt(files[int(i)], edges[ms == i], fmt="%d")

        kw = {}
        if args.workers > 1:
            kw = {"workers": args.workers, "sync_blocks": args.sync_blocks}
        state = part.stream(
            source, num_v, num_e, cl,
            dedup="two_pass" if args.two_pass else "block",
            block_size=args.block_size, sink=sink, **kw)
    except BaseException:
        sa.close()          # abort: drop shard handles, publish nothing
        raise
    finally:
        for f in files:
            f.close()
        if args.two_pass:
            source.close()
    stats = evaluate_membership(state.cnt > 0, state.edges_per, cl)
    sa.finalize(state, {"method": part.name,
                        "dedup": "two_pass" if args.two_pass else "block"})
    return num_v, num_e, stats, sa, state.spill_stats


def _partition_in_memory(args, part, out: pathlib.Path):
    """Fallback for non-streamable methods: materialize the graph."""
    g = read_edge_list(args.edge_list)
    num_v, num_e = g.num_vertices, g.num_edges
    n_super = args.n_super or max(1, args.num_parts // 3)
    cl = scaled_paper_cluster(n_super, args.num_parts - n_super, num_e,
                              slack=args.slack)
    print(f"V={num_v} E={num_e} p={cl.p} method={part.name} "
          f"(kind={part.kind}, caps={sorted(part.capabilities)})")
    assign = part(g, cl)
    stats = evaluate(g, assign, cl)
    sa = StreamAssignment(out / "assignment", cl.p, num_v)
    files = [open(out / f"part{i}.edges", "w") for i in range(cl.p)]
    try:
        sa.sink(g.edges.astype(np.int64), assign.astype(np.int64))
        for i in range(cl.p):
            np.savetxt(files[i], g.edges[assign == i], fmt="%d")
    except BaseException:
        sa.close()
        raise
    finally:
        for f in files:
            f.close()
    from repro.core.machines import vertex_partition_sets
    sa.finalize(vertex_partition_sets(g, assign, cl.p),
                {"method": part.name, "dedup": "in_memory"})
    return num_v, num_e, stats, sa, None


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("edge_list", help="whitespace u-v edge list (.gz ok)")
    ap.add_argument("--part-method", default="hdrf",
                    choices=registry.names(exclude={"oracle"}))
    ap.add_argument("--num-parts", type=int, default=8)
    ap.add_argument("--super", type=int, default=0, dest="n_super",
                    help="how many of the parts are 'super' machines "
                         "(0 = one in three, the paper's default mix)")
    ap.add_argument("--block-size", type=int, default=4096)
    ap.add_argument("--slack", type=float, default=1.8)
    ap.add_argument("--two-pass", action="store_true",
                    help="exact out-of-core dedup via spill buckets "
                         "(streaming methods; default is per-block dedup)")
    ap.add_argument("--bucket-rows", type=int, default=1 << 16,
                    help="--two-pass spill-bucket row target (bounds peak "
                         "edge residency)")
    ap.add_argument("--workers", type=int, default=1,
                    help="W-process pipeline: sharded dedup + parallel "
                         "wave scoring (see 'Multi-worker workflow'); "
                         "1 = sequential bit for bit")
    ap.add_argument("--sync-blocks", type=int, default=None,
                    help="--workers > 1: engine blocks between membership "
                         "sync barriers (1 = bit-identical to sequential)")
    ap.add_argument("--pagerank", action="store_true",
                    help="after partitioning, pack the BSP runtime from "
                         "the shards and run distributed PageRank")
    ap.add_argument("--pagerank-iters", type=int, default=30)
    ap.add_argument("--backend", default="scatter",
                    choices=EDGE_BACKENDS,
                    help="edge-kernel backend for --pagerank (see "
                         "module docstring)")
    ap.add_argument("--fused", action="store_true",
                    help="--pagerank: whole iteration as one on-device "
                         "dispatch (see module docstring)")
    ap.add_argument("--tol", type=float, default=None,
                    help="--pagerank: on-device convergence tolerance "
                         "(implies --fused)")
    ap.add_argument("--message-dtype", default="float32",
                    choices=MESSAGE_DTYPES,
                    help="--pagerank: edge-message precision (bfloat16 "
                         "= low-precision message path)")
    ap.add_argument("--out-dir", default="parts")
    args = ap.parse_args(argv)

    part = registry.get(args.part_method)
    if args.two_pass and not part.supports("streamable"):
        ap.error(f"--two-pass: {part.name!r} is not streamable "
                 f"(in-memory methods dedup exactly already)")
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    t0 = time.perf_counter()
    if part.supports("streamable"):
        num_v, num_e, stats, sa, spill = _partition_streaming(args, part, out)
    else:
        num_v, num_e, stats, sa, spill = _partition_in_memory(args, part, out)
    dt = time.perf_counter() - t0

    counts = sa.edges_per
    meta = {
        "method": part.name, "num_parts": sa.p, "num_vertices": num_v,
        "num_edges": int(counts.sum()), "block_size": args.block_size,
        "dedup": sa.meta["dedup"],
        "seconds": round(dt, 3),
        "TC": stats.tc,
        "replication_factor": round(float(stats.rf), 4),
        "edges_per_part": counts.tolist(),
        "files": [f"part{i}.edges" for i in range(sa.p)],
        "assignment_dir": "assignment",
    }
    if spill is not None:
        meta["spill"] = {
            "num_buckets": spill.num_buckets,
            "bucket_rows": spill.bucket_rows,
            "spilled_rows": spill.spilled_rows,
            "duplicate_rows": spill.duplicate_rows,
            "max_bucket_rows": spill.max_bucket_rows,
            "peak_resident_rows": spill.peak_resident_rows,
        }
    # every edge exactly once: the written total must equal the
    # *independently counted* stream size (exact dedup count in two-pass
    # mode, the same-window per-block count otherwise)
    assert int(counts.sum()) == num_e, \
        f"wrote {int(counts.sum())} edges, counted {num_e}"
    # shards were verified in sa.finalize(); only now publish the manifest,
    # atomically — readers either see no meta.json or a complete one
    write_json_atomic(out / "meta.json", meta)
    print(json.dumps(meta, indent=2))

    if args.pagerank:
        # same report as the launch CLI (shared helper): pack the runtime
        # from the on-disk shards, run supersteps through --backend
        _run_pagerank(PartitionRuntime.create(sa), args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
