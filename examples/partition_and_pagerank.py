"""End-to-end driver (the paper's kind): partition a graph with WindGP and
run distributed PageRank + SSSP on the BSP engine until convergence,
comparing the heterogeneous-cluster makespan against baseline partitioners.

    PYTHONPATH=src python examples/partition_and_pagerank.py
"""
import time

import numpy as np

from repro.bsp import (PartitionRuntime, pagerank, ref, simulate_runtime,
                       sssp)
from repro.core import evaluate, scaled_paper_cluster, windgp
from repro.core.partitioners import get as partitioner
from repro.data import rmat

g = rmat(12, seed=3)
cluster = scaled_paper_cluster(3, 6, g.num_edges)
print(f"graph {g}; cluster p={cluster.p}")

results = {}
for method in ("hash", "ne", "windgp"):
    if method == "windgp":
        assign = windgp(g, cluster, alpha=0.1, beta=0.1,
                        t0=20, theta=0.02).assign
    else:
        assign = partitioner(method)(g, cluster)
    stats = evaluate(g, assign, cluster)
    rt = PartitionRuntime.create(g, assign=assign, cluster=cluster)

    t0 = time.perf_counter()
    pr, _ = pagerank(rt, num_iters=30)
    wall = time.perf_counter() - t0
    sim = simulate_runtime(rt, cluster, num_steps=30)

    _, act = sssp(rt, source=0, num_iters=20)
    sim_sssp = simulate_runtime(rt, cluster, actives=act,
                                comm_scale="active")
    err = np.abs(pr - ref.pagerank(g, num_iters=30)).max()
    results[method] = (stats.tc, sim, sim_sssp)
    print(f"{method:7s} TC={stats.tc:.3e}  PR-makespan={sim:.3e}  "
          f"SSSP-makespan={sim_sssp:.3e}  wall={wall:.1f}s  maxerr={err:.1e}")

print("\nheterogeneous-cluster speedup of WindGP over NE:")
for i, name in enumerate(("TC", "PageRank", "SSSP")):
    print(f"  {name}: {results['ne'][i] / results['windgp'][i]:.2f}x")
