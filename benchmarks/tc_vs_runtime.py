"""Paper Table 1: TC is proportional to the distributed running time.

Two measurements per (partitioner, app):
  * simulated BSP makespan on the heterogeneous cluster (cost-model time,
    driven by the *measured* per-superstep active sets of the real run);
  * wall-clock of the real JAX BSP engine (homogeneous container CPU —
    engine-speed sanity, not the heterogeneity signal).
"""
from __future__ import annotations

import time

import numpy as np

from repro.bsp import PartitionRuntime, bfs, pagerank, simulate_runtime, sssp
from repro.core import evaluate, windgp
from repro.core.partitioners import get as partitioner

from .common import CSV, cluster_for, dataset, timed


def run(quick: bool = True, ds: str = "LJ"):
    csv = CSV("tab1_tc_vs_runtime")
    g = dataset(ds, quick)
    cl = cluster_for(ds, g)
    rows = []
    for m in ("hdrf", "ne", "windgp"):
        if m == "windgp":
            assign = windgp(g, cl, t0=20, theta=0.02,
                            alpha=0.1, beta=0.1).assign
        else:
            assign = partitioner(m)(g, cl)
        tc = evaluate(g, assign, cl).tc
        rt = PartitionRuntime.create(g, assign=assign, cluster=cl)

        t0 = time.perf_counter()
        _, act_pr = pagerank(rt, num_iters=10)
        wall_pr = time.perf_counter() - t0
        sim_pr = simulate_runtime(rt, cl, num_steps=10)

        t0 = time.perf_counter()
        _, act_ss = sssp(rt, source=0, num_iters=15)
        wall_ss = time.perf_counter() - t0
        sim_ss = simulate_runtime(rt, cl, actives=act_ss,
                                  comm_scale="active")

        csv.row(f"{ds}/{m}", 0,
                f"TC={tc:.4e};simPR={sim_pr:.4e};simSSSP={sim_ss:.4e};"
                f"wallPR={wall_pr:.2f}s;wallSSSP={wall_ss:.2f}s")
        rows.append((tc, sim_pr, sim_ss))
    # proportionality check (paper: <10% error for dense)
    tcs = np.array([r[0] for r in rows])
    prs = np.array([r[1] for r in rows])
    ratio = prs / tcs
    err = ratio.std() / ratio.mean()
    csv.row(f"{ds}/dense_proportionality_cv", 0, f"{err:.4f}")
    return rows
