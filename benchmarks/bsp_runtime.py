"""Paper Tables 15/16: distributed graph computing performance per
partitioner (PageRank / SSSP / TriangleCount on the heterogeneous
cluster, simulated makespan from real active sets)."""
from __future__ import annotations

import time

from repro.bsp import (PartitionRuntime, pagerank, simulate_runtime, sssp,
                       triangle_count)
from repro.core import evaluate, windgp
from repro.core.partitioners import get as partitioner

from .common import CSV, cluster_for, dataset, timed


def run(quick: bool = True, datasets=("TW", "LJ", "CP", "RN")):
    csv = CSV("tab15_16_bsp_runtime")
    out = {}
    for ds in datasets:
        g = dataset(ds, quick)
        cl = cluster_for(ds, g)
        for m in ("hdrf", "ne", "windgp"):
            if m == "windgp":
                assign = windgp(g, cl, t0=20, theta=0.02,
                                alpha=0.1, beta=0.1).assign
            else:
                assign = partitioner(m)(g, cl)
            rt = PartitionRuntime.create(g, assign=assign, cluster=cl)
            sim_pr = simulate_runtime(rt, cl, num_steps=10)
            # fused runner: one device dispatch for the whole SSSP run,
            # and the early exit trims the idle tail off the active sets
            _, act = sssp(rt, source=0, num_iters=12, fused=True)
            sim_ss = simulate_runtime(rt, cl, actives=act,
                                      comm_scale="active")
            t0 = time.perf_counter()
            tri = triangle_count(rt, g)
            wall_tri = time.perf_counter() - t0
            csv.row(f"{ds}/{m}", 0,
                    f"simPR={sim_pr:.4e};simSSSP={sim_ss:.4e};"
                    f"triangles={tri};wallTri={wall_tri:.1f}s")
            out[(ds, m)] = (sim_pr, sim_ss)
    return out
