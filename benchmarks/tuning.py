"""Paper Tables 4-9: hyper-parameter tuning (α, β, γ, θ, N0, T0), plus the
SLS wave-knob sweep (``wave_frac`` × ``wave_window`` of the vectorized
destroy–repair admission)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import capacities, windgp
from repro.core import expand as exp_mod
from repro.core import sls as sls_mod
from repro.core.partition_state import PartitionState

from .common import CSV, cluster_for, dataset, median_iqr, spread_str, timed

GRIDS = {
    "alpha": [0, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9],      # Table 4
    "beta": [0, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9],       # Table 5
    "gamma": [0, 0.3, 0.6, 0.9, 1.0],                # Table 6
    "theta": [0.002, 0.006, 0.01, 0.016, 0.02],      # Table 7
    "n0": [1, 3, 5, 7, 9],                            # Table 8
    "t0": [1, 3, 5, 7, 9],                            # Table 9
}


def run(quick: bool = True, datasets=("TW", "LJ", "RN")):
    csv = CSV("tab4_9_tuning")
    results = {}
    for ds in datasets:
        g = dataset(ds, quick)
        cl = cluster_for(ds, g)
        for pname, grid in GRIDS.items():
            tcs = []
            for val in grid:
                kw = dict(alpha=0.1, beta=0.1, gamma=0.9, theta=0.01,
                          n0=5, t0=8)
                # α/β tuning isolates the expansion (paper evaluates the
                # search phase); SLS params need the full pipeline.
                if pname in ("alpha", "beta"):
                    kw.update({pname: val})
                    res, dt = timed(windgp, g, cl, level="windgp+",
                                    alpha=kw["alpha"], beta=kw["beta"])
                else:
                    kw.update({pname: val})
                    res, dt = timed(
                        windgp, g, cl, alpha=kw["alpha"], beta=kw["beta"],
                        gamma=kw["gamma"], theta=kw["theta"],
                        n0=kw["n0"], t0=kw["t0"])
                tcs.append(res.stats.tc)
                csv.row(f"{ds}/{pname}={val}", dt, f"TC={res.stats.tc:.4e}")
            best = grid[int(np.argmin(tcs))]
            csv.row(f"{ds}/{pname}_best", 0, f"{best}")
            results[(ds, pname)] = (grid, tcs)
    return results


WAVE_FRACS = (0.25, 0.5, 0.75, 1.0)
WAVE_WINDOWS = (None, 0.5, 0.25, 0.1)


def run_wave_sweep(quick: bool = True, datasets=("TW", "LJ"),
                   repeats: int = 2, sweeps: int = 5,
                   gamma: float = 0.9, theta: float = 0.05):
    """SLS wave-knob sweep (ROADMAP): ``wave_frac`` × ``wave_window``.

    From one fixed post-expansion partition per proxy, time ``sweeps``
    destroy–repair sweeps per knob setting and record the resulting TC —
    the quality/speed surface the ``repair_edges`` defaults are picked
    from.  The scalar oracle rides along as the quality reference.
    """
    csv = CSV("wave_sweep")
    results = {}
    for ds in datasets:
        g = dataset(ds, quick)
        cl = cluster_for(ds, g)
        deltas = capacities(cl, g.num_vertices, g.num_edges)
        assign, orders = exp_mod.run_expansion(
            g, deltas, 0.1, 0.1, memories=cl.memory(),
            m_node=cl.m_node, m_edge=cl.m_edge, engine="batched")
        obj0 = PartitionState.build(g, assign, cl)
        sls_mod.repair_edges(obj0, np.flatnonzero(assign < 0), orders)
        base = obj0.assign.copy()

        def one(wf=None, ww=None, strict=False):
            times, tc = [], None
            for _ in range(max(1, repeats)):
                obj = PartitionState.build(g, base, cl)
                ords = [list(o) for o in orders]
                kw = {} if strict else {"wave_frac": wf, "wave_window": ww}
                t0 = time.perf_counter()
                for _ in range(sweeps):
                    sls_mod.destroy_repair(obj, ords, gamma, theta, None,
                                           strict=strict, **kw)
                times.append(time.perf_counter() - t0)
                tc = obj.tc
            med, _ = median_iqr(times)
            return med, tc, times

        t_ref, tc_ref, ts = one(strict=True)
        csv.row(f"{ds}/scalar", t_ref, f"{spread_str(ts)} tc={tc_ref:.0f}")
        for wf in WAVE_FRACS:
            for ww in WAVE_WINDOWS:
                med, tc, ts = one(wf, ww)
                gap = (tc - tc_ref) / tc_ref
                csv.row(f"{ds}/wf={wf}/ww={ww}", med,
                        f"{spread_str(ts)} tc={tc:.0f} "
                        f"gap={gap * 100:+.2f}% "
                        f"speedup={t_ref / max(med, 1e-9):.2f}x")
                results[(ds, wf, ww)] = {"seconds": med, "tc": tc,
                                         "tc_gap": gap,
                                         "speedup": t_ref / max(med, 1e-9)}
        results[(ds, "scalar")] = {"seconds": t_ref, "tc": tc_ref}
    return results
