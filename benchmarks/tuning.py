"""Paper Tables 4-9: hyper-parameter tuning (α, β, γ, θ, N0, T0)."""
from __future__ import annotations

import numpy as np

from repro.core import windgp

from .common import CSV, cluster_for, dataset, timed

GRIDS = {
    "alpha": [0, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9],      # Table 4
    "beta": [0, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9],       # Table 5
    "gamma": [0, 0.3, 0.6, 0.9, 1.0],                # Table 6
    "theta": [0.002, 0.006, 0.01, 0.016, 0.02],      # Table 7
    "n0": [1, 3, 5, 7, 9],                            # Table 8
    "t0": [1, 3, 5, 7, 9],                            # Table 9
}


def run(quick: bool = True, datasets=("TW", "LJ", "RN")):
    csv = CSV("tab4_9_tuning")
    results = {}
    for ds in datasets:
        g = dataset(ds, quick)
        cl = cluster_for(ds, g)
        for pname, grid in GRIDS.items():
            tcs = []
            for val in grid:
                kw = dict(alpha=0.1, beta=0.1, gamma=0.9, theta=0.01,
                          n0=5, t0=8)
                # α/β tuning isolates the expansion (paper evaluates the
                # search phase); SLS params need the full pipeline.
                if pname in ("alpha", "beta"):
                    kw.update({pname: val})
                    res, dt = timed(windgp, g, cl, level="windgp+",
                                    alpha=kw["alpha"], beta=kw["beta"])
                else:
                    kw.update({pname: val})
                    res, dt = timed(
                        windgp, g, cl, alpha=kw["alpha"], beta=kw["beta"],
                        gamma=kw["gamma"], theta=kw["theta"],
                        n0=kw["n0"], t0=kw["t0"])
                tcs.append(res.stats.tc)
                csv.row(f"{ds}/{pname}={val}", dt, f"TC={res.stats.tc:.4e}")
            best = grid[int(np.argmin(tcs))]
            csv.row(f"{ds}/{pname}_best", 0, f"{best}")
            results[(ds, pname)] = (grid, tcs)
    return results
