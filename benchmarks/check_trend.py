"""Gate smoke-benchmark metrics against the checked-in trend baseline.

The tier-2 CI jobs emit flat ``{"table/metric": value}`` JSON
(``BENCH_smoke.json``, uploaded as an artifact on every push); this script
compares those numbers against ``benchmarks/trend_baseline.json`` and
fails the job when a gated metric drifts past its bound — the repo's
perf-trajectory tracking.

Baseline schema, per metric::

    "stream/hdrf/tc_gap": {"max": 0.02}            # fail if value > max
    "oocore/peak_ratio":  {"max": 0.6, "min": 0}   # and/or a floor
    "dynamic/p99_us":     {}                       # tracked, ungated

A bound-less entry is *tracked, ungated*: the metric is a deliberate part
of the trajectory record (it prints with every run and rides in the
uploaded artifact) but never fails the job — the home for wall-clock
numbers like latency percentiles and parallel speedups, which CI noise
makes ungateable.  Metrics in the report but absent from the baseline are
listed as untracked (new metrics start untracked; add bounds — or an
empty entry — once their value has a trajectory).  Baseline entries
absent from the report are skipped — the tier-2 matrix jobs each emit a
different subset against the one shared baseline.

Usage:
    python -m benchmarks.check_trend BENCH_smoke.json [--baseline PATH]
    python -m benchmarks.check_trend BENCH_smoke.json --update  # reseed
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

DEFAULT_BASELINE = pathlib.Path(__file__).parent / "trend_baseline.json"

#: headroom applied by ``--update`` when reseeding a max bound
UPDATE_SLACK = 1.25


def check(report: dict, baseline: dict) -> list[str]:
    """Return the list of violations (empty == all gates hold)."""
    bad = []
    for name, bounds in sorted(baseline.items()):
        if name not in report:
            print(f"  skip      {name} (not in this report)")
            continue
        v = report[name]
        lo, hi = bounds.get("min"), bounds.get("max")
        if lo is None and hi is None:
            print(f"  tracked   {name} = {v:.6g}  (ungated)")
            continue
        if hi is not None and v > hi:
            bad.append(f"{name} = {v:.6g} > max {hi:.6g}")
        elif lo is not None and v < lo:
            bad.append(f"{name} = {v:.6g} < min {lo:.6g}")
        else:
            span = " ".join(f"{k}={b:.6g}" for k, b in
                            (("min", lo), ("max", hi)) if b is not None)
            print(f"  ok        {name} = {v:.6g}  ({span})")
    for name in sorted(set(report) - set(baseline)):
        print(f"  untracked {name} = {report[name]:.6g}")
    return bad


def update(report: dict, baseline: dict) -> dict:
    """Reseed: keep existing bounds, add max-bounds for untracked gaps."""
    out = dict(baseline)
    for name, v in sorted(report.items()):
        if name in out or not isinstance(v, (int, float)):
            continue
        if name.endswith(("_gap", "_frac", "_ratio")):
            out[name] = {"max": round(max(v, 0.0) * UPDATE_SLACK + 0.01, 4)}
            print(f"  seeded    {name}: max={out[name]['max']}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("reports", nargs="+",
                    help="BENCH_smoke.json file(s) to gate")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument("--update", action="store_true",
                    help="seed bounds for untracked gap/frac/ratio "
                         "metrics instead of gating")
    args = ap.parse_args(argv)

    baseline = (json.loads(pathlib.Path(args.baseline).read_text())
                if pathlib.Path(args.baseline).exists() else {})
    violations = []
    merged = {}
    for rp in args.reports:
        report = json.loads(pathlib.Path(rp).read_text())
        merged.update(report)
        print(f"{rp}: {len(report)} metrics vs {args.baseline}")
        violations += check(report, baseline)
    if args.update:
        pathlib.Path(args.baseline).write_text(
            json.dumps(update(merged, baseline), indent=2, sort_keys=True)
            + "\n")
        print(f"updated {args.baseline}")
        return 0
    if violations:
        print("\nTREND GATE FAILED:", file=sys.stderr)
        for v in violations:
            print(f"  {v}", file=sys.stderr)
        return 1
    print("trend gates hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
