"""Benchmark entry: one function per paper table/figure.

Prints ``table/name,us_per_call,derived`` CSV rows.  ``--full`` doubles the
graph scales (container default is laptop-scale, see DESIGN.md §7).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig12,...]
"""
from __future__ import annotations

import argparse
import inspect
import time

from . import (ablation, bsp_apps, bsp_runtime, compare_tc, dynamic_replay,
               oocore, parallel_scale, partition_time, scale_graphsize,
               scale_machines, tc_vs_runtime, tuning)

TABLES = {
    "fig12": compare_tc.run,          # TC vs baselines
    "fig8": ablation.run,             # technique ladder
    "tab4_9": tuning.run,             # hyper-parameter grids
    "fig13": scale_graphsize.run,     # graph-size scalability
    "fig14_15": scale_machines.run,   # machine count/types
    "tab11": partition_time.run,      # partitioning time
    "engines": partition_time.run_engine_compare,  # heap vs batched expansion
    "sls": partition_time.run_sls_compare,  # scalar vs vectorized SLS repair
    "stream": partition_time.run_streaming_compare,  # oracle vs block engine
    "oocore": oocore.run,             # out-of-core vs in-memory pipeline
    "parallel": parallel_scale.run,   # W-worker pipeline scaling/quality
    "dynamic": dynamic_replay.run,    # insert/delete timeline replay
    "bsp": bsp_apps.run,              # edge-kernel backends per BSP app
    "wave": tuning.run_wave_sweep,    # SLS wave_frac/wave_window sweep
    "tab1": tc_vs_runtime.run,        # TC ∝ runtime
    "tab15_16": bsp_runtime.run,      # distributed algorithm runtimes
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated table keys")
    ap.add_argument("--repeats", type=int, default=None,
                    help="median-of-N repeats for the timing tables that "
                         "support it (spread printed as IQR)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else set(TABLES)
    t0 = time.perf_counter()
    print("table/name,us_per_call,derived")
    for key, fn in TABLES.items():
        if key not in only:
            continue
        t = time.perf_counter()
        kw = {"quick": not args.full}
        if (args.repeats is not None
                and "repeats" in inspect.signature(fn).parameters):
            kw["repeats"] = args.repeats
        fn(**kw)
        print(f"_meta/{key}_wall,{(time.perf_counter()-t)*1e6:.0f},done",
              flush=True)
    print(f"_meta/total_wall,{(time.perf_counter()-t0)*1e6:.0f},done")


if __name__ == "__main__":
    main()
