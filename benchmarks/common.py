"""Shared benchmark substrate: dataset proxies, clusters, CSV output.

The paper's SNAP datasets are billion-edge; the container is one CPU core.
Each dataset is replaced by a generator-matched proxy (same family, same
average degree, same skew mechanism — R-MAT for the scale-free graphs, a
lattice for roadNet), with machine counts scaled to keep |E|/p in a sane
regime.  Trends/orderings are the reproduction target (DESIGN.md §7).
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import Cluster, Machine, scaled_paper_cluster
from repro.data import rmat, road_mesh

_CACHE = {}


def dataset(name: str, quick: bool = True, bump: int = 0):
    """Proxy graphs: (name, paper dataset, family).

    ``bump`` raises the scale by that many steps beyond the quick/full
    baseline (rmat: +1 scale doubles |V|; mesh: side grows by 150 per
    step) — the engine benchmarks use ``bump=1`` to compare at one step
    past today's default.
    """
    key = (name, quick, bump)
    if key in _CACHE:
        return _CACHE[key]
    s = (0 if quick else 1) + bump     # +1 scale in --full mode
    specs = {
        # paper dataset: (scale, edge_factor) or mesh side
        "TW": ("rmat", 13 + s, 29),   # Twitter: extreme skew, dense
        "CO": ("rmat", 12 + s, 38),   # com-Orkut: dense social
        "LJ": ("rmat", 13 + s, 7),    # LiveJournal: avg deg ~13.6
        "PO": ("rmat", 12 + s, 9),    # Pokec
        "CP": ("rmat", 13 + s, 4),    # cit-Patents: sparse, mild skew
        "RN": ("mesh", 150 * (1 + s), 0),  # roadNet-CA: mesh-like
    }
    kind, a, b = specs[name]
    g = rmat(a, edge_factor=b, seed=42) if kind == "rmat" \
        else road_mesh(a, rewire=0.02, seed=42)
    _CACHE[key] = g
    return g


def cluster_for(name: str, g, slack: float = 1.8) -> Cluster:
    """Paper machine template: 1/5 super machines on big graphs, 1/3 else."""
    if name in ("TW", "CO"):
        return scaled_paper_cluster(2, 10, g.num_edges, slack=slack)
    return scaled_paper_cluster(3, 6, g.num_edges, slack=slack)


class CSV:
    """``name,us_per_call,derived`` rows, as benchmarks/run.py promises."""

    def __init__(self, table: str):
        self.table = table

    def row(self, name: str, seconds: float, derived):
        print(f"{self.table}/{name},{seconds*1e6:.0f},{derived}", flush=True)


def write_bench_json(path: str, metrics: dict) -> None:
    """Persist a flat ``{"table/metric": value}`` dict for CI trend gating.

    The tier-2 smoke jobs write their gateable numbers here
    (``BENCH_smoke.json``); ``benchmarks/check_trend.py`` compares them
    against the checked-in ``benchmarks/trend_baseline.json`` and the CI
    workflow uploads the file as an artifact — the repo's perf trajectory,
    one point per push.
    """
    import json
    flat = {}
    for k, v in metrics.items():
        if isinstance(v, (np.floating, np.integer)):
            v = v.item()
        flat[k] = v
    with open(path, "w") as f:
        json.dump(flat, f, indent=2, sort_keys=True)
    print(f"wrote {path} ({len(flat)} metrics)", flush=True)


def timed(fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, time.perf_counter() - t0


def repeat_timed(fn, repeats: int, *args, **kwargs):
    """Run ``fn`` ``repeats`` times; returns (last result, list of seconds).

    Container timing jitter is ±15%, so single-run numbers are not
    comparable across sessions — report ``median_iqr`` of these instead.
    """
    out, times = None, []
    for _ in range(max(1, repeats)):
        out, dt = timed(fn, *args, **kwargs)
        times.append(dt)
    return out, times


def median_iqr(times) -> tuple[float, float]:
    """(median, interquartile range) of a sample of seconds."""
    q1, med, q3 = np.percentile(np.asarray(times, dtype=np.float64),
                                [25.0, 50.0, 75.0])
    return float(med), float(q3 - q1)


def spread_str(times) -> str:
    """Human-readable ``median±IQR`` tag for CSV ``derived`` columns."""
    med, iqr = median_iqr(times)
    return f"median={med:.3f}s iqr={iqr:.3f}s n={len(times)}"
