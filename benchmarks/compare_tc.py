"""Paper Fig. 12 / Table 14: TC of WindGP vs all baselines, heterogeneous
machines, six dataset proxies."""
from __future__ import annotations

import numpy as np

from repro.core import evaluate, windgp
from repro.core.partitioners import get as partitioner

from .common import CSV, cluster_for, dataset, timed

DATASETS = ("TW", "CO", "LJ", "PO", "CP", "RN")
METHODS = ("hash", "dbh", "greedy", "hdrf", "ebv", "ne", "metis")


def run(quick: bool = True):
    csv = CSV("fig12_compare_tc")
    summary = {}
    for ds in DATASETS:
        g = dataset(ds, quick)
        cl = cluster_for(ds, g)
        tcs = {}
        for m in METHODS:
            assign, dt = timed(partitioner(m), g, cl)
            s = evaluate(g, assign, cl)
            tcs[m] = s.tc
            csv.row(f"{ds}/{m}", dt, f"TC={s.tc:.4e};RF={s.rf:.3f}")
        res, dt = timed(windgp, g, cl, t0=30, theta=0.02,
                        alpha=0.1, beta=0.1)
        tcs["windgp"] = res.stats.tc
        csv.row(f"{ds}/windgp", dt,
                f"TC={res.stats.tc:.4e};RF={res.stats.rf:.3f}")
        best_other = min(v for k, v in tcs.items() if k != "windgp")
        csv.row(f"{ds}/speedup_vs_best", 0,
                f"{best_other / tcs['windgp']:.2f}x")
        summary[ds] = best_other / tcs["windgp"]
    return summary
