"""Out-of-core vs in-memory partitioning: peak residency + wall at matched TC.

The out-of-core pipeline (two-pass spill dedup → graph-free block-stream
engine → on-disk ``StreamAssignment`` → ``PartitionRuntime.from_stream``)
must buy its bounded memory without giving up partition quality.  This
table runs both pipelines over the *same* duplicate-heavy edge-list file
and reports, per method:

* ``tc_gap``/``rf_gap`` — streamed vs in-memory partition quality on the
  identical deduplicated edge set (same metric layer:
  ``evaluate_membership``); the "matched TC" gate.
* ``peak_ratio`` — tracemalloc peak of the oocore pipeline over the
  in-memory pipeline (numpy registers its allocations with tracemalloc,
  so this sees the arrays; RSS high-water is printed alongside for
  context but is monotone per process, hence not a per-path metric).
* ``wall_ratio`` — end-to-end seconds, oocore over in-memory.
* ``spill_peak_frac`` — the dedup layer's own guarantee: peak resident
  edge rows over total spilled rows (``SpillStats`` accounting).

``--smoke`` is the tier-2 CI gate on a tiny proxy: asserts the quality
gaps and the residency bound, and emits ``BENCH_smoke.json`` for
``benchmarks/check_trend.py``.

Run:  PYTHONPATH=src python -m benchmarks.oocore [--smoke] [--json out.json]
"""
from __future__ import annotations

import pathlib
import shutil
import tempfile
import time
import tracemalloc

import numpy as np

from repro.bsp import PartitionRuntime, StreamAssignment
from repro.core import evaluate_membership, scaled_paper_cluster
from repro.core.partitioners import get as partitioner
from repro.data import TwoPassDedup, read_edge_list, rmat

from .common import CSV, write_bench_json

#: reader/spill granularity — small enough that duplicates genuinely
#: cross blocks on the proxy files (that is the machinery under test)
IO_BLOCK = 2048
BUCKET_ROWS = 4096


def _make_edgelist(tmp: pathlib.Path, scale: int, edge_factor: int,
                   dup_factor: int, seed: int = 42) -> pathlib.Path:
    """Write a shuffled, duplicate-heavy edge list (the adversarial input:
    per-block dedup misses almost every repeat)."""
    g = rmat(scale, edge_factor=edge_factor, seed=seed)
    rows = np.concatenate([g.edges] * dup_factor)
    rng = np.random.default_rng(seed)
    rows = rows[rng.permutation(len(rows))]
    path = tmp / f"rmat{scale}x{dup_factor}.txt"
    np.savetxt(path, rows, fmt="%d")
    return path


def _traced(fn):
    """(result, seconds, tracemalloc peak bytes) of one pipeline run."""
    tracemalloc.start()
    tracemalloc.reset_peak()
    t0 = time.perf_counter()
    out = fn()
    dt = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return out, dt, peak


def _in_memory_pipeline(path, method: str, cl_of):
    """read (materializes the raw rows) → blocked partitioner → runtime."""
    from repro.core import evaluate
    g = read_edge_list(path)
    cl = cl_of(g.num_edges)
    assign = partitioner(method)(g, cl)
    stats = evaluate(g, assign, cl)
    rt = PartitionRuntime.create(g, assign=assign, cluster=cl)
    return {"stats": stats, "rt": rt, "num_edges": g.num_edges}


def _oocore_pipeline(path, method: str, cl_of, workdir: pathlib.Path):
    """two-pass spill dedup → graph-free stream → shards → runtime."""
    tp = TwoPassDedup(path, workdir / "spill", block_size=IO_BLOCK,
                      bucket_rows=BUCKET_ROWS)
    num_v, num_e = tp.prepare()
    cl = cl_of(num_e)
    sa = StreamAssignment(workdir / "assign", cl.p, num_v)
    state = partitioner(method).stream(tp, num_v, num_e, cl, sink=sa.sink)
    sa.finalize(state, {"method": method, "dedup": "two_pass"})
    stats = evaluate_membership(state.cnt > 0, state.edges_per, cl)
    rt = PartitionRuntime.create(sa)
    return {"stats": stats, "rt": rt, "num_edges": num_e,
            "spill": tp.stats}


def _compare_one(path, method: str, csv: CSV, label: str,
                 workdir: pathlib.Path) -> dict:
    cl_of = lambda ne: scaled_paper_cluster(3, 6, ne, slack=1.8)
    mem, t_mem, peak_mem = _traced(
        lambda: _in_memory_pipeline(path, method, cl_of))
    ooc, t_ooc, peak_ooc = _traced(
        lambda: _oocore_pipeline(path, method, cl_of, workdir))
    assert ooc["num_edges"] == mem["num_edges"], "dedup disagreement"
    s_m, s_o, spill = mem["stats"], ooc["stats"], ooc["spill"]
    res = {
        "tc_gap": (s_o.tc - s_m.tc) / s_m.tc,
        "rf_gap": (s_o.rf - s_m.rf) / s_m.rf,
        "tc": float(s_o.tc), "rf": float(s_o.rf),
        "peak_ratio": peak_ooc / max(1, peak_mem),
        "wall_ratio": t_ooc / max(1e-9, t_mem),
        "spill_peak_frac": (spill.peak_resident_rows
                            / max(1, spill.spilled_rows)),
        "duplicate_rows": spill.duplicate_rows,
        "in_memory_seconds": t_mem, "oocore_seconds": t_ooc,
        "in_memory_peak_mb": peak_mem / 2**20,
        "oocore_peak_mb": peak_ooc / 2**20,
    }
    csv.row(f"{label}/{method}/in_memory", t_mem,
            f"tc={s_m.tc:.0f} rf={s_m.rf:.3f} peak={peak_mem/2**20:.1f}MB")
    csv.row(f"{label}/{method}/oocore", t_ooc,
            f"tc={s_o.tc:.0f} rf={s_o.rf:.3f} peak={peak_ooc/2**20:.1f}MB "
            f"tc_gap={res['tc_gap']*100:+.2f}% "
            f"rf_gap={res['rf_gap']*100:+.2f}% "
            f"peak_ratio={res['peak_ratio']:.2f} "
            f"spill_peak_frac={res['spill_peak_frac']:.3f}")
    # runtimes must describe the same partitioned graph
    assert int(ooc["rt"].edges_per_machine.sum()) == ooc["num_edges"]
    return res


def run(quick: bool = True, scale: int | None = None, edge_factor: int = 7,
        dup_factor: int = 3, methods=("hdrf", "greedy")) -> dict:
    scale = scale or (11 if quick else 13)
    csv = CSV("oocore")
    out = {}
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="oocore-bench-"))
    try:
        path = _make_edgelist(tmp, scale, edge_factor, dup_factor)
        for m in methods:
            work = tmp / m
            work.mkdir()
            out[m] = _compare_one(path, m, csv, f"rmat{scale}", work)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return out


def run_smoke(json_path: str | None = None) -> dict:
    """Tier-2 CI gate: tiny duplicate-heavy proxy, three assertions —
    streamed quality within 8% TC / 5% RF of the in-memory pipeline on the
    identical deduplicated edge set (the two pipelines consume different —
    equally random — stream orders, so a few percent of the gap is order
    luck on a proxy this small; ``benchmarks/check_trend.py`` tracks the
    exact deterministic value at a tighter bound), and the spill layer's
    peak edge residency under half the spilled rows (the out-of-core
    bound; the proxy is small, production ratios shrink with scale)."""
    res = run(quick=True, edge_factor=7, dup_factor=3, methods=("hdrf",))
    r = res["hdrf"]
    assert r["tc_gap"] <= 0.08 + 1e-9, (
        f"oocore TC {r['tc_gap']*100:+.2f}% > +8% vs in-memory")
    assert r["rf_gap"] <= 0.05 + 1e-9, (
        f"oocore RF {r['rf_gap']*100:+.2f}% > +5% vs in-memory")
    assert r["spill_peak_frac"] <= 0.5 + 1e-9, (
        f"spill peak residency {r['spill_peak_frac']:.3f} > 0.5 of the "
        f"spilled rows — the out-of-core bound regressed")
    if json_path:
        write_bench_json(json_path, {
            "oocore/tc_gap": r["tc_gap"],
            "oocore/rf_gap": r["rf_gap"],
            "oocore/tc": r["tc"],
            "oocore/spill_peak_frac": r["spill_peak_frac"],
            "oocore/peak_ratio": r["peak_ratio"],
            "oocore/wall_ratio": r["wall_ratio"],
            "oocore/duplicate_rows": int(r["duplicate_rows"]),
        })
    return res


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tier-2 CI gate: tiny proxy, asserts quality and "
                         "residency bounds")
    ap.add_argument("--json", default=None,
                    help="write gateable metrics to this path "
                         "(BENCH_smoke.json for CI)")
    ap.add_argument("--scale", type=int, default=None)
    ap.add_argument("--dup-factor", type=int, default=3)
    args = ap.parse_args()
    print("table/name,us_per_call,derived")
    if args.smoke:
        run_smoke(json_path=args.json)
    else:
        out = run(scale=args.scale, dup_factor=args.dup_factor)
        if args.json:
            flat = {f"oocore/{m}/{k}": v for m, r in out.items()
                    for k, v in r.items()}
            write_bench_json(args.json, flat)
