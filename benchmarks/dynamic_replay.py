"""Timeline replay for the dynamic incremental repartitioning layer.

Builds a temporal proxy from the LJ-family rmat graph: a seeded shuffle
of the edge list is the arrival order.  The first ``seed_frac`` of it is
the static seed graph (partitioned from scratch with ``method``); the
rest arrives in fixed-size insert batches, and every ``delete_every``-th
batch also deletes a same-sized sample of currently-live edges (churn,
including edges that only just arrived).  The timeline replays through
``DynamicPartitioner`` with the drift monitor timed *separately* from
placement (``auto_repair=False`` + explicit ``maybe_repair``), so the
report cleanly splits:

* **assignment latency** — p50/p99 of insert wall time / batch rows
  (µs per edge).  The engine waves only over the arriving batch against
  live membership, so per-edge latency is O(batch), not O(E): the
  first-half vs second-half p99 ratio (``lat_growth``) makes that
  visible as the live graph grows.
* **amortized repair cost** — drift-monitor + bounded-repair seconds and
  destroyed edges, divided by total mutations.  The frontier reset after
  each repair charges its cost to the mutations that accumulated it.
* **TC drift** — final live TC vs. partitioning the final graph from
  scratch with the same method at the same machine profile.  This is the
  quality gate: staying incremental must cost ≤ 5% TC (asserted in
  ``--smoke``, bounded by the trend baseline in CI).

Latency/speed numbers are printed and reported but never asserted — CI
wall clock is too noisy; ``check_trend.py`` bounds the deterministic
quality metrics (drift, TC, RF, repair-move fraction) instead.

Run directly:  PYTHONPATH=src python -m benchmarks.dynamic_replay [--smoke]
"""
from __future__ import annotations

import numpy as np

from repro.core import DynamicPartitioner, evaluate, from_edge_list
from repro.core.partitioners import get as partitioner

from .common import CSV, cluster_for, dataset, timed, write_bench_json


def replay_timeline(g, cl, *, method: str = "hdrf", batch: int = 512,
                    seed_frac: float = 0.65, delete_every: int = 4,
                    seed: int = 7, rf_leash: float = 1.03,
                    csv: CSV | None = None, label: str = "LJ") -> dict:
    """Replay one timeline; returns the metrics dict (see module doc).

    ``rf_leash`` goes straight into the monitor's adaptive leash (it
    re-anchors to the measured RF after every repair epoch), tightened
    from the 1.15 deployment default so a proxy-length timeline still
    exercises the repair path."""
    rng = np.random.default_rng(seed)
    edges = g.edges[rng.permutation(g.num_edges)]
    n_seed = int(seed_frac * len(edges))
    gseed = from_edge_list(edges[:n_seed], num_vertices=g.num_vertices)
    dp, t_seed = timed(DynamicPartitioner, gseed, cl, method=method,
                       rf_leash=rf_leash, auto_repair=False)

    lat = []                      # per-edge insert seconds, one per batch
    repair_s = 0.0
    mutations = 0
    pos, nb = 0, 0
    arrivals = edges[n_seed:]
    while pos < len(arrivals):
        b = arrivals[pos:pos + batch]
        pos += len(b)
        nb += 1
        _, dt = timed(dp.insert, b)
        lat.append(dt / len(b))
        mutations += len(b)
        _, rdt = timed(dp.maybe_repair)
        repair_s += rdt
        if delete_every and nb % delete_every == 0:
            live = np.flatnonzero(dp.state.assign >= 0)
            sel = rng.choice(live, size=min(batch, len(live)),
                             replace=False)
            dp.delete(dp.g.edges[sel])
            mutations += len(sel)
            _, rdt = timed(dp.maybe_repair)
            repair_s += rdt

    # scratch re-partition of the final live graph, same machine profile
    live = dp.state.assign >= 0
    gfin = from_edge_list(dp.g.edges[live], num_vertices=dp.g.num_vertices)
    a_scr, t_scr = timed(partitioner(method), gfin, cl)
    s_scr = evaluate(gfin, a_scr, cl)

    lat = np.asarray(lat)
    half = max(1, len(lat) // 2)
    p50, p99 = np.percentile(lat, [50, 99])
    res = {
        "tc": float(dp.tc),
        "tc_scratch": float(s_scr.tc),
        "tc_drift": float((dp.tc - s_scr.tc) / s_scr.tc),
        "rf": float(dp.rf),
        "p50_us": float(p50 * 1e6),
        "p99_us": float(p99 * 1e6),
        # O(batch) evidence: p99 late-half / early-half as |E| grows
        "lat_growth": float(np.percentile(lat[half:], 99)
                            / max(np.percentile(lat[:half], 99), 1e-12)),
        "repair_us_per_op": float(repair_s / max(1, mutations) * 1e6),
        "repair_moves_frac": float(dp.counters["repair_moves"]
                                   / max(1, mutations)),
        "repairs": len([r for r in dp.repairs if r.edges_moved]),
        "mutations": int(mutations),
        "inserted": dp.counters["inserted"],
        "deleted": dp.counters["deleted"],
        "reinserted": dp.counters["reinserted"],
        "seed_seconds": float(t_seed),
        "scratch_seconds": float(t_scr),
    }
    if csv is not None:
        csv.row(f"{label}/{method}/assign_p50", p50,
                f"p99={res['p99_us']:.1f}us growth={res['lat_growth']:.2f}x")
        csv.row(f"{label}/{method}/repair", repair_s / max(1, mutations),
                f"{res['repair_us_per_op']:.1f}us/op "
                f"moves={dp.counters['repair_moves']} "
                f"({res['repair_moves_frac'] * 100:.2f}%/op) "
                f"waves={res['repairs']}")
        csv.row(f"{label}/{method}/tc_drift", 0,
                f"tc={res['tc']:.0f} scratch={res['tc_scratch']:.0f} "
                f"drift={res['tc_drift'] * 100:+.2f}% rf={res['rf']:.3f}")
    return res


def run_smoke(json_path: str | None = None) -> dict:
    """Tier-2 CI ``dynamic`` job: quick-LJ timeline, one assertion —
    final incremental TC within 5% of the same-method scratch partition
    at the same machine profile.  Placement, churn, and repair triggers
    are all seed-deterministic, so TC/RF/drift/move-fraction are exact
    across runs and bounded by the trend baseline."""
    csv = CSV("dynamic_smoke")
    g = dataset("LJ", quick=True)
    cl = cluster_for("LJ", g)
    res = replay_timeline(g, cl, csv=csv, label="tiny_lj")
    # one-sided: the repair waves routinely push the incremental TC
    # *below* scratch (scratch streaming has no SLS pass) — only being
    # worse than scratch is drift
    assert res["tc_drift"] <= 0.05 + 1e-9, (
        f"incremental TC drifted {res['tc_drift'] * 100:+.2f}% "
        f"(> +5%) above the scratch partition")
    csv.row("tiny_lj/ok", 0,
            f"drift={res['tc_drift'] * 100:+.2f}% "
            f"p99={res['p99_us']:.1f}us "
            f"repair={res['repair_us_per_op']:.1f}us/op")
    if json_path:
        write_bench_json(json_path, {
            "dynamic/tc_drift": res["tc_drift"],
            "dynamic/tc": res["tc"],
            "dynamic/rf": res["rf"],
            "dynamic/repair_moves_frac": res["repair_moves_frac"],
            # latency numbers ride along untracked (CI wall clock)
            "dynamic/p50_us": res["p50_us"],
            "dynamic/p99_us": res["p99_us"],
            "dynamic/lat_growth": res["lat_growth"],
            "dynamic/repair_us_per_op": res["repair_us_per_op"],
        })
    return res


def run(quick: bool = True, datasets=("LJ", "TW"),
        methods=("hdrf", "greedy")) -> dict:
    """The replay table: per dataset × method latency/repair/drift rows."""
    csv = CSV("dynamic_replay")
    out = {}
    for ds in datasets:
        g = dataset(ds, quick)
        cl = cluster_for(ds, g)
        out[ds] = {m: replay_timeline(g, cl, method=m, csv=csv, label=ds)
                   for m in methods}
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tier-2 CI gate: quick-LJ timeline, asserts "
                         "incremental TC within 5% of scratch")
    ap.add_argument("--json", default=None,
                    help="--smoke: write gateable metrics to this path "
                         "(BENCH_smoke.json for CI)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print("table/name,us_per_call,derived")
    if args.smoke:
        run_smoke(json_path=args.json)
    else:
        run(quick=not args.full)
