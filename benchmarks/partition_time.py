"""Paper Tables 11/18: partitioning executing time of every method.

Two sections:

* ``tab11_partition_time`` — the paper table: every baseline + windgp per
  dataset (windgp runs its default ``batched`` engine).
* ``engine_compare``      — heap vs batched expansion engine side by side
  on the TW/LJ/RN proxies at one scale step *larger* than the default
  (``bump=1``), reporting per-engine partition time, the speedup, and the
  relative TC gap (the acceptance gate: ≥5× on LJ with |ΔTC| ≤ 2%).
"""
from __future__ import annotations

from repro.core import windgp
from repro.core.baselines import PARTITIONERS

from .common import CSV, cluster_for, dataset, timed

ENGINE_DATASETS = ("TW", "LJ", "RN")


def run_engine_compare(quick: bool = True, datasets=ENGINE_DATASETS,
                       level: str = "windgp+", repeats: int = 5):
    """heap vs batched on +1-scale proxies; returns per-dataset metrics.

    ``windgp+`` isolates preprocessing + expansion (the phase the engine
    rewrite targets); pass ``level="windgp"`` to include SLS (both engines
    then also drive Algorithm 7's re-expansion through the same switch).
    Each engine runs ``repeats`` times; best-of wins (same treatment for
    both, so the ratio is allocation/GC-noise free).
    """
    csv = CSV("engine_compare")
    out = {}
    for ds in datasets:
        g = dataset(ds, quick, bump=1)
        cl = cluster_for(ds, g)
        res = {}
        for engine in ("heap", "batched"):
            best = None
            for _ in range(max(1, repeats)):
                r = windgp(g, cl, t0=8, alpha=0.1, beta=0.1,
                           level=level, engine=engine)
                if best is None or (r.phase_seconds["expand"]
                                    < best.phase_seconds["expand"]):
                    best = r
            # the expand phase is the noise-controlled (best-of) quantity;
            # total seconds ride along as context only
            res[engine] = {"seconds": best.seconds,
                           "expand_seconds": best.phase_seconds["expand"],
                           "tc": float(best.stats.tc)}
            csv.row(f"{ds}/{engine}", best.phase_seconds["expand"],
                    f"total={best.seconds:.2f}s "
                    f"tc={best.stats.tc:.0f}")
        speedup = (res["heap"]["expand_seconds"]
                   / max(res["batched"]["expand_seconds"], 1e-9))
        dtc = (res["batched"]["tc"] - res["heap"]["tc"]) / res["heap"]["tc"]
        csv.row(f"{ds}/speedup", 0, f"{speedup:.2f}x")
        csv.row(f"{ds}/tc_gap", 0, f"{dtc * 100:+.2f}%")
        res["speedup"], res["tc_gap"] = speedup, dtc
        out[ds] = res
    return out


def run(quick: bool = True, datasets=("CO", "LJ", "PO", "CP", "RN")):
    csv = CSV("tab11_partition_time")
    out = {}
    for ds in datasets:
        g = dataset(ds, quick)
        cl = cluster_for(ds, g)
        times = {}
        for m in ("hdrf", "ne", "ebv", "metis"):
            _, dt = timed(PARTITIONERS[m], g, cl)
            times[m] = dt
            csv.row(f"{ds}/{m}", dt, f"{dt:.2f}s")
        _, dt = timed(windgp, g, cl, t0=8, alpha=0.1, beta=0.1)
        times["windgp"] = dt
        csv.row(f"{ds}/windgp", dt, f"{dt:.2f}s")
        csv.row(f"{ds}/windgp_vs_ne", 0,
                f"{times['windgp'] / max(times['ne'], 1e-9):.2f}x")
        out[ds] = times
    return out
