"""Paper Tables 11/18: partitioning executing time of every method."""
from __future__ import annotations

from repro.core import windgp
from repro.core.baselines import PARTITIONERS

from .common import CSV, cluster_for, dataset, timed


def run(quick: bool = True, datasets=("CO", "LJ", "PO", "CP", "RN")):
    csv = CSV("tab11_partition_time")
    out = {}
    for ds in datasets:
        g = dataset(ds, quick)
        cl = cluster_for(ds, g)
        times = {}
        for m in ("hdrf", "ne", "ebv", "metis"):
            _, dt = timed(PARTITIONERS[m], g, cl)
            times[m] = dt
            csv.row(f"{ds}/{m}", dt, f"{dt:.2f}s")
        _, dt = timed(windgp, g, cl, t0=8, alpha=0.1, beta=0.1)
        times["windgp"] = dt
        csv.row(f"{ds}/windgp", dt, f"{dt:.2f}s")
        csv.row(f"{ds}/windgp_vs_ne", 0,
                f"{times['windgp'] / max(times['ne'], 1e-9):.2f}x")
        out[ds] = times
    return out
