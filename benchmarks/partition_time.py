"""Paper Tables 11/18: partitioning executing time of every method.

Four sections:

* ``tab11_partition_time`` — the paper table: every baseline + windgp per
  dataset (windgp runs its default ``batched`` engine).
* ``engine_compare``      — heap vs batched expansion engine side by side
  on the TW/LJ/RN proxies at one scale step *larger* than the default
  (``bump=1``), reporting per-engine partition time (median of
  ``repeats`` with IQR spread), the speedup, the relative TC gap (the
  acceptance gate: ≥5× on LJ with |ΔTC| ≤ 2%), and the degree-split
  frontier ablation (batched with ``hub_split`` off vs on — identical TC
  by construction, so only the time moves).
* ``sls_compare``         — scalar vs vectorized destroy–repair sweeps on
  the same initial partition (gate: ≥3× on LJ with TC within 2% of the
  scalar oracle).
* ``streaming_compare``   — per-edge streaming oracles (greedy/HDRF/EBV)
  vs the block-stream engine across block sizes (gate: ≥5× on LJ at the
  default block with RF and TC within 2% of the stream-order oracle).
* ``--smoke``             — tier-2 CI gate on a tiny proxy: asserts the
  vectorized SLS lands within 2% TC of the scalar oracle AND the block
  engine within 2% RF/TC of each per-edge streaming oracle.

Run directly:  PYTHONPATH=src python -m benchmarks.partition_time [--smoke]
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import capacities, evaluate, scaled_paper_cluster, windgp
from repro.core import expand as exp_mod
from repro.core import sls as sls_mod
from repro.core.partition_state import PartitionState
from repro.core.partitioners import get as partitioner
from repro.data import rmat

from .common import (CSV, cluster_for, dataset, median_iqr, spread_str,
                     timed, write_bench_json)

ENGINE_DATASETS = ("TW", "LJ", "RN")

#: block-stream scorers with a per-edge reference loop
STREAM_METHODS = ("greedy", "hdrf", "ebv")


def _stream_compare_one(g, cl, csv: CSV, label: str, method: str, *,
                        block_sizes=None, repeats: int = 3) -> dict:
    """Per-edge oracle vs block engine on one graph; returns the metrics.

    ``block_sizes=None`` sweeps the method's auto default plus a 4× -
    coarser step (the staleness ablation)."""
    if block_sizes is None:
        b0 = _default_block(method, g.num_edges)
        block_sizes = (b0, 4 * b0)
    oracle = partitioner(f"{method}_oracle")
    blocked = partitioner(method)
    res = {}
    timings = {"oracle": []}
    timings.update({f"B{b}": [] for b in block_sizes})
    runs = {}
    for _ in range(max(1, repeats)):   # interleaved, like run_engine_compare
        t0 = time.perf_counter()
        runs["oracle"] = oracle(g, cl)
        timings["oracle"].append(time.perf_counter() - t0)
        for b in block_sizes:
            t0 = time.perf_counter()
            runs[f"B{b}"] = blocked(g, cl, block_size=b)
            timings[f"B{b}"].append(time.perf_counter() - t0)
    s_orc = evaluate(g, runs["oracle"], cl)
    t_orc, _ = median_iqr(timings["oracle"])
    csv.row(f"{label}/{method}/oracle", t_orc,
            f"{spread_str(timings['oracle'])} tc={s_orc.tc:.0f} "
            f"rf={s_orc.rf:.3f}")
    res["oracle"] = {"seconds": t_orc, "tc": s_orc.tc, "rf": s_orc.rf}
    for b in block_sizes:
        s = evaluate(g, runs[f"B{b}"], cl)
        t_b, _ = median_iqr(timings[f"B{b}"])
        speed = t_orc / max(t_b, 1e-9)
        d_tc = (s.tc - s_orc.tc) / s_orc.tc
        d_rf = (s.rf - s_orc.rf) / s_orc.rf
        csv.row(f"{label}/{method}/block{b}", t_b,
                f"{spread_str(timings[f'B{b}'])} {speed:.2f}x "
                f"tc={d_tc * 100:+.2f}% rf={d_rf * 100:+.2f}%")
        res[b] = {"seconds": t_b, "speedup": speed,
                  "tc_gap": d_tc, "rf_gap": d_rf,
                  "tc": float(s.tc), "rf": float(s.rf)}
    return res


def run_streaming_compare(quick: bool = True, datasets=ENGINE_DATASETS,
                          block_sizes=None, repeats: int = 3):
    """Per-edge oracles vs the block-stream engine across block sizes.

    The acceptance gate lives on LJ at each method's default block size:
    ≥ 5× the per-edge loop with RF and TC within 2% of the stream-order
    oracle (``block_size=1`` bit-equality is a unit test, not a timing
    table).
    """
    csv = CSV("streaming_compare")
    out = {}
    for ds in datasets:
        g = dataset(ds, quick)
        cl = cluster_for(ds, g)
        out[ds] = {m: _stream_compare_one(g, cl, csv, ds, m,
                                          block_sizes=block_sizes,
                                          repeats=repeats)
                   for m in STREAM_METHODS}
    return out


def run_engine_compare(quick: bool = True, datasets=ENGINE_DATASETS,
                       level: str = "windgp+", repeats: int = 5):
    """heap vs batched on +1-scale proxies; returns per-dataset metrics.

    ``windgp+`` isolates preprocessing + expansion (the phase the engine
    rewrite targets); pass ``level="windgp"`` to include SLS (both engines
    then also drive Algorithm 7's re-expansion through the same switch).
    Each variant runs ``repeats`` times; the median is the headline number
    and the IQR spread is printed so cross-session numbers are comparable
    (same treatment for every variant, so the ratios are noise-controlled).
    """
    csv = CSV("engine_compare")
    out = {}
    variants = (("heap", {"engine": "heap"}),
                ("batched", {"engine": "batched"}),
                ("batched_nohub", {"engine": "batched", "hub_split": False}))
    for ds in datasets:
        g = dataset(ds, quick, bump=1)
        cl = cluster_for(ds, g)
        res = {}
        timings = {name: [] for name, _ in variants}
        runs = {}
        # interleave repeats across variants: machine-load drift then hits
        # every variant equally instead of biasing whichever runs last
        for _ in range(max(1, repeats)):
            for name, kw in variants:
                r = windgp(g, cl, t0=8, alpha=0.1, beta=0.1,
                           level=level, **kw)
                timings[name].append(r.phase_seconds["expand"])
                runs[name] = r
        for name, _ in variants:
            times, r = timings[name], runs[name]
            med, _ = median_iqr(times)
            # the expand phase is the noise-controlled quantity; total
            # seconds ride along as context only
            res[name] = {"seconds": r.seconds, "expand_seconds": med,
                         "expand_times": times, "tc": float(r.stats.tc)}
            csv.row(f"{ds}/{name}", med,
                    f"{spread_str(times)} total={r.seconds:.2f}s "
                    f"tc={r.stats.tc:.0f}")
        speedup = (res["heap"]["expand_seconds"]
                   / max(res["batched"]["expand_seconds"], 1e-9))
        dtc = (res["batched"]["tc"] - res["heap"]["tc"]) / res["heap"]["tc"]
        hub_gain = (res["batched_nohub"]["expand_seconds"]
                    / max(res["batched"]["expand_seconds"], 1e-9))
        csv.row(f"{ds}/speedup", 0, f"{speedup:.2f}x")
        csv.row(f"{ds}/tc_gap", 0, f"{dtc * 100:+.2f}%")
        csv.row(f"{ds}/hub_split_gain", 0, f"{hub_gain:.2f}x")
        res["speedup"], res["tc_gap"], res["hub_gain"] = speedup, dtc, hub_gain
        out[ds] = res
    return out


def _sls_compare_one(g, cl, csv: CSV, label: str, *, repeats: int = 3,
                     sweeps: int = 6, gamma: float = 0.9,
                     theta: float = 0.05) -> dict:
    """Time ``sweeps`` destroy–repair sweeps, scalar vs vectorized, from
    the *same* post-expansion partition (θ above the paper default so the
    repair phase, not the destroy bookkeeping, dominates)."""
    deltas = capacities(cl, g.num_vertices, g.num_edges)
    assign, orders = exp_mod.run_expansion(
        g, deltas, 0.1, 0.1, memories=cl.memory(),
        m_node=cl.m_node, m_edge=cl.m_edge, engine="batched")
    obj0 = PartitionState.build(g, assign, cl)
    sls_mod.repair_edges(obj0, np.flatnonzero(assign < 0), orders)
    base = obj0.assign.copy()

    res = {mode: {"times": [], "tc": None}
           for mode in ("scalar", "vectorized")}
    for _ in range(max(1, repeats)):    # interleaved: see run_engine_compare
        for mode in ("scalar", "vectorized"):
            obj = PartitionState.build(g, base, cl)
            ords = [list(o) for o in orders]
            t0 = time.perf_counter()
            for _ in range(sweeps):
                sls_mod.destroy_repair(obj, ords, gamma, theta, None,
                                       strict=(mode == "scalar"))
            res[mode]["times"].append(time.perf_counter() - t0)
            res[mode]["tc"] = obj.tc
    for mode in ("scalar", "vectorized"):
        times, tc = res[mode]["times"], res[mode]["tc"]
        med, _ = median_iqr(times)
        res[mode]["sweep_seconds"] = med
        csv.row(f"{label}/{mode}", med, f"{spread_str(times)} tc={tc:.0f}")
    speedup = (res["scalar"]["sweep_seconds"]
               / max(res["vectorized"]["sweep_seconds"], 1e-9))
    tc_gap = ((res["vectorized"]["tc"] - res["scalar"]["tc"])
              / res["scalar"]["tc"])
    csv.row(f"{label}/speedup", 0, f"{speedup:.2f}x")
    csv.row(f"{label}/tc_gap", 0, f"{tc_gap * 100:+.2f}%")
    res["speedup"], res["tc_gap"] = speedup, tc_gap
    return res


def run_sls_compare(quick: bool = True, datasets=("LJ", "TW"),
                    repeats: int = 3):
    """Scalar vs vectorized destroy–repair (gate: ≥3× on LJ, |ΔTC| ≤ 2%)."""
    csv = CSV("sls_compare")
    out = {}
    for ds in datasets:
        g = dataset(ds, quick, bump=1)
        cl = cluster_for(ds, g)
        out[ds] = _sls_compare_one(g, cl, csv, ds, repeats=repeats)
    return out


def run_smoke(only: str | None = None,
              json_path: str | None = None) -> dict:
    """Tier-2 CI gate on a tiny LJ-family proxy, two assertions:

    * vectorized SLS destroy–repair within 2% TC of the scalar oracle;
    * the block-stream engine within 2% RF *and* TC of each per-edge
      streaming oracle at the default block size.

    ``only`` runs one gate (``"sls"`` / ``"streaming"`` / ``"windgp"`` —
    the last bounds the full pipeline's absolute TC/RF rather than a
    phase) — the CI tier-2 matrix runs them as separate jobs so one slow
    gate doesn't mask the other.  ``json_path`` writes the gateable
    metrics for ``benchmarks/check_trend.py`` (the perf-trajectory
    artifact).

    Speedups are printed and tracked but not asserted here — CI
    wall-clock is too noisy for a hard gate; the trend baseline bounds
    the quality metrics instead.
    """
    g = rmat(11, edge_factor=7, seed=42)
    cl = scaled_paper_cluster(3, 6, g.num_edges)
    out = {}
    metrics = {}
    if only in (None, "sls"):
        csv = CSV("sls_smoke")
        res = _sls_compare_one(g, cl, csv, "tiny_lj", repeats=2, sweeps=4)
        assert res["tc_gap"] <= 0.02 + 1e-9, (
            f"vectorized SLS TC regressed {res['tc_gap'] * 100:+.2f}% "
            f"(> +2%) vs the scalar oracle")
        csv.row("tiny_lj/ok", 0,
                f"tc_gap={res['tc_gap'] * 100:+.2f}% "
                f"speedup={res['speedup']:.2f}x")
        out["sls"] = res
        metrics["sls/tc_gap"] = res["tc_gap"]
        metrics["sls/speedup"] = res["speedup"]
    if only in (None, "streaming"):
        scsv = CSV("stream_smoke")
        for m in STREAM_METHODS:
            b = _default_block(m, g.num_edges)
            r = _stream_compare_one(g, cl, scsv, "tiny_lj", m,
                                    block_sizes=(b,), repeats=2)
            assert r[b]["tc_gap"] <= 0.02 + 1e-9, (
                f"block-stream {m} TC {r[b]['tc_gap'] * 100:+.2f}% > +2% "
                f"vs the per-edge oracle")
            assert r[b]["rf_gap"] <= 0.02 + 1e-9, (
                f"block-stream {m} RF {r[b]['rf_gap'] * 100:+.2f}% > +2% "
                f"vs the per-edge oracle")
            scsv.row(f"tiny_lj/{m}/ok", 0,
                     f"tc={r[b]['tc_gap'] * 100:+.2f}% "
                     f"rf={r[b]['rf_gap'] * 100:+.2f}% "
                     f"speedup={r[b]['speedup']:.2f}x")
            out[m] = r
            metrics[f"stream/{m}/tc_gap"] = r[b]["tc_gap"]
            metrics[f"stream/{m}/rf_gap"] = r[b]["rf_gap"]
            metrics[f"stream/{m}/speedup"] = r[b]["speedup"]
            # absolute quality level (deterministic seeds): the
            # perf-trajectory baseline bounds it directly, not just the
            # oracle-relative gap
            metrics[f"stream/{m}/tc"] = r[b]["tc"]
    if only in (None, "windgp"):
        # end-to-end windgp TC on the deterministic proxy — ROADMAP names
        # this as the untracked gap in check_trend.py: the sls/streaming
        # gates bound phases, nothing bounded the full pipeline's output
        wcsv = CSV("windgp_smoke")
        r, dt = timed(windgp, g, cl, t0=8, alpha=0.1, beta=0.1)
        s = r.stats
        assert s.feasible, "windgp smoke produced an infeasible partition"
        wcsv.row("tiny_lj/windgp", dt,
                 f"tc={s.tc:.0f} rf={s.rf:.3f} feasible={s.feasible}")
        out["windgp"] = {"seconds": dt, "tc": float(s.tc),
                         "rf": float(s.rf)}
        metrics["windgp/tc"] = float(s.tc)
        metrics["windgp/rf"] = float(s.rf)
    if only is not None and not out:
        raise SystemExit(f"unknown smoke gate {only!r} "
                         f"(choices: sls, streaming, windgp)")
    if json_path:
        write_bench_json(json_path, metrics)
    return out


def _default_block(method: str, num_edges: int) -> int:
    """The effective default ``block_size`` of a blocked method."""
    from repro.core.baselines.streaming import (ENGINE_DEFAULTS,
                                                auto_block_size)
    return int(ENGINE_DEFAULTS[method]["block_size"]
               or auto_block_size(num_edges))


def run(quick: bool = True, datasets=("CO", "LJ", "PO", "CP", "RN")):
    csv = CSV("tab11_partition_time")
    out = {}
    for ds in datasets:
        g = dataset(ds, quick)
        cl = cluster_for(ds, g)
        times = {}
        for m in ("hdrf", "ne", "ebv", "metis"):
            _, dt = timed(partitioner(m), g, cl)
            times[m] = dt
            csv.row(f"{ds}/{m}", dt, f"{dt:.2f}s")
        _, dt = timed(windgp, g, cl, t0=8, alpha=0.1, beta=0.1)
        times["windgp"] = dt
        csv.row(f"{ds}/windgp", dt, f"{dt:.2f}s")
        csv.row(f"{ds}/windgp_vs_ne", 0,
                f"{times['windgp'] / max(times['ne'], 1e-9):.2f}x")
        out[ds] = times
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tier-2 CI gate: tiny proxy, asserts vectorized "
                         "SLS TC within 2% of the scalar oracle and the "
                         "block-stream engine within 2% RF/TC of the "
                         "per-edge streaming oracles")
    ap.add_argument("--only", default=None,
                    choices=("sls", "streaming", "windgp"),
                    help="--smoke: run a single gate (the CI tier-2 "
                         "matrix splits them across jobs)")
    ap.add_argument("--json", default=None,
                    help="--smoke: write gateable metrics to this path "
                         "(BENCH_smoke.json for CI)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    print("table/name,us_per_call,derived")
    if args.smoke:
        run_smoke(only=args.only, json_path=args.json)
    else:
        run(quick=not args.full)
        run_engine_compare(quick=not args.full, repeats=args.repeats)
        run_sls_compare(quick=not args.full, repeats=args.repeats)
        run_streaming_compare(quick=not args.full, repeats=args.repeats)
