"""GNN minibatch sampling: throughput, halo fraction, cache, prefetch.

The sampling service (``repro.sampling``) is the workload partition
quality is *for* in GNN training: every minibatch expands a k-hop
neighborhood against machine-owned CSC shards, and each frontier vertex
owned elsewhere is a cross-machine halo fetch.  This benchmark makes
that observable end-to-end, including the feature tensor path:

* ``--smoke`` (the tier-2 ``sampling`` CI job) gates
  - the jax sampler against its NumPy oracle — bitwise on the same key,
    both with- and without-replacement;
  - the fused k-hop dispatch against the hop-at-a-time reference loop —
    bitwise on the LJ proxy, and >= 3x its minibatch throughput (the
    reference keeps the original per-hop dispatch pattern + argsort
    selection, so the ratio measures what fusion + the top_k lowering
    buy);
  - halo-fetch fraction on the LJ proxy: windgp (locality-optimized)
    must beat hash (locality-free) strictly, with hdrf in between as
    context;
  - the halo feature cache: cached resolve bitwise == uncached; at an
    equal pure-LRU budget windgp's hit rate >= hash's and its miss
    traffic per sampled vertex is strictly lower (locality must reach
    the feature path too; hub-tier hit rates ride along tracked —
    a degree-ranked hub tier pins what hash fails to localize, so it
    compresses the conditional-hit-rate spread between methods);
  - prefetch-pipeline determinism: depth 0 and depth 2 produce bitwise
    identical (batch, features) streams (speedup recorded, ungated —
    CI runners time-slice threads);
  - the training-aware knob: ``train_balance`` must reduce the
    max/mean train-vertex skew vs the unbalanced default;
  - samples/sec on the LJ proxy, median of 5 — one-sided floor in the
    trend baseline (see below).
* ``--pipeline`` times sync (depth=0) vs prefetch at depth in {1,2,4}.
* ``--cache-study`` sweeps hit-rate and miss-traffic vs budget per
  partitioner, pure-LRU and with a half-budget static hub tier.
* ``--full`` adds samples/sec vs machine count and a fanout sweep.

Wall-clock variance: the samples/sec floor was promoted from
tracked-ungated after characterizing the smoke job's spread — 5
back-to-back in-process repeats land within ~5% IQR/median on the dev
container, and cross-run medians within ~15%; CI hardware differs from
the container by up to ~2x, so the baseline floor sits at ~3x below the
dev-container median (one-sided: only a collapse fails, faster runners
never do).  The per-run IQR fraction rides along tracked-ungated so the
tolerance itself stays observable.

Run:  PYTHONPATH=src python -m benchmarks.sampling_service --smoke \
          --json BENCH_smoke.json
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import scaled_paper_cluster
from repro.core.partition_state import edge_incidence_counts
from repro.core.partitioners import get as partitioner
from repro.data import rmat
from repro.sampling import (FeatureStore, HaloCache, PrefetchPipeline,
                            SamplingService, sample_fanout,
                            sample_fanout_np)

from .common import (CSV, cluster_for, dataset, median_iqr, repeat_timed,
                     write_bench_json)

FANOUTS = (10, 5)
BATCH = 64
FEAT_DIM = 64

METHOD_KNOBS = (("windgp", dict(t0=8, alpha=0.1, beta=0.1)),
                ("hdrf", {}), ("hash", {}))


def _service(g, cl, method, fanouts=FANOUTS, **knobs) -> SamplingService:
    return SamplingService.create(g, method=method, cluster=cl,
                                  fanouts=fanouts, **knobs)


def _store(svc: SamplingService, feat_dim: int = FEAT_DIM) -> FeatureStore:
    """Deterministic synthetic features — same bits for every method, so
    cache/hit comparisons isolate the partition, not the data."""
    rng = np.random.default_rng(0)
    feats = rng.standard_normal(
        (svc.csc.num_vertices, feat_dim)).astype(np.float32)
    return FeatureStore.build(svc, feats)


def _halo_stats(svc: SamplingService, key, batches: int = 2):
    """Mean halo-fetch fraction over every machine's minibatches, plus
    total sampled entries (the numerator of samples/sec)."""
    halo = frontier = sampled = 0
    for home in range(svc.p):
        if svc.csc.owned_per[home] == 0:
            continue
        for b in range(batches):
            k_seed, k_hop = jax.random.split(
                jax.random.fold_in(jax.random.fold_in(key, home), b))
            seeds = svc.local_seeds(home, BATCH, k_seed)
            mb = svc.sample(seeds, k_hop, home=home)
            for s in mb.hop_stats:
                halo += s.halo
                frontier += s.frontier
            sampled += mb.num_sampled()
    return halo / max(1, frontier), sampled


def _samples_per_sec(svc: SamplingService, key, batches: int = 6,
                     fused: bool = True) -> float:
    """Warm-started sampling throughput on machine 0's seeds."""
    seeds = svc.local_seeds(0, BATCH, jax.random.fold_in(key, 999))
    svc.sample(seeds, key, home=0, fused=fused)   # compile/warm the shapes
    t0 = time.perf_counter()
    n = 0
    for b in range(batches):
        mb = svc.sample(seeds, jax.random.fold_in(key, b), home=0,
                        fused=fused)
        n += mb.num_sampled()
    return n / max(time.perf_counter() - t0, 1e-9)


def _train_skew(g, assign, p, train_mask) -> float:
    """max/mean of per-machine hosted-train-vertex counts."""
    member = edge_incidence_counts(g, assign, p) > 0
    counts = member[:, train_mask].sum(axis=1).astype(np.float64)
    return float(counts.max() / max(counts.mean(), 1e-9))


def _pipeline_bps(svc, store, depth: int, num_batches: int, key,
                  cache_budget: int = 1024) -> float:
    """Batches/sec through a fresh pipeline at the given depth (its own
    cache, so every depth sees the identical cold-start sequence)."""
    cache = HaloCache.for_home(store, 0, capacity=cache_budget)
    with PrefetchPipeline(svc, home=0, batch_size=BATCH,
                          num_batches=num_batches, key=key, depth=depth,
                          store=store, cache=cache) as pl:
        next(pl)                      # warm compile outside the clock
        t0 = time.perf_counter()
        n = sum(1 for _ in pl)
    return n / max(time.perf_counter() - t0, 1e-9)


def _cache_run(svc, store, budget: int, key, batches: int = 4,
               hub_frac: float = 0.0):
    """(hit_rate, misses_per_sampled) over every machine's batch stream
    at one cache budget (fresh per-home caches — DistDGL's one-cache-
    per-trainer shape).  ``hit_rate`` is conditional on an access being
    remote; ``misses_per_sampled`` is the actual fetch traffic per
    sampled vertex, which also credits a partition for having fewer
    remote accesses in the first place."""
    hits = misses = sampled = 0
    for home in range(svc.p):
        if svc.csc.owned_per[home] == 0:
            continue
        cache = HaloCache.for_home(store, home, capacity=budget,
                                   hub_frac=hub_frac)
        for b in range(batches):
            k_seed, k_hop = jax.random.split(
                jax.random.fold_in(jax.random.fold_in(key, home), b))
            seeds = svc.local_seeds(home, BATCH, k_seed)
            mb = svc.sample(seeds, k_hop, home=home)
            _, st = store.gather(mb.all_ids(), home, cache)
            hits += st.hits
            misses += st.misses
            sampled += mb.num_sampled()
    return hits / max(1, hits + misses), misses / max(1, sampled)


def _assert_batch_equal(a, b, what: str) -> None:
    (ma, fa), (mb, fb) = a, b
    assert np.array_equal(ma.seeds, mb.seeds), what
    for ha, hb in zip(ma.hops, mb.hops):
        assert np.array_equal(ha, hb), what
    assert ma.hop_stats == mb.hop_stats, what
    assert (fa is None) == (fb is None), what
    if fa is not None:
        assert np.array_equal(fa, fb), what


def run_smoke(json_path: str | None = None) -> dict:
    metrics = {}
    csv = CSV("sampling_smoke")
    key = jax.random.PRNGKey(0)

    # -- jax sampler ≡ NumPy oracle, bitwise, both replacement modes -------
    g = rmat(9, seed=2)
    cl = scaled_paper_cluster(2, 4, g.num_edges)
    svc = _service(g, cl, "hdrf")
    rows = svc.csc.flat_rowmap()[np.arange(g.num_vertices)]
    gap = 0
    for replace in (False, True):
        got = np.asarray(sample_fanout(svc._table, svc._deg, rows, key, 7,
                                       replace=replace))
        want = sample_fanout_np(np.asarray(svc._table),
                                np.asarray(svc._deg), rows, key, 7,
                                replace=replace)
        gap = max(gap, int((got != want).sum()))
    assert gap == 0, f"jax sampler disagrees with the NumPy oracle on " \
                     f"{gap} entries (same PRNG key — must be bitwise)"
    csv.row("oracle", 0, f"gap={gap} (both replacement modes)")
    metrics["sampling/oracle_gap"] = gap

    # -- halo-fetch fraction vs partitioner on the LJ proxy ----------------
    g = dataset("LJ", True)
    cl = cluster_for("LJ", g)
    halo, services = {}, {}
    for method, knobs in METHOD_KNOBS:
        svc = _service(g, cl, method, **knobs)
        services[method] = svc
        frac, _ = _halo_stats(svc, jax.random.fold_in(key, 1))
        halo[method] = frac
        csv.row(f"lj/halo/{method}", 0, f"halo_frac={frac:.4f}")
        metrics[f"sampling/halo/{method}"] = frac
    ratio = halo["windgp"] / max(halo["hash"], 1e-9)
    csv.row("lj/halo/windgp_vs_hash", 0, f"ratio={ratio:.3f}")
    assert halo["windgp"] < halo["hash"], (
        f"windgp halo fraction {halo['windgp']:.4f} not strictly below "
        f"hash {halo['hash']:.4f} — partition locality is not reaching "
        f"the sampling workload")
    metrics["sampling/halo/windgp_vs_hash"] = ratio

    # -- fused k-hop dispatch: bitwise == per-hop loop, and >= 3x ----------
    svc = services["windgp"]
    seeds = svc.local_seeds(0, BATCH, jax.random.fold_in(key, 5))
    k_hop = jax.random.fold_in(key, 6)
    fused_gap = 0
    a = svc.sample(seeds, k_hop, home=0, fused=True)
    b = svc.sample(seeds, k_hop, home=0, fused=False)
    for ha, hb in zip(a.hops, b.hops):
        fused_gap += int((ha != hb).sum())
    fused_gap += int(a.hop_stats != b.hop_stats)
    assert fused_gap == 0, (
        f"fused k-hop path diverges from the hop-at-a-time reference on "
        f"{fused_gap} entries — must be bitwise")
    rate_fused = _samples_per_sec(svc, jax.random.fold_in(key, 2))
    rate_loop = _samples_per_sec(svc, jax.random.fold_in(key, 2),
                                 batches=3, fused=False)
    fused_x = rate_fused / max(rate_loop, 1e-9)
    csv.row("lj/fused", 0, f"gap={fused_gap} speedup={fused_x:.1f}x")
    metrics["sampling/fused_gap"] = fused_gap
    metrics["sampling/fused_speedup"] = fused_x
    assert fused_x >= 3.0, (
        f"fused k-hop sampling only {fused_x:.2f}x the per-hop reference "
        f"loop on the LJ proxy (gate: >= 3x)")

    # -- samples/sec: median of 5 (the promoted one-sided floor) -----------
    bench_seeds = svc.local_seeds(0, BATCH, jax.random.fold_in(key, 999))

    def burst(n_batches: int = 6) -> int:
        n = 0
        for b in range(n_batches):
            n += svc.sample(bench_seeds, jax.random.fold_in(key, b),
                            home=0).num_sampled()
        return n

    burst(1)                                   # warm the hop shapes
    n_sampled, times = repeat_timed(burst, 5)
    med_t, iqr_t = median_iqr(times)
    rate = n_sampled / max(med_t, 1e-9)
    csv.row("lj/windgp/throughput", med_t,
            f"{rate/1e6:.2f}Msamples/s iqr_frac={iqr_t/med_t:.3f}")
    metrics["sampling/samples_per_sec"] = rate
    metrics["sampling/samples_per_sec_iqr_frac"] = iqr_t / med_t

    # -- feature halo cache: bitwise correct; windgp beats hash on both
    #    LRU hit rate (at a working-set-sized budget) and miss traffic
    #    per sampled vertex.  The gates run pure-LRU (hub_frac=0):
    #    a degree-ranked hub tier pins exactly the vertices hash fails
    #    to localize, so it equalizes methods' *conditional* hit rates —
    #    hub-tier numbers ride along tracked-ungated as context, and the
    #    traffic metric (which also credits windgp for having fewer
    #    remote accesses at all) favors windgp at every configuration.
    budget = 2048
    hits, mps, hub_hits = {}, {}, {}
    for method, _ in METHOD_KNOBS:
        svc = services[method]
        store = _store(svc)
        if method == "windgp":        # cached resolve == uncached, bitwise
            mb = svc.sample(seeds, k_hop, home=0)
            cache = HaloCache.for_home(store, 0, capacity=budget)
            got, _ = store.gather(mb.all_ids(), 0, cache)
            want = store.gather_global(mb.all_ids())
            assert np.array_equal(got, want), \
                "cached feature resolve diverges from the uncached gather"
        hits[method], mps[method] = _cache_run(
            svc, store, budget, jax.random.fold_in(key, 3),
            batches=4, hub_frac=0.0)
        hub_hits[method], _ = _cache_run(
            svc, store, budget, jax.random.fold_in(key, 3),
            batches=4, hub_frac=0.5)
        csv.row(f"lj/cache/{method}", 0,
                f"lru_hit={hits[method]:.3f} "
                f"miss_per_sampled={mps[method]:.4f} "
                f"hub_hit={hub_hits[method]:.3f} budget={budget}")
        metrics[f"sampling/cache/hit/{method}"] = hits[method]
        metrics[f"sampling/cache/mps/{method}"] = mps[method]
        metrics[f"sampling/cache/hub_hit/{method}"] = hub_hits[method]
    hit_ratio = hits["windgp"] / max(hits["hash"], 1e-9)
    traffic_ratio = mps["windgp"] / max(mps["hash"], 1e-9)
    csv.row("lj/cache/windgp_vs_hash", 0,
            f"hit_ratio={hit_ratio:.3f} traffic_ratio={traffic_ratio:.3f}")
    assert hits["windgp"] >= hits["hash"], (
        f"windgp LRU cache hit rate {hits['windgp']:.3f} below hash "
        f"{hits['hash']:.3f} at equal budget {budget} — partition "
        f"locality is not reaching the feature path")
    assert mps["windgp"] < mps["hash"], (
        f"windgp miss traffic {mps['windgp']:.4f} rows/sampled-vertex not "
        f"below hash {mps['hash']:.4f} at equal budget {budget}")
    metrics["sampling/cache/windgp_vs_hash_hit"] = hit_ratio
    metrics["sampling/cache/windgp_vs_hash_traffic"] = traffic_ratio

    # -- prefetch pipeline: depth 0 == depth 2 bitwise; speedup tracked ----
    svc = services["windgp"]
    store = _store(svc)
    streams = {}
    for depth in (0, 2):
        cache = HaloCache.for_home(store, 0, capacity=budget)
        with PrefetchPipeline(svc, home=0, batch_size=32, num_batches=4,
                              key=jax.random.fold_in(key, 8), depth=depth,
                              store=store, cache=cache) as pl:
            streams[depth] = list(pl)
    for i, (a_, b_) in enumerate(zip(streams[0], streams[2])):
        _assert_batch_equal(
            a_, b_, f"pipeline depth 0 vs 2 diverge at batch {i}")
    sync_bps = _pipeline_bps(svc, store, 0, 6, jax.random.fold_in(key, 9))
    d2_bps = _pipeline_bps(svc, store, 2, 6, jax.random.fold_in(key, 9))
    csv.row("lj/pipeline", 0,
            f"sync={sync_bps:.1f}b/s depth2={d2_bps:.1f}b/s "
            f"speedup={d2_bps/max(sync_bps,1e-9):.2f}x")
    metrics["sampling/pipeline/sync_bps"] = sync_bps
    metrics["sampling/pipeline/depth2_bps"] = d2_bps
    metrics["sampling/pipeline/speedup_d2"] = \
        d2_bps / max(sync_bps, 1e-9)

    # -- training-aware balance knob ---------------------------------------
    g = rmat(11, edge_factor=7, seed=42)
    cl = scaled_paper_cluster(3, 6, g.num_edges)
    rng = np.random.default_rng(0)
    train = rng.random(g.num_vertices) < 0.1
    wind = partitioner("windgp")
    a_def = wind(g, cl, t0=8, alpha=0.1, beta=0.1)
    a_bal = wind(g, cl, t0=8, alpha=0.1, beta=0.1,
                 train_mask=train, train_balance=1.0)
    skew_def = _train_skew(g, a_def, cl.p, train)
    skew_bal = _train_skew(g, a_bal, cl.p, train)
    csv.row("train_skew/default", 0, f"max/mean={skew_def:.3f}")
    csv.row("train_skew/balanced", 0, f"max/mean={skew_bal:.3f}")
    assert skew_bal < skew_def, (
        f"train_balance knob did not reduce train-vertex skew "
        f"(balanced {skew_bal:.3f} vs default {skew_def:.3f})")
    metrics["sampling/train_skew_default"] = skew_def
    metrics["sampling/train_skew_balanced"] = skew_bal
    metrics["sampling/train_skew_ratio"] = skew_bal / skew_def

    if json_path:
        write_bench_json(json_path, metrics)
    return metrics


def run_pipeline(repeats: int = 3) -> None:
    """Batches/sec sync vs prefetch at depth in {1, 2, 4} on the LJ
    proxy (windgp partition, feature store + 1024-row halo cache)."""
    csv = CSV("sampling_pipeline")
    key = jax.random.PRNGKey(0)
    g = dataset("LJ", True)
    cl = cluster_for("LJ", g)
    svc = _service(g, cl, "windgp", **dict(METHOD_KNOBS)["windgp"])
    store = _store(svc)
    base = None
    for depth in (0, 1, 2, 4):
        rates = [_pipeline_bps(svc, store, depth, 10,
                               jax.random.fold_in(key, r))
                 for r in range(repeats)]
        med, iqr = median_iqr(rates)
        if depth == 0:
            base = med
        csv.row(f"lj/depth{depth}", 0,
                f"{med:.1f}b/s iqr={iqr:.1f} "
                f"speedup={med/max(base,1e-9):.2f}x")


def run_cache_study(batches: int = 4) -> None:
    """Hit-rate + miss-traffic vs cache-budget curves per partitioner on
    the LJ proxy, at pure LRU (hub_frac=0) and with a half-budget static
    hub tier (hub_frac=0.5).

    Reading the curves: the hub tier raises *every* method's hit rate —
    it pins the globally hottest remote vertices, which is exactly what
    hash fails to localize, so it compresses the conditional-hit-rate
    spread between methods.  Partition locality shows up in (a) the pure-
    LRU hit rate once the budget covers the remote working set (windgp's
    boundary set is smaller and revisited more), and (b) miss traffic
    per sampled vertex, where windgp wins at every budget and hub_frac
    because it also makes fewer remote accesses in the first place.
    Every curve's miss count is bounded by the summed per-hop
    ``fetched_unique`` stats (the zero-cache ceiling)."""
    csv = CSV("sampling_cache")
    key = jax.random.PRNGKey(0)
    g = dataset("LJ", True)
    cl = cluster_for("LJ", g)
    budgets = (128, 256, 512, 1024, 2048, 4096)
    for method, knobs in METHOD_KNOBS:
        svc = _service(g, cl, method, **knobs)
        store = _store(svc)
        for hub_frac in (0.0, 0.5):
            curve = []
            for budget in budgets:
                hit, mps = _cache_run(svc, store, budget,
                                      jax.random.fold_in(key, 3),
                                      batches, hub_frac=hub_frac)
                curve.append(f"hit@{budget}={hit:.3f}/mps={mps:.4f}")
            csv.row(f"lj/{method}/hub{hub_frac:g}", 0, " ".join(curve))


def run_full(repeats: int = 3) -> None:
    """Samples/sec vs machine count + halo per hop, windgp vs hdrf vs
    hash on the LJ proxy."""
    csv = CSV("sampling")
    key = jax.random.PRNGKey(0)
    g = dataset("LJ", True)

    # machine-count sweep at fixed fanouts (hdrf: cheap, representative)
    for n in (3, 6, 12):
        cl = scaled_paper_cluster(1, n - 1, g.num_edges)
        svc = _service(g, cl, "hdrf")
        rates = [_samples_per_sec(svc, jax.random.fold_in(key, r))
                 for r in range(repeats)]
        med, iqr = median_iqr(rates)
        frac, _ = _halo_stats(svc, key)
        csv.row(f"lj/p{n}/throughput", 0,
                f"{med/1e6:.2f}Msamples/s iqr={iqr/1e6:.2f} "
                f"halo={frac:.3f} p={n}")

    # per-hop halo by partitioner at the paper cluster
    cl = cluster_for("LJ", g)
    for method, knobs in METHOD_KNOBS:
        svc = _service(g, cl, method, **knobs)
        seeds = svc.local_seeds(0, BATCH, key)
        mb = svc.sample(seeds, jax.random.fold_in(key, 7), home=0)
        fr = " ".join(f"h{h}={f:.3f}"
                      for h, f in enumerate(mb.halo_fracs()))
        csv.row(f"lj/halo_hops/{method}", 0, fr)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tier-2 CI gate: sampler oracle bitwise, fused "
                         "k-hop bitwise + >=3x the per-hop reference, "
                         "windgp < hash halo fraction, cached features "
                         "bitwise + windgp >= hash LRU hit rate + lower "
                         "miss traffic, pipeline "
                         "depth-determinism, train-balance skew "
                         "reduction; samples/sec floor median-of-5")
    ap.add_argument("--json", default=None,
                    help="write gateable metrics to this path "
                         "(BENCH_smoke.json for CI)")
    ap.add_argument("--pipeline", action="store_true",
                    help="sync vs prefetch batches/sec at depth 1/2/4")
    ap.add_argument("--cache-study", action="store_true",
                    help="hit-rate vs cache-budget curve per partitioner")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    if args.smoke:
        run_smoke(args.json)
    if args.pipeline:
        run_pipeline(args.repeats)
    if args.cache_study:
        run_cache_study()
    if args.full:
        run_full(args.repeats)
    if not (args.smoke or args.full or args.pipeline or args.cache_study):
        ap.print_help()
