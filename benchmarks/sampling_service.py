"""GNN minibatch sampling throughput + halo-fetch fraction vs partitioner.

The sampling service (``repro.sampling``) is the workload partition
quality is *for* in GNN training: every minibatch expands a k-hop
neighborhood against machine-owned CSC shards, and each frontier vertex
owned elsewhere is a cross-machine halo fetch.  This benchmark makes
that observable:

* ``--smoke`` (the tier-2 ``sampling`` CI job) gates
  - the jax sampler against its NumPy oracle — bitwise on the same key,
    both with- and without-replacement;
  - halo-fetch fraction on the LJ proxy: windgp (locality-optimized)
    must beat hash (locality-free) strictly, with hdrf in between as
    context;
  - the training-aware knob: ``train_balance`` must reduce the
    max/mean train-vertex skew vs the unbalanced default;
  - samples/sec on the LJ proxy (tracked, ungated — CI walls drift).
* ``--full`` adds samples/sec vs machine count and a fanout sweep.

Run:  PYTHONPATH=src python -m benchmarks.sampling_service --smoke \
          --json BENCH_smoke.json
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import scaled_paper_cluster
from repro.core.partition_state import edge_incidence_counts
from repro.core.partitioners import get as partitioner
from repro.data import rmat
from repro.sampling import SamplingService, sample_fanout, sample_fanout_np

from .common import CSV, cluster_for, dataset, median_iqr, write_bench_json

FANOUTS = (10, 5)
BATCH = 64


def _service(g, cl, method, fanouts=FANOUTS, **knobs) -> SamplingService:
    return SamplingService.create(g, method=method, cluster=cl,
                                  fanouts=fanouts, **knobs)


def _halo_stats(svc: SamplingService, key, batches: int = 2):
    """Mean halo-fetch fraction over every machine's minibatches, plus
    total sampled entries (the numerator of samples/sec)."""
    halo = frontier = sampled = 0
    for home in range(svc.p):
        if svc.csc.owned_per[home] == 0:
            continue
        for b in range(batches):
            k_seed, k_hop = jax.random.split(
                jax.random.fold_in(jax.random.fold_in(key, home), b))
            seeds = svc.local_seeds(home, BATCH, k_seed)
            mb = svc.sample(seeds, k_hop, home=home)
            for s in mb.hop_stats:
                halo += s.halo
                frontier += s.frontier
            sampled += mb.num_sampled()
    return halo / max(1, frontier), sampled


def _samples_per_sec(svc: SamplingService, key, batches: int = 6) -> float:
    """Warm-started sampling throughput on machine 0's seeds."""
    seeds = svc.local_seeds(0, BATCH, jax.random.fold_in(key, 999))
    svc.sample(seeds, key, home=0)           # compile/warm the hop shapes
    t0 = time.perf_counter()
    n = 0
    for b in range(batches):
        mb = svc.sample(seeds, jax.random.fold_in(key, b), home=0)
        n += mb.num_sampled()
    return n / max(time.perf_counter() - t0, 1e-9)


def _train_skew(g, assign, p, train_mask) -> float:
    """max/mean of per-machine hosted-train-vertex counts."""
    member = edge_incidence_counts(g, assign, p) > 0
    counts = member[:, train_mask].sum(axis=1).astype(np.float64)
    return float(counts.max() / max(counts.mean(), 1e-9))


def run_smoke(json_path: str | None = None) -> dict:
    metrics = {}
    csv = CSV("sampling_smoke")
    key = jax.random.PRNGKey(0)

    # -- jax sampler ≡ NumPy oracle, bitwise, both replacement modes -------
    g = rmat(9, seed=2)
    cl = scaled_paper_cluster(2, 4, g.num_edges)
    svc = _service(g, cl, "hdrf")
    rows = svc.csc.flat_rowmap()[np.arange(g.num_vertices)]
    gap = 0
    for replace in (False, True):
        got = np.asarray(sample_fanout(svc._table, svc._deg, rows, key, 7,
                                       replace=replace))
        want = sample_fanout_np(np.asarray(svc._table),
                                np.asarray(svc._deg), rows, key, 7,
                                replace=replace)
        gap = max(gap, int((got != want).sum()))
    assert gap == 0, f"jax sampler disagrees with the NumPy oracle on " \
                     f"{gap} entries (same PRNG key — must be bitwise)"
    csv.row("oracle", 0, f"gap={gap} (both replacement modes)")
    metrics["sampling/oracle_gap"] = gap

    # -- halo-fetch fraction vs partitioner on the LJ proxy ----------------
    g = dataset("LJ", True)
    cl = cluster_for("LJ", g)
    halo = {}
    for method, knobs in (("windgp", dict(t0=8, alpha=0.1, beta=0.1)),
                          ("hdrf", {}), ("hash", {})):
        svc = _service(g, cl, method, **knobs)
        frac, _ = _halo_stats(svc, jax.random.fold_in(key, 1))
        halo[method] = frac
        csv.row(f"lj/halo/{method}", 0, f"halo_frac={frac:.4f}")
        metrics[f"sampling/halo/{method}"] = frac
        if method == "windgp":
            rate = _samples_per_sec(svc, jax.random.fold_in(key, 2))
            csv.row("lj/windgp/throughput", 0, f"{rate/1e6:.2f}Msamples/s")
            metrics["sampling/samples_per_sec"] = rate
    ratio = halo["windgp"] / max(halo["hash"], 1e-9)
    csv.row("lj/halo/windgp_vs_hash", 0, f"ratio={ratio:.3f}")
    assert halo["windgp"] < halo["hash"], (
        f"windgp halo fraction {halo['windgp']:.4f} not strictly below "
        f"hash {halo['hash']:.4f} — partition locality is not reaching "
        f"the sampling workload")
    metrics["sampling/halo/windgp_vs_hash"] = ratio

    # -- training-aware balance knob ---------------------------------------
    g = rmat(11, edge_factor=7, seed=42)
    cl = scaled_paper_cluster(3, 6, g.num_edges)
    rng = np.random.default_rng(0)
    train = rng.random(g.num_vertices) < 0.1
    wind = partitioner("windgp")
    a_def = wind(g, cl, t0=8, alpha=0.1, beta=0.1)
    a_bal = wind(g, cl, t0=8, alpha=0.1, beta=0.1,
                 train_mask=train, train_balance=1.0)
    skew_def = _train_skew(g, a_def, cl.p, train)
    skew_bal = _train_skew(g, a_bal, cl.p, train)
    csv.row("train_skew/default", 0, f"max/mean={skew_def:.3f}")
    csv.row("train_skew/balanced", 0, f"max/mean={skew_bal:.3f}")
    assert skew_bal < skew_def, (
        f"train_balance knob did not reduce train-vertex skew "
        f"(balanced {skew_bal:.3f} vs default {skew_def:.3f})")
    metrics["sampling/train_skew_default"] = skew_def
    metrics["sampling/train_skew_balanced"] = skew_bal
    metrics["sampling/train_skew_ratio"] = skew_bal / skew_def

    if json_path:
        write_bench_json(json_path, metrics)
    return metrics


def run_full(repeats: int = 3) -> None:
    """Samples/sec vs machine count + halo per hop, windgp vs hdrf vs
    hash on the LJ proxy."""
    csv = CSV("sampling")
    key = jax.random.PRNGKey(0)
    g = dataset("LJ", True)

    # machine-count sweep at fixed fanouts (hdrf: cheap, representative)
    for n in (3, 6, 12):
        cl = scaled_paper_cluster(1, n - 1, g.num_edges)
        svc = _service(g, cl, "hdrf")
        rates = [_samples_per_sec(svc, jax.random.fold_in(key, r))
                 for r in range(repeats)]
        med, iqr = median_iqr(rates)
        frac, _ = _halo_stats(svc, key)
        csv.row(f"lj/p{n}/throughput", 0,
                f"{med/1e6:.2f}Msamples/s iqr={iqr/1e6:.2f} "
                f"halo={frac:.3f} p={n}")

    # per-hop halo by partitioner at the paper cluster
    cl = cluster_for("LJ", g)
    for method, knobs in (("windgp", dict(t0=8, alpha=0.1, beta=0.1)),
                          ("hdrf", {}), ("hash", {})):
        svc = _service(g, cl, method, **knobs)
        seeds = svc.local_seeds(0, BATCH, key)
        mb = svc.sample(seeds, jax.random.fold_in(key, 7), home=0)
        fr = " ".join(f"h{h}={f:.3f}"
                      for h, f in enumerate(mb.halo_fracs()))
        csv.row(f"lj/halo_hops/{method}", 0, fr)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tier-2 CI gate: sampler oracle bitwise + "
                         "windgp < hash halo fraction + train-balance "
                         "skew reduction on proxies")
    ap.add_argument("--json", default=None,
                    help="write gateable metrics to this path "
                         "(BENCH_smoke.json for CI)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    if args.smoke:
        run_smoke(args.json)
    if args.full:
        run_full(args.repeats)
    if not (args.smoke or args.full):
        ap.print_help()
