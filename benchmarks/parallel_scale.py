"""Scaling and quality of the W-worker partitioning pipeline.

Measures the two claims ``repro.core.parallel`` makes on the LJ proxy:

* **correctness** — at ``sync_blocks=1`` the W-worker run is
  *bit-identical* to sequential ``stream_partition`` (membership matrix,
  per-machine totals, and the ``StreamAssignment`` shard bytes), and at
  the default sync period TC/RF degrade by at most 2% (``tc_gap``/
  ``rf_gap`` below are *signed* relative degradation — the parallel run
  being better counts as 0);
* **scaling** — dedup+scoring wall clock at W∈{1,2,4} (the sharded
  spill/dedup passes plus the epoch-parallel wave scoring).

The quality/bit-equality side is deterministic and gated in CI (the
tier-2 ``parallel`` job runs ``--smoke``); the speedups are recorded as
tracked-ungated trend metrics but never asserted there — CI wall clock
is too noisy and the container is single-core, where W processes time-
slice one CPU.  The full run (no ``--smoke``) asserts the paper-style
scaling targets (≥1.6x at W=2, ≥2.5x at W=4) only when the host
actually has the cores to show them.

Run directly:  PYTHONPATH=src python -m benchmarks.parallel_scale [--smoke]
"""
from __future__ import annotations

import os
import pathlib
import shutil
import tempfile

import numpy as np

from repro.bsp import StreamAssignment
from repro.core import evaluate_membership
from repro.core.baselines.streaming import stream_partition
from repro.core.parallel import ShardedTwoPassDedup
from repro.data import TwoPassDedup

from .common import CSV, cluster_for, dataset, timed, write_bench_json

#: full-run scaling targets (dedup+scoring wall vs W=1), asserted only
#: when ``os.cpu_count()`` can physically show them
SPEEDUP_TARGETS = {2: 1.6, 4: 2.5}


def _write_edges(g, path: pathlib.Path) -> None:
    np.savetxt(path, g.edges, fmt="%d")


def _run_once(path, cl, workers: int, sync_blocks: int | None,
              out_dir: pathlib.Path, method: str = "hdrf"):
    """One dedup+scoring pipeline run; returns (state, sa, walls dict)."""
    if workers == 1:
        tp = TwoPassDedup(str(path))
    else:
        tp = ShardedTwoPassDedup(str(path), workers=workers)
    _, t_dedup = timed(tp.prepare)
    sa = StreamAssignment(out_dir, cl.p, tp.num_vertices)
    kw = {} if workers == 1 else {"workers": workers,
                                  "sync_blocks": sync_blocks}
    try:
        state, t_score = timed(
            stream_partition, tp, None, None, cl, method,
            dedup="two_pass", sink=sa.sink, **kw)
    except BaseException:
        sa.close()
        raise
    finally:
        tp.close()
    sa.finalize(state, {"method": method, "dedup": "two_pass"})
    return state, sa, {"dedup_s": t_dedup, "score_s": t_score,
                       "wall_s": t_dedup + t_score}


def _shard_bytes(sa: StreamAssignment) -> list[bytes]:
    return [(sa.dir / f"shard{i}.edges").read_bytes() for i in range(sa.p)]


def _gaps(cl, seq_state, par_state) -> tuple[float, float]:
    """Signed relative TC/RF degradation of the parallel run (>=0 only
    when parallel is *worse*; both metrics are lower-is-better)."""
    s = evaluate_membership(seq_state.cnt > 0, seq_state.edges_per, cl)
    q = evaluate_membership(par_state.cnt > 0, par_state.edges_per, cl)
    return (max(0.0, (q.tc - s.tc) / max(1.0, s.tc)),
            max(0.0, (q.rf - s.rf) / max(1e-12, s.rf)))


def run_smoke(json_path: str | None = None) -> dict:
    """Tier-2 CI ``parallel`` job: quick-LJ proxy at W=2.

    Asserts (deterministic, so gateable): bit-equality with sequential at
    ``sync_blocks=1`` — membership, totals, and shard bytes — and the
    TC/RF ≤ 2% degradation gate at the default sync period.  Walls ride
    along as tracked-ungated trend metrics.
    """
    csv = CSV("parallel_smoke")
    g = dataset("LJ", quick=True)
    cl = cluster_for("LJ", g)
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="parallel_scale_"))
    try:
        path = tmp / "edges.txt"
        _write_edges(g, path)
        seq, sa_seq, w1 = _run_once(path, cl, 1, None, tmp / "w1")
        csv.row("w1", w1["wall_s"],
                f"dedup={w1['dedup_s']:.2f}s score={w1['score_s']:.2f}s")

        # bit-equality at sync_blocks=1
        lock, sa_lock, _ = _run_once(path, cl, 2, 1, tmp / "w2k1")
        assert np.array_equal(seq.cnt, lock.cnt), \
            "sync_blocks=1 membership != sequential"
        assert np.array_equal(seq.edges_per, lock.edges_per)
        assert np.array_equal(seq.verts_per, lock.verts_per)
        assert _shard_bytes(sa_seq) == _shard_bytes(sa_lock), \
            "sync_blocks=1 shard bytes != sequential"
        csv.row("w2_sync1_bitident", 0, "membership+totals+shards equal")

        # quality gate at the default sync period
        par, _sa, w2 = _run_once(path, cl, 2, None, tmp / "w2")
        tc_gap, rf_gap = _gaps(cl, seq, par)
        assert tc_gap <= 0.02 + 1e-9, f"TC degraded {tc_gap:.2%} (> 2%)"
        assert rf_gap <= 0.02 + 1e-9, f"RF degraded {rf_gap:.2%} (> 2%)"
        speedup = w1["wall_s"] / max(w2["wall_s"], 1e-9)
        csv.row("w2", w2["wall_s"],
                f"speedup={speedup:.2f}x tc_gap={tc_gap:.4f} "
                f"rf_gap={rf_gap:.4f}")
        res = {
            "parallel/tc_gap": tc_gap,
            "parallel/rf_gap": rf_gap,
            # wall numbers are tracked-ungated: single-core CI time-slices
            # the workers, so the ratio records contention, not scaling
            "parallel/speedup_w2": speedup,
            "parallel/wall_w1_s": w1["wall_s"],
            "parallel/wall_w2_s": w2["wall_s"],
        }
        if json_path:
            write_bench_json(json_path, res)
        return res
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run(quick: bool = True, workers=(1, 2, 4),
        method: str = "hdrf") -> dict:
    """The scaling table: dedup/scoring/total wall at each W, plus the
    TC/RF gap vs W=1.  Asserts the speedup targets only on hosts with
    enough cores to express them."""
    csv = CSV("parallel_scale")
    g = dataset("LJ", quick)
    cl = cluster_for("LJ", g)
    cores = os.cpu_count() or 1
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="parallel_scale_"))
    out = {}
    try:
        path = tmp / "edges.txt"
        _write_edges(g, path)
        base = None
        for w in workers:
            state, _sa, walls = _run_once(path, cl, w, None, tmp / f"w{w}",
                                          method)
            if base is None:
                base, base_walls = state, walls
                tc_gap = rf_gap = 0.0
                speedup = 1.0
            else:
                tc_gap, rf_gap = _gaps(cl, base, state)
                speedup = base_walls["wall_s"] / max(walls["wall_s"], 1e-9)
            out[w] = dict(walls, speedup=speedup, tc_gap=tc_gap,
                          rf_gap=rf_gap)
            csv.row(f"LJ/{method}/w{w}", walls["wall_s"],
                    f"dedup={walls['dedup_s']:.2f}s "
                    f"score={walls['score_s']:.2f}s "
                    f"speedup={speedup:.2f}x tc_gap={tc_gap:.4f} "
                    f"rf_gap={rf_gap:.4f}")
            assert tc_gap <= 0.02 + 1e-9 and rf_gap <= 0.02 + 1e-9, \
                f"W={w}: quality gate blown (tc {tc_gap:.2%}, rf {rf_gap:.2%})"
            target = SPEEDUP_TARGETS.get(w)
            if target and cores >= w:
                assert speedup >= target, \
                    f"W={w}: {speedup:.2f}x < {target}x target " \
                    f"({cores} cores available)"
            elif target:
                csv.row(f"LJ/{method}/w{w}_target", 0,
                        f"skipped {target}x assertion: {cores} core(s)")
        return out
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tier-2 CI gate: W=2 bit-equality at "
                         "sync_blocks=1 + TC/RF <= 2% at default sync")
    ap.add_argument("--json", default=None,
                    help="--smoke: write gateable metrics to this path "
                         "(BENCH_smoke.json for CI)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print("table/name,us_per_call,derived")
    if args.smoke:
        run_smoke(json_path=args.json)
    else:
        run(quick=not args.full)
