"""Roofline analysis per (arch × shape × mesh) — EXPERIMENTS.md §Roofline.

Three terms per cell, in seconds per step (TPU v5e constants):

    compute    = FLOPs_per_device / 197e12         (bf16 MXU peak)
    memory     = HBM_bytes_per_device / 819e9
    collective = collective_bytes_per_device / 50e9 (ICI per link)

FLOPs/HBM-bytes use an *analytic* closed-form model (documented below):
XLA's ``cost_analysis()`` on CPU counts every ``lax.scan`` body exactly
once (verified experimentally: L=2 and L=4 report identical FLOPs), so the
compiled numbers are only used as a consistency check on the loop-free
portion.  Collective bytes come from the compiled HLO with loop-body
collectives scaled by the layer-scan trip count (recorded by dryrun.py).

MODEL_FLOPS = 6·N_active·T (the assignment's definition) is reported
against the analytic total: the gap is remat recompute, attention
quadratic terms, and MoE capacity-padding waste.
"""
from __future__ import annotations

import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import SHAPES, get_config           # noqa: E402
from repro.models import active_param_count, param_count  # noqa: E402

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link


# ---------------------------------------------------------------------------
# analytic FLOP model
# ---------------------------------------------------------------------------

def _matmul_params(cfg) -> int:
    """Active params participating in matmuls (embedding lookup is free)."""
    n = active_param_count(cfg)
    if cfg.input_mode == "tokens" and not cfg.tie_embeddings:
        n -= cfg.vocab_size * cfg.d_model          # the lookup table
    return n


def _attn_layers(cfg) -> int:
    return sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "attn")


def _ssm_layers(cfg) -> int:
    return cfg.num_layers - _attn_layers(cfg)


def flops_fwd(cfg, B: int, S: int, ctx: int | None = None) -> float:
    """Forward FLOPs for B sequences of S new tokens (ctx = KV history)."""
    T = B * S
    f = 2.0 * _matmul_params(cfg) * T
    hd, H = cfg.head_dim_, cfg.num_heads
    la, ls = _attn_layers(cfg), _ssm_layers(cfg)
    if la:
        if ctx is None:                      # causal self-attention
            f += la * 2.0 * B * S * S * H * hd          # scores+values, /2 causal *2 ops
        else:                                # decode: attend over ctx
            qk = (cfg.kv_lora_rank + cfg.qk_rope_dim
                  if cfg.attn_type == "mla" else hd)
            vd = cfg.kv_lora_rank if cfg.attn_type == "mla" else hd
            f += la * 2.0 * B * S * ctx * H * (qk + vd)
    if ls:
        nh, dh, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        L = cfg.ssd_chunk
        if ctx is None:
            f += ls * 2.0 * T * nh * (L * (ds + dh) + 2.0 * ds * dh)
        else:                                # single-step recurrence
            f += ls * 4.0 * B * S * nh * ds * dh
    # MoE capacity padding: compiled expert matmuls run at capacity
    if cfg.num_experts:
        f *= 1.0  # padding waste accounted in flops_step(as_compiled)
    return f


def flops_step(cfg, shape: str, as_compiled: bool = True) -> float:
    """Whole-step FLOPs across all devices."""
    sh = SHAPES[shape]
    B, S, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    if kind == "train":
        fwd = flops_fwd(cfg, B, S)
        mult = 4.0 if as_compiled else 3.0   # remat re-forward
        f = fwd * mult
    elif kind == "prefill":
        f = flops_fwd(cfg, B, S)
    else:
        f = flops_fwd(cfg, B, 1, ctx=S)
    if as_compiled and cfg.num_experts:
        f *= cfg.capacity_factor             # expert-buffer padding waste
    return f


def model_flops(cfg, shape: str) -> float:
    """The assignment's MODEL_FLOPS: 6·N_active·D (D = tokens processed)."""
    sh = SHAPES[shape]
    B, S, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    tokens = B * (S if kind != "decode" else 1)
    n = active_param_count(cfg)
    return (6.0 if kind == "train" else 2.0) * n * tokens


# ---------------------------------------------------------------------------
# analytic HBM-traffic model (per device)
# ---------------------------------------------------------------------------

def bytes_step(cfg, shape: str, devices: int, model_par: int = 16,
               data_par: int | None = None) -> float:
    sh = SHAPES[shape]
    B, S, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    data_par = data_par or max(1, devices // model_par)
    P = param_count(cfg)
    n_params_dev = P / model_par
    if P > 12e9:                              # FSDP'd archs
        n_params_dev /= data_par
    B_dev = max(1, B // data_par)
    d, L = cfg.d_model, cfg.num_layers
    if kind == "train":
        # params: fwd read + bwd read (bf16) + grad w/r (f32) + m,v r/w
        # (f32) + param write — ≈ 30 bytes per element-shard.
        pb = n_params_dev * 30.0
        # activations: ~12 (B,S,d)-sized reads+writes per layer (remat'd),
        # bf16.
        ab = L * B_dev * S * d * 12.0 * 2.0
        return pb + ab
    if kind == "prefill":
        pb = n_params_dev * 2.0
        ab = L * B_dev * S * d * 6.0 * 2.0
        cache = _cache_bytes(cfg, B_dev, S, model_par)
        return pb + ab + cache
    # decode: params once + full cache read per token
    pb = n_params_dev * 2.0
    cache = _cache_bytes(cfg, B_dev, S, model_par)
    return pb + cache


def _cache_bytes(cfg, B_dev, S, model_par) -> float:
    la, ls = _attn_layers(cfg), _ssm_layers(cfg)
    out = 0.0
    if la:
        if cfg.attn_type == "mla":
            per_tok = cfg.kv_lora_rank + cfg.qk_rope_dim
        else:
            per_tok = 2 * cfg.num_kv_heads * cfg.head_dim_
        out += la * B_dev * S * per_tok * 2.0 / model_par  # seq- or kv-sharded
    if ls:
        out += ls * B_dev * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * 4.0
    return out


# ---------------------------------------------------------------------------
# table assembly
# ---------------------------------------------------------------------------

def analyze(record: dict) -> dict:
    cfg = get_config(record["arch"])
    devices = record["devices"]
    model_par = 16
    f_total = flops_step(cfg, record["shape"])
    f_dev = f_total / devices
    b_dev = bytes_step(cfg, record["shape"], devices, model_par)
    c_dev = record["collectives"]["total_bytes_trip_scaled"]
    t_c = f_dev / PEAK_FLOPS
    t_m = b_dev / HBM_BW
    t_x = c_dev / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops(cfg, record["shape"])
    step_time = max(t_c, t_m, t_x)
    mfu = mf / devices / PEAK_FLOPS / step_time if step_time else 0.0
    return {
        "arch": record["arch"], "shape": record["shape"],
        "mesh": record["mesh"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom,
        "model_flops": mf, "analytic_flops": f_total,
        "useful_ratio": mf / f_total,
        "roofline_fraction": mfu,
        "peak_gib_per_dev": record["peak_bytes_per_device"] / 2 ** 30,
        "hlo_flops_body_once": record.get("flops_hlo_body_once", -1),
    }


def run(path: str = "results/dryrun.jsonl", mesh: str = "pod16x16"):
    rows = []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if "error" in r or r["mesh"] != mesh:
                continue
            rows.append(analyze(r))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = (f"{'arch':24s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'dominant':>10s} {'useful':>7s} {'RLfrac':>7s} "
           f"{'GiB/dev':>8s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
              f"{r['t_collective_s']:9.4f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.2f} {r['roofline_fraction']:7.3f} "
              f"{r['peak_gib_per_dev']:8.2f}")
    return rows


if __name__ == "__main__":
    run(*sys.argv[1:])
