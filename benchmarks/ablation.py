"""Paper Fig. 8: the WindGP-/WindGP*/WindGP+/WindGP technique ladder."""
from __future__ import annotations

from repro.core import windgp

from .common import CSV, cluster_for, dataset, timed

LEVELS = ("windgp-", "windgp*", "windgp+", "windgp")


def run(quick: bool = True, datasets=("TW", "CO", "LJ", "CP", "RN")):
    csv = CSV("fig8_ablation")
    out = {}
    for ds in datasets:
        g = dataset(ds, quick)
        cl = cluster_for(ds, g)
        tcs = {}
        for lvl in LEVELS:
            res, dt = timed(windgp, g, cl, level=lvl, t0=30, theta=0.02,
                            alpha=0.1, beta=0.1)
            tcs[lvl] = res.stats.tc
            csv.row(f"{ds}/{lvl}", dt, f"TC={res.stats.tc:.4e}")
        csv.row(f"{ds}/full_vs_naive", 0,
                f"{tcs['windgp-'] / tcs['windgp']:.2f}x")
        out[ds] = tcs
    return out
