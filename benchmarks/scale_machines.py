"""Paper Figs. 14-15: scalability with machine count and machine-type count."""
from __future__ import annotations

import numpy as np

from repro.core import Cluster, Machine, evaluate, scaled_paper_cluster, windgp
from repro.core.partitioners import get as partitioner

from .common import CSV, dataset, timed


def run(quick: bool = True):
    csv = CSV("fig14_15_scale_machines")
    g = dataset("LJ", quick)
    # Fig 14: machine count sweep (1/3 super, like the paper)
    for p in (9, 15, 21, 30, 45):
        cl = scaled_paper_cluster(p // 3, p - p // 3, g.num_edges, slack=1.8)
        res, dt = timed(windgp, g, cl, t0=20, theta=0.02,
                        alpha=0.1, beta=0.1)
        csv.row(f"machines={p}/windgp", dt, f"TC={res.stats.tc:.4e}")
        a, dtn = timed(partitioner("ne"), g, cl)
        csv.row(f"machines={p}/ne", dtn,
                f"TC={evaluate(g, a, cl).tc:.4e}")

    # Fig 15: machine-type count sweep at p=9
    total_units = 3.0 * g.num_edges * 1.8
    for ntypes in (1, 2, 3, 4, 6):
        machines = []
        # evenly split 9 machines into ntypes tiers; tier k is 1+k/2 "bigger"
        weights = np.array([1 + 0.5 * k for k in range(ntypes)])
        shares = np.repeat(weights, [9 // ntypes] * (ntypes - 1)
                           + [9 - (9 // ntypes) * (ntypes - 1)])
        mem = total_units * shares / shares.sum()
        for k, m in zip(np.repeat(np.arange(ntypes),
                                  [9 // ntypes] * (ntypes - 1)
                                  + [9 - (9 // ntypes) * (ntypes - 1)]), mem):
            c = 5 + 2.5 * k
            machines.append(Machine(float(m), c / 2, c, c))
        cl = Cluster(machines=tuple(machines))
        res, dt = timed(windgp, g, cl, t0=20, theta=0.02,
                        alpha=0.1, beta=0.1)
        csv.row(f"types={ntypes}/windgp", dt, f"TC={res.stats.tc:.4e}")
        for m in ("ne", "ebv"):
            a, dtm = timed(partitioner(m), g, cl)
            csv.row(f"types={ntypes}/{m}", dtm,
                    f"TC={evaluate(g, a, cl).tc:.4e}")
    return None
