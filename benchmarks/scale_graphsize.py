"""Paper Fig. 13 / Table 12: scalability with Graph500 R-MAT scale."""
from __future__ import annotations

import numpy as np

from repro.core import evaluate, scaled_paper_cluster, windgp
from repro.core.partitioners import get as partitioner
from repro.data import graph500

from .common import CSV, timed


def run(quick: bool = True):
    csv = CSV("fig13_scale_graphsize")
    scales = range(10, 15) if quick else range(10, 17)
    tc_by_scale = {}
    for s in scales:
        g = graph500(s, seed=5)
        cl = scaled_paper_cluster(2, 10, g.num_edges, slack=1.8)
        res, dt = timed(windgp, g, cl, t0=20, theta=0.02,
                        alpha=0.1, beta=0.1)
        csv.row(f"S{s}/windgp", dt,
                f"E={g.num_edges};TC={res.stats.tc:.4e}")
        for m in ("ne", "hdrf"):
            assign, dtm = timed(partitioner(m), g, cl)
            st = evaluate(g, assign, cl)
            csv.row(f"S{s}/{m}", dtm, f"TC={st.tc:.4e}")
        tc_by_scale[s] = res.stats.tc
    # growth slope (paper: WindGP <= 1.8, others > 2)
    ss = sorted(tc_by_scale)
    slopes = [np.log2(tc_by_scale[b] / tc_by_scale[a])
              for a, b in zip(ss, ss[1:])]
    csv.row("windgp/slope", 0, f"{np.mean(slopes):.2f}")
    return tc_by_scale
