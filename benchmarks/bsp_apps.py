"""BSP apps × edge-kernel backends: superstep throughput at matched partitions.

The paper's end metric is distributed graph-algorithm runtime on the
partition it produces; this table holds the partition fixed (one hdrf run
per dataset) and swaps the *compute* layer — the edge-kernel backend each
superstep combines messages through (``repro.bsp.backends``):

* ``scatter`` — the gather-scatter oracle (`at[].⊕` per direction);
* ``segment`` — sorted-CSR reduction (cumsum-diff for (+, ×): the CPU
  fast path);
* ``pallas``  — the blocked Block-ELL semiring SpMV (interpret-mode on
  CPU, MXU-shaped on TPU; its ELL fill stats are the utilization proxy).

Per (app × backend): median superstep seconds, edge throughput, speedup
over ``scatter``, and the cross-backend result gap (bitwise for the
min/max semirings, ~1e-7 float drift for (+, ×)).

``--smoke`` is the tier-2 CI gate: asserts backend equivalence on a tiny
proxy for all four apps, ``segment`` ≥ 2× ``scatter`` PageRank superstep
throughput on the LJ proxy, and reports the Pallas layout's ELL fill
stats; emits ``BENCH_smoke.json`` for ``benchmarks/check_trend.py``.

Run:  PYTHONPATH=src python -m benchmarks.bsp_apps [--smoke] [--json out]
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.bsp import PartitionRuntime, build_app
from repro.bsp.engine import make_step
from repro.core import scaled_paper_cluster
from repro.core.partitioners import get as partitioner
from repro.data import rmat

from .common import (CSV, cluster_for, dataset, median_iqr, spread_str,
                     write_bench_json)

APPS = ("pagerank", "sssp", "bfs", "cc")
BACKENDS = ("scatter", "segment", "pallas")

#: CPU-fitting Pallas tile for the proxies (128 is the TPU/MXU default;
#: the interpreter does not need MXU alignment and the dense blocks of a
#: proxy-sized graph stay in memory at 32/64)
SMOKE_BLOCK = 32


def _app_opts(app: str, backend: str, block_size: int) -> dict:
    opts = {} if backend != "pallas" else {"block_size": block_size}
    if app in ("sssp", "bfs"):
        opts["source"] = 0
    return opts


def _superstep_seconds(rt, app: str, backend: str, *, iters: int = 8,
                       repeats: int = 3, block_size: int = SMOKE_BLOCK):
    """Median seconds per (jit-compiled, vmap) superstep, state evolving."""
    spec = build_app(rt, app, backend=backend,
                     **_app_opts(app, backend, block_size))
    step = make_step(spec.superstep, spec.static)
    state, _ = step(spec.state)                 # compile + warm
    jax.block_until_ready(state)
    times = []
    for _ in range(max(1, repeats)):
        state = spec.state
        t0 = time.perf_counter()
        for _ in range(iters):
            state, _ = step(state)
        jax.block_until_ready(state)
        times.append((time.perf_counter() - t0) / iters)
    return times


def _run_app(rt, app: str, backend: str, iters: int,
             block_size: int = SMOKE_BLOCK):
    """Final global result array after ``iters`` supersteps."""
    from repro.bsp.engine import run_bsp
    spec = build_app(rt, app, backend=backend,
                     **_app_opts(app, backend, block_size))
    out, _ = run_bsp(spec.superstep, spec.state, spec.static, iters,
                     check_rep=spec.check_rep)
    return spec.finalize(rt, out)


def _partition(g, cl) -> PartitionRuntime:
    return PartitionRuntime.build(g, partitioner("hdrf")(g, cl), cl.p)


def _equivalence(rt, iters: int = 10, block_size: int = SMOKE_BLOCK):
    """Max |scatter − backend| result gap per app over the other backends."""
    gaps = {}
    for app in APPS:
        ref = _run_app(rt, app, "scatter", iters)
        worst = 0.0
        for be in BACKENDS[1:]:
            got = _run_app(rt, app, be, iters, block_size)
            m = np.isfinite(ref)
            assert (np.isfinite(got) == m).all(), (app, be, "inf mismatch")
            if m.any():
                worst = max(worst, float(np.abs(got[m] - ref[m]).max()))
        gaps[app] = worst
    return gaps


def run(quick: bool = True, datasets=("LJ", "RN"), apps=APPS,
        backends=("scatter", "segment"), repeats: int = 3,
        iters: int = 8) -> dict:
    """Backend timing table at proxy scale.

    ``pallas`` is excluded from timing by default: off-TPU it runs the
    Pallas *interpreter* (a correctness path, orders of magnitude slower
    than compiled), so timing it on CPU proxies only measures the
    emulator.  Pass ``backends=BACKENDS`` on a TPU host (or
    ``--with-pallas``) to include it; its layout fill stats — the part
    that matters off-TPU — are always reported, and the smoke gate checks
    its results on the tiny proxy where the interpreter is affordable.
    """
    csv = CSV("bsp_apps")
    out = {}
    for ds in datasets:
        g = dataset(ds, quick)
        cl = cluster_for(ds, g)
        rt = _partition(g, cl)
        edges = int(rt.edge_valid.sum())
        res = {}
        for app in apps:
            base = None
            ref = None
            for be in backends:
                times = _superstep_seconds(rt, app, be, iters=iters,
                                           repeats=repeats)
                med, _ = median_iqr(times)
                if be == "scatter":
                    base = med
                speed = base / max(med, 1e-9)
                csv.row(f"{ds}/{app}/{be}", med,
                        f"{spread_str(times)} {edges/med/1e6:.2f}Medges/s "
                        f"{speed:.2f}x")
                res[f"{app}/{be}"] = {"seconds": med, "speedup": speed}
                got = _run_app(rt, app, be, max(4, iters // 2))
                if ref is None:
                    ref = got
                else:
                    m = np.isfinite(ref)
                    gp = float(np.abs(got[m] - ref[m]).max()) if m.any() \
                        else 0.0
                    csv.row(f"{ds}/{app}/{be}_gap", 0, f"{gp:.2e}")
                    res[f"{app}/{be}_gap"] = gp
        bsr = rt.local_bsr(block_size=SMOKE_BLOCK)
        csv.row(f"{ds}/pallas/fill", 0, str(bsr.aggregate_fill()))
        res["fill"] = bsr.aggregate_fill()
        out[ds] = res
    return out


def run_smoke(json_path: str | None = None) -> dict:
    """Tier-2 CI gate, three parts:

    * backend equivalence on a tiny proxy, all four apps: (min, +) and
      (or, and) apps must match ``scatter`` bitwise, (+, ×) within 1e-5
      (the cross-backend contract the tests pin per superstep; drift is
      the segment path's reassociated float sum);
    * ``segment`` ≥ 2× ``scatter`` PageRank superstep throughput on the
      LJ proxy (the backend the refactor makes the CPU default
      candidate must actually pay for itself);
    * the Pallas layout's ELL fill stats on the LJ proxy (padding/fill
      accounting of the degree-sorted blocked adjacency).
    """
    metrics = {}
    csv = CSV("bsp_smoke")

    # -- equivalence on the tiny proxy (pallas included) -------------------
    g = rmat(9, seed=2)
    cl = scaled_paper_cluster(2, 4, g.num_edges)
    rt = _partition(g, cl)
    gaps = _equivalence(rt, iters=10)
    for app, gp in gaps.items():
        tol = 1e-5 if app == "pagerank" else 0.0
        assert gp <= tol, (f"{app}: cross-backend gap {gp:.2e} > {tol} "
                           f"(scatter vs segment/pallas)")
        csv.row(f"equiv/{app}", 0, f"gap={gp:.2e} (tol {tol})")
        metrics[f"bsp/equiv/{app}_gap"] = gp

    # -- segment vs scatter PageRank throughput on the LJ proxy ------------
    g = dataset("LJ", True)
    cl = cluster_for("LJ", g)
    rt = _partition(g, cl)
    edges = int(rt.edge_valid.sum())
    t_sc, _ = median_iqr(_superstep_seconds(rt, "pagerank", "scatter"))
    t_sg, _ = median_iqr(_superstep_seconds(rt, "pagerank", "segment"))
    speed = t_sc / max(t_sg, 1e-9)
    csv.row("lj/pagerank/scatter", t_sc, f"{edges/t_sc/1e6:.2f}Medges/s")
    csv.row("lj/pagerank/segment", t_sg,
            f"{edges/t_sg/1e6:.2f}Medges/s {speed:.2f}x")
    assert speed >= 2.0, (
        f"segment backend PageRank superstep only {speed:.2f}x scatter "
        f"on the LJ proxy (gate: >= 2x)")
    metrics["bsp/pagerank/segment_speedup"] = speed

    # -- Pallas ELL fill stats on the LJ proxy -----------------------------
    fill = rt.local_bsr(block_size=SMOKE_BLOCK).aggregate_fill()
    csv.row("lj/pallas/fill", 0,
            f"block_fill={fill['block_fill']:.3f} "
            f"entry_fill={fill['entry_fill']:.4f} "
            f"ell_k_max={fill['ell_k_max']} bm={fill['block_size']}")
    metrics["bsp/pallas/block_fill"] = fill["block_fill"]
    metrics["bsp/pallas/entry_fill"] = fill["entry_fill"]
    metrics["bsp/pallas/ell_k_max"] = fill["ell_k_max"]

    if json_path:
        write_bench_json(json_path, metrics)
    return metrics


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tier-2 CI gate: backend equivalence + segment "
                         ">= 2x scatter PageRank throughput on the LJ "
                         "proxy + pallas ELL fill stats")
    ap.add_argument("--json", default=None,
                    help="write gateable metrics to this path "
                         "(BENCH_smoke.json for CI)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--with-pallas", action="store_true",
                    help="include pallas in the timing table (TPU hosts; "
                         "on CPU this times the interpreter)")
    args = ap.parse_args()
    print("table/name,us_per_call,derived")
    if args.smoke:
        run_smoke(json_path=args.json)
    else:
        run(quick=not args.full, repeats=args.repeats,
            backends=BACKENDS if args.with_pallas
            else ("scatter", "segment"))
